"""Paper case study (§VI-A): "what is the total taxi payment per window?"

Streams NYC-taxi-like fares (lognormal, diurnal rates) through the paper's
four-layer edge topology — 8 sources → 4 edge → 2 edge → 1 datacenter —
and prints per-window totals with ±2σ bounds at a 10% sampling fraction,
then compares against exact and against the SRS baseline.

    PYTHONPATH=src python examples/taxi_analytics.py [--fraction 0.1]
"""
import argparse

import numpy as np

from repro.data import stream as S
from repro.launch.analytics import run_pipeline

ap = argparse.ArgumentParser()
ap.add_argument("--fraction", type=float, default=0.1)
ap.add_argument("--ticks", type=int, default=10)
args = ap.parse_args()

specs = S.taxi_like()
print(f"taxi-like stream: {len(specs)} zones, "
      f"{sum(s.rate for s in specs):.0f} rides/s offered, "
      f"fraction {args.fraction:.0%}\n")

# all three runs on the production scan engine; telemetry on for the
# headline run so the printed bound is the realized in-graph trajectory
# (repro.obs), not a host-side recompute — answers are bit-identical
# either way
whs = run_pipeline(specs, fraction=args.fraction, ticks=args.ticks,
                   mode="whs", warmup_ticks=2, seed=42, engine="scan",
                   telemetry=True)
srs = run_pipeline(specs, fraction=args.fraction, ticks=args.ticks,
                   mode="srs", warmup_ticks=2, seed=42, engine="scan")
native = run_pipeline(specs, fraction=1.0, ticks=args.ticks,
                      mode="whs", warmup_ticks=2, seed=42, engine="scan")

print(f"{'':14}{'ApproxIoT':>12}{'SRS':>12}{'native':>12}")
print(f"{'accuracy loss':14}{whs['accuracy_loss']:>12.4%}"
      f"{srs['accuracy_loss']:>12.4%}{0.0:>12.4%}")
print(f"{'items kept':14}{whs['bandwidth_fraction']:>12.1%}"
      f"{srs['bandwidth_fraction']:>12.1%}{1.0:>12.1%}")
print(f"{'items/s':14}{whs['throughput_items_s']:>12.0f}"
      f"{srs['throughput_items_s']:>12.0f}"
      f"{native['throughput_items_s']:>12.0f}")
tel = whs["telemetry"]
print(f"\nSUM ≈ {whs['approx_sum']:.4e} ± {tel['bound_2sigma']:.2e} "
      f"(exact {whs['exact_sum']:.4e}, within 2σ: {whs['within_2sigma']}, "
      f"realized rel bound {tel['rel_bound_2sigma']:.4%})")
print(f"speedup vs native: "
      f"{whs['throughput_items_s'] / native['throughput_items_s']:.2f}×")

"""End-to-end driver: train a small LM with the ApproxIoT data plane.

The token stream is stratified by domain; each interval is reservoir-
sampled within a budget and the surviving examples carry weights, so the
weighted loss is an unbiased estimate of the full-stream loss. Trains the
smoke smollm-135m config for a few hundred steps on CPU with
checkpoint/restart and straggler calibration enabled — the same driver
(``repro.launch.train``) runs full configs on a production mesh.

    PYTHONPATH=src python examples/approx_train.py [--steps 200]
"""
import argparse

from repro.launch import train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--fraction", type=float, default=0.5)
args = ap.parse_args()

losses = train.main([
    "--arch", "smollm-135m", "--smoke",
    "--steps", str(args.steps),
    "--batch", "8",
    "--seq", "128",
    "--interval-size", "24",
    "--sampling-fraction", str(args.fraction),
    "--simulate-stragglers", "0.05",     # 5% of shards miss their deadline
    "--ckpt-dir", "/tmp/approx_train_ckpt",
    "--log-every", "20",
])
print(f"\ntrained {len(losses)} steps at sampling fraction "
      f"{args.fraction:.0%} with straggler calibration; "
      f"loss {losses[0]:.3f} → {losses[-1]:.3f}")

"""Quickstart: the declarative pipeline API in ten lines.

One frozen ``PipelineSpec`` declares the paper's whole system — the
8-sources → 4 → 2 → 1 edge topology, the weighted hierarchical sampler
at a 10% budget, and a tenant of standing queries answered at the root
every window. ``compile(spec)`` returns a pure pipeline: explicit
state, one fused device dispatch for the entire epoch.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import (PipelineSpec, SamplerSpec, TelemetrySpec,
                       TopologySpec, compile)
from repro.data import stream as S
from repro.obs import snapshot
from repro.query.registry import QueryRegistry

# -- the whole system, declaratively --------------------------------------
spec = PipelineSpec(
    topology=TopologySpec(fanin=(4, 2, 1), capacity=2048, num_strata=4),
    sampler=SamplerSpec(mode="whs", backend="topk", fraction=0.1),
    tenants=(QueryRegistry().register_sum().register_mean()
             .register_quantile("quantiles", (0.5, 0.99))
             .as_tenant("demo"),),
    telemetry=TelemetrySpec(enabled=True),
)
pipe = compile(spec)
state = pipe.init()

# -- one epoch of the paper's Gaussian sub-streams, one fused dispatch ----
sources = [S.StreamSource(S.paper_gaussian(rates=(200,) * 4), seed=i)
           for i in range(8)]
batch = S.batch_ingest(sources, ticks=8, n_nodes=4, width=2048)
state, wa = pipe.run_epoch(state, pipe.default_key, batch.values,
                           batch.strata, batch.counts)

# -- windowed answers ± rigorous bounds -----------------------------------
rows = pipe.rows(wa)
approx = sum(r["sum"] for r in rows)
kept = sum(r["n_sampled"] for r in rows)
# the realized ±2σ bound comes straight from the in-graph telemetry
# counters (repro.obs) — no host-side recompute over the window rows
tel = snapshot(state)
bound = tel["bound_2sigma"]
print(f"{len(rows)} windows, {kept}/{batch.exact_count} items at the root "
      f"(10% budget, realized hop-0 fraction "
      f"{tel['levels'][0]['effective_fraction']:.1%}), 1 fused dispatch")
print(f"SUM  ≈ {approx:.4e} ± {bound:.2e} (2σ)   exact {batch.exact_sum:.4e}"
      f"  (|err| {abs(approx - batch.exact_sum) / batch.exact_sum:.4%})")
last = rows[-1]
p50, p99 = pipe.answer(last["answers"], "quantiles", tenant="demo")
print(f"standing queries (tenant 'demo', last window): "
      f"sum ≈ {pipe.answer(last['answers'], 'sum', tenant='demo')[0]:.4e}, "
      f"p50 ≈ {p50:.1f}, p99 ≈ {p99:.1f}")
assert abs(approx - batch.exact_sum) <= 1.5 * bound, "outside 3σ!"
print("estimates within bounds — done.")

"""Quickstart: ApproxIoT's weighted hierarchical sampling in 60 lines.

Builds one sampling node, streams four Gaussian sub-streams through it,
and answers ``SUM`` / ``MEAN`` with ±2σ error bounds from a 10% sample —
the paper's core loop (Alg. 1 + 2, §III-D).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import whs, queries
from repro.core.types import IntervalBatch, StratumMeta

NUM_STRATA = 4
CAPACITY = 8192          # interval buffer slots (static shape — it jits)
BUDGET = 819             # ≈10% sampling fraction

# --- one interval of data: four sub-streams with very different scales ---
rng = np.random.default_rng(0)
mus = [10.0, 1_000.0, 10_000.0, 100_000.0]
values = np.concatenate([rng.normal(mu, mu * 0.05, CAPACITY // 4) for mu in mus])
strata = np.repeat(np.arange(4), CAPACITY // 4)

batch = IntervalBatch(
    value=jnp.asarray(values, jnp.float32),
    stratum=jnp.asarray(strata, jnp.int32),
    valid=jnp.ones((CAPACITY,), bool),
    meta=StratumMeta.identity(NUM_STRATA),   # source node: W=1, C=0
)

# --- WHSamp: stratified reservoir sampling within the budget -------------
result = whs.whsamp(jax.random.PRNGKey(0), batch, jnp.float32(BUDGET),
                    NUM_STRATA)

print(f"sampled {int(result.selected.sum())}/{CAPACITY} items "
      f"(budget {BUDGET})")
print("per-stratum reservoirs:", np.asarray(result.reservoir, int).tolist())
print("per-stratum weights:   ",
      [f"{w:.1f}" for w in np.asarray(result.meta.weight)])

# --- linear queries with rigorous error bounds ----------------------------
s = queries.weighted_sum(batch, result, NUM_STRATA)
m = queries.weighted_mean(batch, result, NUM_STRATA)
exact_sum = float(values.sum())
exact_mean = float(values.mean())

print(f"\nSUM  ≈ {float(s.estimate):.4e} ± {float(s.bound(2)):.2e} (2σ)"
      f"   exact {exact_sum:.4e}  "
      f"(|err| {abs(float(s.estimate) - exact_sum) / exact_sum:.4%})")
print(f"MEAN ≈ {float(m.estimate):.2f} ± {float(m.bound(2)):.2f} (2σ)"
      f"      exact {exact_mean:.2f}")
assert abs(float(s.estimate) - exact_sum) <= float(s.bound(3)), "outside 3σ!"
print("\nestimates within bounds — done.")

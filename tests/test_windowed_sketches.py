"""Windowed / time-decayed sketch acceptance tests (ISSUE 9).

The load-bearing law: over a drifting stream, the recency variants
(sliding-window KLL ring, exponentially decayed count-min) track the
RECENT distribution where the stream-so-far sketches provably do not —
pinned both at the sketch layer and end-to-end through the compiled
query plan (distribution shift mid-run).
"""
from __future__ import annotations

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro import api  # noqa: E402
from repro.data import stream as S  # noqa: E402
from repro.query import sketches  # noqa: E402
from repro.query.registry import QueryRegistry, QuerySpec  # noqa: E402


# ------------------------------------------------------------ sketch layer --


def test_windowed_quantile_tracks_recent_where_plain_lags():
    key = jax.random.PRNGKey(0)
    plain = sketches.quantile_init(128)
    ring = sketches.windowed_quantile_init(128, window=4)
    rng = np.random.default_rng(1)
    # 12 windows at μ=10, then 8 windows at μ=100
    for w in range(20):
        mu = 10.0 if w < 12 else 100.0
        v = jnp.asarray(rng.normal(mu, 1.0, 200).astype(np.float32))
        ones = jnp.ones_like(v)
        kw = jax.random.fold_in(key, w)
        plain = sketches.quantile_update(kw, plain, v, ones)
        ring = sketches.windowed_quantile_update(kw, ring, v, ones)
    q = jnp.asarray([0.5])
    plain_med = float(sketches.quantile_query(plain, q)[0])
    merged = sketches.windowed_quantile_merged(key, ring)
    ring_med = float(sketches.quantile_query(merged, q)[0])
    # the ring only remembers the last 4 windows — all post-shift
    assert abs(ring_med - 100.0) < 5.0
    # the stream-so-far sketch still answers from the 12 old windows
    assert abs(plain_med - 100.0) > 20.0


def test_windowed_quantile_ring_evicts_in_fifo_order():
    key = jax.random.PRNGKey(2)
    ring = sketches.windowed_quantile_init(64, window=2)
    ones = jnp.ones((64,), jnp.float32)
    for w, mu in enumerate([1.0, 2.0, 3.0, 4.0]):
        ring = sketches.windowed_quantile_update(
            jax.random.fold_in(key, w), ring,
            jnp.full((64,), mu, jnp.float32), ones)
    merged = sketches.windowed_quantile_merged(key, ring)
    med = float(sketches.quantile_query(merged, jnp.asarray([0.5]))[0])
    # windows 1.0 and 2.0 were evicted; only 3.0 / 4.0 remain
    assert med in (3.0, 4.0)
    assert int(ring.head) == 0          # wrapped twice
    assert ring.window == 2 and ring.capacity == 64


def test_decayed_counts_flip_to_new_heavy_key_where_plain_does_not():
    old = jnp.full((64,), 7.0, jnp.float32)
    new = jnp.full((16,), 42.0, jnp.float32)
    ones_old = jnp.ones_like(old)
    ones_new = jnp.ones_like(new)
    plain = sketches.hh_init(k=2, width=256, depth=4)
    dec = sketches.hh_init(k=2, width=256, depth=4)
    # 10 heavy windows of key 7, then 6 light windows of key 42
    for _ in range(10):
        plain = sketches.hh_update(plain, sketches.hh_item_key(old),
                                   ones_old)
        dec = sketches.hh_decayed_update(dec, sketches.hh_item_key(old),
                                         ones_old, decay=0.5)
    for _ in range(6):
        plain = sketches.hh_update(plain, sketches.hh_item_key(new),
                                   ones_new)
        dec = sketches.hh_decayed_update(dec, sketches.hh_item_key(new),
                                         ones_new, decay=0.5)
    # stream-so-far: 640 of key 7 vs 96 of key 42 — old key stays on top
    assert int(plain.key[0]) == 7
    # decayed: old mass halved every window since the shift — new key wins
    assert int(dec.key[0]) == 42
    # decayed total weight reflects the decayed stream, not the raw count
    assert float(dec.total_weight) < float(plain.total_weight)


def test_decayed_update_is_linear_in_the_counts():
    # γ·(A+B) + a + b == (γ·A + a) + (γ·B + b): the decayed CM stays
    # psum-mergeable across devices
    ka = jnp.full((8,), 3.0, jnp.float32)
    kb = jnp.full((8,), 9.0, jnp.float32)
    ones = jnp.ones((8,), jnp.float32)
    merged = sketches.hh_init(k=2, width=128, depth=4)
    a = sketches.hh_init(k=2, width=128, depth=4)
    b = sketches.hh_init(k=2, width=128, depth=4)
    for _ in range(3):
        merged = sketches.hh_decayed_update(
            merged, sketches.hh_item_key(jnp.concatenate([ka, kb])),
            jnp.ones((16,), jnp.float32), decay=0.7)
        a = sketches.hh_decayed_update(a, sketches.hh_item_key(ka), ones,
                                       decay=0.7)
        b = sketches.hh_decayed_update(b, sketches.hh_item_key(kb), ones,
                                       decay=0.7)
    np.testing.assert_allclose(np.asarray(merged.counts),
                               np.asarray(a.counts) + np.asarray(b.counts),
                               rtol=1e-6)


# ---------------------------------------------------------------- registry --


def test_registry_windowed_and_decayed_specs():
    reg = (QueryRegistry()
           .register_windowed_quantile("wq", qs=(0.5, 0.9), capacity=64,
                                       window=3)
           .register_decayed_heavy_hitters("dhh", k=4, width=256,
                                           decay=0.8))
    wq, dhh = reg.specs
    assert wq.out_width == 2 and wq.window == 3
    assert dhh.out_width == 8 and dhh.decay == 0.8
    with pytest.raises(ValueError, match="window"):
        QuerySpec("bad", "windowed_quantile", qs=(0.5,), window=0)
    with pytest.raises(ValueError, match="decay"):
        QuerySpec("bad", "decayed_heavy_hitters", decay=1.0)
    with pytest.raises(ValueError, match="qs"):
        QuerySpec("bad", "windowed_quantile")
    with pytest.raises(ValueError, match="2\\^n"):
        QuerySpec("bad", "decayed_heavy_hitters", width=100)


def test_registry_token_language_parses_new_kinds():
    reg = QueryRegistry.from_tokens("wq:0.5:0.99,dhh:4:0.7,sum")
    wq, dhh, _ = reg.specs
    assert wq.kind == "windowed_quantile" and wq.qs == (0.5, 0.99)
    assert dhh.kind == "decayed_heavy_hitters"
    assert dhh.k == 4 and dhh.decay == 0.7
    with pytest.raises(ValueError, match="malformed query token"):
        QueryRegistry.from_tokens("wq:not-a-number")


def test_spec_roundtrip_keeps_new_fields():
    spec = api.PipelineSpec(
        topology=api.TopologySpec(fanin=(2, 1), capacity=128, num_strata=2),
        sampler=api.SamplerSpec(mode="whs", backend="topk", fraction=1.0),
        tenants=(QueryRegistry()
                 .register_windowed_quantile("wq", qs=(0.5,), window=5)
                 .register_decayed_heavy_hitters("dhh", decay=0.75)
                 .as_tenant("t"),), seed=0)
    assert api.PipelineSpec.from_dict(spec.to_dict()) == spec


# ------------------------------------------------- end-to-end (compiled) --


def test_pipeline_drift_regression_recent_vs_stream_so_far():
    """The ISSUE 9 acceptance regression: a mid-run distribution shift.
    The windowed quantile and decayed top-k track the NEW regime; the
    stream-so-far quantile and plain top-k provably answer from the old
    one."""
    reg = (QueryRegistry()
           .register_quantile("q_all", qs=(0.5,), capacity=64)
           .register_windowed_quantile("q_recent", qs=(0.5,), capacity=64,
                                       window=4)
           .register_heavy_hitters("hh_all", k=2, width=256)
           .register_decayed_heavy_hitters("hh_recent", k=2, width=256,
                                           decay=0.5))
    spec = api.PipelineSpec(
        topology=api.TopologySpec(fanin=(2, 1), capacity=128, num_strata=2),
        sampler=api.SamplerSpec(mode="whs", backend="topk", fraction=1.0),
        tenants=(reg.as_tenant("t"),), seed=0)
    pipe = api.compile(spec)
    state = pipe.init()
    rng = np.random.default_rng(0)
    ticks = []
    for t in range(24):
        # 16 windows around key 10, then 8 around key 100
        mu = 10.0 if t < 16 else 100.0
        v = rng.normal(mu, 0.5, 48).astype(np.float32)
        s = (np.arange(48) % 2).astype(np.int32)
        ticks.append((v, s))
    batch = S.ticks_to_ingest(ticks, n_nodes=2, width=128)
    state, wa = pipe.run_epoch(state, pipe.default_key, batch.values,
                               batch.strata, batch.counts)
    last = pipe.rows(wa)[-1]
    q_all = float(pipe.answer(last["answers"], "q_all")[0])
    q_recent = float(pipe.answer(last["answers"], "q_recent")[0])
    hh_all = float(pipe.answer(last["answers"], "hh_all")[0])
    hh_recent = float(pipe.answer(last["answers"], "hh_recent")[0])
    # recency queries live in the new regime...
    assert abs(q_recent - 100.0) < 5.0
    assert abs(hh_recent - 100.0) <= 1.0
    # ...stream-so-far queries still answer from the old one
    assert abs(q_all - 10.0) < 5.0
    assert abs(hh_all - 10.0) <= 1.0
    # the windowed bound is the merged summary's live rank error
    assert float(pipe.answer(last["bounds"], "q_recent")[0]) >= 0.0


def test_windowed_and_decayed_lower_through_spmd_plan():
    """The same kinds answer on the mesh path (single-device mesh run:
    exercises the all-gather/psum merge lowering)."""
    from repro.launch.analytics import make_data_mesh

    reg = (QueryRegistry()
           .register_windowed_quantile("wq", qs=(0.5,), capacity=64,
                                       window=2)
           .register_decayed_heavy_hitters("dhh", k=2, width=256,
                                           decay=0.5))
    spec = api.PipelineSpec(
        topology=api.TopologySpec(fanin=(1, 1), capacity=128, num_strata=2),
        sampler=api.SamplerSpec(mode="whs", backend="topk", fraction=1.0),
        tenants=(reg.as_tenant("t"),), seed=0)
    pipe = api.compile(spec, mesh=make_data_mesh(1))
    rng = np.random.default_rng(3)
    rows_v = np.zeros((8, 64), np.float32)
    rows_s = np.zeros((8, 64), np.int32)
    counts = np.full((8,), 64, np.int32)
    for t in range(8):
        mu = 5.0 if t < 6 else 50.0
        rows_v[t] = rng.normal(mu, 0.5, 64).astype(np.float32)
        rows_s[t] = np.arange(64) % 2
    batch = S.rows_to_interval_batch(rows_v, rows_s, counts, 2)
    state = pipe.init()
    state, wa = pipe.run_epoch(state, pipe.default_key, batch)
    last = pipe.rows(wa)[-1]
    wq = float(pipe.answer(last["answers"], "wq")[0])
    dhh = float(pipe.answer(last["answers"], "dhh")[0])
    assert abs(wq - 50.0) < 5.0          # ring holds the last 2 windows
    assert abs(dhh - 50.0) <= 1.0        # decayed top-1 is the new key

"""Adaptive stratification (PR 10): allocation conservation across every
policy and backend, the one-row unbiasedness reserve, the StratumManager
split/merge planner, the Eq. 9 metadata remap, and the zero-retrace
contract for route edits. Deterministic (no hypothesis) so the pins run
everywhere; ``tests/test_sampling.py`` carries hypothesis variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.api.spec import (BudgetSpec, PipelineSpec, SamplerSpec, SpecError,
                            StrataSpec, TopologySpec)
from repro.core import sampling
from repro.strata import StratumManager, remap_tree_state

X = 4
POLICIES = ("fair", "proportional", "neyman")


def _alloc(policy, budget, counts, stds=None):
    if policy == "neyman" and stds is None:
        stds = jnp.ones((len(counts),), jnp.float32)
    return np.asarray(sampling.allocate_reservoirs(
        jnp.float32(budget), jnp.asarray(counts, jnp.float32),
        policy=policy, stds=stds))


# ------------------------------------------------------------ allocation --
def test_allocation_conserves_budget_exactly_all_policies():
    """Σ alloc == min(budget, Σ counts) BITWISE, 0 ≤ alloc_i ≤ c_i — the
    PR-10 conservation bugfix pin, over a seeded sweep of shapes,
    budgets and skews (zero budget, empty strata, saturation included)."""
    for seed in range(40):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, 9))
        counts = rng.integers(0, 500, n).astype(np.float32)
        budget = float(rng.integers(0, 3000))
        stds = np.abs(rng.normal(1, 5, n)).astype(np.float32)
        for policy in POLICIES:
            alloc = _alloc(policy, budget, counts, jnp.asarray(stds))
            assert float(alloc.sum()) == min(budget, float(counts.sum())), (
                policy, seed, counts, alloc)
            assert (alloc <= counts).all(), (policy, seed, counts, alloc)
            assert (alloc >= 0).all(), (policy, seed, counts, alloc)


def test_allocation_never_starves_active_strata():
    """Budget ≥ #active ⇒ every non-empty stratum gets ≥ 1 row (the
    one-row reserve: without it a rare stratum's quota/score rounds to
    zero and its items drop with NO weight — bias, not variance)."""
    for seed in range(25):
        rng = np.random.default_rng(100 + seed)
        n = int(rng.integers(2, 9))
        counts = rng.integers(0, 10_000, n).astype(np.float32)
        budget = int(max((counts > 0).sum(), 1)) + int(rng.integers(0, 200))
        stds = np.abs(rng.normal(0, 3, n)).astype(np.float32)
        for policy in POLICIES:
            alloc = _alloc(policy, budget, counts, jnp.asarray(stds))
            assert (alloc[counts > 0] >= 1).all(), (policy, counts, alloc)
            assert (alloc[counts == 0] == 0).all(), (policy, counts, alloc)


def test_rare_stratum_kept_under_skew_shares():
    """The Fig. 11c regime at fraction 0.1: stratum D is ~0.01% of the
    items but carries most of the value mass — every policy must keep
    its reservoir non-empty."""
    from repro.data import stream as S

    rng = np.random.default_rng(7)
    rates = np.array([8000 * sh for sh in S.SKEW_SHARES])
    counts = rng.poisson(rates * 2).astype(np.float32)
    counts[3] = max(counts[3], 1.0)
    budget = 0.1 * counts.sum()
    stds = jnp.asarray([3.2, 9.9, 120.0, 0.0])
    for policy in POLICIES:
        alloc = _alloc(policy, budget, counts, stds)
        assert alloc[3] >= 1, (policy, counts, alloc)


def test_allocation_conserves_inside_fused_kernel():
    """The fused Pallas tick's in-kernel allocation conserves the budget
    bitwise and matches the XLA ref oracle for every policy (neyman's
    stds come from a one-hot ``dot_general`` inside the kernel)."""
    from repro.kernels.fused_level_tick import ops as ft_ops

    rng = np.random.default_rng(3)
    n, cap = 2, 256
    vals = rng.normal(60, 25, (n, cap)).astype(np.float32)
    strata = rng.choice(X, size=(n, cap),
                        p=[0.80, 0.1899, 0.01, 0.0001]).astype(np.int32)
    strata[:, -1] = 3                       # rare stratum present
    valid = np.ones((n, cap), bool)
    u = rng.random((n, cap)).astype(np.float32)
    w_in = np.ones((n, X), np.float32)
    c_in = np.zeros((n, X), np.float32)
    for policy in POLICIES:
        outs = {
            impl: ft_ops.fused_level_tick(
                jnp.asarray(vals), jnp.asarray(strata), jnp.asarray(valid),
                jnp.asarray(u), jnp.asarray(w_in), jnp.asarray(c_in),
                jnp.float32(40.0), X, cap, allocation=policy, impl=impl)
            for impl in ("pallas", "ref")}
        res_p = np.asarray(outs["pallas"][5])
        np.testing.assert_array_equal(res_p, np.asarray(outs["ref"][5]),
                                      err_msg=policy)
        c = np.asarray(outs["pallas"][4])
        for node in range(n):
            assert float(res_p[node].sum()) == min(
                40.0, float(c[node].sum())), (policy, node)
            assert res_p[node][3] >= 1, (policy, res_p[node])


def test_stratum_stats_matches_sampling_stds():
    """The query plane's shared-moments view of per-stratum stds agrees
    with the sampler's (``core.sampling.stratum_stds``) on one window."""
    from repro.core.types import IntervalBatch, StratumMeta
    from repro.query.compiler import stratum_stats

    rng = np.random.default_rng(11)
    m = 512
    vals = jnp.asarray(rng.normal(30, 12, m), jnp.float32)
    strata = jnp.asarray(rng.integers(0, X, m), jnp.int32)
    valid = jnp.asarray(rng.random(m) < 0.8)
    batch = IntervalBatch(vals, strata, valid, StratumMeta.identity(X))
    _, _, stds_q = stratum_stats(batch, X)
    stds_s = sampling.stratum_stds(vals, strata, valid, X)
    np.testing.assert_allclose(np.asarray(stds_q), np.asarray(stds_s),
                               rtol=1e-6)


# --------------------------------------------------------------- manager --
def test_manager_splits_hot_and_merges_starved():
    """Coarse route: one hot multi-key slot splits onto a spare slot
    (heaviest key stays put); a starved slot folds into the lightest
    active one. The committed route stays a valid key→slot table."""
    route = np.array([0, 0, 0, 0, 0, 0, 1, 2], np.int32)   # slot 3 spare
    m = StratumManager(route, 4, split_occupancy=1.5, merge_occupancy=0.1)
    m.observe(np.array([8000, 2000, 500, 300, 100, 50, 900, 2]))
    ops = m.maybe_adapt()
    kinds = sorted(op.kind for op in ops)
    assert kinds == ["merge", "split"], ops
    split = next(op for op in ops if op.kind == "split")
    assert 0 not in split.keys              # heaviest key stays in slot 0
    assert 0.0 < split.share < 1.0
    assert m.route.min() >= 0 and m.route.max() < 4
    # hot slot actually lost mass
    assert m.slot_occupancy()[0] < 8000 + 2950


def test_manager_mass_guard_protects_heavy_rare_stratum():
    """A slot that is rare by count but carries most of the value mass
    (the SKEW_SHARES stratum D) must never be merged away — folding its
    huge items behind a shared sampling weight is a variance cliff."""
    route = np.arange(4, dtype=np.int32)
    m = StratumManager(route, 4, merge_occupancy=0.1)
    counts = np.array([64000.0, 16000.0, 8.0, 1.0])
    mass = np.array([640e3, 1.6e6, 8e3, 10e6])   # D: one 10M item
    m.observe(counts, mass)
    ops = m.maybe_adapt()
    for op in ops:
        assert op.src != 3, ops                  # D never a merge source
    # without the mass signal the same counts DO merge D away
    m2 = StratumManager(route, 4, merge_occupancy=0.1)
    m2.observe(counts)
    assert any(op.src == 3 for op in m2.maybe_adapt())


def test_manager_idempotent_when_balanced():
    m = StratumManager(np.arange(4, dtype=np.int32), 4)
    m.observe(np.array([100.0, 120.0, 90.0, 110.0]))
    assert m.maybe_adapt() == []
    np.testing.assert_array_equal(m.route, np.arange(4))


# ----------------------------------------------------------------- remap --
def _seeded_state(pipe):
    st = pipe.init()
    rng = np.random.default_rng(5)
    f = lambda shape: jnp.asarray(np.abs(rng.normal(2, 1, shape)),
                                  jnp.float32)
    tree = st.tree._replace(
        w_in=tuple(f(a.shape) for a in st.tree.w_in),
        c_in=tuple(f(a.shape) * 50 for a in st.tree.c_in),
        wc_acc=tuple(f(a.shape) * 10 for a in st.tree.wc_acc),
        c_acc=tuple(f(a.shape) * 50 for a in st.tree.c_acc),
        seen=tuple(jnp.ones(a.shape, bool) for a in st.tree.seen))
    return st._replace(tree=tree)


def _routed_spec(num_keys=8, adaptive=False):
    return PipelineSpec(
        topology=TopologySpec(fanin=(4, 2, 1), capacity=512, num_strata=X),
        sampler=SamplerSpec(mode="whs", backend="topk"),
        budget=BudgetSpec(sample_sizes=(64, 64, 64)),
        strata=StrataSpec(num_keys=num_keys, adaptive=adaptive),
        seed=9)


def test_remap_conserves_calibration_mass():
    """Across any split/merge sequence the per-level ΣC^in, Σwc_acc and
    Σc_acc are conserved exactly, shapes/dtypes never change, and merge
    weights are the count-weighted mean (the ``core.window`` merge law)."""
    pipe = api.compile(_routed_spec())
    st = _seeded_state(pipe)
    m = StratumManager(np.asarray(st.tree.route), X,
                       split_occupancy=1.2, merge_occupancy=0.2)
    kc = np.array([9000, 4000, 2500, 800, 30, 10, 4, 1], np.float64)
    m.observe(kc, kc)          # mass ∝ counts: the starved slot is truly cold
    ops = m.maybe_adapt()
    assert ops, "constructed skew must trigger at least one op"
    new_tree = remap_tree_state(st.tree, ops, m.route)
    for name in ("w_in", "c_in", "wc_acc", "c_acc", "seen"):
        for a, b in zip(getattr(st.tree, name), getattr(new_tree, name)):
            assert a.shape == b.shape and a.dtype == b.dtype, name
    for name in ("c_in", "wc_acc", "c_acc"):
        for a, b in zip(getattr(st.tree, name), getattr(new_tree, name)):
            np.testing.assert_allclose(float(jnp.sum(a)), float(jnp.sum(b)),
                                       rtol=1e-5, err_msg=name)


def test_split_merge_zero_retrace():
    """Committing a route remap between epochs reuses the compiled
    program — the padded-slot contract extended to stratification. Both
    the trace counter and the program cache are pinned."""
    from repro.api.pipeline import program_cache_stats

    pipe = api.compile(_routed_spec())
    rng = np.random.default_rng(2)
    ticks, n0, width = 2, 4, 300
    vals = rng.normal(50, 9, (ticks, n0, width)).astype(np.float32)
    strs = rng.integers(0, 8, (ticks, n0, width)).astype(np.int32)
    counts = rng.integers(100, width, (ticks, n0)).astype(np.int32)
    st = pipe.init()
    st, wa0 = pipe.run_epoch(st, pipe.default_key, vals, strs, counts)
    traces = pipe.trace_counter["traces"]
    misses = program_cache_stats()["misses"]
    m = StratumManager(np.asarray(st.tree.route), X,
                       split_occupancy=1.2, merge_occupancy=0.2)
    kc = np.array([9000, 4000, 2500, 800, 30, 10, 4, 1], np.float64)
    m.observe(kc, kc)          # mass ∝ counts: the starved slot is truly cold
    ops = m.maybe_adapt()
    assert ops
    st = st._replace(tree=remap_tree_state(st.tree, ops, m.route))
    st, wa1 = pipe.run_epoch(st, pipe.default_key, vals, strs, counts)
    assert pipe.trace_counter["traces"] == traces, "route edit retraced!"
    assert program_cache_stats()["misses"] == misses
    assert np.isfinite(np.asarray(wa1.sum)).all()


def test_identity_route_is_bitwise_noop():
    """A pipeline with the identity routing table produces bit-identical
    windows to one compiled without routing (the gather really is a
    no-op, not merely statistically neutral)."""
    rng = np.random.default_rng(8)
    ticks, n0, width = 2, 4, 300
    vals = rng.normal(50, 9, (ticks, n0, width)).astype(np.float32)
    strs = rng.integers(0, X, (ticks, n0, width)).astype(np.int32)
    counts = rng.integers(100, width, (ticks, n0)).astype(np.int32)
    outs = {}
    for label, keys in (("routed", X), ("plain", 0)):
        pipe = api.compile(_routed_spec(num_keys=keys))
        st = pipe.init()
        st, wa = pipe.run_epoch(st, pipe.default_key, vals, strs, counts)
        outs[label] = wa
    for field in ("sum", "sum_var", "n_sampled", "histogram"):
        np.testing.assert_array_equal(
            np.asarray(getattr(outs["routed"], field)),
            np.asarray(getattr(outs["plain"], field)), err_msg=field)


def test_coarse_route_remains_unbiased():
    """Routing 8 keys onto 4 slots (and then remapping mid-run) keeps the
    windowed SUM estimate unbiased: the estimate stays within its own
    ±2σ bound of the exact ingest sum."""
    pipe = api.compile(_routed_spec())
    rng = np.random.default_rng(21)
    ticks, n0, width = 4, 4, 300
    st = pipe.init()
    # coarse initial table: key k → slot k % 4 (two keys per slot)
    total, est, var = 0.0, 0.0, 0.0
    m = StratumManager(np.asarray(st.tree.route), X)
    for epoch in range(2):
        vals = np.abs(rng.normal(50, 9, (ticks, n0, width))).astype(
            np.float32)
        strs = rng.integers(0, 8, (ticks, n0, width)).astype(np.int32)
        counts = rng.integers(100, width, (ticks, n0)).astype(np.int32)
        mask = np.arange(width)[None, None, :] < counts[..., None]
        total += float(vals[mask].sum())
        st, wa = pipe.run_epoch(st, pipe.default_key, vals, strs, counts)
        est += float(np.asarray(wa.sum).sum())
        var += float(np.asarray(wa.sum_var).sum())
        keys = strs[mask]
        m.observe(np.bincount(keys, minlength=8),
                  np.bincount(keys, minlength=8,
                              weights=np.abs(vals[mask])))
        ops = m.maybe_adapt()
        if ops:
            st = st._replace(tree=remap_tree_state(st.tree, ops, m.route))
    assert abs(est - total) <= max(2.0 * np.sqrt(var), 0.02 * total), (
        est, total)


# ------------------------------------------------------------------ spec --
def test_strata_spec_validation():
    with pytest.raises(SpecError):
        StrataSpec(num_keys=0, adaptive=True)     # adaptive needs a table
    with pytest.raises(SpecError):
        StrataSpec(num_keys=4, split_occupancy=0.5)
    with pytest.raises(SpecError):
        StrataSpec(num_keys=4, merge_occupancy=1.5)
    s = _routed_spec(num_keys=8, adaptive=True)
    rt = PipelineSpec.from_dict(s.to_dict())
    assert rt.strata == s.strata


def test_run_pipeline_adaptive_end_to_end():
    """The analytics driver's epoch hook: adaptive run commits ops,
    reports the final route, and stays at least as accurate as the
    static-fair arm on the skewed stream."""
    from repro.api.spec import StrataSpec as SS
    from repro.data import stream as S
    from repro.launch.analytics import run_pipeline

    specs = S.paper_poisson(
        rates=tuple(8000 * sh for sh in S.SKEW_SHARES), skewed=True)
    kw = dict(fraction=0.1, ticks=4, seed=2, mode="whs", engine="scan",
              warmup_ticks=1, epoch_ticks=2)
    r_fair = run_pipeline(specs, allocation="fair", **kw)
    r_ad = run_pipeline(specs, allocation="neyman",
                        strata=SS(num_keys=len(specs), adaptive=True), **kw)
    assert "strata_ops" in r_ad and "strata_route" in r_ad
    assert len(r_ad["strata_route"]) == len(specs)
    assert r_ad["accuracy_loss"] <= max(r_fair["accuracy_loss"], 1e-3)

"""Declarative pipeline API: spec validation + serialization, compiled
``init``/``step``/``run_epoch`` bit-equivalence with every legacy
``HostTree`` engine, multi-tenant answer routing ≡ isolated runs,
checkpoint/resume bitwise identity, the back-compat shim, and the SPMD
lowering of the same spec."""
import jax
import numpy as np
import pytest

from repro import api
from repro.api import (BudgetSpec, PipelineSpec, SamplerSpec, SpecError,
                       TenantSpec, TopologySpec)
from repro.core.tree import HostTree
from repro.data import stream as S
from repro.query.registry import QueryRegistry, QuerySpec

X = 3


def _spec(mode="whs", backend="topk", tenants=(), iv=None, seed=5,
          sizes=(96, 96, 96), capacity=768, max_sizes=None):
    return PipelineSpec(
        topology=TopologySpec(fanin=(4, 2, 1), capacity=capacity,
                              interval_ticks=iv, num_strata=X),
        sampler=SamplerSpec(mode=mode, backend=backend,
                            fraction=0.25 if mode == "srs" else None),
        tenants=tuple(tenants),
        budget=BudgetSpec(sample_sizes=sizes, max_sample_sizes=max_sizes),
        seed=seed,
    )


def _legacy_tree(spec: PipelineSpec, engine: str) -> HostTree:
    """The old constructor path (NOT from_spec) — what pre-API callers
    wrote, for shim equivalence checks."""
    return HostTree(
        fanin=list(spec.topology.fanin), num_strata=X,
        capacity=spec.topology.capacity,
        sample_sizes=list(spec.budget.sample_sizes),
        interval_ticks=(list(spec.topology.interval_ticks)
                        if spec.topology.interval_ticks else None),
        seed=spec.seed, mode=spec.sampler.mode,
        fraction=spec.sampler.fraction, engine=engine,
        sampler_backend=spec.sampler.backend,
        queries=(QueryRegistry(list(spec.tenants[0].queries))
                 if spec.tenants else None),
        max_sample_sizes=(list(spec.budget.max_sample_sizes)
                          if spec.budget.max_sample_sizes else None))


def _ingest(ticks, n0=4, width=400, seed=11):
    rng = np.random.default_rng(seed)
    vals = rng.normal(50, 9, (ticks, n0, width)).astype(np.float32)
    strs = rng.integers(0, X, (ticks, n0, width)).astype(np.int32)
    counts = rng.integers(100, width, (ticks, n0)).astype(np.int32)
    return vals, strs, counts


def _run_sequential(tree, vals, strs, counts):
    ticks, n0, _ = vals.shape
    for t in range(1, ticks + 1):
        for node in range(n0):
            c = counts[t - 1, node]
            tree.ingest(node, vals[t - 1, node, :c], strs[t - 1, node, :c])
        tree.tick(t)


def _assert_rows_equal(rows, ref_results):
    assert len(rows) == len(ref_results) > 0
    for ra, rb in zip(rows, ref_results):
        for k in ("tick", "sum", "sum_var", "mean", "mean_var", "n_sampled"):
            assert ra[k] == rb[k], k
        np.testing.assert_array_equal(ra["histogram"], rb["histogram"])
        if "answers" in rb:
            np.testing.assert_array_equal(ra["answers"], rb["answers"])
            np.testing.assert_array_equal(ra["bounds"], rb["bounds"])


def _reg_a():
    return (QueryRegistry().register_sum().register_mean()
            .register_quantile("q", (0.5, 0.9), capacity=64))


def _reg_b():
    return (QueryRegistry().register_count()
            .register_histogram("h", 0.0, 100.0, 8)
            .register_heavy_hitters("hh", k=4, width=256))


# ------------------------------------------------- old ≡ new, bitwise --
@pytest.mark.parametrize("engine,mode,backend", [
    ("loop", "whs", "topk"),
    ("level", "whs", "topk"),
    ("scan", "whs", "topk"),
    ("loop", "srs", "topk"),
    ("scan", "srs", "topk"),
    ("scan", "whs", "argsort"),
    ("level", "whs", "argsort"),
])
def test_compiled_matches_host_tree(engine, mode, backend):
    """compile(spec).run_epoch ≡ the pre-refactor HostTree engines, to
    the bit (results, forwarded counts) on identical ingest."""
    vals, strs, counts = _ingest(4)
    spec = _spec(mode=mode, backend=backend)
    ref = _legacy_tree(spec, engine)
    if engine == "scan":
        ref.run_epoch(1, vals, strs, counts)
    else:
        _run_sequential(ref, vals, strs, counts)
    pipe = api.compile(spec)
    state, wa = pipe.run_epoch(pipe.init(), pipe.default_key, vals, strs,
                               counts)
    _assert_rows_equal(pipe.rows(wa), ref.results)
    n_fwd = np.asarray(wa.n_forwarded)
    fwd = [int(n_fwd[:, l].sum()) for l in range(len(pipe.fanin) - 1)] + [0]
    assert fwd == ref.items_forwarded


def test_compiled_matches_host_tree_async_intervals():
    vals, strs, counts = _ingest(6)
    spec = _spec(iv=(1, 2, 3))
    ref = _legacy_tree(spec, "loop")
    _run_sequential(ref, vals, strs, counts)
    pipe = api.compile(spec)
    _, wa = pipe.run_epoch(pipe.init(), pipe.default_key, vals, strs, counts)
    _assert_rows_equal(pipe.rows(wa), ref.results)


def test_compiled_sample_state_matches_scan_engine():
    """The donated PipelineState.tree is bit-identical to the HostTree
    scan engine's TreeState after the same epoch."""
    vals, strs, counts = _ingest(4)
    spec = _spec()
    ref = _legacy_tree(spec, "scan")
    ref.run_epoch(1, vals, strs, counts)
    pipe = api.compile(spec)
    state, _ = pipe.run_epoch(pipe.init(), pipe.default_key, vals, strs,
                              counts)
    for la, lb in zip(jax.tree.leaves(state.tree),
                      jax.tree.leaves(ref._state)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_fig_config_pipeline_matches_host_tree():
    """The fig7/fig8 configuration (paper_gaussian + the standing-query
    registry through run_pipeline's spec builder): compiled answers ≡
    the HostTree scan engine bitwise."""
    from repro.launch.analytics import build_spec

    reg = (QueryRegistry().register_sum().register_count()
           .register_quantile("q", (0.5, 0.9, 0.99), capacity=128))
    streams = S.paper_gaussian(rates=(300, 300, 300, 300))
    spec = build_spec(streams, fraction=0.1, seed=7, queries=reg)
    sources = [S.StreamSource(streams, seed=7 * 977 + i) for i in range(8)]
    b = S.batch_ingest(sources, 5, 4, spec.topology.capacity)

    ref = HostTree.from_spec(spec, engine="scan")
    ref.run_epoch(1, b.values, b.strata, b.counts, offered=b.offered)
    pipe = api.compile(spec)
    _, wa = pipe.run_epoch(pipe.init(), pipe.default_key, b.values,
                           b.strata, b.counts)
    _assert_rows_equal(pipe.rows(wa), ref.results)


def test_step_equals_run_epoch():
    """T single-tick step() calls ≡ one T-tick run_epoch (same fused
    tick at the level/loop dispatch granularity)."""
    vals, strs, counts = _ingest(3)
    pipe = api.compile(_spec())
    sa = pipe.init()
    rows_stepped = []
    for t in range(3):
        sa, wa = pipe.step(sa, pipe.default_key, vals[t], strs[t], counts[t])
        rows_stepped.extend(pipe.rows(wa))
    sb, wb = pipe.run_epoch(pipe.init(), pipe.default_key, vals, strs,
                            counts)
    _assert_rows_equal(rows_stepped, pipe.rows(wb))
    for la, lb in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_budgets_are_traced_zero_retrace():
    """Moving per-level budgets between epochs reuses the compiled
    program (the closed-loop controller's zero-retrace contract)."""
    vals, strs, counts = _ingest(2)
    pipe = api.compile(_spec(sizes=(64, 64, 64), max_sizes=(96, 96, 96)))
    st = pipe.init()
    st, _ = pipe.run_epoch(st, pipe.default_key, vals, strs, counts)
    traces = pipe.trace_counter["traces"]
    st, _ = pipe.run_epoch(st, pipe.default_key, vals, strs, counts,
                           budgets=[96, 80, 72])
    assert pipe.trace_counter["traces"] == traces
    # ...and clamped to the provisioned ceilings
    assert pipe.clamp_budgets([500, 0.2, 80]) == [96.0, 1.0, 80.0]


# ------------------------------------------------------- multi-tenant --
def test_two_tenants_match_isolated_single_tenant_runs():
    """A 2-tenant spec returns per-tenant answers matching isolated
    single-tenant runs bitwise, while sharing ONE tree dispatch per
    epoch (identical sample state, one fused answer vector)."""
    vals, strs, counts = _ingest(4)
    both = api.compile(_spec(tenants=(_reg_a().as_tenant("alpha"),
                                      _reg_b().as_tenant("beta"))))
    alpha = api.compile(_spec(tenants=(_reg_a().as_tenant("alpha"),)))
    beta = api.compile(_spec(tenants=(_reg_b().as_tenant("beta"),)))

    run = lambda p: p.run_epoch(p.init(), p.default_key, vals, strs, counts)
    s2, w2 = run(both)
    sa, wa = run(alpha)
    sb, wb = run(beta)
    for t, w1 in (("alpha", wa), ("beta", wb)):
        np.testing.assert_array_equal(
            both.tenant_answers(np.asarray(w2.answers), t),
            np.asarray(w1.answers))
        np.testing.assert_array_equal(
            both.tenant_answers(np.asarray(w2.bounds), t),
            np.asarray(w1.bounds))
    # shared tree: sample state identical with 0, 1, or 2 tenants
    for la, lb in zip(jax.tree.leaves(s2.tree._replace(qstate=())),
                      jax.tree.leaves(sa.tree._replace(qstate=()))):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    # per-tenant routing by name, and per-tenant error attribution
    lay = both.query_layout()
    assert "alpha/sum" in lay and "beta/count" in lay
    row_a, row_b = np.asarray(w2.answers)[-1], np.asarray(w2.bounds)[-1]
    rel = both.tenant_rel_errors(row_a, row_b)
    assert set(rel) == {"alpha", "beta"}
    assert rel["alpha"] > 0.0          # CLT queries vote
    assert rel["beta"] == 0.0          # count/hist/hh: no CLT vote


def test_error_budget_spec_defaults_growable_ceiling():
    """target_rel_error without an explicit ceiling provisions the full
    window (max_fraction=1.0, the legacy driver default) — otherwise
    the accuracy controller's ceiling would equal the initial budget
    and the grow loop could never move."""
    spec = PipelineSpec(
        topology=TopologySpec(fanin=(4, 2, 1), capacity=1000, num_strata=X),
        sampler=SamplerSpec(fraction=0.01),
        budget=BudgetSpec(target_rel_error=0.02))
    r = api.resolve(spec)
    assert r.sample_sizes == (10, 10, 10)
    assert r.max_sample_sizes == (1000, 1000, 1000)


def test_worst_tenant_arbiter_moves_budget_for_worst():
    from repro.runtime.budget import BudgetConfig, WorstTenantArbiter

    arb = WorstTenantArbiter(
        BudgetConfig(min_size=8, max_size=512, target_rel_error=0.02),
        initial_size=64)
    size = arb.update({"quiet": 0.001, "noisy": 0.2})
    assert arb.last_tenant == "noisy"
    assert size > 64                   # grows for the worst-off tenant
    for _ in range(30):
        size = arb.update({"quiet": 0.001, "noisy": 0.001})
    assert size < 512                  # shrinks only when all are under


# ------------------------------------------------ serialization + spec --
def test_spec_round_trip_and_hashable():
    import json

    spec = _spec(tenants=(_reg_a().as_tenant("alpha"),
                          _reg_b().as_tenant("beta")), iv=(1, 2, 4))
    spec2 = PipelineSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert spec2 == spec
    assert hash(spec2) == hash(spec)
    assert api.compile(spec) is api.compile(spec2)   # compile cache hit


@pytest.mark.parametrize("build,needle", [
    (lambda: TopologySpec(fanin=(4, 2)), "single root"),
    (lambda: TopologySpec(interval_ticks=(1, 2)), "one entry per level"),
    (lambda: SamplerSpec(mode="srs", fraction=1.7), "fraction must be in"),
    (lambda: SamplerSpec(backend="cuda"), "sampler.backend"),
    (lambda: PipelineSpec(sampler=SamplerSpec(mode="srs", fraction=0.2),
                          tenants=(_reg_a().as_tenant("a"),)),
     "WHS stratum metadata"),
    (lambda: PipelineSpec(
        topology=TopologySpec(fanin=(2, 1), capacity=64, num_strata=X),
        budget=BudgetSpec(sample_sizes=(128, 16))), "exceeds the level-0"),
    (lambda: PipelineSpec(       # pinned UPPER-level budget overflows its
        topology=TopologySpec(fanin=(4, 2, 1), capacity=1024, num_strata=X),
        budget=BudgetSpec(sample_sizes=(8, 500, 8))), "exceeds the level-1"),
    (lambda: PipelineSpec(
        budget=BudgetSpec(sample_sizes=(64,) * 3,
                          max_sample_sizes=(32,) * 3)), "dominate"),
    (lambda: PipelineSpec(tenants=(TenantSpec("a", (QuerySpec("s", "sum"),)),
                                   TenantSpec("a", (QuerySpec("c", "count"),)))),
     "duplicate tenant"),
    (lambda: PipelineSpec.from_dict({"topology": {"bogus": 3}}),
     "unknown keys"),
    (lambda: PipelineSpec.from_dict({"version": 9}), "version"),
])
def test_spec_errors_are_actionable(build, needle):
    with pytest.raises(SpecError, match=needle):
        build()


# --------------------------------------------------------- checkpoint --
def test_checkpoint_resume_bitwise_identical(tmp_path):
    """save → restore → continue ≡ an uninterrupted run, to the bit
    (answers AND every state leaf), across a fresh compile from the
    serialized spec."""
    vals, strs, counts = _ingest(6)
    spec = _spec(tenants=(_reg_a().as_tenant("alpha"),))

    pipe = api.compile(spec)
    st = pipe.init()
    st, wa1 = pipe.run_epoch(st, pipe.default_key, vals[:3], strs[:3],
                             counts[:3])
    api.save_state(tmp_path / "ck", 1, st, spec=spec)
    st, wa2 = pipe.run_epoch(st, pipe.default_key, vals[3:], strs[3:],
                             counts[3:])
    rows_uninterrupted = pipe.rows(wa2)

    pipe2 = api.compile(PipelineSpec.from_dict(spec.to_dict()))
    st2, meta = api.restore_state(tmp_path / "ck", pipe2)
    assert meta["pipeline_spec"] == spec.to_dict()
    st2, wb2 = pipe2.run_epoch(st2, pipe2.default_key, vals[3:], strs[3:],
                               counts[3:])
    _assert_rows_equal(pipe2.rows(wb2), rows_uninterrupted)
    for la, lb in zip(jax.tree.leaves(st), jax.tree.leaves(st2)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_checkpoint_restore_rejects_wrong_spec(tmp_path):
    spec = _spec()
    pipe = api.compile(spec)
    api.save_state(tmp_path / "ck", 1, pipe.init(), spec=spec)
    other = api.compile(_spec(seed=6))
    with pytest.raises(SpecError, match="different PipelineSpec"):
        api.restore_state(tmp_path / "ck", other)


# --------------------------------------------------------------- shim --
def test_host_tree_from_spec_shim_smoke():
    """HostTree.from_spec(spec) ≡ the legacy keyword constructor, and
    the legacy build_tree wrapper still stands."""
    from repro.launch.analytics import build_tree

    vals, strs, counts = _ingest(3)
    spec = _spec(tenants=(_reg_a().as_tenant("alpha"),))
    old = _legacy_tree(spec, "level")
    new = HostTree.from_spec(spec, engine="level")
    _run_sequential(old, vals, strs, counts)
    _run_sequential(new, vals, strs, counts)
    _assert_rows_equal(new.results, old.results)

    t = build_tree(X, 768, 0.125, engine="level")
    assert t.sample_sizes == [96, 96, 96]   # fraction × capacity


def test_run_pipeline_accepts_explicit_spec():
    from repro.launch.analytics import build_spec, run_pipeline

    streams = S.paper_gaussian(rates=(120,) * 4)
    spec = build_spec(streams, fraction=0.2, seed=3)
    a = run_pipeline(streams, pipeline_spec=spec, ticks=4, engine="scan")
    b = run_pipeline(streams, fraction=0.2, seed=3, ticks=4, engine="scan")
    assert a["approx_sum"] == b["approx_sum"]
    assert a["dispatches"] == 1


# --------------------------------------------------------------- spmd --
def test_compile_with_mesh_matches_spmd_epoch():
    """compile(spec, mesh=...) ≡ direct per-interval
    spmd_local_then_root calls on a 1-device mesh, bit-for-bit."""
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.tree import spmd_local_then_root
    from repro.core.types import IntervalBatch, StratumMeta

    m, ticks = 256, 3
    rng = np.random.default_rng(0)
    batches = IntervalBatch(
        value=jnp.asarray(rng.normal(100, 10, (ticks, m)), jnp.float32),
        stratum=jnp.asarray(rng.integers(0, X, (ticks, m)), jnp.int32),
        valid=jnp.ones((ticks, m), bool),
        meta=StratumMeta(jnp.ones((ticks, X)), jnp.zeros((ticks, X))))
    mesh = jax.make_mesh((1,), ("data",))
    spec = _spec(sizes=(32, 32, 64))
    pipe = api.compile(spec, mesh=mesh)
    assert pipe.local_budget == 32 and pipe.root_budget == 64
    state, (s_t, m_t) = pipe.run_epoch(pipe.init(), pipe.default_key,
                                       batches)

    spec1 = IntervalBatch(P("data"), P("data"), P("data"),
                          StratumMeta(P(), P()))
    one = shard_map(
        lambda k, b: spmd_local_then_root(
            k, b, axis_name="data", num_strata=X, local_budget=32,
            root_budget=64, allocation="fair", sampler_backend="topk"),
        mesh=mesh, in_specs=(P(), spec1), out_specs=(P(), P()))
    for i in range(ticks):
        b = IntervalBatch(batches.value[i], batches.stratum[i],
                          batches.valid[i],
                          StratumMeta(batches.meta.weight[i],
                                      batches.meta.count[i]))
        s1, m1 = one(jax.random.fold_in(pipe.default_key, i), b)
        assert float(s1.estimate) == float(s_t.estimate[i])
        assert float(m1.estimate) == float(m_t.estimate[i])


def test_compile_with_mesh_accepts_tenants_and_srs():
    """The PR-5 lowering: tenant specs and the srs baseline now compile
    onto the mesh (formerly SpecError rejections); genuinely unsupported
    shapes keep actionable errors."""
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(SpecError, match="no axis"):
        api.compile(_spec(), mesh=mesh, axis_name="model")
    srs = api.compile(_spec(mode="srs"), mesh=mesh)
    assert srs.plan is None and srs.init() == ()
    tenanted = api.compile(_spec(tenants=(_reg_a().as_tenant("a"),)),
                           mesh=mesh)
    assert tenanted.plan is not None
    assert tenanted.tenant_names == ("a",)
    assert int(tenanted.init().tick) == 0

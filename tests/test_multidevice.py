"""Multi-device semantics, validated on 8 forced host devices.

These tests run in a SUBPROCESS with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the main test
process must keep the default 1-device backend), and check the two
properties that cannot be observed on one device:

  * ``spmd_local_then_root`` on a real 8-way "data" mesh produces an
    accurate, *replicated* root estimate (§III-E distributed execution);
  * the group-local MoE dispatch (G = #batch shards) stays numerically
    equivalent to the single-group path on the same inputs.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

_SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.tree import spmd_local_then_root
    from repro.core.types import IntervalBatch, StratumMeta

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    m, x = 8192, 4
    batch = IntervalBatch(
        value=jnp.asarray(rng.normal(100, 10, m), jnp.float32),
        stratum=jnp.asarray(rng.integers(0, x, m), jnp.int32),
        valid=jnp.ones((m,), bool),
        meta=StratumMeta.identity(x),
    )
    def f(key, b):
        s, mn = spmd_local_then_root(key, b, axis_name="data", num_strata=x,
                                     local_budget=256, root_budget=512)
        return s.estimate, s.variance, mn.estimate
    specs = IntervalBatch(P("data"), P("data"), P("data"), StratumMeta(P(), P()))
    try:
        shard_map = jax.shard_map            # jax >= 0.6
    except AttributeError:
        from jax.experimental.shard_map import shard_map
    fn = shard_map(f, mesh=mesh, in_specs=(P(), specs),
                   out_specs=(P(), P(), P()))
    est, var, mean = fn(jax.random.PRNGKey(0), batch)
    print(json.dumps({
        "est": float(est), "var": float(var), "mean": float(mean),
        "exact": float(np.asarray(batch.value).sum()),
        "n_dev": len(jax.devices()),
    }))
""")

_MOE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import registry
    from repro.launch.meshctx import use_mesh
    from repro.models import moe as MOE

    cfg = registry.get_config("qwen2-moe-a2.7b").reduced()
    key = jax.random.PRNGKey(0)
    p = MOE.moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model), jnp.float32)

    # capacity_factor=8 ⇒ per-(group,expert) capacity == tg·k, so NOTHING
    # can drop in either path: outputs must agree exactly (the paths may
    # only differ through per-group-vs-global capacity drop patterns).
    cf = 8.0
    y1, aux1 = MOE.moe_apply(p, cfg, x, capacity_factor=cf)   # no mesh → G=1
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    with use_mesh(mesh):                          # G=4 group-local dispatch
        y4, aux4 = jax.jit(
            lambda p, x: MOE.moe_apply(p, cfg, x, capacity_factor=cf))(p, x)
    print(json.dumps({
        "max_dev": float(jnp.max(jnp.abs(y1 - y4))),
        "scale": float(jnp.max(jnp.abs(y1))),
        "aux1": float(aux1), "aux4": float(aux4),
    }))
""")


def _run(script: str) -> dict:
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_spmd_hierarchy_eight_devices():
    r = _run(_SPMD_SCRIPT)
    assert r["n_dev"] == 8
    assert abs(r["est"] - r["exact"]) / r["exact"] < 0.05
    assert r["var"] >= 0
    assert abs(r["mean"] - 100.0) < 5.0


def test_moe_group_local_dispatch_matches_single_group():
    r = _run(_MOE_SCRIPT)
    # Zero drops by construction → the two dispatch layouts compute the
    # same math; only einsum reduction order may differ.
    assert r["max_dev"] < 1e-3 * max(r["scale"], 1.0), r
    assert abs(r["aux1"] - r["aux4"]) < 1e-5

"""Per-arch smoke tests + train/decode equivalence (validates the chunked
SSD / RWKV algebra and KV-cache paths against the full-sequence forward)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import SHAPES, shape_applicable
from repro.models import model as M


def _smoke_batch(cfg, b=2, s=128, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "stratum": jnp.zeros((b,), jnp.int32),
        "weight": jnp.ones((b,), jnp.float32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(rng.normal(size=(b, s // 2, cfg.d_model)),
                                      cfg.param_dtype)
        batch["tokens"] = batch["tokens"][:, : s // 2]
        batch["labels"] = batch["labels"][:, : s // 2]
    if cfg.family == "vlm":
        p = cfg.num_patches
        batch["patches"] = jnp.asarray(rng.normal(size=(b, p, cfg.d_model)),
                                       cfg.param_dtype)
        batch["tokens"] = batch["tokens"][:, : s - p]
        batch["labels"] = batch["labels"][:, : s - p]
    return batch


@pytest.mark.parametrize("arch", registry.ARCH_NAMES)
def test_arch_smoke_forward_and_shapes(arch):
    cfg = registry.get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg)
    logits, aux = M.forward(cfg, params, batch)
    b = batch["tokens"].shape[0]
    s_out = batch["tokens"].shape[1] + (cfg.num_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (b, s_out, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, metrics = M.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", registry.ARCH_NAMES)
def test_arch_smoke_one_train_step(arch):
    from repro.optim import adamw, train_step
    cfg = registry.get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    step = jax.jit(train_step.make_train_step(cfg, adamw.AdamWConfig(lr=1e-3)))
    batch = _smoke_batch(cfg)
    p2, o2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    delta = sum(float(jnp.abs(a - b).sum())
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ["smollm-135m", "qwen3-4b", "olmo-1b",
                                  "rwkv6-7b", "zamba2-1.2b", "qwen2-moe-a2.7b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode steps reproduce the full-seq forward logits —
    validates KV caches, rope offsets, and the chunked↔recurrent algebra."""
    cfg = registry.get_config(arch).reduced()
    if cfg.family == "moe":  # avoid dropped tokens breaking equivalence
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    b, s = 2, 128
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg, b=b, s=s)
    logits_full, _ = M.forward(cfg, params, batch)

    cache = M.init_cache(cfg, b, s)
    step = jax.jit(lambda p, c, tok, pos: M.decode_step(cfg, p, c, tok, pos))
    outs = []
    for t in range(s):
        lg, cache = step(params, cache, batch["tokens"][:, t:t + 1], jnp.int32(t))
        outs.append(np.asarray(lg, np.float32))
    dec = np.stack(outs, axis=1)
    full = np.asarray(logits_full, np.float32)
    np.testing.assert_allclose(dec, full, rtol=2e-2, atol=2e-2)


def test_encdec_decode_matches_forward():
    cfg = registry.get_config("whisper-medium").reduced()
    b, s = 2, 64
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg, b=b, s=2 * s)
    logits_full, _ = M.forward(cfg, params, batch)

    cache = M.build_encdec_cache(cfg, params, batch["frames"], s)
    step = jax.jit(lambda p, c, tok, pos: M.decode_step(cfg, p, c, tok, pos))
    outs = []
    for t in range(s):
        lg, cache = step(params, cache, batch["tokens"][:, t:t + 1], jnp.int32(t))
        outs.append(np.asarray(lg, np.float32))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(logits_full, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_long_context_shapes_gate():
    for arch in registry.ARCH_NAMES:
        cfg = registry.get_config(arch)
        ok, why = shape_applicable(cfg, SHAPES["long_500k"])
        assert ok == (cfg.family in ("ssm", "hybrid")), (arch, ok, why)
        if not ok:
            assert "attention" in why


def test_param_count_formulas_close_to_actual():
    for arch in ["smollm-135m", "olmo-1b", "rwkv6-7b", "zamba2-1.2b"]:
        cfg = registry.get_config(arch).reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        predicted = cfg.param_count()
        assert abs(predicted - actual) / actual < 0.25, (arch, predicted, actual)

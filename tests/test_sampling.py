"""Core sampling invariants: unit + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import sampling, whs
from repro.core.types import IntervalBatch, StratumMeta


def make_batch(values, strata, num_strata, w=None, c=None):
    m = len(values)
    meta = StratumMeta.identity(num_strata)
    if w is not None:
        meta = StratumMeta(jnp.asarray(w, jnp.float32), jnp.asarray(c, jnp.float32))
    return IntervalBatch(jnp.asarray(values, jnp.float32),
                         jnp.asarray(strata, jnp.int32),
                         jnp.ones((m,), bool), meta)


# --------------------------------------------------------------- property --
@settings(max_examples=30, deadline=None)
@given(
    st.integers(1, 6),                 # num strata
    st.integers(10, 400),              # items
    st.integers(1, 200),               # budget
    st.integers(0, 2 ** 31 - 1),
)
def test_priority_sample_sizes(num_strata, m, budget, seed):
    """Per-stratum selected count == min(c_i, N_i), never exceeds budget."""
    rng = np.random.default_rng(seed)
    strata = rng.integers(0, num_strata, m).astype(np.int32)
    c = np.bincount(strata, minlength=num_strata).astype(np.float32)
    res = sampling.allocate_reservoirs(jnp.float32(budget), jnp.asarray(c))
    sel = sampling.stratified_priority_sample(
        jax.random.PRNGKey(seed), jnp.asarray(strata),
        jnp.ones((m,), bool), res, num_strata)
    sel = np.asarray(sel)
    for i in range(num_strata):
        got = int(sel[strata == i].sum())
        assert got == min(int(c[i]), int(res[i]))
    assert sel.sum() <= budget


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 5), st.integers(50, 300), st.integers(0, 2 ** 31 - 1))
def test_fair_allocation_waterfills(num_strata, budget, seed):
    """Small strata keep everything; budget never exceeded; active strata
    with enough items get at least the base share."""
    rng = np.random.default_rng(seed)
    counts = rng.integers(0, 200, num_strata).astype(np.float32)
    res = np.asarray(sampling.allocate_reservoirs(
        jnp.float32(budget), jnp.asarray(counts)))
    assert res.sum() <= budget + 1e-3
    assert (res[counts == 0] == 0).all()
    base = budget // max((counts > 0).sum(), 1)
    for i in range(num_strata):
        if counts[i] > 0:
            assert res[i] >= min(base, counts[i]) - 1  # floor slack


def test_invalid_items_never_selected():
    m, x = 64, 3
    strata = jnp.zeros((m,), jnp.int32)
    valid = jnp.arange(m) < 10
    sel = sampling.stratified_priority_sample(
        jax.random.PRNGKey(0), strata, valid, jnp.full((x,), 100.0), x)
    assert not bool((np.asarray(sel) & ~np.asarray(valid)).any())
    assert int(sel.sum()) == 10


# ------------------------------------------------------------ unbiasedness --
def test_weighted_sum_unbiased_skewed():
    """E[estimate] ≈ exact over repeated sampling (skewed strata)."""
    rng = np.random.default_rng(1)
    m, x = 2048, 4
    sizes = [1600, 400, 40, 8]
    strata = np.concatenate([np.full(s, i) for i, s in enumerate(sizes)])
    vals = np.concatenate([rng.normal(10, 5, sizes[0]),
                           rng.normal(1e3, 50, sizes[1]),
                           rng.normal(1e4, 500, sizes[2]),
                           rng.normal(1e5, 5e3, sizes[3])]).astype(np.float32)
    batch = make_batch(vals, strata, x)
    exact = float(vals.sum())
    ests = []
    for t in range(60):
        res = whs.whsamp(jax.random.PRNGKey(t), batch, jnp.float32(200), x)
        from repro.core import queries
        ests.append(float(queries.weighted_sum(batch, res, x).estimate))
    bias = abs(np.mean(ests) - exact) / exact
    assert bias < 0.01, f"relative bias {bias:.4f}"


def test_weight_telescoping_two_nodes():
    """Sync intervals: after 2 hops W_out == c_src / N_bottleneck (Eq. 6)."""
    rng = np.random.default_rng(2)
    x = 2
    c_src = 640
    vals = rng.normal(0, 1, c_src).astype(np.float32)
    strata = np.zeros(c_src, np.int32)
    strata[320:] = 1
    b1 = make_batch(vals, strata, x)
    r1 = whs.whsamp(jax.random.PRNGKey(0), b1, jnp.float32(128), x)
    out1 = whs.compact_sample(b1, r1, 128)
    # node 2 receives the sample; its budget is smaller (the bottleneck)
    r2 = whs.whsamp(jax.random.PRNGKey(1), out1, jnp.float32(32), x)
    w2 = np.asarray(r2.meta.weight)
    # per stratum: c_src_i = 320, bottleneck N = 16 each (fair split of 32)
    n1 = np.asarray(r1.reservoir)
    n2 = np.asarray(r2.reservoir)
    expect = 320.0 / n2  # c_src / N at the bottleneck (node 2)
    np.testing.assert_allclose(w2, expect, rtol=1e-5)


def test_async_calibration_figure4():
    """The paper's Fig. 4 example: misaligned intervals, Eq. 9 calibration
    gives W_out == c_src / N_2 regardless of the split α."""
    rng = np.random.default_rng(3)
    x = 1
    c_src = 1000
    n1, n2 = 200, 50
    vals = rng.normal(5, 1, c_src).astype(np.float32)
    strata = np.zeros(c_src, np.int32)
    b1 = make_batch(vals, strata, x)
    r1 = whs.whsamp(jax.random.PRNGKey(0), b1, jnp.float32(n1), x)
    out1 = whs.compact_sample(b1, r1, n1)

    # node 2 sees only α of node 1's sample in this interval
    alpha = 0.6
    c2 = int(alpha * n1)
    part = IntervalBatch(out1.value[:c2], out1.stratum[:c2],
                         jnp.ones((c2,), bool), out1.meta)
    r2 = whs.whsamp(jax.random.PRNGKey(1), part, jnp.float32(n2), x)
    w2 = float(r2.meta.weight[0])
    # Eq. 9: W = (c_src/N1) · (c2/N2) · (N1/c2) = c_src/N2
    assert abs(w2 - c_src / n2) / (c_src / n2) < 1e-5


def test_merge_property_distributed_workers():
    """§III-E: two workers' reservoirs merge into a valid sample —
    re-selecting top-N from the union matches a single-node sample law
    (checked via selection-count invariant + unbiased estimate)."""
    rng = np.random.default_rng(4)
    m, x = 1024, 2
    vals = rng.normal(10, 3, m).astype(np.float32)
    strata = (np.arange(m) % x).astype(np.int32)
    # split across 2 workers, each samples N/2 per stratum
    ests = []
    for t in range(40):
        key = jax.random.PRNGKey(t)
        k1, k2, k3 = jax.random.split(key, 3)
        half = m // 2
        res_sizes = jnp.full((x,), 32.0)
        parts = []
        for kk, sl in ((k1, slice(0, half)), (k2, slice(half, m))):
            b = make_batch(vals[sl], strata[sl], x)
            sel = sampling.stratified_priority_sample(
                kk, b.stratum, b.valid, res_sizes / 2, x)
            parts.append((vals[sl][np.asarray(sel)], strata[sl][np.asarray(sel)]))
        mv = np.concatenate([p[0] for p in parts])
        ms = np.concatenate([p[1] for p in parts])
        # local weights: (m/2 per worker → c_i = m/(2x)) / (N_i/2)
        w = (m / (2 * x)) / (32 / 2)
        ests.append(float(mv.sum() * w))
    bias = abs(np.mean(ests) - vals.sum()) / abs(vals.sum())
    assert bias < 0.02, bias


# ------------------------------------------------ allocation properties --
ALL_POLICIES = ("fair", "proportional", "neyman")


def _alloc(policy, budget, counts, stds=None):
    if policy == "neyman" and stds is None:
        stds = jnp.ones_like(counts)
    return np.asarray(sampling.allocate_reservoirs(
        jnp.float32(budget), jnp.asarray(counts, jnp.float32),
        policy=policy, stds=stds))


@settings(max_examples=40, deadline=None)
@given(st.sampled_from(ALL_POLICIES), st.integers(1, 8),
       st.integers(0, 3000), st.integers(0, 2 ** 31 - 1))
def test_allocation_conserves_budget_exactly(policy, num_strata, budget,
                                             seed):
    """Σ alloc == min(budget, Σ counts) BITWISE, alloc_i ≤ c_i, alloc ≥ 0 —
    for every policy (the PR-10 conservation bugfix pin: the old fair
    water-fill could strand the division remainder, and quota floors
    could both under- and over-shoot the budget)."""
    rng = np.random.default_rng(seed)
    counts = rng.integers(0, 500, num_strata).astype(np.float32)
    stds = np.abs(rng.normal(1, 5, num_strata)).astype(np.float32)
    alloc = _alloc(policy, budget, counts, jnp.asarray(stds))
    assert float(alloc.sum()) == min(float(budget), float(counts.sum())), (
        policy, counts, alloc)
    assert (alloc <= counts).all(), (policy, counts, alloc)
    assert (alloc >= 0).all(), (policy, counts, alloc)


@settings(max_examples=40, deadline=None)
@given(st.sampled_from(ALL_POLICIES), st.integers(2, 8),
       st.integers(0, 2 ** 31 - 1))
def test_allocation_never_starves_active_strata(policy, num_strata, seed):
    """Budget ≥ #active ⇒ every non-empty stratum gets ≥ 1 row. Without
    the one-row reserve a rare stratum's quota/score rounds to zero and
    its items drop with no weight — bias, not variance."""
    rng = np.random.default_rng(seed)
    counts = rng.integers(0, 10_000, num_strata).astype(np.float32)
    budget = int(max((counts > 0).sum(), 1)) + int(rng.integers(0, 200))
    alloc = _alloc(policy, budget, counts,
                   jnp.abs(jnp.asarray(rng.normal(0, 3, num_strata),
                                       jnp.float32)))
    assert (alloc[counts > 0] >= 1).all(), (policy, counts, alloc)
    assert (alloc[counts == 0] == 0).all(), (policy, counts, alloc)


def test_rare_stratum_kept_under_skew_shares():
    """The Fig. 11c regime at fraction 0.1: stratum D is ~0.01% of items
    but most of the value mass — every policy must keep it non-empty."""
    from repro.data import stream as S

    rng = np.random.default_rng(7)
    rates = np.array([8000 * sh for sh in S.SKEW_SHARES])
    counts = rng.poisson(rates * 2).astype(np.float32)
    counts[3] = max(counts[3], 1.0)          # D present this interval
    budget = 0.1 * counts.sum()
    stds = jnp.asarray([3.2, 9.9, 120.0, 0.0])
    for policy in ALL_POLICIES:
        alloc = _alloc(policy, budget, counts, stds)
        assert alloc[3] >= 1, (policy, counts, alloc)


def test_allocation_conserves_inside_fused_kernel():
    """The fused Pallas tick's in-kernel allocation conserves the budget
    bitwise and matches the XLA reference for every policy (the kernel
    computes neyman's stds itself via a one-hot dot_general)."""
    from repro.kernels.fused_level_tick import ops as ft_ops

    rng = np.random.default_rng(3)
    n, cap, x = 2, 256, 4
    vals = rng.normal(60, 25, (n, cap)).astype(np.float32)
    # heavy skew: stratum 3 rare
    strata = rng.choice(x, size=(n, cap),
                        p=[0.80, 0.1899, 0.01, 0.0001]).astype(np.int32)
    strata[:, -1] = 3
    valid = np.ones((n, cap), bool)
    u = rng.random((n, cap)).astype(np.float32)
    w_in = np.ones((n, x), np.float32)
    c_in = np.zeros((n, x), np.float32)
    size = jnp.float32(40.0)
    for policy in ALL_POLICIES:
        outs = {}
        for impl in ("pallas", "ref"):
            outs[impl] = ft_ops.fused_level_tick(
                jnp.asarray(vals), jnp.asarray(strata), jnp.asarray(valid),
                jnp.asarray(u), jnp.asarray(w_in), jnp.asarray(c_in),
                size, x, cap, allocation=policy, impl=impl)
        res_p = np.asarray(outs["pallas"][5])
        res_r = np.asarray(outs["ref"][5])
        np.testing.assert_array_equal(res_p, res_r, err_msg=policy)
        c = np.asarray(outs["pallas"][4])
        for node in range(n):
            assert float(res_p[node].sum()) == min(40.0,
                                                   float(c[node].sum())), (
                policy, node, res_p[node], c[node])
            assert res_p[node][3] >= 1, (policy, res_p[node])

"""Per-kernel allclose vs pure-jnp oracle, sweeping shapes and dtypes
(interpret mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sampling
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.sample_mask import ops as sm_ops, ref as sm_ref
from repro.kernels.sketch_update import ops as sk_ops, ref as sk_ref
from repro.kernels.stratified_stats import ops as ss_ops, ref as ss_ref


@pytest.mark.parametrize("m,x", [(512, 4), (4096, 16), (10_000, 7), (4095, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_stratified_stats_matches_ref(m, x, dtype):
    rng = np.random.default_rng(m + x)
    vals = jnp.asarray(rng.normal(5, 2, m), dtype)
    strat = jnp.asarray(rng.integers(0, x, m), jnp.int32)
    mask = jnp.asarray(rng.random(m) < 0.5)
    a = ss_ops.stratified_stats(vals, strat, mask, x, impl="pallas")
    b = ss_ref.stratified_stats(vals, strat, mask, x)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-2)


def test_stratified_stats_empty_strata():
    vals = jnp.ones((256,), jnp.float32)
    strat = jnp.zeros((256,), jnp.int32)
    mask = jnp.zeros((256,), bool)
    out = ss_ops.stratified_stats(vals, strat, mask, 4, impl="pallas")
    assert float(jnp.abs(out).sum()) == 0.0


@pytest.mark.parametrize("m,x", [(1000, 4), (8192, 32), (333, 2)])
def test_sample_mask_matches_ref_and_sampler(m, x):
    rng = np.random.default_rng(m * x)
    u = jnp.asarray(rng.random(m), jnp.float32)
    strat = jnp.asarray(rng.integers(0, x, m), jnp.int32)
    valid = jnp.asarray(rng.random(m) < 0.9)
    res = jnp.asarray(rng.integers(1, max(m // x, 2), x), jnp.float32)
    w = jnp.asarray(rng.random(x) * 10, jnp.float32)

    tau = sm_ops.thresholds_from_reservoirs(u, strat, valid, res, x)
    k1, w1 = sm_ops.sample_mask(u, strat, valid, tau, w, impl="pallas")
    k2, w2 = sm_ref.sample_mask(u, strat, valid, tau, w)
    assert (np.asarray(k1) == np.asarray(k2)).all()
    np.testing.assert_allclose(w1, w2)

    # threshold path ≡ sort-based priority sampler (same priorities)
    sel = sampling.stratified_priority_sample(
        jax.random.PRNGKey(0), strat, valid, res, x, priorities=u)
    assert (np.asarray(k1) == np.asarray(sel)).all()


@pytest.mark.parametrize("m,depth,width", [(512, 4, 256), (4096, 2, 1024),
                                           (5000, 6, 128)])
def test_cms_update_matches_ref(m, depth, width):
    rng = np.random.default_rng(m + width)
    keys = jnp.asarray(rng.integers(-10_000, 10_000, m),
                       jnp.int32).astype(jnp.uint32)
    w = jnp.asarray(rng.random(m) * (rng.random(m) > 0.3), jnp.float32)
    a = sk_ops.cms_update(keys, w, depth, width, impl="pallas")
    b = sk_ref.cms_update(keys, w, depth, width)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-3)
    # every depth row conserves the total folded weight
    np.testing.assert_allclose(np.asarray(a).sum(axis=1),
                               np.full(depth, float(w.sum())), rtol=1e-5)


def test_cms_update_rejects_non_power_of_two_width():
    with pytest.raises(AssertionError):
        sk_ops.cms_update(jnp.zeros(8, jnp.uint32), jnp.ones(8, jnp.float32),
                          2, 100, impl="pallas")


@pytest.mark.parametrize("p,c", [(512, 128), (4096, 256), (777, 64)])
def test_quantile_compact_matches_ref(p, c):
    rng = np.random.default_rng(p * c)
    vals = np.sort(rng.normal(0, 10, p)).astype(np.float32)
    w = (rng.random(p) * (rng.random(p) > 0.2)).astype(np.float32)
    cumw = np.cumsum(w, dtype=np.float32)
    prev = np.concatenate([[0], cumw[:-1]]).astype(np.float32)
    t = ((np.arange(c) + 0.41) * (cumw[-1] / c)).astype(np.float32)
    a = sk_ops.quantile_compact(jnp.asarray(vals), jnp.asarray(prev),
                                jnp.asarray(cumw), jnp.asarray(t),
                                impl="pallas")
    b = sk_ref.quantile_compact(jnp.asarray(vals), jnp.asarray(prev),
                                jnp.asarray(cumw), jnp.asarray(t))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # every in-range target is captured by exactly one slot interval
    in_range = t < cumw[-1]
    hit_counts = ((prev[:, None] <= t[None, :])
                  & (t[None, :] < cumw[:, None])).sum(axis=0)
    assert (hit_counts[in_range] == 1).all()


@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (1, 2, 1, 128, 64), (2, 4, 2, 256, 64), (1, 8, 2, 256, 128),
    (2, 3, 3, 128, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, hq, hkv, s, d, dtype):
    rng = np.random.default_rng(b * s + d)
    q = jnp.asarray(rng.normal(size=(b, hq, s, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    o1 = fa_ops.attention(q, k, v, impl="pallas")
    o2 = fa_ref.attention(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), rtol=tol, atol=tol)


def test_flash_attention_is_causal():
    """Future kv must not leak: perturbing k/v at position t>t0 must not
    change outputs at positions ≤ t0."""
    rng = np.random.default_rng(0)
    b, h, s, d = 1, 2, 256, 64
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    o1 = fa_ops.attention(q, k, v, impl="pallas")
    k2 = k.at[:, :, 200:, :].set(99.0)
    v2 = v.at[:, :, 200:, :].set(-99.0)
    o2 = fa_ops.attention(q, k2, v2, impl="pallas")
    np.testing.assert_allclose(o1[:, :, :200], o2[:, :, :200], atol=1e-5)
    assert np.abs(np.asarray(o1[:, :, 200:] - o2[:, :, 200:])).max() > 0.1

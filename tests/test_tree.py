"""HostTree end-to-end: the paper's topology, accuracy, bandwidth, skew."""
import numpy as np
import pytest

from repro.data import stream as S
from repro.launch.analytics import run_pipeline


def test_pipeline_accuracy_within_bounds_gaussian():
    r = run_pipeline(S.paper_gaussian(), fraction=0.2, ticks=10, seed=1)
    assert r["accuracy_loss"] < 0.02
    assert r["within_2sigma"] or r["accuracy_loss"] < 0.005


def test_bandwidth_saving_tracks_fraction():
    """Fig. 8: items forwarded from layer 0 ≈ sampling fraction."""
    r = run_pipeline(S.paper_gaussian(), fraction=0.1, ticks=8, seed=2)
    assert r["bandwidth_fraction"] < 0.2
    r2 = run_pipeline(S.paper_gaussian(), fraction=0.5, ticks=8, seed=2)
    assert r2["bandwidth_fraction"] > r["bandwidth_fraction"]


def test_skew_whs_beats_srs_style_allocation():
    """Fig. 11c: under heavy skew, stratified allocation is orders of
    magnitude more accurate than the SRS coin-flip baseline.

    Proportional allocation used to be SRS-like here because it rounded
    rare stratum D down to ZERO reservoir rows — dropped mass, i.e. bias.
    The one-row unbiasedness reserve in ``allocate_reservoirs`` fixed
    that, so proportional is now merely higher-variance than fair (it
    over-spends budget on the bulk strata) while both stratified policies
    crush true SRS, which misses stratum D entirely."""
    specs = S.paper_poisson(rates=tuple(4000 * s for s in S.SKEW_SHARES),
                            skewed=True)
    errs = {}
    for alloc in ("fair", "proportional"):
        losses = [run_pipeline(specs, fraction=0.1, ticks=6, seed=s,
                               allocation=alloc)["accuracy_loss"]
                  for s in range(3)]
        errs[alloc] = np.mean(losses)
    srs = np.mean([run_pipeline(specs, fraction=0.1, ticks=6, seed=s,
                                mode="srs")["accuracy_loss"]
                   for s in range(3)])
    assert errs["fair"] < errs["proportional"], errs
    assert errs["fair"] * 100 < srs, (errs, srs)
    assert errs["proportional"] * 100 < srs, (errs, srs)


def test_async_intervals_stay_unbiased():
    """§III-C: different interval lengths per level still give accurate
    results thanks to Eq. 9 calibration."""
    r = run_pipeline(S.paper_gaussian(), fraction=0.3, ticks=12,
                     interval_ticks=[1, 2, 3], seed=3)
    assert r["accuracy_loss"] < 0.03, r["accuracy_loss"]


def test_spmd_hierarchy_single_device():
    """In-graph two-level hierarchy under shard_map on a 1-device mesh."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    from repro.core.tree import spmd_local_then_root
    from repro.core.types import IntervalBatch, StratumMeta

    mesh = jax.make_mesh((1,), ("data",))
    rng = np.random.default_rng(0)
    m, x = 1024, 4
    batch = IntervalBatch(
        value=jnp.asarray(rng.normal(100, 10, m), jnp.float32),
        stratum=jnp.asarray(rng.integers(0, x, m), jnp.int32),
        valid=jnp.ones((m,), bool),
        meta=StratumMeta.identity(x),
    )

    def f(key, b):
        s, mn = spmd_local_then_root(key, b, axis_name="data", num_strata=x,
                                     local_budget=256, root_budget=128)
        return s.estimate, s.variance, mn.estimate

    batch_specs = IntervalBatch(P("data"), P("data"), P("data"),
                                StratumMeta(P(), P()))
    fn = shard_map(f, mesh=mesh,
                   in_specs=(P(), batch_specs),
                   out_specs=(P(), P(), P()))
    est, var, mean = fn(jax.random.PRNGKey(0), batch)
    exact = float(np.asarray(batch.value).sum())
    assert abs(float(est) - exact) / exact < 0.1
    assert float(var) >= 0

"""Window metadata semantics (§III-C + the parallel-merge correction).

The interval accumulators must (a) sum counts over intra-interval
messages, (b) combine weights by count-weighted mean — preserving the
represented-item total Σ wₖCₖ — and (c) fall back to sticky values for
strata with no fresh metadata (Fig. 3 late-item case).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.window import Window


def test_two_children_counts_sum_weights_average():
    w = Window(capacity=64, num_strata=2, interval_ticks=1)
    # child A: 4 items of stratum 0, weight 3, count 4
    w.deliver(np.ones(4, np.float32), np.zeros(4, np.int32),
              np.array([3.0, 1.0], np.float32), np.array([4.0, 0.0], np.float32))
    # child B: 8 items of stratum 0, weight 6, count 8
    w.deliver(np.ones(8, np.float32), np.zeros(8, np.int32),
              np.array([6.0, 1.0], np.float32), np.array([8.0, 0.0], np.float32))
    _, _, _, w_in, c_in = w.flush()
    assert c_in[0] == 12.0                       # counts sum
    np.testing.assert_allclose(w_in[0], (3 * 4 + 6 * 8) / 12)   # cw-mean
    # stratum 1 had no items delivered: sticky defaults survive
    assert w_in[1] == 1.0 and c_in[1] == 0.0


def test_sticky_across_intervals():
    w = Window(capacity=64, num_strata=1, interval_ticks=1)
    w.deliver(np.ones(4, np.float32), np.zeros(4, np.int32),
              np.array([5.0], np.float32), np.array([4.0], np.float32))
    w.flush()
    # next interval: items arrive with NO metadata (late relative to their
    # W/C message, Fig. 3) → the saved sets apply
    w.deliver(np.ones(2, np.float32), np.zeros(2, np.int32))
    _, _, _, w_in, c_in = w.flush()
    assert w_in[0] == 5.0 and c_in[0] == 4.0


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.floats(1.0, 100.0), st.integers(1, 20)),
                min_size=1, max_size=6))
def test_merge_preserves_represented_total(messages):
    """Σ w_eff·c_eff over the interval == Σ over messages wₖ·Cₖ (the pool
    must represent exactly the items its children claimed to represent)."""
    w = Window(capacity=256, num_strata=1, interval_ticks=1)
    for wk, ck in messages:
        w.deliver(np.ones(ck, np.float32), np.zeros(ck, np.int32),
                  np.array([wk], np.float32), np.array([float(ck)], np.float32))
    _, _, _, w_in, c_in = w.flush()
    want = sum(wk * ck for wk, ck in messages)
    np.testing.assert_allclose(w_in[0] * c_in[0], want, rtol=1e-5)


def test_max_rule_would_overestimate():
    """Documents the paper correction: max-combining unequal children
    inflates the represented total; the count-weighted mean does not."""
    w = Window(capacity=64, num_strata=1, interval_ticks=1)
    w.deliver(np.ones(10, np.float32), np.zeros(10, np.int32),
              np.array([2.0], np.float32), np.array([10.0], np.float32))
    w.deliver(np.ones(10, np.float32), np.zeros(10, np.int32),
              np.array([4.0], np.float32), np.array([10.0], np.float32))
    _, _, _, w_in, c_in = w.flush()
    true_total = 2 * 10 + 4 * 10                  # 60 represented items
    assert w_in[0] * c_in[0] == true_total        # cw-mean: exact
    assert max(2.0, 4.0) * c_in[0] > true_total   # max rule: +33%

"""System-level behaviour: the paper's headline claims, reproduced.

Each test maps to a claim in the paper's abstract/evaluation:
  * accuracy 3.3x-8.8x better than SRS at equal fraction (Figs. 6/11),
  * throughput gain from sampling vs native execution (Figs. 7/12b),
  * overhead of the sampler ~0 at fraction 1.0 (Fig. 7),
  * SRS catastrophically wrong under skew, WHS fine (Fig. 11c).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import queries, srs, whs
from repro.core.types import IntervalBatch, StratumMeta


def _skewed_batch(seed, m=8192, x=4):
    rng = np.random.default_rng(seed)
    shares = (0.80, 0.1989, 0.001, 0.0001)
    sizes = [max(int(m * s), 1) for s in shares]
    sizes[0] = m - sum(sizes[1:])
    strata = np.concatenate([np.full(s, i) for i, s in enumerate(sizes)])
    vals = np.concatenate([
        rng.poisson(10.0, sizes[0]),
        rng.poisson(100.0, sizes[1]),
        rng.poisson(1000.0, sizes[2]),
        rng.poisson(10_000_000.0, sizes[3]),
    ]).astype(np.float32)
    perm = rng.permutation(len(vals))
    return IntervalBatch(jnp.asarray(vals[perm]),
                         jnp.asarray(strata[perm], jnp.int32),
                         jnp.ones((len(vals),), bool),
                         StratumMeta.identity(x)), float(vals.sum())


def _accuracy(fraction, trials=15):
    whs_err, srs_err = [], []
    for t in range(trials):
        batch, exact = _skewed_batch(t)
        m = batch.capacity
        res = whs.whsamp(jax.random.PRNGKey(t), batch,
                         jnp.float32(fraction * m), 4)
        q = queries.weighted_sum(batch, res, 4)
        whs_err.append(abs(float(q.estimate) - exact) / exact)
        sel = srs.srs_select(jax.random.PRNGKey(1000 + t), batch, fraction)
        q2 = srs.srs_sum(batch, sel, fraction)
        srs_err.append(abs(float(q2.estimate) - exact) / exact)
    return float(np.mean(whs_err)), float(np.mean(srs_err))


def test_claim_accuracy_beats_srs_under_skew():
    """Fig. 11c: at 10% sampling, WHS accuracy many times better than SRS."""
    whs_e, srs_e = _accuracy(0.10)
    assert whs_e < 0.01, f"WHS accuracy loss too high: {whs_e}"
    assert srs_e > 3.3 * whs_e, f"expected >=3.3x gap: whs={whs_e} srs={srs_e}"


def test_claim_accuracy_improves_with_fraction():
    """Fig. 6: accuracy loss decreases monotonically-ish with fraction."""
    e10, _ = _accuracy(0.10, trials=8)
    e60, _ = _accuracy(0.60, trials=8)
    assert e60 < e10


def test_claim_throughput_scales_with_sampling():
    """Figs. 7/12b: root-side work scales ~1/fraction (items forwarded)."""
    from repro.data import stream as S
    from repro.launch.analytics import run_pipeline
    r10 = run_pipeline(S.paper_gaussian(), fraction=0.1, ticks=6, seed=0)
    r80 = run_pipeline(S.paper_gaussian(), fraction=0.8, ticks=6, seed=0)
    # the paper reports 1.3x-9.9x throughput at 80%→10% fractions; the
    # structural proxy is items-forwarded-to-root per ingested item.
    speedup = r80["bandwidth_fraction"] / r10["bandwidth_fraction"]
    assert speedup > 3.0, speedup


def test_claim_sampler_overhead_near_zero_at_full_fraction():
    """Fig. 7: fraction=1.0 ≈ native: nothing dropped, weights all 1."""
    batch, exact = _skewed_batch(0)
    res = whs.whsamp(jax.random.PRNGKey(0), batch,
                     jnp.float32(batch.capacity), 4)
    q = queries.weighted_sum(batch, res, 4)
    assert int(res.selected.sum()) == batch.capacity
    np.testing.assert_allclose(np.asarray(res.meta.weight), 1.0)
    np.testing.assert_allclose(float(q.estimate), exact, rtol=1e-5)
    assert float(q.variance) == 0.0

"""Fault-tolerance substrate: checkpoint atomicity/restore, straggler
calibration unbiasedness, budget controller convergence."""
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.runtime.budget import BudgetConfig, BudgetController
from repro.runtime.straggler import DeadlineTracker, calibrate_weights


# ------------------------------------------------------------- checkpoint --
def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32), "d": jnp.float32(7.0)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 3, t, meta={"step": 3})
    assert ckpt.latest_step(tmp_path) == 3
    restored, meta = ckpt.restore(tmp_path, 3, jax.eval_shape(lambda: t))
    assert meta["step"] == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_n_and_tmp_ignored(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4):
        ckpt.save(tmp_path, s, t, keep_n=2)
    steps = sorted(p.name for p in pathlib.Path(tmp_path).iterdir())
    assert steps == ["step_000000003", "step_000000004"]
    # a crashed write (tmp dir) must not be visible as a checkpoint
    (pathlib.Path(tmp_path) / "step_000000099.tmp").mkdir()
    assert ckpt.latest_step(tmp_path) == 4


def test_checkpoint_elastic_restore_resharded(tmp_path):
    """Restore onto explicit shardings (1-device 'new mesh')."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    t = _tree()
    ckpt.save(tmp_path, 0, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    restored, _ = ckpt.restore(tmp_path, 0, jax.eval_shape(lambda: t),
                               shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(t["a"]))


def test_async_checkpointer(tmp_path):
    c = ckpt.AsyncCheckpointer(tmp_path)
    c.save(5, _tree(), meta={"step": 5})
    c.wait()
    assert ckpt.latest_step(tmp_path) == 5


# -------------------------------------------------------------- straggler --
def test_calibrated_weights_unbiased():
    """E[Σ w'·x over present] == Σ w·x when shards drop out at random."""
    rng = np.random.default_rng(0)
    b = 256
    w = rng.uniform(0.5, 4.0, b).astype(np.float32)
    x = rng.normal(2, 1, b).astype(np.float32)
    target = float((w * x).sum())
    ests = []
    for t in range(400):
        present = rng.random(b) > 0.3
        w2 = calibrate_weights(w, present)
        # estimator of the weighted *mean* is exactly unbiased; the sum
        # estimator needs the total-weight scale which calibrate preserves:
        ests.append(float((w2 * x).sum()))
    bias = abs(np.mean(ests) - target) / abs(target)
    assert bias < 0.05, bias


def test_calibrated_weights_zero_absent_and_scale():
    w = np.ones((4,), np.float32)
    present = np.array([True, True, False, False])
    w2 = calibrate_weights(w, present)
    assert (w2[~present] == 0).all()
    np.testing.assert_allclose(w2[present], 2.0)  # 1/α with α=0.5


def test_deadline_tracker_flags_outliers():
    tr = DeadlineTracker(num_shards=8)
    for _ in range(10):
        tr.observe(np.full(8, 1.0))
    lat = np.full(8, 1.0)
    lat[3] = 50.0
    present = tr.observe(lat)
    assert not present[3] and present.sum() == 7


# ----------------------------------------------------------------- budget --
def test_budget_controller_shrinks_on_latency():
    c = BudgetController(BudgetConfig(min_size=10, max_size=1000,
                                      target_latency_s=1.0), 500)
    for _ in range(10):
        size = c.update(latency_s=2.0)
    assert size < 500


def test_budget_controller_grows_on_error():
    c = BudgetController(BudgetConfig(min_size=10, max_size=1000,
                                      target_rel_error=0.01), 100)
    for _ in range(10):
        size = c.update(rel_error=0.05)
    assert size > 100


def test_budget_controller_respects_bounds():
    c = BudgetController(BudgetConfig(min_size=10, max_size=200,
                                      target_latency_s=1.0), 100)
    for _ in range(50):
        size = c.update(latency_s=100.0)
    assert size == 10


def test_budget_controller_accuracy_mode_converges_to_target():
    """Closed-loop accuracy mode on a synthetic stream whose relative
    error follows the CLT law rel ≈ k/√size: the controller settles
    within 10% of target_rel_error (and therefore at the implied size),
    starting from either side of the target."""
    target = 0.02
    k_clt = 1.0                      # rel(size) = 1/√size → size* = 2500
    for start in (50, 40_000):       # under- and over-budgeted starts
        c = BudgetController(BudgetConfig(min_size=10, max_size=100_000,
                                          target_rel_error=target), start)
        size = start
        for _ in range(40):
            rel = k_clt / np.sqrt(size)
            size = c.update(rel_error=rel)
        final_rel = k_clt / np.sqrt(size)
        assert abs(final_rel - target) <= 0.1 * target, (start, size,
                                                         final_rel)


def test_budget_controller_accuracy_mode_respects_clamps():
    """Only the latency path exercised the clamps before: a hopeless
    error target pins the size at max_size; a trivially loose one at
    min_size — never beyond either."""
    cfg = BudgetConfig(min_size=32, max_size=512, target_rel_error=0.001)
    c = BudgetController(cfg, 64)
    for _ in range(60):
        size = c.update(rel_error=0.5)      # never achievable → grow
        assert 32 <= size <= 512
    assert size == 512
    c2 = BudgetController(cfg, 256)
    for _ in range(60):
        size = c2.update(rel_error=1e-6)    # absurdly accurate → shrink
        assert 32 <= size <= 512
    assert size == 32


# ------------------------------------------- per-level error attribution --
def test_level_error_shares_follow_variance_contribution():
    from repro.runtime.budget import level_error_shares

    # level 0 keeps 10% (heavy subsampling), level 1 keeps 90%, level 2
    # forwards everything: shares must rank 0 > 1 > 2 and level 2 gets 0
    shares = level_error_shares([1000, 100, 90], [100, 90, 90])
    assert shares[0] > shares[1] > shares[2] == 0.0
    assert abs(sum(shares) - 1.0) < 1e-12
    # no subsampling anywhere (or no traffic): uniform fallback
    assert level_error_shares([100, 100], [100, 100]) == [0.5, 0.5]
    assert level_error_shares([0, 0, 0], [0, 0, 0]) == [1 / 3] * 3


def test_arbiter_update_levels_moves_only_dominant_level():
    """With the worst tenant's error attributed ~entirely to level 0,
    level 0's budget grows while the no-share level is free to shrink —
    the point of per-level attribution (vs. update() moving all levels
    in lockstep)."""
    from repro.runtime.budget import WorstTenantArbiter

    cfg = BudgetConfig(min_size=16, max_size=4096, target_rel_error=0.02)
    arb = WorstTenantArbiter(cfg, initial_size=256)
    sizes0 = None
    for _ in range(10):
        sizes = arb.update_levels({"quiet": 0.001, "noisy": 0.2},
                                  [0.95, 0.05, 0.0])
        sizes0 = sizes0 or sizes
    assert arb.last_tenant == "noisy"
    assert arb.last_shares == [0.95, 0.05, 0.0]
    assert sizes[0] > 256          # dominant level grows
    assert sizes[2] < 256          # zero-share level releases budget
    # first move (pre-saturation): growth ordered by share
    assert sizes0[0] > sizes0[1] > sizes0[2]
    # legacy single-knob API untouched
    assert arb.update({"noisy": 0.2}) > 0

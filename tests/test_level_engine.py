"""Level-vectorized engine: dispatch model, bit-equivalence with the
per-node loop engine, and the compaction truncation fix."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import whs
from repro.core.tree import HostTree
from repro.core.types import IntervalBatch, StratumMeta
from repro.data import stream as S
from repro.launch.analytics import run_pipeline


def _feed(tree, ticks, seed=0, rate=600, x=4):
    rng = np.random.default_rng(seed)
    n0 = tree.fanin[0]
    for t in range(1, ticks + 1):
        for node in range(n0):
            vals = rng.normal(100, 20, rate).astype(np.float32)
            strata = rng.integers(0, x, rate).astype(np.int32)
            tree.ingest(node, vals, strata)
        tree.tick(t)


def _tree(engine, mode="whs", **kw):
    return HostTree(fanin=[4, 2, 1], num_strata=4, capacity=4096,
                    sample_sizes=[256, 256, 256], seed=3, mode=mode,
                    fraction=0.25 if mode == "srs" else None,
                    engine=engine, **kw)


# ------------------------------------------------------------ dispatches --
@pytest.mark.parametrize("mode", ["whs", "srs"])
def test_one_dispatch_per_level_per_tick(mode):
    tree = _tree("level", mode)
    _feed(tree, 1)
    # tick 1: level 0 flushes, its forwards make levels 1 and 2 due+nonempty
    # within the same tick → exactly one jitted dispatch per level.
    assert tree.dispatch_count == len(tree.fanin)
    _feed(tree, 1)  # ticks again with fresh data
    assert tree.dispatch_count == 2 * len(tree.fanin)


def test_loop_engine_dispatches_per_node():
    tree = _tree("loop")
    _feed(tree, 1)
    assert tree.dispatch_count == sum(tree.fanin)  # 4 + 2 + 1


def test_empty_tick_dispatches_nothing():
    tree = _tree("level")
    tree.tick(1)  # nothing ingested
    assert tree.dispatch_count == 0


# ------------------------------------------------------------ regression --
@pytest.mark.parametrize("mode", ["whs", "srs"])
def test_level_engine_matches_loop_engine(mode):
    """The vectorized engine is bit-identical to the seed per-node engine:
    same keys, same estimates, same bandwidth accounting."""
    trees = {e: _tree(e, mode) for e in ("level", "loop")}
    for tree in trees.values():
        _feed(tree, 4, seed=7)
    lvl, lp = trees["level"], trees["loop"]
    assert lvl.items_forwarded == lp.items_forwarded
    assert len(lvl.results) == len(lp.results) > 0
    for a, b in zip(lvl.results, lp.results):
        assert a["sum"] == b["sum"]
        assert a["mean"] == b["mean"]
        assert a["n_sampled"] == b["n_sampled"]
        np.testing.assert_array_equal(a["histogram"], b["histogram"])


def test_level_engine_matches_loop_via_pipeline():
    """Full pipeline (async intervals included) agrees across engines."""
    kw = dict(fraction=0.2, ticks=5, seed=2, interval_ticks=[1, 2, 1])
    a = run_pipeline(S.paper_gaussian(), engine="level", **kw)
    b = run_pipeline(S.paper_gaussian(), engine="loop", **kw)
    np.testing.assert_allclose(a["approx_sum"], b["approx_sum"], rtol=1e-6)
    np.testing.assert_allclose(a["bound_2sigma"], b["bound_2sigma"], rtol=1e-6)
    assert a["items_forwarded"] == b["items_forwarded"]


def test_level_whsamp_matches_per_node_whsamp():
    """level_whsamp over stacked buffers ≡ whsamp per node, same keys."""
    rng = np.random.default_rng(0)
    n, cap, x = 4, 512, 3
    values = jnp.asarray(rng.normal(10, 3, (n, cap)), jnp.float32)
    strata = jnp.asarray(rng.integers(0, x, (n, cap)), jnp.int32)
    valid = jnp.asarray(rng.random((n, cap)) < 0.8)
    w_in = jnp.asarray(rng.uniform(1, 5, (n, x)), jnp.float32)
    c_in = jnp.asarray(rng.integers(0, 100, (n, x)), jnp.float32)
    keys = jax.vmap(lambda i: jax.random.fold_in(jax.random.PRNGKey(42), i))(
        jnp.arange(n, dtype=jnp.uint32))

    res = whs.level_whsamp(keys, values, strata, valid, w_in, c_in,
                           jnp.float32(64), x)
    for i in range(n):
        batch = IntervalBatch(values[i], strata[i], valid[i],
                              StratumMeta(w_in[i], c_in[i]))
        ri = whs.whsamp(keys[i], batch, jnp.float32(64), x)
        assert (np.asarray(res.selected[i]) == np.asarray(ri.selected)).all()
        np.testing.assert_array_equal(res.meta.weight[i], ri.meta.weight)
        np.testing.assert_array_equal(res.meta.count[i], ri.meta.count)
        np.testing.assert_array_equal(res.y[i], ri.y)


def test_pallas_backend_through_tree_matches_argsort():
    kw = dict(fraction=0.25, ticks=2, seed=4, capacity=1024)
    a = run_pipeline(S.paper_gaussian(), sampler_backend="argsort", **kw)
    p = run_pipeline(S.paper_gaussian(), sampler_backend="pallas", **kw)
    np.testing.assert_allclose(a["approx_sum"], p["approx_sum"], rtol=1e-6)


# ------------------------------------------------------------ truncation --
def test_compact_sample_truncation_weight_corrected():
    """n_sel > out_capacity: the forwarded sample must still represent the
    same item total (W·C preserved per stratum) instead of silently
    dropping mass."""
    rng = np.random.default_rng(1)
    m, x = 256, 2
    batch = IntervalBatch(jnp.asarray(rng.normal(5, 1, m), jnp.float32),
                          jnp.asarray(np.arange(m) % x, jnp.int32),
                          jnp.ones((m,), bool), StratumMeta.identity(x))
    res = whs.whsamp(jax.random.PRNGKey(0), batch, jnp.float32(64), x)
    out = whs.compact_sample(batch, res, 16)     # 64 selected → 16 slots
    assert int(np.asarray(out.valid).sum()) == 16
    kept = np.bincount(np.asarray(out.stratum)[np.asarray(out.valid)],
                       minlength=x).astype(np.float64)
    # represented totals survive the truncation: W'·C' == W·Y per stratum
    w0, c0 = np.asarray(res.meta.weight), np.asarray(res.y)
    w1, c1 = np.asarray(out.meta.weight), np.asarray(out.meta.count)
    np.testing.assert_array_equal(c1, kept)
    np.testing.assert_allclose(w1 * c1, w0 * c0, rtol=1e-6)


def test_compact_sample_no_truncation_unchanged():
    """Provisioned case (out_capacity ≥ Σ Y): meta passes through exactly."""
    rng = np.random.default_rng(2)
    m, x = 256, 2
    batch = IntervalBatch(jnp.asarray(rng.normal(5, 1, m), jnp.float32),
                          jnp.asarray(np.arange(m) % x, jnp.int32),
                          jnp.ones((m,), bool), StratumMeta.identity(x))
    res = whs.whsamp(jax.random.PRNGKey(0), batch, jnp.float32(64), x)
    out = whs.compact_sample(batch, res, 64)
    np.testing.assert_array_equal(np.asarray(out.meta.weight),
                                  np.asarray(res.meta.weight))
    np.testing.assert_array_equal(np.asarray(out.meta.count),
                                  np.asarray(res.meta.count))

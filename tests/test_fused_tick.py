"""Fused single-kernel level tick: the Pallas kernel must be bit-identical
to the jnp oracle (counts + allocation + argsort selection + Alg. 2 weight
update + scatter pack), and ``whs.level_tick`` with the ``pallas_fused``
backend must be bit-identical to ``level_whsamp`` + ``level_compact`` with
the ``argsort`` reference. All checks run in interpret mode off-TPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sampling, whs
from repro.kernels.fused_level_tick import ops as ft_ops
from repro.kernels.fused_level_tick import ref as ft_ref

jax.config.update("jax_enable_x64", False)


def _level(seed, n, cap, x, fill=1.0, front_packed=True):
    """A stacked level: [n, cap] buffers with ~fill*cap live items."""
    rng = np.random.default_rng(seed)
    vals = rng.normal(100, 25, (n, cap)).astype(np.float32)
    strata = rng.integers(0, x, (n, cap)).astype(np.int32)
    counts = rng.integers(0, max(int(fill * cap), 1) + 1, n)
    if front_packed:
        valid = np.arange(cap)[None, :] < counts[:, None]
    else:
        valid = np.zeros((n, cap), bool)
        for i in range(n):
            valid[i, rng.choice(cap, counts[i], replace=False)] = True
    w_in = np.abs(rng.normal(1, 0.2, (n, x))).astype(np.float32)
    c_in = rng.integers(0, 500, (n, x)).astype(np.float32)
    u = rng.random((n, cap)).astype(np.float32)
    return (jnp.asarray(vals), jnp.asarray(strata), jnp.asarray(valid),
            jnp.asarray(u), jnp.asarray(w_in), jnp.asarray(c_in))


def _assert_tick_equal(a, b):
    names = ("keep", "values_c", "strata_c", "n_keep", "c", "reservoirs",
             "y", "w_out", "c_out")
    for name, x, y in zip(names, a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=name)


# ------------------------------------------------- kernel vs jnp oracle --
@pytest.mark.parametrize("n,cap,x,budget,fill,packed", [
    (4, 256, 4, 60, 1.0, True),
    (2, 512, 8, 500, 0.6, True),
    (3, 128, 3, 7, 0.9, False),     # holes: scatter pack path
    (1, 1024, 16, 999, 1.0, True),  # budget ~= capacity: saturation path
    (2, 256, 5, 0, 1.0, True),      # zero budget: sentinel thresholds
])
def test_fused_kernel_matches_oracle(n, cap, x, budget, fill, packed):
    vals, strata, valid, u, w_in, c_in = _level(
        7 * n + cap, n, cap, x, fill=fill, front_packed=packed)
    size = jnp.asarray(float(budget), jnp.float32)
    out_cap = cap
    a = ft_ops.fused_level_tick(vals, strata, valid, u, w_in, c_in, size,
                                x, out_cap, impl="pallas")
    b = ft_ops.fused_level_tick(vals, strata, valid, u, w_in, c_in, size,
                                x, out_cap, impl="ref")
    _assert_tick_equal(a, b)


def test_fused_kernel_truncating_out_capacity():
    vals, strata, valid, u, w_in, c_in = _level(11, 3, 256, 4)
    size = jnp.asarray(48.0, jnp.float32)
    for out_cap in (64, 96):
        a = ft_ops.fused_level_tick(vals, strata, valid, u, w_in, c_in,
                                    size, 4, out_cap, impl="pallas")
        b = ft_ops.fused_level_tick(vals, strata, valid, u, w_in, c_in,
                                    size, 4, out_cap, impl="ref")
        _assert_tick_equal(a, b)


def test_fused_select_matches_argsort_reference():
    rng = np.random.default_rng(3)
    m, x = 4096, 8
    u = jnp.asarray(rng.random(m).astype(np.float32))
    strata = jnp.asarray(rng.integers(0, x, m).astype(np.int32))
    valid = jnp.asarray(rng.random(m) < 0.8)
    res = jnp.asarray(rng.integers(0, 200, x).astype(np.float32))
    a = ft_ops.fused_select(u, strata, valid, res, x, impl="pallas")
    b = ft_ops.fused_select(u, strata, valid, res, x, impl="ref")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_select_exact_ties_match_argsort():
    """Quantised priorities force real f32 collisions; the in-kernel tie
    rank must keep exactly the earliest-position ties, like lexsort."""
    rng = np.random.default_rng(9)
    m, x = 8192, 4
    u = (rng.integers(0, 97, m) / 97.0).astype(np.float32)  # heavy ties
    strata = jnp.asarray(rng.integers(0, x, m).astype(np.int32))
    valid = jnp.asarray(np.ones(m, bool))
    res = jnp.asarray(np.full(x, 37.0, np.float32))
    a = ft_ops.fused_select(jnp.asarray(u), strata, valid, res, x,
                            impl="pallas")
    b = ft_ops.fused_select(jnp.asarray(u), strata, valid, res, x,
                            impl="ref")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -------------------------------------- level_tick vs unfused reference --
@pytest.mark.parametrize("backend", ["pallas_fused", "argsort", "topk"])
@pytest.mark.parametrize("fill,packed", [(1.0, True), (0.5, True),
                                         (0.8, False)])
def test_level_tick_matches_unfused_pipeline(backend, fill, packed):
    n, cap, x = 3, 256, 4
    vals, strata, valid, u, w_in, c_in = _level(21, n, cap, x, fill=fill,
                                                front_packed=packed)
    keys = jax.random.split(jax.random.key(5), n)
    size = jnp.asarray(40.0, jnp.float32)
    out_cap = 128

    vc, sc, sv, meta, res = whs.level_tick(
        keys, vals, strata, valid, w_in, c_in, size, x,
        out_capacity=out_cap, backend=backend)

    # Unfused reference, always through the argsort oracle.
    ref_res = whs.level_whsamp(keys, vals, strata, valid, w_in, c_in, size,
                               x, max_reservoir=out_cap, backend="argsort")
    rvc, rsc, rsv, rmeta = whs.level_compact(vals, strata, ref_res,
                                             out_capacity=out_cap)
    np.testing.assert_array_equal(np.asarray(vc), np.asarray(rvc))
    np.testing.assert_array_equal(np.asarray(sc), np.asarray(rsc))
    np.testing.assert_array_equal(np.asarray(sv), np.asarray(rsv))
    np.testing.assert_array_equal(np.asarray(meta.weight),
                                  np.asarray(rmeta.weight))
    np.testing.assert_array_equal(np.asarray(meta.count),
                                  np.asarray(rmeta.count))
    np.testing.assert_array_equal(np.asarray(res.selected),
                                  np.asarray(ref_res.selected))


def test_level_tick_saturated_passthrough_bit_identical():
    """fraction >= 1.0: budget covers every stratum, buffers front-packed
    -> the passthrough branch must equal the full select + scatter pack."""
    n, cap, x = 2, 256, 4
    vals, strata, valid, u, w_in, c_in = _level(33, n, cap, x, fill=0.4)
    keys = jax.random.split(jax.random.key(8), n)
    size = jnp.asarray(float(cap), jnp.float32)   # saturating budget
    for backend in ("argsort", "pallas_fused"):
        vc, sc, sv, meta, res = whs.level_tick(
            keys, vals, strata, valid, w_in, c_in, size, x,
            out_capacity=cap, backend=backend)
        np.testing.assert_array_equal(np.asarray(res.selected),
                                      np.asarray(valid))
        ref_res = whs.level_whsamp(keys, vals, strata, valid, w_in, c_in,
                                   size, x, max_reservoir=cap,
                                   backend="argsort")
        rvc, rsc, rsv, rmeta = whs.level_compact(vals, strata, ref_res,
                                                 out_capacity=cap)
        np.testing.assert_array_equal(np.asarray(vc), np.asarray(rvc))
        np.testing.assert_array_equal(np.asarray(meta.weight),
                                      np.asarray(rmeta.weight))


def test_backend_registry_advertises_fused():
    be = sampling.get_backend("pallas_fused")
    assert getattr(be, "fused_level_tick", False)
    assert getattr(be, "flatten_for_level", False)
    # plain backends must NOT take the fused branch
    assert not getattr(sampling.get_backend("argsort"),
                       "fused_level_tick", False)


def test_oracle_composes_unfused_stages():
    """The ref oracle itself must agree with the hand-composed stages —
    guards against the oracle and kernel drifting together."""
    n, cap, x = 2, 128, 4
    vals, strata, valid, u, w_in, c_in = _level(55, n, cap, x, fill=0.7)
    size = jnp.asarray(30.0, jnp.float32)
    keep, vc, sc, n_keep, c, res, y, w_out, c_out = ft_ref.fused_level_tick(
        vals, strata, valid, u, w_in, c_in, size, x, cap)
    for i in range(n):
        counts_i = sampling.stratum_counts(strata[i], valid[i], x)
        np.testing.assert_array_equal(np.asarray(c[i]),
                                      np.asarray(counts_i))
        res_i = sampling.allocate_reservoirs(size, counts_i, policy="fair")
        np.testing.assert_array_equal(np.asarray(res[i]),
                                      np.asarray(res_i))
        sel_i = sampling.stratified_priority_sample(
            None, strata[i], valid[i], res_i, x, priorities=u[i])
        np.testing.assert_array_equal(np.asarray(keep[i]),
                                      np.asarray(sel_i))

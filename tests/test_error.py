"""Error-bound (§III-D) correctness: variance estimates and CLT coverage."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import error as err, queries, whs
from repro.core.types import IntervalBatch, StratumMeta


def test_sample_variance_matches_numpy():
    rng = np.random.default_rng(0)
    vals = rng.normal(3, 2, 500).astype(np.float32)
    strata = rng.integers(0, 3, 500).astype(np.int32)
    sel = rng.random(500) < 0.7
    y, s1, s2 = err.stratum_moments(jnp.asarray(vals), jnp.asarray(strata),
                                    jnp.asarray(sel), 3)
    s_sq = np.asarray(err.sample_variance(y, s1, s2))
    for i in range(3):
        ref = np.var(vals[sel & (strata == i)], ddof=1)
        np.testing.assert_allclose(s_sq[i], ref, rtol=2e-3)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_zero_variance_when_fully_sampled(seed):
    """c_i ≤ N_i ⇒ FPC kills the variance: exact answer, zero bound."""
    rng = np.random.default_rng(seed)
    m = 64
    vals = rng.normal(0, 1, m).astype(np.float32)
    batch = IntervalBatch(jnp.asarray(vals), jnp.zeros((m,), jnp.int32),
                          jnp.ones((m,), bool), StratumMeta.identity(1))
    res = whs.whsamp(jax.random.PRNGKey(seed), batch, jnp.float32(m), 1)
    q = queries.weighted_sum(batch, res, 1)
    np.testing.assert_allclose(float(q.estimate), vals.sum(), rtol=1e-5)
    assert float(q.variance) == 0.0


def test_two_sigma_coverage():
    """±2σ bound contains the exact sum ≈95% of the time (CLT)."""
    rng = np.random.default_rng(42)
    m, x = 4096, 4
    strata = rng.integers(0, x, m).astype(np.int32)
    vals = rng.normal(50, 20, m).astype(np.float32)
    batch = IntervalBatch(jnp.asarray(vals), jnp.asarray(strata),
                          jnp.ones((m,), bool), StratumMeta.identity(x))
    exact = float(vals.sum())
    hits = 0
    trials = 100
    for t in range(trials):
        res = whs.whsamp(jax.random.PRNGKey(t), batch, jnp.float32(400), x)
        q = queries.weighted_sum(batch, res, x)
        if abs(float(q.estimate) - exact) <= float(q.bound(2.0)):
            hits += 1
    assert hits >= 85, f"2σ coverage only {hits}/{trials}"


def test_mean_estimator_and_bound():
    rng = np.random.default_rng(7)
    m, x = 2048, 2
    strata = (np.arange(m) % x).astype(np.int32)
    vals = np.where(strata == 0, rng.normal(10, 1, m),
                    rng.normal(1000, 10, m)).astype(np.float32)
    batch = IntervalBatch(jnp.asarray(vals), jnp.asarray(strata),
                          jnp.ones((m,), bool), StratumMeta.identity(x))
    res = whs.whsamp(jax.random.PRNGKey(0), batch, jnp.float32(256), x)
    q = queries.weighted_mean(batch, res, x)
    exact = float(vals.mean())
    assert abs(float(q.estimate) - exact) / exact < 0.05
    assert float(q.variance) > 0


def test_histogram_exact_at_fraction_one():
    """Fraction 1.0 ⇒ every weight is 1: the histogram estimate equals the
    exact histogram bin-for-bin and every bin's variance is exactly 0."""
    rng = np.random.default_rng(12)
    m, x = 1024, 3
    strata = rng.integers(0, x, m).astype(np.int32)
    vals = rng.uniform(0, 10, m).astype(np.float32)
    batch = IntervalBatch(jnp.asarray(vals), jnp.asarray(strata),
                          jnp.ones((m,), bool), StratumMeta.identity(x))
    res = whs.whsamp(jax.random.PRNGKey(2), batch, jnp.float32(m), x)
    edges = jnp.linspace(0, 10, 9)
    q = queries.weighted_histogram(batch, res, x, edges)
    exact, _ = np.histogram(vals, np.asarray(edges))
    np.testing.assert_array_equal(np.asarray(q.estimate), exact)
    np.testing.assert_array_equal(np.asarray(q.variance),
                                  np.zeros(8, np.float32))


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_clt_two_sigma_coverage_property(base_seed):
    """Satellite property: over ≥200 independent sampling draws, the ±2σ
    interval from approx_sum AND approx_mean covers the true value at a
    rate consistent with 95% (tolerance band — 2σ two-sided coverage over
    200 Bernoulli(0.95) trials stays above 0.88 w.p. ≫ 0.999)."""
    rng = np.random.default_rng(base_seed)
    m, x = 2048, 3
    strata = rng.integers(0, x, m).astype(np.int32)
    vals = rng.normal(100, 30, m).astype(np.float32)
    batch = IntervalBatch(jnp.asarray(vals), jnp.asarray(strata),
                          jnp.ones((m,), bool), StratumMeta.identity(x))
    exact_sum = float(np.asarray(vals, np.float64).sum())
    exact_mean = exact_sum / m
    trials = 200

    @jax.jit
    def trial(key):
        res = whs.whsamp(key, batch, jnp.float32(256), x)
        qs = queries.weighted_sum(batch, res, x)
        qm = queries.weighted_mean(batch, res, x)
        return qs.estimate, qs.bound(2.0), qm.estimate, qm.bound(2.0)

    keys = jax.random.split(jax.random.PRNGKey(base_seed), trials)
    se, sb, me, mb = (np.asarray(o) for o in jax.vmap(trial)(keys))
    hit_sum = int((np.abs(se - exact_sum) <= sb).sum())
    hit_mean = int((np.abs(me - exact_mean) <= mb).sum())
    assert 0.88 * trials <= hit_sum <= trials, f"sum coverage {hit_sum}/200"
    assert 0.88 * trials <= hit_mean <= trials, f"mean coverage {hit_mean}/200"


def test_histogram_estimates_counts():
    rng = np.random.default_rng(8)
    m, x = 4096, 2
    strata = (np.arange(m) % x).astype(np.int32)
    vals = rng.uniform(0, 10, m).astype(np.float32)
    batch = IntervalBatch(jnp.asarray(vals), jnp.asarray(strata),
                          jnp.ones((m,), bool), StratumMeta.identity(x))
    res = whs.whsamp(jax.random.PRNGKey(0), batch, jnp.float32(1024), x)
    edges = jnp.linspace(0, 10, 6)
    q = queries.weighted_histogram(batch, res, x, edges)
    exact, _ = np.histogram(vals, np.asarray(edges))
    rel = np.abs(np.asarray(q.estimate) - exact) / np.maximum(exact, 1)
    assert (rel < 0.2).all(), rel

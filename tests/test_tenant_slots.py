"""PR-7 padded-slot control plane: admit/retire as state edits.

The contracts under test:
  * admit/retire equivalence — a churned pipeline's answers are bitwise
    what a fresh compile of the same live set produces (local and mesh
    paths): slots and masking are invisible in the public vector;
  * masked-slot invariance — retired slots never perturb active
    tenants' answers, bounds, or error attribution;
  * the PR-4 two-tenant bitwise law survives any bucket size (slots
    padded by churn, then masked);
  * zero-retrace churn — recycling slots inside a bucket traces
    nothing; only crossing a bucket boundary compiles (one program per
    bucket, cached);
  * checkpoint slot manifests — restoring into a differently-churned
    pipeline is an actionable ``SpecError``, not silent mis-routing.
"""
import jax
import numpy as np
import pytest

from repro import api
from repro.api import (BudgetSpec, PipelineSpec, SamplerSpec, SpecError,
                       TenantSpec, TopologySpec)
from repro.data import stream as S
from repro.query.registry import QueryRegistry

X = 3


def _spec(tenants, seed=5):
    return PipelineSpec(
        topology=TopologySpec(fanin=(4, 2, 1), capacity=768, num_strata=X),
        sampler=SamplerSpec(mode="whs", backend="topk"),
        tenants=tuple(tenants),
        budget=BudgetSpec(sample_sizes=(96, 96, 96)),
        seed=seed,
    )


def _reg_a():
    return (QueryRegistry().register_sum().register_mean()
            .register_quantile("q", (0.5, 0.9), capacity=64))


def _reg_b():
    return (QueryRegistry().register_count()
            .register_histogram("h", 0.0, 100.0, 8)
            .register_heavy_hitters("hh", k=4, width=256))


def _tenant(name, reg):
    return TenantSpec.from_registry(name, reg)


def _ingest(ticks=3, n0=4, width=400, seed=11):
    rng = np.random.default_rng(seed)
    vals = rng.normal(50, 9, (ticks, n0, width)).astype(np.float32)
    strs = rng.integers(0, X, (ticks, n0, width)).astype(np.int32)
    counts = rng.integers(100, width, (ticks, n0)).astype(np.int32)
    return vals, strs, counts


def _epoch(pipe, data, state=None):
    state = pipe.init() if state is None else state
    return pipe.run_epoch(state, pipe.default_key, *data)


# ---------------------------------------------------- churn equivalence --
def test_admit_equivalence_local():
    """compile({a}) + admit(b) + admit(c) ≡ compile({a,b,c}), bitwise —
    c shares a's signature, so its admit grows a slot bucket (1→2)
    rather than opening a group; the public vector must not notice."""
    data = _ingest()
    a, b = _tenant("alpha", _reg_a()), _tenant("beta", _reg_b())
    c = _tenant("gamma", _reg_a())

    fresh = api.compile(_spec((a, b, c)))
    _, w_fresh = _epoch(fresh, data)

    pipe = api.compile(_spec((a,)))
    state = pipe.init()
    pipe, state = pipe.admit(state, b)
    pipe, state = pipe.admit(state, c)
    state, w_churn = pipe.run_epoch(state, pipe.default_key, *data)

    assert pipe.tenant_names == fresh.tenant_names
    np.testing.assert_array_equal(np.asarray(w_churn.answers),
                                  np.asarray(w_fresh.answers))
    np.testing.assert_array_equal(np.asarray(w_churn.bounds),
                                  np.asarray(w_fresh.bounds))
    # churn edited the spec too: the clone is recompilable as-is
    assert tuple(t.name for t in pipe.spec.tenants) == (
        "alpha", "beta", "gamma")


def test_retire_equivalence_local():
    """compile({a,b,c}) + retire(b) answers ≡ compile({a,c}) answers,
    bitwise — b's slot stays allocated but masked, and the compacted
    public vector carries exactly the live tenants' blocks."""
    data = _ingest()
    a, b = _tenant("alpha", _reg_a()), _tenant("beta", _reg_b())
    c = _tenant("gamma", _reg_a())

    pipe = api.compile(_spec((a, b, c)))
    state = pipe.init()
    pipe, state = pipe.retire(state, "beta")
    state, w_churn = pipe.run_epoch(state, pipe.default_key, *data)

    fresh = api.compile(_spec((a, c)))
    _, w_fresh = _epoch(fresh, data)

    assert pipe.tenant_names == ("alpha", "gamma")
    np.testing.assert_array_equal(np.asarray(w_churn.answers),
                                  np.asarray(w_fresh.answers))
    np.testing.assert_array_equal(np.asarray(w_churn.bounds),
                                  np.asarray(w_fresh.bounds))
    with pytest.raises(SpecError):
        pipe.retire(state, "nope")


def test_admit_retire_equivalence_mesh():
    """The same churn law on the SPMD path (1-device mesh in-process):
    admit + retire are sharded-state edits and the merged-summary
    answers match a fresh compile of the live set bitwise."""
    mesh = jax.make_mesh((1,), ("data",))
    a, b = _tenant("alpha", _reg_a()), _tenant("beta", _reg_b())
    c = _tenant("gamma", _reg_a())
    rng = np.random.default_rng(3)
    T, M = 3, 512
    vals = rng.normal(50, 9, (T, M)).astype(np.float32)
    strs = rng.integers(0, X, (T, M)).astype(np.int32)
    counts = np.full((T,), M, np.int64)
    batches = S.rows_to_interval_batch(vals, strs, counts, X)

    def mesh_spec(tenants):
        return PipelineSpec(
            topology=TopologySpec(fanin=(4, 2, 1), capacity=M,
                                  num_strata=X),
            sampler=SamplerSpec(mode="whs", backend="topk", fraction=0.25),
            tenants=tuple(tenants), seed=0)

    pipe = api.compile(mesh_spec((a, b)), mesh=mesh)
    state = pipe.init()
    pipe, state = pipe.admit(state, c)
    pipe, state = pipe.retire(state, "beta")
    state, w_churn = pipe.run_epoch(state, pipe.default_key, batches)

    fresh = api.compile(mesh_spec((a, c)), mesh=mesh)
    _, w_fresh = fresh.run_epoch(fresh.init(), fresh.default_key, batches)

    assert pipe.tenant_names == ("alpha", "gamma")
    np.testing.assert_array_equal(np.asarray(w_churn.answers),
                                  np.asarray(w_fresh.answers))
    np.testing.assert_array_equal(np.asarray(w_churn.bounds),
                                  np.asarray(w_fresh.bounds))


# ------------------------------------------------ masked-slot invariance --
def test_masked_slots_never_affect_active_tenants():
    """A retired neighbour (frozen sketch state, mask off) is invisible:
    the surviving tenants' per-window answers, bounds, and per-tenant
    error attribution are bitwise those of a never-churned pipeline."""
    from repro.runtime.budget import aggregate_tenant_rel_errors

    data = _ingest()
    a, b = _tenant("alpha", _reg_a()), _tenant("beta", _reg_b())
    c = _tenant("gamma", _reg_a())

    # run an epoch WITH gamma live (its sketches absorb data), then
    # retire it — the frozen non-empty slot state must not leak
    pipe = api.compile(_spec((a, b, c)))
    state, _ = _epoch(pipe, data)
    pipe, state = pipe.retire(state, "gamma")
    state, w_churn = pipe.run_epoch(state, pipe.default_key, *data)

    ref = api.compile(_spec((a, b)))
    st_ref, _ = _epoch(ref, data)
    st_ref, w_ref = ref.run_epoch(st_ref, ref.default_key, *data)

    np.testing.assert_array_equal(np.asarray(w_churn.answers),
                                  np.asarray(w_ref.answers))
    np.testing.assert_array_equal(np.asarray(w_churn.bounds),
                                  np.asarray(w_ref.bounds))
    # arbitration sees only live tenants
    per = aggregate_tenant_rel_errors(pipe.plan, pipe.rows(w_churn))
    assert set(per) == {"alpha", "beta"}


def test_two_tenant_law_survives_any_bucket():
    """The PR-4 bitwise law (multi-tenant answers ≡ isolated runs) with
    slots padded well past the live count: grow alpha's group to bucket
    4 via same-signature admits, retire them all, and the padded+masked
    plan must still answer exactly like the isolated single-tenant
    pipelines."""
    data = _ingest()
    a, b = _tenant("alpha", _reg_a()), _tenant("beta", _reg_b())

    pipe = api.compile(_spec((a, b)))
    state = pipe.init()
    for i in range(3):  # alpha's group: bucket 1 → 4
        pipe, state = pipe.admit(state, _tenant(f"pad{i}", _reg_a()))
    for i in range(3):
        pipe, state = pipe.retire(state, f"pad{i}")
    assert sum(n for _, n in pipe.plan.core.groups) >= 5
    state, w2 = pipe.run_epoch(state, pipe.default_key, *data)

    for t, reg in (("alpha", _reg_a()), ("beta", _reg_b())):
        solo = api.compile(_spec((_tenant(t, reg),)))
        _, w1 = _epoch(solo, data)
        np.testing.assert_array_equal(
            pipe.plan.tenant_answers(np.asarray(w2.answers), t),
            np.asarray(w1.answers))
        np.testing.assert_array_equal(
            pipe.plan.tenant_answers(np.asarray(w2.bounds), t),
            np.asarray(w1.bounds))


# ------------------------------------------------- zero-retrace churn --
def test_zero_retrace_churn_inside_bucket():
    """Slot recycling inside a bucket traces nothing: the tick program
    is keyed on the canonical (name-free) core, so retire + admit of
    same-signature tenants reuses the jitted executable."""
    from repro.api.pipeline import program_cache_stats

    data = _ingest(ticks=2)
    regs = [_tenant(f"t{i}", _reg_a()) for i in range(8)]
    pipe = api.compile(_spec(tuple(regs)))
    state, _ = _epoch(pipe, data)
    t0 = pipe.trace_counter["traces"]
    m0 = program_cache_stats()["misses"]

    for i in range(4):
        pipe, state = pipe.retire(state, f"t{i}")
    for i in range(4):
        pipe, state = pipe.admit(state, _tenant(f"new{i}", _reg_a()))
    state, _ = pipe.run_epoch(state, pipe.default_key, *data)

    assert pipe.trace_counter["traces"] == t0
    assert program_cache_stats()["misses"] == m0


def test_one_trace_per_bucket_boundary():
    """Crossing a bucket boundary compiles exactly one new program;
    every admit until the NEXT boundary is then free."""
    from repro.api.pipeline import program_cache_stats

    data = _ingest(ticks=2)
    regs = [_tenant(f"t{i}", _reg_a()) for i in range(2)]
    pipe = api.compile(_spec(tuple(regs)))
    state, _ = _epoch(pipe, data)  # bucket 2 program traced
    m0 = program_cache_stats()["misses"]

    pipe, state = pipe.admit(state, _tenant("t2", _reg_a()))  # 2 → 4
    state, _ = pipe.run_epoch(state, pipe.default_key, *data)
    assert program_cache_stats()["misses"] == m0 + 1

    pipe, state = pipe.admit(state, _tenant("t3", _reg_a()))  # inside 4
    state, _ = pipe.run_epoch(state, pipe.default_key, *data)
    assert program_cache_stats()["misses"] == m0 + 1


# ---------------------------------------------------- checkpoint slots --
def test_restore_rejects_differently_churned_pipeline(tmp_path):
    """A checkpoint written under one slot configuration must not load
    into a pipeline that churned differently — the slot manifest rides
    the checkpoint and the mismatch is an actionable SpecError."""
    data = _ingest(ticks=2)
    a, b = _tenant("alpha", _reg_a()), _tenant("beta", _reg_b())

    pipe = api.compile(_spec((a, b)))
    state, _ = _epoch(pipe, data)
    api.save_state(tmp_path, 1, state, pipeline=pipe)

    # same live set, same spec — restores bitwise
    again = api.compile(_spec((a, b)))
    restored, _ = api.restore_state(tmp_path, again, 1)
    for la, lb in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))

    # differently-churned: same live set reached via churn, different
    # slot allocation (gamma grew alpha's bucket) → actionable rejection
    churned = api.compile(_spec((a, b)))
    churned, st2 = churned.admit(churned.init(), _tenant("gamma", _reg_a()))
    churned, st2 = churned.retire(st2, "gamma")
    with pytest.raises(SpecError, match="tenant-slot configuration"):
        api.restore_state(tmp_path, churned, 1)

"""Sharding-rule unit tests (mesh built from 1 real device is enough to
exercise the rule engine; the real 512-way lowering is the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import registry
from repro.launch import sharding
from repro.models import model as M


class FakeMesh:
    """Axis-name/size stand-in (rule engine only reads names + shape)."""
    def __init__(self, shape: dict):
        self._shape = shape
        self.axis_names = tuple(shape)
        self.size = int(np.prod(list(shape.values())))
    @property
    def shape(self):
        return dict(self._shape)


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _specs(arch, mesh):
    cfg = registry.get_config(arch)
    p_shape = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    return cfg, p_shape, sharding.param_specs(p_shape, mesh)


def test_dense_rules_single_pod():
    cfg, shp, spec = _specs("olmo-1b", MESH1)
    assert spec["embed"]["table"] == P("model", "data")
    assert spec["unembed"]["w"] == P("data", "model")
    # stacked layer leaves get the leading None
    assert spec["layers"]["attn"]["wq"] == P(None, "data", "model")
    assert spec["layers"]["mlp"]["w_down"] == P(None, "model", "data")


def test_multi_pod_fsdp_spans_pods():
    cfg, shp, spec = _specs("olmo-1b", MESH2)
    assert spec["layers"]["attn"]["wq"] == P(None, ("pod", "data"), "model")
    assert spec["embed"]["table"] == P("model", ("pod", "data"))


def test_odd_vocab_falls_back_replicated():
    cfg, shp, spec = _specs("whisper-medium", MESH1)
    # 51865 is not divisible by 16 on either axis grouping
    assert spec["embed"]["table"] == P(None, "data")
    assert spec["unembed"]["w"] == P("data", None)


def test_moe_ep_when_divisible_else_tp():
    _, _, spec = _specs("qwen2-moe-a2.7b", MESH1)   # 60 experts: TP fallback
    assert spec["layers"]["moe"]["w_gate"] == P(None, None, "data", "model")
    _, _, spec16 = _specs("grok-1-314b", MESH1)     # 8 experts: TP fallback
    assert spec16["layers"]["moe"]["w_gate"] == P(None, None, "data", "model")


def test_cache_specs_shard_heads_or_seq():
    cfg = registry.get_config("deepseek-coder-33b")   # kv=8: heads don't divide
    cache = M.cache_specs(cfg, 128, 1024)
    spec = sharding.cache_specs_tree(cache, MESH1)
    assert spec["k"] == P(None, "data", None, "model", None)
    cfg2 = registry.get_config("olmo-1b")             # kv=16: heads divide
    cache2 = M.cache_specs(cfg2, 128, 1024)
    spec2 = sharding.cache_specs_tree(cache2, MESH1)
    assert spec2["k"] == P(None, "data", "model", None, None)


def test_cache_long_context_batch1_seq_sharded():
    cfg = registry.get_config("zamba2-1.2b")
    cache = M.cache_specs(cfg, 1, 524_288)
    spec = sharding.cache_specs_tree(cache, MESH1)
    # B=1 can't shard the batch → sequence-parallel over the data axis
    assert spec["attn_k"] == P(None, None, "model", "data", None)


def test_batch_specs():
    specs = {
        "tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32),
        "weight": jax.ShapeDtypeStruct((256,), jnp.float32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    out = sharding.batch_specs(specs, MESH1)
    assert out["tokens"] == P("data", None)
    assert out["weight"] == P("data")
    assert out["pos"] == P()
    out2 = sharding.batch_specs(specs, MESH2)
    assert out2["tokens"] == P(("pod", "data"), None)


def test_every_param_spec_divides(capsys):
    """No rule may emit a non-divisible sharding for any arch (the
    validator must have cleaned it up)."""
    for arch in registry.ARCH_NAMES:
        cfg, shp, spec = _specs(arch, MESH2)
        sizes = MESH2.shape

        def check(path, leaf, sp):
            for dim, ax in zip(leaf.shape, tuple(sp) + (None,) * 9):
                if ax is None:
                    continue
                prod = 1
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    prod *= sizes[a]
                assert dim % prod == 0, (arch, path, leaf.shape, sp)

        jax.tree_util.tree_map_with_path(
            lambda p, l, s: check(p, l, s), shp, spec)

"""Continuous query plane: registry/compiler correctness, sketch accuracy,
K=8 queries answered in the scan engine's single epoch dispatch with
bit-identical sample state, dynamic budgets with zero retraces, and the
closed-loop error-budget controller."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import whs
from repro.core.tree import HostTree
from repro.core.types import IntervalBatch, StratumMeta
from repro.core.window import TreeState
from repro.query import sketches as sk
from repro.query.registry import QueryRegistry, QuerySpec

X = 3


def _k8_registry():
    return (QueryRegistry()
            .register_sum()
            .register_count()
            .register_mean()
            .register_histogram("hist_lo", 0.0, 80.0, 16)
            .register_histogram("hist_hi", 0.0, 120.0, 8)
            .register_quantile("quant", (0.5, 0.9, 0.99), capacity=128)
            .register_quantile("median", (0.5,), capacity=64)
            .register_heavy_hitters("hh", k=8, width=512, depth=4))


def _tree(engine, queries=None, seed=5, **kw):
    return HostTree(fanin=[4, 2, 1], num_strata=X, capacity=768,
                    sample_sizes=[96, 96, 96], seed=seed, engine=engine,
                    sampler_backend="topk", queries=queries, **kw)


def _ingest_arrays(ticks, n0=4, width=400, seed=11):
    rng = np.random.default_rng(seed)
    vals = rng.normal(50, 9, (ticks, n0, width)).astype(np.float32)
    strs = rng.integers(0, X, (ticks, n0, width)).astype(np.int32)
    counts = rng.integers(100, width, (ticks, n0)).astype(np.int32)
    return vals, strs, counts


def _full_batch(m=512, seed=0, strata=X):
    rng = np.random.default_rng(seed)
    vals = rng.normal(50, 9, m).astype(np.float32)
    strs = rng.integers(0, strata, m).astype(np.int32)
    return IntervalBatch(jnp.asarray(vals), jnp.asarray(strs),
                         jnp.ones((m,), bool), StratumMeta.identity(strata))


# ------------------------------------------------------------- registry --
def test_registry_layout_and_k():
    plan = _k8_registry().compile(X)
    assert plan.k == 8
    lay = plan.layout()
    assert lay["sum"] == (0, 1, "sum")
    assert lay["hist_lo"][1] == 16 and lay["quant"][1] == 3
    assert lay["hh"][1] == 16                      # k keys + k estimates
    assert plan.n_out == sum(w for _, w, _ in lay.values())


def test_registry_rejects_duplicates_and_bad_kinds():
    reg = QueryRegistry().register_sum()
    with pytest.raises(ValueError):
        reg.register_sum()
    with pytest.raises(ValueError):
        QuerySpec("x", "p99")
    with pytest.raises(ValueError):
        QuerySpec("q", "quantile")                 # no qs
    with pytest.raises(ValueError):
        QuerySpec("h", "heavy_hitters", width=1000)  # not 2^n


def test_registry_from_tokens_roundtrip():
    reg = QueryRegistry.from_tokens(
        "sum,count,mean,hist:0:100:8,q:0.5:0.99,hh:4")
    kinds = [s.kind for s in reg.specs]
    assert kinds == ["sum", "count", "mean", "histogram", "quantile",
                     "heavy_hitters"]
    assert reg.specs[3].bins == 8 and reg.specs[4].qs == (0.5, 0.99)
    assert reg.specs[5].k == 4


# ------------------------------------------------------------- compiler --
def test_compiled_clt_queries_match_reference_functions():
    """Fused evaluation ≡ the standalone queries.* / error.* functions."""
    from repro.core import queries as Q

    batch = _full_batch()
    res = whs.whsamp(jax.random.PRNGKey(3), batch, jnp.float32(128), X)
    plan = (QueryRegistry().register_sum().register_count().register_mean()
            .register_histogram("h", 0.0, 80.0, 16)).compile(X)
    _, ans, bnd = plan.evaluate(jax.random.PRNGKey(9), batch, res,
                                plan.init_state())
    s = Q.weighted_sum(batch, res, X)
    c = Q.weighted_count(batch, res, X)
    m = Q.weighted_mean(batch, res, X)
    h = Q.weighted_histogram(batch, res, X, jnp.linspace(0.0, 80.0, 17))
    np.testing.assert_array_equal(plan.answer(ans, "sum"),
                                  [float(s.estimate)])
    np.testing.assert_allclose(plan.answer(ans, "count"),
                               [float(c.estimate)], rtol=1e-6)
    np.testing.assert_array_equal(plan.answer(ans, "mean"),
                                  [float(m.estimate)])
    np.testing.assert_array_equal(plan.answer(ans, "h"),
                                  np.asarray(h.estimate))
    np.testing.assert_array_equal(plan.answer(bnd, "sum"),
                                  [float(s.bound(2.0))])
    np.testing.assert_array_equal(plan.answer(bnd, "h"),
                                  np.asarray(h.bound(2.0)))


def test_fraction_one_quantile_and_hh_exact():
    """At fraction 1.0 every weight is 1: the quantile summary under its
    capacity is lossless and heavy-hitter estimates equal true counts."""
    m = 100
    rng = np.random.default_rng(4)
    vals = np.round(rng.normal(20, 3, m)).astype(np.float32)
    batch = IntervalBatch(jnp.asarray(vals),
                          jnp.zeros((m,), jnp.int32),
                          jnp.ones((m,), bool), StratumMeta.identity(1))
    res = whs.whsamp(jax.random.PRNGKey(0), batch, jnp.float32(m), 1)
    plan = (QueryRegistry()
            .register_quantile("q", (0.25, 0.5, 0.75), capacity=256)
            .register_heavy_hitters("hh", k=4, width=1024)).compile(1)
    _, ans, _ = plan.evaluate(jax.random.PRNGKey(1), batch, res,
                              plan.init_state())
    qv = plan.answer(ans, "q")
    srt = np.sort(vals)
    for q, v in zip((0.25, 0.5, 0.75), qv):
        # lossless summary ⇒ exactly the order statistic at rank ⌊q·m⌋
        assert v == srt[int(np.floor(q * m))]
    hh = plan.answer(ans, "hh")
    keys, ests = hh[:4].astype(np.int64), hh[4:]
    true = {k: (np.round(vals).astype(np.int64) == k).sum() for k in keys}
    for k, e in zip(keys, ests):
        assert e == true[k], (k, e, true[k])


# ----------------------------------------------------------- scan engine --
def test_k8_single_dispatch_and_bit_identical_sample_state():
    """THE acceptance property: K=8 standing queries answered per window
    in the same single dispatch per epoch, and every sample/reservoir
    state leaf bit-identical to a run with no queries registered."""
    vals, strs, counts = _ingest_arrays(4)
    plain = _tree("scan")
    plain.run_epoch(1, vals, strs, counts)
    reg = _k8_registry()
    qt = _tree("scan", queries=reg)
    assert qt.plan.k == 8
    qt.run_epoch(1, vals, strs, counts)

    assert qt.dispatch_count == 1 == plain.dispatch_count
    for f in TreeState.LEVEL_FIELDS:
        for a, b in zip(getattr(plain._state, f), getattr(qt._state, f)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert len(qt.results) == len(plain.results) > 0
    for ra, rb in zip(plain.results, qt.results):
        for k in ("tick", "sum", "sum_var", "mean", "mean_var", "n_sampled"):
            assert ra[k] == rb[k], k
        assert rb["answers"].shape == (qt.plan.n_out,)
        assert rb["bounds"].shape == (qt.plan.n_out,)


def test_engines_agree_on_answers_bitwise():
    """scan ≡ level ≡ loop on the full K=8 answer vectors (same key
    folding, same math, three execution strategies)."""
    vals, strs, counts = _ingest_arrays(3)
    scan = _tree("scan", queries=_k8_registry())
    scan.run_epoch(1, vals, strs, counts)
    for engine in ("level", "loop"):
        other = _tree(engine, queries=_k8_registry())
        for t in range(1, 4):
            for node in range(4):
                c = counts[t - 1, node]
                other.ingest(node, vals[t - 1, node, :c],
                             strs[t - 1, node, :c])
            other.tick(t)
        assert len(other.results) == len(scan.results)
        for ra, rb in zip(scan.results, other.results):
            np.testing.assert_array_equal(ra["answers"], rb["answers"])
            np.testing.assert_array_equal(ra["bounds"], rb["bounds"])


def test_sketch_state_rides_tree_state_and_is_donated():
    vals, strs, counts = _ingest_arrays(2)
    qt = _tree("scan", queries=_k8_registry())
    # slotted layout: one (mask, stacked-per-spec) group, leaves carrying
    # a leading slot axis (a raw registry is one single-slot group)
    (mask, stacked), = qt._state.qstate
    assert mask.shape == (1,) and bool(np.asarray(mask)[0])
    assert len(stacked) == 8
    qt.run_epoch(1, vals, strs, counts)
    # donated: the old sketch buffers are invalidated with the rest
    with pytest.raises(RuntimeError):
        np.asarray(stacked[5].value)
    # quantile sketch accumulated the windows' weighted mass
    (_, stacked2), = qt._state.qstate
    total = float(np.asarray(stacked2[5].weight).sum())
    assert total > 0.0


def test_dynamic_budgets_no_retrace_and_monotone_sample():
    """set_sample_sizes moves budgets between epochs with ZERO retraces
    (budgets are traced inputs), and a bigger budget keeps more items."""
    vals, strs, counts = _ingest_arrays(6)
    tree = _tree("scan", max_sample_sizes=[256, 256, 256])
    tree.run_epoch(1, vals[:2], strs[:2], counts[:2])
    traces = tree._trace_counter["traces"]
    n_small = tree.results[-1]["n_sampled"]
    tree.set_sample_sizes([256, 256, 256])
    tree.run_epoch(3, vals[2:4], strs[2:4], counts[2:4])
    n_big = tree.results[-1]["n_sampled"]
    tree.set_sample_sizes([40, 40, 40])
    tree.run_epoch(5, vals[4:], strs[4:], counts[4:])
    n_tiny = tree.results[-1]["n_sampled"]
    assert tree._trace_counter["traces"] == traces, "budget change retraced!"
    assert tree.dispatch_count == 3
    assert n_tiny < n_small < n_big
    # clamped to the provisioned ceiling
    tree.set_sample_sizes([9999, 9999, 9999])
    assert tree.sample_sizes == [256.0, 256.0, 256.0]


def test_closed_loop_reaches_target_within_20_epochs():
    """run_pipeline's error-budget loop: starting far under-budgeted, the
    controller reaches the target relative error within 20 epochs."""
    from repro.data import stream as S
    from repro.launch.analytics import run_pipeline

    target = 0.05
    r = run_pipeline(S.paper_gaussian(rates=(300, 300, 300, 300)),
                     fraction=0.01, ticks=80, epoch_ticks=4, seed=3,
                     engine="scan", warmup_ticks=1,
                     target_rel_error=target, max_fraction=0.8)
    traj = r["controller"]
    assert len(traj) == 20
    hit = [t["step"] for t in traj if t["rel_error"] <= target * 1.1]
    assert hit and hit[0] < 20, traj
    # and it stays in the neighbourhood once there (no blow-up)
    assert traj[-1]["rel_error"] <= target * 1.6


def test_plan_requires_whs_mode():
    with pytest.raises(AssertionError):
        _tree("scan", queries=_k8_registry(), mode="srs", fraction=0.25)


# -------------------------------------------------------------- sketches --
def test_quantile_sketch_exact_under_capacity():
    rng = np.random.default_rng(0)
    data = rng.normal(10, 2, 200).astype(np.float32)
    q = sk.quantile_init(256)
    for chunk in np.split(data, 4):
        b = jnp.asarray(chunk)
        q = sk.quantile_update(jax.random.PRNGKey(1), q, b,
                               jnp.ones_like(b))
    est = np.asarray(sk.quantile_query(q, jnp.asarray([0.0, 0.5, 1.0])))
    srt = np.sort(data)
    assert est[0] == srt[0] and est[2] == srt[-1]
    assert abs((data <= est[1]).mean() - 0.5) <= 1.0 / len(data) + 1e-6
    np.testing.assert_allclose(float(q.total_weight), len(data), rtol=1e-6)


def test_quantile_sketch_rank_error_within_bound():
    """Compacting 40k items through a C=256 summary keeps measured rank
    error within the configured bound (the fig8 acceptance property)."""
    rng = np.random.default_rng(7)
    data = rng.lognormal(3.0, 1.0, 40_000).astype(np.float32)
    q = sk.quantile_init(256)
    key = jax.random.PRNGKey(0)
    for i, chunk in enumerate(np.split(data, 40)):
        b = jnp.asarray(chunk)
        q = sk.quantile_update(jax.random.fold_in(key, i), q, b,
                               jnp.ones_like(b))
    qs = (0.1, 0.5, 0.9, 0.99)
    est = np.asarray(sk.quantile_query(q, jnp.asarray(qs)))
    bound = sk.quantile_rank_error_bound(256)
    for target, v in zip(qs, est):
        rank = (data <= v).mean()
        assert abs(rank - target) <= bound, (target, rank)


def test_quantile_sketch_weighted_update():
    """Weight-2 items count twice: matches duplicating the items."""
    rng = np.random.default_rng(3)
    data = rng.normal(0, 1, 300).astype(np.float32)
    a = sk.quantile_update(jax.random.PRNGKey(5), sk.quantile_init(1024),
                           jnp.asarray(data),
                           jnp.full((300,), 2.0, jnp.float32))
    dup = np.repeat(data, 2)
    b = sk.quantile_update(jax.random.PRNGKey(5), sk.quantile_init(1024),
                           jnp.asarray(dup), jnp.ones((600,), jnp.float32))
    qs = jnp.asarray([0.25, 0.5, 0.75])
    np.testing.assert_allclose(np.asarray(sk.quantile_query(a, qs)),
                               np.asarray(sk.quantile_query(b, qs)),
                               atol=1e-5)


def test_heavy_hitters_tracks_skewed_stream():
    rng = np.random.default_rng(1)
    pop = np.array([7, 13, 29, 101, 555])
    keys = rng.choice(pop, p=[0.45, 0.3, 0.15, 0.07, 0.03], size=8000)
    h = sk.hh_init(4, 512, 4)
    for chunk in np.split(keys.astype(np.float32), 8):
        b = jnp.asarray(chunk)
        h = sk.hh_update(h, sk.hh_item_key(b), jnp.ones_like(b))
    got = set(int(k) for k in np.asarray(h.key))
    assert got == {7, 13, 29, 101}
    bound = float(sk.hh_error_bound(512, h.total_weight))
    for k, e in zip(np.asarray(h.key), np.asarray(h.est)):
        true = (keys == k).sum()
        assert true <= e <= true + bound + 1e-4   # CM only over-counts


def test_heavy_hitters_masked_items_ignored():
    h = sk.hh_init(2, 256, 2)
    keys = jnp.asarray([4, 4, 9], jnp.int32)
    w = jnp.asarray([1.0, 1.0, 0.0], jnp.float32)
    h = sk.hh_update(h, keys, w)
    assert int(h.key[0]) == 4 and float(h.est[0]) == 2.0
    assert int(h.key[1]) == int(sk.HH_EMPTY_KEY)  # 9 carried no weight
    np.testing.assert_allclose(float(h.total_weight), 2.0)

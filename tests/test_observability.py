"""Observability plane (repro.obs) acceptance tests.

The load-bearing laws:

* telemetry is FREE and NEUTRAL — with ``TelemetrySpec(enabled=True)``
  the sample state and window answers are bit-identical to the
  telemetry-off run, and the epoch dispatch count is unchanged;
* the SPMD byte counter obeys the static per-window model:
  ``merge_bytes == windows x summary_bytes_per_window`` (the same
  number the PR-5 collectives audit bounds);
* the span tracer emits a well-formed span tree and schema-valid
  Chrome/Perfetto JSON;
* the Prometheus-text renderer and parser are strict inverses (CI's
  smoke step leans on the parser rejecting malformed text);
* the straggler monitor folds host-side deadline accounting into the
  same telemetry leaves.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro import api  # noqa: E402
from repro import obs  # noqa: E402
from repro.api.spec import (PipelineSpec, SamplerSpec,  # noqa: E402
                            TelemetrySpec, TopologySpec)
from repro.data import stream as S  # noqa: E402
from repro.obs.metrics import (MetricsRegistry,  # noqa: E402
                               metrics_text, parse_prometheus_text,
                               render_pipeline_metrics)
from repro.obs.trace import SpanTracer  # noqa: E402
from repro.query.registry import QueryRegistry  # noqa: E402


FANIN = (4, 2, 1)
CAPACITY = 256
TICKS = 12
NUM_STRATA = 2


def _registry() -> QueryRegistry:
    return (QueryRegistry().register_sum().register_mean()
            .register_quantile("q", (0.5, 0.9), capacity=64))


def _spec(telemetry: bool) -> PipelineSpec:
    return PipelineSpec(
        topology=TopologySpec(fanin=FANIN, capacity=CAPACITY,
                              num_strata=NUM_STRATA),
        sampler=SamplerSpec(mode="whs", backend="topk", fraction=0.2),
        tenants=(_registry().as_tenant("acme"),),
        telemetry=TelemetrySpec(enabled=telemetry),
        seed=3,
    )


def _ingest(seed=0, ticks=TICKS):
    rng = np.random.default_rng(seed)
    vals = rng.normal(50.0, 9.0,
                      (ticks, FANIN[0], CAPACITY)).astype(np.float32)
    strs = rng.integers(0, NUM_STRATA,
                        (ticks, FANIN[0], CAPACITY)).astype(np.int32)
    counts = np.full((ticks, FANIN[0]), CAPACITY, np.int64)
    return vals, strs, counts


def _run(telemetry: bool):
    pipe = api.compile(_spec(telemetry))
    state = pipe.init()
    vals, strs, counts = _ingest()
    state, wa = pipe.run_epoch(state, pipe.default_key, vals, strs, counts)
    return pipe, state, wa


@pytest.fixture(scope="module")
def on_off():
    pipe_on, state_on, wa_on = _run(telemetry=True)
    pipe_off, state_off, wa_off = _run(telemetry=False)
    return (pipe_on, state_on, wa_on), (pipe_off, state_off, wa_off)


# ---------------------------------------------------------------------------
# law 1: telemetry-on is bitwise-neutral and costs no extra dispatch
# ---------------------------------------------------------------------------

def test_sample_state_bitwise_identical_on_off(on_off):
    (_, s_on, _), (_, s_off, _) = on_off
    tree_on = s_on.tree._replace(telemetry=())
    tree_off = s_off.tree._replace(telemetry=())
    for leaf_on, leaf_off in zip(jax.tree.leaves(tree_on),
                                 jax.tree.leaves(tree_off)):
        np.testing.assert_array_equal(np.asarray(leaf_on),
                                      np.asarray(leaf_off))


def test_window_answers_bitwise_identical_on_off(on_off):
    (_, _, wa_on), (_, _, wa_off) = on_off
    for leaf_on, leaf_off in zip(jax.tree.leaves(wa_on),
                                 jax.tree.leaves(wa_off)):
        np.testing.assert_array_equal(np.asarray(leaf_on),
                                      np.asarray(leaf_off))


def test_epoch_dispatch_count_unchanged(on_off):
    (pipe_on, _, _), (pipe_off, _, _) = on_off
    # one traced program each: telemetry rides the scan carry, it is not
    # an extra output or a second dispatch
    assert pipe_on.trace_counter["traces"] == 1
    assert pipe_off.trace_counter["traces"] == 1


def test_off_state_carries_zero_extra_leaves(on_off):
    (_, s_on, _), (_, s_off, _) = on_off
    assert s_off.tree.telemetry == ()
    extra = (len(jax.tree.leaves(s_on.tree))
             - len(jax.tree.leaves(s_off.tree)))
    assert extra == len(obs.EpochTelemetry._fields)


# ---------------------------------------------------------------------------
# snapshot semantics
# ---------------------------------------------------------------------------

def test_snapshot_levels_and_windows(on_off):
    (pipe, state, wa), _ = on_off
    snap = obs.snapshot(state)
    assert snap is not None
    assert len(snap["levels"]) == len(FANIN)
    assert snap["windows"] == len(pipe.rows(wa))
    for lv in snap["levels"]:
        assert lv["items_in"] >= lv["items_kept"] > 0
        assert 0.0 < lv["effective_fraction"] <= 1.0
    assert len(snap["strata"]) == NUM_STRATA


def test_snapshot_bound_matches_adhoc_recompute(on_off):
    """bound_2sigma is THE one place the ±2σ math lives: it must equal
    the ad-hoc host recompute the examples used to do."""
    (pipe, state, wa), _ = on_off
    snap = obs.snapshot(state)
    rows = pipe.rows(wa)
    adhoc = 2.0 * float(np.sqrt(sum(r["sum_var"] for r in rows)))
    assert snap["bound_2sigma"] == pytest.approx(adhoc, rel=1e-4)
    assert snap["sum_estimate"] == pytest.approx(
        float(sum(r["sum"] for r in rows)), rel=1e-4)


def test_snapshot_none_when_disabled(on_off):
    _, (_, s_off, _) = on_off
    assert obs.snapshot(s_off) is None


def test_tenant_rel_bounds(on_off):
    (pipe, state, _), _ = on_off
    per = obs.telemetry.tenant_rel_bounds(pipe, state)
    assert set(per) == {"acme"}
    assert 0.0 < per["acme"] < 1.0


def test_reset_zeroes_counters(on_off):
    (pipe, state, _), _ = on_off
    state0 = obs.reset(state)
    snap = obs.snapshot(state0)
    assert snap["windows"] == 0
    assert snap["sum_estimate"] == 0.0
    for lv in snap["levels"]:
        assert lv["items_in"] == 0.0
    # shape-preserving: resuming a same-length epoch from the reset
    # state retraces nothing
    n0 = pipe.trace_counter["traces"]
    vals, strs, counts = _ingest(seed=1)
    pipe.run_epoch(state0, pipe.default_key, vals, strs, counts)
    assert pipe.trace_counter["traces"] == n0


# ---------------------------------------------------------------------------
# law 2: SPMD byte counter + bitwise neutrality on the mesh
# ---------------------------------------------------------------------------

_SPMD_HARNESS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax
    import numpy as np

    from repro import api
    from repro.api.spec import (PipelineSpec, SamplerSpec, TelemetrySpec,
                                TenantSpec, TopologySpec)
    from repro.data import stream as S
    from repro.query.registry import QueryRegistry

    T, M, X = 6, 512, 2

    def spec(telemetry):
        reg = (QueryRegistry().register_sum().register_mean()
               .register_quantile("q", (0.5, 0.9), capacity=64))
        return PipelineSpec(
            topology=TopologySpec(fanin=(1,), capacity=M,
                                  num_strata=X),
            sampler=SamplerSpec(mode="whs", backend="topk",
                                fraction=0.25),
            tenants=(reg.as_tenant("acme"),),
            telemetry=TelemetrySpec(enabled=telemetry),
            seed=5)

    rng = np.random.default_rng(0)
    vals = rng.normal(40.0, 8.0, (T, M)).astype(np.float32)
    strs = rng.integers(0, X, (T, M)).astype(np.int32)
    counts = np.full((T,), M, np.int64)
    batches = S.rows_to_interval_batch(vals, strs, counts, X)
    mesh = jax.make_mesh((4,), ("data",), devices=jax.devices()[:4])

    out = {}
    answers = {}
    for tel in (True, False):
        pipe = api.compile(spec(tel), mesh=mesh)
        state = pipe.init()
        state, wa = pipe.run_epoch(state, pipe.default_key, batches)
        answers[tel] = [np.asarray(x).tolist()
                        for x in jax.tree.leaves(wa)]
        if tel:
            snap = pipe.telemetry_snapshot(state)
            out["windows"] = snap["windows"]
            out["merge_bytes"] = snap["merge_bytes"]
            out["bytes_per_window"] = pipe.summary_bytes_per_window
            n0 = pipe.trace_counter["traces"]
            state, _ = pipe.run_epoch(state, pipe.default_key, batches)
            out["retraced"] = pipe.trace_counter["traces"] - n0
    out["bitwise"] = answers[True] == answers[False]
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def spmd():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SPMD_HARNESS],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-4000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_spmd_answers_bitwise_identical_on_off(spmd):
    assert spmd["bitwise"]


def test_spmd_merge_bytes_law(spmd):
    """The sketch-merge byte counter equals windows x the static
    per-window summary model — the same all-gather payload the PR-5
    collectives audit bounds."""
    assert spmd["windows"] > 0
    assert spmd["merge_bytes"] == pytest.approx(
        spmd["windows"] * spmd["bytes_per_window"])


def test_spmd_second_epoch_no_retrace(spmd):
    assert spmd["retraced"] == 0


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------

def test_span_tree_well_formed_and_aggregated():
    tr = SpanTracer()
    with tr.span("epoch_dispatch", ticks=4):
        with tr.span("ingest"):
            pass
        with tr.span("block_until_ready"):
            pass
    with tr.span("checkpoint", op="save"):
        pass
    assert tr.well_formed()
    assert tr.calls["epoch_dispatch"] == 1
    assert tr.calls["ingest"] == 1
    assert tr.durations["epoch_dispatch"] >= tr.durations["ingest"]


def test_chrome_trace_schema(tmp_path):
    tr = SpanTracer()
    with tr.span("outer", epoch=1):
        with tr.span("inner"):
            pass
    doc = tr.chrome_trace()
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert {e["name"] for e in evs} == {"outer", "inner"}
    for e in evs:
        assert e["ph"] == "X" and e["cat"] == "repro"
        assert isinstance(e["ts"], float) and isinstance(e["dur"], float)
        assert e["dur"] >= 0.0
    inner = next(e for e in evs if e["name"] == "inner")
    outer = next(e for e in evs if e["name"] == "outer")
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3
    assert outer["args"]["epoch"] == 1
    # save round-trips through json
    path = tmp_path / "trace.json"
    tr.save(str(path))
    assert json.loads(path.read_text())["traceEvents"]


def test_disabled_tracer_records_nothing():
    tr = SpanTracer(enabled=False)
    with tr.span("x"):
        pass
    assert not tr.events and not tr.calls


# ---------------------------------------------------------------------------
# metrics registry + Prometheus text
# ---------------------------------------------------------------------------

def test_prometheus_render_parse_round_trip():
    reg = MetricsRegistry()
    reg.counter("repro_items_in_total", 128.0, help_="items offered",
                level="0")
    reg.counter("repro_items_in_total", 64.5, level="1")
    reg.gauge("repro_effective_fraction", 0.25, help_="kept/in")
    text = reg.to_text()
    fams = parse_prometheus_text(text)
    assert fams["repro_items_in_total"]["type"] == "counter"
    samples = fams["repro_items_in_total"]["samples"]
    assert samples[(("level", "0"),)] == 128.0
    assert samples[(("level", "1"),)] == 64.5
    assert fams["repro_effective_fraction"]["samples"][()] == 0.25
    # idempotent: parse(render(parse(x))) == parse(x)
    reg2 = MetricsRegistry()
    for name, fam in fams.items():
        for labels, v in fam["samples"].items():
            getattr(reg2, fam["type"])(name, v, **dict(labels))
    assert parse_prometheus_text(reg2.to_text()) == fams


@pytest.mark.parametrize("bad", [
    "", "repro_x{unclosed=\"1\" 3\n",
    "just some words\n", "repro_y 1 2 3 4\n",
])
def test_prometheus_parser_rejects_malformed(bad):
    with pytest.raises(ValueError):
        parse_prometheus_text(bad)


def test_render_pipeline_metrics_end_to_end(on_off):
    (pipe, state, _), _ = on_off
    tr = SpanTracer()
    with tr.span("epoch_dispatch"):
        pass
    text = metrics_text(pipeline=pipe, state=state, tracer=tr)
    fams = parse_prometheus_text(text)
    for name in ("repro_items_in_total", "repro_items_kept_total",
                 "repro_effective_fraction", "repro_windows_total",
                 "repro_realized_bound_2sigma", "repro_tenant_rel_bound",
                 "repro_program_cache_misses_total",
                 "repro_plan_cache_builds_total",
                 "repro_span_seconds_total"):
        assert name in fams, f"{name} missing from exposition"
    n_levels = len({k for k in
                    fams["repro_items_in_total"]["samples"]})
    assert n_levels == len(FANIN)
    assert fams["repro_tenant_rel_bound"]["samples"][
        (("tenant", "acme"),)] > 0.0


# ---------------------------------------------------------------------------
# straggler wiring (ROADMAP item 1's signal)
# ---------------------------------------------------------------------------

def test_straggler_monitor_folds_into_telemetry(on_off):
    (pipe, state, _), _ = on_off
    mon = obs.StragglerMonitor(num_shards=4)
    before = obs.snapshot(state)
    # 12 on-time windows to build the deadline estimate, then one
    # window with a straggling shard
    for _ in range(12):
        present = mon.observe([1.0, 1.0, 1.0, 1.0])
        assert present.all()
    present = mon.observe([1.0, 1.0, 1.0, 1e6])
    assert present.sum() == 3 and not present[3]
    assert mon.late_shards_total == 1
    assert mon.widened_windows_total == 1
    state2 = mon.fold_into(state)
    snap = obs.snapshot(state2)
    assert snap["late_shards"] == before["late_shards"] + 1
    assert snap["widened_windows"] == before["widened_windows"] + 1
    # Eq. 9 recalibration: arrived shards' weights scale by 1/alpha
    w = np.ones(4, np.float64)
    w2 = mon.calibrate(w, present)
    assert w2[:3] == pytest.approx(4.0 / 3.0)
    # folding is idempotent once the deltas drain
    assert mon.fold_into(state2) is state2


def test_straggler_totals_in_metrics(on_off):
    (pipe, state, _), _ = on_off
    mon = obs.StragglerMonitor(num_shards=2)
    for _ in range(12):
        mon.observe([1.0, 1.0])
    mon.observe([1.0, 1e6])
    text = metrics_text(pipeline=pipe, state=state, straggler=mon)
    fams = parse_prometheus_text(text)
    assert fams["repro_straggler_monitor_late_shards_total"][
        "samples"][()] == 1.0
    assert fams["repro_straggler_monitor_widened_windows_total"][
        "samples"][()] == 1.0


# ---------------------------------------------------------------------------
# spec plumbing + benchmark provenance/regression gate
# ---------------------------------------------------------------------------

def test_spec_round_trip_with_telemetry():
    spec = _spec(telemetry=True)
    d = spec.to_dict()
    assert d["telemetry"] == {"enabled": True}
    back = PipelineSpec.from_dict(d)
    assert back.telemetry.enabled is True
    # specs serialized before the telemetry section default to off
    d2 = spec.to_dict()
    del d2["telemetry"]
    assert PipelineSpec.from_dict(d2).telemetry.enabled is False


def test_telemetry_spec_rejects_non_bool():
    with pytest.raises(Exception):
        TelemetrySpec(enabled=1)


def test_run_metadata_and_compare_gate():
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks import common

    meta = common.run_metadata(telemetry={"windows": 3})
    assert meta["git_sha"] and len(meta["git_sha"]) == 40
    assert meta["device"]["platform"]
    assert meta["telemetry"] == {"windows": 3}

    base = {"meta": meta, "fig7": {"ok": True, "rows": [
        {"fraction": 0.1, "engine": "scan", "whs_items_s": 1000.0},
        {"fraction": 0.2, "engine": "scan", "whs_items_s": 2000.0}]}}
    good = {"fig7": {"ok": True, "rows": [
        {"fraction": 0.1, "engine": "scan", "whs_items_s": 950.0},
        {"fraction": 0.2, "engine": "scan", "whs_items_s": 2500.0}]}}
    bad = {"fig7": {"ok": True, "rows": [
        {"fraction": 0.1, "engine": "scan", "whs_items_s": 800.0}]}}
    assert common.compare_reports(base, good, tol=0.10) == []
    regs = common.compare_reports(base, bad, tol=0.10)
    assert len(regs) == 1 and regs[0]["column"] == "whs_items_s"
    assert regs[0]["drop_pct"] == pytest.approx(20.0)
    # a failed module never gates
    assert common.compare_reports(
        base, {"fig7": {"ok": False}}, tol=0.10) == []

"""Sketch merge algebra — the property set the SPMD query plane rests on.

``merge`` must behave like a commutative monoid up to answer
equivalence: associative and commutative (answers agree within the
summaries' published rank bounds), ``merge(empty, s) ≡ s``, and a merge
of split-stream summaries must answer like one summary fed the
concatenated stream — exactly for the linear sketches (CM counts,
stratum moments), within the rank bound for the quantile compactor.
"""


import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    # Deterministic fallback so the algebra stays pinned on hosts
    # without hypothesis (CI installs it and gets the full search).
    class _Ints:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

    class st:  # noqa: N801 — mirrors the hypothesis module name
        integers = staticmethod(lambda lo, hi: _Ints(lo, hi))

    def settings(**kw):
        return lambda f: f

    def given(*strats):
        def deco(f):
            def wrapper():
                rng = np.random.default_rng(0xA5)
                for _ in range(8):
                    f(*(int(rng.integers(s.lo, s.hi + 1)) for s in strats))
            # plain rename (not functools.wraps: pytest would introspect
            # the wrapped signature and demand fixtures for its params)
            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            return wrapper
        return deco

from repro.core import error as err
from repro.query import sketches as sk

CAP = 64


def _qsketch(key, data, cap=CAP):
    b = jnp.asarray(data, jnp.float32)
    return sk.quantile_update(key, sk.quantile_init(cap), b,
                              jnp.ones_like(b))


def _stream(seed: int, n: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    # integer-valued f32 so linear aggregates are exact under any
    # summation grouping (the "exact for moments" property is about the
    # algebra, not f32 rounding)
    return np.round(rng.normal(100, 25, n)).astype(np.float32)


def _ranks(data: np.ndarray, values: np.ndarray) -> np.ndarray:
    return np.asarray([(data <= v).mean() for v in np.asarray(values)])


QS = jnp.asarray([0.1, 0.25, 0.5, 0.75, 0.9])


# ------------------------------------------------------------- quantile --
@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000))
def test_quantile_merge_associative_commutative(seed):
    """(a⊕b)⊕c, a⊕(b⊕c), (b⊕a)⊕c all answer the union stream within
    their own published rank bounds — merge order is immaterial up to
    answer equivalence."""
    data = _stream(seed, 900)
    parts = np.split(data, 3)
    key = jax.random.PRNGKey(seed)
    ks = [jax.random.fold_in(key, i) for i in range(8)]
    a, b, c = (_qsketch(k, p) for k, p in zip(ks, parts))
    m1 = sk.quantile_merge(ks[3], sk.quantile_merge(ks[4], a, b), c)
    m2 = sk.quantile_merge(ks[5], a, sk.quantile_merge(ks[6], b, c))
    m3 = sk.quantile_merge(ks[3], sk.quantile_merge(ks[4], b, a), c)
    for m in (m1, m2, m3):
        np.testing.assert_allclose(float(m.total_weight), len(data),
                                   rtol=1e-6)
        bound = float(m.rank_error_bound) + 1.0 / CAP
        got = _ranks(data, sk.quantile_query(m, QS))
        assert np.all(np.abs(got - np.asarray(QS)) <= bound), (got, bound)
    # same merge randomness ⇒ the commuted merge is answer-identical
    np.testing.assert_array_equal(np.asarray(sk.quantile_query(m1, QS)),
                                  np.asarray(sk.quantile_query(m3, QS)))


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000))
def test_quantile_merge_empty_is_identity(seed):
    data = _stream(seed, 200)
    s = _qsketch(jax.random.PRNGKey(seed), data)
    for m in (sk.quantile_merge(jax.random.PRNGKey(1), s,
                                sk.quantile_init(CAP)),
              sk.quantile_merge(jax.random.PRNGKey(2),
                                sk.quantile_init(CAP), s)):
        np.testing.assert_allclose(float(m.total_weight),
                                   float(s.total_weight), rtol=1e-6)
        assert float(m.compactions) == float(s.compactions)
        np.testing.assert_array_equal(
            np.asarray(sk.quantile_query(m, QS)),
            np.asarray(sk.quantile_query(s, QS)))


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 6))
def test_quantile_merged_answers_match_concatenated_stream(seed, n_parts):
    """N split-stream summaries merged (one compaction — the stacked
    merge the SPMD all-gather path uses) answer the concatenated stream
    within the merged summary's published rank bound."""
    data = _stream(seed, 240 * n_parts)
    key = jax.random.PRNGKey(seed)
    parts = [_qsketch(jax.random.fold_in(key, i), p)
             for i, p in enumerate(np.split(data, n_parts))]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *parts)
    m = sk.quantile_merge_stacked(jax.random.fold_in(key, 99), stacked)
    np.testing.assert_allclose(float(m.total_weight), len(data), rtol=1e-6)
    bound = float(m.rank_error_bound) + 1.0 / CAP
    got = _ranks(data, sk.quantile_query(m, QS))
    assert np.all(np.abs(got - np.asarray(QS)) <= bound), (got, bound)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_leveled_bound_beats_collapsed_bound_on_long_streams(seed):
    """Long streams: the leveled sketch's live bound 2·√(Σq²)/W must
    undercut the collapsed one-buffer bound 2·√U/C for the SAME number
    of compactions — most compactions happen at low levels where the
    buffer weight (hence the quantum) is a sliver of the stream — and
    the realized rank error must stay inside the leveled bound."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    s = sk.quantile_init(CAP)
    n_up, batch = 48, 96
    chunks = []
    for i in range(n_up):
        b = rng.normal(100, 25, batch).astype(np.float32)
        chunks.append(b)
        s = sk.quantile_update(jax.random.fold_in(key, i), s,
                               jnp.asarray(b), jnp.ones((batch,)))
    data = np.concatenate(chunks)
    assert float(s.compactions) > 0
    collapsed = 2.0 * np.sqrt(float(s.compactions)) / CAP
    live = float(s.rank_error_bound)
    assert live < collapsed, (live, collapsed)
    got = _ranks(data, sk.quantile_query(s, QS))
    assert np.all(np.abs(got - np.asarray(QS)) <= live + 1.0 / CAP), (
        got, live)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_quantile_merge_is_levelwise(seed):
    """Same-schedule merge folds level-by-level: the merged state keeps
    the [L, C] schedule, both histories' compaction/quantum accounting
    rides along, and an empty merge is a level-wise no-op — bitwise
    state identity per level, not just answer identity."""
    data = _stream(seed, 2000)     # spills well past level 0
    key = jax.random.PRNGKey(seed)
    a = _qsketch(jax.random.fold_in(key, 0), data[:1000])
    b = _qsketch(jax.random.fold_in(key, 1), data[1000:])
    m = sk.quantile_merge(jax.random.fold_in(key, 2), a, b)
    assert m.value.shape == a.value.shape == (a.levels, CAP)
    # histories add (the fold itself may append further compactions)
    assert float(m.compactions) >= float(a.compactions) + float(b.compactions)
    assert float(m.err_q2) >= float(a.err_q2) + float(b.err_q2)
    e = sk.quantile_init(CAP)
    for m0 in (sk.quantile_merge(jax.random.PRNGKey(1), a, e),
               sk.quantile_merge(jax.random.PRNGKey(2), e, a)):
        np.testing.assert_array_equal(np.asarray(m0.value),
                                      np.asarray(a.value))
        np.testing.assert_array_equal(np.asarray(m0.weight),
                                      np.asarray(a.weight))
        assert float(m0.err_q2) == float(a.err_q2)
        assert float(m0.compactions) == float(a.compactions)


def test_quantile_merge_cross_schedule_flattens():
    """A summary with a different schedule merges like a weighted batch
    (flattened into level 0) — answers still land within the merged
    summary's published bound."""
    data = _stream(11, 600)
    a = _qsketch(jax.random.PRNGKey(0), data[:300], cap=CAP)
    b = _qsketch(jax.random.PRNGKey(1), data[300:], cap=128)
    assert b.value.shape != a.value.shape
    m = sk.quantile_merge(jax.random.PRNGKey(2), a, b)
    assert m.value.shape == a.value.shape
    np.testing.assert_allclose(float(m.total_weight), len(data), rtol=1e-6)
    bound = float(m.rank_error_bound) + 1.0 / CAP
    got = _ranks(data, sk.quantile_query(m, QS))
    assert np.all(np.abs(got - np.asarray(QS)) <= bound), (got, bound)


def test_static_planning_bound_tighter_than_collapsed():
    """The leveled static planning bound must beat the old collapsed
    2·√U/C at every deployed capacity, and dominate the live bound a
    real stream realizes within its horizon."""
    import math
    for cap in (64, 256, 1024):
        old = 2.0 * math.sqrt(64.0) / cap
        new = sk.quantile_rank_error_bound(cap)
        assert new < old, (cap, new, old)
    key = jax.random.PRNGKey(7)
    rng = np.random.default_rng(7)
    s = sk.quantile_init(256)
    for i in range(40):
        b = jnp.asarray(rng.lognormal(0.0, 1.0, 1024).astype(np.float32))
        s = sk.quantile_update(jax.random.fold_in(key, i), s, b,
                               jnp.ones((1024,)))
    assert float(s.rank_error_bound) <= sk.quantile_rank_error_bound(256)


# -------------------------------------------------------- heavy hitters --
def _hh_stream(seed: int, n: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.choice(np.array([7, 13, 29, 101, 555], np.int32),
                      p=[0.45, 0.3, 0.15, 0.07, 0.03], size=n)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000))
def test_hh_merge_counts_exact_vs_concatenated(seed):
    """CM tables are linear: any split/merge grouping produces the
    bitwise-identical table (and therefore identical point estimates)
    as one sketch fed the concatenated stream."""
    keys = _hh_stream(seed, 3000)
    ones = lambda k: jnp.ones((len(k),), jnp.float32)
    parts = np.split(keys, 3)
    hs = [sk.hh_update(sk.hh_init(4, 256, 3), jnp.asarray(p), ones(p))
          for p in parts]
    whole = sk.hh_update(sk.hh_init(4, 256, 3), jnp.asarray(keys),
                         ones(keys))
    m1 = sk.hh_merge(sk.hh_merge(hs[0], hs[1]), hs[2])
    m2 = sk.hh_merge(hs[2], sk.hh_merge(hs[1], hs[0]))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *hs)
    m3 = sk.hh_merge_stacked(stacked)
    probe = jnp.asarray([7, 13, 29, 101, 555, 999], jnp.int32)
    for m in (m1, m2, m3):
        np.testing.assert_array_equal(np.asarray(m.counts),
                                      np.asarray(whole.counts))
        np.testing.assert_array_equal(
            np.asarray(sk.hh_point_estimate(m, probe)),
            np.asarray(sk.hh_point_estimate(whole, probe)))
    # identical merged counts ⇒ the top-k refresh ranks candidates
    # identically: merge order cannot change the surviving key set
    assert (set(np.asarray(m1.key).tolist())
            == set(np.asarray(m2.key).tolist())
            == set(np.asarray(m3.key).tolist()))


def test_hh_merge_empty_is_identity():
    keys = _hh_stream(3, 2000)
    s = sk.hh_update(sk.hh_init(4, 256, 3), jnp.asarray(keys),
                     jnp.ones((len(keys),), jnp.float32))
    empty = sk.hh_init(4, 256, 3)
    for m in (sk.hh_merge(s, empty), sk.hh_merge(empty, s)):
        np.testing.assert_array_equal(np.asarray(m.counts),
                                      np.asarray(s.counts))
        assert (set(np.asarray(m.key).tolist())
                == set(np.asarray(s.key).tolist()))
        np.testing.assert_array_equal(np.sort(np.asarray(m.est)),
                                      np.sort(np.asarray(s.est)))


def test_hh_merge_recovers_split_heavy_hitters():
    """A key that is heavy only in the union (spread across workers so
    no single worker tracks it top-1) survives the top-k re-merge —
    the property a naive 'take the union of local top-1s' would lose."""
    a = np.concatenate([np.full(60, 7), np.full(50, 13), np.full(45, 29)])
    b = np.concatenate([np.full(60, 101), np.full(50, 13), np.full(45, 29)])
    ones = lambda n: jnp.ones((n,), jnp.float32)
    ha = sk.hh_update(sk.hh_init(2, 256, 3), jnp.asarray(a, jnp.int32),
                      ones(len(a)))
    hb = sk.hh_update(sk.hh_init(2, 256, 3), jnp.asarray(b, jnp.int32),
                      ones(len(b)))
    m = sk.hh_merge(ha, hb)
    got = set(np.asarray(m.key).tolist())
    # 13 (100 total) out-counts both locally-top keys 7 and 101 (60 each)
    assert 13 in got, got


# --------------------------------------------------------------- moments --
@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 8))
def test_stratum_moments_merge_exact(seed, n_parts):
    """The CLT moment accumulators the SPMD path psum-merges are plain
    sums: split-stream moments added in any grouping equal the
    concatenated-stream moments exactly (integer-valued f32)."""
    n = 128 * n_parts
    # small integer values: Σx² stays below 2^24, so f32 sums are exact
    data = np.round(np.random.default_rng(seed).normal(10, 3, n)
                    ).astype(np.float32)
    strata = (np.arange(n) % 3).astype(np.int32)
    sel = np.ones((n,), bool)
    whole = err.stratum_moments(jnp.asarray(data), jnp.asarray(strata),
                                jnp.asarray(sel), 3)
    acc = [np.zeros(3, np.float32)] * 3
    for dpart, spart in zip(np.split(data, n_parts),
                            np.split(strata, n_parts)):
        part = err.stratum_moments(jnp.asarray(dpart), jnp.asarray(spart),
                                   jnp.ones((len(dpart),), bool), 3)
        acc = [a + np.asarray(p) for a, p in zip(acc, part[:3])]
    for a, w in zip(acc, whole):
        np.testing.assert_array_equal(a, np.asarray(w))

"""Sketch merge algebra — the property set the SPMD query plane rests on.

``merge`` must behave like a commutative monoid up to answer
equivalence: associative and commutative (answers agree within the
summaries' published rank bounds), ``merge(empty, s) ≡ s``, and a merge
of split-stream summaries must answer like one summary fed the
concatenated stream — exactly for the linear sketches (CM counts,
stratum moments), within the rank bound for the quantile compactor.
"""


import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    # Deterministic fallback so the algebra stays pinned on hosts
    # without hypothesis (CI installs it and gets the full search).
    class _Ints:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

    class st:  # noqa: N801 — mirrors the hypothesis module name
        integers = staticmethod(lambda lo, hi: _Ints(lo, hi))

    def settings(**kw):
        return lambda f: f

    def given(*strats):
        def deco(f):
            def wrapper():
                rng = np.random.default_rng(0xA5)
                for _ in range(8):
                    f(*(int(rng.integers(s.lo, s.hi + 1)) for s in strats))
            # plain rename (not functools.wraps: pytest would introspect
            # the wrapped signature and demand fixtures for its params)
            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            return wrapper
        return deco

from repro.core import error as err
from repro.query import sketches as sk

CAP = 64


def _qsketch(key, data, cap=CAP):
    b = jnp.asarray(data, jnp.float32)
    return sk.quantile_update(key, sk.quantile_init(cap), b,
                              jnp.ones_like(b))


def _stream(seed: int, n: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    # integer-valued f32 so linear aggregates are exact under any
    # summation grouping (the "exact for moments" property is about the
    # algebra, not f32 rounding)
    return np.round(rng.normal(100, 25, n)).astype(np.float32)


def _ranks(data: np.ndarray, values: np.ndarray) -> np.ndarray:
    return np.asarray([(data <= v).mean() for v in np.asarray(values)])


QS = jnp.asarray([0.1, 0.25, 0.5, 0.75, 0.9])


# ------------------------------------------------------------- quantile --
@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000))
def test_quantile_merge_associative_commutative(seed):
    """(a⊕b)⊕c, a⊕(b⊕c), (b⊕a)⊕c all answer the union stream within
    their own published rank bounds — merge order is immaterial up to
    answer equivalence."""
    data = _stream(seed, 900)
    parts = np.split(data, 3)
    key = jax.random.PRNGKey(seed)
    ks = [jax.random.fold_in(key, i) for i in range(8)]
    a, b, c = (_qsketch(k, p) for k, p in zip(ks, parts))
    m1 = sk.quantile_merge(ks[3], sk.quantile_merge(ks[4], a, b), c)
    m2 = sk.quantile_merge(ks[5], a, sk.quantile_merge(ks[6], b, c))
    m3 = sk.quantile_merge(ks[3], sk.quantile_merge(ks[4], b, a), c)
    for m in (m1, m2, m3):
        np.testing.assert_allclose(float(m.total_weight), len(data),
                                   rtol=1e-6)
        bound = float(m.rank_error_bound) + 1.0 / CAP
        got = _ranks(data, sk.quantile_query(m, QS))
        assert np.all(np.abs(got - np.asarray(QS)) <= bound), (got, bound)
    # same merge randomness ⇒ the commuted merge is answer-identical
    np.testing.assert_array_equal(np.asarray(sk.quantile_query(m1, QS)),
                                  np.asarray(sk.quantile_query(m3, QS)))


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000))
def test_quantile_merge_empty_is_identity(seed):
    data = _stream(seed, 200)
    s = _qsketch(jax.random.PRNGKey(seed), data)
    for m in (sk.quantile_merge(jax.random.PRNGKey(1), s,
                                sk.quantile_init(CAP)),
              sk.quantile_merge(jax.random.PRNGKey(2),
                                sk.quantile_init(CAP), s)):
        np.testing.assert_allclose(float(m.total_weight),
                                   float(s.total_weight), rtol=1e-6)
        assert float(m.compactions) == float(s.compactions)
        np.testing.assert_array_equal(
            np.asarray(sk.quantile_query(m, QS)),
            np.asarray(sk.quantile_query(s, QS)))


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 6))
def test_quantile_merged_answers_match_concatenated_stream(seed, n_parts):
    """N split-stream summaries merged (one compaction — the stacked
    merge the SPMD all-gather path uses) answer the concatenated stream
    within the merged summary's published rank bound."""
    data = _stream(seed, 240 * n_parts)
    key = jax.random.PRNGKey(seed)
    parts = [_qsketch(jax.random.fold_in(key, i), p)
             for i, p in enumerate(np.split(data, n_parts))]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *parts)
    m = sk.quantile_merge_stacked(jax.random.fold_in(key, 99), stacked)
    np.testing.assert_allclose(float(m.total_weight), len(data), rtol=1e-6)
    bound = float(m.rank_error_bound) + 1.0 / CAP
    got = _ranks(data, sk.quantile_query(m, QS))
    assert np.all(np.abs(got - np.asarray(QS)) <= bound), (got, bound)


# -------------------------------------------------------- heavy hitters --
def _hh_stream(seed: int, n: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.choice(np.array([7, 13, 29, 101, 555], np.int32),
                      p=[0.45, 0.3, 0.15, 0.07, 0.03], size=n)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000))
def test_hh_merge_counts_exact_vs_concatenated(seed):
    """CM tables are linear: any split/merge grouping produces the
    bitwise-identical table (and therefore identical point estimates)
    as one sketch fed the concatenated stream."""
    keys = _hh_stream(seed, 3000)
    ones = lambda k: jnp.ones((len(k),), jnp.float32)
    parts = np.split(keys, 3)
    hs = [sk.hh_update(sk.hh_init(4, 256, 3), jnp.asarray(p), ones(p))
          for p in parts]
    whole = sk.hh_update(sk.hh_init(4, 256, 3), jnp.asarray(keys),
                         ones(keys))
    m1 = sk.hh_merge(sk.hh_merge(hs[0], hs[1]), hs[2])
    m2 = sk.hh_merge(hs[2], sk.hh_merge(hs[1], hs[0]))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *hs)
    m3 = sk.hh_merge_stacked(stacked)
    probe = jnp.asarray([7, 13, 29, 101, 555, 999], jnp.int32)
    for m in (m1, m2, m3):
        np.testing.assert_array_equal(np.asarray(m.counts),
                                      np.asarray(whole.counts))
        np.testing.assert_array_equal(
            np.asarray(sk.hh_point_estimate(m, probe)),
            np.asarray(sk.hh_point_estimate(whole, probe)))
    # identical merged counts ⇒ the top-k refresh ranks candidates
    # identically: merge order cannot change the surviving key set
    assert (set(np.asarray(m1.key).tolist())
            == set(np.asarray(m2.key).tolist())
            == set(np.asarray(m3.key).tolist()))


def test_hh_merge_empty_is_identity():
    keys = _hh_stream(3, 2000)
    s = sk.hh_update(sk.hh_init(4, 256, 3), jnp.asarray(keys),
                     jnp.ones((len(keys),), jnp.float32))
    empty = sk.hh_init(4, 256, 3)
    for m in (sk.hh_merge(s, empty), sk.hh_merge(empty, s)):
        np.testing.assert_array_equal(np.asarray(m.counts),
                                      np.asarray(s.counts))
        assert (set(np.asarray(m.key).tolist())
                == set(np.asarray(s.key).tolist()))
        np.testing.assert_array_equal(np.sort(np.asarray(m.est)),
                                      np.sort(np.asarray(s.est)))


def test_hh_merge_recovers_split_heavy_hitters():
    """A key that is heavy only in the union (spread across workers so
    no single worker tracks it top-1) survives the top-k re-merge —
    the property a naive 'take the union of local top-1s' would lose."""
    a = np.concatenate([np.full(60, 7), np.full(50, 13), np.full(45, 29)])
    b = np.concatenate([np.full(60, 101), np.full(50, 13), np.full(45, 29)])
    ones = lambda n: jnp.ones((n,), jnp.float32)
    ha = sk.hh_update(sk.hh_init(2, 256, 3), jnp.asarray(a, jnp.int32),
                      ones(len(a)))
    hb = sk.hh_update(sk.hh_init(2, 256, 3), jnp.asarray(b, jnp.int32),
                      ones(len(b)))
    m = sk.hh_merge(ha, hb)
    got = set(np.asarray(m.key).tolist())
    # 13 (100 total) out-counts both locally-top keys 7 and 101 (60 each)
    assert 13 in got, got


# --------------------------------------------------------------- moments --
@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 8))
def test_stratum_moments_merge_exact(seed, n_parts):
    """The CLT moment accumulators the SPMD path psum-merges are plain
    sums: split-stream moments added in any grouping equal the
    concatenated-stream moments exactly (integer-valued f32)."""
    n = 128 * n_parts
    # small integer values: Σx² stays below 2^24, so f32 sums are exact
    data = np.round(np.random.default_rng(seed).normal(10, 3, n)
                    ).astype(np.float32)
    strata = (np.arange(n) % 3).astype(np.int32)
    sel = np.ones((n,), bool)
    whole = err.stratum_moments(jnp.asarray(data), jnp.asarray(strata),
                                jnp.asarray(sel), 3)
    acc = [np.zeros(3, np.float32)] * 3
    for dpart, spart in zip(np.split(data, n_parts),
                            np.split(strata, n_parts)):
        part = err.stratum_moments(jnp.asarray(dpart), jnp.asarray(spart),
                                   jnp.ones((len(dpart),), bool), 3)
        acc = [a + np.asarray(p) for a, p in zip(acc, part[:3])]
    for a, w in zip(acc, whole):
        np.testing.assert_array_equal(a, np.asarray(w))

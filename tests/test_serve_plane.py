"""Streaming serve plane (repro.serve) acceptance tests.

The load-bearing laws (ISSUE 9):

* bounded-queue drop accounting: ``items_in == items_out +
  items_dropped + depth`` under every backpressure policy, with every
  drop counted (never silent);
* straggler window ≡ on-time window BITWISE when no shard is late —
  the executor path adds nothing to a synchronous ``run_epoch`` run;
* a window with a late shard publishes a *partial* answer whose Eq. 9
  calibrated estimate covers the true value and whose bounds are
  widened by 1/α ≥ 1 (partial bound ≥ full bound), and the late data
  folds into the next window (Σ raw counts conserves every item);
* ``stop()`` drains: no queued items remain, accounting still closes;
* the ``repro_serve_*`` metric families render and Prometheus-parse.

All tests run with an injected fake clock and deterministic sources.
"""
from __future__ import annotations

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro import api  # noqa: E402
from repro.obs.metrics import (metrics_text,  # noqa: E402
                               parse_prometheus_text)
from repro.query.registry import QueryRegistry  # noqa: E402
from repro.serve import (BoundedShardQueue, ConstantSource,  # noqa: E402
                         DoubleBuffer, LateShardSource, StreamingExecutor,
                         WindowPublisher)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float):
        self.t += dt


def _registry() -> QueryRegistry:
    return (QueryRegistry().register_count("n").register_sum("s")
            .register_mean("m"))


def _spec(fraction: float = 1.0) -> api.PipelineSpec:
    return api.PipelineSpec(
        topology=api.TopologySpec(fanin=(2, 1), capacity=256, num_strata=2),
        sampler=api.SamplerSpec(mode="whs", backend="topk",
                                fraction=fraction),
        tenants=(_registry().as_tenant("t"),), seed=0)


def _executor(clock, **kw) -> StreamingExecutor:
    kw.setdefault("epoch_ticks", 4)
    kw.setdefault("width", 64)
    kw.setdefault("queue_capacity", 256)
    return StreamingExecutor(clock=clock, **kw)


def _run(ex, clock, ticks, dt=1.0):
    for _ in range(ticks):
        clock.advance(dt)
        ex.pump()


# ---------------------------------------------------------------- queues --


@pytest.mark.parametrize("policy", ["block", "drop_oldest", "degrade"])
def test_queue_accounting_law(policy):
    q = BoundedShardQueue(capacity=16, policy=policy, seed=3)
    rng = np.random.default_rng(0)
    for step in range(50):
        n = int(rng.integers(0, 8))
        q.put(rng.normal(size=n), np.zeros(n, np.int32), float(step))
        q.get_many(int(rng.integers(0, 6)))
        assert q.accounting_ok, q.stats()
    assert q.high_watermark <= q.capacity


def test_queue_block_defers_overflow():
    q = BoundedShardQueue(capacity=4, policy="block")
    accepted = q.put(np.arange(10.0), np.zeros(10, np.int32), 0.0)
    assert accepted == 4 and q.deferred == 6 and q.depth == 4
    assert q.items_dropped == 0 and q.accounting_ok


def test_queue_drop_oldest_keeps_freshest():
    q = BoundedShardQueue(capacity=4, policy="drop_oldest")
    q.put(np.arange(10.0), np.zeros(10, np.int32), 0.0)
    assert q.items_dropped == 6 and q.depth == 4
    values, _, _ = q.get_many(10)
    np.testing.assert_array_equal(values, [6.0, 7.0, 8.0, 9.0])
    assert q.accounting_ok


def test_queue_degrade_sheds_proportionally_and_deterministically():
    def fill(seed):
        q = BoundedShardQueue(capacity=32, policy="degrade", seed=seed)
        for step in range(8):
            q.put(np.arange(16.0), np.zeros(16, np.int32), float(step))
        return q
    a, b = fill(7), fill(7)
    assert a.items_dropped == b.items_dropped > 0   # deterministic, shedding
    assert a.depth == b.depth and a.accounting_ok
    # an empty queue accepts everything (p_drop = 0)
    q = BoundedShardQueue(capacity=32, policy="degrade", seed=7)
    assert q.put(np.arange(8.0), np.zeros(8, np.int32), 0.0) == 8


def test_queue_rejects_bad_policy_and_capacity():
    with pytest.raises(ValueError, match="policy"):
        BoundedShardQueue(capacity=4, policy="shrug")
    with pytest.raises(ValueError, match="capacity"):
        BoundedShardQueue(capacity=0)


# --------------------------------------------------------------- staging --


def test_double_buffer_packs_truncates_and_zeroes_on_swap():
    buf = DoubleBuffer(epoch_ticks=2, n_nodes=1, width=4)
    assert buf.stage(0, 0, np.arange(3.0), np.zeros(3, np.int32),
                     arrival=5.0) == 3
    assert buf.stage(0, 0, np.arange(3.0), np.zeros(3, np.int32),
                     arrival=2.0) == 1          # only one slot left
    assert buf.truncated_total == 2 and buf.staged_total == 4
    assert buf.first_arrival(0) == 2.0
    epoch = buf.swap()
    np.testing.assert_array_equal(epoch.counts, [[4], [0]])
    np.testing.assert_array_equal(epoch.values[0, 0], [0, 1, 2, 0])
    assert epoch.offered[0, 0] == 6
    # the newly active buffer is clean
    assert buf.first_arrival(0) == np.inf
    assert buf.swap().counts.sum() == 0


# --------------------------------------------- bitwise on-time equivalence --


def test_on_time_run_is_bitwise_equal_to_synchronous_epochs():
    pipe = api.compile(_spec())
    clock = FakeClock()
    ex = _executor(clock)
    ex.start(pipe, [ConstantSource(0, rate=6, value=2.0, stratum=0),
                    ConstantSource(1, rate=6, value=3.0, stratum=1)],
             warmup=False)
    _run(ex, clock, 8)            # two full epochs
    ex.stop()
    assert all(not w.partial and w.alpha == 1.0 for w in ex.published)

    # the same ingest, run synchronously through the bare pipeline with
    # the executor's per-epoch key schedule
    values = np.zeros((4, 2, 64), np.float32)
    strata = np.zeros((4, 2, 64), np.int32)
    counts = np.full((4, 2), 6, np.int32)
    values[:, 0, :6] = 2.0
    values[:, 1, :6] = 3.0
    strata[:, 1, :6] = 1
    state = pipe.init()
    rows = []
    for epoch in range(2):
        key = jax.random.fold_in(pipe.default_key, epoch)
        state, wa = pipe.run_epoch(state, key, values, strata, counts)
        rows.extend(pipe.rows(wa))
    assert len(rows) == len(ex.published) == 8
    for row, win in zip(rows, ex.published):
        assert row["tick"] == win.tick
        # published complete windows pass the arrays through UNTOUCHED
        np.testing.assert_array_equal(row["answers"], win.answers)
        np.testing.assert_array_equal(row["bounds"], win.bounds)
        assert row["sum"] == win.sum and row["mean"] == win.mean
        np.testing.assert_array_equal(row["histogram"], win.histogram)


# ------------------------------------------- straggler / partial windows --


def test_late_shard_publishes_partial_then_folds_into_next_window():
    pipe = api.compile(_spec())
    clock = FakeClock()
    ex = _executor(clock)
    # shard 1 is late for its pump ticks [4, 6) -> global ticks 5..6
    ex.start(pipe, [ConstantSource(0, rate=8, value=2.0),
                    LateShardSource(ConstantSource(1, rate=8, value=2.0),
                                    4, 6)], warmup=False)
    _run(ex, clock, 12)
    summary = ex.stop()

    partials = [w for w in ex.published if w.partial]
    assert len(partials) == 2 and summary["windows_partial"] == 2
    n = lambda vec, w=None: float(pipe.answer(vec, "n")[0])
    for w in partials:
        assert w.tick in (5, 6)
        # Eq. 9: α = 8/16, raw answer covers only the arrived shard,
        # calibrated answer recovers the TRUE full-window value exactly
        # (constant source, fraction 1.0, exact EWMA rate)
        assert w.alpha == pytest.approx(0.5)
        assert n(w.raw["answers"]) == pytest.approx(8.0)
        assert n(w.answers) == pytest.approx(16.0)
        truth_sum = 2.0 * 16
        assert w.sum == pytest.approx(truth_sum)
        # widened bounds dominate the raw ones: bound' = bound / α
        raw_b = np.asarray(w.raw["bounds"], np.float64)
        np.testing.assert_allclose(np.asarray(w.bounds, np.float64),
                                   raw_b / w.alpha, rtol=1e-6)
        assert (np.asarray(w.bounds) >= np.asarray(w.raw["bounds"])).all()
    # the late data folds into the NEXT window (global tick 7): its raw
    # count carries this window's 16 plus the 16 withheld items
    by_tick = {w.tick: w for w in ex.published}
    assert n(by_tick[7].raw["answers"]) == pytest.approx(32.0)
    assert not by_tick[7].partial
    # conservation: nothing was dropped — every admitted item is counted
    # in exactly one window's RAW answer
    total_raw = sum(n(w.raw["answers"]) for w in ex.published)
    assert total_raw == pytest.approx(summary["queue_items_in"])
    assert summary["queue_items_dropped"] == 0
    # the monitor accounted the late shard-windows
    assert ex.monitor.late_shards_total == 2
    assert ex.monitor.widened_windows_total == 2


def test_partial_window_bound_covers_truth_under_sampling():
    # fraction < 1: the calibrated estimate is noisy; truth must sit
    # inside estimate ± widened bound for the linear queries
    pipe = api.compile(_spec(fraction=0.5))
    clock = FakeClock()
    ex = _executor(clock)
    ex.start(pipe, [ConstantSource(0, rate=24, value=2.0),
                    LateShardSource(ConstantSource(1, rate=24, value=2.0),
                                    4, 6)], warmup=False)
    _run(ex, clock, 12)
    ex.stop()
    partials = [w for w in ex.published if w.partial]
    assert partials
    for w in partials:
        truth = 2.0 * 48                      # both shards' items
        s = float(pipe.answer(w.answers, "s")[0])
        b = float(pipe.answer(w.bounds, "s")[0])
        assert abs(s - truth) <= b + 1e-5


def test_drops_widen_bounds_too():
    # degrade policy sheds load under pressure; shed items count into α
    # so even with NO late shard the window publishes partial
    pipe = api.compile(_spec())
    clock = FakeClock()
    ex = _executor(clock, policy="degrade", queue_capacity=32)
    ex.start(pipe, [ConstantSource(0, rate=48, value=2.0),
                    ConstantSource(1, rate=48, value=2.0)], warmup=False)
    _run(ex, clock, 8)
    summary = ex.stop()
    assert summary["queue_items_dropped"] > 0
    partials = [w for w in ex.published if w.partial]
    assert partials and all(w.alpha < 1.0 for w in partials)


# ------------------------------------------------------- drain-on-stop --


def test_stop_drains_queues_clean():
    pipe = api.compile(_spec())
    clock = FakeClock()
    # max_records < rate: queues accumulate a backlog during the run
    ex = _executor(clock, max_records=4)
    ex.start(pipe, [ConstantSource(0, rate=8, value=1.0),
                    ConstantSource(1, rate=8, value=1.0)], warmup=False)
    _run(ex, clock, 6)
    assert any(q.depth > 0 for q in ex._queues)
    summary = ex.stop()
    assert summary["queue_depth"] == [0, 0]
    assert summary["queue_items_in"] == summary["queue_items_out"]
    assert all(q.accounting_ok for q in ex._queues)
    # everything drained lands in a window: raw counts conserve items
    total_raw = sum(float(pipe.answer(w.raw["answers"], "n")[0])
                    for w in ex.published)
    assert total_raw == pytest.approx(summary["queue_items_in"])
    with pytest.raises(RuntimeError, match="not started"):
        ex.stop()


def test_restart_after_stop():
    pipe = api.compile(_spec())
    clock = FakeClock()
    ex = _executor(clock)
    ex.start(pipe, [ConstantSource(0, rate=4), ConstantSource(1, rate=4)],
             warmup=False)
    with pytest.raises(RuntimeError, match="already started"):
        ex.start(pipe, [])
    _run(ex, clock, 4)
    ex.stop()
    ex.start(pipe, [ConstantSource(0, rate=4), ConstantSource(1, rate=4)],
             warmup=False)
    _run(ex, clock, 4)
    assert ex.stop()["windows_published"] == 4


# ------------------------------------------------------------- metrics --


def test_serve_metric_families_roundtrip():
    pipe = api.compile(_spec())
    clock = FakeClock()
    ex = _executor(clock)
    ex.start(pipe, [ConstantSource(0, rate=8, value=2.0),
                    LateShardSource(ConstantSource(1, rate=8, value=2.0),
                                    4, 6)], warmup=False)
    _run(ex, clock, 12)
    ex.stop()
    text = metrics_text(pipeline=pipe, state=ex.state,
                        straggler=ex.monitor, executor=ex)
    fams = parse_prometheus_text(text)
    for name in ("repro_serve_queue_depth",
                 "repro_serve_queue_high_watermark",
                 "repro_serve_queue_items_total",
                 "repro_serve_queue_dropped_total",
                 "repro_serve_queue_deferred_total",
                 "repro_serve_staged_items_total",
                 "repro_serve_truncated_items_total",
                 "repro_serve_ingest_overlap_fraction",
                 "repro_serve_windows_published_total",
                 "repro_serve_windows_partial_total",
                 "repro_serve_window_latency_seconds"):
        assert name in fams, name
    assert fams["repro_serve_windows_partial_total"]["samples"][()] == 2.0
    assert fams["repro_serve_queue_depth"]["samples"][
        (("shard", "0"),)] == 0.0
    samples = fams["repro_serve_window_latency_seconds"]["samples"]
    assert (("quantile", "p50"),) in samples


# ---------------------------------------------------------- publisher --


class _StubPipeline:
    plan = object()

    def query_layout(self):
        return {"c": (0, 1, "count"), "m": (1, 1, "mean"),
                "q": (2, 2, "quantile"), "hh": (4, 4, "heavy_hitters")}


def test_publisher_widening_rules_per_kind():
    pub = WindowPublisher(_StubPipeline())
    row = dict(tick=3, sum=10.0, sum_var=4.0, mean=5.0, mean_var=1.0,
               n_sampled=7, histogram=np.array([1.0, 3.0]),
               answers=np.array([8.0, 5.0, 1.5, 2.5, 11.0, 12.0, 40.0,
                                 60.0], np.float32),
               bounds=np.arange(8, dtype=np.float32))
    win = pub.publish(row, alpha=0.5, partial=True, publish_time=9.0,
                      first_arrival=7.0)
    assert win.latency == 2.0 and win.partial and win.alpha == 0.5
    # linear slots scale by 1/α; mean and quantile VALUES do not; the
    # heavy-hitter key half does not, its estimate half does
    np.testing.assert_allclose(
        win.answers, [16.0, 5.0, 1.5, 2.5, 11.0, 12.0, 80.0, 120.0])
    np.testing.assert_allclose(win.bounds, np.arange(8) * 2.0)
    assert win.sum == 20.0 and win.sum_var == 16.0
    assert win.mean == 5.0 and win.mean_var == 4.0
    np.testing.assert_allclose(win.histogram, [2.0, 6.0])
    # complete windows pass through untouched — the same objects
    full = pub.publish(row, alpha=1.0, partial=False, publish_time=9.0,
                       first_arrival=7.0)
    assert full.answers is row["answers"] and full.bounds is row["bounds"]
    assert full.sum == 10.0 and full.histogram is row["histogram"]

"""SRS baseline through the HostTree: Horvitz–Thompson unbiasedness and
the accuracy gap vs WHS that the paper's evaluation rests on."""
import numpy as np
import pytest

from repro.data import stream as S
from repro.launch.analytics import run_pipeline


def test_srs_pipeline_roughly_unbiased():
    losses = [run_pipeline(S.paper_gaussian(), fraction=0.3, ticks=6, seed=s,
                           mode="srs")["accuracy_loss"] for s in (1, 2, 3, 4)]
    # per-run HT noise is a few %, but the signed errors average out
    assert np.mean(losses) < 0.06


def test_whs_beats_srs_under_skew():
    specs = S.paper_poisson(rates=tuple(4000 * sh for sh in S.SKEW_SHARES),
                            skewed=True)
    whs = run_pipeline(specs, fraction=0.1, ticks=5, seed=3)["accuracy_loss"]
    srs = run_pipeline(specs, fraction=0.1, ticks=5, seed=3,
                       mode="srs")["accuracy_loss"]
    assert whs * 50 < srs, (whs, srs)     # paper: 2600× at this setting


def test_srs_bandwidth_exceeds_whs_at_equal_fraction():
    """Per-level coin flip keeps f^(1/3) at hop 0 — one reason stratified
    budget-based sampling also wins on bandwidth (Fig. 8)."""
    whs = run_pipeline(S.paper_gaussian(), fraction=0.1, ticks=4, seed=1)
    srs = run_pipeline(S.paper_gaussian(), fraction=0.1, ticks=4, seed=1,
                       mode="srs")
    assert srs["bandwidth_fraction"] > 2 * whs["bandwidth_fraction"]

"""Scan engine: bit-equivalence with the per-node loop oracle across all
sampler backends, the one-dispatch-per-epoch execution model, donated
on-device state, and the batched ingest path."""
import jax
import numpy as np
import pytest

from repro.core.tree import HostTree
from repro.data import stream as S
from repro.launch.analytics import run_pipeline

X = 3


def _tree(engine, mode="whs", backend="topk", iv=None, seed=5):
    return HostTree(fanin=[4, 2, 1], num_strata=X, capacity=768,
                    sample_sizes=[96, 96, 96], seed=seed, mode=mode,
                    fraction=0.25 if mode == "srs" else None,
                    interval_ticks=iv, engine=engine,
                    sampler_backend=backend)


def _ingest_arrays(ticks, n0=4, width=400, seed=11):
    rng = np.random.default_rng(seed)
    vals = rng.normal(50, 9, (ticks, n0, width)).astype(np.float32)
    strs = rng.integers(0, X, (ticks, n0, width)).astype(np.int32)
    counts = rng.integers(100, width, (ticks, n0)).astype(np.int32)
    return vals, strs, counts


def _run_sequential(tree, vals, strs, counts):
    ticks, n0, _ = vals.shape
    for t in range(1, ticks + 1):
        for node in range(n0):
            c = counts[t - 1, node]
            tree.ingest(node, vals[t - 1, node, :c], strs[t - 1, node, :c])
        tree.tick(t)


def _assert_same_results(a: HostTree, b: HostTree):
    assert len(a.results) == len(b.results) > 0
    for ra, rb in zip(a.results, b.results):
        for k in ("tick", "sum", "sum_var", "mean", "mean_var", "n_sampled"):
            assert ra[k] == rb[k], k
        np.testing.assert_array_equal(ra["histogram"], rb["histogram"])
    assert a.items_forwarded == b.items_forwarded


# ---------------------------------------------------------- equivalence --
@pytest.mark.parametrize("backend", ["argsort", "topk", "pallas", "pallas_fused"])
def test_scan_matches_loop_oracle_all_backends(backend):
    """One fused epoch dispatch ≡ per-node per-tick dispatches, to the bit
    (same (tick, level, node) key folding, same f32 metadata math)."""
    vals, strs, counts = _ingest_arrays(4)
    ref = _tree("loop", backend=backend)
    _run_sequential(ref, vals, strs, counts)
    scan = _tree("scan", backend=backend)
    scan.run_epoch(1, vals, strs, counts)
    _assert_same_results(ref, scan)


@pytest.mark.parametrize("mode", ["whs", "srs"])
def test_scan_matches_loop_oracle_modes(mode):
    vals, strs, counts = _ingest_arrays(5)
    ref = _tree("loop", mode=mode)
    _run_sequential(ref, vals, strs, counts)
    scan = _tree("scan", mode=mode)
    scan.run_epoch(1, vals, strs, counts)
    _assert_same_results(ref, scan)


def test_scan_matches_loop_async_intervals():
    """Interval gating (due/not-due levels accumulate in place) agrees
    with the host engines' per-level due checks."""
    vals, strs, counts = _ingest_arrays(6)
    ref = _tree("loop", iv=[1, 2, 3])
    _run_sequential(ref, vals, strs, counts)
    scan = _tree("scan", iv=[1, 2, 3])
    scan.run_epoch(1, vals, strs, counts)
    _assert_same_results(ref, scan)


def test_scan_multi_epoch_continues_stream():
    """Two epochs chain through the donated state exactly like one: sticky
    metadata and tick indices carry across the epoch boundary."""
    vals, strs, counts = _ingest_arrays(6)
    ref = _tree("loop")
    _run_sequential(ref, vals, strs, counts)
    scan = _tree("scan")
    scan.run_epoch(1, vals[:3], strs[:3], counts[:3])
    scan.run_epoch(4, vals[3:], strs[3:], counts[3:])
    _assert_same_results(ref, scan)


def test_scan_ingest_accounting_matches_under_overload():
    """A (tick, node) offering more items than the level-0 buffer holds:
    items_ingested counts the OFFERED items (pre-truncation) on every
    engine, so bandwidth fractions agree."""
    kw = dict(fraction=0.5, ticks=3, seed=3, capacity=512, warmup_ticks=0)
    a = run_pipeline(S.paper_gaussian(), engine="level", **kw)
    b = run_pipeline(S.paper_gaussian(), engine="scan", **kw)
    assert a["items_ingested"] == b["items_ingested"]
    assert a["items_forwarded"] == b["items_forwarded"]
    np.testing.assert_allclose(a["bandwidth_fraction"],
                               b["bandwidth_fraction"], rtol=0)


def test_scan_matches_level_via_pipeline():
    """Full driver path (batched ingest generation included) agrees with
    the level engine on the fig7 workload."""
    kw = dict(fraction=0.2, ticks=4, seed=2, warmup_ticks=0)
    a = run_pipeline(S.paper_gaussian(), engine="level", **kw)
    b = run_pipeline(S.paper_gaussian(), engine="scan", **kw)
    np.testing.assert_allclose(a["approx_sum"], b["approx_sum"], rtol=1e-6)
    np.testing.assert_allclose(a["bound_2sigma"], b["bound_2sigma"], rtol=1e-6)
    assert a["items_forwarded"] == b["items_forwarded"]
    assert b["dispatches"] == 1


# ------------------------------------------------------------ dispatches --
def test_one_compiled_dispatch_per_epoch():
    """An epoch is ONE jitted call: the epoch fn compiles once, every
    subsequent epoch reuses the executable, and no per-tick/per-level
    dispatches happen (the tree-step traces exactly as often as the scan
    program compiles — never per executed tick)."""
    vals, strs, counts = _ingest_arrays(4)
    tree = _tree("scan")
    tree.run_epoch(1, vals, strs, counts)
    traces_after_first = tree._trace_counter["traces"]
    assert tree.dispatch_count == 1
    tree.run_epoch(5, vals, strs, counts)
    assert tree.dispatch_count == 2
    # same epoch length → same executable, zero retracing
    assert tree._trace_counter["traces"] == traces_after_first
    assert tree._epoch_fns[4]._cache_size() == 1


def test_scan_state_is_donated():
    """The epoch dispatch donates the whole TreeState: the previous
    epoch's buffers are invalidated, not copied."""
    vals, strs, counts = _ingest_arrays(2)
    tree = _tree("scan")
    state_before = tree._state
    tree.run_epoch(1, vals, strs, counts)
    with pytest.raises(RuntimeError):
        np.asarray(state_before.values[0])


def test_scan_rejects_per_tick_api():
    tree = _tree("scan")
    with pytest.raises(RuntimeError):
        tree.ingest(0, np.ones(3, np.float32), np.zeros(3, np.int32))
    with pytest.raises(RuntimeError):
        tree.tick(1)


# ---------------------------------------------------------- spmd epoch --
def test_spmd_epoch_matches_per_interval():
    """spmd_local_then_root_epoch over T stacked batches ≡ T per-interval
    calls with fold_in(key, i) keys, bit-for-bit (1-device mesh)."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    try:
        shard_map = jax.shard_map
    except AttributeError:
        from jax.experimental.shard_map import shard_map
    from repro.core.tree import (spmd_local_then_root,
                                 spmd_local_then_root_epoch)
    from repro.core.types import IntervalBatch, StratumMeta

    m, ticks = 256, 3
    rng = np.random.default_rng(0)
    batches = IntervalBatch(
        value=jnp.asarray(rng.normal(100, 10, (ticks, m)), jnp.float32),
        stratum=jnp.asarray(rng.integers(0, X, (ticks, m)), jnp.int32),
        valid=jnp.ones((ticks, m), bool),
        meta=StratumMeta(jnp.ones((ticks, X)), jnp.zeros((ticks, X))))
    mesh = jax.make_mesh((1,), ("data",))
    key = jax.random.PRNGKey(0)
    kw = dict(axis_name="data", num_strata=X, local_budget=32,
              root_budget=64)

    specs_t = IntervalBatch(P(None, "data"), P(None, "data"),
                            P(None, "data"), StratumMeta(P(), P()))
    s_t, m_t = shard_map(
        lambda k, b: spmd_local_then_root_epoch(k, b, **kw),
        mesh=mesh, in_specs=(P(), specs_t), out_specs=(P(), P()))(key, batches)

    spec1 = IntervalBatch(P("data"), P("data"), P("data"),
                          StratumMeta(P(), P()))
    one = shard_map(lambda k, b: spmd_local_then_root(k, b, **kw),
                    mesh=mesh, in_specs=(P(), spec1), out_specs=(P(), P()))
    for i in range(ticks):
        b = IntervalBatch(batches.value[i], batches.stratum[i],
                          batches.valid[i],
                          StratumMeta(batches.meta.weight[i],
                                      batches.meta.count[i]))
        s1, m1 = one(jax.random.fold_in(key, i), b)
        assert float(s1.estimate) == float(s_t.estimate[i])
        assert float(m1.estimate) == float(m_t.estimate[i])


# -------------------------------------------------------- batched ingest --
def test_batch_ingest_matches_sequential_generation():
    """batch_ingest consumes the source RNGs exactly like the sequential
    drivers and packs per (tick, node) in source order."""
    specs = S.paper_gaussian(rates=(50, 50, 50, 50))
    seq = [S.StreamSource(specs, seed=i) for i in range(4)]
    bat = [S.StreamSource(specs, seed=i) for i in range(4)]
    b = S.batch_ingest(bat, ticks=3, n_nodes=2, width=2048)
    exact = 0.0
    for t in range(3):
        fill = [0, 0]
        for i, src in enumerate(seq):
            v, s = src.tick()
            exact += float(v.sum())
            node, f = i % 2, fill[i % 2]
            np.testing.assert_array_equal(b.values[t, node, f:f + len(v)], v)
            np.testing.assert_array_equal(b.strata[t, node, f:f + len(v)], s)
            fill[node] = f + len(v)
        assert list(b.counts[t]) == fill
    assert b.exact_sum == exact


def test_stream_source_batch_matches_ticks():
    specs = S.paper_gaussian(rates=(40, 40, 40, 40))
    a = S.StreamSource(specs, seed=9)
    bsrc = S.StreamSource(specs, seed=9)
    values, strata, counts = bsrc.batch(3)
    for t in range(3):
        v, s = a.tick()
        assert counts[t] == len(v)
        np.testing.assert_array_equal(values[t, :len(v)], v)
        np.testing.assert_array_equal(strata[t, :len(v)], s)

"""Sampler-backend equivalence: the ``pallas`` (interpret-mode), ``topk``
and ``argsort`` backends must produce identical keep-masks and weight sets
for the same key — across strata counts, skew, empty strata, and
degenerate reservoir allocations. No hypothesis dependency: the sweeps are
explicit so these run everywhere tier-1 runs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sampling, whs
from repro.core.types import IntervalBatch, StratumMeta

BACKENDS = ("argsort", "topk", "pallas", "pallas_fused")
ALT_BACKENDS = ("topk", "pallas", "pallas_fused")   # compared against the argsort reference


def _batch(seed, m, x, skew=None, valid_frac=1.0):
    rng = np.random.default_rng(seed)
    if skew is None:
        strata = rng.integers(0, x, m).astype(np.int32)
    else:
        # heavily skewed stratum shares, e.g. (0.9, 0.09, 0.009, ...)
        probs = np.asarray(skew, np.float64)
        strata = rng.choice(x, size=m, p=probs / probs.sum()).astype(np.int32)
    vals = rng.normal(100, 25, m).astype(np.float32)
    valid = rng.random(m) < valid_frac
    return IntervalBatch(jnp.asarray(vals), jnp.asarray(strata),
                         jnp.asarray(valid), StratumMeta.identity(x))


@pytest.mark.parametrize("alt", ALT_BACKENDS)
@pytest.mark.parametrize("m,x,budget", [
    (256, 1, 64), (512, 4, 100), (4096, 16, 500), (333, 3, 7), (1000, 7, 999),
])
def test_whsamp_backends_identical(alt, m, x, budget):
    batch = _batch(m + x, m, x, valid_frac=0.9)
    key = jax.random.PRNGKey(budget)
    a = whs.whsamp(key, batch, jnp.float32(budget), x, backend="argsort",
                   max_reservoir=budget)
    p = whs.whsamp(key, batch, jnp.float32(budget), x, backend=alt,
                   max_reservoir=budget)
    assert (np.asarray(a.selected) == np.asarray(p.selected)).all()
    np.testing.assert_array_equal(np.asarray(a.meta.weight),
                                  np.asarray(p.meta.weight))
    np.testing.assert_array_equal(np.asarray(a.meta.count),
                                  np.asarray(p.meta.count))
    np.testing.assert_array_equal(np.asarray(a.c), np.asarray(p.c))


@pytest.mark.parametrize("alt", ALT_BACKENDS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_whsamp_backends_identical_under_skew(alt, seed):
    """0.01%-share strata (the paper's §V-E setting) select identically."""
    x = 4
    batch = _batch(seed, 4096, x, skew=(0.80, 0.1989, 0.001, 0.0001))
    key = jax.random.PRNGKey(seed)
    a = whs.whsamp(key, batch, jnp.float32(400), x, backend="argsort",
                   max_reservoir=400)
    p = whs.whsamp(key, batch, jnp.float32(400), x, backend=alt,
                   max_reservoir=400)
    assert (np.asarray(a.selected) == np.asarray(p.selected)).all()
    np.testing.assert_array_equal(np.asarray(a.meta.weight),
                                  np.asarray(p.meta.weight))


@pytest.mark.parametrize("alt", ALT_BACKENDS)
def test_backends_identical_with_empty_strata(alt):
    """Strata with zero items must select nothing and keep sticky meta on
    every backend."""
    m, x = 512, 6
    rng = np.random.default_rng(5)
    strata = rng.integers(0, 2, m).astype(np.int32)   # strata 2..5 empty
    batch = IntervalBatch(jnp.asarray(rng.normal(0, 1, m), jnp.float32),
                          jnp.asarray(strata), jnp.ones((m,), bool),
                          StratumMeta.identity(x))
    key = jax.random.PRNGKey(9)
    a = whs.whsamp(key, batch, jnp.float32(64), x, backend="argsort",
                   max_reservoir=64)
    p = whs.whsamp(key, batch, jnp.float32(64), x, backend=alt,
                   max_reservoir=64)
    assert (np.asarray(a.selected) == np.asarray(p.selected)).all()
    np.testing.assert_array_equal(np.asarray(a.meta.weight),
                                  np.asarray(p.meta.weight))
    assert (np.asarray(a.meta.weight)[2:] == 1.0).all()  # sticky identity


def test_topk_matches_argsort_on_priority_ties():
    """At m ≈ 44k, f32 uniform draws collide (24-bit resolution) — the
    topk backend's position-ordered tie resolution must reproduce the
    stable lexsort law bit-for-bit."""
    m, x, budget = 44032, 8, 1104
    rng = np.random.default_rng(0)
    batch = IntervalBatch(jnp.asarray(rng.normal(100, 10, m), jnp.float32),
                          jnp.asarray(rng.integers(0, x, m), jnp.int32),
                          jnp.ones((m,), bool), StratumMeta.identity(x))
    key = jax.random.PRNGKey(m)
    u = np.asarray(jax.random.uniform(key, (m,)))
    assert m - len(np.unique(u)) > 0, "test needs priority collisions"
    a = whs.whsamp(key, batch, jnp.float32(budget), x, backend="argsort",
                   max_reservoir=budget)
    t = whs.whsamp(key, batch, jnp.float32(budget), x, backend="topk",
                   max_reservoir=budget)
    assert (np.asarray(a.selected) == np.asarray(t.selected)).all()


@pytest.mark.parametrize("backend", BACKENDS)
def test_select_zero_reservoir_keeps_nothing(backend):
    """N_i = 0 with c_i > 0 must keep zero items (regression: the threshold
    path used to clip τ to the stratum max and keep one)."""
    m, x = 128, 2
    be = sampling.get_backend(backend)
    strata = jnp.asarray(np.arange(m) % x, jnp.int32)
    sel = be.select(jax.random.PRNGKey(0), strata, jnp.ones((m,), bool),
                    jnp.asarray([0.0, 5.0]), x, max_reservoir=5)
    sel = np.asarray(sel)
    assert sel[::2].sum() == 0      # stratum 0: reservoir 0
    assert sel[1::2].sum() == 5     # stratum 1: reservoir 5


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_counts_exact(backend):
    m, x = 777, 5
    rng = np.random.default_rng(3)
    strata = rng.integers(0, x, m).astype(np.int32)
    valid = rng.random(m) < 0.6
    be = sampling.get_backend(backend)
    got = np.asarray(be.counts(jnp.asarray(strata), jnp.asarray(valid), x))
    want = np.bincount(strata[valid], minlength=x).astype(np.float32)
    np.testing.assert_array_equal(got, want)


def test_same_priorities_same_mask_across_backends():
    """The backend contract: identical priorities ⇒ identical masks."""
    m, x = 2048, 8
    rng = np.random.default_rng(11)
    strata = jnp.asarray(rng.integers(0, x, m), jnp.int32)
    valid = jnp.asarray(rng.random(m) < 0.85)
    prio = jnp.asarray(rng.random(m), jnp.float32)
    res = jnp.asarray(rng.integers(0, 60, x), jnp.float32)
    masks = [
        np.asarray(sampling.get_backend(b).select(
            jax.random.PRNGKey(0), strata, valid, res, x, priorities=prio,
            max_reservoir=60))
        for b in BACKENDS
    ]
    for other in masks[1:]:
        assert (masks[0] == other).all()


def test_get_backend_unknown_name():
    with pytest.raises(ValueError, match="unknown sampler backend"):
        sampling.get_backend("quantum")


@pytest.mark.parametrize("backend,check_rep", [
    ("topk", True), ("pallas", False),  # pallas_call has no replication rule
])
def test_spmd_path_backend_selectable(backend, check_rep):
    """sampler_backend is honored end-to-end through the shard_map data
    plane (1-device mesh)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.tree import spmd_local_then_root

    mesh = jax.make_mesh((1,), ("data",))
    rng = np.random.default_rng(0)
    m, x = 1024, 4
    batch = IntervalBatch(jnp.asarray(rng.normal(100, 10, m), jnp.float32),
                          jnp.asarray(rng.integers(0, x, m), jnp.int32),
                          jnp.ones((m,), bool), StratumMeta.identity(x))

    def f(key, b):
        s, _ = spmd_local_then_root(key, b, axis_name="data", num_strata=x,
                                    local_budget=256, root_budget=128,
                                    sampler_backend=backend)
        return s.estimate

    fn = shard_map(f, mesh=mesh,
                   in_specs=(P(), IntervalBatch(P("data"), P("data"),
                                                P("data"),
                                                StratumMeta(P(), P()))),
                   out_specs=P(), check_rep=check_rep)
    est = float(fn(jax.random.PRNGKey(0), batch))
    exact = float(np.asarray(batch.value).sum())
    assert abs(est - exact) / exact < 0.1

"""Validate the text-level HLO cost model against XLA's cost analysis on
loop-free modules, and its trip-count multiplication on scans."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlocost


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_matches_xla_on_loop_free_dots():
    def f(x, w1, w2):
        return jax.nn.relu(x @ w1) @ w2

    s = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    w1 = jax.ShapeDtypeStruct((512, 1024), jnp.float32)
    w2 = jax.ShapeDtypeStruct((1024, 128), jnp.float32)
    c = _compile(f, s, w1, w2)
    xla = c.cost_analysis()
    xla = xla[0] if isinstance(xla, list) else xla
    mine = hlocost.analyze_text(c.as_text())
    expected_dots = 2 * 256 * 512 * 1024 + 2 * 256 * 1024 * 128
    assert mine["dot_flops"] == expected_dots
    # within 10% of XLA's total (elementwise bookkeeping differs slightly)
    assert abs(mine["flops"] - float(xla["flops"])) / float(xla["flops"]) < 0.1


def test_scan_trip_count_multiplied():
    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        y, _ = jax.lax.scan(body, x, None, length=12)
        return y

    c = _compile(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    mine = hlocost.analyze_text(c.as_text())
    one_dot = 2 * 128 ** 3
    assert mine["dot_flops"] == pytest.approx(12 * one_dot, rel=1e-6)
    # XLA counts the body once — our model must exceed it
    xla = c.cost_analysis()
    xla = xla[0] if isinstance(xla, list) else xla
    assert mine["flops"] > 5 * float(xla["flops"])


def test_nested_scan_multiplies_products():
    def f(x):
        def inner(c, _):
            return c @ c, None

        def outer(c, _):
            y, _ = jax.lax.scan(inner, c, None, length=3)
            return y, None

        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    c = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    mine = hlocost.analyze_text(c.as_text())
    assert mine["dot_flops"] == pytest.approx(15 * 2 * 64 ** 3, rel=1e-6)


def test_collective_bytes_counted(monkeypatch):
    """all-reduce inside a scan is multiplied by the trip count."""
    txt = """
HloModule test, is_scheduled=true

%body (arg: (s32[], f32[4,256])) -> (s32[], f32[4,256]) {
  %arg = (s32[], f32[4,256]) parameter(0)
  %gte0 = s32[] get-tuple-element(%arg), index=0
  %gte1 = f32[4,256]{1,0} get-tuple-element(%arg), index=1
  %ar = f32[4,256]{1,0} all-reduce(%gte1), replica_groups={}, to_apply=%sum
  ROOT %t = (s32[], f32[4,256]) tuple(%gte0, %ar)
}

%cond (arg2: (s32[], f32[4,256])) -> pred[] {
  %arg2 = (s32[], f32[4,256]) parameter(0)
  %g = s32[] get-tuple-element(%arg2), index=0
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%g, %c), direction=LT
}

ENTRY %main (p0: f32[4,256]) -> f32[4,256] {
  %p0 = f32[4,256]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %tup = (s32[], f32[4,256]) tuple(%c0, %p0)
  %w = (s32[], f32[4,256]) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %out = f32[4,256]{1,0} get-tuple-element(%w), index=1
}
"""
    mine = hlocost.analyze_text(txt)
    assert mine["collectives"]["all-reduce"]["count"] == 7
    assert mine["collective_bytes"] == 7 * 4 * 256 * 4

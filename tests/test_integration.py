"""End-to-end integration: approx-training pipeline, resume-from-ckpt,
serve loop telemetry, SRS-vs-WHS training equivalence."""
import numpy as np
import pytest

from repro.launch import serve, train


def test_train_loss_decreases(tmp_path):
    losses = train.main([
        "--arch", "smollm-135m", "--smoke", "--steps", "30", "--batch", "8",
        "--seq", "128", "--interval-size", "24", "--log-every", "100",
        "--ckpt-dir", str(tmp_path / "ck")])
    assert np.mean(losses[-5:]) < losses[0] - 0.5


def test_train_resume_from_checkpoint(tmp_path):
    ckdir = str(tmp_path / "ck")
    train.main(["--arch", "smollm-135m", "--smoke", "--steps", "10",
                "--batch", "4", "--seq", "64", "--log-every", "100",
                "--ckpt-dir", ckdir])
    # second run resumes at step 10 and continues through step 13
    losses = train.main(["--arch", "smollm-135m", "--smoke", "--steps", "14",
                         "--batch", "4", "--seq", "64", "--log-every", "100",
                         "--ckpt-dir", ckdir])
    assert len(losses) == 4  # steps 10..13: no step repeated, none skipped


def test_train_with_stragglers_still_converges(tmp_path):
    losses = train.main([
        "--arch", "smollm-135m", "--smoke", "--steps", "30", "--batch", "8",
        "--seq", "128", "--log-every", "100", "--simulate-stragglers", "0.2",
        "--ckpt-dir", str(tmp_path / "ck")])
    assert np.mean(losses[-5:]) < losses[0] - 0.3


def test_serve_telemetry_close_to_exact():
    approx_mean, exact_mean = serve.main([
        "--arch", "smollm-135m", "--smoke", "--requests", "16", "--batch", "8",
        "--prompt-len", "16", "--decode-len", "4",
        "--telemetry-fraction", "0.5"])
    assert abs(approx_mean - exact_mean) / exact_mean < 0.25

"""SPMD query plane: multi-device equivalence harness.

The acceptance property of the mesh lowering (``repro.api.compile(spec,
mesh=...)`` with tenants): on 2/4/8 simulated devices the per-tenant
``WindowAnswers`` agree with the single-device run on the same total
stream — EXACT queries (the HT count, variance 0 by construction)
bitwise, CLT queries within their published ±2σ bounds, sketch queries
within their published rank/CM bounds — and only sketch summaries
(never raw reservoirs) cross a device boundary, asserted against the
traced collectives' operand shapes.

Multi-device checks run in a SUBPROCESS with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the main test
process must keep the default 1-device backend); one subprocess run
feeds every assertion via a module-scoped fixture. Dispatch/donation/
retrace and CLT-coverage properties need no second device and run
in-process on a 1-device mesh.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

# ---------------------------------------------------------------------------
# the multi-device worker: every device-count run + ground truth in one go
# ---------------------------------------------------------------------------
_HARNESS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np

    from repro import api
    from repro.api.spec import (PipelineSpec, SamplerSpec, TenantSpec,
                                TopologySpec)
    from repro.data import stream as S
    from repro.query.registry import QueryRegistry

    X, T, M = 3, 4, 8192
    HEAVY = np.array([7, 13, 29, 101], np.int64)

    rng = np.random.default_rng(0)
    vals = np.where(
        rng.random((T, M)) < 0.55,
        rng.choice(HEAVY, p=[0.5, 0.3, 0.15, 0.05], size=(T, M)),
        np.round(rng.normal(50.0, 9.0, (T, M)))).astype(np.float32)
    strs = rng.integers(0, X, (T, M)).astype(np.int32)
    counts = np.full((T,), M, np.int64)
    batches = S.rows_to_interval_batch(vals, strs, counts, X)

    def tenants():
        a = (QueryRegistry().register_sum().register_count()
             .register_mean()
             .register_quantile("q", (0.5, 0.9), capacity=64)
             .register_heavy_hitters("hh", k=4, width=64, depth=2))
        b = (QueryRegistry().register_count("n")
             .register_histogram("h", 0.0, 128.0, 16))
        return (TenantSpec.from_registry("a", a),
                TenantSpec.from_registry("b", b))

    def make_spec(fraction, mode="whs", with_tenants=True):
        return PipelineSpec(
            topology=TopologySpec(fanin=(4, 2, 1), capacity=M // 8,
                                  num_strata=X),
            sampler=SamplerSpec(mode=mode, backend="topk",
                                fraction=fraction),
            tenants=tenants() if with_tenants else (),
            seed=0)

    def mesh_of(n):
        return jax.make_mesh((n,), ("data",), devices=jax.devices()[:n])

    out = {"exact": {
        "sum": [float(v.sum()) for v in vals],
        "count": float(M),
        "mean": [float(v.mean()) for v in vals],
    }}

    # ---- tenant runs across device counts (sampled regime) ---------------
    spec = make_spec(0.25)
    runs = {}
    for n in (1, 2, 4, 8):
        pipe = api.compile(spec, mesh=mesh_of(n))
        st, wa = pipe.run_epoch(pipe.init(), pipe.default_key, batches)
        runs[n] = dict(answers=np.asarray(wa.answers).tolist(),
                       bounds=np.asarray(wa.bounds).tolist(),
                       n_sampled=np.asarray(wa.n_sampled).tolist(),
                       ok=np.asarray(wa.ok).tolist(),
                       tick=np.asarray(wa.tick).tolist())
    out["tenant_runs"] = runs
    out["layout"] = {k: list(v) for k, v in
                     api.compile(spec, mesh=mesh_of(1)).plan.layout()
                     .items()}
    out["local_budget"] = api.compile(spec, mesh=mesh_of(1)).local_budget

    # quantile ground truth: rank of each answered value on the stream
    # the continuous sketch has absorbed so far (windows 0..t)
    def ranks_so_far(values_row, t):
        seen = vals[:t + 1].reshape(-1)
        return [float((seen <= v).mean()) for v in values_row]
    lay = {k: v for k, v in out["layout"].items()}
    qo, qw, _ = lay["a/q"]
    out["q_ranks"] = {
        n: [ranks_so_far(np.asarray(runs[n]["answers"])[t, qo:qo + qw], t)
            for t in range(T)] for n in runs}
    # heavy-hitter ground truth: cumulative rounded-key counts after
    # each window (the continuous sketch spans windows 0..t)
    out["hh_true_cum"] = []
    for t in range(T):
        keys_seen = np.round(vals[:t + 1].reshape(-1)).astype(np.int64)
        uniq, cnt = np.unique(keys_seen, return_counts=True)
        out["hh_true_cum"].append(
            {int(k): int(c) for k, c in zip(uniq, cnt)})
    out["hh_heavy"] = [int(k) for k in HEAVY]

    # ---- exact regime: fraction 1.0 on 8 devices (budget == shard) -------
    # single stratum: fair allocation then covers every item (per-stratum
    # caps keep multi-strata fraction-1.0 merely near-exact), so every
    # weight is exactly 1 and the sketch holds the raw stream
    spec_exact = PipelineSpec(
        topology=TopologySpec(fanin=(4, 2, 1), capacity=M // 8,
                              num_strata=1),
        sampler=SamplerSpec(mode="whs", backend="topk", fraction=1.0),
        tenants=tenants(), seed=0)
    batches1 = S.rows_to_interval_batch(vals, np.zeros_like(strs), counts, 1)
    pipe1 = api.compile(spec_exact, mesh=mesh_of(8))
    assert pipe1.local_budget == M // 8
    st, wa = pipe1.run_epoch(pipe1.init(), pipe1.default_key, batches1)
    out["exact_regime"] = dict(answers=np.asarray(wa.answers).tolist(),
                               bounds=np.asarray(wa.bounds).tolist())
    out["exact_regime_q_ranks"] = [
        ranks_so_far(np.asarray(wa.answers)[t, qo:qo + qw], t)
        for t in range(T)]

    # ---- multi-epoch resume (4 devices): 2+2 ticks ≡ 4 ticks -------------
    pipe = api.compile(spec, mesh=mesh_of(4))
    stA, waA = pipe.run_epoch(pipe.init(), pipe.default_key,
                              jax.tree.map(lambda v: v[:2], batches))
    stA, waB = pipe.run_epoch(stA, pipe.default_key,
                              jax.tree.map(lambda v: v[2:], batches))
    two = np.concatenate([np.asarray(waA.answers), np.asarray(waB.answers)])
    one = np.asarray(runs[4]["answers"])
    out["resume"] = dict(
        bitwise=bool((two == one).all()),
        max_abs_diff=float(np.max(np.abs(two - one))),
        ticks=np.concatenate([np.asarray(waA.tick),
                              np.asarray(waB.tick)]).tolist())

    # ---- srs baseline on the mesh (no tenants) ---------------------------
    srs = {}
    for n in (1, 8):
        pipe = api.compile(make_spec(0.25, mode="srs", with_tenants=False),
                           mesh=mesh_of(n))
        _, (sq, mq) = pipe.run_epoch(pipe.init(), pipe.default_key, batches)
        srs[n] = dict(sum=np.asarray(sq.estimate).tolist(),
                      sum_var=np.asarray(sq.variance).tolist(),
                      mean=np.asarray(mq.estimate).tolist(),
                      mean_var=np.asarray(mq.variance).tolist())
    out["srs_runs"] = srs

    # ---- collectives audit: what actually crosses the mesh ---------------
    COLL = ("all_gather", "psum", "pmin", "pmax", "pmean", "all_to_all",
            "ppermute", "reduce_scatter")
    def walk(jaxpr, acc):
        for eqn in jaxpr.eqns:
            if any(c in eqn.primitive.name for c in COLL):
                elems = max(int(np.prod(v.aval.shape) or 1)
                            for v in eqn.invars if hasattr(v, "aval"))
                acc.append([eqn.primitive.name, elems])
            for v in eqn.params.values():
                for j in (v if isinstance(v, (tuple, list)) else (v,)):
                    inner = getattr(j, "jaxpr", None)
                    if inner is not None and hasattr(inner, "eqns"):
                        walk(inner, acc)
                    elif hasattr(j, "eqns"):
                        walk(j, acc)
    pipe = api.compile(spec, mesh=mesh_of(8))
    closed = jax.make_jaxpr(
        lambda st, k, b, bt: pipe._fn(st, k, b, bt))(
        pipe.init(), pipe.default_key, jnp.float32(pipe.local_budget),
        batches)
    acc = []
    walk(closed.jaxpr, acc)
    out["collectives"] = acc
    out["shard_items"] = M // 8
    out["n_devices_seen"] = len(jax.devices())
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def harness():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _HARNESS],
                         capture_output=True, text=True, timeout=900,
                         env=env, cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def _slice(r, lay, name):
    o, w, _ = lay[name]
    a = np.asarray(r["answers"])[..., o:o + w]
    b = np.asarray(r["bounds"])[..., o:o + w]
    return a, b


def test_eight_devices_simulated(harness):
    assert harness["n_devices_seen"] == 8
    for n, r in harness["tenant_runs"].items():
        assert np.asarray(r["ok"]).all()
        assert r["tick"] == [0, 1, 2, 3]


def test_exact_queries_bitwise_across_device_counts(harness):
    """Both tenants' HT counts (variance 0 by construction) are
    bitwise-identical on 1, 2, 4, and 8 devices — the merged answer is
    a sum of exact per-shard integers, independent of the split."""
    lay = harness["layout"]
    ref = harness["tenant_runs"]["1"]
    for name in ("a/count", "b/n"):
        want, _ = _slice(ref, lay, name)
        np.testing.assert_array_equal(want[:, 0],
                                      np.asarray(harness["exact"]["count"]))
        for n in ("2", "4", "8"):
            got, bb = _slice(harness["tenant_runs"][n], lay, name)
            np.testing.assert_array_equal(got, want), (name, n)
            np.testing.assert_array_equal(bb, 0.0)


def test_clt_answers_within_published_bounds(harness):
    """Per-tenant SUM/MEAN on every device count stay within their own
    reported ±2σ of the exact stream aggregate (fixed seeds; 2x slack
    on the 2σ bound keeps the deterministic check off the 5% tail)."""
    lay = harness["layout"]
    exact_sum = np.asarray(harness["exact"]["sum"])
    exact_mean = np.asarray(harness["exact"]["mean"])
    for n, r in harness["tenant_runs"].items():
        a, b = _slice(r, lay, "a/sum")
        assert np.all(np.abs(a[:, 0] - exact_sum) <= 2 * b[:, 0] + 1e-3), n
        assert np.all(b[:, 0] > 0.0), n
        a, b = _slice(r, lay, "a/mean")
        assert np.all(np.abs(a[:, 0] - exact_mean) <= 2 * b[:, 0] + 1e-3), n


def test_histogram_tenant_merges_exactly_at_full_mass(harness):
    """Tenant b's static-edge histogram: total estimated mass across the
    bins equals the HT count (the per-bin linear queries psum-merge
    without loss) on every device count."""
    lay = harness["layout"]
    for n, r in harness["tenant_runs"].items():
        h, _ = _slice(r, lay, "b/h")
        np.testing.assert_allclose(h.sum(axis=-1),
                                   harness["exact"]["count"],
                                   rtol=1e-4), n


def test_quantile_answers_within_published_rank_bounds(harness):
    """The merged compactor's answers, ranked on the exact stream it has
    absorbed so far, stay within the reported rank-error bound plus the
    sampling slack (the sketch summarizes an HT-weighted sample)."""
    lay = harness["layout"]
    for n, r in harness["tenant_runs"].items():
        _, b = _slice(r, lay, "a/q")
        ranks = np.asarray(harness["q_ranks"][n])        # [T, 2]
        targets = np.asarray([0.5, 0.9])
        slack = 0.06  # CLT slack of the ~(budget·devices)-item sample
        assert np.all(np.abs(ranks - targets) <= b + slack), (n, ranks, b)


def test_exact_regime_is_tight(harness):
    """fraction 1.0 on 8 devices (budget == shard): every weight is 1,
    so SUM is the exact integer sum, the quantile ranks meet the bound
    with NO sampling slack, and heavy-hitter estimates obey the pure CM
    bound (only over-count) against true stream counts."""
    lay = harness["layout"]
    r = harness["exact_regime"]
    a, b = _slice(r, lay, "a/sum")
    np.testing.assert_array_equal(a[:, 0],
                                  np.asarray(harness["exact"]["sum"]))
    ranks = np.asarray(harness["exact_regime_q_ranks"])
    _, qb = _slice(r, lay, "a/q")
    assert np.all(np.abs(ranks - np.asarray([0.5, 0.9]))
                  <= qb + 1e-6), (ranks, qb)
    hh_a, hh_b = _slice(r, lay, "a/hh")
    for t in range(hh_a.shape[0]):
        keys, ests = hh_a[t, :4].astype(np.int64), hh_a[t, 4:]
        bound = hh_b[t, 4]
        true = {int(k): v for k, v in harness["hh_true_cum"][t].items()}
        for k, e in zip(keys, ests):
            tk = true.get(int(k), 0)
            assert tk - 1e-3 <= e <= tk + bound + 1e-3, (t, k, e, tk, bound)


def test_heavy_hitters_found_on_every_device_count(harness):
    """The top-k re-merge surfaces the true heavy keys regardless of how
    the stream was sharded, and estimates stay within the CM bound plus
    HT sampling slack of the true counts."""
    lay = harness["layout"]
    heavy = set(harness["hh_heavy"])
    true = {int(k): v for k, v in harness["hh_true_cum"][-1].items()}
    for n, r in harness["tenant_runs"].items():
        hh_a, hh_b = _slice(r, lay, "a/hh")
        keys = set(hh_a[-1, :4].astype(np.int64).tolist())
        assert keys == heavy, (n, keys)
        w_total = sum(true.values())
        for k, e in zip(hh_a[-1, :4].astype(np.int64), hh_a[-1, 4:]):
            # CM bound + 4σ-ish HT slack of the sampled fold-in
            assert abs(e - true[int(k)]) <= hh_b[-1, 4] + 0.05 * w_total, \
                (n, k, e, true[int(k)])


def test_multi_epoch_resume_bitwise(harness):
    """Two 2-tick epochs through the donated state produce bitwise the
    answers of one 4-tick epoch — global-tick key folding plus carried
    sketch state make the epoch boundary invisible."""
    assert harness["resume"]["ticks"] == [0, 1, 2, 3]
    assert harness["resume"]["bitwise"], harness["resume"]
    assert harness["resume"]["max_abs_diff"] == 0.0


def test_srs_baseline_on_mesh_within_bounds(harness):
    """whs is not alone on the mesh: the §IV-B coin-flip baseline also
    lowers (HT from psum-ed moments), agreeing with the exact stream and
    with its own single-device run within combined ±2σ bounds."""
    exact_sum = np.asarray(harness["exact"]["sum"])
    exact_mean = np.asarray(harness["exact"]["mean"])
    for n, r in harness["srs_runs"].items():
        est = np.asarray(r["sum"])
        sig = np.sqrt(np.asarray(r["sum_var"]))
        assert np.all(np.abs(est - exact_sum) <= 3 * sig), n
        m = np.asarray(r["mean"])
        ms = np.sqrt(np.asarray(r["mean_var"]))
        assert np.all(np.abs(m - exact_mean) <= 3 * ms + 1e-3), n
    d = np.abs(np.asarray(harness["srs_runs"]["1"]["sum"])
               - np.asarray(harness["srs_runs"]["8"]["sum"]))
    comb = 2 * (np.sqrt(np.asarray(harness["srs_runs"]["1"]["sum_var"]))
                + np.sqrt(np.asarray(harness["srs_runs"]["8"]["sum_var"])))
    assert np.all(d <= comb)


def test_only_sketch_summaries_cross_devices(harness):
    """The communicated-bytes audit: every cross-device collective in
    the traced epoch moves at most a sketch-sized operand — strictly
    smaller than one device's compacted reservoir, let alone its shard
    of raw items. The reservoir never crosses."""
    from repro.query.sketches import kll_schedule

    colls = harness["collectives"]
    assert colls, "no collectives traced — the audit went blind"
    sizes = {}
    for name, elems in colls:
        sizes[name] = max(sizes.get(name, 0), elems)
    max_elems = max(sizes.values())
    # largest legitimate summary: the leveled KLL value/weight gather
    # (levels x capacity per leaf — 4x64 here), then the 2x64 CM table
    # psum (=128). At capacity 64 the leveled state matches the
    # compacted reservoir's per-leaf footprint, so the sharp claim is
    # against the RAW shard: no operand ever approaches one device's
    # window of raw items, and the reservoir leaves themselves (values,
    # weights, strata, validity at budget width) never cross.
    legit = max(128, len(kll_schedule(64)) * 64)
    assert max_elems <= legit, sizes
    assert max_elems < harness["shard_items"], sizes
    assert any("all_gather" in n for n in sizes), sizes
    assert any("psum" in n for n in sizes), sizes


# ---------------------------------------------------------------------------
# 1-device in-process properties: dispatch model, donation, traced budgets,
# CLT coverage (mirrors test_scan_engine's assertions for the SPMD epoch)
# ---------------------------------------------------------------------------
X = 3


def _tenant_spec(capacity=1024, fraction=0.25, seed=0):
    from repro.api.spec import (BudgetSpec, PipelineSpec, SamplerSpec,
                                TenantSpec, TopologySpec)
    from repro.query.registry import QueryRegistry

    a = (QueryRegistry().register_sum().register_count().register_mean()
         .register_quantile("q", (0.5, 0.9), capacity=64)
         .register_heavy_hitters("hh", k=4, width=64, depth=2))
    b = QueryRegistry().register_count("n")
    return PipelineSpec(
        topology=TopologySpec(fanin=(4, 2, 1), capacity=capacity,
                              num_strata=X),
        sampler=SamplerSpec(mode="whs", backend="topk", fraction=fraction),
        tenants=(TenantSpec.from_registry("a", a),
                 TenantSpec.from_registry("b", b)),
        # ceiling above the initial budget: the controller (and the
        # zero-retrace test) must have room to move the traced budget
        budget=BudgetSpec(max_fraction=1.0),
        seed=seed)


def _batches(ticks=2, m=2048, seed=0):
    from repro.data import stream as S

    rng = np.random.default_rng(seed)
    vals = np.round(rng.normal(50, 9, (ticks, m))).astype(np.float32)
    strs = rng.integers(0, X, (ticks, m)).astype(np.int32)
    return S.rows_to_interval_batch(vals, strs, np.full((ticks,), m), X)


def _mesh1():
    import jax

    return jax.make_mesh((1,), ("data",), devices=jax.devices()[:1])


def test_spmd_epoch_one_dispatch_donated_zero_retrace():
    """One jitted dispatch per epoch; the whole state (tick + qstate
    leaves) donated; moving the traced budget never retraces."""
    import jax

    from repro import api

    pipe = api.compile(_tenant_spec(), mesh=_mesh1())
    batches = _batches(2)
    s0 = pipe.init()
    q_before = s0.qstate
    s1, wa1 = pipe.run_epoch(s0, pipe.default_key, batches)
    traces = pipe.trace_counter["traces"]
    assert traces == 1
    n_small = int(np.asarray(wa1.n_sampled)[-1])
    # donated: the previous epoch's sketch buffers are invalidated
    with pytest.raises(RuntimeError):
        np.asarray(jax.tree.leaves(q_before)[0])
    # budget move: bigger sample, same executable (budgets are traced)
    s2, wa2 = pipe.run_epoch(s1, pipe.default_key, _batches(2, seed=1),
                             budgets=[pipe.max_local_budget])
    assert pipe.trace_counter["traces"] == traces, "budget move retraced!"
    assert int(np.asarray(wa2.n_sampled)[-1]) > n_small
    # clamped to the provisioned ceiling
    assert pipe.clamp_budgets([10 ** 9]) == float(pipe.max_local_budget)
    # executable reuse: epoch 1 compiles once; epoch 2 may re-lower once
    # (shard_map canonicalizes the carried state's sharding) but from
    # then on every epoch reuses the cached executable — and the fused
    # tick never re-traces
    cache_after_two = pipe._fn._cache_size()
    assert cache_after_two <= 2
    pipe.run_epoch(s2, pipe.default_key, _batches(2, seed=2),
                   budgets=[64])
    assert pipe._fn._cache_size() == cache_after_two
    assert pipe.trace_counter["traces"] == traces


def test_spmd_budgets_rejected_without_tenants():
    from repro import api
    from repro.api.spec import SpecError

    spec = _tenant_spec()
    import dataclasses

    plain = dataclasses.replace(spec, tenants=())
    pipe = api.compile(plain, mesh=_mesh1())
    with pytest.raises(SpecError, match="tenant"):
        pipe.run_epoch(pipe.init(), pipe.default_key, _batches(1),
                       budgets=[64])


def test_spmd_rejects_indivisible_item_axis():
    """Actionable error for a genuinely unsupported layout: the item
    axis must shard evenly over the mesh."""
    import jax

    from repro import api
    from repro.api.spec import SpecError

    if len(jax.devices()) < 2:
        # build the 2-way mesh error by padding to an odd width on 1 dev
        pipe = api.compile(_tenant_spec(), mesh=_mesh1())
        pipe.n_devices = 2   # simulate the check's arithmetic
        with pytest.raises(SpecError, match="divide evenly"):
            pipe._check_batches(_batches(1, m=2049))
    else:  # pragma: no cover — multi-device hosts check the real path
        mesh = jax.make_mesh((2,), ("data",), devices=jax.devices()[:2])
        pipe = api.compile(_tenant_spec(), mesh=mesh)
        with pytest.raises(SpecError, match="divide evenly"):
            pipe.run_epoch(pipe.init(), pipe.default_key,
                           _batches(1, m=2049))


def test_local_compile_unchanged_by_spmd_lowering():
    """Regression guard for the satellite 'compile(spec) without a mesh
    is bit-identical before/after this PR': the local compiled pipeline
    still bit-matches the per-node loop oracle on a tenant spec (the
    SPMD lowering shares the plan/compiler code — it must not perturb
    the local path)."""
    from repro import api
    from repro.core.tree import HostTree

    spec = _tenant_spec(capacity=768, fraction=0.125)
    batches = _batches(3, m=700)
    vals = np.asarray(batches.value)
    strs = np.asarray(batches.stratum)

    pipe = api.compile(spec)
    # local runtime consumes [T, n0, width] node-major ingest
    n0 = spec.topology.fanin[0]
    width = 700 // n0
    v4 = vals[:, :n0 * width].reshape(3, n0, width)
    s4 = strs[:, :n0 * width].reshape(3, n0, width)
    c4 = np.full((3, n0), width)
    state, wa = pipe.run_epoch(pipe.init(), pipe.default_key, v4, s4, c4)
    rows = pipe.rows(wa)

    ref = HostTree.from_spec(spec, engine="loop")
    for t in range(1, 4):
        for node in range(n0):
            ref.ingest(node, v4[t - 1, node], s4[t - 1, node])
        ref.tick(t)
    assert len(rows) == len(ref.results) > 0
    for ra, rb in zip(rows, ref.results):
        for k in ("sum", "sum_var", "mean", "mean_var", "n_sampled"):
            assert ra[k] == rb[k], k
        np.testing.assert_array_equal(ra["answers"], rb["answers"])
        np.testing.assert_array_equal(ra["bounds"], rb["bounds"])


def test_spmd_clt_coverage_vmapped():
    """Satellite: vmapped multi-seed run — for each tenant's sum/mean on
    the merged root, the measured ±2σ coverage stays at/above the
    nominal-minus-noise threshold (CLT ≈ 95%; 90% floors the
    200-draw binomial wobble)."""
    import jax
    import jax.numpy as jnp

    from repro import api

    from jax.sharding import PartitionSpec as P

    from repro.core import tree as T
    from repro.core.types import IntervalBatch, StratumMeta

    try:
        shard_map = jax.shard_map
    except AttributeError:
        from jax.experimental.shard_map import shard_map

    pipe = api.compile(_tenant_spec(fraction=0.125), mesh=_mesh1())
    plan = pipe.plan
    batches = _batches(1, m=2048, seed=7)
    batch = jax.tree.map(lambda v: v[0], batches)    # one window
    exact_sum = float(np.asarray(batches.value).sum())
    exact_mean = float(np.asarray(batches.value).mean())
    n_draws = 200

    # the vmap runs INSIDE the shard-mapped program (vmapped collectives
    # batch fine; vmap-over-shard_map would fight the replication check)
    def many(keys, b):
        def one(k):
            _, outs = T.spmd_query_plane_tick(
                k, b, plan.init_state(), plan, axis_name="data",
                budget=jnp.float32(pipe.local_budget),
                max_budget=pipe.max_local_budget, num_strata=X,
                allocation="fair", sampler_backend="topk")
            return outs[7], outs[8]                  # answers, bounds
        return jax.vmap(one)(keys)

    item = P("data")
    specs = IntervalBatch(item, item, item, StratumMeta(P(), P()))
    fn = shard_map(many, mesh=_mesh1(), in_specs=(P(), specs),
                   out_specs=(P(), P()))
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(n_draws))
    ans, bnd = jax.jit(fn)(keys, batch)
    ans, bnd = np.asarray(ans), np.asarray(bnd)
    lay = pipe.plan.layout()
    for name, exact in (("a/sum", exact_sum), ("a/mean", exact_mean)):
        o = lay[name][0]
        hits = np.abs(ans[:, o] - exact) <= bnd[:, o]
        assert hits.mean() >= 0.90, (name, hits.mean())
        assert bnd[:, o].min() > 0.0
    # the exact count is covered trivially but must be *exact*
    o = lay["a/count"][0]
    np.testing.assert_array_equal(ans[:, o], 2048.0)
    o = lay["b/n"][0]
    np.testing.assert_array_equal(ans[:, o], 2048.0)

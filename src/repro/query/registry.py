"""Registry of standing queries for the continuous query plane.

A ``QuerySpec`` declares one standing query over the root's windowed
sample stream; a ``QueryRegistry`` is an ordered collection of them.
``registry.compile(num_strata)`` hands the specs to
``repro.query.compiler``, which fuses all of them into ONE batched
root-evaluation function that the tree engines execute inside the scan
tick — every epoch then returns per-window answers ± bounds for every
registered query with no extra dispatches.

Specs are frozen/hashable (tuple-valued fields only) so compiled plans
can close over them inside jitted step factories.

Query kinds and their answer layout (one contiguous f32 slice per query
in the plan's flat answer vector):

    sum / count / mean   1 slot   CLT estimate, bound = 2σ       (§III-D)
    histogram            bins     per-bin count estimate, 2σ
    quantile             len(qs)  value at each quantile; bound = the
                                  sketch's live rank-error ε
    heavy_hitters        2·k      [k keys (as f32), k count estimates];
                                  bound on the estimate slots = CM ε·W
    windowed_quantile    len(qs)  value at each quantile over the LAST
                                  ``window`` root windows (ring of KLL
                                  sub-sketches merged per query); bound =
                                  the merged summary's rank-error ε
    decayed_heavy_hitters 2·k     like heavy_hitters with counts decayed
                                  ``γ = decay`` per window — recent-stream
                                  top-k; bound = CM ε on the decayed
                                  total weight

Caveat: heavy-hitter keys ride the f32 answer vector, which is exact
only for |key| ≤ 2²⁴ (and turns an empty slot's sentinel into 2³¹);
gate consumers on ``est > 0`` and read exact i32 keys from the sketch
state when key IDs can exceed 2²⁴.
"""
from __future__ import annotations

import dataclasses


VALID_KINDS = ("sum", "count", "mean", "histogram", "quantile",
               "heavy_hitters", "windowed_quantile",
               "decayed_heavy_hitters")


@dataclasses.dataclass(frozen=True)
class QuerySpec:
    name: str
    kind: str
    # histogram
    lo: float = 0.0
    hi: float = 1.0
    bins: int = 32
    # quantile sketch (also windowed_quantile)
    qs: tuple = ()
    capacity: int = 256
    # heavy hitters (also decayed_heavy_hitters)
    k: int = 8
    width: int = 1024
    depth: int = 4
    # windowed_quantile: sliding-window span in root windows
    window: int = 8
    # decayed_heavy_hitters: per-window count decay factor
    decay: float = 0.9

    def __post_init__(self):
        if self.kind not in VALID_KINDS:
            raise ValueError(f"unknown query kind {self.kind!r}; "
                             f"valid: {VALID_KINDS}")
        if self.kind == "histogram" and not (self.bins > 0
                                             and self.hi > self.lo):
            raise ValueError(f"histogram {self.name!r} needs hi > lo, bins > 0")
        if self.kind in ("quantile", "windowed_quantile"):
            if not self.qs:
                raise ValueError(f"{self.kind} {self.name!r} needs qs")
            object.__setattr__(self, "qs", tuple(float(q) for q in self.qs))
        if self.kind == "windowed_quantile" and int(self.window) < 1:
            raise ValueError(f"windowed_quantile {self.name!r} needs "
                             f"window >= 1, got {self.window}")
        if self.kind in ("heavy_hitters", "decayed_heavy_hitters") \
                and self.width & (self.width - 1):
            raise ValueError(f"{self.kind} {self.name!r} width must be 2^n")
        if self.kind == "decayed_heavy_hitters" \
                and not 0.0 < float(self.decay) < 1.0:
            raise ValueError(f"decayed_heavy_hitters {self.name!r} needs "
                             f"decay in (0, 1), got {self.decay}")

    @property
    def out_width(self) -> int:
        """Slots this query occupies in the plan's flat answer vector."""
        return {"sum": 1, "count": 1, "mean": 1, "histogram": self.bins,
                "quantile": len(self.qs), "heavy_hitters": 2 * self.k,
                "windowed_quantile": len(self.qs),
                "decayed_heavy_hitters": 2 * self.k,
                }[self.kind]


class QueryRegistry:
    """Ordered collection of standing queries (insertion order = answer
    layout order)."""

    def __init__(self, specs: list[QuerySpec] | None = None):
        self._specs: dict[str, QuerySpec] = {}
        for sp in specs or []:
            self.register(sp)

    def register(self, spec: QuerySpec) -> "QueryRegistry":
        if spec.name in self._specs:
            raise ValueError(f"query {spec.name!r} already registered")
        self._specs[spec.name] = spec
        return self

    # Convenience constructors — chainable.
    def register_sum(self, name: str = "sum"):
        return self.register(QuerySpec(name, "sum"))

    def register_count(self, name: str = "count"):
        return self.register(QuerySpec(name, "count"))

    def register_mean(self, name: str = "mean"):
        return self.register(QuerySpec(name, "mean"))

    def register_histogram(self, name: str, lo: float, hi: float,
                           bins: int = 32):
        return self.register(QuerySpec(name, "histogram", lo=lo, hi=hi,
                                       bins=bins))

    def register_quantile(self, name: str, qs, capacity: int = 256):
        return self.register(QuerySpec(name, "quantile", qs=tuple(qs),
                                       capacity=capacity))

    def register_heavy_hitters(self, name: str, k: int = 8,
                               width: int = 1024, depth: int = 4):
        return self.register(QuerySpec(name, "heavy_hitters", k=k,
                                       width=width, depth=depth))

    def register_windowed_quantile(self, name: str, qs, capacity: int = 256,
                                   window: int = 8):
        """Quantiles over the last ``window`` root windows — the serve
        plane's "last N minutes" answer (a stream-so-far ``quantile``
        never forgets old data)."""
        return self.register(QuerySpec(name, "windowed_quantile",
                                       qs=tuple(qs), capacity=capacity,
                                       window=window))

    def register_decayed_heavy_hitters(self, name: str, k: int = 8,
                                       width: int = 1024, depth: int = 4,
                                       decay: float = 0.9):
        """Top-k over an exponentially decayed stream (``decay`` per root
        window) — recent heavy hitters instead of all-time ones."""
        return self.register(QuerySpec(name, "decayed_heavy_hitters", k=k,
                                       width=width, depth=depth,
                                       decay=decay))

    @property
    def specs(self) -> tuple[QuerySpec, ...]:
        return tuple(self._specs.values())

    def __len__(self) -> int:
        return len(self._specs)

    def __contains__(self, name: str) -> bool:
        return name in self._specs

    def compile(self, num_strata: int):
        """Fuse every registered query into one batched evaluation plan."""
        from repro.query import compiler

        return compiler.CompiledQueryPlan(self.specs, num_strata)

    def shape_signature(self) -> tuple[QuerySpec, ...]:
        """Name-free signature (names canonicalized to ``q0, q1, ...``).
        Tenants whose registries share a signature evaluate as rows of
        ONE vmapped slot group in the slotted tenant plan — admitting
        another such tenant reuses the traced program instead of
        compiling a new one."""
        from repro.query import compiler

        return compiler.canonical_signature(self.specs)

    def as_tenant(self, name: str):
        """Wrap this registry as one ``repro.api`` pipeline tenant: N
        tenants' registries share one tree (a single batched root
        evaluation per window) with per-tenant answer routing."""
        from repro.api.spec import TenantSpec

        return TenantSpec.from_registry(name, self)

    @classmethod
    def from_tokens(cls, tokens: str) -> "QueryRegistry":
        """Parse the CLI mini-language: comma-separated query tokens.

            sum | count | mean
            hist:<lo>:<hi>:<bins>
            q:<q1>:<q2>:...          (quantile sketch)
            hh[:<k>]                 (heavy hitters)
            wq:<q1>:<q2>:...         (windowed quantile, window 8)
            dhh[:<k>[:<decay>]]      (decayed heavy hitters)

        e.g. ``--queries sum,count,mean,hist:0:100:32,q:0.5:0.9:0.99,hh,
        wq:0.5:0.99,dhh:4:0.9``
        """
        reg = cls()
        for tok in (t.strip() for t in tokens.split(",") if t.strip()):
            parts = tok.split(":")
            head = parts[0]
            try:
                if head in ("sum", "count", "mean"):
                    reg.register(QuerySpec(_unique(reg, head), head))
                elif head == "hist":
                    lo, hi = float(parts[1]), float(parts[2])
                    bins = int(parts[3]) if len(parts) > 3 else 32
                    reg.register_histogram(_unique(reg, "hist"), lo, hi, bins)
                elif head == "q":
                    qs = tuple(float(p) for p in parts[1:])
                    reg.register_quantile(_unique(reg, "quantile"), qs)
                elif head == "hh":
                    k = int(parts[1]) if len(parts) > 1 else 8
                    reg.register_heavy_hitters(_unique(reg, "hh"), k=k)
                elif head == "wq":
                    qs = tuple(float(p) for p in parts[1:])
                    reg.register_windowed_quantile(_unique(reg, "wq"), qs)
                elif head == "dhh":
                    k = int(parts[1]) if len(parts) > 1 else 8
                    decay = float(parts[2]) if len(parts) > 2 else 0.9
                    reg.register_decayed_heavy_hitters(
                        _unique(reg, "dhh"), k=k, decay=decay)
                else:
                    raise ValueError(f"unknown query token {tok!r}")
            except (IndexError, ValueError) as e:
                if isinstance(e, ValueError) and "query token" in str(e):
                    raise
                raise ValueError(
                    f"malformed query token {tok!r} "
                    f"(expected e.g. hist:<lo>:<hi>[:<bins>], "
                    f"q:<q1>[:<q2>...], hh[:<k>], wq:<q1>[:<q2>...], "
                    f"dhh[:<k>[:<decay>]]): {e}") from e
        return reg


def _unique(reg: QueryRegistry, base: str) -> str:
    if base not in reg:
        return base
    i = 2
    while f"{base}{i}" in reg:
        i += 1
    return f"{base}{i}"

"""Mergeable sketch state for standing queries — pure-JAX pytrees.

Both sketches are fixed-shape pytrees, so they ride along ``TreeState``
as donated device-resident leaves inside the scan engine's epoch
dispatch and update once per window with no host round-trip. Both are
*mergeable*: folding a batch in is the same operation as folding another
sketch's summary in, which is what lets one edge sample answer many
standing queries (and many tenants share one sketch pipeline).

``QuantileSketch`` — a KLL-style compactor collapsed to one weighted
buffer of ``C`` summary points. An update merges the current summary
with the (weighted) batch, sorts by value, and — when over capacity —
compacts back to ``C`` points at randomized equi-weight rank targets
``t_k = (k + u)·W/C``, each re-weighted to ``W/C``. The randomized
offset ``u`` makes every compaction's rank perturbation zero-mean
(KLL's core trick), so errors across compactions accumulate as a random
walk, not linearly: rank error ≈ √(#compactions)/C. While the total
weight still fits in ``C`` points the summary is exact.

``HeavyHitterSketch`` — a weighted count-min sketch (``depth × width``,
multiply-shift hashing) plus a tracked top-``k`` candidate set. Batch
update: fold the batch into the counts (one ``cms_update`` kernel pass),
re-estimate all candidates (old top-k ∪ batch keys) against the fresh
counts, dedupe, and keep the best ``k``. Estimates only over-count
(collisions), by at most ``(2/width)·W`` per the standard CM bound.

Merge algebra (the §III-E distributed query plane rests on this): both
sketches close under ``merge`` — ``quantile_merge`` folds one summary's
weighted buffer into another (one compaction when over capacity, both
histories' compaction counts ride along in the bound), and ``hh_merge``
adds the linear CM tables and re-merges the top-k candidate union
against the merged counts. The ``*_stacked`` variants take a leading
stack axis (exactly what ``jax.lax.all_gather`` of per-device state
produces under ``shard_map``) and merge N summaries with ONE compaction
/ one candidate refresh, so the pod-scale path ships O(sketch) bytes
per window — never a reservoir. Properties (associativity/commutativity
up to answer equivalence, identity, merge ≡ concatenated stream) are
pinned in ``tests/test_sketch_merge.py``.

Heavy inner passes route through ``kernels.sketch_update.ops`` (Pallas
on TPU, jnp oracle elsewhere).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.sketch_update import ops as sk_ops
from repro.kernels.sketch_update.ref import hash_buckets

HH_EMPTY_KEY = jnp.int32(2**31 - 1)   # sentinel: unoccupied top-k slot


# --------------------------------------------------------------- quantile --
class QuantileSketch(NamedTuple):
    """``value``/``weight`` f32[C]; weight 0 marks an empty slot. Slots are
    kept value-sorted (empty slots may interleave; they carry no mass).
    ``compactions`` f32[] counts lossy compaction steps — it drives the
    reported rank-error bound (``rank_error_bound``), which a lossless
    (under-capacity) summary keeps at exactly 0."""

    value: jnp.ndarray
    weight: jnp.ndarray
    compactions: jnp.ndarray

    @property
    def capacity(self) -> int:
        return self.value.shape[0]

    @property
    def total_weight(self) -> jnp.ndarray:
        return jnp.sum(self.weight)

    @property
    def rank_error_bound(self) -> jnp.ndarray:
        """Current ±2σ rank-error bound (fraction of total weight).

        One compaction perturbs any rank by at most one weight quantum
        ``W/C`` with a zero-mean randomized sign; over ``U`` compactions
        the perturbations random-walk, so ±2σ ≈ ``2·√U/C`` — tracked
        live, so the bound stays honest for arbitrarily long streams."""
        return jnp.where(
            self.compactions > 0.0,
            2.0 * jnp.sqrt(jnp.maximum(self.compactions, 1.0))
            / self.capacity,
            0.0)


def quantile_init(capacity: int) -> QuantileSketch:
    return QuantileSketch(value=jnp.zeros((capacity,), jnp.float32),
                          weight=jnp.zeros((capacity,), jnp.float32),
                          compactions=jnp.zeros((), jnp.float32))


def quantile_rank_error_bound(capacity: int, max_updates: int = 64) -> float:
    """Static planning bound: the rank error a ``capacity`` sketch stays
    within across ``max_updates`` compactions (2·√U/C — see
    ``QuantileSketch.rank_error_bound`` for the live per-window value).
    Validated empirically in ``benchmarks/fig8_accuracy.py``."""
    return 2.0 * math.sqrt(float(max_updates)) / float(capacity)


def quantile_update(key: jax.Array, sk: QuantileSketch, values: jnp.ndarray,
                    weights: jnp.ndarray, *, impl: str = "auto"
                    ) -> QuantileSketch:
    """Fold a weighted batch (weight 0 = excluded item) into the summary."""
    cap = sk.capacity
    v = jnp.concatenate([sk.value, values])
    w = jnp.concatenate([sk.weight, jnp.maximum(weights, 0.0)])
    order = jnp.argsort(v)
    v_s, w_s = v[order], w[order]
    cumw = jnp.cumsum(w_s)
    total = cumw[-1]
    n_live = jnp.sum(w_s > 0.0)

    def exact():
        # Everything fits: pack live slots to the front (stable, so the
        # value ordering survives) — the summary is lossless.
        pack = jnp.argsort(jnp.where(w_s > 0.0, 0, 1), stable=True)
        return v_s[pack][:cap], w_s[pack][:cap], sk.compactions

    def compact():
        u = jax.random.uniform(key, ())
        t = (jnp.arange(cap, dtype=jnp.float32) + u) * (total / cap)
        cumw_prev = jnp.concatenate([jnp.zeros((1,), jnp.float32), cumw[:-1]])
        picked = sk_ops.quantile_compact(v_s, cumw_prev, cumw, t, impl=impl)
        # f32 rounding can push the last target(s) to >= total; rank-W is
        # the max live value by definition.
        vmax = jnp.max(jnp.where(w_s > 0.0, v_s, -jnp.inf))
        picked = jnp.where(t >= total, vmax, picked)
        return (picked, jnp.full((cap,), total / cap, jnp.float32),
                sk.compactions + 1.0)

    value, weight, compactions = jax.lax.cond(n_live <= cap, exact, compact)
    return QuantileSketch(value=value, weight=weight,
                          compactions=compactions)


def quantile_query(sk: QuantileSketch, qs: jnp.ndarray) -> jnp.ndarray:
    """f32[len(qs)] value estimates at quantiles ``qs`` (each in [0, 1])."""
    order = jnp.argsort(sk.value)
    v_s, w_s = sk.value[order], sk.weight[order]
    cumw = jnp.cumsum(w_s)
    total = cumw[-1]
    t = jnp.clip(qs, 0.0, 1.0) * total
    # first live slot with cumw > t; q == 1.0 maps to the max live value
    idx = jnp.searchsorted(cumw, t, side="right")
    vmax = jnp.max(jnp.where(w_s > 0.0, v_s, -jnp.inf))
    out = jnp.where(idx < sk.capacity, v_s[jnp.minimum(idx, sk.capacity - 1)],
                    vmax)
    return jnp.where(total > 0.0, out, 0.0)


def quantile_merge(key: jax.Array, a: QuantileSketch, b: QuantileSketch,
                   *, impl: str = "auto") -> QuantileSketch:
    """Merge two summaries into one of ``a``'s capacity.

    Folding ``b``'s weighted buffer into ``a`` is the same operation as
    folding a batch in (mergeability by construction); ``b``'s compaction
    history is added so the merged ``rank_error_bound`` stays honest
    (rank errors of the two histories random-walk independently — summing
    the counts upper-bounds the merged variance)."""
    out = quantile_update(key, a._replace(compactions=a.compactions
                                          + b.compactions),
                          b.value, b.weight, impl=impl)
    return out


def quantile_merge_stacked(key: jax.Array, stacked: QuantileSketch,
                           *, impl: str = "auto") -> QuantileSketch:
    """Merge ``N`` stacked summaries (leaves ``[N, ...]`` — the layout an
    ``all_gather`` of per-device state produces) with ONE compaction.

    Equivalent to a left fold of :func:`quantile_merge` up to answer
    equivalence, but the single compaction adds one rank perturbation
    instead of ``N − 1``, so the merged bound is tighter."""
    cap = stacked.value.shape[-1]
    base = QuantileSketch(value=jnp.zeros((cap,), jnp.float32),
                          weight=jnp.zeros((cap,), jnp.float32),
                          compactions=jnp.sum(stacked.compactions))
    return quantile_update(key, base, stacked.value.reshape(-1),
                           stacked.weight.reshape(-1), impl=impl)


# ---------------------------------------------------------- heavy hitters --
class HeavyHitterSketch(NamedTuple):
    """``counts`` f32[depth, width] weighted count-min state;
    ``key`` i32[k] / ``est`` f32[k] the tracked top-k candidates
    (``HH_EMPTY_KEY`` marks an unoccupied slot)."""

    counts: jnp.ndarray
    key: jnp.ndarray
    est: jnp.ndarray

    @property
    def depth(self) -> int:
        return self.counts.shape[0]

    @property
    def width(self) -> int:
        return self.counts.shape[1]

    @property
    def total_weight(self) -> jnp.ndarray:
        return jnp.sum(self.counts[0])


def hh_init(k: int, width: int, depth: int) -> HeavyHitterSketch:
    return HeavyHitterSketch(
        counts=jnp.zeros((depth, width), jnp.float32),
        key=jnp.full((k,), HH_EMPTY_KEY, jnp.int32),
        est=jnp.zeros((k,), jnp.float32),
    )


def hh_error_bound(width: int, total_weight: jnp.ndarray) -> jnp.ndarray:
    """CM over-count bound: est − true ≤ (2/width)·W w.h.p. (1 − 2^-depth)."""
    return (2.0 / float(width)) * total_weight


def hh_point_estimate(sk: HeavyHitterSketch, keys: jnp.ndarray) -> jnp.ndarray:
    """f32[M] count-min estimates (min over depth rows) for ``keys``."""
    buckets = hash_buckets(keys, sk.depth, sk.width)           # [D, M]
    per_row = jnp.take_along_axis(sk.counts, buckets, axis=1)  # [D, M]
    return jnp.min(per_row, axis=0)


def _refresh_topk(counts: jnp.ndarray, cand_key: jnp.ndarray,
                  k_slots: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Re-estimate a candidate-key pool against a CM table and keep the
    best ``k_slots``: dedupe by sorting (duplicates share one CM
    estimate, so which survives is irrelevant), then top-k by estimate.
    Shared by the batch update and the merge paths — the "top-k
    re-merge" is exactly a refresh over the union of candidate sets."""
    fresh = HeavyHitterSketch(counts=counts,
                              key=jnp.zeros((0,), jnp.int32),
                              est=jnp.zeros((0,), jnp.float32))
    cand_est = jnp.where(cand_key == HH_EMPTY_KEY, -1.0,
                         hh_point_estimate(fresh, cand_key))
    order = jnp.argsort(cand_key)
    ks, es = cand_key[order], cand_est[order]
    first = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    score = jnp.where(first & (ks != HH_EMPTY_KEY), es, -1.0)
    top_est, top_ix = jax.lax.top_k(score, k_slots)
    occupied = top_est >= 0.0
    return (jnp.where(occupied, ks[top_ix], HH_EMPTY_KEY),
            jnp.maximum(top_est, 0.0))


def hh_update(sk: HeavyHitterSketch, keys: jnp.ndarray,
              weights: jnp.ndarray, *, impl: str = "auto"
              ) -> HeavyHitterSketch:
    """Fold a weighted key batch in and refresh the top-k candidate set."""
    k_slots = sk.key.shape[0]
    w = jnp.maximum(weights, 0.0)
    delta = sk_ops.cms_update(keys.astype(jnp.uint32), w, sk.depth, sk.width,
                              impl=impl)
    counts = sk.counts + delta
    cand_key = jnp.concatenate(
        [sk.key, jnp.where(w > 0.0, keys, HH_EMPTY_KEY)])
    key_out, est_out = _refresh_topk(counts, cand_key, k_slots)
    return HeavyHitterSketch(counts=counts, key=key_out, est=est_out)


def hh_merge(a: HeavyHitterSketch, b: HeavyHitterSketch) -> HeavyHitterSketch:
    """Merge two sketches: CM tables are linear (counts add exactly —
    the merged table equals one table fed the concatenated stream), and
    the top-k re-merges as a candidate refresh over both key sets
    against the merged counts."""
    counts = a.counts + b.counts
    cand_key = jnp.concatenate([a.key, b.key])
    key_out, est_out = _refresh_topk(counts, cand_key, a.key.shape[0])
    return HeavyHitterSketch(counts=counts, key=key_out, est=est_out)


def hh_merge_stacked(stacked: HeavyHitterSketch) -> HeavyHitterSketch:
    """Merge ``N`` stacked sketches (leaves ``[N, ...]``, e.g. from an
    ``all_gather`` of per-device state) with one candidate refresh."""
    counts = jnp.sum(stacked.counts, axis=0)
    cand_key = stacked.key.reshape(-1)
    key_out, est_out = _refresh_topk(counts, cand_key,
                                     stacked.key.shape[-1])
    return HeavyHitterSketch(counts=counts, key=key_out, est=est_out)


def hh_item_key(values: jnp.ndarray) -> jnp.ndarray:
    """Default item→key map for value streams: round to the nearest int.

    IoT heavy hitters are "which readings dominate the stream"; rounding
    buckets the f32 payload into integer keys. Pipelines with a real key
    column should pass it directly to ``hh_update`` instead.
    """
    return jnp.round(values).astype(jnp.int32)

"""Mergeable sketch state for standing queries — pure-JAX pytrees.

Both sketches are fixed-shape pytrees, so they ride along ``TreeState``
as donated device-resident leaves inside the scan engine's epoch
dispatch and update once per window with no host round-trip. Both are
*mergeable*: folding a batch in is the same operation as folding another
sketch's summary in, which is what lets one edge sample answer many
standing queries (and many tenants share one sketch pipeline).

``QuantileSketch`` — a true multi-level KLL compactor: ``L`` weighted
buffers of ``C`` points each (``kll_schedule``). A batch enters level 0;
any level that overflows its capacity compacts its buffer at randomized
equi-weight rank targets ``t_k = (k + u)·W/m`` and pushes the ``m = C/2``
survivors (weight ``W/m`` each) up one level, so heavy quanta live only
in the rarely-compacted top buffer (which compacts in place to ``C``
points). The randomized offset ``u`` makes every compaction's rank
perturbation zero-mean (KLL's core trick), so perturbations random-walk:
the sketch tracks ``err_q2 = Σ quantum²`` across its history and reports
``rank_error_bound = 2·√(err_q2)/W`` — each level-``h`` quantum covers
only that level's buffer weight, which is why the leveled bound beats
the collapsed single-buffer ``2·√U/C`` on long streams. While a level's
live points fit in ``C`` slots its fold is lossless, so a stream that
never exceeds level 0 is summarised exactly.

``HeavyHitterSketch`` — a weighted count-min sketch (``depth × width``,
multiply-shift hashing) plus a tracked top-``k`` candidate set. Batch
update: fold the batch into the counts (one ``cms_update`` kernel pass),
re-estimate all candidates (old top-k ∪ batch keys) against the fresh
counts, dedupe, and keep the best ``k``. Estimates only over-count
(collisions), by at most ``(2/width)·W`` per the standard CM bound.

Windowed / decayed variants (the serve plane's "last N minutes, not
stream-so-far" answers):

``WindowedQuantileSketch`` — a ring of ``R`` KLL sub-sketches, one per
root window. Each update writes a FRESH sub-sketch into the head slot
(evicting the slot written ``R`` windows ago) and advances the head, so
the ring always holds exactly the last ``R`` windows' summaries. A query
merges the ring through ``quantile_merge_stacked`` — one compaction
pass over all ``R`` slots — and answers from the merged summary with
its honest rank-error bound. Batches that fit the sub-sketch capacity
are summarised losslessly per window, so the only rank error is the
query-time merge's.

``hh_decayed_update`` — exponential decay on the SAME
``HeavyHitterSketch`` state: ``counts ← γ·counts + batch``, so an item
seen ``t`` windows ago contributes ``γ^t`` of its weight and the top-k
tracks the *recent* heavy hitters. Decay commutes with the linear CM
merge (``γ(A+B)+a+b = (γA+a)+(γB+b)``), so the distributed ``psum``
merge path is unchanged; the CM bound applies with the decayed total
weight ``Σ γ^t·W_t``.

Merge algebra (the §III-E distributed query plane rests on this): both
sketches close under ``merge`` — ``quantile_merge`` folds one summary's
weighted buffer into another (one compaction when over capacity, both
histories' compaction counts ride along in the bound), and ``hh_merge``
adds the linear CM tables and re-merges the top-k candidate union
against the merged counts. The ``*_stacked`` variants take a leading
stack axis (exactly what ``jax.lax.all_gather`` of per-device state
produces under ``shard_map``) and merge N summaries with ONE compaction
/ one candidate refresh, so the pod-scale path ships O(sketch) bytes
per window — never a reservoir. Properties (associativity/commutativity
up to answer equivalence, identity, merge ≡ concatenated stream) are
pinned in ``tests/test_sketch_merge.py``.

Heavy inner passes route through ``kernels.sketch_update.ops`` (Pallas
on TPU, jnp oracle elsewhere).
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.sketch_update import ops as sk_ops
from repro.kernels.sketch_update.ref import hash_buckets

HH_EMPTY_KEY = jnp.int32(2**31 - 1)   # sentinel: unoccupied top-k slot


# --------------------------------------------------------------- quantile --
class QuantileSketch(NamedTuple):
    """``value``/``weight`` f32[L, C]: ``L`` level buffers of ``C`` slots
    (``kll_schedule``); weight 0 marks an empty slot. Each level's live
    slots are kept value-sorted and packed to the front. ``compactions``
    f32[] counts lossy compaction steps; ``err_q2`` f32[] accumulates the
    squared weight quantum of each — together they drive the reported
    rank-error bound (``rank_error_bound``), which a lossless (never
    overflowed) summary keeps at exactly 0."""

    value: jnp.ndarray
    weight: jnp.ndarray
    compactions: jnp.ndarray
    err_q2: jnp.ndarray

    @property
    def levels(self) -> int:
        return self.value.shape[0]

    @property
    def capacity(self) -> int:
        return self.value.shape[-1]

    @property
    def total_weight(self) -> jnp.ndarray:
        return jnp.sum(self.weight)

    @property
    def rank_error_bound(self) -> jnp.ndarray:
        """Current ±2σ rank-error bound (fraction of total weight).

        A compaction at buffer weight ``W_buf`` perturbs any rank by at
        most one weight quantum ``q = W_buf/m`` with a zero-mean
        randomized sign; independent perturbations random-walk, so
        ±2σ = ``2·√(Σ q²)/W``. Level-``h`` quanta cover only that level's
        buffer weight — far below total ``W`` for long streams — so this
        is strictly tighter than the collapsed one-buffer ``2·√U/C``
        whenever any compaction ran below the top level."""
        return jnp.where(
            self.compactions > 0.0,
            2.0 * jnp.sqrt(self.err_q2)
            / jnp.maximum(self.total_weight, 1e-30),
            0.0)


def kll_schedule(capacity: int) -> tuple[int, ...]:
    """Per-level slot capacities for a ``capacity``-point sketch.

    Uniform ``C`` slots per level: level 0 must hold ``capacity`` points
    so the ≤-capacity stream stays exact (`quantile_init`'s lossless
    contract), and equal upper levels keep every fold's argsort the same
    cost. Depth grows with capacity — tiny sketches don't benefit from
    levels they can never fill."""
    if capacity < 16:
        levels = 1
    elif capacity < 64:
        levels = 2
    else:
        levels = 4
    return (capacity,) * levels


def quantile_init(capacity: int) -> QuantileSketch:
    levels = len(kll_schedule(capacity))
    return QuantileSketch(value=jnp.zeros((levels, capacity), jnp.float32),
                          weight=jnp.zeros((levels, capacity), jnp.float32),
                          compactions=jnp.zeros((), jnp.float32),
                          err_q2=jnp.zeros((), jnp.float32))


def quantile_rank_error_bound(capacity: int, max_updates: int = 64) -> float:
    """Static planning bound: the rank error a ``capacity`` sketch stays
    within across ``max_updates`` batch folds, for any batch size.

    Runs the leveled schedule's weight bookkeeping on the host (no data,
    just per-level counts/weights) and takes the worst ``2·√(Σq²)/W``
    over a batch-size grid spanning under- to over-capacity batches —
    the quantum sum is monotone in how often low levels spill, which the
    grid's extremes bracket. Strictly tighter than the old collapsed
    ``2·√U/C`` whenever the schedule has >1 level. Validated empirically
    in ``tests/test_query_plane.py`` / ``benchmarks/fig8_accuracy.py``."""
    ks = kll_schedule(capacity)
    top = len(ks) - 1
    worst = 0.0
    for batch in sorted({max(capacity // 4, 1), capacity, 4 * capacity}):
        n = [0.0] * len(ks)
        w = [0.0] * len(ks)
        var = 0.0
        for _ in range(int(max_updates)):
            cv, cw = float(batch), float(batch)
            for h, k in enumerate(ks):
                n[h] += cv
                w[h] += cw
                if n[h] <= k:
                    break
                m = k if h == top else k // 2
                q = w[h] / m
                var += q * q
                if h == top:
                    n[h] = float(k)   # in-place compact, no spill
                    break
                cv, cw = float(m), w[h]
                n[h] = 0.0
                w[h] = 0.0
        total = float(max_updates) * float(batch)
        worst = max(worst, 2.0 * math.sqrt(var) / total)
    return worst


def _fold_level(key: jax.Array, lvl_v: jnp.ndarray, lvl_w: jnp.ndarray,
                add_v: jnp.ndarray, add_w: jnp.ndarray, *, m_up: int,
                impl: str):
    """Fold extra weighted points into one ``C``-slot level buffer.

    Returns ``(value[C], weight[C], carry_v[m_up], carry_w[m_up],
    did_compact, q2)``. While the live points fit, the fold is lossless
    (stable value-sorted live-first pack) and the carry is empty. On
    overflow the buffer compacts at randomized equi-weight rank targets:
    ``m_up > 0`` pushes the ``m_up`` survivors up as the carry and empties
    the level; ``m_up == 0`` (top level) compacts in place to ``C``
    points. ``q2`` is the squared weight quantum of the compaction."""
    cap = lvl_v.shape[0]
    m = cap if m_up == 0 else m_up
    v = jnp.concatenate([lvl_v, add_v])
    w = jnp.concatenate([lvl_w, jnp.maximum(add_w, 0.0)])
    order = jnp.argsort(v)
    v_s, w_s = v[order], w[order]
    cumw = jnp.cumsum(w_s)
    total = cumw[-1]
    n_live = jnp.sum(w_s > 0.0)
    zero_carry = jnp.zeros((m_up,), jnp.float32)
    zero = jnp.zeros((), jnp.float32)

    def exact():
        # Everything fits: pack live slots to the front (stable, so the
        # value ordering survives) — the fold is lossless.
        pack = jnp.argsort(jnp.where(w_s > 0.0, 0, 1), stable=True)
        return (v_s[pack][:cap], w_s[pack][:cap], zero_carry, zero_carry,
                zero, zero)

    def compact():
        u = jax.random.uniform(key, ())
        q = total / m
        t = (jnp.arange(m, dtype=jnp.float32) + u) * q
        cumw_prev = jnp.concatenate([jnp.zeros((1,), jnp.float32),
                                     cumw[:-1]])
        picked = sk_ops.quantile_compact(v_s, cumw_prev, cumw, t, impl=impl)
        # f32 rounding can push the last target(s) to >= total; rank-W is
        # the max live value by definition.
        vmax = jnp.max(jnp.where(w_s > 0.0, v_s, -jnp.inf))
        picked = jnp.where(t >= total, vmax, picked)
        pw = jnp.full((m,), q, jnp.float32)
        one = jnp.ones((), jnp.float32)
        if m_up == 0:
            return (picked, pw, zero_carry, zero_carry, one, q * q)
        return (jnp.zeros((cap,), jnp.float32),
                jnp.zeros((cap,), jnp.float32), picked, pw, one, q * q)

    return jax.lax.cond(n_live <= cap, exact, compact)


def _fold_all(key: jax.Array, sk: QuantileSketch, incoming, *, impl: str
              ) -> QuantileSketch:
    """Cascade a per-level list of extra ``(value, weight)`` buffers (or
    ``None``) through the sketch, carrying each level's spill up."""
    levels, cap = sk.value.shape
    carry_v = jnp.zeros((0,), jnp.float32)
    carry_w = jnp.zeros((0,), jnp.float32)
    comp, err = sk.compactions, sk.err_q2
    rows_v, rows_w = [], []
    for h in range(levels):
        add_v, add_w = [carry_v], [carry_w]
        if incoming[h] is not None:
            add_v.append(incoming[h][0])
            add_w.append(incoming[h][1])
        m_up = 0 if h == levels - 1 else cap // 2
        nv, nw, carry_v, carry_w, did, q2 = _fold_level(
            jax.random.fold_in(key, h), sk.value[h], sk.weight[h],
            jnp.concatenate(add_v), jnp.concatenate(add_w),
            m_up=m_up, impl=impl)
        rows_v.append(nv)
        rows_w.append(nw)
        comp = comp + did
        err = err + q2
    return QuantileSketch(value=jnp.stack(rows_v), weight=jnp.stack(rows_w),
                          compactions=comp, err_q2=err)


@functools.partial(jax.jit, static_argnames=("impl",))
def quantile_update(key: jax.Array, sk: QuantileSketch, values: jnp.ndarray,
                    weights: jnp.ndarray, *, impl: str = "auto"
                    ) -> QuantileSketch:
    """Fold a weighted batch (weight 0 = excluded item) into the summary.

    The batch enters level 0; overflow cascades up the schedule, one
    (possible) compaction per level."""
    incoming = [(values, weights)] + [None] * (sk.levels - 1)
    return _fold_all(key, sk, incoming, impl=impl)


@jax.jit
def quantile_query(sk: QuantileSketch, qs: jnp.ndarray) -> jnp.ndarray:
    """f32[len(qs)] value estimates at quantiles ``qs`` (each in [0, 1]).

    All levels answer together: the flattened ``[L·C]`` weighted point
    set is one summary — level only matters for *where compaction error
    entered*, not for querying."""
    flat_v = sk.value.reshape(-1)
    flat_w = sk.weight.reshape(-1)
    order = jnp.argsort(flat_v)
    v_s, w_s = flat_v[order], flat_w[order]
    cumw = jnp.cumsum(w_s)
    total = cumw[-1]
    t = jnp.clip(qs, 0.0, 1.0) * total
    # first live slot with cumw > t; q == 1.0 maps to the max live value
    n = flat_v.shape[0]
    idx = jnp.searchsorted(cumw, t, side="right")
    vmax = jnp.max(jnp.where(w_s > 0.0, v_s, -jnp.inf))
    out = jnp.where(idx < n, v_s[jnp.minimum(idx, n - 1)], vmax)
    return jnp.where(total > 0.0, out, 0.0)


@functools.partial(jax.jit, static_argnames=("impl",))
def quantile_merge(key: jax.Array, a: QuantileSketch, b: QuantileSketch,
                   *, impl: str = "auto") -> QuantileSketch:
    """Merge two summaries into one with ``a``'s schedule.

    Same-schedule sketches merge level-wise — level-``h`` points carry
    level-``h`` quanta, so keeping them at their level preserves the
    leveled error accounting (mergeability by construction: each level
    fold is the batch-fold operation). A ``b`` with a different schedule
    flattens into level 0 like a batch. Both histories' ``compactions``
    and ``err_q2`` are added so the merged ``rank_error_bound`` stays
    honest (the two histories' rank errors random-walk independently —
    summing the variances upper-bounds the merged variance)."""
    base = a._replace(compactions=a.compactions + b.compactions,
                      err_q2=a.err_q2 + b.err_q2)
    if b.value.shape == a.value.shape:
        incoming = [(b.value[h], b.weight[h]) for h in range(a.levels)]
    else:
        incoming = ([(b.value.reshape(-1), b.weight.reshape(-1))]
                    + [None] * (a.levels - 1))
    return _fold_all(key, base, incoming, impl=impl)


@functools.partial(jax.jit, static_argnames=("impl",))
def quantile_merge_stacked(key: jax.Array, stacked: QuantileSketch,
                           *, impl: str = "auto") -> QuantileSketch:
    """Merge ``N`` stacked summaries (leaves ``[N, ...]`` — the layout an
    ``all_gather`` of per-device state produces) in one level-wise pass.

    Equivalent to a left fold of :func:`quantile_merge` up to answer
    equivalence, but each level compacts at most once for the whole
    merge (≤ ``L`` rank perturbations instead of up to ``L·(N − 1)``),
    so the merged bound is tighter."""
    levels, cap = stacked.value.shape[-2:]
    base = QuantileSketch(
        value=jnp.zeros((levels, cap), jnp.float32),
        weight=jnp.zeros((levels, cap), jnp.float32),
        compactions=jnp.sum(stacked.compactions),
        err_q2=jnp.sum(stacked.err_q2))
    incoming = [(stacked.value[..., h, :].reshape(-1),
                 stacked.weight[..., h, :].reshape(-1))
                for h in range(levels)]
    return _fold_all(key, base, incoming, impl=impl)


# ------------------------------------------------- windowed quantiles --
class WindowedQuantileSketch(NamedTuple):
    """Ring of ``R`` per-window KLL sub-sketches: ``value``/``weight``
    f32[R, L, C], ``compactions``/``err_q2`` f32[R] (per-slot histories),
    ``head`` i32[] — the next slot to overwrite. Slot ``head`` holds the
    oldest window; a query over the ring covers exactly the last ``R``
    updates."""

    value: jnp.ndarray
    weight: jnp.ndarray
    compactions: jnp.ndarray
    err_q2: jnp.ndarray
    head: jnp.ndarray

    @property
    def window(self) -> int:
        return self.value.shape[0]

    @property
    def capacity(self) -> int:
        return self.value.shape[-1]


def windowed_quantile_init(capacity: int, window: int
                           ) -> WindowedQuantileSketch:
    levels = len(kll_schedule(capacity))
    r = int(window)
    return WindowedQuantileSketch(
        value=jnp.zeros((r, levels, capacity), jnp.float32),
        weight=jnp.zeros((r, levels, capacity), jnp.float32),
        compactions=jnp.zeros((r,), jnp.float32),
        err_q2=jnp.zeros((r,), jnp.float32),
        head=jnp.zeros((), jnp.int32))


@functools.partial(jax.jit, static_argnames=("impl",))
def windowed_quantile_update(key: jax.Array, sk: WindowedQuantileSketch,
                             values: jnp.ndarray, weights: jnp.ndarray, *,
                             impl: str = "auto") -> WindowedQuantileSketch:
    """Summarise ONE window's weighted batch into the head slot (fresh
    sub-sketch — the slot's previous window falls out of scope) and
    advance the ring. A batch that fits the sub-sketch capacity is
    summarised exactly (the lossless fold contract)."""
    levels, cap = sk.value.shape[-2:]
    sub = QuantileSketch(value=jnp.zeros((levels, cap), jnp.float32),
                         weight=jnp.zeros((levels, cap), jnp.float32),
                         compactions=jnp.zeros((), jnp.float32),
                         err_q2=jnp.zeros((), jnp.float32))
    sub = quantile_update(key, sub, values, weights, impl=impl)
    i = sk.head
    return WindowedQuantileSketch(
        value=sk.value.at[i].set(sub.value),
        weight=sk.weight.at[i].set(sub.weight),
        compactions=sk.compactions.at[i].set(sub.compactions),
        err_q2=sk.err_q2.at[i].set(sub.err_q2),
        head=(i + 1) % sk.window)


@functools.partial(jax.jit, static_argnames=("impl",))
def windowed_quantile_merged(key: jax.Array, sk: WindowedQuantileSketch, *,
                             impl: str = "auto") -> QuantileSketch:
    """Merge the ring's live slots into one query-time summary — exactly
    ``quantile_merge_stacked`` over the ``[R, ...]`` stacked sub-sketches
    (empty slots carry zero weight and zero error history, so a not-yet-
    filled ring answers from the windows it has)."""
    stacked = QuantileSketch(value=sk.value, weight=sk.weight,
                             compactions=sk.compactions, err_q2=sk.err_q2)
    return quantile_merge_stacked(key, stacked, impl=impl)


# ---------------------------------------------------------- heavy hitters --
class HeavyHitterSketch(NamedTuple):
    """``counts`` f32[depth, width] weighted count-min state;
    ``key`` i32[k] / ``est`` f32[k] the tracked top-k candidates
    (``HH_EMPTY_KEY`` marks an unoccupied slot)."""

    counts: jnp.ndarray
    key: jnp.ndarray
    est: jnp.ndarray

    @property
    def depth(self) -> int:
        return self.counts.shape[0]

    @property
    def width(self) -> int:
        return self.counts.shape[1]

    @property
    def total_weight(self) -> jnp.ndarray:
        return jnp.sum(self.counts[0])


def hh_init(k: int, width: int, depth: int) -> HeavyHitterSketch:
    return HeavyHitterSketch(
        counts=jnp.zeros((depth, width), jnp.float32),
        key=jnp.full((k,), HH_EMPTY_KEY, jnp.int32),
        est=jnp.zeros((k,), jnp.float32),
    )


def hh_error_bound(width: int, total_weight: jnp.ndarray) -> jnp.ndarray:
    """CM over-count bound: est − true ≤ (2/width)·W w.h.p. (1 − 2^-depth)."""
    return (2.0 / float(width)) * total_weight


def hh_point_estimate(sk: HeavyHitterSketch, keys: jnp.ndarray) -> jnp.ndarray:
    """f32[M] count-min estimates (min over depth rows) for ``keys``."""
    buckets = hash_buckets(keys, sk.depth, sk.width)           # [D, M]
    per_row = jnp.take_along_axis(sk.counts, buckets, axis=1)  # [D, M]
    return jnp.min(per_row, axis=0)


def _refresh_topk(counts: jnp.ndarray, cand_key: jnp.ndarray,
                  k_slots: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Re-estimate a candidate-key pool against a CM table and keep the
    best ``k_slots``: dedupe by sorting (duplicates share one CM
    estimate, so which survives is irrelevant), then top-k by estimate.
    Shared by the batch update and the merge paths — the "top-k
    re-merge" is exactly a refresh over the union of candidate sets."""
    fresh = HeavyHitterSketch(counts=counts,
                              key=jnp.zeros((0,), jnp.int32),
                              est=jnp.zeros((0,), jnp.float32))
    cand_est = jnp.where(cand_key == HH_EMPTY_KEY, -1.0,
                         hh_point_estimate(fresh, cand_key))
    order = jnp.argsort(cand_key)
    ks, es = cand_key[order], cand_est[order]
    first = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    score = jnp.where(first & (ks != HH_EMPTY_KEY), es, -1.0)
    top_est, top_ix = jax.lax.top_k(score, k_slots)
    occupied = top_est >= 0.0
    return (jnp.where(occupied, ks[top_ix], HH_EMPTY_KEY),
            jnp.maximum(top_est, 0.0))


def hh_update(sk: HeavyHitterSketch, keys: jnp.ndarray,
              weights: jnp.ndarray, *, impl: str = "auto"
              ) -> HeavyHitterSketch:
    """Fold a weighted key batch in and refresh the top-k candidate set."""
    k_slots = sk.key.shape[0]
    w = jnp.maximum(weights, 0.0)
    delta = sk_ops.cms_update(keys.astype(jnp.uint32), w, sk.depth, sk.width,
                              impl=impl)
    counts = sk.counts + delta
    cand_key = jnp.concatenate(
        [sk.key, jnp.where(w > 0.0, keys, HH_EMPTY_KEY)])
    key_out, est_out = _refresh_topk(counts, cand_key, k_slots)
    return HeavyHitterSketch(counts=counts, key=key_out, est=est_out)


def hh_decayed_update(sk: HeavyHitterSketch, keys: jnp.ndarray,
                      weights: jnp.ndarray, decay: float, *,
                      impl: str = "auto") -> HeavyHitterSketch:
    """Fold one window's weighted key batch into an exponentially decayed
    CM table: ``counts ← decay·counts + batch``, then refresh the top-k
    against the decayed counts. An item seen ``t`` windows ago weighs
    ``decay^t``, so the candidate set tracks the RECENT heavy hitters —
    a long-retired key's estimate shrinks geometrically until a current
    key overtakes it. Decay is linear, so the distributed psum merge of
    per-device tables stays exact (each device decays its own shard)."""
    k_slots = sk.key.shape[0]
    w = jnp.maximum(weights, 0.0)
    delta = sk_ops.cms_update(keys.astype(jnp.uint32), w, sk.depth, sk.width,
                              impl=impl)
    counts = jnp.float32(decay) * sk.counts + delta
    cand_key = jnp.concatenate(
        [sk.key, jnp.where(w > 0.0, keys, HH_EMPTY_KEY)])
    key_out, est_out = _refresh_topk(counts, cand_key, k_slots)
    return HeavyHitterSketch(counts=counts, key=key_out, est=est_out)


def hh_merge(a: HeavyHitterSketch, b: HeavyHitterSketch) -> HeavyHitterSketch:
    """Merge two sketches: CM tables are linear (counts add exactly —
    the merged table equals one table fed the concatenated stream), and
    the top-k re-merges as a candidate refresh over both key sets
    against the merged counts."""
    counts = a.counts + b.counts
    cand_key = jnp.concatenate([a.key, b.key])
    key_out, est_out = _refresh_topk(counts, cand_key, a.key.shape[0])
    return HeavyHitterSketch(counts=counts, key=key_out, est=est_out)


def hh_merge_stacked(stacked: HeavyHitterSketch) -> HeavyHitterSketch:
    """Merge ``N`` stacked sketches (leaves ``[N, ...]``, e.g. from an
    ``all_gather`` of per-device state) with one candidate refresh."""
    counts = jnp.sum(stacked.counts, axis=0)
    cand_key = stacked.key.reshape(-1)
    key_out, est_out = _refresh_topk(counts, cand_key,
                                     stacked.key.shape[-1])
    return HeavyHitterSketch(counts=counts, key=key_out, est=est_out)


def hh_item_key(values: jnp.ndarray) -> jnp.ndarray:
    """Default item→key map for value streams: round to the nearest int.

    IoT heavy hitters are "which readings dominate the stream"; rounding
    buckets the f32 payload into integer keys. Pipelines with a real key
    column should pass it directly to ``hh_update`` instead.
    """
    return jnp.round(values).astype(jnp.int32)

"""Query-plan compiler: fuse K standing queries into one root evaluation.

``CompiledQueryPlan`` turns a tuple of ``QuerySpec``s into three pure
functions the tree engines call at the root, *inside* the jitted tick:

* ``init_state()``   — sketch state pytree (one entry per spec; ``()``
  for stateless CLT queries). Joins ``TreeState`` as donated
  device-resident leaves under the scan engine.
* ``evaluate(key, batch, res, state)`` — answers every registered query
  from ONE window sample: a single shared ``stratum_moments`` pass feeds
  all CLT queries (sum/count/mean), histograms do one bin-scatter each,
  and sketch queries fold the window into their state and answer from
  it. Returns ``(state', answers f32[n_out], bounds f32[n_out])`` — a
  flat, statically-laid-out answer vector, so the scan engine stacks T
  windows of answers into one ``[T, n_out]`` epoch output with zero
  host round-trips.
* ``exact_answers(values, strata)`` — host-side (NumPy) ground truth in
  the same layout, for accuracy benchmarks.

The evaluation draws NO randomness from the sampler's key stream — the
quantile compactor's offset comes from a ``fold_in`` side-branch — so
registering queries leaves every sample and every reservoir state
bit-identical to a run with no queries registered (asserted in
``tests/test_query_plane.py``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import error as err
from repro.core.types import IntervalBatch, SampleResult
from repro.query import sketches
from repro.query.registry import QuerySpec

# fold_in tag separating the query plane's PRNG stream from the sampler's
_QUERY_KEY_TAG = 0x51C7
# fold_in tag for the replicated cross-device merge randomness (SPMD path)
_MERGE_KEY_TAG = 0x4D52
# fold_in tag for the windowed-quantile ring's query-time merge randomness
# (a side branch of the per-query key, so the ring update stream is
# untouched by how often the ring is queried)
_WINDOW_MERGE_TAG = 0x574D


def stratum_stats(batch: IntervalBatch, num_strata: int):
    """Pre-sampling per-stratum ``(count, mean, std)`` of one window,
    from the same shared ``stratum_moments`` pass that feeds the CLT
    queries. This is the query-plane variance signal for the adaptive
    stratification plane (``repro.strata``): occupancy says where the
    arrivals go, std says which strata actually need rows. ``neyman``
    allocation recomputes the identical moments where the batch lives —
    ``core.sampling.stratum_stds`` in XLA, a one-hot ``dot_general``
    inside the fused Pallas tick — so the two views agree bitwise on
    the same window (pinned in ``tests/test_strata.py``)."""
    y, s1, s2 = err.stratum_moments(batch.value, batch.stratum,
                                    batch.valid, num_strata)
    safe = jnp.maximum(y, 1.0)
    mean = s1 / safe
    var = jnp.maximum(s2 / safe - mean * mean, 0.0)
    return y, mean, jnp.sqrt(var)


class CompiledQueryPlan:
    """Static, jit-closable fusion of K specs. All array work is pure."""

    def __init__(self, specs: tuple[QuerySpec, ...], num_strata: int):
        if not specs:
            raise ValueError("cannot compile an empty query registry")
        self.specs = tuple(specs)
        self.num_strata = int(num_strata)
        off = 0
        self._layout: dict[str, tuple[int, int, str]] = {}
        for sp in self.specs:
            self._layout[sp.name] = (off, sp.out_width, sp.kind)
            off += sp.out_width
        self.n_out = off

    @property
    def k(self) -> int:
        return len(self.specs)

    def layout(self) -> dict[str, tuple[int, int, str]]:
        """name → (offset, width, kind) into the flat answer vector."""
        return dict(self._layout)

    def answer(self, vec: np.ndarray, name: str) -> np.ndarray:
        """Slice one query's answers out of a flat (host) answer vector."""
        o, w, _ = self._layout[name]
        return np.asarray(vec)[..., o:o + w]

    def init_state(self) -> tuple:
        state = []
        for sp in self.specs:
            if sp.kind == "quantile":
                state.append(sketches.quantile_init(sp.capacity))
            elif sp.kind in ("heavy_hitters", "decayed_heavy_hitters"):
                state.append(sketches.hh_init(sp.k, sp.width, sp.depth))
            elif sp.kind == "windowed_quantile":
                state.append(sketches.windowed_quantile_init(sp.capacity,
                                                             sp.window))
            else:
                state.append(())
        return tuple(state)

    # ------------------------------------------------------------- eval --
    def evaluate(self, key: jax.Array, batch: IntervalBatch,
                 res: SampleResult, state: tuple) -> tuple:
        """(state', answers f32[n_out], bounds f32[n_out]) for one window."""
        x = self.num_strata
        sel = res.selected
        w_item = res.meta.weight[batch.stratum] * sel.astype(jnp.float32)
        # ONE moments pass shared by every CLT query (the fusion win: the
        # seed evaluated each query with its own segment-sum sweep).
        y, s1, s2 = err.stratum_moments(batch.value, batch.stratum, sel, x)

        outs, bnds, new_state = [], [], []
        for i, sp in enumerate(self.specs):
            kq = jax.random.fold_in(jax.random.fold_in(key, _QUERY_KEY_TAG), i)
            st = state[i]
            if sp.kind == "sum":
                q = err.approx_sum_from_moments(y, s1, s2, res.meta)
                a, b, st2 = q.estimate[None], q.bound(2.0)[None], ()
            elif sp.kind == "count":
                # HT count is exact per stratum given the metadata
                # (every item's indicator is 1): variance 0.
                a = jnp.sum(y * res.meta.weight)[None]
                b, st2 = jnp.zeros((1,), jnp.float32), ()
            elif sp.kind == "mean":
                q = err.approx_mean_from_moments(y, s1, s2, res.meta)
                a, b, st2 = q.estimate[None], q.bound(2.0)[None], ()
            elif sp.kind == "histogram":
                from repro.core import queries as Q

                edges = jnp.linspace(sp.lo, sp.hi, sp.bins + 1)
                q = Q.weighted_histogram(batch, res, x, edges)
                a, b, st2 = q.estimate, q.bound(2.0), ()
            elif sp.kind == "quantile":
                st2 = sketches.quantile_update(kq, st, batch.value, w_item)
                a = sketches.quantile_query(st2, jnp.asarray(sp.qs))
                # live bound: 2·√(Σ quantum²)/W over the leveled
                # compaction history — honest for arbitrarily long
                # standing-query streams, and tighter than the collapsed
                # 2·√U/C because low-level quanta stay small.
                b = jnp.full((len(sp.qs),), 1.0) * st2.rank_error_bound
            elif sp.kind == "heavy_hitters":
                keys = sketches.hh_item_key(batch.value)
                st2 = sketches.hh_update(st, keys, w_item)
                eps_w = sketches.hh_error_bound(sp.width, st2.total_weight)
                a = jnp.concatenate([st2.key.astype(jnp.float32), st2.est])
                b = jnp.concatenate([jnp.zeros((sp.k,), jnp.float32),
                                     jnp.full((sp.k,), 1.0) * eps_w])
            elif sp.kind == "windowed_quantile":
                # one window → one ring slot; the query-time merge over
                # the last `window` slots answers "last N windows", which
                # a stream-so-far quantile sketch cannot.
                st2 = sketches.windowed_quantile_update(kq, st, batch.value,
                                                        w_item)
                km = jax.random.fold_in(kq, _WINDOW_MERGE_TAG)
                merged = sketches.windowed_quantile_merged(km, st2)
                a = sketches.quantile_query(merged, jnp.asarray(sp.qs))
                b = jnp.full((len(sp.qs),), 1.0) * merged.rank_error_bound
            elif sp.kind == "decayed_heavy_hitters":
                keys = sketches.hh_item_key(batch.value)
                st2 = sketches.hh_decayed_update(st, keys, w_item, sp.decay)
                eps_w = sketches.hh_error_bound(sp.width, st2.total_weight)
                a = jnp.concatenate([st2.key.astype(jnp.float32), st2.est])
                b = jnp.concatenate([jnp.zeros((sp.k,), jnp.float32),
                                     jnp.full((sp.k,), 1.0) * eps_w])
            else:  # pragma: no cover — registry validates kinds
                raise AssertionError(sp.kind)
            outs.append(a.astype(jnp.float32))
            bnds.append(b.astype(jnp.float32))
            new_state.append(st2)
        return tuple(new_state), jnp.concatenate(outs), jnp.concatenate(bnds)

    # ------------------------------------------------------------- spmd --
    def evaluate_spmd(self, key: jax.Array, batch: IntervalBatch,
                      res: SampleResult, state: tuple,
                      axis_name: str) -> tuple:
        """Distributed ``evaluate`` under ``shard_map``: every device
        holds one shard of the window (``batch``/``res`` are its local
        sample) plus its own sketch ``state``; the answers come from
        MERGED per-device summaries, and only those summaries —
        O(sketch) bytes — cross the device boundary:

        * CLT queries: per-device (estimate, variance) from the local
          moments pass, ``psum``-merged (independent local samples sum
          in estimate and variance; the mean re-weights by each shard's
          population share). ``count`` merges the *pre-sampling* stratum
          counts ``Σ C_i·W^in_i`` — the same quantity the HT count
          reconstructs, but summed as exact integers, so the merged
          answer is bitwise-identical across device counts.
        * histograms: per-bin HT estimate/variance, ``psum``-merged
          (linear queries merge exactly).
        * sketches: the local state updates from the local sample (own
          PRNG side-branch per device), then the per-device summaries
          all-gather and merge in-graph (``quantile_merge_stacked`` /
          ``hh_merge_stacked``) with REPLICATED merge randomness, so
          every device answers from the identical merged summary.

        ``key`` must be replicated across ``axis_name``. Returns
        ``(state', answers, bounds)`` with ``state'`` device-local and
        answers/bounds replicated in value (the caller re-types them
        with a ``pmean``, see ``core.tree.spmd_query_plane_tick``)."""
        x = self.num_strata
        sel = res.selected
        w_item = res.meta.weight[batch.stratum] * sel.astype(jnp.float32)
        y, s1, s2 = err.stratum_moments(batch.value, batch.stratum, sel, x)
        psum = lambda v: jax.lax.psum(v, axis_name)
        dev = jax.lax.axis_index(axis_name)
        # Each shard's estimated source population (Σ c_src) — the mean's
        # merge weight: MEAN over the union is the share-weighted mean.
        total_local = jnp.sum(y * res.meta.weight)
        total = jnp.maximum(psum(total_local), 1.0)
        share = total_local / total

        outs, bnds, new_state = [], [], []
        for i, sp in enumerate(self.specs):
            kq = jax.random.fold_in(jax.random.fold_in(key, _QUERY_KEY_TAG), i)
            kq_local = jax.random.fold_in(kq, dev)
            kq_merge = jax.random.fold_in(kq, _MERGE_KEY_TAG)
            st = state[i]
            if sp.kind == "sum":
                q = err.approx_sum_from_moments(y, s1, s2, res.meta)
                a = psum(q.estimate)[None]
                b, st2 = 2.0 * jnp.sqrt(psum(q.variance))[None], ()
            elif sp.kind == "count":
                # Exact by construction: C_i·W^in_i needs no sample, and
                # integer f32 sums are associative — N-device ≡ 1-device
                # to the bit (the harness' "exact queries" property).
                a = psum(jnp.sum(res.c * batch.meta.weight))[None]
                b, st2 = jnp.zeros((1,), jnp.float32), ()
            elif sp.kind == "mean":
                q = err.approx_mean_from_moments(y, s1, s2, res.meta)
                a = psum(q.estimate * share)[None]
                b = 2.0 * jnp.sqrt(psum(q.variance * share * share))[None]
                st2 = ()
            elif sp.kind == "histogram":
                from repro.core import queries as Q

                edges = jnp.linspace(sp.lo, sp.hi, sp.bins + 1)
                q = Q.weighted_histogram(batch, res, x, edges)
                a = psum(q.estimate)
                b, st2 = 2.0 * jnp.sqrt(psum(q.variance)), ()
            elif sp.kind == "quantile":
                st2 = sketches.quantile_update(kq_local, st, batch.value,
                                               w_item)
                g = jax.tree.map(lambda v: jax.lax.all_gather(v, axis_name),
                                 st2)
                merged = sketches.quantile_merge_stacked(kq_merge, g)
                a = sketches.quantile_query(merged, jnp.asarray(sp.qs))
                b = jnp.full((len(sp.qs),), 1.0) * merged.rank_error_bound
            elif sp.kind == "heavy_hitters":
                keys = sketches.hh_item_key(batch.value)
                st2 = sketches.hh_update(st, keys, w_item)
                # counts are linear: psum ≡ gather-then-sum, at 1/N the
                # gather bytes; only the k-slot candidate keys gather.
                g_counts = jax.lax.psum(st2.counts, axis_name)
                g_keys = jax.lax.all_gather(st2.key, axis_name, tiled=True)
                mk, me = sketches._refresh_topk(g_counts, g_keys, sp.k)
                eps_w = sketches.hh_error_bound(sp.width,
                                                jnp.sum(g_counts[0]))
                a = jnp.concatenate([mk.astype(jnp.float32), me])
                b = jnp.concatenate([jnp.zeros((sp.k,), jnp.float32),
                                     jnp.full((sp.k,), 1.0) * eps_w])
            elif sp.kind == "windowed_quantile":
                st2 = sketches.windowed_quantile_update(kq_local, st,
                                                        batch.value, w_item)
                # all-gather the per-device rings and flatten device×slot
                # into one stacked axis — one merge pass answers over the
                # union of every device's last `window` sub-sketches.
                gv = jax.lax.all_gather(st2.value, axis_name)
                gw = jax.lax.all_gather(st2.weight, axis_name)
                gc = jax.lax.all_gather(st2.compactions, axis_name)
                ge = jax.lax.all_gather(st2.err_q2, axis_name)
                stacked = sketches.QuantileSketch(
                    value=gv.reshape((-1,) + gv.shape[-2:]),
                    weight=gw.reshape((-1,) + gw.shape[-2:]),
                    compactions=gc.reshape(-1),
                    err_q2=ge.reshape(-1))
                km = jax.random.fold_in(kq_merge, _WINDOW_MERGE_TAG)
                merged = sketches.quantile_merge_stacked(km, stacked)
                a = sketches.quantile_query(merged, jnp.asarray(sp.qs))
                b = jnp.full((len(sp.qs),), 1.0) * merged.rank_error_bound
            elif sp.kind == "decayed_heavy_hitters":
                # decay is linear, so psum of per-device decayed tables
                # equals the decayed global table: γ(ΣA_i) + Σa_i.
                keys = sketches.hh_item_key(batch.value)
                st2 = sketches.hh_decayed_update(st, keys, w_item, sp.decay)
                g_counts = jax.lax.psum(st2.counts, axis_name)
                g_keys = jax.lax.all_gather(st2.key, axis_name, tiled=True)
                mk, me = sketches._refresh_topk(g_counts, g_keys, sp.k)
                eps_w = sketches.hh_error_bound(sp.width,
                                                jnp.sum(g_counts[0]))
                a = jnp.concatenate([mk.astype(jnp.float32), me])
                b = jnp.concatenate([jnp.zeros((sp.k,), jnp.float32),
                                     jnp.full((sp.k,), 1.0) * eps_w])
            else:  # pragma: no cover — registry validates kinds
                raise AssertionError(sp.kind)
            outs.append(a.astype(jnp.float32))
            bnds.append(b.astype(jnp.float32))
            new_state.append(st2)
        return tuple(new_state), jnp.concatenate(outs), jnp.concatenate(bnds)

    # ------------------------------------------------------ ground truth --
    def exact_answers(self, values: np.ndarray,
                      strata: np.ndarray | None = None) -> np.ndarray:
        """Host-side exact answers over the full stream, layout-aligned.

        Windowed CLT queries aggregate over the whole stream (their
        per-window estimates are summed/averaged the same way by the
        caller). Sketch slots need care:

        * ``quantile`` slots hold the exact ``inverted_cdf`` order
          statistics — the same "first value whose rank exceeds q·W"
          rule the sketch answers with. Compare in RANK space (measure
          the sketch value's rank on the stream, as fig8 does): value-
          space differences are density-sensitive and can be large in
          flat regions even at zero rank error.
        * ``heavy_hitters`` slots are NaN: the sketch reports *its own*
          candidate keys, so a slot-for-slot diff against the true
          top-k is meaningless — get per-key truth from the raw stream
          (``np.round(values)`` counts), keyed by the sketch's keys.
        """
        values = np.asarray(values, np.float64)
        out = np.zeros((self.n_out,), np.float64)
        for sp in self.specs:
            o, w, _ = self._layout[sp.name]
            if sp.kind == "sum":
                out[o] = values.sum()
            elif sp.kind == "count":
                out[o] = len(values)
            elif sp.kind == "mean":
                out[o] = values.mean() if len(values) else 0.0
            elif sp.kind == "histogram":
                edges = np.linspace(sp.lo, sp.hi, sp.bins + 1)
                ix = np.clip(np.searchsorted(edges, values, side="right") - 1,
                             0, sp.bins - 1)
                out[o:o + w] = np.bincount(ix, minlength=sp.bins)
            elif sp.kind == "quantile":
                out[o:o + w] = np.quantile(values, np.asarray(sp.qs),
                                           method="inverted_cdf")
            elif sp.kind in ("heavy_hitters", "decayed_heavy_hitters",
                             "windowed_quantile"):
                # sketch-relative answers: hh slots report the sketch's
                # own candidate keys, and the windowed/decayed variants
                # answer over the RECENT stream — a full-stream exact
                # value is the wrong ground truth for all three. Slice
                # the recent stream (or per-key counts) on the host when
                # truth is needed.
                out[o:o + w] = np.nan
        return out


class MultiTenantPlan:
    """K tenants' query registries fused into ONE batched root evaluation.

    Each tenant keeps its own ``CompiledQueryPlan`` (so its PRNG stream,
    sketch state, and answers are bit-identical to a single-tenant run of
    the same registry), but all plans evaluate inside the SAME traced root
    step from the SAME window sample — N tenants share one tree dispatch
    per epoch. The flat answer vector is the tenants' vectors concatenated
    in registration order; ``tenant_slice``/``answer`` route per-tenant
    views back out, and ``layout()`` exposes ``"tenant/query"``-prefixed
    names so shared consumers (error-budget feedback, dashboards) can
    attribute every slot to its tenant.

    Duck-types ``CompiledQueryPlan`` (``evaluate``/``init_state``/
    ``n_out``/``layout``/``answer``), so every engine — scan tick,
    level/loop root steps — accepts it unchanged.
    """

    def __init__(self, tenants, num_strata: int):
        """``tenants``: ordered ``(name, (QuerySpec, ...))`` pairs."""
        tenants = tuple((str(n), tuple(specs)) for n, specs in tenants)
        if not tenants:
            raise ValueError("cannot compile an empty tenant list")
        names = [n for n, _ in tenants]
        if len(set(names)) != len(names):
            dup = sorted({n for n in names if names.count(n) > 1})
            raise ValueError(f"duplicate tenant names: {dup}")
        self.tenant_names = tuple(names)
        self.num_strata = int(num_strata)
        self.plans = tuple(CompiledQueryPlan(specs, num_strata)
                           for _, specs in tenants)
        self._offsets = {}
        off = 0
        for name, plan in zip(self.tenant_names, self.plans):
            self._offsets[name] = off
            off += plan.n_out
        self.n_out = off

    @property
    def k(self) -> int:
        return sum(p.k for p in self.plans)

    def plan_for(self, tenant: str) -> CompiledQueryPlan:
        if tenant not in self._offsets:
            raise KeyError(f"unknown tenant {tenant!r}; "
                           f"registered: {list(self.tenant_names)}")
        return self.plans[self.tenant_names.index(tenant)]

    def tenant_slice(self, tenant: str) -> tuple[int, int]:
        """(offset, width) of one tenant's block in the flat vector."""
        return self._offsets[tenant], self.plan_for(tenant).n_out

    def layout(self) -> dict[str, tuple[int, int, str]]:
        """``"tenant/query"`` → (absolute offset, width, kind)."""
        out = {}
        for name, plan in zip(self.tenant_names, self.plans):
            base = self._offsets[name]
            for q, (o, w, kind) in plan.layout().items():
                out[f"{name}/{q}"] = (base + o, w, kind)
        return out

    def answer(self, vec: np.ndarray, name: str) -> np.ndarray:
        """Slice one ``"tenant/query"`` answer out of a flat vector."""
        o, w, _ = self.layout()[name]
        return np.asarray(vec)[..., o:o + w]

    def tenant_answers(self, vec: np.ndarray, tenant: str) -> np.ndarray:
        o, w = self.tenant_slice(tenant)
        return np.asarray(vec)[..., o:o + w]

    def init_state(self) -> tuple:
        return tuple(p.init_state() for p in self.plans)

    def evaluate(self, key: jax.Array, batch: IntervalBatch,
                 res: SampleResult, state: tuple) -> tuple:
        """One fused evaluation for all tenants. Every tenant plan gets
        the SAME key — exactly what a single-tenant run would pass — so
        each tenant's answers/bounds/sketch state bit-match an isolated
        run of its registry on the same sample."""
        states, outs, bnds = [], [], []
        for plan, st in zip(self.plans, state):
            st2, a, b = plan.evaluate(key, batch, res, st)
            states.append(st2)
            outs.append(a)
            bnds.append(b)
        return (tuple(states), jnp.concatenate(outs), jnp.concatenate(bnds))

    def evaluate_spmd(self, key: jax.Array, batch: IntervalBatch,
                      res: SampleResult, state: tuple,
                      axis_name: str) -> tuple:
        """Distributed fused evaluation for all tenants (one batched root
        over the merged summaries — see ``CompiledQueryPlan.
        evaluate_spmd``). Every tenant plan gets the SAME replicated key,
        mirroring the local ``evaluate``, so each tenant's merged answers
        match an isolated single-tenant SPMD run of its registry."""
        states, outs, bnds = [], [], []
        for plan, st in zip(self.plans, state):
            st2, a, b = plan.evaluate_spmd(key, batch, res, st, axis_name)
            states.append(st2)
            outs.append(a)
            bnds.append(b)
        return (tuple(states), jnp.concatenate(outs), jnp.concatenate(bnds))

    def exact_answers(self, values: np.ndarray,
                      strata: np.ndarray | None = None) -> np.ndarray:
        return np.concatenate([p.exact_answers(values, strata)
                               for p in self.plans])


def slot_bucket(n: int) -> int:
    """Smallest power-of-two slot count ≥ n. Buckets are what keep the
    compile count flat under churn: a group only retraces when its LIVE
    tenant count crosses a power of two, so an 8→10k admit sweep costs
    ⌈log2(10k/8)⌉+1 = 12 distinct traces, not 10k. No floor: small
    deployments pay zero padding (a 1-tenant group vmaps over 1 slot, so
    per-window compute and cross-device summary bytes match the unslotted
    plan exactly); padding waste is bounded at <2x live tenants at every
    scale."""
    n = max(int(n), 1)
    b = 1
    while b < n:
        b *= 2
    return b


def canonical_signature(specs) -> tuple[QuerySpec, ...]:
    """Name-free shape signature of a registry: the specs with names
    canonicalized to ``q0, q1, ...``. Two tenants share a signature iff
    their registries are identical up to query names — exactly the
    condition under which their root evaluations are the same traced
    program and can share one vmapped slot group."""
    return tuple(dataclasses.replace(sp, name=f"q{i}")
                 for i, sp in enumerate(specs))


class SlotPlanCore:
    """The TRACED half of the slotted tenant plan: per shape-signature
    group, one canonical template ``CompiledQueryPlan`` evaluated via
    ``jax.vmap`` over ``n_slots`` stacked sketch-state rows plus a
    per-slot active mask. Tenant NAMES never enter this object — routing
    lives on the cheap host-side ``SlottedTenantPlan`` wrapper — so one
    core (and one trace of everything closing over it) serves every
    pipeline whose live set maps onto the same (signature, bucket)s.

    Masking semantics (all verified bitwise): an active slot's answers
    and state updates are untouched by ``jnp.where(True, new, old)``;
    an inactive slot freezes at its current state and answers zeros.
    ``vmap`` row evaluation is bitwise row-position-independent, so a
    slot's answers don't depend on which slot it is, what the other
    slots hold, or the bucket size — the foundation of the
    churn ≡ fresh-compile equivalence law.

    The vmap is also the perf story: batch/res/key are unbatched, so
    the shared ``stratum_moments`` pass (and every other slot-
    independent intermediate) is computed ONCE per window; the per-slot
    marginal cost is just the answer assembly + sketch fold."""

    def __init__(self, groups, num_strata: int):
        """``groups``: ordered ``(canonical_specs, n_slots)`` pairs."""
        self.num_strata = int(num_strata)
        self.groups = tuple((CompiledQueryPlan(sig, num_strata), int(n))
                            for sig, n in groups)
        self._offsets = []
        off = 0
        for tmpl, n in self.groups:
            self._offsets.append(off)
            off += n * tmpl.n_out
        self.n_out = off

    def group_offset(self, gi: int) -> int:
        return self._offsets[gi]

    def init_state(self) -> tuple:
        """All slots inactive, all rows at the template's init state."""
        out = []
        for tmpl, n in self.groups:
            row = tmpl.init_state()
            stacked = jax.tree.map(
                lambda v: jnp.broadcast_to(v, (n,) + v.shape).copy(), row)
            out.append((jnp.zeros((n,), bool), stacked))
        return tuple(out)

    def _eval(self, key, batch, res, state, eval_one):
        states, outs, bnds = [], [], []
        for (tmpl, _n), (mask, st) in zip(self.groups, state):
            def row(m_, s_, tmpl=tmpl):
                s2, a, b = eval_one(tmpl, key, batch, res, s_)
                a = jnp.where(m_, a, 0.0)
                b = jnp.where(m_, b, 0.0)
                s2 = jax.tree.map(lambda nw, old: jnp.where(m_, nw, old),
                                  s2, s_)
                return s2, a, b
            s2, a, b = jax.vmap(row)(mask, st)
            states.append((mask, s2))
            outs.append(a.reshape(-1))
            bnds.append(b.reshape(-1))
        return tuple(states), jnp.concatenate(outs), jnp.concatenate(bnds)

    def evaluate(self, key: jax.Array, batch: IntervalBatch,
                 res: SampleResult, state: tuple) -> tuple:
        return self._eval(key, batch, res, state,
                          lambda p, k, b, r, s: p.evaluate(k, b, r, s))

    def evaluate_spmd(self, key: jax.Array, batch: IntervalBatch,
                      res: SampleResult, state: tuple,
                      axis_name: str) -> tuple:
        # collectives-under-vmap: psum/all_gather batch fine inside
        # shard_map, so the mesh path vmaps over slots identically.
        return self._eval(
            key, batch, res, state,
            lambda p, k, b, r, s: p.evaluate_spmd(k, b, r, s, axis_name))


# Canonical SlotPlanCore per (num_strata, ((signature, n_slots), ...)) —
# THE size-bucketed plan cache. Everything traced (tick fns, epoch fns,
# SPMD epoch fns) closes over the core object, so a cache hit here means
# jit cache hits everywhere downstream: admitting tenant #513 into an
# existing 1024-bucket reuses the 1024-bucket programs verbatim.
_CORE_CACHE: dict = {}
_CORE_STATS = {"builds": 0, "hits": 0}


def slot_plan_core(groups, num_strata: int) -> SlotPlanCore:
    key = (int(num_strata), tuple((tuple(sig), int(n)) for sig, n in groups))
    core = _CORE_CACHE.get(key)
    if core is None:
        core = SlotPlanCore(groups, num_strata)
        _CORE_CACHE[key] = core
        _CORE_STATS["builds"] += 1
    else:
        _CORE_STATS["hits"] += 1
    return core


def plan_cache_stats() -> dict:
    """{"builds": distinct traced plan shapes, "hits": cache reuses}."""
    return dict(_CORE_STATS)


class SlottedTenantPlan:
    """Host-side routing wrapper over a cached ``SlotPlanCore``: maps
    live tenant names to (group, slot) and answers layout/slicing
    queries. Construction is cheap (no tracing) and instances are
    IMMUTABLE — ``admit``/``retire`` return a new wrapper plus a pure
    qstate transform, never touching the shared core.

    Two answer-vector coordinate systems meet here. The TRACED programs
    produce the PADDED vector (``core.n_out`` — every slot, inactive
    ones zero); the PUBLIC vector is compacted to the live tenants'
    blocks in admission order (``n_out``, ``layout()``,
    ``tenant_slice`` — bit-for-bit the pre-slot ``MultiTenantPlan``
    layout, so every consumer reads it unchanged). ``compact(arr)``
    maps padded → public with one eager gather at the host boundary —
    OUTSIDE the jit, so churn moves the gather columns without
    retracing anything.

    Duck-types the plan protocol (``evaluate``/``evaluate_spmd``/
    ``init_state``/``layout``/``answer``/``tenant_slice``), so every
    engine and every ``MultiTenantPlan`` consumer accepts it unchanged.
    With a single live tenant, ``layout()`` uses plain query names
    (PR 4 behavior); with several, ``"tenant/query"``."""

    def __init__(self, core: SlotPlanCore, entries):
        """``entries``: ordered ``(name, specs, group_idx, slot_idx)``."""
        self.core = core
        self.entries = tuple(entries)
        self.num_strata = core.num_strata
        self.tenant_names = tuple(e[0] for e in self.entries)
        if len(set(self.tenant_names)) != len(self.tenant_names):
            ns = list(self.tenant_names)
            dup = sorted({n for n in ns if ns.count(n) > 1})
            raise ValueError(f"duplicate tenant names: {dup}")
        self._by_name = {e[0]: e for e in self.entries}
        self._plan_cache: dict = {}
        self._slices = {}
        off = 0
        for name, _, gi, _si in self.entries:
            w = core.groups[gi][0].n_out
            self._slices[name] = (off, w)
            off += w
        self.n_out = off            # PUBLIC (compacted) width
        self._cols = None           # lazy padded→public column map

    @property
    def k(self) -> int:
        return sum(len(e[1]) for e in self.entries)

    @property
    def plans(self) -> tuple:
        """Per-live-tenant template plans (host-side views)."""
        return tuple(self.plan_for(t) for t in self.tenant_names)

    def plan_for(self, tenant: str) -> CompiledQueryPlan:
        if tenant not in self._by_name:
            raise KeyError(f"unknown tenant {tenant!r}; "
                           f"registered: {list(self.tenant_names)}")
        if tenant not in self._plan_cache:
            self._plan_cache[tenant] = CompiledQueryPlan(
                self._by_name[tenant][1], self.num_strata)
        return self._plan_cache[tenant]

    def padded_slice(self, tenant: str) -> tuple[int, int]:
        """(offset, width) of one tenant's slot block in the PADDED
        (traced) answer vector."""
        _, _, gi, si = self._by_name[tenant]
        tmpl, _n = self.core.groups[gi]
        return self.core.group_offset(gi) + si * tmpl.n_out, tmpl.n_out

    def tenant_slice(self, tenant: str) -> tuple[int, int]:
        """(offset, width) of one tenant's block in the flat PUBLIC
        (compacted) answer vector — live blocks in admission order."""
        if tenant not in self._slices:
            raise KeyError(f"unknown tenant {tenant!r}; "
                           f"registered: {list(self.tenant_names)}")
        return self._slices[tenant]

    def live_columns(self) -> np.ndarray:
        """Padded-vector column index of every public-vector slot."""
        if self._cols is None:
            cols = []
            for name in self.tenant_names:
                o, w = self.padded_slice(name)
                cols.extend(range(o, o + w))
            self._cols = np.asarray(cols, np.int32)
        return self._cols

    def compact(self, arr):
        """Gather a padded answers/bounds array down to the public
        (live-tenant) vector along the last axis. Eager — never traced,
        so the column map follows churn with zero retraces."""
        if arr is None:
            return None
        return arr[..., self.live_columns()]

    def layout(self) -> dict[str, tuple[int, int, str]]:
        out = {}
        single = len(self.tenant_names) == 1
        for name in self.tenant_names:
            base, _ = self.tenant_slice(name)
            for q, (o, w, kind) in self.plan_for(name).layout().items():
                label = q if single else f"{name}/{q}"
                out[label] = (base + o, w, kind)
        return out

    def answer(self, vec: np.ndarray, name: str) -> np.ndarray:
        o, w, _ = self.layout()[name]
        return np.asarray(vec)[..., o:o + w]

    def tenant_answers(self, vec: np.ndarray, tenant: str) -> np.ndarray:
        o, w = self.tenant_slice(tenant)
        return np.asarray(vec)[..., o:o + w]

    def init_state(self) -> tuple:
        """Core init state with this wrapper's live slots activated."""
        state = list(self.core.init_state())
        for _, _, gi, si in self.entries:
            mask, st = state[gi]
            state[gi] = (mask.at[si].set(True), st)
        return tuple(state)

    def evaluate(self, key, batch, res, state):
        return self.core.evaluate(key, batch, res, state)

    def evaluate_spmd(self, key, batch, res, state, axis_name):
        return self.core.evaluate_spmd(key, batch, res, state, axis_name)

    def exact_answers(self, values, strata=None) -> np.ndarray:
        """Host-side exact answers in the PUBLIC (compacted) layout."""
        return np.concatenate([self.plan_for(t).exact_answers(values, strata)
                               for t in self.tenant_names])

    # ------------------------------------------------------- manifest --
    def slot_manifest(self) -> dict:
        """JSON-able description of the slot configuration — what the
        checkpoint manifest records so a restore into a differently-
        churned pipeline fails loudly instead of mis-routing answers."""
        groups = []
        for gi, (tmpl, n) in enumerate(self.core.groups):
            sig = [f"{sp.kind}:{sp.out_width}" for sp in tmpl.specs]
            slots = {name: si for name, _, g, si in self.entries if g == gi}
            groups.append({"signature": sig, "n_slots": int(n),
                           "slots": slots})
        return {"groups": groups}

    # ---------------------------------------------------- admit/retire --
    def admit(self, name: str, specs) -> tuple:
        """Returns ``(new_plan, transform)`` where ``transform(qstate,
        slot_axis)`` edits the state pytree: activates the new tenant's
        slot (resetting its row to init) and, when the signature's
        bucket is full, pads the group to the next bucket. Pure state
        edits — the only retrace is a bucket-cache MISS on growth."""
        name = str(name)
        specs = tuple(specs)
        if name in self._by_name:
            raise ValueError(f"tenant {name!r} already admitted")
        if not specs:
            raise ValueError(f"tenant {name!r} has an empty registry")
        sig = canonical_signature(specs)
        groups = [(tuple(t.specs), n) for t, n in self.core.groups]
        gi = next((i for i, (s, _) in enumerate(groups) if s == sig), None)
        if gi is None:
            # new signature: append a fresh minimum-bucket group
            gi, si = len(groups), 0
            groups.append((sig, slot_bucket(1)))
            core = slot_plan_core(groups, self.num_strata)
            tmpl, n = core.groups[gi]
            row = tmpl.init_state()

            def transform(qstate, slot_axis=0):
                lead = _lead_shape(qstate, slot_axis)
                mask = jnp.zeros(lead + (n,), bool).at[..., 0].set(True)
                st = jax.tree.map(
                    lambda v: jnp.broadcast_to(
                        v, lead + (n,) + v.shape).copy(), row)
                return tuple(qstate) + ((mask, st),)
        else:
            used = {e[3] for e in self.entries if e[2] == gi}
            n_now = groups[gi][1]
            free = [s for s in range(n_now) if s not in used]
            if free:
                si, core, grow = free[0], self.core, 0
            else:
                si, grow = n_now, n_now  # first slot of the padding
                groups[gi] = (sig, n_now * 2)
                core = slot_plan_core(groups, self.num_strata)
            tmpl, _n = core.groups[gi]
            row = tmpl.init_state()

            def transform(qstate, slot_axis=0, gi=gi, si=si, grow=grow):
                qstate = list(qstate)
                mask, st = qstate[gi]
                if grow:
                    pad = jax.tree.map(
                        lambda v: jnp.broadcast_to(
                            v, mask.shape[:slot_axis] + (grow,) + v.shape
                        ).copy(), row)
                    st = jax.tree.map(
                        lambda a, p: jnp.concatenate([a, p], axis=slot_axis),
                        st, pad)
                    mask = jnp.concatenate(
                        [mask, jnp.zeros(mask.shape[:slot_axis] + (grow,),
                                         bool)], axis=slot_axis)
                idx = (slice(None),) * slot_axis + (si,)
                mask = mask.at[idx].set(True)
                # reset the slot's row: it may hold a retired tenant's
                # frozen sketch, and admission must match fresh compile.
                st = jax.tree.map(lambda a, v: a.at[idx].set(v), st, row)
                qstate[gi] = (mask, st)
                return tuple(qstate)

        entries = self.entries + ((name, specs, gi, si),)
        return SlottedTenantPlan(core, entries), transform

    def retire(self, name: str) -> tuple:
        """Returns ``(new_plan, transform)``: flips the slot's mask bit
        off. The row's state freezes in place (never shrinks a bucket —
        shrinking would retrace; the slot is reused by a later admit)."""
        if name not in self._by_name:
            raise KeyError(f"unknown tenant {name!r}; "
                           f"registered: {list(self.tenant_names)}")
        if len(self.entries) == 1:
            raise ValueError(
                f"cannot retire {name!r}: it is the last live tenant")
        _, _, gi, si = self._by_name[name]
        entries = tuple(e for e in self.entries if e[0] != name)

        def transform(qstate, slot_axis=0):
            qstate = list(qstate)
            mask, st = qstate[gi]
            idx = (slice(None),) * slot_axis + (si,)
            qstate[gi] = (mask.at[idx].set(False), st)
            return tuple(qstate)

        return SlottedTenantPlan(self.core, entries), transform


def _lead_shape(qstate, slot_axis: int) -> tuple:
    """Leading (device) axes of the state layout, read off the first
    group's mask — ``()`` locally, ``(n_devices,)`` on the mesh."""
    if not qstate or slot_axis == 0:
        return ()
    return qstate[0][0].shape[:slot_axis]


def build_slotted_plan(tenants, num_strata: int) -> SlottedTenantPlan:
    """Group tenants by canonical shape signature, pad each group to its
    slot bucket, and wrap the cached core with name routing. Slots are
    assigned in admission order within each group, so a fresh compile of
    any live set is the canonical slot assignment churn must match."""
    tenants = tuple((str(n), tuple(specs)) for n, specs in tenants)
    if not tenants:
        raise ValueError("cannot compile an empty tenant list")
    sigs: list = []
    members: list = []
    for name, specs in tenants:
        sig = canonical_signature(specs)
        try:
            gi = sigs.index(sig)
        except ValueError:
            gi = len(sigs)
            sigs.append(sig)
            members.append([])
        members[gi].append(name)
    groups = tuple((sig, slot_bucket(len(m)))
                   for sig, m in zip(sigs, members))
    core = slot_plan_core(groups, num_strata)
    by_name = dict(tenants)
    entries = []
    slot_of = {name: (gi, si)
               for gi, m in enumerate(members) for si, name in enumerate(m)}
    for name, specs in tenants:
        gi, si = slot_of[name]
        entries.append((name, specs, gi, si))
    return SlottedTenantPlan(core, tuple(entries))


def tenant_rel_errors(plan, answers_row, bounds_row,
                      default_tenant: str = "default") -> dict[str, float]:
    """Per-tenant measured relative error of one window: the WORST
    relative ±2σ bound across each tenant's CLT queries (sum/mean) — the
    attribution signal the worst-tenant-first budget arbiter consumes.
    Sketch queries carry structural bounds and don't vote; a tenant with
    no CLT queries reports 0.0 (it never drives the shared budget). A
    plain single-registry ``CompiledQueryPlan`` attributes everything to
    ``default_tenant``. THE one implementation — the compiled-pipeline
    method and the analytics feedback loop both call this."""
    answers_row = np.asarray(answers_row)
    bounds_row = np.asarray(bounds_row)
    out = {t: 0.0 for t in
           (plan.tenant_names if hasattr(plan, "tenant_names")
            else (default_tenant,))}
    for tenant, off in tenant_clt_slots(plan, default_tenant):
        est = abs(float(answers_row[..., off]))
        rel = float(bounds_row[..., off]) / max(est, 1e-9)
        out[tenant] = max(out[tenant], rel)
    return out


def tenant_clt_slots(plan, default_tenant: str = "default"):
    """Yield ``(tenant, public_offset)`` for every CLT (sum/mean) query
    slot — THE tenant-attribution rule, shared by
    :func:`tenant_rel_errors` (one window's row) and
    ``repro.obs.telemetry.tenant_rel_bounds`` (the cumulative in-graph
    trajectory). Sketch slots carry structural bounds and are skipped."""
    multi = hasattr(plan, "tenant_names")
    names = plan.tenant_names if multi else (default_tenant,)
    for name, (off, _, kind) in plan.layout().items():
        if kind not in ("sum", "mean"):
            continue
        tenant = name.split("/", 1)[0] if (multi and "/" in name) \
            else names[0]
        yield tenant, off

"""Query-plan compiler: fuse K standing queries into one root evaluation.

``CompiledQueryPlan`` turns a tuple of ``QuerySpec``s into three pure
functions the tree engines call at the root, *inside* the jitted tick:

* ``init_state()``   — sketch state pytree (one entry per spec; ``()``
  for stateless CLT queries). Joins ``TreeState`` as donated
  device-resident leaves under the scan engine.
* ``evaluate(key, batch, res, state)`` — answers every registered query
  from ONE window sample: a single shared ``stratum_moments`` pass feeds
  all CLT queries (sum/count/mean), histograms do one bin-scatter each,
  and sketch queries fold the window into their state and answer from
  it. Returns ``(state', answers f32[n_out], bounds f32[n_out])`` — a
  flat, statically-laid-out answer vector, so the scan engine stacks T
  windows of answers into one ``[T, n_out]`` epoch output with zero
  host round-trips.
* ``exact_answers(values, strata)`` — host-side (NumPy) ground truth in
  the same layout, for accuracy benchmarks.

The evaluation draws NO randomness from the sampler's key stream — the
quantile compactor's offset comes from a ``fold_in`` side-branch — so
registering queries leaves every sample and every reservoir state
bit-identical to a run with no queries registered (asserted in
``tests/test_query_plane.py``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import error as err
from repro.core.types import IntervalBatch, SampleResult
from repro.query import sketches
from repro.query.registry import QuerySpec

# fold_in tag separating the query plane's PRNG stream from the sampler's
_QUERY_KEY_TAG = 0x51C7
# fold_in tag for the replicated cross-device merge randomness (SPMD path)
_MERGE_KEY_TAG = 0x4D52


class CompiledQueryPlan:
    """Static, jit-closable fusion of K specs. All array work is pure."""

    def __init__(self, specs: tuple[QuerySpec, ...], num_strata: int):
        if not specs:
            raise ValueError("cannot compile an empty query registry")
        self.specs = tuple(specs)
        self.num_strata = int(num_strata)
        off = 0
        self._layout: dict[str, tuple[int, int, str]] = {}
        for sp in self.specs:
            self._layout[sp.name] = (off, sp.out_width, sp.kind)
            off += sp.out_width
        self.n_out = off

    @property
    def k(self) -> int:
        return len(self.specs)

    def layout(self) -> dict[str, tuple[int, int, str]]:
        """name → (offset, width, kind) into the flat answer vector."""
        return dict(self._layout)

    def answer(self, vec: np.ndarray, name: str) -> np.ndarray:
        """Slice one query's answers out of a flat (host) answer vector."""
        o, w, _ = self._layout[name]
        return np.asarray(vec)[..., o:o + w]

    def init_state(self) -> tuple:
        state = []
        for sp in self.specs:
            if sp.kind == "quantile":
                state.append(sketches.quantile_init(sp.capacity))
            elif sp.kind == "heavy_hitters":
                state.append(sketches.hh_init(sp.k, sp.width, sp.depth))
            else:
                state.append(())
        return tuple(state)

    # ------------------------------------------------------------- eval --
    def evaluate(self, key: jax.Array, batch: IntervalBatch,
                 res: SampleResult, state: tuple) -> tuple:
        """(state', answers f32[n_out], bounds f32[n_out]) for one window."""
        x = self.num_strata
        sel = res.selected
        w_item = res.meta.weight[batch.stratum] * sel.astype(jnp.float32)
        # ONE moments pass shared by every CLT query (the fusion win: the
        # seed evaluated each query with its own segment-sum sweep).
        y, s1, s2 = err.stratum_moments(batch.value, batch.stratum, sel, x)

        outs, bnds, new_state = [], [], []
        for i, sp in enumerate(self.specs):
            kq = jax.random.fold_in(jax.random.fold_in(key, _QUERY_KEY_TAG), i)
            st = state[i]
            if sp.kind == "sum":
                q = err.approx_sum_from_moments(y, s1, s2, res.meta)
                a, b, st2 = q.estimate[None], q.bound(2.0)[None], ()
            elif sp.kind == "count":
                # HT count is exact per stratum given the metadata
                # (every item's indicator is 1): variance 0.
                a = jnp.sum(y * res.meta.weight)[None]
                b, st2 = jnp.zeros((1,), jnp.float32), ()
            elif sp.kind == "mean":
                q = err.approx_mean_from_moments(y, s1, s2, res.meta)
                a, b, st2 = q.estimate[None], q.bound(2.0)[None], ()
            elif sp.kind == "histogram":
                from repro.core import queries as Q

                edges = jnp.linspace(sp.lo, sp.hi, sp.bins + 1)
                q = Q.weighted_histogram(batch, res, x, edges)
                a, b, st2 = q.estimate, q.bound(2.0), ()
            elif sp.kind == "quantile":
                st2 = sketches.quantile_update(kq, st, batch.value, w_item)
                a = sketches.quantile_query(st2, jnp.asarray(sp.qs))
                # live bound: 2·√(Σ quantum²)/W over the leveled
                # compaction history — honest for arbitrarily long
                # standing-query streams, and tighter than the collapsed
                # 2·√U/C because low-level quanta stay small.
                b = jnp.full((len(sp.qs),), 1.0) * st2.rank_error_bound
            elif sp.kind == "heavy_hitters":
                keys = sketches.hh_item_key(batch.value)
                st2 = sketches.hh_update(st, keys, w_item)
                eps_w = sketches.hh_error_bound(sp.width, st2.total_weight)
                a = jnp.concatenate([st2.key.astype(jnp.float32), st2.est])
                b = jnp.concatenate([jnp.zeros((sp.k,), jnp.float32),
                                     jnp.full((sp.k,), 1.0) * eps_w])
            else:  # pragma: no cover — registry validates kinds
                raise AssertionError(sp.kind)
            outs.append(a.astype(jnp.float32))
            bnds.append(b.astype(jnp.float32))
            new_state.append(st2)
        return tuple(new_state), jnp.concatenate(outs), jnp.concatenate(bnds)

    # ------------------------------------------------------------- spmd --
    def evaluate_spmd(self, key: jax.Array, batch: IntervalBatch,
                      res: SampleResult, state: tuple,
                      axis_name: str) -> tuple:
        """Distributed ``evaluate`` under ``shard_map``: every device
        holds one shard of the window (``batch``/``res`` are its local
        sample) plus its own sketch ``state``; the answers come from
        MERGED per-device summaries, and only those summaries —
        O(sketch) bytes — cross the device boundary:

        * CLT queries: per-device (estimate, variance) from the local
          moments pass, ``psum``-merged (independent local samples sum
          in estimate and variance; the mean re-weights by each shard's
          population share). ``count`` merges the *pre-sampling* stratum
          counts ``Σ C_i·W^in_i`` — the same quantity the HT count
          reconstructs, but summed as exact integers, so the merged
          answer is bitwise-identical across device counts.
        * histograms: per-bin HT estimate/variance, ``psum``-merged
          (linear queries merge exactly).
        * sketches: the local state updates from the local sample (own
          PRNG side-branch per device), then the per-device summaries
          all-gather and merge in-graph (``quantile_merge_stacked`` /
          ``hh_merge_stacked``) with REPLICATED merge randomness, so
          every device answers from the identical merged summary.

        ``key`` must be replicated across ``axis_name``. Returns
        ``(state', answers, bounds)`` with ``state'`` device-local and
        answers/bounds replicated in value (the caller re-types them
        with a ``pmean``, see ``core.tree.spmd_query_plane_tick``)."""
        x = self.num_strata
        sel = res.selected
        w_item = res.meta.weight[batch.stratum] * sel.astype(jnp.float32)
        y, s1, s2 = err.stratum_moments(batch.value, batch.stratum, sel, x)
        psum = lambda v: jax.lax.psum(v, axis_name)
        dev = jax.lax.axis_index(axis_name)
        # Each shard's estimated source population (Σ c_src) — the mean's
        # merge weight: MEAN over the union is the share-weighted mean.
        total_local = jnp.sum(y * res.meta.weight)
        total = jnp.maximum(psum(total_local), 1.0)
        share = total_local / total

        outs, bnds, new_state = [], [], []
        for i, sp in enumerate(self.specs):
            kq = jax.random.fold_in(jax.random.fold_in(key, _QUERY_KEY_TAG), i)
            kq_local = jax.random.fold_in(kq, dev)
            kq_merge = jax.random.fold_in(kq, _MERGE_KEY_TAG)
            st = state[i]
            if sp.kind == "sum":
                q = err.approx_sum_from_moments(y, s1, s2, res.meta)
                a = psum(q.estimate)[None]
                b, st2 = 2.0 * jnp.sqrt(psum(q.variance))[None], ()
            elif sp.kind == "count":
                # Exact by construction: C_i·W^in_i needs no sample, and
                # integer f32 sums are associative — N-device ≡ 1-device
                # to the bit (the harness' "exact queries" property).
                a = psum(jnp.sum(res.c * batch.meta.weight))[None]
                b, st2 = jnp.zeros((1,), jnp.float32), ()
            elif sp.kind == "mean":
                q = err.approx_mean_from_moments(y, s1, s2, res.meta)
                a = psum(q.estimate * share)[None]
                b = 2.0 * jnp.sqrt(psum(q.variance * share * share))[None]
                st2 = ()
            elif sp.kind == "histogram":
                from repro.core import queries as Q

                edges = jnp.linspace(sp.lo, sp.hi, sp.bins + 1)
                q = Q.weighted_histogram(batch, res, x, edges)
                a = psum(q.estimate)
                b, st2 = 2.0 * jnp.sqrt(psum(q.variance)), ()
            elif sp.kind == "quantile":
                st2 = sketches.quantile_update(kq_local, st, batch.value,
                                               w_item)
                g = jax.tree.map(lambda v: jax.lax.all_gather(v, axis_name),
                                 st2)
                merged = sketches.quantile_merge_stacked(kq_merge, g)
                a = sketches.quantile_query(merged, jnp.asarray(sp.qs))
                b = jnp.full((len(sp.qs),), 1.0) * merged.rank_error_bound
            elif sp.kind == "heavy_hitters":
                keys = sketches.hh_item_key(batch.value)
                st2 = sketches.hh_update(st, keys, w_item)
                # counts are linear: psum ≡ gather-then-sum, at 1/N the
                # gather bytes; only the k-slot candidate keys gather.
                g_counts = jax.lax.psum(st2.counts, axis_name)
                g_keys = jax.lax.all_gather(st2.key, axis_name, tiled=True)
                mk, me = sketches._refresh_topk(g_counts, g_keys, sp.k)
                eps_w = sketches.hh_error_bound(sp.width,
                                                jnp.sum(g_counts[0]))
                a = jnp.concatenate([mk.astype(jnp.float32), me])
                b = jnp.concatenate([jnp.zeros((sp.k,), jnp.float32),
                                     jnp.full((sp.k,), 1.0) * eps_w])
            else:  # pragma: no cover — registry validates kinds
                raise AssertionError(sp.kind)
            outs.append(a.astype(jnp.float32))
            bnds.append(b.astype(jnp.float32))
            new_state.append(st2)
        return tuple(new_state), jnp.concatenate(outs), jnp.concatenate(bnds)

    # ------------------------------------------------------ ground truth --
    def exact_answers(self, values: np.ndarray,
                      strata: np.ndarray | None = None) -> np.ndarray:
        """Host-side exact answers over the full stream, layout-aligned.

        Windowed CLT queries aggregate over the whole stream (their
        per-window estimates are summed/averaged the same way by the
        caller). Sketch slots need care:

        * ``quantile`` slots hold the exact ``inverted_cdf`` order
          statistics — the same "first value whose rank exceeds q·W"
          rule the sketch answers with. Compare in RANK space (measure
          the sketch value's rank on the stream, as fig8 does): value-
          space differences are density-sensitive and can be large in
          flat regions even at zero rank error.
        * ``heavy_hitters`` slots are NaN: the sketch reports *its own*
          candidate keys, so a slot-for-slot diff against the true
          top-k is meaningless — get per-key truth from the raw stream
          (``np.round(values)`` counts), keyed by the sketch's keys.
        """
        values = np.asarray(values, np.float64)
        out = np.zeros((self.n_out,), np.float64)
        for sp in self.specs:
            o, w, _ = self._layout[sp.name]
            if sp.kind == "sum":
                out[o] = values.sum()
            elif sp.kind == "count":
                out[o] = len(values)
            elif sp.kind == "mean":
                out[o] = values.mean() if len(values) else 0.0
            elif sp.kind == "histogram":
                edges = np.linspace(sp.lo, sp.hi, sp.bins + 1)
                ix = np.clip(np.searchsorted(edges, values, side="right") - 1,
                             0, sp.bins - 1)
                out[o:o + w] = np.bincount(ix, minlength=sp.bins)
            elif sp.kind == "quantile":
                out[o:o + w] = np.quantile(values, np.asarray(sp.qs),
                                           method="inverted_cdf")
            elif sp.kind == "heavy_hitters":
                out[o:o + w] = np.nan
        return out


class MultiTenantPlan:
    """K tenants' query registries fused into ONE batched root evaluation.

    Each tenant keeps its own ``CompiledQueryPlan`` (so its PRNG stream,
    sketch state, and answers are bit-identical to a single-tenant run of
    the same registry), but all plans evaluate inside the SAME traced root
    step from the SAME window sample — N tenants share one tree dispatch
    per epoch. The flat answer vector is the tenants' vectors concatenated
    in registration order; ``tenant_slice``/``answer`` route per-tenant
    views back out, and ``layout()`` exposes ``"tenant/query"``-prefixed
    names so shared consumers (error-budget feedback, dashboards) can
    attribute every slot to its tenant.

    Duck-types ``CompiledQueryPlan`` (``evaluate``/``init_state``/
    ``n_out``/``layout``/``answer``), so every engine — scan tick,
    level/loop root steps — accepts it unchanged.
    """

    def __init__(self, tenants, num_strata: int):
        """``tenants``: ordered ``(name, (QuerySpec, ...))`` pairs."""
        tenants = tuple((str(n), tuple(specs)) for n, specs in tenants)
        if not tenants:
            raise ValueError("cannot compile an empty tenant list")
        names = [n for n, _ in tenants]
        if len(set(names)) != len(names):
            dup = sorted({n for n in names if names.count(n) > 1})
            raise ValueError(f"duplicate tenant names: {dup}")
        self.tenant_names = tuple(names)
        self.num_strata = int(num_strata)
        self.plans = tuple(CompiledQueryPlan(specs, num_strata)
                           for _, specs in tenants)
        self._offsets = {}
        off = 0
        for name, plan in zip(self.tenant_names, self.plans):
            self._offsets[name] = off
            off += plan.n_out
        self.n_out = off

    @property
    def k(self) -> int:
        return sum(p.k for p in self.plans)

    def plan_for(self, tenant: str) -> CompiledQueryPlan:
        if tenant not in self._offsets:
            raise KeyError(f"unknown tenant {tenant!r}; "
                           f"registered: {list(self.tenant_names)}")
        return self.plans[self.tenant_names.index(tenant)]

    def tenant_slice(self, tenant: str) -> tuple[int, int]:
        """(offset, width) of one tenant's block in the flat vector."""
        return self._offsets[tenant], self.plan_for(tenant).n_out

    def layout(self) -> dict[str, tuple[int, int, str]]:
        """``"tenant/query"`` → (absolute offset, width, kind)."""
        out = {}
        for name, plan in zip(self.tenant_names, self.plans):
            base = self._offsets[name]
            for q, (o, w, kind) in plan.layout().items():
                out[f"{name}/{q}"] = (base + o, w, kind)
        return out

    def answer(self, vec: np.ndarray, name: str) -> np.ndarray:
        """Slice one ``"tenant/query"`` answer out of a flat vector."""
        o, w, _ = self.layout()[name]
        return np.asarray(vec)[..., o:o + w]

    def tenant_answers(self, vec: np.ndarray, tenant: str) -> np.ndarray:
        o, w = self.tenant_slice(tenant)
        return np.asarray(vec)[..., o:o + w]

    def init_state(self) -> tuple:
        return tuple(p.init_state() for p in self.plans)

    def evaluate(self, key: jax.Array, batch: IntervalBatch,
                 res: SampleResult, state: tuple) -> tuple:
        """One fused evaluation for all tenants. Every tenant plan gets
        the SAME key — exactly what a single-tenant run would pass — so
        each tenant's answers/bounds/sketch state bit-match an isolated
        run of its registry on the same sample."""
        states, outs, bnds = [], [], []
        for plan, st in zip(self.plans, state):
            st2, a, b = plan.evaluate(key, batch, res, st)
            states.append(st2)
            outs.append(a)
            bnds.append(b)
        return (tuple(states), jnp.concatenate(outs), jnp.concatenate(bnds))

    def evaluate_spmd(self, key: jax.Array, batch: IntervalBatch,
                      res: SampleResult, state: tuple,
                      axis_name: str) -> tuple:
        """Distributed fused evaluation for all tenants (one batched root
        over the merged summaries — see ``CompiledQueryPlan.
        evaluate_spmd``). Every tenant plan gets the SAME replicated key,
        mirroring the local ``evaluate``, so each tenant's merged answers
        match an isolated single-tenant SPMD run of its registry."""
        states, outs, bnds = [], [], []
        for plan, st in zip(self.plans, state):
            st2, a, b = plan.evaluate_spmd(key, batch, res, st, axis_name)
            states.append(st2)
            outs.append(a)
            bnds.append(b)
        return (tuple(states), jnp.concatenate(outs), jnp.concatenate(bnds))

    def exact_answers(self, values: np.ndarray,
                      strata: np.ndarray | None = None) -> np.ndarray:
        return np.concatenate([p.exact_answers(values, strata)
                               for p in self.plans])


def tenant_rel_errors(plan, answers_row, bounds_row,
                      default_tenant: str = "default") -> dict[str, float]:
    """Per-tenant measured relative error of one window: the WORST
    relative ±2σ bound across each tenant's CLT queries (sum/mean) — the
    attribution signal the worst-tenant-first budget arbiter consumes.
    Sketch queries carry structural bounds and don't vote; a tenant with
    no CLT queries reports 0.0 (it never drives the shared budget). A
    plain single-registry ``CompiledQueryPlan`` attributes everything to
    ``default_tenant``. THE one implementation — the compiled-pipeline
    method and the analytics feedback loop both call this."""
    answers_row = np.asarray(answers_row)
    bounds_row = np.asarray(bounds_row)
    multi = hasattr(plan, "tenant_names")
    names = plan.tenant_names if multi else (default_tenant,)
    out = {t: 0.0 for t in names}
    for name, (off, _, kind) in plan.layout().items():
        if kind not in ("sum", "mean"):
            continue
        tenant = name.split("/", 1)[0] if multi else names[0]
        est = abs(float(answers_row[..., off]))
        rel = float(bounds_row[..., off]) / max(est, 1e-9)
        out[tenant] = max(out[tenant], rel)
    return out

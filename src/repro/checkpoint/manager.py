"""Checkpointing: atomic, keep-N, elastic restore.

Layout (one directory per step):
    <root>/step_000123.tmp/   → written, fsync'd, then renamed to
    <root>/step_000123/
        manifest.json         tree structure + shapes + dtypes + meta
        arr_00000.npy ...     one file per leaf (host order)

Leaves are saved as *full* (unsharded) arrays — ``jax.device_get`` gathers
shards — so a checkpoint written on one mesh restores onto any other
(elastic scaling): ``restore(..., shardings=...)`` re-shards on load. At
real fleet scale you would write per-host shard files instead; the
manifest already records the source mesh to support that layout.

Fault-tolerance contract: a crash mid-write leaves only ``*.tmp`` (ignored
by ``latest_step``); ``keep_n`` prunes old steps only after a successful
rename.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(root: str | pathlib.Path, step: int, tree, *, meta: dict | None = None,
         keep_n: int = 3) -> pathlib.Path:
    root = pathlib.Path(root)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:09d}"
    tmp = root / f"step_{step:09d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "num_leaves": len(leaves),
        "meta": meta or {},
        "written_at": time.time(),
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"arr_{i:05d}.npy", arr)
        manifest["leaves"].append({"shape": list(arr.shape), "dtype": str(arr.dtype)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)

    if keep_n:
        steps = sorted(p for p in root.iterdir()
                       if p.is_dir() and p.name.startswith("step_")
                       and not p.name.endswith(".tmp"))
        for old in steps[:-keep_n]:
            shutil.rmtree(old)
    return final


def latest_step(root: str | pathlib.Path) -> int | None:
    root = pathlib.Path(root)
    if not root.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in root.iterdir()
             if p.is_dir() and p.name.startswith("step_")
             and not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def read_manifest(root: str | pathlib.Path, step: int) -> dict:
    """Read a step's manifest WITHOUT materializing any leaves — callers
    (e.g. ``api.pipeline.restore_state``) validate recorded metadata
    (pipeline spec, tenant-slot configuration) before committing to the
    leaf-by-leaf template restore, so a mismatched checkpoint fails with
    an actionable error instead of a shape assertion."""
    path = pathlib.Path(root) / f"step_{step:09d}"
    return json.loads((path / "manifest.json").read_text())


def restore(root: str | pathlib.Path, step: int, target_tree, *, shardings=None):
    """Load into the structure of ``target_tree`` (shape/dtype template).
    With ``shardings`` (matching pytree of NamedSharding), leaves are
    device_put directly to their shards — elastic re-mesh on load."""
    path = pathlib.Path(root) / f"step_{step:09d}"
    manifest = json.loads((path / "manifest.json").read_text())
    leaves, treedef = _flatten(target_tree)
    assert manifest["num_leaves"] == len(leaves), \
        f"leaf count mismatch: ckpt {manifest['num_leaves']} vs target {len(leaves)}"
    out = []
    sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                 if shardings is not None else [None] * len(leaves))
    for i, (tmpl, sh) in enumerate(zip(leaves, sh_leaves)):
        arr = np.load(path / f"arr_{i:05d}.npy")
        assert tuple(arr.shape) == tuple(tmpl.shape), f"leaf {i} shape mismatch"
        if sh is not None:
            out.append(jax.device_put(arr.astype(tmpl.dtype), sh))
        else:
            out.append(jax.numpy.asarray(arr, dtype=tmpl.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["meta"]


class AsyncCheckpointer:
    """Overlap checkpoint writes with training (one in flight)."""

    def __init__(self, root: str | pathlib.Path, keep_n: int = 3):
        self.root = root
        self.keep_n = keep_n
        self._thread: threading.Thread | None = None

    def save(self, step: int, tree, meta: dict | None = None) -> None:
        self.wait()
        # device_get on the main thread (jax arrays are not thread-safe to
        # donate), then write on the worker.
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._thread = threading.Thread(
            target=save, args=(self.root, step, host_tree),
            kwargs=dict(meta=meta, keep_n=self.keep_n), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

"""The jit-able train/prefill steps shared by the launcher and dry-run."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.optim import adamw


def make_train_step(cfg, opt_cfg: adamw.AdamWConfig):
    """(params, opt_state, batch) → (params, opt_state, metrics)."""

    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            M.loss_fn, argnums=1, has_aux=True)(cfg, params, batch)
        params, opt_state, opt_m = adamw.update(opt_cfg, grads, opt_state, params)
        metrics = dict(metrics, **opt_m, total_loss=loss)
        return params, opt_state, metrics

    return step


def make_prefill_step(cfg):
    """(params, batch) → logits — inference prefill (no cache output here;
    the serving path materializes the cache, see launch/serve.py)."""

    def step(params, batch):
        logits, _ = M.forward(cfg, params, batch)
        return logits

    return step


def make_decode_step(cfg):
    def step(params, cache, token, pos):
        return M.decode_step(cfg, params, cache, token, pos)

    return step

"""AdamW with fp32 master state over bf16 params, global-norm clipping,
and warmup-cosine schedule. Pure pytree functions — optimizer state shards
exactly like the parameters (ZeRO-style: the sharding rules put m/v/master
on the same FSDP axes), so memory per chip is (2 + 12)·N/chips bytes.

Distributed-optimization notes (see DESIGN.md §5):
  * gradients materialize in the param dtype (bf16) → DP all-reduces move
    2-byte words (the "gradient compression" XLA can't do by itself);
  * the fp32 master copy lives only in the optimizer state;
  * update math runs in fp32 and re-casts once.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * jnp.clip(t, 0, 1)))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params) -> dict:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    # copy=True: fp32 params would otherwise alias master (astype is a
    # no-op) and break buffer donation in the train step.
    copy = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    return {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "master": jax.tree.map(copy, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state, params):
    """→ (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        new_master = master - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                                    + cfg.weight_decay * master)
        return m, v, new_master

    flat = jax.tree.map(upd, grads, state["m"], state["v"], state["master"])
    m = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    v = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    master = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_params = jax.tree.map(lambda mst, p: mst.astype(p.dtype), master, params)
    new_state = {"m": m, "v": v, "master": master, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}

"""Training launcher: end-to-end driver with the ApproxIoT data plane,
checkpoint/restart, straggler calibration, and adaptive budget control.

On this CPU container it runs reduced configs (``--smoke``); on a fleet
the same code runs the full config under the production mesh (the dry-run
proves those lower+compile). Fault tolerance:

  * checkpoint every ``--ckpt-every`` steps (atomic, keep-N, async),
  * auto-resume from the latest checkpoint in ``--ckpt-dir``,
  * SIGTERM → final checkpoint → clean exit (preemption-safe),
  * per-shard deadline tracking; late shards are dropped and the loss
    re-weighted (unbiased — runtime/straggler.py).

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
        --steps 200 --batch 8 --seq 256 --sampling-fraction 0.5
"""
from __future__ import annotations

import argparse
import dataclasses
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.data.pipeline import ApproxTrainPipeline, PipelineConfig
from repro.data.stream import TokenStream
from repro.checkpoint import manager as ckpt
from repro.models import model as M
from repro.optim import adamw, train_step
from repro.runtime.budget import BudgetConfig, BudgetController
from repro.runtime.straggler import DeadlineTracker, calibrate_weights


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=registry.ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--interval-size", type=int, default=32)
    ap.add_argument("--sampling-fraction", type=float, default=0.5)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--simulate-stragglers", type=float, default=0.0,
                    help="probability a shard misses its deadline")
    ap.add_argument("--exact", action="store_true",
                    help="disable sampling (native execution baseline)")
    args = ap.parse_args(argv)

    cfg = registry.get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                warmup_steps=max(args.steps // 20, 5))

    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    opt_state = adamw.init(params)
    step_fn = jax.jit(train_step.make_train_step(cfg, opt_cfg),
                      donate_argnums=(0, 1))

    stream = TokenStream(cfg.vocab_size, args.seq, cfg.num_strata,
                         rates=list(np.linspace(1.0, 4.0, cfg.num_strata)))
    pipe_cfg = PipelineConfig(
        batch_size=args.batch, interval_size=args.interval_size,
        num_strata=cfg.num_strata,
        sampling_fraction=1.0 if args.exact else args.sampling_fraction)
    pipeline = ApproxTrainPipeline(pipe_cfg, stream)
    budget = BudgetController(
        BudgetConfig(min_size=args.batch, max_size=args.interval_size,
                     target_latency_s=None),
        initial_size=int(args.interval_size * pipe_cfg.sampling_fraction))
    deadline = DeadlineTracker(num_shards=max(len(jax.devices()), 4))
    rng = np.random.default_rng(0)

    start = 0
    latest = ckpt.latest_step(args.ckpt_dir)
    if latest is not None:
        (params, opt_state), meta = ckpt.restore(
            args.ckpt_dir, latest, (params, opt_state))
        start = int(meta.get("step", latest)) + 1
        print(f"[resume] from step {start}")

    checkpointer = ckpt.AsyncCheckpointer(args.ckpt_dir)
    stop = {"now": False}
    signal.signal(signal.SIGTERM, lambda *a: stop.update(now=True))

    losses = []
    t_start = time.time()
    for step in range(start, args.steps):
        batch = pipeline.next_batch()
        # straggler simulation: shards that miss the deadline lose their
        # examples; Eq. 9 calibration keeps the loss unbiased.
        lat = rng.exponential(0.1, deadline.lat.shape[1] if deadline.lat.size else 4)
        if args.simulate_stragglers > 0:
            lat = lat + (rng.random(lat.shape) < args.simulate_stragglers) * 10.0
        present_shards = deadline.observe(lat)
        shard_of = np.arange(args.batch) % len(present_shards)
        present = present_shards[shard_of]
        if not present.all():
            batch["weight"] = calibrate_weights(batch["weight"], present)

        t0 = time.time()
        params, opt_state, metrics = step_fn(
            params, opt_state, jax.tree.map(jnp.asarray, batch))
        loss = float(metrics["loss"])
        losses.append(loss)
        budget.update(latency_s=time.time() - t0)

        if step % args.log_every == 0:
            frac = pipeline.stats["sampled"] / max(pipeline.stats["arrived"], 1)
            print(f"step {step:5d} loss {loss:.4f} gnorm "
                  f"{float(metrics['grad_norm']):.3f} lr {float(metrics['lr']):.2e} "
                  f"sampled {frac:.2%} stragglers {int((~present).sum())}")
        if step and step % args.ckpt_every == 0 or stop["now"]:
            checkpointer.save(step, (params, opt_state), meta={"step": step})
            if stop["now"]:
                print("[sigterm] checkpointed, exiting")
                break

    checkpointer.save(args.steps - 1, (params, opt_state),
                      meta={"step": args.steps - 1})
    checkpointer.wait()
    dt = time.time() - t_start
    print(f"done: {len(losses)} steps in {dt:.1f}s "
          f"({len(losses) / max(dt, 1e-9):.2f} steps/s); "
          f"loss {losses[0]:.4f} → {np.mean(losses[-5:]):.4f}")
    return losses


if __name__ == "__main__":
    main()

"""Compiled-artifact analysis → roofline terms (EXPERIMENTS.md §Roofline).

Two cost sources:
  * ``compiled.cost_analysis()`` (XLA) — reported for reference, but it
    counts every ``while`` body once, so scan-over-layers models are
    under-counted by ~L×;
  * ``launch.hlocost`` — text-level model over the partitioned HLO that
    multiplies by ``known_trip_count`` (validated against XLA on
    loop-free modules). The roofline terms use this one.

Terms (per-device: partitioned-module shapes are per-chip already):
    compute_s    = flops / PEAK_FLOPS
    memory_s     = bytes / HBM_BW
    collective_s = collective_operand_bytes / ICI_BW
"""
from __future__ import annotations

import re
from typing import Any

from repro.launch import hlocost

# TPU v5e-class hardware constants (per chip).
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link


def roofline_terms(flops: float, bytes_: float, coll_bytes: float) -> dict:
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_ / HBM_BW,
        "collective_s": coll_bytes / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    frac = terms["compute_s"] / bound if bound > 0 else 0.0
    return dict(terms, dominant=dominant, step_s=bound, compute_fraction=frac)


def summarize(compiled, *, chips: int, extra_flops_per_chip: float = 0.0,
              flash_seq: int | None = None) -> dict[str, Any]:
    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, list):
        xla_cost = xla_cost[0]

    hlo = compiled.as_text()
    model = hlocost.analyze_text(hlo, zero_s2_seq=flash_seq)
    flops = model["flops"] + extra_flops_per_chip
    bytes_ = model["bytes"]
    coll_bytes = model["collective_bytes"]

    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        }
    except Exception as e:  # backend may not implement it
        mem_rec = {"error": str(e)}

    return {
        "flops_per_chip": flops,
        "dot_flops_per_chip": model["dot_flops"],
        "bytes_per_chip": bytes_,
        "collective_bytes_per_chip": coll_bytes,
        "collectives": model["collectives"],
        "xla_cost_flops": float(xla_cost.get("flops", 0.0)),
        "xla_bytes_accessed": float(xla_cost.get("bytes accessed", 0.0)),
        "memory": mem_rec,
        **{"terms": roofline_terms(flops, bytes_, coll_bytes)},
        "hlo_size": len(hlo),
    }

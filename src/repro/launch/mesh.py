"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state. The single-pod mesh is 16×16 = 256 chips ("data", "model"); the
multi-pod mesh adds a leading "pod" axis → 2×16×16 = 512 chips. The
"pod" axis participates in batch DP and ZeRO weight sharding (DCI-friendly
collectives only: gradient all-reduce + param all-gather).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host has — smoke tests / examples (usually 1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: the 512
placeholder host devices let ``jax.make_mesh`` build the production
meshes; ``.lower(**ShapeDtypeStructs).compile()`` exercises SPMD
partitioning, sharding propagation, and collective insertion exactly as a
real TPU fleet would see them. Results (memory/cost/collective stats) are
cached as JSON under ``benchmarks/results/dryrun/`` for the roofline
harness.

Usage:
    python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.configs.base import SHAPES, shape_applicable
from repro.launch import analysis, sharding
from repro.launch.mesh import make_production_mesh
from repro.launch.meshctx import use_mesh
from repro.models import model as M
from repro.optim import adamw, train_step

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def _named(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool, extra=None):
    """Lower + compile one cell; returns (record, compiled)."""
    cfg = registry.get_config(arch)
    if extra:
        import dataclasses
        cfg = dataclasses.replace(cfg, **extra)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size

    t0 = time.time()
    with use_mesh(mesh):
        p_shape = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
        p_spec = sharding.param_specs(p_shape, mesh)
        p_sh = _named(mesh, p_spec)
        specs = registry.input_specs(cfg, shape)

        if shape.kind == "train":
            o_shape = jax.eval_shape(adamw.init, p_shape)
            o_sh = _named(mesh, sharding.opt_state_specs(o_shape, p_spec, mesh))
            b_sh = _named(mesh, sharding.batch_specs(specs, mesh))
            step = train_step.make_train_step(cfg, adamw.AdamWConfig())
            jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(p_shape, o_shape, specs)
        elif shape.kind == "prefill":
            b_sh = _named(mesh, sharding.batch_specs(specs, mesh))
            step = train_step.make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh), out_shardings=None)
            lowered = jitted.lower(p_shape, specs)
        else:  # decode
            cache_shape = specs["cache"]
            c_sh = _named(mesh, sharding.cache_specs_tree(cache_shape, mesh))
            tok_sh = _named(mesh, sharding.batch_specs(
                {"token": specs["token"]}, mesh))["token"]
            step = train_step.make_decode_step(cfg)
            jitted = jax.jit(step,
                             in_shardings=(p_sh, c_sh, tok_sh, NamedSharding(mesh, P())),
                             out_shardings=(None, c_sh), donate_argnums=(1,))
            lowered = jitted.lower(p_shape, cache_shape, specs["token"], specs["pos"])
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    # "flash_costed": lower via the XLA attention path (Pallas custom calls
    # cannot compile on the CPU host backend), but price the S×S score
    # tensors as VMEM-resident — the HBM profile of the validated Pallas
    # flash kernel (kernels/flash_attention, allclose-tested vs ref.py).
    flash_seq = shape.seq_len if cfg.attention_impl == "flash_costed" else None
    rec = analysis.summarize(compiled, chips=chips, flash_seq=flash_seq)
    if flash_seq:
        rec["attention"] = "pallas-flash (repriced S² → VMEM)"
    # MODEL_FLOPS: 6·N·D train / 2·N·D prefill+decode (per chip, active N)
    n_act = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * n_act * tokens / chips
    rec.update(
        arch=arch, shape=shape_name, mesh="2x16x16" if multi_pod else "16x16",
        chips=chips, kind=shape.kind, seq_len=shape.seq_len,
        global_batch=shape.global_batch,
        params=cfg.param_count(), active_params=n_act,
        model_flops_per_chip=model_flops,
        model_vs_hlo=model_flops / max(rec["flops_per_chip"], 1.0),
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
    )
    return rec, compiled


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, force=False,
             tag: str = "", extra=None, verbose=True):
    RESULTS.mkdir(parents=True, exist_ok=True)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    out = RESULTS / f"{arch}__{shape_name}__{mesh_tag}{tag}.json"
    if out.exists() and not force:
        if verbose:
            print(f"[skip-cached] {out.name}")
        return json.loads(out.read_text())

    cfg = registry.get_config(arch)
    ok, why = shape_applicable(cfg, SHAPES[shape_name])
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "skipped": True, "reason": why}
        out.write_text(json.dumps(rec, indent=1))
        if verbose:
            print(f"[skip-n/a]    {arch} × {shape_name}: {why}")
        return rec

    try:
        rec, compiled = lower_cell(arch, shape_name, multi_pod=multi_pod, extra=extra)
        if verbose:
            print(f"--- {arch} × {shape_name} × {mesh_tag} ---")
            try:
                print(f"memory_analysis: {compiled.memory_analysis()}")
            except Exception as e:
                print(f"memory_analysis: unavailable ({e})")
            t = rec["terms"]
            print(f"flops/chip={rec['flops_per_chip']:.3e} "
                  f"bytes/chip={rec['bytes_per_chip']:.3e} "
                  f"coll/chip={rec['collective_bytes_per_chip']:.3e} | "
                  f"compute={t['compute_s']:.4f}s memory={t['memory_s']:.4f}s "
                  f"coll={t['collective_s']:.4f}s dominant={t['dominant']} "
                  f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)")
    except Exception as e:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        if verbose:
            print(f"[FAIL] {arch} × {shape_name} × {mesh_tag}: {e}")
    out.write_text(json.dumps(rec, indent=1, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_NAMES)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    cells = ([(a, s) for a in registry.ARCH_NAMES for s in SHAPES]
             if args.all else [(args.arch, args.shape)])
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            rec = run_cell(arch, shape, multi_pod=mp, force=args.force)
            failures += 1 if "error" in rec else 0
    print(f"done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()

"""Text-level cost model over post-SPMD-partitioned HLO.

Why: XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE,
so a scan-over-layers transformer reports ~1/L of its true FLOPs, and
collectives inside the scan (the FSDP all-gathers!) are similarly
under-counted. This module re-derives cost from ``compiled.as_text()``:

  * parses every computation, builds the call graph
    (entry → while bodies → fusions → …),
  * multiplies by ``known_trip_count`` at each ``while``,
  * FLOPs: dots = 2·numel(result)·contract_size; elementwise = numel;
    reduce = numel(operand); data movement = 0,
  * bytes: operands+result of every scheduled op outside fusion bodies
    (XLA "bytes accessed" semantics),
  * collectives: operand bytes × loop multiplier, per kind.

Shapes in the partitioned module are per-device, so all outputs are
per-chip. Validated against ``cost_analysis()`` on loop-free modules
(tests/test_hlocost.py).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*(?:e[0-9a-z]+)?)\[([0-9,]*)\]")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{")
_OP_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$"
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1, "token": 0,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "cbrt", "negate", "abs", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "compare", "select", "and",
    "or", "xor", "not", "clamp", "sine", "cosine", "tan", "atan2", "erf",
    "logistic", "remainder", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "rng", "rng-bit-generator", "map",
}
_DATA_MOVE = {
    "broadcast", "reshape", "transpose", "slice", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "gather", "scatter", "copy",
    "pad", "reverse", "convert", "bitcast", "bitcast-convert", "iota",
    "reduce", "reduce-window", "sort", "dot", "fusion", "select-and-scatter",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start", "custom-call", "convolution", "cholesky",
    "triangular-solve", "fft",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "token", "while", "conditional",
               "call", "partition-id", "replica-id", "domain", "opt-barrier"}


def _shapes_bytes(text: str) -> float:
    return float(sum(
        _DTYPE_BYTES.get(d, 0) * _numel(dims) for d, dims in _SHAPE_RE.findall(text)
    ))


def _numel(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _result_numel(rtype: str) -> int:
    return sum(_numel(dims) for _, dims in _SHAPE_RE.findall(rtype))


_NAME_RE = re.compile(r"%([\w.\-]+)")


@dataclasses.dataclass
class Op:
    name: str
    rtype: str
    opcode: str
    rest: str            # operand list + attributes (rest of line)

    def operands_text(self) -> str:
        depth = 1
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return self.rest[:i]
        return self.rest

    def operand_names(self) -> list[str]:
        return _NAME_RE.findall(self.operands_text())

    def attr(self, key: str) -> str | None:
        m = re.search(key + r"=%([\w.\-]+)", self.rest)
        return m.group(1) if m else None


def parse_module(text: str) -> tuple[dict[str, list[Op]], str]:
    comps: dict[str, list[Op]] = {}
    entry = ""
    cur: list[Op] | None = None
    for line in text.splitlines():
        if line and not line[0].isspace():
            m = _HEADER_RE.match(line)
            if m:
                cur = comps.setdefault(m.group(1), [])
                if line.startswith("ENTRY"):
                    entry = m.group(1)
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            cur.append(Op(m.group(1), m.group(2), m.group(3), m.group(4)))
    return comps, entry


class HloCost:
    """``zero_s2_seq``: flash-kernel repricing. When set to the sequence
    length S, any shape whose last dim == S and second-to-last dim ≥ S/64
    (the attention-score S×S tiles, under any context/head partitioning)
    contributes 0 bytes — on TPU the validated Pallas flash kernel streams
    those tiles through VMEM and they never touch HBM. FLOPs are NOT
    repriced (the kernel still does the math), so the resulting byte
    profile is exactly q/k/v reads + output writes."""

    def __init__(self, text: str, zero_s2_seq: int | None = None):
        self.zero_s2_seq = zero_s2_seq
        self.comps, self.entry = parse_module(text)
        self._memo: dict[tuple[str, bool], dict] = {}
        # Scheduled HLO prints operands without types — resolve shapes via
        # a per-computation def-use table (SSA names are computation-local).
        self._types: dict[str, dict[str, str]] = {
            cname: {op.name: op.rtype for op in ops}
            for cname, ops in self.comps.items()
        }

    # ------------------------------------------------------------------
    def _bytes_of(self, text: str) -> float:
        """Bytes of all shapes in ``text``, with flash S² repricing."""
        s2 = self.zero_s2_seq
        total = 0.0
        for d, dims_s in _SHAPE_RE.findall(text):
            dims = [int(x) for x in dims_s.split(",") if x]
            if (s2 and len(dims) >= 2 and dims[-1] == s2
                    and dims[-2] >= max(s2 // 64, 2)):
                continue
            n = 1
            for x in dims:
                n *= x
            total += _DTYPE_BYTES.get(d, 0) * n
        return float(total)

    def _operand_types(self, comp: str, op: Op) -> list[str]:
        table = self._types.get(comp, {})
        out = [table.get(n, "") for n in op.operand_names()]
        # unscheduled modules may inline types in the operand list
        if not any(out) and _SHAPE_RE.search(op.operands_text()):
            return [op.operands_text()]
        return out

    def _operand_bytes(self, comp: str, op: Op) -> float:
        return sum(self._bytes_of(t) for t in self._operand_types(comp, op))

    def _op_flops(self, comp: str, op: Op) -> float:
        oc = op.opcode
        if oc == "dot":
            types = self._operand_types(comp, op)
            lhs = _SHAPE_RE.search(types[0]) if types else None
            if not lhs:
                return 0.0
            ldims = [int(x) for x in lhs.group(2).split(",") if x]
            m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
            contract = 1
            if m:
                for ix in m.group(1).split(","):
                    if ix:
                        contract *= ldims[int(ix)]
            return 2.0 * _result_numel(op.rtype) * contract
        if oc == "convolution":
            types = self._operand_types(comp, op)
            k = 1
            if len(types) > 1:
                m = _SHAPE_RE.search(types[1])
                if m:
                    k = _numel(m.group(2))
            return 2.0 * _result_numel(op.rtype) * max(k, 1)
        if oc in _ELEMENTWISE:
            return float(_result_numel(op.rtype))
        if oc in ("reduce", "reduce-window", "all-reduce", "all-reduce-start",
                  "reduce-scatter", "select-and-scatter"):
            types = self._operand_types(comp, op)
            m = _SHAPE_RE.search(types[0]) if types else None
            return float(_numel(m.group(2))) if m else 0.0
        return 0.0

    def _op_bytes(self, comp: str, op: Op) -> float:
        if op.opcode in _SKIP_BYTES:
            return 0.0
        if op.opcode in ("slice", "dynamic-slice", "gather"):
            # XLA cost semantics: a slice/gather touches the *extracted*
            # region (read) + result (write), not the whole source buffer.
            return 2.0 * self._bytes_of(op.rtype)
        if op.opcode == "dynamic-update-slice":
            # read + write of the update region only; the big buffer is
            # aliased through untouched.
            types = self._operand_types(comp, op)
            upd = self._bytes_of(types[1]) if len(types) > 1 else 0.0
            return 2.0 * upd
        if op.opcode in _ELEMENTWISE or op.opcode in _DATA_MOVE:
            return self._operand_bytes(comp, op) + self._bytes_of(op.rtype)
        return 0.0

    # Fusion byte model (mirrors HloCostAnalysis utilization semantics):
    # a fusion parameter consumed ONLY by slice/dynamic-slice/gather inside
    # the fusion contributes the sliced bytes, not the full buffer; a
    # parameter that is only the in-place target of a root dynamic-update-
    # slice contributes the update-region bytes; everything else reads
    # fully. The result side likewise: a DUS root writes its update region.
    # ``bitcast``/``copy``/``convert`` are pass-throughs for consumption
    # classification: the CPU host backend emulates bf16 by widening whole
    # buffers to f32 around in-place updates (convert → DUS → convert),
    # which a TPU compile performs as a single in-place bf16 DUS — counting
    # the widening converts would charge the full buffer per loop trip.
    _SLICE_LIKE = ("slice", "dynamic-slice", "gather")
    _TRANSPARENT = ("bitcast", "copy", "convert")

    def _fusion_bytes(self, comp: str, op: Op, called: str | None) -> float:
        full = self._operand_bytes(comp, op) + self._bytes_of(op.rtype)
        ops = self.comps.get(called or "", [])
        if not ops:
            return full
        # Pure dtype-cast fusions (convert/bitcast only) are free on TPU:
        # XLA fuses the cast into the producing/consuming op's register
        # stream. The CPU host backend materializes them because it
        # emulates bf16 in f32 — charging them would double-count the
        # neighbouring op's traffic.
        if all(o.opcode in ("parameter", "convert", "bitcast") for o in ops):
            return 0.0
        types = self._types.get(called, {})
        consumers: dict[str, list[Op]] = {}
        for o in ops:
            if o.opcode == "parameter":
                continue
            for nm in o.operand_names():
                consumers.setdefault(nm, []).append(o)

        def resolved_consumers(name: str, depth: int = 0) -> list[tuple[Op, str]]:
            """(consumer, consumed-as-name) pairs, looking through bitcasts."""
            out = []
            for c in consumers.get(name, []):
                if c.opcode in self._TRANSPARENT and depth < 8:
                    out.extend(resolved_consumers(c.name, depth + 1))
                else:
                    out.append((c, name))
            return out

        read = 0.0
        for p in (o for o in ops if o.opcode == "parameter"):
            cons = resolved_consumers(p.name)
            if cons and all(c.opcode in self._SLICE_LIKE for c, _ in cons):
                read += sum(self._bytes_of(c.rtype) for c, _ in cons)
            elif cons and all(
                c.opcode == "dynamic-update-slice"
                and c.operand_names()[:1] == [nm] for c, nm in cons
            ):
                for c, _ in cons:
                    onames = c.operand_names()
                    upd_t = types.get(onames[1], "") if len(onames) > 1 else ""
                    read += self._bytes_of(upd_t)
            else:
                read += self._bytes_of(p.rtype)

        def resolve_root(o: Op, depth: int = 0) -> Op:
            if o.opcode in self._TRANSPARENT and depth < 8:
                src = o.operand_names()
                tgt = next((x for x in ops if x.name == src[0]), None) if src else None
                if tgt is not None:
                    return resolve_root(tgt, depth + 1)
            return o

        root = resolve_root(ops[-1])
        if root.opcode == "dynamic-update-slice":
            onames = root.operand_names()
            upd_t = types.get(onames[1], "") if len(onames) > 1 else ""
            write = self._bytes_of(upd_t)
        else:
            write = self._bytes_of(op.rtype)
        return read + write

    # ------------------------------------------------------------------
    def comp_cost(self, name: str, inside_fusion: bool = False) -> dict:
        key = (name, inside_fusion)
        if key in self._memo:
            return self._memo[key]
        total = {"flops": 0.0, "bytes": 0.0, "coll": {}, "dot_flops": 0.0}
        for op in self.comps.get(name, []):
            oc = op.opcode
            if oc == "fusion":
                called = op.attr("calls")
                if called:
                    sub = self.comp_cost(called, True)
                    total["flops"] += sub["flops"]
                    total["dot_flops"] += sub["dot_flops"]
                    _merge_coll(total["coll"], sub["coll"], 1.0)
                if not inside_fusion:
                    total["bytes"] += self._fusion_bytes(name, op, called)
                continue
            if oc == "while":
                body = op.attr("body")
                cond = op.attr("condition")
                trip = 1.0
                m = _TRIP_RE.search(op.rest)
                if m:
                    trip = float(m.group(1))
                for sub_name in (body, cond):
                    if sub_name:
                        sub = self.comp_cost(sub_name, inside_fusion)
                        total["flops"] += trip * sub["flops"]
                        total["dot_flops"] += trip * sub["dot_flops"]
                        total["bytes"] += trip * sub["bytes"]
                        _merge_coll(total["coll"], sub["coll"], trip)
                continue
            if oc in ("call", "async-start"):
                called = op.attr("to_apply") or op.attr("called_computation")
                if called:
                    sub = self.comp_cost(called, inside_fusion)
                    for k in ("flops", "dot_flops", "bytes"):
                        total[k] += sub[k]
                    _merge_coll(total["coll"], sub["coll"], 1.0)
                continue
            if oc == "conditional":
                branches = re.findall(r"branch_computations=\{([^}]*)\}", op.rest)
                names = re.findall(r"%([\w.\-]+)", branches[0]) if branches else []
                for b in (op.attr("true_computation"), op.attr("false_computation")):
                    if b:
                        names.append(b)
                subs = [self.comp_cost(b, inside_fusion) for b in names]
                if subs:
                    big = max(subs, key=lambda s: s["flops"])
                    for k in ("flops", "dot_flops", "bytes"):
                        total[k] += big[k]
                    _merge_coll(total["coll"], big["coll"], 1.0)
                continue

            f = self._op_flops(name, op)
            total["flops"] += f
            if oc == "dot":
                total["dot_flops"] += f
            if not inside_fusion:
                total["bytes"] += self._op_bytes(name, op)
            base = oc.removesuffix("-start")
            if base in _COLLECTIVES and not oc.endswith("-done"):
                nbytes = self._operand_bytes(name, op)
                rec = total["coll"].setdefault(base, {"count": 0.0, "bytes": 0.0})
                rec["count"] += 1
                rec["bytes"] += nbytes
        self._memo[key] = total
        return total

    def totals(self) -> dict:
        t = self.comp_cost(self.entry)
        coll_bytes = sum(v["bytes"] for v in t["coll"].values())
        return {
            "flops": t["flops"], "dot_flops": t["dot_flops"],
            "bytes": t["bytes"], "collectives": t["coll"],
            "collective_bytes": coll_bytes,
        }


def _merge_coll(dst: dict, src: dict, mult: float) -> None:
    for k, v in src.items():
        rec = dst.setdefault(k, {"count": 0.0, "bytes": 0.0})
        rec["count"] += v["count"] * mult
        rec["bytes"] += v["bytes"] * mult


def analyze_text(text: str, zero_s2_seq: int | None = None) -> dict:
    return HloCost(text, zero_s2_seq=zero_s2_seq).totals()

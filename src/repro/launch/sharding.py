"""Sharding rules: parameter/optimizer/batch/cache PartitionSpecs.

Strategy (DESIGN.md §5): batch over ("pod","data"); TP over "model"
(heads / d_ff / vocab / experts); FSDP (ZeRO-3 style) over "data"
[+"pod"] on each weight's non-TP matrix dim. Rules are regex → spec-
builder over the flattened param path; stacked layer leaves (under
``layers``/``enc_layers``) get a leading None for the scan dim.
"""
from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axes(mesh: Mesh):
    names = mesh.axis_names
    batch = tuple(a for a in ("pod", "data") if a in names)
    if len(batch) == 1:
        # Bare name, not a 1-tuple: PartitionSpec treats P(("data",)) and
        # P("data") as distinct specs, and consumers compare against the
        # bare-name form.
        batch = batch[0]
    fsdp = batch  # ZeRO across pods too
    model = "model" if "model" in names else None
    return batch or None, (fsdp or None), model


# rule table: regex on ".../leaf" path → f(batch, fsdp, model) → P(...)
_RULES: list[tuple[str, Any]] = [
    # embeddings / unembedding
    (r"embed/table$",            lambda b, f, m: P(m, f)),
    (r"unembed/w$",              lambda b, f, m: P(f, m)),
    # attention
    (r"attn.*/w[qkv]$",          lambda b, f, m: P(f, m)),
    (r"attn.*/wo$",              lambda b, f, m: P(m, f)),
    (r"(q|k)_norm/scale$",       lambda b, f, m: P()),
    # dense mlp / shared expert
    (r"(mlp|shared)/w_(gate|up)$", lambda b, f, m: P(f, m)),
    (r"(mlp|shared)/w_down$",    lambda b, f, m: P(m, f)),
    (r"mlp/b_up$",               lambda b, f, m: P(m)),
    (r"mlp/b_down$",             lambda b, f, m: P()),
    # MoE experts: EP over model when E divides it, else TP over moe_d_ff
    # (shape-aware — see _spec_for_path special case below)
    (r"moe/router$",             lambda b, f, m: P(f, None)),
    # mamba2
    (r"mamba/w_in$",             lambda b, f, m: P(f, m)),
    (r"mamba/w_out$",            lambda b, f, m: P(m, f)),
    (r"mamba/conv_[wb]$",        lambda b, f, m: P(None, m) if True else P()),
    (r"mamba/norm_scale$",       lambda b, f, m: P(m)),
    (r"mamba/(a_log|dt_bias|d_skip)$", lambda b, f, m: P()),
    # rwkv6
    (r"tm_cm/w_[rkvg]$",         lambda b, f, m: P(f, m)),
    (r"tm_cm/w_o$",              lambda b, f, m: P(m, f)),
    (r"tm_cm/cm_[kr]$",          lambda b, f, m: P(f, m)),
    (r"tm_cm/cm_v$",             lambda b, f, m: P(m, f)),
    (r"tm_cm/w_lora_a$",         lambda b, f, m: P(f, None)),
    (r"tm_cm/w_lora_b$",         lambda b, f, m: P(None, f)),
    (r"tm_cm/(mu_.|cm_mu|w0|u_bonus|ln_scale|ln_bias)$", lambda b, f, m: P()),
    # norms & anything 1-D
    (r"(ln\d?|ln_x|final_norm|enc_final_norm)/(scale|bias)$", lambda b, f, m: P()),
]


def _spec_for_path(path: str, shape: tuple, mesh: Mesh) -> P:
    ndim = len(shape)
    b, f, m = _axes(mesh)
    n_model = dict(mesh.shape).get("model", 1)
    stacked = path.startswith(("layers/", "enc_layers/")) or "/layers/" in path
    if re.search(r"moe/w_(gate|up|down)$", path):
        # stacked leaf: [L, E, d, f] / [L, E, f, d]
        e = shape[1] if stacked else shape[0]
        if m and e % n_model == 0:
            spec = P(m, f, None) if path.endswith(("gate", "up")) else P(m, None, f)
        else:  # EP impossible → replicate experts, TP the ffn dim
            spec = P(None, f, m) if path.endswith(("gate", "up")) else P(None, m, f)
        parts = ([None] if stacked else []) + list(spec)
        return P(*parts)
    for pat, fn in _RULES:
        if re.search(pat, path):
            spec = fn(b, f, m)
            break
    else:
        spec = P()
    parts = list(spec)
    # pad/truncate to tensor rank (minus stack dim)
    want = ndim - (1 if stacked else 0)
    parts = (parts + [None] * want)[:want]
    if stacked:
        parts = [None] + parts
    return _validate(P(*parts), shape, mesh)


def _validate(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop axes whose mesh size doesn't divide the dim (e.g. odd vocabs:
    whisper 51865, internvl 151655 — those fall back to replicated on that
    dim; FSDP/TP still applies to the other dims)."""
    sizes = dict(mesh.shape)
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        prod = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            prod *= sizes.get(a, 1)
        out.append(ax if dim % prod == 0 else None)
    return P(*out)


def _path_str(path) -> str:
    out = []
    for k in path:
        out.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return "/".join(out)


def param_specs(params_shape, mesh: Mesh):
    """Pytree of PartitionSpec matching a params (or shape) pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for_path(_path_str(path), tuple(leaf.shape), mesh),
        params_shape,
    )


def opt_state_specs(opt_shape, params_spec, mesh: Mesh):
    """m/v/master shard exactly like their parameter; step replicated."""
    return {
        "m": params_spec, "v": params_spec, "master": params_spec,
        "step": P(),
    }


def batch_specs(batch_shape, mesh: Mesh):
    """Token batches: batch dim over ("pod","data") when divisible."""
    b, f, m = _axes(mesh)
    n_batch = 1
    if b:
        for ax in (b if isinstance(b, tuple) else (b,)):
            n_batch *= mesh.shape[ax]

    def spec(path, leaf):
        if len(leaf.shape) == 0:
            return P()
        bdim = leaf.shape[0]
        first = b if b and bdim % max(n_batch, 1) == 0 and bdim >= n_batch else None
        rest = [None] * (len(leaf.shape) - 1)
        # embeddings streams ([B, S, d_model] stubs) put d_model on model
        if len(leaf.shape) == 3 and path.endswith(("frames", "patches")):
            rest = [None, None]
        return P(first, *rest)

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec(_path_str(path), leaf), batch_shape)


def cache_specs_tree(cache_shape, mesh: Mesh):
    """Decode caches: batch over DP axes when divisible, else shard the
    sequence axis (long_500k, B=1); heads over model."""
    b, f, m = _axes(mesh)
    n_batch = 1
    if b:
        for ax in (b if isinstance(b, tuple) else (b,)):
            n_batch *= mesh.shape[ax]

    n_model = dict(mesh.shape).get("model", 1)

    def spec(path, leaf):
        shape = leaf.shape
        nd = len(shape)
        if nd == 0:
            return P()
        leaf_name = path.split("/")[-1]
        # layout: [L, B, ...] (stacked caches)
        batch_ok = nd >= 2 and shape[1] % max(n_batch, 1) == 0 and shape[1] >= n_batch
        parts = [None] * nd
        if batch_ok:
            parts[1] = b
        if leaf_name in ("ssm", "wkv"):
            # [L, B, H, N, P] / [L, B, H, k, k]
            if nd == 5 and m and shape[2] % n_model == 0:
                parts[2] = m
        elif leaf_name == "conv":
            if nd == 4 and m and shape[3] % n_model == 0:
                parts[3] = m
        elif leaf_name in ("tm_shift", "cm_shift"):
            if nd == 3 and m and shape[2] % n_model == 0:
                parts[2] = m
        elif nd == 5:
            # attention caches [L, B, Hkv, S, hd]: TP on heads when they
            # divide; otherwise sequence-parallel the cache over "model".
            if m and shape[2] % n_model == 0:
                parts[2] = m
            elif m and shape[3] % n_model == 0:
                parts[3] = m
            if not batch_ok and b and shape[3] % n_batch == 0 and parts[3] is None:
                parts[3] = b           # long-context B=1: SP over DP axes too
        return P(*parts)

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec(_path_str(path), leaf), cache_shape)


def to_named(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def spmd_epoch_specs(axis_name: str = "data"):
    """(in_specs, out_specs) for the analytics SPMD epoch under
    ``shard_map`` (``repro.api.compile(spec, mesh=...)``): epoch batches
    are ``IntervalBatch``es with a leading tick axis — items sharded
    over ``axis_name`` on the item axis, per-tick metadata sets
    replicated; the root's (sum, mean) results are replicated (every
    device computes the root stage redundantly)."""
    from repro.core.types import IntervalBatch, StratumMeta

    item = P(None, axis_name)
    in_specs = (P(), IntervalBatch(item, item, item,
                                   StratumMeta(P(), P())))
    out_specs = (P(), P())
    return in_specs, out_specs


def spmd_query_epoch_specs(axis_name: str, qstate):
    """Sketch-aware ``shard_map`` spec components for the SPMD query
    plane (``repro.api.spmd`` tenant lowering).

    Per-device sketch state leaves carry a leading device axis sharded
    over ``axis_name`` (each device owns exactly its own quantile
    buffers / CM tables / top-k slots — they all-gather as O(sketch)
    summaries at the window boundary, never as items); epoch batches
    stay item-sharded on their trailing axis; everything the root
    returns (per-window answers, bounds, built-in workload) is
    replicated. Returns ``dict(qstate=..., batches=..., replicated=P())``
    — components, because the caller owns the state/output pytree
    structure they assemble into."""
    from repro.core.types import IntervalBatch, StratumMeta

    item = P(None, axis_name)
    return dict(
        qstate=jax.tree.map(lambda _: P(axis_name), qstate),
        batches=IntervalBatch(item, item, item, StratumMeta(P(), P())),
        replicated=P(),
    )

"""Mesh context: logical-axis sharding constraints that degrade gracefully.

Model code annotates activations with *logical* axes ("batch", "model",
"seq", ...). When a mesh is installed (launch/dry-run), these resolve to
``with_sharding_constraint`` over physical axes; in single-device smoke
tests they are no-ops. Batch maps to ``("pod","data")`` when a pod axis
exists, so the same model code serves both production meshes.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

# logical name -> candidate physical axes, first present in the mesh win(s).
_LOGICAL = {
    "batch": ("pod", "data"),       # all present axes combined
    "fsdp": ("data",),              # weight-shard axis
    "fsdp_pod": ("pod", "data"),    # weight-shard incl. pod (ZeRO across pods)
    "model": ("model",),
    "expert": ("model",),
    None: (),
}


def current_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        with mesh:  # legacy Mesh context (axis-name resolution for pjit)
            yield mesh
    finally:
        _state.mesh = prev


def resolve_spec(*logical: str | None) -> P:
    """Translate logical axis names into a PartitionSpec for current mesh."""
    mesh = current_mesh()
    names = set(mesh.axis_names) if mesh is not None else set()
    out = []
    for ax in logical:
        if ax is None:
            out.append(None)
            continue
        phys = tuple(a for a in _LOGICAL.get(ax, (ax,)) if a in names)
        if not phys:
            out.append(None)
        elif len(phys) == 1:
            out.append(phys[0])
        else:
            out.append(phys)
    return P(*out)


def shard(x: jax.Array, *logical: str | None) -> jax.Array:
    """Constrain activation sharding; identity when no mesh installed."""
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, resolve_spec(*logical))
    )

"""Serving launcher: the ApproxIoT telemetry plane over an inference
fleet, in two modes.

**One-shot** (default): batched prefill + decode, then every serving
batch's per-request latency records become one tick of ingest into the
emulated edge hierarchy (edge aggregators → datacenter root) on a REAL
compiled pipeline, where the dashboard's standing queries (request
count → QPS, mean latency, p50/p99 via the quantile sketch) are a query
**tenant** answered at the root every window. One ``PipelineSpec``
declares the whole thing; one fused dispatch runs the epoch.

**Continuous** (``--serve-loop``): the same telemetry plane behind the
always-on ``repro.serve.StreamingExecutor`` — subscribed sources feed
bounded per-shard queues (``--backpressure`` policy), ingest
double-buffers against the in-flight device epoch, and every root
window publishes straggler-tolerantly: late shards yield *partial*
answers with Eq. 9-widened bounds and their data folds into the next
window. The loop registry adds the serve plane's recency queries
(sliding-window quantiles, decayed heavy hitters); ``stop()`` drains
the queues clean. ``--inject-straggler`` forces one edge shard late for
an epoch to demonstrate the partial-window path.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
        --requests 64 --decode-len 16
    PYTHONPATH=src python -m repro.launch.serve --serve-loop --duration 5 \
        --smoke --inject-straggler
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.configs import registry
from repro.data import stream as S
from repro.models import model as M
from repro.obs import telemetry as obs_telemetry
from repro.obs.metrics import metrics_text
from repro.obs.trace import get_tracer, span
from repro.optim import train_step
from repro.query.registry import QueryRegistry


NUM_CLASSES = 4          # request classes = telemetry strata
EDGE_NODES = 2           # telemetry aggregators in front of the root


def dashboard_registry() -> QueryRegistry:
    """The dashboard's standing queries, registered once."""
    return (QueryRegistry()
            .register_count("requests")
            .register_sum("latency_total_ms")
            .register_mean("latency_mean_ms")
            .register_quantile("latency_q_ms", qs=(0.5, 0.99), capacity=256))


def serve_registry(window: int = 4) -> QueryRegistry:
    """The continuous dashboard: everything the one-shot dashboard
    answers plus the serve plane's recency queries — "last ``window``
    windows" latency quantiles and exponentially decayed hot-class
    counts (a stream-so-far sketch never forgets old load)."""
    return (dashboard_registry()
            .register_windowed_quantile("latency_q_recent_ms",
                                        qs=(0.5, 0.99), capacity=128,
                                        window=window)
            .register_decayed_heavy_hitters("hot_latency_keys", k=4,
                                            width=256, decay=0.8))


def telemetry_spec(capacity: int, fraction: float, seed: int = 0,
                   telemetry: bool = False,
                   registry_fn=dashboard_registry) -> api.PipelineSpec:
    """The serving fleet's telemetry plane as one declarative spec:
    per-request records → 2 edge aggregators → 1 datacenter root, the
    dashboard (``registry_fn()``) as a query tenant on the shared tree."""
    return api.PipelineSpec(
        topology=api.TopologySpec(fanin=(EDGE_NODES, 1), capacity=capacity,
                                  num_strata=NUM_CLASSES),
        sampler=api.SamplerSpec(mode="whs", backend="topk",
                                fraction=fraction),
        tenants=(registry_fn().as_tenant("dashboard"),),
        telemetry=api.TelemetrySpec(enabled=telemetry),
        seed=seed,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=registry.ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode-len", type=int, default=16)
    ap.add_argument("--telemetry-fraction", type=float, default=0.25)
    ap.add_argument("--hot-admit", action="store_true",
                    help="demo tenant churn on the live telemetry plane "
                         "(local path): serve half the epoch with the "
                         "dashboard tenant only, hot-admit an 'slo' "
                         "tenant mid-stream (a state edit, not a "
                         "recompile), answer its queries over the second "
                         "half, then retire + re-admit it and print the "
                         "zero-retrace evidence from the plan/program "
                         "caches")
    ap.add_argument("--mesh", type=int, default=None, metavar="N",
                    help="run the telemetry plane on an N-device 'data' "
                         "mesh (repro.api.compile(spec, mesh=...)): each "
                         "device samples its shard of every batch's "
                         "records and the dashboard tenant answers from "
                         "merged sketch summaries — no raw records cross "
                         "devices. CPU: export XLA_FLAGS=--xla_force_"
                         "host_platform_device_count=N")
    ap.add_argument("--telemetry", action="store_true",
                    help="carry EpochTelemetry counters inside the "
                         "pipeline state (repro.obs) — sample state and "
                         "dashboard answers stay bit-identical")
    ap.add_argument("--metrics-dump", default=None, metavar="PATH",
                    help="write a Prometheus-text metrics snapshot of the "
                         "telemetry plane to PATH at exit (implies "
                         "--telemetry)")
    ap.add_argument("--metrics-every", type=int, default=None, metavar="N",
                    help="print a metrics snapshot to stdout every N "
                         "telemetry windows during the epoch (implies "
                         "--telemetry; local path only)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write the host span tracer's Chrome/Perfetto "
                         "trace.json to PATH")
    ap.add_argument("--serve-loop", action="store_true",
                    help="continuous mode: run the telemetry plane behind "
                         "the always-on repro.serve.StreamingExecutor "
                         "(bounded queues, double-buffered ingest, "
                         "straggler-tolerant windows) instead of one "
                         "one-shot epoch; --requests/--batch set the "
                         "epoch length in ticks")
    ap.add_argument("--duration", type=float, default=5.0, metavar="SEC",
                    help="serve-loop: wall-clock seconds to pump before "
                         "draining")
    ap.add_argument("--tick-interval", type=float, default=0.02,
                    metavar="SEC",
                    help="serve-loop: target seconds between pumps")
    ap.add_argument("--backpressure", default="block",
                    choices=("block", "drop_oldest", "degrade"),
                    help="serve-loop: bounded-queue policy when ingest "
                         "outruns the device")
    ap.add_argument("--queue-capacity", type=int, default=4096,
                    help="serve-loop: per-shard bounded queue capacity")
    ap.add_argument("--inject-straggler", action="store_true",
                    help="serve-loop: hold one edge shard's deliveries "
                         "for a full epoch so partial windows with "
                         "widened bounds publish, then fold the late "
                         "data into the next window")
    args = ap.parse_args(argv)
    if args.metrics_dump or args.metrics_every:
        args.telemetry = True

    # Requests are served (and in loop mode, staged) in whole batches —
    # the same check guards both modes with the same actionable error.
    n_batches = args.requests // args.batch
    if n_batches == 0:
        ap.error(f"--requests {args.requests} < --batch {args.batch}: "
                 f"no serving batch would run (requests are served in "
                 f"whole batches)")

    if args.serve_loop:
        return _serve_loop(args)

    cfg = registry.get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    max_len = args.prompt_len + args.decode_len

    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    decode = jax.jit(train_step.make_decode_step(cfg), donate_argnums=(1,))

    rng = np.random.default_rng(0)
    tick_records: list[tuple[np.ndarray, np.ndarray]] = []
    t_all = time.time()
    for b in range(n_batches):
        toks = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len))
        cache = M.init_cache(cfg, args.batch, max_len)
        t0 = time.time()
        # prefill via repeated decode (teacher-forcing the prompt) — keeps
        # one compiled step; a production path would use a prefill kernel.
        tok = jnp.asarray(toks[:, :1], jnp.int32)
        for pos in range(args.prompt_len - 1):
            _, cache = decode(params, cache, jnp.asarray(toks[:, pos:pos+1], jnp.int32),
                              jnp.int32(pos))
        for pos in range(args.prompt_len - 1, max_len):
            logits, cache = decode(params, cache, tok, jnp.int32(pos))
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        jax.block_until_ready(tok)
        dt = (time.time() - t0) / args.batch
        # one tick of telemetry per serving batch: ms per request,
        # stratified by request class
        tick_records.append((
            np.full((args.batch,), dt * 1000, np.float32),
            rng.integers(0, NUM_CLASSES, args.batch).astype(np.int32)))
    wall = time.time() - t_all

    # ---- telemetry through the real pipeline -----------------------------
    # Each serving batch is one tick into the 2→1 hierarchy; the compiled
    # pipeline samples at every hop and the dashboard tenant's standing
    # queries are answered at the root each window — one fused dispatch
    # for the whole epoch. With --mesh the SAME spec lowers onto the
    # §III-E SPMD data plane instead: every device samples its shard of
    # each batch's records and the dashboard tenant answers from MERGED
    # sketch summaries — no raw record crosses a device boundary.
    capacity = max(64, args.batch)
    m = sum(len(v) for v, _ in tick_records)
    if args.mesh:
        from repro.launch.analytics import make_data_mesh

        pipe = api.compile(telemetry_spec(capacity, args.telemetry_fraction,
                                          telemetry=args.telemetry),
                           mesh=make_data_mesh(args.mesh))
        with span("ingest", ticks=len(tick_records)):
            flat = S.ticks_to_ingest(tick_records, n_nodes=1, width=capacity)
            width = -(-capacity // args.mesh) * args.mesh
            batches = S.rows_to_interval_batch(
                flat.values[:, 0], flat.strata[:, 0], flat.counts[:, 0],
                NUM_CLASSES, width=width)
        state = pipe.init()
        with span("epoch_dispatch", ticks=len(tick_records)):
            state, wa = pipe.run_epoch(state, pipe.default_key, batches)
        with span("block_until_ready"):
            jax.block_until_ready(wa)
    else:
        pipe = api.compile(telemetry_spec(capacity, args.telemetry_fraction,
                                          telemetry=args.telemetry))
        state = pipe.init()
        with span("ingest", ticks=len(tick_records)):
            batch = S.ticks_to_ingest(tick_records, n_nodes=EDGE_NODES,
                                      width=capacity)
        if args.hot_admit:
            from repro.api.pipeline import program_cache_stats

            h = max(1, len(tick_records) // 2)
            with span("epoch_dispatch", ticks=h):
                state, waA = pipe.run_epoch(state, pipe.default_key,
                                            batch.values[:h],
                                            batch.strata[:h],
                                            batch.counts[:h])
            rows_a = pipe.rows(waA)
            m0 = program_cache_stats()["misses"]
            slo = (QueryRegistry().register_count("n")
                   .register_mean("mean_ms")
                   .register_quantile("p999_ms", qs=(0.999,), capacity=128)
                   .as_tenant("slo"))
            # hot admit: slot edit on the carried state, answers resume
            # mid-stream — the dashboard tenant's sketches are untouched
            pipe2, state = pipe.admit(state, slo)
            with span("epoch_dispatch", ticks=len(batch.values) - h):
                state, waB = pipe2.run_epoch(state, pipe2.default_key,
                                             batch.values[h:],
                                             batch.strata[h:],
                                             batch.counts[h:])
            rows_b = pipe2.rows(waB)
            m1 = program_cache_stats()["misses"]
            pipe3, state = pipe2.retire(state, "slo")
            pipe4, state = pipe3.admit(state, slo)
            m2 = program_cache_stats()["misses"]
            last_b = rows_b[-1]
            slo_n = float(sum(pipe2.answer(r["answers"], "n", tenant="slo")[0]
                              for r in rows_b))
            p999 = float(pipe2.answer(last_b["answers"], "p999_ms",
                                      tenant="slo")[0])
            print(f"hot-admit 'slo' tenant after {h}/{len(tick_records)} "
                  f"ticks: {len(rows_b)} windows answered mid-stream "
                  f"({slo_n:.0f} requests seen, p99.9 ≈ {p999:.2f} ms)")
            print(f"  churn cost: admit into a new slot group traced "
                  f"{m1 - m0} program(s); retire + re-admit into the warm "
                  f"slot traced {m2 - m1} (plan cache: "
                  f"{program_cache_stats()['hits']} hits)")
            rows = rows_a + rows_b
            row_pipes = [pipe] * len(rows_a) + [pipe2] * len(rows_b)
            pipe = pipe4
        else:
            # --metrics-every N slices the epoch into N-tick chunks and
            # exposes the /metrics surface between dispatches; without it
            # the single chunk is the whole epoch (identical behaviour).
            n_ticks = len(batch.values)
            step = args.metrics_every or n_ticks
            chunk_rows = []
            for s0 in range(0, n_ticks, max(step, 1)):
                s1 = min(s0 + max(step, 1), n_ticks)
                with span("epoch_dispatch", ticks=s1 - s0):
                    state, wa = pipe.run_epoch(
                        state, pipe.default_key, batch.values[s0:s1],
                        batch.strata[s0:s1], batch.counts[s0:s1])
                with span("block_until_ready"):
                    jax.block_until_ready(wa)
                chunk_rows.extend(pipe.rows(wa))
                if args.metrics_every:
                    print(f"--- metrics after {s1}/{n_ticks} ticks ---")
                    print(metrics_text(pipeline=pipe, state=state,
                                       tracer=get_tracer()))
    if args.mesh:
        rows = pipe.rows(wa)
        row_pipes = [pipe] * len(rows)
    elif not args.hot_admit:
        rows = chunk_rows
        row_pipes = [pipe] * len(rows)
    # rows from before/after a hot admit carry different layouts — answer
    # each row through the pipeline that produced it
    pipe_of = {id(r): p for p, r in zip(row_pipes, rows)}
    a = lambda name, row: pipe_of[id(row)].answer(row["answers"], name,
                                                  tenant="dashboard")
    bnd = lambda name, row: pipe_of[id(row)].answer(row["bounds"], name,
                                                    tenant="dashboard")

    # CLT queries aggregate across windows; the quantile sketch is
    # continuous (its state spans the whole epoch), so the last window
    # answers over every request served.
    last = rows[-1]
    n_est = float(sum(a("requests", r)[0] for r in rows))
    total_est = float(sum(a("latency_total_ms", r)[0] for r in rows))
    mean_est = total_est / max(n_est, 1e-9)
    mean_bnd = float(max(bnd("latency_mean_ms", r)[0] for r in rows))
    p50, p99 = a("latency_q_ms", last)
    exact_all = np.concatenate([v for v, _ in tick_records])
    exact_mean = float(exact_all.mean())
    n_kept = int(sum(r["n_sampled"] for r in rows))
    plane = (f"{args.mesh}-device SPMD mesh (merged sketch summaries)"
             if args.mesh else f"{EDGE_NODES}→1 hierarchy")
    print(f"served {m} requests in {wall:.1f}s")
    print(f"telemetry plane: {len(rows)} windows through the "
          f"{plane}, {pipe.plan.k} standing queries, "
          f"1 fused dispatch, {n_kept}/{m} records at the root")
    print(f"  QPS              ≈ {n_est / max(wall, 1e-9):.2f}")
    print(f"  total latency-ms ≈ {total_est:.1f} "
          f"± {float(sum(bnd('latency_total_ms', r)[0] for r in rows)):.1f}"
          f" (2σ)")
    print(f"  mean latency-ms  ≈ {mean_est:.2f} ± {mean_bnd:.2f} "
          f"(exact {exact_mean:.2f})")
    print(f"  p50 / p99 ms     ≈ {float(p50):.2f} / {float(p99):.2f} "
          f"(sketch rank-ε {float(bnd('latency_q_ms', last)[0]):.3f})")
    snap = obs_telemetry.snapshot(state)
    if snap is not None:
        print(f"  telemetry        {snap['windows']} windows, realized "
              f"±2σ {snap['bound_2sigma']:.3e} "
              f"(rel {snap['rel_bound_2sigma']:.4f})"
              + (f", {snap['merge_bytes']:.0f} sketch bytes merged"
                 if args.mesh else ""))
    if args.metrics_dump:
        text = metrics_text(pipeline=pipe, state=state, tracer=get_tracer())
        with open(args.metrics_dump, "w") as f:
            f.write(text)
        print(f"  wrote {args.metrics_dump}")
    if args.trace:
        get_tracer().save(args.trace)
        print(f"  wrote {args.trace}")
    return mean_est, exact_mean


def _serve_loop(args):
    """Continuous mode: the telemetry plane behind the streaming
    executor (see module doc). Returns the executor's final stats."""
    from repro.serve import (LateShardSource, StreamingExecutor,
                             SyntheticSource)

    epoch_ticks = args.requests // args.batch
    capacity = max(64, args.batch)
    pipe = api.compile(telemetry_spec(capacity, args.telemetry_fraction,
                                      telemetry=args.telemetry,
                                      registry_fn=serve_registry))
    # Per-shard synthetic request-latency sources: NUM_CLASSES request
    # classes with distinct latency profiles (ms); class = stratum.
    per_class = max(2, args.batch // (EDGE_NODES * NUM_CLASSES))
    sources = [SyntheticSource(
        shard, specs=[S.SubstreamSpec("gaussian",
                                      (20.0 * 2 ** c, 2.0 * 2 ** c),
                                      per_class)
                      for c in range(NUM_CLASSES)], seed=shard)
        for shard in range(EDGE_NODES)]
    if args.inject_straggler:
        # Hold the last shard's deliveries for one full epoch starting
        # at the second: the affected windows publish partial (widened
        # bounds) and the backlog folds into the following window.
        sources[-1] = LateShardSource(sources[-1], epoch_ticks,
                                      2 * epoch_ticks)
    ex = StreamingExecutor(epoch_ticks=epoch_ticks, width=capacity,
                           queue_capacity=args.queue_capacity,
                           policy=args.backpressure)
    ex.start(pipe, sources)
    t0 = time.time()
    ticks = 0
    with span("serve_loop", duration=args.duration):
        while time.time() - t0 < args.duration:
            tick_t0 = time.time()
            ex.pump()
            ticks += 1
            sleep = args.tick_interval - (time.time() - tick_t0)
            if sleep > 0:
                time.sleep(sleep)
    summary = ex.stop()
    wall = time.time() - t0
    print(f"serve-loop: {ticks} ticks in {wall:.1f}s — "
          f"{summary['epochs']} epochs of {epoch_ticks} ticks, "
          f"backpressure={args.backpressure}"
          + (", straggler injected" if args.inject_straggler else ""))
    print(f"  windows published    {summary['windows_published']} "
          f"({summary['windows_partial']} partial, bounds widened 1/α)")
    print(f"  queue accounting     in {summary['queue_items_in']}, "
          f"dropped {summary['queue_items_dropped']}, deferred "
          f"{summary['queue_deferred']}, high-watermark "
          f"{summary['queue_high_watermark']}, drained to depth "
          f"{max(summary['queue_depth'], default=0)}")
    print(f"  ingest/dispatch overlap {summary['overlap_fraction']:.2f} "
          f"(measured while a device epoch was in flight)")
    print(f"  window latency       p50 {summary['latency_p50'] * 1e3:.1f} "
          f"ms / p99 {summary['latency_p99'] * 1e3:.1f} ms "
          f"(arrival → published answer)")
    if ex.published:
        last = ex.published[-1]
        p50, p99 = last.raw["answers"][
            slice(*_qslice(pipe, "latency_q_ms"))]
        r50, r99 = last.raw["answers"][
            slice(*_qslice(pipe, "latency_q_recent_ms"))]
        print(f"  latency p50/p99 ms   stream-so-far ≈ {float(p50):.1f} / "
              f"{float(p99):.1f}; recent windows ≈ {float(r50):.1f} / "
              f"{float(r99):.1f}")
    snap = obs_telemetry.snapshot(ex.state)
    if snap is not None:
        print(f"  telemetry            {snap['late_shards']} late shards, "
              f"{snap['widened_windows']} widened windows "
              f"(in-graph counters)")
    if args.metrics_dump:
        text = metrics_text(pipeline=pipe, state=ex.state,
                            tracer=get_tracer(), straggler=ex.monitor,
                            executor=ex)
        with open(args.metrics_dump, "w") as f:
            f.write(text)
        print(f"  wrote {args.metrics_dump}")
    if args.trace:
        get_tracer().save(args.trace)
        print(f"  wrote {args.trace}")
    return summary


def _qslice(pipe, name: str) -> tuple[int, int]:
    o, w, _ = pipe.query_layout()[name]
    return o, o + w


if __name__ == "__main__":
    main()

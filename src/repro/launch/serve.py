"""Serving launcher: batched prefill + decode with approximate telemetry.

The request stream is the ApproxIoT input: per-request latency/token
records form sub-streams (stratified by request class), and the serving
dashboard is the first consumer of the continuous query plane: its
standing queries (request count → QPS, mean latency, p50/p99 via the
quantile sketch) are registered once in a ``repro.query`` registry and
answered together from ONE weighted sample — instead of logging every
request or issuing ad-hoc per-metric query calls. The paper's analytics
plane applied to an inference fleet.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
        --requests 64 --decode-len 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core import whs
from repro.core.types import IntervalBatch, StratumMeta
from repro.models import model as M
from repro.optim import train_step
from repro.query.registry import QueryRegistry


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=registry.ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode-len", type=int, default=16)
    ap.add_argument("--telemetry-fraction", type=float, default=0.25)
    args = ap.parse_args(argv)

    cfg = registry.get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    max_len = args.prompt_len + args.decode_len

    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    decode = jax.jit(train_step.make_decode_step(cfg), donate_argnums=(1,))

    rng = np.random.default_rng(0)
    lat_records, lat_strata = [], []
    t_all = time.time()
    n_batches = args.requests // args.batch
    for b in range(n_batches):
        toks = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len))
        cache = M.init_cache(cfg, args.batch, max_len)
        t0 = time.time()
        # prefill via repeated decode (teacher-forcing the prompt) — keeps
        # one compiled step; a production path would use a prefill kernel.
        tok = jnp.asarray(toks[:, :1], jnp.int32)
        for pos in range(args.prompt_len - 1):
            _, cache = decode(params, cache, jnp.asarray(toks[:, pos:pos+1], jnp.int32),
                              jnp.int32(pos))
        for pos in range(args.prompt_len - 1, max_len):
            logits, cache = decode(params, cache, tok, jnp.int32(pos))
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        jax.block_until_ready(tok)
        dt = (time.time() - t0) / args.batch
        lat_records += [dt * 1000] * args.batch              # ms per request
        lat_strata += list(rng.integers(0, 4, args.batch))   # request class

    # ---- approximate telemetry through the query registry ----------------
    # The dashboard's standing queries, registered once; the compiled plan
    # answers all of them from the same weighted sample in one evaluation.
    wall = time.time() - t_all
    dash = (QueryRegistry()
            .register_count("requests")
            .register_sum("latency_total_ms")
            .register_mean("latency_mean_ms")
            .register_quantile("latency_q_ms", qs=(0.5, 0.99), capacity=256))
    plan = dash.compile(num_strata=4)

    m = len(lat_records)
    batch = IntervalBatch(
        value=jnp.asarray(lat_records, jnp.float32),
        stratum=jnp.asarray(lat_strata, jnp.int32),
        valid=jnp.ones((m,), bool),
        meta=StratumMeta.identity(4),
    )
    res = whs.whsamp(jax.random.PRNGKey(1), batch,
                     jnp.float32(args.telemetry_fraction * m), 4)
    _, answers, bounds = plan.evaluate(jax.random.PRNGKey(2), batch, res,
                                       plan.init_state())
    answers, bounds = np.asarray(answers), np.asarray(bounds)
    a = lambda name: plan.answer(answers, name)
    b = lambda name: plan.answer(bounds, name)

    exact_mean = float(np.mean(lat_records))
    qps = float(a("requests")[0]) / max(wall, 1e-9)
    p50, p99 = a("latency_q_ms")
    print(f"served {m} requests in {wall:.1f}s")
    print(f"telemetry (from {int(res.selected.sum())}/{m} sampled records, "
          f"{plan.k} standing queries, one evaluation):")
    print(f"  QPS              ≈ {qps:.2f}")
    print(f"  total latency-ms ≈ {a('latency_total_ms')[0]:.1f} "
          f"± {b('latency_total_ms')[0]:.1f} (2σ)")
    print(f"  mean latency-ms  ≈ {a('latency_mean_ms')[0]:.2f} "
          f"± {b('latency_mean_ms')[0]:.2f} (exact {exact_mean:.2f})")
    print(f"  p50 / p99 ms     ≈ {p50:.2f} / {p99:.2f} "
          f"(sketch rank-ε {b('latency_q_ms')[0]:.3f})")
    return float(a("latency_mean_ms")[0]), exact_mean


if __name__ == "__main__":
    main()

"""The paper's own workload: hierarchical stream analytics driver.

Builds the §V testbed topology (8 sources → 4 → 2 → 1 root) as a
``HostTree``, streams synthetic sub-streams through it, and reports
windowed SUM/MEAN with ±kσ error bounds, accuracy-vs-exact, throughput,
per-hop bandwidth, and a modeled end-to-end latency. This is what
benchmarks/fig*.py drive.

Latency model (Fig. 9/10): the testbed's WAN is emulated following §V-A —
RTTs of 20/40/80 ms between layers, 1 Gbps links, 16 B/item. End-to-end
latency of an item =

    window_wait (interval/2 on average, per level)
  + measured per-node processing time per interval
  + Σ_hops (RTT_h/2 + forwarded_bytes_h / link_bw)

Sampling cuts both the upper-level processing (smaller buffers) and the
transfer terms — the same mechanism as the paper's speedup.

    PYTHONPATH=src python -m repro.launch.analytics --dist gaussian \
        --fraction 0.1 --ticks 20
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro import api
from repro.api.spec import (BudgetSpec, PipelineSpec, SamplerSpec,
                            TenantSpec, TopologySpec)
from repro.core.tree import HostTree
from repro.data import stream as S

# §V-A WAN emulation constants.
HOP_RTT_S = (0.020, 0.040, 0.080)   # source→L0, L0→L1, L1→root
LINK_BW = 1e9 / 8                   # 1 Gbps in bytes/s
ITEM_BYTES = 16                     # value + stratum tag + framing


def default_capacity(specs, num_sources: int = 8, fanin=(4, 2, 1),
                     interval_ticks=None) -> int:
    """Level-0 buffer provisioning for the offered load (Σ rates ×
    sources per node × interval, 35% Poisson slack) — level-0 drops
    carry no metadata, so an under-provisioned ingest buffer silently
    biases the estimate downward."""
    per_node_rate = sum(s.rate for s in specs) * num_sources / fanin[0]
    iv0 = (interval_ticks or [1])[0]
    return max(int(1.35 * per_node_rate * iv0) + 256 & ~255, 1024)


def build_spec(specs=None, *, fraction: float, capacity: int | None = None,
               num_strata: int | None = None,
               num_sources: int = 8, fanin=(4, 2, 1), interval_ticks=None,
               allocation: str = "fair", seed: int = 0, mode: str = "whs",
               sampler_backend: str = "topk", queries=None,
               target_rel_error: float | None = None,
               max_fraction: float | None = None,
               telemetry: bool = False,
               strata=None) -> PipelineSpec:
    """The §V testbed job as ONE declarative ``PipelineSpec`` — what
    every driver (this CLI, benchmarks, examples) constructs and hands
    to ``repro.api.compile`` / ``HostTree.from_spec``.

    ``specs`` (the sub-stream mix) sizes the level-0 buffers for the
    offered load and sets ``num_strata``; pass explicit ``capacity``/
    ``num_strata`` to build a spec without a stream description.

    ``queries`` registers the standing-query plane: a ``QueryRegistry``
    becomes the single ``"default"`` tenant; a sequence of
    ``TenantSpec``s compiles N tenants into one shared batched root
    evaluation with per-tenant answer routing."""
    if capacity is None:
        capacity = default_capacity(specs, num_sources, fanin,
                                    interval_ticks)
    if num_strata is None:
        num_strata = len(specs)
    if queries is None:
        tenants = ()
    elif isinstance(queries, (list, tuple)):
        tenants = tuple(queries)
    else:
        tenants = (TenantSpec.from_registry("default", queries),)
    from repro.api.spec import StrataSpec, TelemetrySpec

    if strata is None:
        strata = StrataSpec()
    return PipelineSpec(
        topology=TopologySpec(fanin=tuple(fanin), capacity=capacity,
                              interval_ticks=(tuple(interval_ticks)
                                              if interval_ticks else None),
                              num_strata=num_strata),
        sampler=SamplerSpec(mode=mode, backend=sampler_backend,
                            allocation=allocation, fraction=fraction),
        tenants=tenants,
        budget=BudgetSpec(max_fraction=max_fraction,
                          target_rel_error=target_rel_error),
        seed=seed,
        telemetry=TelemetrySpec(enabled=telemetry),
        strata=strata,
    )


def _window_rel_error(w: dict, plan=None) -> float:
    """Measured relative ±2σ error of one root window — the signal the
    error-budget controller consumes (no ground truth needed online).

    With a registered query plan this is the WORST per-query relative
    bound across the CLT queries (sum/mean) in the window's answer
    vector; otherwise the built-in windowed SUM's. Sketch queries carry
    deterministic structural bounds, so they don't vote."""
    rels = []
    if plan is not None and "answers" in w:
        for _, (off, _, kind) in plan.layout().items():
            if kind in ("sum", "mean"):
                est = abs(float(w["answers"][off]))
                rels.append(float(w["bounds"][off]) / max(est, 1e-9))
    if not rels:
        est = abs(w["sum"])
        rels = [2.0 * float(np.sqrt(max(w["sum_var"], 0.0)))
                / max(est, 1e-9)]
    return max(rels)


def build_tree(num_strata: int, capacity: int, fraction: float,
               fanin=(4, 2, 1), interval_ticks=None, allocation="fair",
               seed: int = 0, mode: str = "whs", engine: str = "level",
               sampler_backend: str = "topk", queries=None,
               max_fraction: float | None = None) -> HostTree:
    """Back-compat wrapper: the keyword soup becomes one declarative
    ``PipelineSpec`` (see ``build_spec``) consumed through the
    ``HostTree.from_spec`` shim. Budget sizing (WHS fraction×capacity,
    the SRS HT-safe provisioning, controller ceilings) now lives in
    ``repro.api.spec.derive_sample_sizes`` — one source of truth."""
    spec = build_spec(fraction=fraction, capacity=capacity,
                      num_strata=num_strata, fanin=fanin,
                      interval_ticks=interval_ticks, allocation=allocation,
                      seed=seed, mode=mode, sampler_backend=sampler_backend,
                      queries=queries, max_fraction=max_fraction)
    return HostTree.from_spec(spec, engine=engine)


class _CompiledDriver:
    """``run_pipeline``'s scan-engine executor: drives a pure
    ``repro.api.CompiledPipeline`` (explicit donated state, budgets as
    traced inputs) while keeping ``HostTree``'s accounting surface
    (``results``/``items_*``/``level_time_s``/``dispatch_count``), so
    one driver body serves the per-tick shim engines and the compiled
    runtime alike. The scan engine cannot observe per-level time inside
    its fused dispatch, so epoch wall-time is attributed to levels
    proportionally to their buffer slots — same model as the old
    ``HostTree.run_epoch``."""

    def __init__(self, pipe: "api.CompiledPipeline"):
        self.pipe = pipe
        self.state = pipe.init()
        self.plan = pipe.plan
        self.fanin = list(pipe.fanin)
        self.capacities = list(pipe.capacities)
        self.sample_sizes = list(pipe.sample_sizes)
        self.max_sample_sizes = list(pipe.max_sample_sizes)
        self._key = pipe.default_key
        self.results: list[dict] = []
        self.items_ingested = 0
        self.items_forwarded = [0] * len(self.fanin)
        self.level_time_s = [0.0] * len(self.fanin)
        self.dispatch_count = 0

    def run_epoch(self, t0: int, values, strata, counts, offered=None):
        import time as _time

        from repro.core.tree import accumulate_epoch_accounting
        from repro.obs.trace import span

        t_start = _time.perf_counter()
        with span("epoch_dispatch", t0=t0, ticks=int(np.shape(counts)[0])):
            self.state, wa = self.pipe.run_epoch(
                self.state, self._key, values, strata, counts,
                budgets=self.sample_sizes)
        with span("block_until_ready"):
            rows = self.pipe.rows(wa)             # device→host sync
            n_fwd = np.asarray(wa.n_forwarded)
        wall = _time.perf_counter() - t_start
        accumulate_epoch_accounting(self, wall, counts, offered, n_fwd)
        self.results.extend(rows)

    def reset_query_state(self) -> None:
        self.state = self.pipe.reset_queries(self.state)

    def set_sample_sizes(self, sizes) -> None:
        self.sample_sizes = self.pipe.clamp_budgets(sizes)


def run_pipeline(specs, *, fraction: float = 0.1, ticks: int,
                 capacity: int | None = None,
                 num_sources: int = 8, fanin=(4, 2, 1), interval_ticks=None,
                 allocation: str = "fair", seed: int = 0, mode: str = "whs",
                 engine: str = "level", sampler_backend: str = "topk",
                 warmup_ticks: int = 0, epoch_ticks: int | None = None,
                 queries=None, target_rel_error: float | None = None,
                 max_fraction: float | None = None,
                 pipeline_spec: PipelineSpec | None = None,
                 return_stream: bool = False,
                 telemetry: bool = False,
                 strata=None):
    """Stream → tree → per-window results + ground truth. Returns a dict.

    ``capacity=None`` provisions level-0 buffers for the offered load
    (Σ rates × sources per node × interval, with 35% Poisson slack) —
    level-0 drops carry no metadata, so an under-provisioned ingest
    buffer silently biases the estimate downward.

    ``warmup_ticks`` extra ticks are run first (jit compilation, caches)
    and excluded from the throughput/latency wall-clock measurement —
    accuracy accounting starts after warmup too, so estimates match.

    ``engine="scan"`` batches ``epoch_ticks`` ticks (default:
    ``min(ticks, 64)`` — bounding the epoch keeps the host-side ingest
    batch and the stacked per-tick outputs flat in memory and the scan
    compile time constant for long runs) into one fused dispatch per
    epoch. Its warmup runs one full epoch (any ``warmup_ticks > 0``
    requests it) so the measured epochs hit a compiled program, and
    ``ticks`` is rounded up to whole epochs so every dispatch reuses
    the one compiled scan length.

    ``queries`` registers a ``repro.query`` standing-query registry at
    the root: every window's results then carry ``answers``/``bounds``
    vectors for all K queries (same dispatch count — the plan evaluates
    inside the tick). ``target_rel_error`` closes the §IV-B loop: a
    ``BudgetController`` reads each epoch's (window's) measured relative
    ±2σ error and moves the per-level sample budgets toward the target,
    within ``[8, capacity·max_fraction]`` (``max_fraction`` defaults to
    1.0 when a controller is active). ``return_stream`` additionally
    returns the raw ingested stream for ground-truth evaluation.

    ``pipeline_spec`` supplies the whole job as one declarative
    ``repro.api.PipelineSpec`` (what this function builds internally via
    ``build_spec`` otherwise); the keyword knobs it covers (fraction,
    mode, fanin, intervals, queries, budget policy, seed) are then read
    from the spec. ``engine="scan"`` executes through the compiled
    ``repro.api`` runtime (pure ``init``/``run_epoch`` with donated
    state); ``"level"``/``"loop"`` drive the per-tick ``HostTree`` shim
    on the same spec — bit-identical on identical ingest.
    """
    if pipeline_spec is None:
        if target_rel_error is not None:
            assert mode == "whs", "the error-budget loop drives WHS budgets"
            max_fraction = 1.0 if max_fraction is None else max_fraction
        pipeline_spec = build_spec(
            specs, fraction=fraction, capacity=capacity,
            num_sources=num_sources, fanin=fanin,
            interval_ticks=interval_ticks, allocation=allocation, seed=seed,
            mode=mode, sampler_backend=sampler_backend, queries=queries,
            target_rel_error=target_rel_error, max_fraction=max_fraction,
            telemetry=telemetry, strata=strata)
    # The spec is the job description: derive every reported/derived
    # quantity from it so an explicitly-passed spec and the legacy
    # keyword path behave identically.
    mode = pipeline_spec.sampler.mode
    fraction = pipeline_spec.sampler.fraction
    sampler_backend = pipeline_spec.sampler.backend
    fanin = tuple(pipeline_spec.topology.fanin)
    interval_ticks = (list(pipeline_spec.topology.interval_ticks)
                      if pipeline_spec.topology.interval_ticks else None)
    target_rel_error = pipeline_spec.budget.target_rel_error
    if engine == "scan":
        tree = _CompiledDriver(api.compile(pipeline_spec))
    else:
        assert not pipeline_spec.strata.adaptive, (
            "adaptive stratification rides the scan engine's route leaf")
        tree = HostTree.from_spec(pipeline_spec, engine=engine)
    manager = None
    if pipeline_spec.strata.adaptive:
        from repro import strata as strata_mod

        manager = strata_mod.StratumManager(
            np.asarray(tree.state.tree.route),
            pipeline_spec.topology.num_strata,
            split_occupancy=pipeline_spec.strata.split_occupancy,
            merge_occupancy=pipeline_spec.strata.merge_occupancy)
    sources = [S.StreamSource(specs, seed=pipeline_spec.seed * 977 + i)
               for i in range(num_sources)]
    controller = None
    trajectory: list[dict] = []
    if target_rel_error is not None:
        from repro.runtime.budget import (BudgetConfig, BudgetController,
                                          WorstTenantArbiter)

        cfg = BudgetConfig(min_size=pipeline_spec.budget.min_size,
                           max_size=int(tree.max_sample_sizes[0]),
                           target_rel_error=target_rel_error,
                           kp=pipeline_spec.budget.kp,
                           ki=pipeline_spec.budget.ki)
        if len(pipeline_spec.tenants) > 1:
            # N tenants share the tree: worst-tenant-first fairness on
            # the one budget knob (see runtime.budget).
            controller = WorstTenantArbiter(
                cfg, initial_size=int(tree.sample_sizes[0]))
        else:
            controller = BudgetController(
                cfg, initial_size=int(tree.sample_sizes[0]))
    # Only materialize the raw stream when the caller asked for it —
    # collection is O(items) host memory/time, which would silently void
    # the scan engine's flat-memory property on long --queries runs.
    collect = return_stream
    stream_v: list[np.ndarray] = []
    stream_s: list[np.ndarray] = []

    def _feedback(new_windows: list[dict], step: int) -> None:
        """Feed the controller the freshest measured relative ±2σ error
        and move every level's budget (§IV-B adaptive feedback). With
        N tenants the error is attributed per tenant and the worst-off
        tenant drives the shared budget (worst-tenant-first fairness)."""
        if controller is None or not new_windows:
            return
        if hasattr(controller, "last_tenant"):     # WorstTenantArbiter
            from repro.runtime.budget import (aggregate_tenant_rel_errors,
                                              level_error_shares)

            per = aggregate_tenant_rel_errors(tree.plan, new_windows)
            # Per-level attribution: split the worst tenant's error
            # across levels by measured (1-f)/f variance shares, so the
            # controller moves only the levels that dominate the error.
            ins = [tree.items_ingested] + list(tree.items_forwarded[:-1])
            shares = level_error_shares(ins, tree.items_forwarded)
            sizes = controller.update_levels(per, shares)
            entry = dict(step=step, rel_error=max(per.values() or [0.0]),
                         size=max(sizes), sizes=list(sizes),
                         level_shares=[round(float(s), 6) for s in shares],
                         tenant=controller.last_tenant,
                         tenant_rel_errors=per)
        else:
            rels = [_window_rel_error(w, tree.plan) for w in new_windows]
            rel = float(np.mean([r for r in rels if np.isfinite(r)]
                                or [0.0]))
            size = controller.update(rel_error=rel)
            sizes = [size] * len(tree.fanin)
            entry = dict(step=step, rel_error=rel, size=size)
        tree.set_sample_sizes(sizes)
        trajectory.append(entry)

    if engine == "scan":
        epoch_t = min(epoch_ticks or 64, ticks)
        n_epochs = -(-ticks // epoch_t)  # ceil: whole epochs only
        width = tree.capacities[0]
        t0_tick = 1
        if warmup_ticks > 0:  # one full epoch: compiles the scan program
            wb = S.batch_ingest(sources, epoch_t, tree.fanin[0], width)
            tree.run_epoch(t0_tick, wb.values, wb.strata, wb.counts,
                           offered=wb.offered)
            t0_tick += epoch_t
    else:
        for t in range(1, warmup_ticks + 1):
            for i, src in enumerate(sources):
                vals, strs = src.tick()
                tree.ingest(i % tree.fanin[0], vals, strs)
            tree.tick(t)
    # reset accounting after warmup (sketch state included: continuous
    # answers must cover exactly the measured stream)
    tree.reset_query_state()
    if engine == "scan":
        from repro.obs import telemetry as obs_telemetry

        tree.state = obs_telemetry.reset(tree.state)
    tree.results.clear()
    tree.items_ingested = 0
    tree.items_forwarded = [0] * len(tree.fanin)
    tree.level_time_s = [0.0] * len(tree.fanin)
    tree.dispatch_count = 0

    exact_sum = 0.0
    exact_cnt = 0
    ingest_truncation_warned = False
    t0 = time.time()
    if engine == "scan":
        from repro.obs.trace import span

        for e in range(n_epochs):
            with span("ingest", epoch=e):
                b = S.batch_ingest(sources, epoch_t, tree.fanin[0], width)
            exact_sum += b.exact_sum
            exact_cnt += b.exact_count
            dropped = int((b.offered - b.counts).sum())
            if dropped and not ingest_truncation_warned:
                # Level-0 drops carry no metadata, so truncation biases
                # every estimate downward with no error signal — this
                # happens when the stream offered to run_pipeline is
                # heavier than the load the spec's capacity was
                # provisioned for (e.g. a spec built for a different
                # num_sources/rates).
                import warnings

                warnings.warn(
                    f"level-0 ingest truncated {dropped} items in epoch "
                    f"{e} (capacity {width} per node/tick is below the "
                    f"offered load) — estimates will bias low; rebuild "
                    f"the PipelineSpec for the actual source count and "
                    f"rates", RuntimeWarning, stacklevel=2)
                ingest_truncation_warned = True
            if collect:
                for tt in range(epoch_t):
                    for node in range(tree.fanin[0]):
                        c = int(b.counts[tt, node])
                        stream_v.append(b.values[tt, node, :c])
                        stream_s.append(b.strata[tt, node, :c])
            n_before = len(tree.results)
            tree.run_epoch(t0_tick + e * epoch_t, b.values, b.strata,
                           b.counts, offered=b.offered)
            _feedback(tree.results[n_before:], step=e)
            if manager is not None and e + 1 < n_epochs:
                # Epoch boundary: fold this epoch's per-key arrival
                # counts into the manager and commit any split/merge as
                # a pure route+metadata edit — same shapes, so the next
                # epoch reuses the compiled program (zero retraces,
                # pinned in tests/test_strata.py).
                from repro import strata as strata_mod

                pos = np.arange(np.shape(b.strata)[-1])[None, None, :]
                live = pos < np.asarray(b.counts)[..., None]
                keys = np.asarray(b.strata)[live]
                kc = np.bincount(keys, minlength=manager.num_keys)
                km = np.bincount(keys, minlength=manager.num_keys,
                                 weights=np.abs(np.asarray(b.values)[live]))
                manager.observe(kc, km)
                ops = manager.maybe_adapt()
                if ops:
                    tree.state = tree.state._replace(
                        tree=strata_mod.remap_tree_state(
                            tree.state.tree, ops, manager.route))
    else:
        for t in range(warmup_ticks + 1, warmup_ticks + ticks + 1):
            for i, src in enumerate(sources):
                vals, strs = src.tick()
                exact_sum += float(vals.sum())
                exact_cnt += len(vals)
                if collect:
                    stream_v.append(vals)
                    stream_s.append(strs)
                tree.ingest(i % tree.fanin[0], vals, strs)
            n_before = len(tree.results)
            tree.tick(t)
            _feedback(tree.results[n_before:], step=t)
    wall = time.time() - t0

    approx_sum = float(sum(r["sum"] for r in tree.results))
    bound = 2 * float(np.sqrt(sum(r["sum_var"] for r in tree.results)))
    acc_loss = abs(approx_sum - exact_sum) / max(abs(exact_sum), 1e-9)

    # -------- latency + pipeline-throughput model (module docstring) -----
    # level_time_s[lvl] sums every node of the level; in the testbed the
    # nodes are separate machines, so per-item path cost and the sustained
    # rate are per-NODE quantities.
    n_windows = max(len(tree.results), 1)
    it = interval_ticks or [1] * len(tree.fanin)
    window_wait = sum(iv / 2.0 for iv in it)          # in ticks
    node_time = [lt / max(n, 1) for lt, n in zip(tree.level_time_s, tree.fanin)]
    proc = sum(nt / n_windows for nt in node_time)
    fwd = [tree.items_ingested] + tree.items_forwarded[:-1]
    transfer = sum(
        HOP_RTT_S[min(h, len(HOP_RTT_S) - 1)] / 2.0
        + (fwd[h] / n_windows / max(tree.fanin[min(h, len(tree.fanin) - 1)], 1))
        * ITEM_BYTES / LINK_BW
        for h in range(len(tree.fanin)))
    latency = proc + transfer
    # Sustained pipeline rate = the slowest stage (per node): the §V-A
    # methodology saturates the datacenter node, so at fraction 1.0 the
    # root is the bottleneck and sampling moves it toward the edge.
    bottleneck = max(nt / max(wall, 1e-9) for nt in node_time)  # utilization
    pipeline_tp = (exact_cnt / max(wall, 1e-9)) / max(bottleneck, 1e-9)
    extras = {}
    if tree.plan is not None:
        extras["query_layout"] = {
            n: dict(offset=o, width=wd, kind=k)
            for n, (o, wd, k) in tree.plan.layout().items()}
        extras["windows_answers"] = [r["answers"] for r in tree.results
                                     if "answers" in r]
        extras["windows_bounds"] = [r["bounds"] for r in tree.results
                                    if "bounds" in r]
    if controller is not None:
        extras["controller"] = trajectory
        extras["final_sample_sizes"] = list(tree.sample_sizes)
    if manager is not None:
        import dataclasses as _dc

        extras["strata_ops"] = [_dc.asdict(op) for op in manager.ops_log]
        extras["strata_route"] = np.asarray(tree.state.tree.route).tolist()
    if engine == "scan" and getattr(tree.pipe, "telemetry_enabled", False):
        from repro.obs.metrics import metrics_text
        from repro.obs.telemetry import snapshot, tenant_rel_bounds
        from repro.obs.trace import get_tracer

        snap = snapshot(tree.state)
        if snap is not None:
            snap["slot_rel_bound_mean"] = np.asarray(
                snap["slot_rel_bound_mean"]).tolist()
            snap["tenant_rel_bounds"] = tenant_rel_bounds(tree.pipe,
                                                          tree.state)
            extras["telemetry"] = snap
            extras["metrics"] = metrics_text(
                pipeline=tree.pipe, state=tree.state, tracer=get_tracer(),
                controller=controller)
    if return_stream:
        extras["stream_values"] = (np.concatenate(stream_v) if stream_v
                                   else np.zeros(0, np.float32))
        extras["stream_strata"] = (np.concatenate(stream_s) if stream_s
                                   else np.zeros(0, np.int32))
    return {
        **extras,
        "fraction": fraction,
        "mode": mode,
        "engine": engine,
        "sampler_backend": sampler_backend,
        "dispatches": tree.dispatch_count,
        "approx_sum": approx_sum,
        "exact_sum": exact_sum,
        "bound_2sigma": bound,
        "accuracy_loss": acc_loss,
        "within_2sigma": abs(approx_sum - exact_sum) <= bound,
        "items_ingested": tree.items_ingested,
        "items_forwarded": tree.items_forwarded,
        "bandwidth_fraction": (tree.items_forwarded[0] /
                               max(tree.items_ingested, 1)),
        "wall_s": wall,
        "throughput_items_s": exact_cnt / max(wall, 1e-9),
        "pipeline_items_s": pipeline_tp,
        "level_time_s": list(tree.level_time_s),
        "latency_s": latency,
        "latency_window_ticks": window_wait,
        "windows": len(tree.results),
    }


def make_data_mesh(n_devices: int):
    """A 1-axis ``("data",)`` mesh over ``n_devices`` local devices, with
    an actionable error when the host doesn't expose enough (CPU runs
    need ``XLA_FLAGS=--xla_force_host_platform_device_count=N``)."""
    import jax

    have = len(jax.devices())
    if n_devices > have:
        raise RuntimeError(
            f"--mesh {n_devices} needs {n_devices} devices but jax sees "
            f"{have}; on CPU export "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_devices} "
            f"before importing jax")
    return jax.make_mesh((n_devices,), ("data",),
                         devices=jax.devices()[:n_devices])


def run_spmd_pipeline(specs, *, fraction: float = 0.1, ticks: int,
                      n_devices: int = 1, mesh=None, queries=None,
                      seed: int = 0, mode: str = "whs",
                      sampler_backend: str = "topk",
                      allocation: str = "fair",
                      epoch_ticks: int | None = None,
                      target_rel_error: float | None = None,
                      max_fraction: float | None = None,
                      warmup: bool = True,
                      telemetry: bool = False):
    """The §III-E pod-scale data plane end to end: stream → mesh →
    merged-summary query plane → per-window answers. Returns a dict in
    the ``run_pipeline`` report style.

    Every tick is ONE flat interval batch of the whole pod's arrivals,
    sharded over the mesh axis on the item axis; ``epoch_ticks`` windows
    batch into one jitted dispatch. With ``queries`` tenants the root
    answers come from merged per-device sketch summaries (never raw
    reservoirs — see ``repro.api.spmd``); ``target_rel_error`` closes
    the §IV-B loop on the mesh: the per-epoch measured per-tenant error
    (attributed from the merged answers) drives the shared traced sample
    budget, worst-tenant-first when several tenants share the plane.
    """
    from repro import api

    mesh = mesh if mesh is not None else make_data_mesh(n_devices)
    n_dev = int(np.prod(list(mesh.shape.values())))
    src = S.StreamSource(specs, seed=seed * 977)
    per_tick = sum(sp.rate for sp in specs)
    # item axis: offered load + Poisson slack, padded to shard evenly
    width = int(1.35 * per_tick) + 256
    width = -(-width // n_dev) * n_dev
    if target_rel_error is not None and max_fraction is None:
        max_fraction = 1.0
    spec = build_spec(specs, fraction=fraction, capacity=width // n_dev,
                      num_strata=len(specs), allocation=allocation,
                      seed=seed, mode=mode, sampler_backend=sampler_backend,
                      queries=queries, target_rel_error=target_rel_error,
                      max_fraction=max_fraction, telemetry=telemetry)
    pipe = api.compile(spec, mesh=mesh)
    epoch_t = min(epoch_ticks or 32, ticks)
    n_epochs = -(-ticks // epoch_t)

    controller = None
    trajectory: list[dict] = []
    budget = float(pipe.local_budget)
    if target_rel_error is not None and pipe.plan is not None:
        from repro.runtime.budget import (BudgetConfig, BudgetController,
                                          WorstTenantArbiter)

        cfg = BudgetConfig(min_size=spec.budget.min_size,
                           max_size=pipe.max_local_budget,
                           target_rel_error=target_rel_error,
                           kp=spec.budget.kp, ki=spec.budget.ki)
        controller = (WorstTenantArbiter(cfg, initial_size=pipe.local_budget)
                      if len(spec.tenants) > 1 else
                      BudgetController(cfg, initial_size=pipe.local_budget))

    state = pipe.init()
    if warmup:  # compile the epoch program off the measured clock
        v, s, c = S.StreamSource(specs, seed=seed * 977 + 1).batch(
            epoch_t, width)
        b = S.rows_to_interval_batch(v, s, c, len(specs))
        state, _ = pipe.run_epoch(state, pipe.default_key, b,
                                  budgets=[budget] if pipe.plan else None)
        state = pipe.init()
        pipe.trace_counter["traces"] = 0

    from repro.obs import telemetry as obs_telemetry
    from repro.obs.trace import span

    state = obs_telemetry.reset(state)   # counters cover measured epochs
    results: list[dict] = []
    exact_sum, exact_cnt = 0.0, 0
    dispatches = 0
    t0 = time.time()
    for e in range(n_epochs):
        with span("ingest", epoch=e):
            v, s, c = src.batch(epoch_t, width)
            exact_sum += float((v * (np.arange(width)[None, :]
                                     < c[:, None])).sum())
            exact_cnt += int(c.sum())
            b = S.rows_to_interval_batch(v, s, c, len(specs))
        if pipe.plan is not None:
            # the tenant path folds the carried GLOBAL tick into the key,
            # so one key gives fresh randomness every epoch
            with span("epoch_dispatch", epoch=e):
                state, wa = pipe.run_epoch(state, pipe.default_key, b,
                                           budgets=[budget])
            with span("block_until_ready"):
                rows = pipe.rows(wa)
            if controller is not None and rows:
                if hasattr(controller, "last_tenant"):
                    size, per = controller.update_from_windows(pipe.plan,
                                                               rows)
                    entry = dict(step=e, size=size,
                                 rel_error=max(per.values() or [0.0]),
                                 tenant=controller.last_tenant,
                                 tenant_rel_errors=per)
                else:
                    rels = [_window_rel_error(w, pipe.plan) for w in rows]
                    rel = float(np.mean([r for r in rels
                                         if np.isfinite(r)] or [0.0]))
                    size = controller.update(rel_error=rel)
                    entry = dict(step=e, size=size, rel_error=rel)
                budget = float(size)
                trajectory.append(entry)
        else:
            # stateless path folds only the epoch-local tick index:
            # fold the epoch number here or every epoch would reuse the
            # exact same selection randomness
            import jax

            k_e = jax.random.fold_in(pipe.default_key, e)
            state, (sq, mq) = pipe.run_epoch(state, k_e, b)
            rows = [dict(tick=e * epoch_t + i,
                         sum=float(np.asarray(sq.estimate)[i]),
                         sum_var=float(np.asarray(sq.variance)[i]),
                         mean=float(np.asarray(mq.estimate)[i]),
                         mean_var=float(np.asarray(mq.variance)[i]))
                    for i in range(epoch_t)]
        dispatches += 1
        results.extend(rows)
    wall = time.time() - t0

    approx_sum = float(sum(r["sum"] for r in results))
    bound = 2 * float(np.sqrt(sum(r["sum_var"] for r in results)))
    acc_loss = abs(approx_sum - exact_sum) / max(abs(exact_sum), 1e-9)
    out = {
        "fraction": fraction, "mode": mode, "engine": "spmd",
        "n_devices": n_dev, "sampler_backend": sampler_backend,
        "dispatches": dispatches, "retraces": pipe.trace_counter["traces"],
        "approx_sum": approx_sum, "exact_sum": exact_sum,
        "bound_2sigma": bound, "accuracy_loss": acc_loss,
        "within_2sigma": abs(approx_sum - exact_sum) <= bound,
        "items_ingested": exact_cnt,
        "wall_s": wall,
        "throughput_items_s": exact_cnt / max(wall, 1e-9),
        "windows": len(results),
    }
    if pipe.plan is not None:
        out["query_layout"] = {
            n: dict(offset=o, width=wd, kind=k)
            for n, (o, wd, k) in pipe.plan.layout().items()}
        out["windows_answers"] = [r["answers"] for r in results
                                  if "answers" in r]
        out["windows_bounds"] = [r["bounds"] for r in results
                                 if "bounds" in r]
        # the §III-E bandwidth story: what crosses the mesh per window
        out["summary_bytes_per_window"] = pipe.summary_bytes_per_window
        out["reservoir_bytes_per_window"] = pipe.reservoir_bytes_per_window
    if controller is not None:
        out["controller"] = trajectory
        out["final_sample_sizes"] = [budget]
    if telemetry and pipe.plan is not None:
        from repro.obs.metrics import metrics_text
        from repro.obs.telemetry import snapshot, tenant_rel_bounds
        from repro.obs.trace import get_tracer

        snap = snapshot(state)
        if snap is not None:
            snap["slot_rel_bound_mean"] = np.asarray(
                snap["slot_rel_bound_mean"]).tolist()
            snap["tenant_rel_bounds"] = tenant_rel_bounds(pipe, state)
            out["telemetry"] = snap
            out["metrics"] = metrics_text(
                pipeline=pipe, state=state, tracer=get_tracer(),
                controller=controller)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dist", default="gaussian",
                    choices=["gaussian", "poisson", "poisson-skewed", "taxi",
                             "pollution"])
    ap.add_argument("--fraction", type=float, default=0.1)
    ap.add_argument("--ticks", type=int, default=20)
    ap.add_argument("--allocation", default="fair",
                    choices=["fair", "proportional", "neyman"],
                    help="per-stratum reservoir split: fair = equal "
                         "water-filled shares, proportional = largest-"
                         "remainder by arrival count, neyman = count×std "
                         "optimal (the adaptive arm of Fig. 11c)")
    ap.add_argument("--adaptive-strata", action="store_true",
                    help="scan engine: split hot / merge starved strata "
                         "at epoch boundaries via the key→stratum route "
                         "table (repro.strata) — a pure state edit, no "
                         "recompiles")
    ap.add_argument("--mode", default="whs", choices=["whs", "srs"])
    ap.add_argument("--engine", default="level",
                    choices=["level", "loop", "scan"],
                    help="level = one jitted dispatch per level per tick; "
                         "loop = per-node reference engine; scan = whole "
                         "tree fused, one dispatch per epoch of ticks")
    ap.add_argument("--epoch-ticks", type=int, default=None,
                    help="scan engine: ticks fused per epoch dispatch "
                         "(default: min(ticks, 64))")
    ap.add_argument("--backend", default="topk",
                    choices=["argsort", "topk", "pallas", "pallas_fused"],
                    help="sampler selection backend: argsort = lexsort "
                         "reference, topk = dense partial-selection "
                         "thresholds, pallas = fused kernels (interpret "
                         "mode off-TPU)")
    ap.add_argument("--queries", default=None, metavar="TOKENS",
                    help="standing queries answered at the root every "
                         "window, e.g. "
                         "'sum,count,mean,hist:0:120000:32,q:0.5:0.9:0.99,hh'"
                         " (see repro.query.registry)")
    ap.add_argument("--target-rel-error", type=float, default=None,
                    help="close the §IV-B loop: adapt per-level sample "
                         "budgets online until the measured relative ±2σ "
                         "error meets this target")
    ap.add_argument("--max-fraction", type=float, default=None,
                    help="budget ceiling for the error-budget controller "
                         "(fraction of window capacity; default 1.0)")
    ap.add_argument("--mesh", type=int, default=None, metavar="N",
                    help="run the §III-E SPMD data plane on an N-device "
                         "'data' mesh instead of the emulated tree "
                         "(CPU: export XLA_FLAGS=--xla_force_host_"
                         "platform_device_count=N); with --queries the "
                         "tenants lower onto the merged-summary query "
                         "plane — only sketch summaries cross devices")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the result report to PATH (BENCH artifact)")
    ap.add_argument("--telemetry", action="store_true",
                    help="enable the in-graph observability plane "
                         "(repro.obs): the report/--json gains a "
                         "'telemetry' snapshot and a Prometheus-text "
                         "'metrics' block (scan engine and --mesh paths)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write the host span tracer's Chrome/Perfetto "
                         "trace.json to PATH (load in ui.perfetto.dev)")
    args = ap.parse_args(argv)

    specs = {
        "gaussian": S.paper_gaussian(),
        "poisson": S.paper_poisson(),
        "poisson-skewed": S.paper_poisson(
            rates=tuple(8000 * s for s in S.SKEW_SHARES), skewed=True),
        "taxi": S.taxi_like(),
        "pollution": S.pollution_like(),
    }[args.dist]
    registry = None
    if args.queries:
        from repro.query.registry import QueryRegistry

        registry = QueryRegistry.from_tokens(args.queries)
    if args.telemetry and args.mesh is None and args.engine != "scan":
        # telemetry leaves live in the compiled runtimes' donated state
        args.engine = "scan"
    strata_spec = None
    if args.adaptive_strata:
        from repro.api.spec import StrataSpec

        assert args.mesh is None, "--adaptive-strata needs the scan engine"
        args.engine = "scan"   # the route leaf lives in the scan state
        strata_spec = StrataSpec(num_keys=len(specs), adaptive=True)
    if args.mesh is not None:
        r = run_spmd_pipeline(
            specs, fraction=args.fraction, ticks=args.ticks,
            n_devices=args.mesh, queries=registry, mode=args.mode,
            sampler_backend=args.backend, allocation=args.allocation,
            epoch_ticks=args.epoch_ticks,
            target_rel_error=args.target_rel_error,
            max_fraction=args.max_fraction, telemetry=args.telemetry)
    else:
        r = run_pipeline(specs, fraction=args.fraction, ticks=args.ticks,
                         allocation=args.allocation, mode=args.mode,
                         engine=args.engine, sampler_backend=args.backend,
                         warmup_ticks=2, epoch_ticks=args.epoch_ticks,
                         queries=registry,
                         target_rel_error=args.target_rel_error,
                         max_fraction=args.max_fraction,
                         telemetry=args.telemetry, strata=strata_spec)
    print(f"dist={args.dist} mode={args.mode} engine={r['engine']} "
          f"backend={args.backend} fraction={r['fraction']:.0%}"
          + (f" mesh={r['n_devices']}dev" if args.mesh else ""))
    print(f"  SUM ≈ {r['approx_sum']:.4e} ± {r['bound_2sigma']:.2e} "
          f"(exact {r['exact_sum']:.4e}; within 2σ: {r['within_2sigma']})")
    print(f"  accuracy loss  {r['accuracy_loss']:.5%}")
    if "strata_ops" in r:
        kinds = [op["kind"] for op in r["strata_ops"]]
        print(f"  strata         {kinds.count('split')} splits, "
              f"{kinds.count('merge')} merges; route {r['strata_route']}")
    if "bandwidth_fraction" in r:
        print(f"  bandwidth kept {r['bandwidth_fraction']:.1%} of ingested "
              f"items")
    elif "summary_bytes_per_window" in r:
        # both sides per device SHIPPED per window (gather traffic scales
        # with the mesh the same way on both paths)
        print(f"  cross-device   {r['summary_bytes_per_window']} B/window "
              f"of sketch summaries per device (reservoir all-gather "
              f"would ship {r['reservoir_bytes_per_window']} B and grow "
              f"with the sample budget)")
    print(f"  throughput     {r['throughput_items_s']:.0f} items/s "
          f"({r['items_ingested']} items, {r['windows']} windows, "
          f"{r['dispatches']} jitted dispatches)")
    if "latency_s" in r:
        print(f"  latency        {r['latency_s'] * 1e3:.1f} ms/window "
              f"(+{r['latency_window_ticks']:.1f} tick window wait)")
    if registry is not None and r.get("windows_answers"):
        last_a, last_b = r["windows_answers"][-1], r["windows_bounds"][-1]
        print("  standing queries (last window, ± bound):")
        for name, lay in r["query_layout"].items():
            o, wd = lay["offset"], lay["width"]
            a = ", ".join(f"{v:.4g}" for v in last_a[o:o + min(wd, 6)])
            b = ", ".join(f"{v:.3g}" for v in last_b[o:o + min(wd, 6)])
            more = " …" if wd > 6 else ""
            print(f"    {name:<12} [{a}{more}] ± [{b}{more}]")
    if r.get("controller"):
        tr = r["controller"]
        print(f"  error-budget controller: size {tr[0]['size']}→"
              f"{tr[-1]['size']} over {len(tr)} updates "
              f"(rel err {tr[0]['rel_error']:.4f}→{tr[-1]['rel_error']:.4f},"
              f" target {args.target_rel_error})")
    if r.get("telemetry"):
        tel = r["telemetry"]
        fr = ", ".join(f"L{i}:{lv['effective_fraction']:.3f}"
                       for i, lv in enumerate(tel["levels"]))
        print(f"  telemetry      {tel['windows']} windows, realized ±2σ "
              f"{tel['bound_2sigma']:.3e} "
              f"(rel {tel['rel_bound_2sigma']:.4f}); eff fraction {fr}")
    if args.trace:
        from repro.obs.trace import get_tracer

        get_tracer().save(args.trace)
        print(f"  wrote {args.trace}")
    if args.json:
        import json
        import pathlib

        payload = {k: v for k, v in r.items()
                   if k not in ("windows_answers", "windows_bounds")}
        pathlib.Path(args.json).write_text(
            json.dumps(payload, indent=1, default=str))
        print(f"  wrote {args.json}")
    return r


if __name__ == "__main__":
    main()

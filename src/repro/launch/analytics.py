"""The paper's own workload: hierarchical stream analytics driver.

Builds the §V testbed topology (8 sources → 4 → 2 → 1 root) as a
``HostTree``, streams synthetic sub-streams through it, and reports
windowed SUM/MEAN with ±kσ error bounds, accuracy-vs-exact, throughput,
per-hop bandwidth, and a modeled end-to-end latency. This is what
benchmarks/fig*.py drive.

Latency model (Fig. 9/10): the testbed's WAN is emulated following §V-A —
RTTs of 20/40/80 ms between layers, 1 Gbps links, 16 B/item. End-to-end
latency of an item =

    window_wait (interval/2 on average, per level)
  + measured per-node processing time per interval
  + Σ_hops (RTT_h/2 + forwarded_bytes_h / link_bw)

Sampling cuts both the upper-level processing (smaller buffers) and the
transfer terms — the same mechanism as the paper's speedup.

    PYTHONPATH=src python -m repro.launch.analytics --dist gaussian \
        --fraction 0.1 --ticks 20
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.tree import HostTree
from repro.data import stream as S

# §V-A WAN emulation constants.
HOP_RTT_S = (0.020, 0.040, 0.080)   # source→L0, L0→L1, L1→root
LINK_BW = 1e9 / 8                   # 1 Gbps in bytes/s
ITEM_BYTES = 16                     # value + stratum tag + framing


def build_tree(num_strata: int, capacity: int, fraction: float,
               fanin=(4, 2, 1), interval_ticks=None, allocation="fair",
               seed: int = 0, mode: str = "whs", engine: str = "level",
               sampler_backend: str = "topk") -> HostTree:
    if mode == "srs":
        # Coin-flip keeps ~p_level of arrivals at each node. A level-l node
        # receives fanin[0]·capacity·p^l / fanin[l] items (fan-in
        # concentrates the stream), so its outbound buffer must hold
        # p^(l+1)·that, with slack — truncating would break Horvitz–
        # Thompson unbiasedness.
        p = fraction ** (1.0 / len(fanin))
        total = fanin[0] * capacity
        sizes = [max(int(1.3 * total * (p ** (lvl + 1)) / fanin[lvl]), 8)
                 for lvl in range(len(fanin))]
    else:
        sizes = [max(int(capacity * fraction), 1)] * len(fanin)
    return HostTree(
        fanin=list(fanin), num_strata=num_strata, capacity=capacity,
        sample_sizes=sizes, interval_ticks=interval_ticks,
        allocation=allocation, seed=seed, mode=mode, fraction=fraction,
        engine=engine, sampler_backend=sampler_backend)


def run_pipeline(specs, *, fraction: float, ticks: int, capacity: int | None = None,
                 num_sources: int = 8, fanin=(4, 2, 1), interval_ticks=None,
                 allocation: str = "fair", seed: int = 0, mode: str = "whs",
                 engine: str = "level", sampler_backend: str = "topk",
                 warmup_ticks: int = 0, epoch_ticks: int | None = None):
    """Stream → tree → per-window results + ground truth. Returns a dict.

    ``capacity=None`` provisions level-0 buffers for the offered load
    (Σ rates × sources per node × interval, with 35% Poisson slack) —
    level-0 drops carry no metadata, so an under-provisioned ingest
    buffer silently biases the estimate downward.

    ``warmup_ticks`` extra ticks are run first (jit compilation, caches)
    and excluded from the throughput/latency wall-clock measurement —
    accuracy accounting starts after warmup too, so estimates match.

    ``engine="scan"`` batches ``epoch_ticks`` ticks (default:
    ``min(ticks, 64)`` — bounding the epoch keeps the host-side ingest
    batch and the stacked per-tick outputs flat in memory and the scan
    compile time constant for long runs) into one fused dispatch per
    epoch. Its warmup runs one full epoch (any ``warmup_ticks > 0``
    requests it) so the measured epochs hit a compiled program, and
    ``ticks`` is rounded up to whole epochs so every dispatch reuses
    the one compiled scan length.
    """
    if capacity is None:
        per_node_rate = sum(s.rate for s in specs) * num_sources / fanin[0]
        iv0 = (interval_ticks or [1])[0]
        capacity = max(int(1.35 * per_node_rate * iv0) + 256 & ~255, 1024)
    tree = build_tree(len(specs), capacity, fraction, fanin,
                      interval_ticks, allocation, seed, mode,
                      engine, sampler_backend)
    sources = [S.StreamSource(specs, seed=seed * 977 + i)
               for i in range(num_sources)]

    if engine == "scan":
        epoch_t = min(epoch_ticks or 64, ticks)
        n_epochs = -(-ticks // epoch_t)  # ceil: whole epochs only
        width = tree.capacities[0]
        t0_tick = 1
        if warmup_ticks > 0:  # one full epoch: compiles the scan program
            wb = S.batch_ingest(sources, epoch_t, tree.fanin[0], width)
            tree.run_epoch(t0_tick, wb.values, wb.strata, wb.counts,
                           offered=wb.offered)
            t0_tick += epoch_t
    else:
        for t in range(1, warmup_ticks + 1):
            for i, src in enumerate(sources):
                vals, strs = src.tick()
                tree.ingest(i % tree.fanin[0], vals, strs)
            tree.tick(t)
    # reset accounting after warmup
    tree.results.clear()
    tree.items_ingested = 0
    tree.items_forwarded = [0] * len(tree.fanin)
    tree.level_time_s = [0.0] * len(tree.fanin)
    tree.dispatch_count = 0

    exact_sum = 0.0
    exact_cnt = 0
    t0 = time.time()
    if engine == "scan":
        for e in range(n_epochs):
            b = S.batch_ingest(sources, epoch_t, tree.fanin[0], width)
            exact_sum += b.exact_sum
            exact_cnt += b.exact_count
            tree.run_epoch(t0_tick + e * epoch_t, b.values, b.strata,
                           b.counts, offered=b.offered)
    else:
        for t in range(warmup_ticks + 1, warmup_ticks + ticks + 1):
            for i, src in enumerate(sources):
                vals, strs = src.tick()
                exact_sum += float(vals.sum())
                exact_cnt += len(vals)
                tree.ingest(i % tree.fanin[0], vals, strs)
            tree.tick(t)
    wall = time.time() - t0

    approx_sum = float(sum(r["sum"] for r in tree.results))
    bound = 2 * float(np.sqrt(sum(r["sum_var"] for r in tree.results)))
    acc_loss = abs(approx_sum - exact_sum) / max(abs(exact_sum), 1e-9)

    # -------- latency + pipeline-throughput model (module docstring) -----
    # level_time_s[lvl] sums every node of the level; in the testbed the
    # nodes are separate machines, so per-item path cost and the sustained
    # rate are per-NODE quantities.
    n_windows = max(len(tree.results), 1)
    it = interval_ticks or [1] * len(tree.fanin)
    window_wait = sum(iv / 2.0 for iv in it)          # in ticks
    node_time = [lt / max(n, 1) for lt, n in zip(tree.level_time_s, tree.fanin)]
    proc = sum(nt / n_windows for nt in node_time)
    fwd = [tree.items_ingested] + tree.items_forwarded[:-1]
    transfer = sum(
        HOP_RTT_S[min(h, len(HOP_RTT_S) - 1)] / 2.0
        + (fwd[h] / n_windows / max(tree.fanin[min(h, len(tree.fanin) - 1)], 1))
        * ITEM_BYTES / LINK_BW
        for h in range(len(tree.fanin)))
    latency = proc + transfer
    # Sustained pipeline rate = the slowest stage (per node): the §V-A
    # methodology saturates the datacenter node, so at fraction 1.0 the
    # root is the bottleneck and sampling moves it toward the edge.
    bottleneck = max(nt / max(wall, 1e-9) for nt in node_time)  # utilization
    pipeline_tp = (exact_cnt / max(wall, 1e-9)) / max(bottleneck, 1e-9)
    return {
        "fraction": fraction,
        "mode": mode,
        "engine": engine,
        "sampler_backend": sampler_backend,
        "dispatches": tree.dispatch_count,
        "approx_sum": approx_sum,
        "exact_sum": exact_sum,
        "bound_2sigma": bound,
        "accuracy_loss": acc_loss,
        "within_2sigma": abs(approx_sum - exact_sum) <= bound,
        "items_ingested": tree.items_ingested,
        "items_forwarded": tree.items_forwarded,
        "bandwidth_fraction": (tree.items_forwarded[0] /
                               max(tree.items_ingested, 1)),
        "wall_s": wall,
        "throughput_items_s": exact_cnt / max(wall, 1e-9),
        "pipeline_items_s": pipeline_tp,
        "level_time_s": list(tree.level_time_s),
        "latency_s": latency,
        "latency_window_ticks": window_wait,
        "windows": len(tree.results),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dist", default="gaussian",
                    choices=["gaussian", "poisson", "poisson-skewed", "taxi",
                             "pollution"])
    ap.add_argument("--fraction", type=float, default=0.1)
    ap.add_argument("--ticks", type=int, default=20)
    ap.add_argument("--allocation", default="fair",
                    choices=["fair", "proportional"])
    ap.add_argument("--mode", default="whs", choices=["whs", "srs"])
    ap.add_argument("--engine", default="level",
                    choices=["level", "loop", "scan"],
                    help="level = one jitted dispatch per level per tick; "
                         "loop = per-node reference engine; scan = whole "
                         "tree fused, one dispatch per epoch of ticks")
    ap.add_argument("--epoch-ticks", type=int, default=None,
                    help="scan engine: ticks fused per epoch dispatch "
                         "(default: min(ticks, 64))")
    ap.add_argument("--backend", default="topk",
                    choices=["argsort", "topk", "pallas"],
                    help="sampler selection backend: argsort = lexsort "
                         "reference, topk = dense partial-selection "
                         "thresholds, pallas = fused kernels (interpret "
                         "mode off-TPU)")
    args = ap.parse_args(argv)

    specs = {
        "gaussian": S.paper_gaussian(),
        "poisson": S.paper_poisson(),
        "poisson-skewed": S.paper_poisson(
            rates=tuple(8000 * s for s in S.SKEW_SHARES), skewed=True),
        "taxi": S.taxi_like(),
        "pollution": S.pollution_like(),
    }[args.dist]
    r = run_pipeline(specs, fraction=args.fraction, ticks=args.ticks,
                     allocation=args.allocation, mode=args.mode,
                     engine=args.engine, sampler_backend=args.backend,
                     warmup_ticks=2, epoch_ticks=args.epoch_ticks)
    print(f"dist={args.dist} mode={args.mode} engine={args.engine} "
          f"backend={args.backend} fraction={r['fraction']:.0%}")
    print(f"  SUM ≈ {r['approx_sum']:.4e} ± {r['bound_2sigma']:.2e} "
          f"(exact {r['exact_sum']:.4e}; within 2σ: {r['within_2sigma']})")
    print(f"  accuracy loss  {r['accuracy_loss']:.5%}")
    print(f"  bandwidth kept {r['bandwidth_fraction']:.1%} of ingested items")
    print(f"  throughput     {r['throughput_items_s']:.0f} items/s "
          f"({r['items_ingested']} items, {r['windows']} windows, "
          f"{r['dispatches']} jitted dispatches)")
    print(f"  latency        {r['latency_s'] * 1e3:.1f} ms/window "
          f"(+{r['latency_window_ticks']:.1f} tick window wait)")
    return r


if __name__ == "__main__":
    main()

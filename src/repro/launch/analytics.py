"""The paper's own workload: hierarchical stream analytics driver.

Builds the §V testbed topology (8 sources → 4 → 2 → 1 root) as a
``HostTree``, streams synthetic sub-streams through it, and reports
windowed SUM/MEAN with ±kσ error bounds, accuracy-vs-exact, throughput,
per-hop bandwidth, and a modeled end-to-end latency. This is what
benchmarks/fig*.py drive.

Latency model (Fig. 9/10): the testbed's WAN is emulated following §V-A —
RTTs of 20/40/80 ms between layers, 1 Gbps links, 16 B/item. End-to-end
latency of an item =

    window_wait (interval/2 on average, per level)
  + measured per-node processing time per interval
  + Σ_hops (RTT_h/2 + forwarded_bytes_h / link_bw)

Sampling cuts both the upper-level processing (smaller buffers) and the
transfer terms — the same mechanism as the paper's speedup.

    PYTHONPATH=src python -m repro.launch.analytics --dist gaussian \
        --fraction 0.1 --ticks 20
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.tree import HostTree
from repro.data import stream as S

# §V-A WAN emulation constants.
HOP_RTT_S = (0.020, 0.040, 0.080)   # source→L0, L0→L1, L1→root
LINK_BW = 1e9 / 8                   # 1 Gbps in bytes/s
ITEM_BYTES = 16                     # value + stratum tag + framing


def _window_rel_error(w: dict, plan=None) -> float:
    """Measured relative ±2σ error of one root window — the signal the
    error-budget controller consumes (no ground truth needed online).

    With a registered query plan this is the WORST per-query relative
    bound across the CLT queries (sum/mean) in the window's answer
    vector; otherwise the built-in windowed SUM's. Sketch queries carry
    deterministic structural bounds, so they don't vote."""
    rels = []
    if plan is not None and "answers" in w:
        for _, (off, _, kind) in plan.layout().items():
            if kind in ("sum", "mean"):
                est = abs(float(w["answers"][off]))
                rels.append(float(w["bounds"][off]) / max(est, 1e-9))
    if not rels:
        est = abs(w["sum"])
        rels = [2.0 * float(np.sqrt(max(w["sum_var"], 0.0)))
                / max(est, 1e-9)]
    return max(rels)


def build_tree(num_strata: int, capacity: int, fraction: float,
               fanin=(4, 2, 1), interval_ticks=None, allocation="fair",
               seed: int = 0, mode: str = "whs", engine: str = "level",
               sampler_backend: str = "topk", queries=None,
               max_fraction: float | None = None) -> HostTree:
    if mode == "srs":
        # Coin-flip keeps ~p_level of arrivals at each node. A level-l node
        # receives fanin[0]·capacity·p^l / fanin[l] items (fan-in
        # concentrates the stream), so its outbound buffer must hold
        # p^(l+1)·that, with slack — truncating would break Horvitz–
        # Thompson unbiasedness.
        p = fraction ** (1.0 / len(fanin))
        total = fanin[0] * capacity
        sizes = [max(int(1.3 * total * (p ** (lvl + 1)) / fanin[lvl]), 8)
                 for lvl in range(len(fanin))]
        max_sizes = None
    else:
        sizes = [max(int(capacity * fraction), 1)] * len(fanin)
        # Closed-loop operation provisions buffers for the controller's
        # budget ceiling so it can grow the sample without retraces.
        max_sizes = ([max(int(capacity * max_fraction), 1)] * len(fanin)
                     if max_fraction is not None else None)
    return HostTree(
        fanin=list(fanin), num_strata=num_strata, capacity=capacity,
        sample_sizes=sizes, interval_ticks=interval_ticks,
        allocation=allocation, seed=seed, mode=mode, fraction=fraction,
        engine=engine, sampler_backend=sampler_backend, queries=queries,
        max_sample_sizes=max_sizes)


def run_pipeline(specs, *, fraction: float, ticks: int, capacity: int | None = None,
                 num_sources: int = 8, fanin=(4, 2, 1), interval_ticks=None,
                 allocation: str = "fair", seed: int = 0, mode: str = "whs",
                 engine: str = "level", sampler_backend: str = "topk",
                 warmup_ticks: int = 0, epoch_ticks: int | None = None,
                 queries=None, target_rel_error: float | None = None,
                 max_fraction: float | None = None,
                 return_stream: bool = False):
    """Stream → tree → per-window results + ground truth. Returns a dict.

    ``capacity=None`` provisions level-0 buffers for the offered load
    (Σ rates × sources per node × interval, with 35% Poisson slack) —
    level-0 drops carry no metadata, so an under-provisioned ingest
    buffer silently biases the estimate downward.

    ``warmup_ticks`` extra ticks are run first (jit compilation, caches)
    and excluded from the throughput/latency wall-clock measurement —
    accuracy accounting starts after warmup too, so estimates match.

    ``engine="scan"`` batches ``epoch_ticks`` ticks (default:
    ``min(ticks, 64)`` — bounding the epoch keeps the host-side ingest
    batch and the stacked per-tick outputs flat in memory and the scan
    compile time constant for long runs) into one fused dispatch per
    epoch. Its warmup runs one full epoch (any ``warmup_ticks > 0``
    requests it) so the measured epochs hit a compiled program, and
    ``ticks`` is rounded up to whole epochs so every dispatch reuses
    the one compiled scan length.

    ``queries`` registers a ``repro.query`` standing-query registry at
    the root: every window's results then carry ``answers``/``bounds``
    vectors for all K queries (same dispatch count — the plan evaluates
    inside the tick). ``target_rel_error`` closes the §IV-B loop: a
    ``BudgetController`` reads each epoch's (window's) measured relative
    ±2σ error and moves the per-level sample budgets toward the target,
    within ``[8, capacity·max_fraction]`` (``max_fraction`` defaults to
    1.0 when a controller is active). ``return_stream`` additionally
    returns the raw ingested stream for ground-truth evaluation.
    """
    if capacity is None:
        per_node_rate = sum(s.rate for s in specs) * num_sources / fanin[0]
        iv0 = (interval_ticks or [1])[0]
        capacity = max(int(1.35 * per_node_rate * iv0) + 256 & ~255, 1024)
    if target_rel_error is not None:
        assert mode == "whs", "the error-budget loop drives WHS budgets"
        max_fraction = 1.0 if max_fraction is None else max_fraction
    tree = build_tree(len(specs), capacity, fraction, fanin,
                      interval_ticks, allocation, seed, mode,
                      engine, sampler_backend, queries=queries,
                      max_fraction=max_fraction)
    sources = [S.StreamSource(specs, seed=seed * 977 + i)
               for i in range(num_sources)]
    controller = None
    trajectory: list[dict] = []
    if target_rel_error is not None:
        from repro.runtime.budget import BudgetConfig, BudgetController

        controller = BudgetController(
            BudgetConfig(min_size=8, max_size=int(tree.max_sample_sizes[0]),
                         target_rel_error=target_rel_error),
            initial_size=int(tree.sample_sizes[0]))
    # Only materialize the raw stream when the caller asked for it —
    # collection is O(items) host memory/time, which would silently void
    # the scan engine's flat-memory property on long --queries runs.
    collect = return_stream
    stream_v: list[np.ndarray] = []
    stream_s: list[np.ndarray] = []

    def _feedback(new_windows: list[dict], step: int) -> None:
        """Feed the controller the freshest measured relative ±2σ error
        and move every level's budget (§IV-B adaptive feedback)."""
        if controller is None or not new_windows:
            return
        rels = [_window_rel_error(w, tree.plan) for w in new_windows]
        rel = float(np.mean([r for r in rels if np.isfinite(r)] or [0.0]))
        size = controller.update(rel_error=rel)
        tree.set_sample_sizes([size] * len(tree.fanin))
        trajectory.append(dict(step=step, rel_error=rel, size=size))

    if engine == "scan":
        epoch_t = min(epoch_ticks or 64, ticks)
        n_epochs = -(-ticks // epoch_t)  # ceil: whole epochs only
        width = tree.capacities[0]
        t0_tick = 1
        if warmup_ticks > 0:  # one full epoch: compiles the scan program
            wb = S.batch_ingest(sources, epoch_t, tree.fanin[0], width)
            tree.run_epoch(t0_tick, wb.values, wb.strata, wb.counts,
                           offered=wb.offered)
            t0_tick += epoch_t
    else:
        for t in range(1, warmup_ticks + 1):
            for i, src in enumerate(sources):
                vals, strs = src.tick()
                tree.ingest(i % tree.fanin[0], vals, strs)
            tree.tick(t)
    # reset accounting after warmup (sketch state included: continuous
    # answers must cover exactly the measured stream)
    tree.reset_query_state()
    tree.results.clear()
    tree.items_ingested = 0
    tree.items_forwarded = [0] * len(tree.fanin)
    tree.level_time_s = [0.0] * len(tree.fanin)
    tree.dispatch_count = 0

    exact_sum = 0.0
    exact_cnt = 0
    t0 = time.time()
    if engine == "scan":
        for e in range(n_epochs):
            b = S.batch_ingest(sources, epoch_t, tree.fanin[0], width)
            exact_sum += b.exact_sum
            exact_cnt += b.exact_count
            if collect:
                for tt in range(epoch_t):
                    for node in range(tree.fanin[0]):
                        c = int(b.counts[tt, node])
                        stream_v.append(b.values[tt, node, :c])
                        stream_s.append(b.strata[tt, node, :c])
            n_before = len(tree.results)
            tree.run_epoch(t0_tick + e * epoch_t, b.values, b.strata,
                           b.counts, offered=b.offered)
            _feedback(tree.results[n_before:], step=e)
    else:
        for t in range(warmup_ticks + 1, warmup_ticks + ticks + 1):
            for i, src in enumerate(sources):
                vals, strs = src.tick()
                exact_sum += float(vals.sum())
                exact_cnt += len(vals)
                if collect:
                    stream_v.append(vals)
                    stream_s.append(strs)
                tree.ingest(i % tree.fanin[0], vals, strs)
            n_before = len(tree.results)
            tree.tick(t)
            _feedback(tree.results[n_before:], step=t)
    wall = time.time() - t0

    approx_sum = float(sum(r["sum"] for r in tree.results))
    bound = 2 * float(np.sqrt(sum(r["sum_var"] for r in tree.results)))
    acc_loss = abs(approx_sum - exact_sum) / max(abs(exact_sum), 1e-9)

    # -------- latency + pipeline-throughput model (module docstring) -----
    # level_time_s[lvl] sums every node of the level; in the testbed the
    # nodes are separate machines, so per-item path cost and the sustained
    # rate are per-NODE quantities.
    n_windows = max(len(tree.results), 1)
    it = interval_ticks or [1] * len(tree.fanin)
    window_wait = sum(iv / 2.0 for iv in it)          # in ticks
    node_time = [lt / max(n, 1) for lt, n in zip(tree.level_time_s, tree.fanin)]
    proc = sum(nt / n_windows for nt in node_time)
    fwd = [tree.items_ingested] + tree.items_forwarded[:-1]
    transfer = sum(
        HOP_RTT_S[min(h, len(HOP_RTT_S) - 1)] / 2.0
        + (fwd[h] / n_windows / max(tree.fanin[min(h, len(tree.fanin) - 1)], 1))
        * ITEM_BYTES / LINK_BW
        for h in range(len(tree.fanin)))
    latency = proc + transfer
    # Sustained pipeline rate = the slowest stage (per node): the §V-A
    # methodology saturates the datacenter node, so at fraction 1.0 the
    # root is the bottleneck and sampling moves it toward the edge.
    bottleneck = max(nt / max(wall, 1e-9) for nt in node_time)  # utilization
    pipeline_tp = (exact_cnt / max(wall, 1e-9)) / max(bottleneck, 1e-9)
    extras = {}
    if tree.plan is not None:
        extras["query_layout"] = {
            n: dict(offset=o, width=wd, kind=k)
            for n, (o, wd, k) in tree.plan.layout().items()}
        extras["windows_answers"] = [r["answers"] for r in tree.results
                                     if "answers" in r]
        extras["windows_bounds"] = [r["bounds"] for r in tree.results
                                    if "bounds" in r]
    if controller is not None:
        extras["controller"] = trajectory
        extras["final_sample_sizes"] = list(tree.sample_sizes)
    if return_stream:
        extras["stream_values"] = (np.concatenate(stream_v) if stream_v
                                   else np.zeros(0, np.float32))
        extras["stream_strata"] = (np.concatenate(stream_s) if stream_s
                                   else np.zeros(0, np.int32))
    return {
        **extras,
        "fraction": fraction,
        "mode": mode,
        "engine": engine,
        "sampler_backend": sampler_backend,
        "dispatches": tree.dispatch_count,
        "approx_sum": approx_sum,
        "exact_sum": exact_sum,
        "bound_2sigma": bound,
        "accuracy_loss": acc_loss,
        "within_2sigma": abs(approx_sum - exact_sum) <= bound,
        "items_ingested": tree.items_ingested,
        "items_forwarded": tree.items_forwarded,
        "bandwidth_fraction": (tree.items_forwarded[0] /
                               max(tree.items_ingested, 1)),
        "wall_s": wall,
        "throughput_items_s": exact_cnt / max(wall, 1e-9),
        "pipeline_items_s": pipeline_tp,
        "level_time_s": list(tree.level_time_s),
        "latency_s": latency,
        "latency_window_ticks": window_wait,
        "windows": len(tree.results),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dist", default="gaussian",
                    choices=["gaussian", "poisson", "poisson-skewed", "taxi",
                             "pollution"])
    ap.add_argument("--fraction", type=float, default=0.1)
    ap.add_argument("--ticks", type=int, default=20)
    ap.add_argument("--allocation", default="fair",
                    choices=["fair", "proportional"])
    ap.add_argument("--mode", default="whs", choices=["whs", "srs"])
    ap.add_argument("--engine", default="level",
                    choices=["level", "loop", "scan"],
                    help="level = one jitted dispatch per level per tick; "
                         "loop = per-node reference engine; scan = whole "
                         "tree fused, one dispatch per epoch of ticks")
    ap.add_argument("--epoch-ticks", type=int, default=None,
                    help="scan engine: ticks fused per epoch dispatch "
                         "(default: min(ticks, 64))")
    ap.add_argument("--backend", default="topk",
                    choices=["argsort", "topk", "pallas"],
                    help="sampler selection backend: argsort = lexsort "
                         "reference, topk = dense partial-selection "
                         "thresholds, pallas = fused kernels (interpret "
                         "mode off-TPU)")
    ap.add_argument("--queries", default=None, metavar="TOKENS",
                    help="standing queries answered at the root every "
                         "window, e.g. "
                         "'sum,count,mean,hist:0:120000:32,q:0.5:0.9:0.99,hh'"
                         " (see repro.query.registry)")
    ap.add_argument("--target-rel-error", type=float, default=None,
                    help="close the §IV-B loop: adapt per-level sample "
                         "budgets online until the measured relative ±2σ "
                         "error meets this target")
    ap.add_argument("--max-fraction", type=float, default=None,
                    help="budget ceiling for the error-budget controller "
                         "(fraction of window capacity; default 1.0)")
    args = ap.parse_args(argv)

    specs = {
        "gaussian": S.paper_gaussian(),
        "poisson": S.paper_poisson(),
        "poisson-skewed": S.paper_poisson(
            rates=tuple(8000 * s for s in S.SKEW_SHARES), skewed=True),
        "taxi": S.taxi_like(),
        "pollution": S.pollution_like(),
    }[args.dist]
    registry = None
    if args.queries:
        from repro.query.registry import QueryRegistry

        registry = QueryRegistry.from_tokens(args.queries)
    r = run_pipeline(specs, fraction=args.fraction, ticks=args.ticks,
                     allocation=args.allocation, mode=args.mode,
                     engine=args.engine, sampler_backend=args.backend,
                     warmup_ticks=2, epoch_ticks=args.epoch_ticks,
                     queries=registry, target_rel_error=args.target_rel_error,
                     max_fraction=args.max_fraction)
    print(f"dist={args.dist} mode={args.mode} engine={args.engine} "
          f"backend={args.backend} fraction={r['fraction']:.0%}")
    print(f"  SUM ≈ {r['approx_sum']:.4e} ± {r['bound_2sigma']:.2e} "
          f"(exact {r['exact_sum']:.4e}; within 2σ: {r['within_2sigma']})")
    print(f"  accuracy loss  {r['accuracy_loss']:.5%}")
    print(f"  bandwidth kept {r['bandwidth_fraction']:.1%} of ingested items")
    print(f"  throughput     {r['throughput_items_s']:.0f} items/s "
          f"({r['items_ingested']} items, {r['windows']} windows, "
          f"{r['dispatches']} jitted dispatches)")
    print(f"  latency        {r['latency_s'] * 1e3:.1f} ms/window "
          f"(+{r['latency_window_ticks']:.1f} tick window wait)")
    if registry is not None and r.get("windows_answers"):
        last_a, last_b = r["windows_answers"][-1], r["windows_bounds"][-1]
        print("  standing queries (last window, ± bound):")
        for name, lay in r["query_layout"].items():
            o, wd = lay["offset"], lay["width"]
            a = ", ".join(f"{v:.4g}" for v in last_a[o:o + min(wd, 6)])
            b = ", ".join(f"{v:.3g}" for v in last_b[o:o + min(wd, 6)])
            more = " …" if wd > 6 else ""
            print(f"    {name:<12} [{a}{more}] ± [{b}{more}]")
    if r.get("controller"):
        tr = r["controller"]
        print(f"  error-budget controller: size {tr[0]['size']}→"
              f"{tr[-1]['size']} over {len(tr)} updates "
              f"(rel err {tr[0]['rel_error']:.4f}→{tr[-1]['rel_error']:.4f},"
              f" target {args.target_rel_error})")
    return r


if __name__ == "__main__":
    main()

"""Linear queries over weighted samples.

Any query of the form ``Σ_k f(item_k)`` is estimated unbiasedly from the
weighted sample as ``Σ_i W_i^out · Σ_{k∈sample_i} f(item_k)`` — SUM, COUNT,
MEAN, histograms, and (importantly for the training plane) the total loss
of a token stream all fit. Each query returns a ``QueryResult`` with a CLT
variance so the root can attach ±kσ bounds (§III-D).
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from repro.core import error as err
from repro.core.types import IntervalBatch, QueryResult, SampleResult, StratumMeta


def weighted_sum(batch: IntervalBatch, res: SampleResult, num_strata: int) -> QueryResult:
    return err.approx_sum(batch.value, batch.stratum, res.selected, res.meta, num_strata)


def weighted_mean(batch: IntervalBatch, res: SampleResult, num_strata: int) -> QueryResult:
    return err.approx_mean(batch.value, batch.stratum, res.selected, res.meta, num_strata)


def weighted_count(batch: IntervalBatch, res: SampleResult, num_strata: int) -> QueryResult:
    """Estimated number of items in the original stream (f = 1)."""
    ones = jnp.ones_like(batch.value)
    return err.approx_sum(ones, batch.stratum, res.selected, res.meta, num_strata)


def weighted_histogram(
    batch: IntervalBatch,
    res: SampleResult,
    num_strata: int,
    edges: jnp.ndarray,
) -> QueryResult:
    """Estimated item-count per value bin — a vector of linear queries.

    ``edges`` f32[B+1] monotone. Returns estimate f32[B] with per-bin
    variance (each bin indicator is a linear query; bins share samples so
    variances are per-bin CLT, covariances ignored as in the paper).
    """
    nbins = edges.shape[0] - 1
    bin_ix = jnp.clip(jnp.searchsorted(edges, batch.value, side="right") - 1, 0, nbins - 1)
    w_item = res.meta.weight[batch.stratum]
    sel = res.selected
    est = jnp.zeros((nbins,), jnp.float32).at[jnp.where(sel, bin_ix, nbins - 1)].add(
        jnp.where(sel, w_item, 0.0)
    )
    # Per-bin plug-in variance: var_bin ≈ Σ_items w_item·(w_item−1) over
    # sampled items in the bin (Bernoulli-in-stratum indicator queries;
    # exactly 0 at fraction 1.0 where every w_item == 1).
    contrib = jnp.where(sel, w_item * jnp.maximum(w_item - 1.0, 0.0), 0.0)
    var = jnp.zeros((nbins,), jnp.float32).at[
        jnp.where(sel, bin_ix, nbins - 1)
    ].add(contrib)
    return QueryResult(estimate=est, variance=var)


def map_query(
    f: Callable[[jnp.ndarray], jnp.ndarray],
    batch: IntervalBatch,
    res: SampleResult,
    num_strata: int,
) -> QueryResult:
    """Generic linear query ``Σ f(item)`` — the extension point for users."""
    return err.approx_sum(f(batch.value), batch.stratum, res.selected, res.meta, num_strata)


def weighted_loss(
    per_example_loss: jnp.ndarray,
    stratum: jnp.ndarray,
    selected: jnp.ndarray,
    meta: StratumMeta,
) -> jnp.ndarray:
    """Training-plane query: unbiased mean loss of the *full* stream.

    ``E[Σ_sel w·loss / Σ_sel w·1] ≈ full-stream mean loss`` — the ratio
    estimator the approximate-training pipeline feeds to ``grad``.
    """
    w = meta.weight[stratum] * selected.astype(jnp.float32)
    return jnp.sum(w * per_example_loss) / jnp.maximum(jnp.sum(w), 1e-9)

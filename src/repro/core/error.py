"""Error estimation for approximate linear queries (§III-D).

At the root, each stratum ``S_i`` contributes ``Y_i`` uniformly-sampled
items with effective weight ``W_i^out``. By the CLT (finite-population
corrected):

    Var(SUM_i)  = c_src_i · (c_src_i − Y_i) · s_i² / Y_i          (Eq. 11)
    Var(MEAN_*) = Σ_i φ_i² · s_i²/Y_i · (c_src_i − Y_i)/c_src_i    (Eq. 14)

with ``c_src_i`` recovered as ``Y_i · W_i^out`` (valid because either
``Y_i = N_{i,χ}`` or the stratum was never down-sampled), ``s_i²`` the
per-stratum sample variance, and ``φ_i = c_src_i / Σ c_src``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.types import QueryResult, StratumMeta


def stratum_moments(
    value: jnp.ndarray, stratum: jnp.ndarray, selected: jnp.ndarray, num_strata: int
):
    """Per-stratum (Y_i, Σx, Σx²) over the *sampled* items. All f32[X]."""
    seg = jnp.where(selected, stratum, num_strata)
    zeros = jnp.zeros((num_strata + 1,), jnp.float32)
    y = zeros.at[seg].add(1.0)[:num_strata]
    s1 = zeros.at[seg].add(jnp.where(selected, value, 0.0))[:num_strata]
    s2 = zeros.at[seg].add(jnp.where(selected, value * value, 0.0))[:num_strata]
    return y, s1, s2


def sample_variance(y: jnp.ndarray, s1: jnp.ndarray, s2: jnp.ndarray) -> jnp.ndarray:
    """Unbiased per-stratum sample variance ``s_i²`` (Eq. 12). 0 when Y_i<2."""
    mean = s1 / jnp.maximum(y, 1.0)
    ss = jnp.maximum(s2 - y * mean * mean, 0.0)
    return jnp.where(y > 1.0, ss / jnp.maximum(y - 1.0, 1.0), 0.0)


def approx_sum(
    value: jnp.ndarray,
    stratum: jnp.ndarray,
    selected: jnp.ndarray,
    meta: StratumMeta,
    num_strata: int,
) -> QueryResult:
    """``SUM_* ± bound`` (Eq. 3 + Eq. 11)."""
    y, s1, s2 = stratum_moments(value, stratum, selected, num_strata)
    return approx_sum_from_moments(y, s1, s2, meta)


def approx_sum_from_moments(
    y: jnp.ndarray, s1: jnp.ndarray, s2: jnp.ndarray, meta: StratumMeta
) -> QueryResult:
    """Eq. 3 + Eq. 11 from precomputed per-stratum moments.

    Split out so a fused multi-query evaluation (``repro.query.compiler``)
    can share ONE ``stratum_moments`` pass across every CLT query."""
    s_sq = sample_variance(y, s1, s2)
    est_per = s1 * meta.weight                       # Eq. 2/4
    c_src = y * meta.weight                          # §III-D
    fpc = jnp.maximum(c_src - y, 0.0)
    var_per = jnp.where(y > 0.0, c_src * fpc * s_sq / jnp.maximum(y, 1.0), 0.0)
    return QueryResult(estimate=jnp.sum(est_per), variance=jnp.sum(var_per))


def approx_mean(
    value: jnp.ndarray,
    stratum: jnp.ndarray,
    selected: jnp.ndarray,
    meta: StratumMeta,
    num_strata: int,
) -> QueryResult:
    """``MEAN_* ± bound`` (Eq. 13 + Eq. 14)."""
    y, s1, s2 = stratum_moments(value, stratum, selected, num_strata)
    return approx_mean_from_moments(y, s1, s2, meta)


def approx_mean_from_moments(
    y: jnp.ndarray, s1: jnp.ndarray, s2: jnp.ndarray, meta: StratumMeta
) -> QueryResult:
    """Eq. 13 + Eq. 14 from precomputed per-stratum moments."""
    s_sq = sample_variance(y, s1, s2)
    c_src = y * meta.weight
    total = jnp.maximum(jnp.sum(c_src), 1.0)
    phi = c_src / total
    mean_per = s1 / jnp.maximum(y, 1.0)
    est = jnp.sum(phi * mean_per)
    var_per = jnp.where(
        (y > 0.0) & (c_src > 0.0),
        phi * phi * s_sq / jnp.maximum(y, 1.0) * fpc_ratio(c_src, y),
        0.0,
    )
    return QueryResult(estimate=est, variance=jnp.sum(var_per))


def fpc_ratio(c_src: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Finite-population correction ``(c_src − Y)/c_src``."""
    return jnp.maximum(c_src - y, 0.0) / jnp.maximum(c_src, 1.0)

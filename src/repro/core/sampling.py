"""Stratified reservoir sampling, adapted for TPU as *priority sampling*.

The paper's per-stratum reservoir sampling (Vitter's Algorithm R inside
Alg. 2, line 10) is inherently sequential: item ``i`` is kept with
probability ``N/i`` and evicts a random resident. Its *output
distribution*, however, is simply "a uniform random subset of size
``min(c, N)`` without replacement". We realize that distribution with a
branch-free, fully-parallel equivalent:

    draw an i.i.d. priority  u_k ~ U(0,1)  per item,
    keep the stratum's top-``N_i`` items by priority.

Equivalence: every size-``min(c,N)`` subset of a stratum is equally likely
under both schemes. Priority sampling additionally merges across shards
for free (top-``N`` of a union of priority-tagged samples is a valid
sample of the union — used for §III-E distributed execution), and lowers
to one sort + gathers on TPU instead of a data-dependent loop.

All shapes are static; the dynamic item count rides in ``valid``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def stratum_counts(stratum: jnp.ndarray, valid: jnp.ndarray, num_strata: int) -> jnp.ndarray:
    """``c_i``: number of valid items per stratum. f32[X]."""
    seg = jnp.where(valid, stratum, num_strata)
    return jnp.zeros((num_strata + 1,), jnp.float32).at[seg].add(1.0)[:num_strata]


def allocate_reservoirs(
    sample_size: jnp.ndarray,
    counts: jnp.ndarray,
    *,
    policy: str = "fair",
    water_fill_iters: int = 4,
) -> jnp.ndarray:
    """``getSampleSize`` (Alg. 2 line 7): split the interval budget across strata.

    ``fair`` (default): equal share per *active* stratum, with water-filling —
    capacity unused by small strata (``c_i < share``) is iteratively
    redistributed to the rest. This is what gives ApproxIoT its skew
    robustness (§V-E): a stratum with 0.01% of the items still gets a full
    share of the reservoir.

    ``proportional``: ``N_i ∝ c_i`` (what SRS approximates in expectation);
    kept for ablations.
    """
    counts = counts.astype(jnp.float32)
    active = counts > 0
    n_active = jnp.maximum(jnp.sum(active.astype(jnp.float32)), 1.0)
    sample_size = jnp.asarray(sample_size, jnp.float32)

    if policy == "proportional":
        total = jnp.maximum(jnp.sum(counts), 1.0)
        return jnp.where(active, jnp.floor(sample_size * counts / total), 0.0)

    if policy != "fair":
        raise ValueError(f"unknown allocation policy: {policy}")

    def body(_, alloc):
        # alloc: current per-stratum cap. Strata smaller than their cap
        # release the surplus; it is re-split among the still-capped strata.
        used = jnp.minimum(alloc, counts)
        surplus = jnp.sum(alloc - used)
        capped = active & (counts > alloc)
        n_capped = jnp.maximum(jnp.sum(capped.astype(jnp.float32)), 1.0)
        bump = jnp.where(capped, jnp.floor(surplus / n_capped), 0.0)
        return jnp.where(active, used + bump, 0.0)

    share = jnp.where(active, jnp.floor(sample_size / n_active), 0.0)
    alloc = jax.lax.fori_loop(0, water_fill_iters, body, share)
    # N_i > c_i and N_i = c_i are equivalent (all items kept, weight 1), so
    # clamping to c_i loses nothing and makes Y_i = N_i hold when saturated.
    return jnp.where(active, jnp.minimum(alloc, counts), 0.0)


def stratified_priority_sample(
    key: jax.Array,
    stratum: jnp.ndarray,
    valid: jnp.ndarray,
    reservoirs: jnp.ndarray,
    num_strata: int,
    priorities: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Select per-stratum top-``N_i``-by-priority items. Returns bool[M].

    Exactly reproduces per-stratum reservoir sampling's output law
    (uniform w/o replacement, size ``min(c_i, N_i)``).
    """
    m = stratum.shape[0]
    if priorities is None:
        priorities = jax.random.uniform(key, (m,))
    # Composite sort key: [stratum, descending priority]; invalid items are
    # banished to a sentinel stratum that sorts last.
    seg = jnp.where(valid, stratum, num_strata).astype(jnp.float32)
    sort_key = seg * 2.0 + (1.0 - jnp.where(valid, priorities, -0.5))
    order = jnp.argsort(sort_key)

    counts_ext = jnp.zeros((num_strata + 2,), jnp.int32).at[
        jnp.where(valid, stratum, num_strata)
    ].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts_ext)[:-1]])

    seg_sorted = jnp.where(valid, stratum, num_strata)[order]
    rank = jnp.arange(m, dtype=jnp.int32) - starts[seg_sorted]
    res_ext = jnp.concatenate([reservoirs.astype(jnp.int32), jnp.zeros((2,), jnp.int32)])
    keep_sorted = rank < res_ext[seg_sorted]

    return jnp.zeros((m,), bool).at[order].set(keep_sorted) & valid


def merge_priority_samples(
    priorities_a: jnp.ndarray, priorities_b: jnp.ndarray
) -> jnp.ndarray:
    """§III-E merge helper: union of two priority-tagged shard samples.

    Because selection is "top-N by i.i.d. priority", two workers' local
    reservoirs merge by concatenation + re-selection — no coordination.
    Returns the concatenated priority vector (caller re-runs selection).
    """
    return jnp.concatenate([priorities_a, priorities_b], axis=0)

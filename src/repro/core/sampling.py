"""Stratified reservoir sampling, adapted for TPU as *priority sampling*.

The paper's per-stratum reservoir sampling (Vitter's Algorithm R inside
Alg. 2, line 10) is inherently sequential: item ``i`` is kept with
probability ``N/i`` and evicts a random resident. Its *output
distribution*, however, is simply "a uniform random subset of size
``min(c, N)`` without replacement". We realize that distribution with a
branch-free, fully-parallel equivalent:

    draw an i.i.d. priority  u_k ~ U(0,1)  per item,
    keep the stratum's top-``N_i`` items by priority.

Equivalence: every size-``min(c,N)`` subset of a stratum is equally likely
under both schemes. Priority sampling additionally merges across shards
for free (top-``N`` of a union of priority-tagged samples is a valid
sample of the union — used for §III-E distributed execution), and lowers
to one sort + gathers on TPU instead of a data-dependent loop.

All shapes are static; the dynamic item count rides in ``valid``.

Selection is routed through a pluggable ``SamplerBackend`` so the same
WHSamp math can run on either of two equivalent realizations:

* ``argsort``  — one XLA sort over (stratum, priority) composite keys and
  a rank test (this module's ``stratified_priority_sample``).
* ``topk``     — exact per-stratum thresholds from a dense ``lax.top_k``
  (partial selection beats a full sort ~3× on CPU) with stable,
  position-ordered tie resolution, so its masks are bit-identical to
  ``argsort``'s.
* ``pallas``   — per-stratum counts via the fused ``stratified_stats``
  kernel, exact thresholds τ_i from ``kernels.sample_mask.ops``, then the
  fused ``sample_mask`` Pallas kernel for the threshold-select pass
  (compiled on TPU, interpret mode elsewhere).
* ``pallas_fused`` — the whole selection (counts, thresholds via a
  sort-free bisection on priority bit patterns, tie-exact keep mask) in
  ONE Pallas kernel (``kernels.fused_level_tick``); through
  ``whs.level_tick`` it additionally fuses the Alg. 2 weight update and
  the compaction into the same kernel with VMEM-resident reservoirs.

All produce identical keep-masks for identical priorities (exact f32
priority ties included — measure-zero for continuous draws); callers pick
one by name (``get_backend``) everywhere a sampler runs.
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp


def stratum_counts(stratum: jnp.ndarray, valid: jnp.ndarray, num_strata: int) -> jnp.ndarray:
    """``c_i``: number of valid items per stratum. f32[X]."""
    seg = jnp.where(valid, stratum, num_strata)
    return jnp.zeros((num_strata + 1,), jnp.float32).at[seg].add(1.0)[:num_strata]


def stratum_stds(
    values: jnp.ndarray, stratum: jnp.ndarray, valid: jnp.ndarray,
    num_strata: int,
) -> jnp.ndarray:
    """Per-stratum value standard deviation over valid items. f32[X].

    Feeds the ``neyman`` allocation policy (``N_i ∝ c_i·σ_i``); empty
    strata report 0 (their ``c_i·σ_i`` score is 0 anyway)."""
    seg = jnp.where(valid, stratum, num_strata)
    v = jnp.where(valid, values.astype(jnp.float32), 0.0)
    ones = valid.astype(jnp.float32)
    c = jnp.zeros((num_strata + 1,), jnp.float32).at[seg].add(ones)[:num_strata]
    s1 = jnp.zeros((num_strata + 1,), jnp.float32).at[seg].add(v)[:num_strata]
    s2 = jnp.zeros((num_strata + 1,), jnp.float32).at[seg].add(v * v)[:num_strata]
    safe = jnp.maximum(c, 1.0)
    var = jnp.maximum(s2 / safe - jnp.square(s1 / safe), 0.0)
    return jnp.sqrt(var)


def _exclusive_prefix(x: jnp.ndarray) -> jnp.ndarray:
    """Exclusive prefix sum of a 1-D f32 vector via an O(X²) comparison
    matrix. No ``cumsum``/1-D iota so it lowers inside Pallas TPU kernels
    (X = num_strata is small, so the quadratic matrix is free)."""
    n = x.shape[0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    below = jnp.where(jj < ii, jnp.broadcast_to(x[None, :], (n, n)), 0.0)
    return jnp.sum(below, axis=1)


def _settle(alloc, counts, active, budget):
    """Exact-conservation top-up: hand the not-yet-spent part of ``budget``
    to the lowest-indexed strata with headroom (a sequential fill expressed
    as one clip against the exclusive prefix of headroom), so that
    ``Σ alloc == budget`` holds exactly in f32 integer arithmetic."""
    alloc = jnp.where(active, jnp.minimum(alloc, counts), 0.0)
    head = jnp.where(active, counts - alloc, 0.0)
    leftover = budget - jnp.sum(alloc)
    give = jnp.clip(leftover - _exclusive_prefix(head), 0.0, head)
    return alloc + give


def allocate_reservoirs(
    sample_size: jnp.ndarray,
    counts: jnp.ndarray,
    *,
    policy: str = "fair",
    water_fill_iters: int = 4,
    stds: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """``getSampleSize`` (Alg. 2 line 7): split the interval budget across strata.

    Every policy conserves the budget exactly: ``Σ alloc ==
    min(sample_size, Σ counts)`` (floors and water-fill surpluses are
    settled deterministically onto strata with headroom, lowest index
    first), and ``alloc_i ≤ c_i`` always.

    ``fair`` (default): equal share per *active* stratum, with water-filling —
    capacity unused by small strata (``c_i < share``) is iteratively
    redistributed to the rest. This is what gives ApproxIoT its skew
    robustness (§V-E): a stratum with 0.01% of the items still gets a full
    share of the reservoir.

    ``proportional``: ``N_i ∝ c_i`` (what SRS approximates in expectation),
    largest-remainder rounded so rare strata keep their fractional claim;
    kept for ablations.

    ``neyman``: ``N_i ∝ c_i·σ_i`` (minimum-variance allocation for the
    stratified SUM estimator), water-filled like ``fair``. Requires
    ``stds`` — the per-stratum value standard deviations.

    ``proportional`` and ``neyman`` both RESERVE one row per non-empty
    stratum before splitting the remainder. Without the reserve a rare
    stratum's quota/score rounds to zero and its items are dropped with
    no weight — a BIAS, not just variance (under ``SKEW_SHARES`` one
    stratum-D item can carry most of the window's mass). ``fair`` gets
    the same guarantee from its equal shares.
    """
    counts = counts.astype(jnp.float32)
    active = counts > 0
    n_active = jnp.maximum(jnp.sum(active.astype(jnp.float32)), 1.0)
    sample_size = jnp.asarray(sample_size, jnp.float32)
    # The spendable budget: strata can never absorb more than their counts.
    budget = jnp.minimum(sample_size, jnp.sum(counts))

    if policy in ("proportional", "neyman"):
        # One-row unbiasedness reserve; the sequential clip caps it at the
        # budget (index order) when budget < #active — same trick as
        # ``_settle``, Pallas-safe.
        one = jnp.minimum(counts, 1.0)
        reserve = jnp.clip(budget - _exclusive_prefix(one), 0.0, one)
        rem_budget = budget - jnp.sum(reserve)
        rem_counts = counts - reserve

    if policy == "proportional":
        total = jnp.maximum(jnp.sum(rem_counts), 1.0)
        quota = rem_budget * rem_counts / total  # q_i ≤ c_i−r_i: budget ≤ Σc
        base = jnp.floor(quota)
        frac = jnp.where(rem_counts > 0, quota - base, -1.0)
        n_extra = jnp.round(rem_budget - jnp.sum(base))
        # Largest-remainder (Hamilton) rounding without a sort: rank_i =
        # |{j : frac_j > frac_i, ties to the lower index}|, Pallas-safe.
        n = counts.shape[0]
        ii = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
        jj = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
        fr_j = jnp.broadcast_to(frac[None, :], (n, n))
        fr_i = jnp.broadcast_to(frac[:, None], (n, n))
        ahead = (fr_j > fr_i) | ((fr_j == fr_i) & (jj < ii))
        rank = jnp.sum(ahead.astype(jnp.float32), axis=1)
        alloc = reserve + base + jnp.where((rem_counts > 0)
                                           & (rank < n_extra), 1.0, 0.0)
        return _settle(alloc, counts, active, budget)

    if policy == "neyman":
        if stds is None:
            raise ValueError("neyman allocation requires per-stratum stds")
        sigma = jnp.maximum(stds.astype(jnp.float32), 1e-6)
        score = jnp.where(active, counts * sigma, 0.0)

        def neyman_body(_, alloc):
            # Strata already at capacity drop out; the unspent budget is
            # re-split ∝ c·σ among the rest.
            uncapped = active & (alloc < counts)
            s = jnp.where(uncapped, score, 0.0)
            s_tot = jnp.maximum(jnp.sum(s), 1e-30)
            spare = budget - jnp.sum(alloc)
            return jnp.minimum(alloc + jnp.floor(spare * s / s_tot), counts)

        s_tot0 = jnp.maximum(jnp.sum(score), 1e-30)
        alloc0 = jnp.minimum(reserve + jnp.floor(rem_budget * score / s_tot0),
                             counts)
        alloc = jax.lax.fori_loop(0, water_fill_iters, neyman_body, alloc0)
        return _settle(alloc, counts, active, budget)

    if policy != "fair":
        raise ValueError(f"unknown allocation policy: {policy}")

    def body(_, alloc):
        # alloc: current per-stratum cap. Strata smaller than their cap
        # release the surplus; it is re-split among the still-capped strata.
        used = jnp.minimum(alloc, counts)
        surplus = jnp.sum(alloc - used)
        capped = active & (counts > alloc)
        n_capped = jnp.maximum(jnp.sum(capped.astype(jnp.float32)), 1.0)
        bump = jnp.where(capped, jnp.floor(surplus / n_capped), 0.0)
        return jnp.where(active, used + bump, 0.0)

    share = jnp.where(active, jnp.floor(budget / n_active), 0.0)
    alloc = jax.lax.fori_loop(0, water_fill_iters, body, share)
    # N_i > c_i and N_i = c_i are equivalent (all items kept, weight 1), so
    # clamping to c_i loses nothing and makes Y_i = N_i hold when saturated;
    # the settle pass then restores the division remainder and any
    # water-fill surplus dropped by the floors.
    return _settle(alloc, counts, active, budget)


def stratified_priority_sample(
    key: jax.Array,
    stratum: jnp.ndarray,
    valid: jnp.ndarray,
    reservoirs: jnp.ndarray,
    num_strata: int,
    priorities: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Select per-stratum top-``N_i``-by-priority items. Returns bool[M].

    Exactly reproduces per-stratum reservoir sampling's output law
    (uniform w/o replacement, size ``min(c_i, N_i)``).
    """
    m = stratum.shape[0]
    if priorities is None:
        priorities = jax.random.uniform(key, (m,))
    # Lexicographic sort [stratum asc, priority desc]; invalid items are
    # banished to a sentinel stratum that sorts last. Two full-precision
    # keys (not one packed float key): packing seg into the exponent bits
    # ties nearby priorities once seg grows, which breaks the exact
    # per-node ≡ level-flattened equivalence the engine relies on.
    seg = jnp.where(valid, stratum, num_strata)
    order = jnp.lexsort((jnp.where(valid, -priorities, 0.5), seg))

    counts_ext = jnp.zeros((num_strata + 2,), jnp.int32).at[
        jnp.where(valid, stratum, num_strata)
    ].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts_ext)[:-1]])

    seg_sorted = jnp.where(valid, stratum, num_strata)[order]
    rank = jnp.arange(m, dtype=jnp.int32) - starts[seg_sorted]
    res_ext = jnp.concatenate([reservoirs.astype(jnp.int32), jnp.zeros((2,), jnp.int32)])
    keep_sorted = rank < res_ext[seg_sorted]

    return jnp.zeros((m,), bool).at[order].set(keep_sorted) & valid


# --------------------------------------------------------------------------
# Pluggable sampler backends.
# --------------------------------------------------------------------------
@runtime_checkable
class SamplerBackend(Protocol):
    """The two operations WHSamp needs from a selection engine.

    Implementations must agree on the output *law*: ``counts`` returns
    exact per-stratum valid-item counts, and ``select`` keeps exactly the
    per-stratum top-``N_i`` items by priority (ties broken arbitrarily).
    Given identical ``priorities`` all backends return identical masks, so
    they are interchangeable mid-pipeline and testable against each other.
    """

    name: str

    def counts(self, stratum: jnp.ndarray, valid: jnp.ndarray,
               num_strata: int) -> jnp.ndarray:
        """Valid items per stratum. f32[X]."""
        ...

    def select(self, key, stratum: jnp.ndarray, valid: jnp.ndarray,
               reservoirs: jnp.ndarray, num_strata: int, *,
               priorities: jnp.ndarray | None = None,
               max_reservoir: int | None = None,
               batch_hint: int = 1) -> jnp.ndarray:
        """Per-stratum top-``N_i``-by-priority keep mask. bool[M].

        ``max_reservoir`` is an optional *static* upper bound on every
        ``N_i`` (e.g. the level's interval budget); backends may exploit
        it (``topk`` sizes its partial selection with it) or ignore it.
        ``batch_hint`` tells the backend how many sibling problems are
        being vmapped over this call (the level engine passes its node
        count) so memory guards can account for the whole batch.
        """
        ...


class ArgsortBackend:
    """Reference backend: one XLA lexsort + rank test (always available)."""

    name = "argsort"

    def counts(self, stratum, valid, num_strata):
        return stratum_counts(stratum, valid, num_strata)

    def select(self, key, stratum, valid, reservoirs, num_strata, *,
               priorities=None, max_reservoir=None, batch_hint=1):
        return stratified_priority_sample(
            key, stratum, valid, reservoirs, num_strata, priorities=priorities
        )


class TopKBackend:
    """Threshold backend: τ_i from a dense per-stratum ``lax.top_k``.

    Densifies priorities to ``[X, M]`` (invalid/foreign slots → −1), takes
    the top ``max_reservoir`` per stratum, and reads τ_i = the ``N_i``-th
    largest. Items with ``u > τ`` are kept outright; items with ``u == τ``
    (exact f32 ties) are kept in buffer-position order until the reservoir
    is full — the same (priority desc, position asc) law as the stable
    lexsort, so masks are **bit-identical** to ``argsort``'s. Partial
    selection is ~3× cheaper than the full sort on CPU; the dense matrix
    costs ``X·M`` memory **per vmapped sibling** (``batch_hint`` of them
    under the level engine), so selection falls back to ``argsort`` when
    the whole batch exceeds ``_DENSE_LIMIT`` or no static
    ``max_reservoir`` is known.
    """

    name = "topk"
    _DENSE_LIMIT = 1 << 22  # elements of the densified [X, M] matrices

    def counts(self, stratum, valid, num_strata):
        return stratum_counts(stratum, valid, num_strata)

    def select(self, key, stratum, valid, reservoirs, num_strata, *,
               priorities=None, max_reservoir=None, batch_hint=1):
        m = stratum.shape[0]
        if priorities is None:
            priorities = jax.random.uniform(key, (m,))
        if (max_reservoir is None
                or max(int(batch_hint), 1) * num_strata * m > self._DENSE_LIMIT):
            return stratified_priority_sample(
                key, stratum, valid, reservoirs, num_strata,
                priorities=priorities,
            )
        k = int(min(m, max(int(max_reservoir), 1)))
        p_eff = jnp.where(valid, priorities, -1.0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (num_strata, m), 0)
        onrow = stratum[None, :] == cols
        dense = jnp.where(onrow, p_eff[None, :], -1.0)
        topv = jax.lax.top_k(dense, k)[0]                       # [X, k] desc
        n_int = reservoirs.astype(jnp.int32)
        tau = jnp.take_along_axis(
            topv, jnp.clip(n_int - 1, 0, k - 1)[:, None], axis=1)[:, 0]
        # N_i ≤ 0 keeps nothing (τ above any priority); τ == −1 (stratum
        # smaller than its reservoir) keeps every valid item.
        tau = jnp.where(n_int <= 0, 2.0, tau)
        seg_tau = tau[stratum]
        strict = valid & (priorities > seg_tau)
        m_strict = jnp.zeros((num_strata,), jnp.int32).at[stratum].add(
            strict.astype(jnp.int32))
        slack = n_int - m_strict
        tie = valid & (priorities == seg_tau)
        tie_rank = jnp.cumsum(
            jnp.where(onrow, tie[None, :].astype(jnp.int32), 0), axis=1)
        rank_at = tie_rank[stratum, jnp.arange(m)]
        return strict | (tie & (rank_at <= slack[stratum]))


class PallasBackend:
    """TPU-native backend built on the two Pallas kernels.

    ``counts`` is the count column of the fused ``stratified_stats`` pass;
    ``select`` finds exact per-stratum thresholds τ_i (tiny sort) and runs
    the fused ``sample_mask`` threshold kernel over the item buffer. On
    non-TPU hosts the kernels execute in interpret mode, so the backend is
    selectable (and bit-checked against ``argsort``) everywhere.

    ``flatten_for_level = True``: the level engine flattens a level into
    one composite-stratum problem for this backend (one kernel sweep per
    level) instead of vmapping per node — vmapping a ``pallas_call`` adds
    a grid dimension, which interpret mode handles poorly.
    """

    name = "pallas"
    flatten_for_level = True

    def counts(self, stratum, valid, num_strata):
        from repro.kernels.stratified_stats import ops as ss_ops

        stats = ss_ops.stratified_stats(
            jnp.zeros(stratum.shape, jnp.float32), stratum, valid, num_strata,
            impl="pallas",
        )
        return stats[:, 0]

    def select(self, key, stratum, valid, reservoirs, num_strata, *,
               priorities=None, max_reservoir=None, batch_hint=1):
        from repro.kernels.sample_mask import ops as sm_ops

        if priorities is None:
            priorities = jax.random.uniform(key, (stratum.shape[0],))
        tau = sm_ops.thresholds_from_reservoirs(
            priorities, stratum, valid, reservoirs, num_strata
        )
        keep, _ = sm_ops.sample_mask(
            priorities, stratum, valid, tau,
            jnp.ones((num_strata,), jnp.float32), impl="pallas",
        )
        return keep


class PallasFusedBackend:
    """Single-kernel backend: the whole sampling tick fused in VMEM.

    ``select`` runs the ``fused_level_tick`` kernel's selection stage —
    per-stratum counts, an exact bitwise binary search for each τ_i (no
    in-kernel sort), and the strict/tie keep decomposition — in ONE
    Pallas pass with the item buffer VMEM-resident, so its masks are
    **bit-identical** to ``argsort``'s even on exact f32 priority ties
    (unlike ``pallas``, which keeps extras on ties). The level engine
    additionally routes whole-level ticks through the fused kernel (see
    ``whs.level_tick``), collapsing sample + weight-update + compaction
    into one kernel launch per level.

    The dense one-hot working set is ``O(M·X)`` VMEM per problem, so
    selection falls back to ``argsort`` beyond ``_DENSE_LIMIT``.
    """

    name = "pallas_fused"
    flatten_for_level = True
    fused_level_tick = True
    _DENSE_LIMIT = 1 << 22

    def counts(self, stratum, valid, num_strata):
        from repro.kernels.stratified_stats import ops as ss_ops

        stats = ss_ops.stratified_stats(
            jnp.zeros(stratum.shape, jnp.float32), stratum, valid, num_strata,
            impl="pallas",
        )
        return stats[:, 0]

    def select(self, key, stratum, valid, reservoirs, num_strata, *,
               priorities=None, max_reservoir=None, batch_hint=1):
        from repro.kernels.fused_level_tick import ops as ft_ops

        m = stratum.shape[0]
        if priorities is None:
            priorities = jax.random.uniform(key, (m,))
        if max(int(batch_hint), 1) * num_strata * m > self._DENSE_LIMIT:
            return stratified_priority_sample(
                key, stratum, valid, reservoirs, num_strata,
                priorities=priorities,
            )
        return ft_ops.fused_select(priorities, stratum, valid, reservoirs,
                                   num_strata, impl="pallas")


_BACKENDS: dict[str, SamplerBackend] = {}


def register_backend(backend: SamplerBackend) -> None:
    _BACKENDS[backend.name] = backend


register_backend(ArgsortBackend())
register_backend(TopKBackend())
register_backend(PallasBackend())
register_backend(PallasFusedBackend())

DEFAULT_BACKEND = "argsort"


def get_backend(backend: str | SamplerBackend) -> SamplerBackend:
    """Resolve a backend by name (or pass an instance through)."""
    if isinstance(backend, str):
        try:
            return _BACKENDS[backend]
        except KeyError:
            raise ValueError(
                f"unknown sampler backend {backend!r}; "
                f"registered: {sorted(_BACKENDS)}"
            ) from None
    return backend


def merge_priority_samples(
    priorities_a: jnp.ndarray, priorities_b: jnp.ndarray
) -> jnp.ndarray:
    """§III-E merge helper: union of two priority-tagged shard samples.

    Because selection is "top-N by i.i.d. priority", two workers' local
    reservoirs merge by concatenation + re-selection — no coordination.
    Returns the concatenated priority vector (caller re-runs selection).
    """
    return jnp.concatenate([priorities_a, priorities_b], axis=0)

"""ApproxIoT core: weighted hierarchical stratified reservoir sampling.

Public surface:
    types     — IntervalBatch / StratumMeta / SampleResult / QueryResult
    sampling  — priority-sampling primitive + reservoir allocation
    whs       — WHSamp (Alg. 2 + Eq. 9) node step
    srs       — simple-random-sampling baseline
    error     — CLT error estimation (Eq. 11/14)
    queries   — linear queries (sum/mean/count/histogram/loss)
    tree      — host-emulated edge tree + in-graph SPMD hierarchy
    window    — per-node interval buffers
"""
from repro.core import error, queries, sampling, srs, tree, whs, window  # noqa: F401
from repro.core.types import (  # noqa: F401
    IntervalBatch,
    QueryResult,
    SampleResult,
    StratumMeta,
)

"""Interval / window bookkeeping (§III-A "foreach time interval").

Each node owns its intervals — they are *not* synchronized across nodes
(§III-C). A ``Window`` accumulates delivered items into a fixed-capacity
buffer and flushes when its interval elapses.

Metadata combination rules (this is where Alg. 1's "getDataStream"
semantics live):

* Within one interval a node may receive **several messages** carrying
  ``(W^out, C^out)`` sets — from multiple children, and/or several
  intervals' worth from the same child. The per-stratum counts **sum**
  (``C^in_i`` must equal the total number of items the downstream layer
  forwarded for stratum *i* during *this* node's interval, or Eq. 9's
  ``C^in/c`` calibration is biased by the number of messages). The weights
  combine with the **count-weighted mean**: a merged pool of messages
  ``(w_k, C_k)`` represents ``Σ w_k·C_k`` original items over ``Σ C_k``
  forwarded ones, so ``W^in = Σ w_k C_k / Σ C_k``. (The paper's Eq. 5
  ``max`` rule is for combining nodes along a single upstream *path*;
  applied across parallel children with stochastic counts it inflates the
  estimate by ``E[max c] / E[c] ≈ +2%`` per merge level — measured, see
  EXPERIMENTS.md. The count-weighted mean is the unbiased merge.)
* Across intervals the sets are **sticky** (§III-C, Fig. 3): items that
  arrive before their metadata use the most recent saved ``W^in``/``C^in``.

Three implementations share these semantics:

* ``Window``     — one node's buffer (the per-node loop engine).
* ``LevelState`` — every node of a level stacked into ``[n_nodes, ...]``
  arrays, so the level-vectorized engine can flush a whole level into one
  jitted dispatch and fold a level step's outputs back in bulk.
* ``TreeState``  — every level of the whole hierarchy held as a pytree of
  on-device arrays, so the scan engine (``core.tree``) can run the entire
  tree — ingest, flush, sample, route, metadata fold — inside one jitted
  ``lax.scan`` epoch with donated buffers. The host never touches the
  buffers between ticks.

Accumulator precision: all three keep the interval accumulators in
**float32 and fold messages in child order**. The scan engine does this
math in-graph, where float64 is unavailable without globally enabling
x64 (which would change every PRNG draw), so the host buffers use the
same f32 sequential accumulation — that is what keeps all three engines
bit-identical to each other. The merge spans at most a level's fan-in
messages per interval, so the precision loss vs f64 is ≤ a few ulp on
the weight sets — orders of magnitude below the sampling variance.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class Window:
    def __init__(self, capacity: int, num_strata: int, interval_ticks: int):
        self.capacity = int(capacity)
        self.num_strata = int(num_strata)
        self.interval_ticks = int(interval_ticks)
        # Sticky sets: most recent effective W^in / C^in per stratum.
        self.w_in = np.ones((num_strata,), np.float32)
        self.c_in = np.zeros((num_strata,), np.float32)
        self._reset()

    def _reset(self) -> None:
        self.values = np.zeros((self.capacity,), np.float32)
        self.strata = np.zeros((self.capacity,), np.int32)
        self.fill = 0
        self.dropped = 0
        # This-interval metadata accumulators: Σ w·C and Σ C per stratum
        # (f32, message order — see module docstring on precision).
        self._wc_acc = np.zeros((self.num_strata,), np.float32)
        self._c_acc = np.zeros((self.num_strata,), np.float32)
        self._seen = np.zeros((self.num_strata,), bool)

    def deliver(self, values: np.ndarray, strata: np.ndarray,
                weight: np.ndarray | None = None, count: np.ndarray | None = None) -> None:
        """Append items; fold the message's W/C sets into this interval."""
        if weight is not None and count is not None:
            present = np.zeros((self.num_strata,), bool)
            present[np.unique(strata)] = True
            w = weight.astype(np.float32)
            c = count.astype(np.float32)
            self._wc_acc = np.where(present, self._wc_acc + w * c, self._wc_acc)
            self._c_acc = np.where(present, self._c_acc + c, self._c_acc)
            self._seen |= present
        n = len(values)
        take = min(n, self.capacity - self.fill)
        if take < n:
            self.dropped += n - take  # backpressure accounting
        self.values[self.fill : self.fill + take] = values[:take]
        self.strata[self.fill : self.fill + take] = strata[:take]
        self.fill += take

    def due(self, tick: int) -> bool:
        return tick % self.interval_ticks == 0

    def flush(self):
        """Return (values, strata, valid, w_in, c_in) and reset the buffer.

        Strata with fresh metadata this interval use the accumulated sets;
        the rest fall back to the sticky values (§III-C)."""
        valid = np.zeros((self.capacity,), bool)
        valid[: self.fill] = True
        w_merged = self._wc_acc / np.maximum(self._c_acc, np.float32(1.0))
        w_eff = np.where(self._seen, w_merged, self.w_in).astype(np.float32)
        c_eff = np.where(self._seen, self._c_acc, self.c_in).astype(np.float32)
        self.w_in, self.c_in = w_eff, c_eff  # refresh stickies
        out = (self.values.copy(), self.strata.copy(), valid,
               w_eff.copy(), c_eff.copy())
        self._reset()
        return out


class TreeState(NamedTuple):
    """The whole hierarchy's interval state as one on-device pytree.

    Every field is a tuple with one entry per level (levels have distinct
    node counts and capacities, so the node axis is uniform *within* a
    level and the level axis is a pytree axis). This is the carry of the
    scan engine's fused tree-step: ``core.tree`` appends ingest/forwarded
    items, flushes, and folds metadata entirely in-graph, and the epoch
    dispatch donates every leaf so reservoir/window buffers are reused
    in place on device across ticks.

    Per level ``l`` (``n`` nodes, capacity ``M``, ``X`` strata):

    ``values``/``strata``  f32/i32 ``[n, M]`` — item buffers. Flushing
        only resets ``fill`` (stale slots beyond ``fill`` are masked by
        the ``valid`` ranges everywhere downstream, exactly like the
        host engines mask with a fresh-zeroed buffer).
    ``fill``/``dropped``   i32 ``[n]`` — occupancy + backpressure count.
    ``w_in``/``c_in``      f32 ``[n, X]`` — sticky W^in/C^in sets.
    ``wc_acc``/``c_acc``   f32 ``[n, X]`` — this-interval Σw·C / ΣC.
    ``seen``               bool ``[n, X]`` — strata with fresh metadata.

    ``qstate`` is NOT per-level: it is the continuous query plane's
    sketch state (``repro.query.compiler.CompiledQueryPlan.init_state``),
    owned by the root and updated once per root window inside the tick —
    ``()`` when no queries are registered. It rides in ``TreeState`` so
    the epoch dispatch donates it with everything else and standing-query
    state never leaves the device.
    """

    values: tuple
    strata: tuple
    fill: tuple
    dropped: tuple
    w_in: tuple
    c_in: tuple
    wc_acc: tuple
    c_acc: tuple
    seen: tuple
    qstate: tuple = ()
    # Optional ``repro.obs.telemetry.EpochTelemetry`` leaves, carried in
    # the donated state so the scan tick can accumulate counters at zero
    # extra dispatches. ``()`` (zero leaves) when telemetry is disabled —
    # checkpoints, donation, and epoch shapes are untouched by default.
    telemetry: tuple = ()
    # Optional adaptive-stratification routing table: i32 ``[num_keys]``
    # mapping ingest stratum keys → sampling strata (slots). The scan tick
    # gathers through it at source ingest, so a host-side split/merge of
    # strata (``repro.strata.StratumManager``) is a pure same-shape edit
    # of this leaf — zero retraces, exactly like a telemetry reset. ``()``
    # (zero leaves) when routing is disabled: ingest strata are used as-is.
    route: tuple = ()

    # The per-level buffer fields (everything except the root-owned
    # ``qstate``, ``telemetry`` and ``route``) — what the scan tick
    # iterates over level by level.
    LEVEL_FIELDS = ("values", "strata", "fill", "dropped", "w_in", "c_in",
                    "wc_acc", "c_acc", "seen")

    @staticmethod
    def create(fanin: list[int], capacities: list[int],
               num_strata: int, qstate: tuple = (),
               telemetry: tuple = (), route: tuple = ()) -> "TreeState":
        """Fresh (empty-buffer, identity-metadata) whole-tree state;
        ``qstate`` seeds the root's query-sketch state (pass the
        compiled plan's ``init_state()`` when queries are registered);
        ``route`` seeds the key→stratum routing table (pass an identity
        ``jnp.arange(num_keys, dtype=jnp.int32)`` to enable adaptive
        stratification)."""
        import jax.numpy as jnp

        x = num_strata
        zl = lambda dt: tuple(jnp.zeros((n, c), dt)
                              for n, c in zip(fanin, capacities))
        zn = lambda dt: tuple(jnp.zeros((n,), dt) for n in fanin)
        zx = lambda dt: tuple(jnp.zeros((n, x), dt) for n in fanin)
        return TreeState(
            values=zl(jnp.float32), strata=zl(jnp.int32),
            fill=zn(jnp.int32), dropped=zn(jnp.int32),
            w_in=tuple(jnp.ones((n, x), jnp.float32) for n in fanin),
            c_in=zx(jnp.float32), wc_acc=zx(jnp.float32),
            c_acc=zx(jnp.float32), seen=zx(bool), qstate=qstate,
            telemetry=telemetry, route=route,
        )


class LevelState:
    """Stacked windows for all nodes of one hierarchy level.

    Same interval semantics as ``Window`` (count-sum / count-weighted-mean
    metadata merge, sticky fallback), but held as ``[n_nodes, ...]`` arrays
    so one flush feeds one jitted level step, and the step's per-parent
    packed outputs fold back without per-item host work. Within a level all
    nodes share one interval length (§IV's topology), which is what makes
    the stacked flush legal.
    """

    def __init__(self, n_nodes: int, capacity: int, num_strata: int,
                 interval_ticks: int):
        self.n_nodes = int(n_nodes)
        self.capacity = int(capacity)
        self.num_strata = int(num_strata)
        self.interval_ticks = int(interval_ticks)
        # Sticky sets: most recent effective W^in / C^in per node × stratum.
        self.w_in = np.ones((self.n_nodes, self.num_strata), np.float32)
        self.c_in = np.zeros((self.n_nodes, self.num_strata), np.float32)
        self._reset()

    def _reset(self) -> None:
        n, cap, x = self.n_nodes, self.capacity, self.num_strata
        self.values = np.zeros((n, cap), np.float32)
        self.strata = np.zeros((n, cap), np.int32)
        self.fill = np.zeros((n,), np.int64)
        self.dropped = np.zeros((n,), np.int64)
        # This-interval metadata accumulators: Σ w·C and Σ C per stratum
        # (f32, child order — see module docstring on precision).
        self._wc_acc = np.zeros((n, x), np.float32)
        self._c_acc = np.zeros((n, x), np.float32)
        self._seen = np.zeros((n, x), bool)

    def deliver(self, node: int, values: np.ndarray, strata: np.ndarray,
                weight: np.ndarray | None = None,
                count: np.ndarray | None = None) -> None:
        """Append items to one node; fold the message's W/C sets in."""
        if weight is not None and count is not None:
            present = np.zeros((self.num_strata,), bool)
            present[np.unique(strata)] = True
            w = weight.astype(np.float32)
            c = count.astype(np.float32)
            self._wc_acc[node] = np.where(
                present, self._wc_acc[node] + w * c, self._wc_acc[node])
            self._c_acc[node] = np.where(
                present, self._c_acc[node] + c, self._c_acc[node])
            self._seen[node] |= present
        n = len(values)
        take = min(n, self.capacity - int(self.fill[node]))
        if take < n:
            self.dropped[node] += n - take  # backpressure accounting
        f = int(self.fill[node])
        self.values[node, f:f + take] = values[:take]
        self.strata[node, f:f + take] = strata[:take]
        self.fill[node] += take

    def deliver_packed(self, packed_values: np.ndarray,
                       packed_strata: np.ndarray,
                       counts: np.ndarray) -> None:
        """Fold a level step's per-parent packed items into the buffers.

        ``packed_values/strata`` are ``[n_nodes, D]`` with each row's first
        ``counts[p]`` slots holding real items (children concatenated in
        child-index order — the same order the loop engine delivers in).
        """
        for p in range(self.n_nodes):
            n = int(counts[p])
            take = min(n, self.capacity - int(self.fill[p]))
            if take < n:
                self.dropped[p] += n - take
            f = int(self.fill[p])
            self.values[p, f:f + take] = packed_values[p, :take]
            self.strata[p, f:f + take] = packed_strata[p, :take]
            self.fill[p] += take

    def fold_meta(self, parent_ix: np.ndarray, present: np.ndarray,
                  weight: np.ndarray, count: np.ndarray) -> None:
        """Fold per-child (W^out, C^out) messages into parent accumulators.

        ``parent_ix[j]`` is the parent of child ``j``; ``present[j, x]``
        marks strata child ``j`` actually forwarded items for (a message
        with no items for a stratum contributes no metadata — exactly
        ``Window.deliver``'s ``np.unique`` rule). f32 accumulation in
        child order keeps this bit-identical to per-message delivery and
        to the scan engine's in-graph fold.
        """
        w = weight.astype(np.float32)
        c = count.astype(np.float32)
        zero = np.float32(0.0)
        np.add.at(self._wc_acc, parent_ix, np.where(present, w * c, zero))
        np.add.at(self._c_acc, parent_ix, np.where(present, c, zero))
        np.logical_or.at(self._seen, parent_ix, present)

    def due(self, tick: int) -> bool:
        return tick % self.interval_ticks == 0

    def flush_all(self):
        """Return stacked (values, strata, valid, w_in, c_in); reset.

        Semantics per node match ``Window.flush``: fresh metadata wins,
        otherwise sticky values survive (§III-C).
        """
        valid = np.arange(self.capacity)[None, :] < self.fill[:, None]
        w_merged = self._wc_acc / np.maximum(self._c_acc, np.float32(1.0))
        w_eff = np.where(self._seen, w_merged, self.w_in).astype(np.float32)
        c_eff = np.where(self._seen, self._c_acc, self.c_in).astype(np.float32)
        self.w_in, self.c_in = w_eff, c_eff  # refresh stickies
        out = (self.values.copy(), self.strata.copy(), valid,
               w_eff.copy(), c_eff.copy())
        self._reset()
        return out

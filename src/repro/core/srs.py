"""Simple Random Sampling baseline (§IV-B "coin flip sampling").

The paper's comparison baseline: every item is kept independently with
probability ``fraction`` regardless of its stratum. The estimator for a
linear query scales the sample aggregate by ``1/fraction``. Under skewed
sub-stream arrival rates this overlooks rare-but-significant strata
(Fig. 11c), which is exactly what ApproxIoT's stratified allocation fixes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import IntervalBatch, QueryResult


def srs_select(key: jax.Array, batch: IntervalBatch, fraction: float | jnp.ndarray) -> jnp.ndarray:
    """Bernoulli(fraction) coin flip per valid item. Returns bool[M]."""
    u = jax.random.uniform(key, (batch.capacity,))
    return (u < fraction) & batch.valid


def level_srs_select(keys: jax.Array, valid: jnp.ndarray,
                     fraction: float | jnp.ndarray) -> jnp.ndarray:
    """``srs_select`` over a stacked hierarchy level: one key per node,
    ``valid`` is ``[n_nodes, cap]``. Pure array program — traces inside
    ``jit``, ``vmap``, and the scan engine's ``lax.scan`` tree-step —
    and draws the exact per-node uniforms ``srs_select`` would, so the
    loop / level / scan engines stay bit-identical."""
    cap = valid.shape[1]
    u = jax.vmap(lambda k: jax.random.uniform(k, (cap,)))(keys)
    return (u < fraction) & valid


def srs_sum(batch: IntervalBatch, selected: jnp.ndarray, fraction: float) -> QueryResult:
    """Horvitz–Thompson estimate of the interval SUM under SRS.

    Var: Bernoulli sampling variance  Σ x_k² · (1−p)/p  over kept items'
    population — estimated from the sample as Σ_{k∈sample} x_k²·(1−p)/p².
    """
    x = jnp.where(selected, batch.value, 0.0)
    p = jnp.asarray(fraction, jnp.float32)
    est = jnp.sum(x) / p
    var = jnp.sum(x * x) * (1.0 - p) / (p * p)
    return QueryResult(estimate=est, variance=var)


def srs_mean(batch: IntervalBatch, selected: jnp.ndarray, fraction: float) -> QueryResult:
    """Plain sample mean under SRS (self-weighting)."""
    n = jnp.maximum(jnp.sum(selected.astype(jnp.float32)), 1.0)
    x = jnp.where(selected, batch.value, 0.0)
    mean = jnp.sum(x) / n
    ss = jnp.sum(jnp.where(selected, (batch.value - mean) ** 2, 0.0))
    s_sq = ss / jnp.maximum(n - 1.0, 1.0)
    return QueryResult(estimate=mean, variance=s_sq / n)

"""Hierarchy executors: the paper's logical tree, two ways.

``HostTree`` — a discrete-tick emulation of the edge topology (the Kafka
pipeline of §IV): per-node windows, asynchronous intervals, compacted
forwarding, query + error bounds at the root. Used by benchmarks/examples
to reproduce Figs. 6–12. Two execution engines share identical sampling
semantics (and identical randomness — per-node keys are derived by
folding (tick, level, node) into the tree's base key):

* ``engine="level"`` (default) — the level-vectorized engine. Each level's
  nodes live in one ``LevelState`` of stacked buffers; a tick issues
  exactly **one jitted dispatch per level**: WHS/SRS sampling vmapped (and
  selection flattened into a single composite-stratum sort / kernel pass,
  see ``whs.level_whsamp``), compaction row-wise, and child→parent routing
  done in-graph through static scatter indices, so the host only copies
  packed buffers. This is what keeps the host out of the hot loop at high
  fan-in, and — because a level is now a single array program — what makes
  sharding a level over a mesh axis a ``shard_map`` annotation rather than
  a rewrite.
* ``engine="loop"`` — the per-node reference engine (one jitted step per
  node per tick, the seed implementation). Kept as the bit-exact oracle
  for the vectorized engines and for dispatch-cost comparisons.
* ``engine="scan"`` — the fused whole-tree engine. The entire hierarchy
  (ingest → per-level sampling → in-graph child→parent routing →
  metadata fold → root query) is one traced tree-step, and ``T`` ticks
  are batched into a single ``lax.scan`` **epoch** dispatch with every
  reservoir/window buffer donated (``donate_argnums``), so state never
  leaves the device between ticks. Host cost per epoch: one ingest
  transfer down, one stacked result transfer up, one dispatch — the
  per-tick Python round-trip that bounds the ``level`` engine at high
  tick rates is gone. Level steps reuse the *same* core functions as the
  ``level`` engine (``_whs_level_core`` etc.) and the same
  ``(tick, level, node)`` key folding, so all three engines are
  bit-identical on identical ingest.

``sampler_backend`` selects the selection engine end-to-end — ``topk``
(``HostTree``'s default: dense partial-selection thresholds, bit-identical
to the reference and fastest on CPU), ``argsort`` (lexsort reference), or
``pallas`` (fused kernels); see ``core.sampling``. All three backends
trace inside the scan engine's ``lax.scan`` (the pallas kernels run in
interpret mode off-TPU).

``spmd_local_then_root`` — the in-graph two-level hierarchy used at pod
scale: every device samples its local sub-streams, compacts, all-gathers
the *reservoirs only* (this is the bandwidth saving), and the root stage
re-samples + answers the query. Pure ``shard_map``-compatible function; no
coordination beyond one all-gather of sampled data.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import error as err
from repro.core import sampling
from repro.core import whs
from repro.core.types import IntervalBatch, QueryResult, StratumMeta


# --------------------------------------------------------------------------
# Deterministic per-node keys: fold (tick, level, node) into the base key.
# Both engines use this chain, which is what makes them bit-comparable.
# --------------------------------------------------------------------------
def _node_key(key, t, lvl: int, ix):
    return jax.random.fold_in(
        jax.random.fold_in(jax.random.fold_in(key, t), lvl), ix
    )


def _level_keys(key, t, lvl: int, n_nodes: int):
    k = jax.random.fold_in(jax.random.fold_in(key, t), lvl)
    return jax.vmap(
        lambda i: jax.random.fold_in(k, i)
    )(jnp.arange(n_nodes, dtype=jnp.uint32))


def derive_capacities(fanin, capacity: int, max_sample_sizes,
                      interval_ticks) -> list[int]:
    """Per-level buffer capacities from the level-0 capacity and the
    per-level budget ceilings. Level ``l+1``'s buffer holds every child's
    budget times the exact arrival bound (ceil children-per-parent ×
    flushes-per-interval) — a parent buffer can never truncate. Shared by
    ``HostTree`` and the ``repro.api`` compiler, which is what keeps the
    two front doors bit-identical."""
    capacities: list[int] = []
    cap = int(capacity)
    for lvl, n_nodes in enumerate(fanin):
        capacities.append(cap)
        if lvl + 1 < len(fanin):
            children_per_parent = -(-n_nodes // fanin[lvl + 1])  # ceil
            flushes = -(-interval_ticks[lvl + 1] // interval_ticks[lvl])
            cap = max(int(max_sample_sizes[lvl]) * children_per_parent
                      * flushes, 64)
    return capacities


def _child_routing(n_nodes: int, n_parents: int) -> np.ndarray:
    """Static routing table: ``child_of[p, j]`` = index of parent ``p``'s
    ``j``-th child (ascending), padded with the sentinel ``n_nodes``.
    Children map to parents by ``ix % n_parents`` (the testbed wiring)."""
    cpp = -(-n_nodes // n_parents)  # ceil
    child_of = np.full((n_parents, cpp), n_nodes, np.int32)
    for j in range(n_nodes):
        child_of[j % n_parents, j // n_parents] = j
    return child_of


def _present_strata(strata_c, valid_c, num_strata: int):
    """bool[n, X]: strata each node actually forwards items for (drives the
    parent's metadata fold — a message with no items for a stratum must not
    contribute metadata, mirroring ``Window.deliver``)."""
    n = strata_c.shape[0]
    node_ix = jnp.arange(n, dtype=jnp.int32)[:, None]
    seg = jnp.where(valid_c, node_ix * num_strata + strata_c, n * num_strata)
    cnt = jnp.zeros((n * num_strata + 1,), jnp.int32).at[
        seg.reshape(-1)
    ].add(1)[: n * num_strata]
    return (cnt > 0).reshape(n, num_strata)


def _route_pack(values_c, strata_c, valid_c, child_of: np.ndarray):
    """In-graph child→parent routing + packing.

    Gathers each parent's children (static indices), then stably packs the
    valid items to the front of each parent row — children in child-index
    order, items in compacted order, i.e. exactly the order the per-node
    loop engine would deliver them in. Returns
    ``(packed_values[P, D], packed_strata[P, D], n_delivered[P])``.
    """
    n, oc = values_c.shape
    p = child_of.shape[0]
    d = child_of.shape[1] * oc
    vpad = jnp.concatenate([values_c, jnp.zeros((1, oc), values_c.dtype)])
    spad = jnp.concatenate([strata_c, jnp.zeros((1, oc), strata_c.dtype)])
    mpad = jnp.concatenate([valid_c, jnp.zeros((1, oc), bool)])
    gather = jnp.asarray(child_of)
    gv = vpad[gather].reshape(p, d)
    gs = spad[gather].reshape(p, d)
    gm = mpad[gather].reshape(p, d)
    packed_v, packed_s, n_deliv = whs.pack_rows(gv, gs, gm, d)
    return packed_v, packed_s, n_deliv


# --------------------------------------------------------------------------
# Pure core functions — the single source of truth for node/level/root math.
# The jitted `level`/`loop` step factories AND the scan engine's fused
# tree-step call these, which is what keeps every engine bit-identical.
# --------------------------------------------------------------------------
def _whs_root_core(key, t, lvl, values, strata, valid, w_in, c_in,
                   sample_size, *, num_strata, allocation, backend, budget,
                   hist_bins=64, plan=None, qstate=(), telemetry=False):
    """Root = sampling + the user query (§III-A lines 16-20). The query here
    is the paper's evaluation workload: windowed SUM and MEAN with error
    bounds, plus a value histogram (a representative GROUP-BY aggregate —
    the datacenter node runs the real analytics, not just the sampler).

    ``plan`` (a ``repro.query.compiler.CompiledQueryPlan``) extends the
    workload with the continuous query plane: every registered standing
    query is answered from the SAME window sample in the same traced
    program — the plan consumes no sampler randomness (its PRNG stream is
    a ``fold_in`` side-branch of the node key), so sample state is
    bit-identical with or without queries registered. Returns
    ``(outs, qstate')`` where ``outs`` gains ``(answers, bounds)``
    f32[plan.n_out] tails when a plan is present.
    """
    from repro.core import queries

    k = _node_key(key, t, lvl, 0)
    batch = IntervalBatch(values, strata, valid, StratumMeta(w_in, c_in))
    res = whs.whsamp(k, batch, sample_size, num_strata,
                     allocation=allocation, backend=backend,
                     max_reservoir=budget)
    s = err.approx_sum(batch.value, batch.stratum, res.selected, res.meta, num_strata)
    m = err.approx_mean(batch.value, batch.stratum, res.selected, res.meta, num_strata)
    lo = jnp.min(jnp.where(res.selected, batch.value, jnp.inf))
    hi = jnp.max(jnp.where(res.selected, batch.value, -jnp.inf))
    edges = jnp.linspace(lo, hi + 1e-6, hist_bins + 1)
    h = queries.weighted_histogram(batch, res, num_strata, edges)
    outs = (s.estimate, s.variance, m.estimate, m.variance,
            jnp.sum(res.selected.astype(jnp.int32)), h.estimate)
    if plan is None:
        if telemetry:
            outs = outs + (res.c.astype(jnp.float32),
                           res.y.astype(jnp.float32))
        return outs, ()
    qstate2, answers, bounds = plan.evaluate(k, batch, res, qstate)
    outs = outs + (answers, bounds)
    if telemetry:
        # per-stratum offered (c) and kept (y = min(c, reservoir)) counts —
        # the realized stratified sampling fraction comes straight from the
        # sampler's own bookkeeping, no recomputation.
        outs = outs + (res.c.astype(jnp.float32), res.y.astype(jnp.float32))
    return outs, qstate2


def _srs_root_core(key, t, lvl, values, strata, valid, w_in, c_in,
                   p_keep, f_total, *, num_strata, hist_bins=64):
    """Same query workload as the WHS root (fair throughput comparison):
    SUM/MEAN + histogram, with Horvitz–Thompson 1/f weights."""
    from repro.core import srs

    k = _node_key(key, t, lvl, 0)
    batch = IntervalBatch(values, strata, valid, StratumMeta(w_in, c_in))
    selected = srs.srs_select(k, batch, p_keep)
    s = srs.srs_sum(batch, selected, f_total)
    m = srs.srs_mean(batch, selected, f_total)
    lo = jnp.min(jnp.where(selected, batch.value, jnp.inf))
    hi = jnp.max(jnp.where(selected, batch.value, -jnp.inf))
    edges = jnp.linspace(lo, hi + 1e-6, hist_bins + 1)
    bin_ix = jnp.clip(jnp.searchsorted(edges, batch.value, side="right") - 1,
                      0, hist_bins - 1)
    hist = jnp.zeros((hist_bins,), jnp.float32).at[
        jnp.where(selected, bin_ix, hist_bins - 1)
    ].add(jnp.where(selected, 1.0 / f_total, 0.0))
    return (s.estimate, s.variance, m.estimate, m.variance,
            jnp.sum(selected.astype(jnp.int32)), hist)


def _whs_level_core(key, t, lvl, values, strata, valid, w_in, c_in,
                    sample_size, *, num_strata, out_capacity, child_of,
                    allocation, backend):
    """One WHS hierarchy level: sample, compact, route to parents.

    Runs through ``whs.level_tick`` — one fused Pallas kernel for the
    ``pallas_fused`` backend, the saturation passthrough for the rest —
    bit-identical to the unfused ``level_whsamp`` + ``level_compact``.
    """
    n_nodes = values.shape[0]
    keys = _level_keys(key, t, lvl, n_nodes)
    v_c, s_c, valid_c, meta, res = whs.level_tick(
        keys, values, strata, valid, w_in, c_in, sample_size, num_strata,
        out_capacity=out_capacity, allocation=allocation, backend=backend)
    present = _present_strata(s_c, valid_c, num_strata)
    packed_v, packed_s, n_deliv = _route_pack(v_c, s_c, valid_c, child_of)
    n_fwd = jnp.sum(valid_c, axis=1, dtype=jnp.int32)
    return (packed_v, packed_s, n_deliv,
            meta.weight, meta.count, present, n_fwd)


def _srs_level_core(key, t, lvl, values, strata, valid, w_in, c_in,
                    p_keep, *, num_strata, out_capacity, child_of):
    """One SRS hierarchy level: coin-flip keep, compact, route to parents."""
    from repro.core import srs

    n_nodes, capacity = values.shape
    out_cap = min(out_capacity, capacity)
    keys = _level_keys(key, t, lvl, n_nodes)
    selected = srs.level_srs_select(keys, valid, p_keep)
    v_c, s_c, n_sel = whs.pack_rows(values, strata, selected, out_cap)
    n_keep = jnp.minimum(n_sel, out_cap)
    valid_c = jnp.arange(out_cap)[None, :] < n_keep[:, None]
    present = _present_strata(s_c, valid_c, num_strata)
    packed_v, packed_s, n_deliv = _route_pack(v_c, s_c, valid_c, child_of)
    # SRS carries no sampler metadata: W/C sets pass through unchanged.
    return packed_v, packed_s, n_deliv, w_in, c_in, present, n_keep


# --------------------------------------------------------------------------
# Jitted per-node steps (loop engine — the bit-exact reference).
# The sticky W/C buffers are donated (argnums 6/7): their shapes/dtypes
# match the outgoing meta sets exactly, so XLA reuses the reservoir
# metadata buffers in place instead of copying them every tick.
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _node_step(capacity: int, num_strata: int, out_capacity: int,
               allocation: str, backend: str, lvl: int):
    @functools.partial(jax.jit, donate_argnums=(6, 7))
    def step(key, t, ix, values, strata, valid, w_in, c_in, sample_size):
        k = _node_key(key, t, lvl, ix)
        batch = IntervalBatch(values, strata, valid, StratumMeta(w_in, c_in))
        res = whs.whsamp(k, batch, sample_size, num_strata,
                         allocation=allocation, backend=backend,
                         max_reservoir=out_capacity)
        out = whs.compact_sample(batch, res, out_capacity)
        return (out.value, out.stratum, out.valid,
                out.meta.weight, out.meta.count, res.y)

    return step


@functools.lru_cache(maxsize=None)
def _root_step(capacity: int, num_strata: int, allocation: str, backend: str,
               lvl: int, budget: int, hist_bins: int = 64):
    @jax.jit
    def step(key, t, values, strata, valid, w_in, c_in, sample_size):
        outs, _ = _whs_root_core(key, t, lvl, values, strata, valid, w_in,
                                 c_in, sample_size, num_strata=num_strata,
                                 allocation=allocation, backend=backend,
                                 budget=budget, hist_bins=hist_bins)
        return outs

    return step


def _plan_root_step(plan, num_strata: int, allocation: str,
                    backend: str, lvl: int, budget: int):
    """Per-tree jitted root step for the ``level``/``loop`` engines when a
    query plan is registered: the host threads the sketch state through
    (donated — same shapes in and out, so XLA updates it in place)."""

    @functools.partial(jax.jit, donate_argnums=(7,))
    def step(key, t, values, strata, valid, w_in, c_in, qstate, sample_size):
        return _whs_root_core(key, t, lvl, values, strata, valid, w_in, c_in,
                              sample_size, num_strata=num_strata,
                              allocation=allocation, backend=backend,
                              budget=budget, plan=plan, qstate=qstate)

    return step


# --- SRS baseline (§IV-B): coin-flip keep at every node, HT estimate at root.
@functools.lru_cache(maxsize=None)
def _srs_node_step(capacity: int, num_strata: int, out_capacity: int, lvl: int):
    from repro.core import srs

    out_cap = min(out_capacity, capacity)

    @functools.partial(jax.jit, donate_argnums=(6, 7))
    def step(key, t, ix, values, strata, valid, w_in, c_in, p_keep):
        k = _node_key(key, t, lvl, ix)
        batch = IntervalBatch(values, strata, valid, StratumMeta(w_in, c_in))
        selected = srs.srs_select(k, batch, p_keep)
        # compact without weight bookkeeping (SRS carries no metadata)
        v_c, s_c, n_sel = whs.pack_rows(values[None, :], strata[None, :],
                                        selected[None, :], out_cap)
        slot_valid = jnp.arange(out_cap) < jnp.minimum(n_sel[0], out_cap)
        return v_c[0], s_c[0], slot_valid, w_in, c_in, n_sel[0]

    return step


@functools.lru_cache(maxsize=None)
def _srs_root_step(capacity: int, num_strata: int, lvl: int,
                   hist_bins: int = 64):
    @jax.jit
    def step(key, t, values, strata, valid, w_in, c_in, p_keep, f_total):
        return _srs_root_core(key, t, lvl, values, strata, valid, w_in, c_in,
                              p_keep, f_total, num_strata=num_strata,
                              hist_bins=hist_bins)

    return step


# --------------------------------------------------------------------------
# Jitted level steps (level-vectorized engine): one dispatch per level.
# Sticky W/C sets donated (argnums 5/6) — same shapes as the outgoing meta.
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _whs_level_step(n_nodes: int, capacity: int, num_strata: int,
                    out_capacity: int, n_parents: int, allocation: str,
                    backend: str, lvl: int):
    child_of = _child_routing(n_nodes, n_parents)

    @functools.partial(jax.jit, donate_argnums=(5, 6))
    def step(key, t, values, strata, valid, w_in, c_in, sample_size):
        return _whs_level_core(key, t, lvl, values, strata, valid, w_in, c_in,
                               sample_size, num_strata=num_strata,
                               out_capacity=out_capacity, child_of=child_of,
                               allocation=allocation, backend=backend)

    return step


@functools.lru_cache(maxsize=None)
def _srs_level_step(n_nodes: int, capacity: int, num_strata: int,
                    out_capacity: int, n_parents: int, lvl: int):
    child_of = _child_routing(n_nodes, n_parents)

    @functools.partial(jax.jit, donate_argnums=(5, 6))
    def step(key, t, values, strata, valid, w_in, c_in, p_keep):
        return _srs_level_core(key, t, lvl, values, strata, valid, w_in, c_in,
                               p_keep, num_strata=num_strata,
                               out_capacity=out_capacity, child_of=child_of)

    return step


# --------------------------------------------------------------------------
# Scan engine: the whole tree fused into one tree-step, T ticks per dispatch.
# --------------------------------------------------------------------------
def _append_rows(values, strata, fill, dropped, add_v, add_s, add_n,
                 empty: bool = False):
    """In-graph ``Window.deliver`` / ``LevelState.deliver_packed``: append
    each row's first ``add_n[r]`` incoming items at the row's fill offset,
    truncating at capacity (prefix rule — identical to the host buffers'
    backpressure behavior).

    ``empty=True`` is the static all-1-interval fast path: the receiving
    buffer is provably empty (it flushed last tick and receives exactly
    one message per tick), so the append is a plain prefix overwrite — no
    scatter. The message already arrives front-packed, so the buffer *is*
    the (zero-padded) message; slots past ``take`` are masked by ``fill``
    downstream either way."""
    n, cap = values.shape
    k = add_v.shape[1]
    add_n = add_n.astype(jnp.int32)
    if empty:
        take = jnp.minimum(add_n, cap)
        if k < cap:
            padv = jnp.zeros((n, cap - k), add_v.dtype)
            pads = jnp.zeros((n, cap - k), add_s.dtype)
            add_v = jnp.concatenate([add_v, padv], axis=1)
            add_s = jnp.concatenate([add_s, pads], axis=1)
        elif k > cap:
            add_v, add_s = add_v[:, :cap], add_s[:, :cap]
        return add_v, add_s, take, dropped + (add_n - take)
    take = jnp.minimum(add_n, cap - fill)
    j = jnp.arange(k, dtype=jnp.int32)[None, :]
    ok = j < take[:, None]
    pos = fill[:, None] + j
    row = jnp.arange(n, dtype=jnp.int32)[:, None]
    idx = jnp.where(ok, row * cap + pos, n * cap).reshape(-1)
    values = values.reshape(-1).at[idx].set(
        add_v.reshape(-1), mode="drop").reshape(n, cap)
    strata = strata.reshape(-1).at[idx].set(
        add_s.reshape(-1), mode="drop").reshape(n, cap)
    return values, strata, fill + take, dropped + (add_n - take)


def _fold_meta_graph(wc_acc, c_acc, seen, child_of: np.ndarray,
                     present, w_out, c_out):
    """In-graph ``LevelState.fold_meta``: fold each child's (W^out, C^out)
    message into its parent's interval accumulators, child slots in
    ascending order (a static unroll over the children-per-parent axis,
    so the f32 accumulation order bit-matches the host's sequential
    ``np.add.at``)."""
    n, x = w_out.shape
    pad = lambda a, dt: jnp.concatenate([a, jnp.zeros((1, x), dt)])
    wp = pad(w_out, w_out.dtype)
    cp = pad(c_out, c_out.dtype)
    prp = jnp.concatenate([present, jnp.zeros((1, x), bool)])
    gather = jnp.asarray(child_of)          # [P, cpp], sentinel row = n
    for k in range(child_of.shape[1]):
        ch = gather[:, k]
        pr = prp[ch]
        wc_acc = wc_acc + jnp.where(pr, wp[ch] * cp[ch], 0.0)
        c_acc = c_acc + jnp.where(pr, cp[ch], 0.0)
        seen = seen | pr
    return wc_acc, c_acc, seen


def _flush_meta(wc_acc, c_acc, seen, w_in, c_in):
    """In-graph ``flush`` metadata merge: fresh count-weighted-mean sets
    where metadata arrived this interval, sticky values elsewhere."""
    w_merged = wc_acc / jnp.maximum(c_acc, 1.0)
    w_eff = jnp.where(seen, w_merged, w_in)
    c_eff = jnp.where(seen, c_acc, c_in)
    return w_eff, c_eff


def _build_scan_tick(fanin, capacities, sample_sizes, interval_ticks,
                     num_strata, allocation, backend, mode, p_level,
                     fraction, trace_counter=None, plan=None,
                     telemetry=False):
    """Build the fused whole-tree tick: ``(state, key, t, budgets, ingest)
    → (state', per-tick outputs)``.

    Levels are chained in-graph exactly like ``_tick_level`` chains them on
    the host: level ``l`` flushes, samples, and its packed forwards are
    appended to level ``l+1``'s buffers *before* level ``l+1`` flushes, so
    one tick pushes data through the whole hierarchy. Levels whose interval
    has not elapsed are gated with ``where`` (their buffers keep
    accumulating); with all-1 intervals (the paper topology) the gates are
    static and the graph is branch-free.

    ``sample_sizes`` here are the *static maximum* per-level budgets —
    they size the forwarding buffers and partial selections. The budgets
    actually applied each tick arrive as the traced ``budgets`` f32
    [n_levels] argument, so the closed-loop ``BudgetController`` can move
    per-level sample sizes between epochs without a single retrace.

    ``plan`` is the compiled continuous-query plan (or ``None``): the
    root's standing queries evaluate inside this same traced tick, with
    their sketch state carried in ``state.qstate`` (donated with the
    rest of ``TreeState``).

    ``telemetry`` statically compiles the ``EpochTelemetry`` counter
    update in (or out). Every counter derives from quantities the tick
    already computes — flush occupancy, forwarded counts, the root
    sampler's per-stratum ``c``/``y`` — and telemetry consumes no PRNG,
    so sample state and window answers are bit-identical either way.
    """
    from repro.core.window import TreeState

    n_levels = len(fanin)
    child_tables = [_child_routing(fanin[l], fanin[l + 1])
                    for l in range(n_levels - 1)]

    def tick(state: "TreeState", key, t, budgets, ing_v, ing_s, ing_n):
        if trace_counter is not None:
            trace_counter["traces"] += 1
        lv = {f: list(getattr(state, f)) for f in TreeState.LEVEL_FIELDS}

        # Adaptive stratification: when a routing table rides in the state,
        # ingest stratum ids are *keys* gathered through the (traced,
        # host-editable) key→stratum table. The identity table is a
        # bitwise no-op; a split/merge between epochs is a same-shape edit
        # of the leaf (``repro.strata.StratumManager``) — zero retraces.
        if not isinstance(state.route, tuple):
            num_keys = state.route.shape[0]
            ing_s = state.route[jnp.clip(ing_s, 0, num_keys - 1)]

        # Source → level-0 delivery (one slice of the epoch's ingest batch).
        # With a 1-tick level-0 interval the buffer is empty here (it
        # flushed last tick), so the append is a scatter-free overwrite.
        (lv["values"][0], lv["strata"][0], lv["fill"][0],
         lv["dropped"][0]) = _append_rows(
            lv["values"][0], lv["strata"][0], lv["fill"][0],
            lv["dropped"][0], ing_v, ing_s, ing_n,
            empty=int(interval_ticks[0]) == 1)

        n_fwd_levels = []
        root_out = None
        tel_in, tel_kept = [], []
        root_strat = None
        for l in range(n_levels):
            iv = int(interval_ticks[l])
            is_root = l == n_levels - 1
            cap = capacities[l]
            fill = lv["fill"][l]
            if telemetry:
                # Items offered at this level's flush: the pre-flush
                # occupancy, zero on not-due ticks. Computed OUTSIDE the
                # cond from state the tick already holds.
                offered = jnp.sum(fill).astype(jnp.float32)
                tel_in.append(offered if iv == 1 else
                              jnp.where(t % iv == 0, offered, 0.0))

            def run_level(l=l, iv=iv, is_root=is_root, cap=cap, fill=fill):
                """Flush + sample + route + reset for a due level. Returns
                every state leaf the level touches plus its outputs, so a
                not-due tick can ``cond`` the whole body away."""
                valid = (jnp.arange(cap, dtype=jnp.int32)[None, :]
                         < fill[:, None])
                w_eff, c_eff = _flush_meta(lv["wc_acc"][l], lv["c_acc"][l],
                                           lv["seen"][l], lv["w_in"][l],
                                           lv["c_in"][l])
                values, strata = lv["values"][l], lv["strata"][l]
                # Interval reset (``flush``): clear occupancy +
                # accumulators, refresh stickies. Buffer contents are left
                # stale — every consumer masks by the valid range, exactly
                # as with zeroing.
                reset = (jnp.zeros_like(fill), jnp.zeros_like(lv["wc_acc"][l]),
                         jnp.zeros_like(lv["c_acc"][l]),
                         jnp.zeros_like(lv["seen"][l]), w_eff, c_eff)
                if is_root:
                    # Root: single node — squeeze node axis, run the query.
                    if mode == "srs":
                        outs = _srs_root_core(
                            key, t, l, values[0], strata[0], valid[0],
                            w_eff[0], c_eff[0], jnp.float32(p_level),
                            jnp.float32(fraction), num_strata=num_strata)
                        q_new = state.qstate
                    else:
                        outs, q_new = _whs_root_core(
                            key, t, l, values[0], strata[0], valid[0],
                            w_eff[0], c_eff[0], budgets[l],
                            num_strata=num_strata, allocation=allocation,
                            backend=backend, budget=int(sample_sizes[l]),
                            plan=plan, qstate=state.qstate,
                            telemetry=telemetry)
                    root_ok = jnp.sum(fill) > 0
                    return ((root_ok,) + outs, reset, q_new)
                if mode == "srs":
                    (packed_v, packed_s, n_deliv, w_out, c_out, present,
                     n_fwd) = _srs_level_core(
                        key, t, l, values, strata, valid, w_eff, c_eff,
                        jnp.float32(p_level), num_strata=num_strata,
                        out_capacity=int(sample_sizes[l]),
                        child_of=child_tables[l])
                else:
                    (packed_v, packed_s, n_deliv, w_out, c_out, present,
                     n_fwd) = _whs_level_core(
                        key, t, l, values, strata, valid, w_eff, c_eff,
                        budgets[l], num_strata=num_strata,
                        out_capacity=int(sample_sizes[l]),
                        child_of=child_tables[l],
                        allocation=allocation, backend=backend)
                # 1-tick intervals on both ends ⇒ exactly one message into
                # an empty parent buffer per tick ⇒ scatter-free overwrite.
                parent = _append_rows(
                    lv["values"][l + 1], lv["strata"][l + 1],
                    lv["fill"][l + 1], lv["dropped"][l + 1],
                    packed_v, packed_s, n_deliv,
                    empty=(iv == 1 and int(interval_ticks[l + 1]) == 1))
                parent_meta = _fold_meta_graph(
                    lv["wc_acc"][l + 1], lv["c_acc"][l + 1],
                    lv["seen"][l + 1], child_tables[l], present,
                    w_out, c_out)
                return (parent + parent_meta + (jnp.sum(n_fwd),)) + reset

            def skip_level(l=l, is_root=is_root, fill=fill):
                """Not-due tick: every touched leaf unchanged, null output."""
                keep = (fill, lv["wc_acc"][l], lv["c_acc"][l], lv["seen"][l],
                        lv["w_in"][l], lv["c_in"][l])
                if is_root:
                    f32 = lambda: jnp.zeros((), jnp.float32)
                    nul = (jnp.zeros((), bool), f32(), f32(), f32(), f32(),
                           jnp.zeros((), jnp.int32),
                           jnp.zeros((64,), jnp.float32))
                    if plan is not None:
                        nul = nul + (jnp.zeros((plan.n_out,), jnp.float32),
                                     jnp.zeros((plan.n_out,), jnp.float32))
                    if telemetry and mode != "srs":
                        nul = nul + (jnp.zeros((num_strata,), jnp.float32),
                                     jnp.zeros((num_strata,), jnp.float32))
                    return (nul, keep, state.qstate)
                nul = (lv["values"][l + 1], lv["strata"][l + 1],
                       lv["fill"][l + 1], lv["dropped"][l + 1],
                       lv["wc_acc"][l + 1], lv["c_acc"][l + 1],
                       lv["seen"][l + 1], jnp.zeros((), jnp.int32))
                return nul + keep

            if iv == 1:
                out = run_level()
            else:
                # cond executes ONE branch at runtime: a level whose
                # interval has not elapsed costs nothing — its buffers
                # keep accumulating untouched.
                out = jax.lax.cond(t % iv == 0, run_level, skip_level)

            if is_root:
                root_out, tail, q_out = out
                if telemetry and mode != "srs":
                    root_strat = root_out[-2:]
                    root_out = root_out[:-2]
                if telemetry:
                    tel_kept.append(root_out[5].astype(jnp.float32))
            else:
                (lv["values"][l + 1], lv["strata"][l + 1], lv["fill"][l + 1],
                 lv["dropped"][l + 1], lv["wc_acc"][l + 1],
                 lv["c_acc"][l + 1], lv["seen"][l + 1]) = out[:7]
                n_fwd_levels.append(out[7])
                if telemetry:
                    tel_kept.append(out[7].astype(jnp.float32))
                tail = out[8:]
            (lv["fill"][l], lv["wc_acc"][l], lv["c_acc"][l], lv["seen"][l],
             lv["w_in"][l], lv["c_in"][l]) = tail

        if telemetry:
            tel = state.telemetry
            d_in = jnp.stack(tel_in)
            d_kept = jnp.stack(tel_kept)
            flushed = d_in > 0
            root_ok = root_out[0]
            se, sv = root_out[1], root_out[2]
            new_tel = tel._replace(
                items_in=tel.items_in + d_in,
                items_kept=tel.items_kept + d_kept,
                flushes=tel.flushes + flushed.astype(jnp.int32),
                saturation_hits=tel.saturation_hits + (
                    flushed & (d_kept >= d_in)).astype(jnp.int32),
                windows=tel.windows + root_ok.astype(jnp.int32),
                root_sum=tel.root_sum + jnp.where(root_ok, se, 0.0),
                root_sum_var=tel.root_sum_var + jnp.where(root_ok, sv, 0.0),
            )
            if root_strat is not None:
                new_tel = new_tel._replace(
                    stratum_in=new_tel.stratum_in + root_strat[0],
                    stratum_kept=new_tel.stratum_kept + root_strat[1])
            if plan is not None:
                ans, bnd = root_out[7], root_out[8]
                rel = bnd / jnp.maximum(jnp.abs(ans), 1e-9)
                new_tel = new_tel._replace(
                    slot_rel_bound_sum=new_tel.slot_rel_bound_sum
                    + jnp.where(root_ok, rel, 0.0))
        else:
            new_tel = state.telemetry

        new_state = TreeState(
            **{f: tuple(lv[f]) for f in TreeState.LEVEL_FIELDS},
            qstate=q_out, telemetry=new_tel, route=state.route)
        out = root_out + (jnp.stack(n_fwd_levels),)
        return new_state, out

    return tick


def _build_epoch_fn(tick_fn, epoch_ticks: int):
    """One jitted dispatch per ``epoch_ticks``-tick epoch: ``lax.scan``
    over the fused tree-step, every ``TreeState`` buffer donated so the
    reservoir/window state is updated in place on device."""

    def epoch(state, key, t0, budgets, ing_v, ing_s, ing_n):
        ts = t0 + jnp.arange(epoch_ticks, dtype=jnp.int32)

        def body(st, xs):
            t, v, s, n = xs
            return tick_fn(st, key, t, budgets, v, s, n)

        return jax.lax.scan(body, state, (ts, ing_v, ing_s, ing_n))

    return jax.jit(epoch, donate_argnums=(0,))


def accumulate_epoch_accounting(tree, wall: float, counts, offered,
                                n_fwd) -> None:
    """Per-epoch accounting shared by ``HostTree.run_epoch`` and the
    compiled-pipeline driver (``launch.analytics._CompiledDriver``) —
    one implementation so the engines are compared under identical
    bookkeeping. A fused epoch cannot observe per-level time inside its
    single dispatch, so wall-time is attributed to levels proportionally
    to their buffer slots (``n_nodes × capacity`` — a static model of
    where the work is); ``offered`` is the pre-truncation ingest count
    (defaults to ``counts``); ``n_fwd`` is the stacked per-(tick, level)
    forwarded-item count."""
    import numpy as np

    tree.dispatch_count += 1
    slots = [n * c for n, c in zip(tree.fanin, tree.capacities)]
    total = float(sum(slots))
    for lvl, s in enumerate(slots):
        tree.level_time_s[lvl] += wall * s / total
    tree.items_ingested += int(
        np.asarray(counts if offered is None else offered).sum())
    for lvl in range(len(tree.fanin) - 1):
        tree.items_forwarded[lvl] += int(n_fwd[:, lvl].sum())


class HostTree:
    """Emulated edge topology (default geometry = the paper's testbed:
    8 sources → 4 edge nodes → 2 edge nodes → 1 root).

    ``mode="whs"`` runs the paper's weighted hierarchical sampler;
    ``mode="srs"`` runs the §IV-B coin-flip baseline (per-level keep
    probability ``p_level`` so the end-to-end fraction matches WHS's).

    ``engine`` selects the execution strategy (see module docstring):
    ``"level"`` issues one jitted dispatch per level per tick,
    ``"loop"`` one per node per tick, ``"scan"`` one per **epoch** of
    ``T`` ticks (drive it with ``run_epoch`` instead of
    ``ingest``/``tick``). ``dispatch_count`` tracks jitted step
    invocations so tests/benchmarks can verify the dispatch model.
    ``sampler_backend`` is threaded through to every WHSamp call.

    Donation caveat: the ``level``/``loop`` engines donate the sticky
    W/C metadata buffers into their steps, and the ``scan`` engine
    donates the *entire* ``TreeState``; callers must not hold references
    to state arrays across a tick/epoch (the tree itself never does —
    host flushes hand fresh copies to the steps).

    Per-level processing wall-time is accumulated in ``level_time_s``
    (drives the Fig. 9/10 latency model). The scan engine cannot observe
    per-level time inside its fused dispatch, so it attributes each
    epoch's device wall-time to levels proportionally to their buffer
    slots (``n_nodes × capacity``) — a static model of where the work
    is."""

    def __init__(
        self,
        fanin: list[int],                 # nodes per level, root last, e.g. [4, 2, 1]
        num_strata: int,
        capacity: int,
        sample_sizes: list[int],          # per level: interval budget
        interval_ticks: list[int] | None = None,
        allocation: str = "fair",
        seed: int = 0,
        mode: str = "whs",                # whs | srs
        fraction: float | None = None,    # srs: end-to-end sampling fraction
        engine: str = "level",            # level | loop
        # topk is bit-identical to the argsort reference (see core.sampling)
        # and ~1.7x faster on CPU — the tree defaults to it; the library
        # functions keep the argsort reference as their default.
        sampler_backend: str = "topk",
        # Continuous query plane: a QueryRegistry (or compiled plan) of
        # standing queries answered at the root every window, inside the
        # same dispatch(es). whs mode only (the plan needs WHS metadata).
        queries=None,
        # Static per-level budget ceilings for the closed-loop controller:
        # buffers/partial selections are provisioned for these, while
        # ``set_sample_sizes`` moves the applied budgets anywhere below
        # them between ticks/epochs with zero retraces. Defaults to
        # ``sample_sizes`` (fixed-budget operation).
        max_sample_sizes: list[int] | None = None,
        # Adaptive stratification (scan engine only): number of ingest
        # stratum *keys*. When set, ingest strata are routed through a
        # key→stratum table seeded to identity; ``set_route`` installs a
        # split/merge remap between epochs at zero retraces.
        route_keys: int | None = None,
    ):
        from repro.core.window import LevelState, TreeState, Window

        assert fanin[-1] == 1, "last level must be the single root"
        assert mode in ("whs", "srs")
        assert engine in ("level", "loop", "scan")
        assert route_keys is None or engine == "scan", \
            "adaptive stratum routing needs the scan engine"
        self.fanin = fanin
        self.num_strata = num_strata
        self.allocation = allocation
        self.sample_sizes = list(sample_sizes)
        self.max_sample_sizes = list(max_sample_sizes or sample_sizes)
        assert all(m >= s for m, s in zip(self.max_sample_sizes,
                                          self.sample_sizes)), \
            "max_sample_sizes must dominate the initial sample_sizes"
        self.mode = mode
        self.engine = engine
        self.sampler_backend = sampler_backend
        self.fraction = fraction
        if queries is not None and not hasattr(queries, "evaluate"):
            # Raw QueryRegistry: build the same slotted single-tenant
            # plan the API front door compiles, so legacy-constructed
            # trees stay bitwise interchangeable with spec-built ones
            # (same padded traced program, same compacted public rows).
            from repro.query.compiler import build_slotted_plan

            queries = build_slotted_plan((("default", queries.specs),),
                                         num_strata)
        self.plan = queries
        # Traced programs close over the name-free core when the plan is
        # slotted (tenant routing is host-side only).
        self._traced_plan = getattr(queries, "core", queries)
        assert self.plan is None or mode == "whs", \
            "the query plane needs WHS stratum metadata (mode='whs')"
        # SRS keeps items with the same probability at every level so the
        # compounded keep-rate equals the end-to-end ``fraction``.
        self.p_level = (float(fraction) ** (1.0 / len(fanin))
                        if fraction is not None else 1.0)
        interval_ticks = interval_ticks or [1] * len(fanin)
        # Exact arrival-bound buffer provisioning (see derive_capacities:
        # with globally-ticked intervals the bound is tight, so upper-level
        # buffers — and their sort/top-k passes — carry no 2x slack).
        self.capacities = derive_capacities(fanin, capacity,
                                            self.max_sample_sizes,
                                            interval_ticks)
        if engine == "loop":
            self.levels = [
                [Window(self.capacities[lvl], num_strata, interval_ticks[lvl])
                 for _ in range(n_nodes)]
                for lvl, n_nodes in enumerate(fanin)
            ]
        elif engine == "level":
            self.levels = [
                LevelState(n_nodes, self.capacities[lvl], num_strata,
                           interval_ticks[lvl])
                for lvl, n_nodes in enumerate(fanin)
            ]
        else:  # scan: whole-tree on-device state, one dispatch per epoch
            self.levels = None
            self._state = TreeState.create(
                fanin, self.capacities, num_strata,
                qstate=self.plan.init_state() if self.plan is not None
                else (),
                route=(jnp.arange(int(route_keys), dtype=jnp.int32)
                       if route_keys else ()))
            self._trace_counter = {"traces": 0}
            self._tick_fn = _build_scan_tick(
                fanin, self.capacities, self.max_sample_sizes, interval_ticks,
                num_strata, allocation, sampler_backend, mode, self.p_level,
                fraction, trace_counter=self._trace_counter,
                plan=self._traced_plan)
            self._epoch_fns: dict[int, object] = {}
        if engine != "scan" and self.plan is not None:
            # level/loop engines: host-threaded sketch state + a dedicated
            # root step closing over the plan.
            self._qstate = self.plan.init_state()
            self._plan_step = _plan_root_step(
                self.plan, num_strata, allocation, sampler_backend,
                len(fanin) - 1, int(self.max_sample_sizes[-1]))
        self._key = jax.random.PRNGKey(seed)
        self.items_forwarded = [0] * len(fanin)   # bandwidth accounting (Fig. 8)
        self.items_ingested = 0
        self.level_time_s = [0.0] * len(fanin)    # processing time (Fig. 9/10)
        self.dispatch_count = 0                   # jitted step invocations
        self.results: list[dict] = []

    @classmethod
    def from_spec(cls, spec, engine: str = "level") -> "HostTree":
        """Back-compat shim: build a ``HostTree`` from a declarative
        ``repro.api.PipelineSpec`` — the one front door. New code should
        use ``repro.api.compile(spec)`` (pure ``init``/``run_epoch``,
        explicit donated state); this constructor exists so the per-tick
        ``level``/``loop`` engines and legacy drivers consume the same
        job description. Resolution (sample sizes, ceilings, intervals,
        query plan) is shared with the API compiler, so the two paths
        are bit-identical."""
        from repro.api.spec import resolve

        r = resolve(spec)
        return cls(
            fanin=list(spec.topology.fanin),
            num_strata=spec.topology.num_strata,
            capacity=spec.topology.capacity,
            sample_sizes=list(r.sample_sizes),
            interval_ticks=list(r.interval_ticks),
            allocation=spec.sampler.allocation,
            seed=spec.seed,
            mode=spec.sampler.mode,
            fraction=spec.sampler.fraction,
            engine=engine,
            sampler_backend=spec.sampler.backend,
            queries=r.plan,
            max_sample_sizes=list(r.max_sample_sizes),
            route_keys=(spec.strata.num_keys or None)
            if engine == "scan" else None,
        )

    def ingest(self, node: int, values: np.ndarray, strata: np.ndarray) -> None:
        """Source → level-0 node delivery."""
        if self.engine == "scan":
            raise RuntimeError("engine='scan' ingests per epoch: use "
                               "run_epoch(t0, values, strata, counts)")
        self.items_ingested += len(values)
        if self.engine == "loop":
            self.levels[0][node].deliver(values, strata)
        else:
            self.levels[0].deliver(node, values, strata)

    def tick(self, t: int) -> None:
        """Advance one global tick: flush every due window, push upstream."""
        if self.engine == "scan":
            raise RuntimeError("engine='scan' advances per epoch: use "
                               "run_epoch(t0, values, strata, counts)")
        if self.engine == "loop":
            self._tick_loop(t)
        else:
            self._tick_level(t)

    # ------------------------------------------------------------- scan --
    def run_epoch(self, t0: int, values: np.ndarray, strata: np.ndarray,
                  counts: np.ndarray,
                  offered: np.ndarray | None = None) -> None:
        """Advance ``T`` ticks (``t0 .. t0+T-1``) in ONE jitted dispatch.

        ``values``/``strata`` are ``[T, fanin[0], width]`` tick-major
        padded ingest (see ``data.stream.batch_ingest``), ``counts`` the
        per-(tick, node) item counts. ``offered`` is the pre-truncation
        count for ``items_ingested`` accounting, so bandwidth fractions
        match the per-tick engines when a (tick, node) overflows the
        ingest width (defaults to ``counts``). The whole epoch's ingest
        moves host→device in one transfer; the tree state stays on
        device (donated) and only the stacked per-tick root results come
        back.
        """
        import time as _time

        assert self.engine == "scan", "run_epoch requires engine='scan'"
        epoch_ticks, n0, _ = values.shape
        assert n0 == self.fanin[0], "ingest rows must match level-0 nodes"
        fn = self._epoch_fns.get(epoch_ticks)
        if fn is None:
            fn = self._epoch_fns[epoch_ticks] = _build_epoch_fn(
                self._tick_fn, epoch_ticks)
        budgets = jnp.asarray([float(s) for s in self.sample_sizes],
                              jnp.float32)
        t_start = _time.perf_counter()
        self._state, outs = fn(
            self._state, self._key, jnp.int32(t0), budgets,
            jnp.asarray(values, jnp.float32), jnp.asarray(strata, jnp.int32),
            jnp.asarray(counts, jnp.int32))
        if self.plan is not None:
            (root_ok, se, sv, me, mv, nsel, hist, ans, bnd, n_fwd) = (
                np.asarray(o) for o in outs)      # one device→host sync
            if hasattr(self.plan, "compact"):
                ans, bnd = self.plan.compact(ans), self.plan.compact(bnd)
        else:
            (root_ok, se, sv, me, mv, nsel, hist, n_fwd) = (
                np.asarray(o) for o in outs)
            ans = bnd = None
        wall = _time.perf_counter() - t_start
        accumulate_epoch_accounting(self, wall, counts, offered, n_fwd)
        for i in range(epoch_ticks):
            if root_ok[i]:
                row = dict(
                    tick=t0 + i, sum=float(se[i]), sum_var=float(sv[i]),
                    mean=float(me[i]), mean_var=float(mv[i]),
                    n_sampled=int(nsel[i]), histogram=hist[i],
                )
                if ans is not None:
                    row["answers"], row["bounds"] = ans[i], bnd[i]
                self.results.append(row)

    def reset_query_state(self) -> None:
        """Reset the standing queries' sketch state to empty (drivers call
        this after warmup so continuous answers cover only measured
        ticks; windowed CLT answers are stateless and unaffected)."""
        if self.plan is None:
            return
        if self.engine == "scan":
            self._state = self._state._replace(qstate=self.plan.init_state())
        else:
            self._qstate = self.plan.init_state()

    def set_route(self, route) -> None:
        """Install a new key→stratum routing table (adaptive
        stratification). A same-shape leaf edit on the donated state —
        the next epoch runs the remapped strata with zero retraces."""
        assert self.engine == "scan", "routing lives in the scan state"
        assert not isinstance(self._state.route, tuple), \
            "tree was built without route_keys"
        r = jnp.asarray(route, jnp.int32)
        assert r.shape == self._state.route.shape, "route shape is static"
        self._state = self._state._replace(route=r)

    def set_sample_sizes(self, sizes) -> None:
        """Move the applied per-level sample budgets (closed-loop knob).

        Budgets are traced values in every engine, so this never
        recompiles; they are clamped to the provisioned
        ``max_sample_sizes`` ceilings (buffers upstream were sized for
        those — exceeding them would truncate forwards)."""
        assert len(sizes) == len(self.fanin)
        self.sample_sizes = [
            min(max(float(s), 1.0), float(m))
            for s, m in zip(sizes, self.max_sample_sizes)
        ]

    def _root_result(self, t: int, outs) -> dict:
        """Host-side result row from a root step's outputs (plan-aware)."""
        se, sv, me, mv, nsel, hist = outs[:6]
        row = dict(tick=t, sum=float(se), sum_var=float(sv),
                   mean=float(me), mean_var=float(mv), n_sampled=int(nsel),
                   histogram=np.asarray(hist))
        if len(outs) > 6:
            ans, bnd = np.asarray(outs[6]), np.asarray(outs[7])
            if self.plan is not None and hasattr(self.plan, "compact"):
                ans, bnd = self.plan.compact(ans), self.plan.compact(bnd)
            row["answers"], row["bounds"] = ans, bnd
        return row

    # ------------------------------------------------------------- loop --
    def _tick_loop(self, t: int) -> None:
        import time as _time

        for lvl, nodes in enumerate(self.levels):
            is_root = lvl == len(self.levels) - 1
            n_parents = self.fanin[lvl + 1] if not is_root else 1
            for ix, win in enumerate(nodes):
                if not win.due(t) or win.fill == 0:
                    continue
                values, strata, valid, w_in, c_in = win.flush()
                t0 = _time.perf_counter()
                if is_root:
                    if self.mode == "srs":
                        step = _srs_root_step(win.capacity, self.num_strata, lvl)
                        outs = step(
                            self._key, t, values, strata, valid, w_in, c_in,
                            jnp.float32(self.p_level), jnp.float32(self.fraction))
                    elif self.plan is not None:
                        outs, self._qstate = self._plan_step(
                            self._key, t, values, strata, valid, w_in, c_in,
                            self._qstate, jnp.float32(self.sample_sizes[lvl]))
                    else:
                        step = _root_step(win.capacity, self.num_strata,
                                          self.allocation, self.sampler_backend,
                                          lvl, int(self.max_sample_sizes[lvl]))
                        outs = step(
                            self._key, t, values, strata, valid, w_in, c_in,
                            jnp.float32(self.sample_sizes[lvl]))
                    self.dispatch_count += 1
                    row = self._root_result(t, outs)  # np.asarray syncs
                    self.level_time_s[lvl] += _time.perf_counter() - t0
                    self.results.append(row)
                else:
                    out_cap = self.max_sample_sizes[lvl]
                    if self.mode == "srs":
                        step = _srs_node_step(win.capacity, self.num_strata,
                                              out_cap, lvl)
                        ov, os_, oval, w_out, c_out, _ = step(
                            self._key, t, ix, values, strata, valid, w_in, c_in,
                            jnp.float32(self.p_level))
                    else:
                        step = _node_step(win.capacity, self.num_strata, out_cap,
                                          self.allocation, self.sampler_backend,
                                          lvl)
                        ov, os_, oval, w_out, c_out, _ = step(
                            self._key, t, ix, values, strata, valid, w_in, c_in,
                            jnp.float32(self.sample_sizes[lvl]))
                    self.dispatch_count += 1
                    ov, os_, oval = np.asarray(ov), np.asarray(os_), np.asarray(oval)
                    self.level_time_s[lvl] += _time.perf_counter() - t0
                    n = int(oval.sum())
                    self.items_forwarded[lvl] += n
                    parent = self.levels[lvl + 1][ix % n_parents]
                    parent.deliver(ov[:n], os_[:n], np.asarray(w_out), np.asarray(c_out))

    # ------------------------------------------------------------ level --
    def _tick_level(self, t: int) -> None:
        import time as _time

        for lvl, state in enumerate(self.levels):
            is_root = lvl == len(self.levels) - 1
            if not state.due(t) or int(state.fill.sum()) == 0:
                continue
            values, strata, valid, w_in, c_in = state.flush_all()
            t0 = _time.perf_counter()
            if is_root:
                # The root is always a single node: squeeze the node axis and
                # run the (shared) scalar root step — still one dispatch.
                if self.mode == "srs":
                    step = _srs_root_step(state.capacity, self.num_strata, lvl)
                    outs = step(
                        self._key, t, values[0], strata[0], valid[0],
                        w_in[0], c_in[0],
                        jnp.float32(self.p_level), jnp.float32(self.fraction))
                elif self.plan is not None:
                    outs, self._qstate = self._plan_step(
                        self._key, t, values[0], strata[0], valid[0],
                        w_in[0], c_in[0], self._qstate,
                        jnp.float32(self.sample_sizes[lvl]))
                else:
                    step = _root_step(state.capacity, self.num_strata,
                                      self.allocation, self.sampler_backend,
                                      lvl, int(self.max_sample_sizes[lvl]))
                    outs = step(
                        self._key, t, values[0], strata[0], valid[0],
                        w_in[0], c_in[0],
                        jnp.float32(self.sample_sizes[lvl]))
                self.dispatch_count += 1
                row = self._root_result(t, outs)  # np.asarray syncs
                self.level_time_s[lvl] += _time.perf_counter() - t0
                self.results.append(row)
            else:
                n_parents = self.fanin[lvl + 1]
                out_cap = self.max_sample_sizes[lvl]
                if self.mode == "srs":
                    step = _srs_level_step(state.n_nodes, state.capacity,
                                           self.num_strata, out_cap,
                                           n_parents, lvl)
                    outs = step(self._key, t, values, strata, valid, w_in, c_in,
                                jnp.float32(self.p_level))
                else:
                    step = _whs_level_step(state.n_nodes, state.capacity,
                                           self.num_strata, out_cap, n_parents,
                                           self.allocation,
                                           self.sampler_backend, lvl)
                    outs = step(self._key, t, values, strata, valid, w_in, c_in,
                                jnp.float32(self.sample_sizes[lvl]))
                self.dispatch_count += 1
                (packed_v, packed_s, n_deliv,
                 w_out, c_out, present, n_fwd) = (np.asarray(o) for o in outs)
                self.level_time_s[lvl] += _time.perf_counter() - t0
                self.items_forwarded[lvl] += int(n_fwd.sum())
                parent = self.levels[lvl + 1]
                parent.deliver_packed(packed_v, packed_s, n_deliv)
                parent_ix = np.arange(state.n_nodes) % n_parents
                parent.fold_meta(parent_ix, present, w_out, c_out)


# --------------------------------------------------------------------------
# In-graph SPMD hierarchy (pod-scale data plane).
# --------------------------------------------------------------------------
def spmd_local_then_root(
    key: jax.Array,
    batch: IntervalBatch,
    *,
    axis_name: str,
    num_strata: int,
    local_budget: int,
    root_budget: int,
    allocation: str = "fair",
    sampler_backend: str = sampling.DEFAULT_BACKEND,
) -> tuple[QueryResult, QueryResult]:
    """Two-level hierarchical sampling across a mesh axis.

    Level 1 (edge): each device samples its local interval batch and
    compacts to ``local_budget`` slots. Level 2 (root): the compacted
    reservoirs — not the raw stream — are all-gathered and re-sampled,
    then SUM/MEAN + error bounds are computed. Returns (sum, mean).

    Call under ``shard_map`` with ``axis_name`` bound, e.g. the "data"
    axis; every device computes the root stage redundantly (no single
    point of failure, no coordination — §III-E). ``sampler_backend``
    selects the selection engine at both stages; with ``"pallas"`` the
    enclosing ``shard_map`` must pass ``check_rep=False`` (JAX has no
    replication rule for ``pallas_call``).
    """
    # Local stage: per-device key. Root stage: the SAME key on every device
    # so the redundantly-computed root result is bit-identical (replicated).
    k_local = jax.random.fold_in(key, jax.lax.axis_index(axis_name))
    k_root = jax.random.fold_in(key, 0x5F3759DF)
    res = whs.whsamp(k_local, batch, jnp.float32(local_budget), num_strata,
                     allocation=allocation, backend=sampler_backend,
                     max_reservoir=local_budget)
    compact = whs.compact_sample(batch, res, local_budget)

    g_val = jax.lax.all_gather(compact.value, axis_name, tiled=True)
    g_str = jax.lax.all_gather(compact.stratum, axis_name, tiled=True)
    g_vld = jax.lax.all_gather(compact.valid, axis_name, tiled=True)
    # Workers sample disjoint shards of each sub-stream (§III-E): the union
    # of their per-stratum reservoirs carries per-worker weights. Merging
    # parallel workers uses the count-weighted mean (the pool represents
    # Σ w_k·C_k originals over Σ C_k forwarded items) — see core/window.py
    # for why Eq. 5's max rule is path-only and biases parallel merges.
    g_c = jax.lax.psum(compact.meta.count, axis_name)
    g_w = (jax.lax.psum(compact.meta.weight * compact.meta.count, axis_name)
           / jnp.maximum(g_c, 1.0))
    # Strata empty across all workers: weight is irrelevant (no items) —
    # use 1 so the result stays replicated across the axis.
    g_w = jnp.where(g_c > 0.0, g_w, 1.0)

    root_batch = IntervalBatch(g_val, g_str, g_vld, StratumMeta(g_w, g_c))
    res_root = whs.whsamp(k_root, root_batch, jnp.float32(root_budget), num_strata,
                          allocation=allocation, backend=sampler_backend,
                          max_reservoir=root_budget)
    s = err.approx_sum(root_batch.value, root_batch.stratum, res_root.selected,
                       res_root.meta, num_strata)
    m = err.approx_mean(root_batch.value, root_batch.stratum, res_root.selected,
                        res_root.meta, num_strata)
    # The root stage is computed redundantly from all-gathered (identical)
    # data + an axis-invariant key, so results are replicated in value; a
    # scalar pmean re-types them as invariant for shard_map's vma check
    # (all_gather outputs stay `varying` under JAX's vma typing).
    rep = lambda t: jax.tree.map(lambda x: jax.lax.pmean(x, axis_name), t)
    return rep(s), rep(m)


def spmd_local_then_root_epoch(
    key: jax.Array,
    batches: IntervalBatch,
    *,
    axis_name: str,
    num_strata: int,
    local_budget: int,
    root_budget: int,
    allocation: str = "fair",
    sampler_backend: str = sampling.DEFAULT_BACKEND,
) -> tuple[QueryResult, QueryResult]:
    """Epoch-batched ``spmd_local_then_root``: ``T`` interval batches in
    one ``lax.scan``, one dispatch per epoch instead of one per interval.

    ``batches`` is an ``IntervalBatch`` whose array leaves carry a leading
    tick axis (``value[T, M]``, per-tick ``meta`` sets ``[T, X]``). Each
    tick ``i`` folds ``i`` into the epoch key, so results match ``T``
    separate calls with ``fold_in(key, i)`` keys bit-for-bit. Returns
    (sum, mean) ``QueryResult``s with ``[T]``-stacked leaves. Call under
    ``shard_map`` exactly like the per-interval function.
    """
    def body(i, batch):
        s, m = spmd_local_then_root(
            jax.random.fold_in(key, i), batch, axis_name=axis_name,
            num_strata=num_strata, local_budget=local_budget,
            root_budget=root_budget, allocation=allocation,
            sampler_backend=sampler_backend)
        return (s, m)

    t = batches.value.shape[0]
    _, outs = jax.lax.scan(
        lambda c, xs: (c, body(xs[0], xs[1])),
        0, (jnp.arange(t, dtype=jnp.int32), batches))
    return outs


def spmd_query_plane_tick(
    key: jax.Array,
    batch: IntervalBatch,
    qstate: tuple,
    plan,
    *,
    axis_name: str,
    budget: jnp.ndarray,
    max_budget: int,
    num_strata: int,
    allocation: str = "fair",
    sampler_backend: str = sampling.DEFAULT_BACKEND,
    hist_bins: int = 64,
):
    """One window of the distributed multi-tenant query plane (§III-E +
    the PR-3 query plane, merged by summaries).

    Every device WHS-samples its local shard of the window (``budget``
    is the TRACED applied sample budget, ``max_budget`` the static
    ceiling sizing the partial selections), then the window is answered
    from MERGED per-device summaries: the built-in workload (SUM/MEAN ±
    variance, sample count, histogram) merges via ``psum`` of per-shard
    moments, and the standing-query plan evaluates through
    ``CompiledQueryPlan.evaluate_spmd`` — local sketch updates,
    all-gathered O(sketch) summaries, one batched root evaluation per
    window. NO raw reservoir items cross the device boundary (contrast
    ``spmd_local_then_root``, which gathers the compacted reservoirs);
    cross-device traffic per window is the sketch buffers plus a
    handful of per-stratum scalars.

    ``key`` must be replicated across the axis. Returns
    ``(qstate', outs)`` with ``qstate'`` device-local and every leaf of
    ``outs`` replicated (re-typed axis-invariant via ``pmean``):
    ``(ok, sum, sum_var, mean, mean_var, n_sampled, histogram,
    answers, bounds)``.
    """
    k_local = jax.random.fold_in(key, jax.lax.axis_index(axis_name))
    res = whs.whsamp(k_local, batch, budget, num_strata,
                     allocation=allocation, backend=sampler_backend,
                     max_reservoir=max_budget)
    sel = res.selected
    psum = lambda v: jax.lax.psum(v, axis_name)

    y, s1, s2 = err.stratum_moments(batch.value, batch.stratum, sel,
                                    num_strata)
    s_loc = err.approx_sum_from_moments(y, s1, s2, res.meta)
    m_loc = err.approx_mean_from_moments(y, s1, s2, res.meta)
    # Mean merges share-weighted: each shard's mean estimates ITS
    # sub-population's mean, so the union mean re-weights by the shard's
    # estimated population Σ c_src (same rule as evaluate_spmd's "mean").
    total_local = jnp.sum(y * res.meta.weight)
    share = total_local / jnp.maximum(psum(total_local), 1.0)
    se, sv = psum(s_loc.estimate), psum(s_loc.variance)
    me = psum(m_loc.estimate * share)
    mv = psum(m_loc.variance * share * share)
    n_sel = psum(jnp.sum(sel.astype(jnp.int32)))
    ok = psum(jnp.sum(batch.valid.astype(jnp.int32))) > 0

    # Built-in histogram: replicated data-dependent edges (pmin/pmax of
    # the per-shard sampled range — two scalars), then a psum of the
    # per-bin HT estimates (linear queries merge exactly).
    from repro.core import queries

    lo = jax.lax.pmin(jnp.min(jnp.where(sel, batch.value, jnp.inf)),
                      axis_name)
    hi = jax.lax.pmax(jnp.max(jnp.where(sel, batch.value, -jnp.inf)),
                      axis_name)
    edges = jnp.linspace(lo, hi + 1e-6, hist_bins + 1)
    hist = psum(queries.weighted_histogram(batch, res, num_strata,
                                           edges).estimate)

    if plan is None:
        qstate2, tail = qstate, ()
    else:
        qstate2, answers, bounds = plan.evaluate_spmd(
            key, batch, res, qstate, axis_name)
        # psum/pmin outputs are already axis-invariant, but the sketch-
        # derived answer slots descend from all_gathers, which stay
        # vma-typed `varying`; one pmean over the (replicated-in-value)
        # answer vectors re-types them for the shard_map out check.
        # Exact for power-of-two meshes (N·x/N); the psum-merged slots
        # are untouched collectives-wise — the vectors are [n_out] f32.
        rep = lambda v: jax.lax.pmean(v, axis_name)
        tail = (rep(answers), rep(bounds))
    return qstate2, (ok, se, sv, me, mv, n_sel, hist) + tail


def spmd_query_plane_epoch(
    key: jax.Array,
    t0: jnp.ndarray,
    budget: jnp.ndarray,
    batches: IntervalBatch,
    qstate: tuple,
    plan,
    *,
    axis_name: str,
    max_budget: int,
    num_strata: int,
    allocation: str = "fair",
    sampler_backend: str = sampling.DEFAULT_BACKEND,
    hist_bins: int = 64,
):
    """Epoch-batched ``spmd_query_plane_tick``: ``T`` windows in one
    ``lax.scan`` with the sketch state as the carry — one dispatch per
    epoch, per-device sketch state never leaving the device (only its
    per-window summaries do). Window ``i`` folds the GLOBAL tick
    ``t0 + i`` into the epoch key, so multi-epoch runs resume
    bit-identically to one long epoch (asserted in
    ``tests/test_spmd_query_plane.py``). ``budget`` is the traced
    applied level-0 budget — the closed-loop controller moves it between
    epochs with zero retraces."""
    t = batches.value.shape[0]

    def body(carry, xs):
        i, batch = xs
        return spmd_query_plane_tick(
            jax.random.fold_in(key, i), batch, carry, plan,
            axis_name=axis_name, budget=budget, max_budget=max_budget,
            num_strata=num_strata, allocation=allocation,
            sampler_backend=sampler_backend, hist_bins=hist_bins)

    ts = t0 + jnp.arange(t, dtype=jnp.int32)
    qfinal, outs = jax.lax.scan(body, qstate, (ts, batches))
    return qfinal, outs


def spmd_srs_epoch(
    key: jax.Array,
    batches: IntervalBatch,
    *,
    axis_name: str,
    fraction: float,
):
    """§IV-B coin-flip baseline on the mesh: each device keeps its shard's
    items with probability ``fraction`` (one flat stage — the SPMD path
    has no intermediate hops to compound through) and the HT SUM / sample
    MEAN merge from ``psum``-ed sample moments — like the WHS query
    plane, no item ever crosses the device boundary. Returns
    (sum, mean) ``QueryResult``s with ``[T]``-stacked leaves, same
    contract as ``spmd_local_then_root_epoch``."""
    from repro.core import srs

    p = jnp.float32(fraction)

    def tick(i, batch):
        k_local = jax.random.fold_in(jax.random.fold_in(key, i),
                                     jax.lax.axis_index(axis_name))
        sel = srs.srs_select(k_local, batch, p)
        x = jnp.where(sel, batch.value, 0.0)
        psum = lambda v: jax.lax.psum(v, axis_name)
        n = psum(jnp.sum(sel.astype(jnp.float32)))
        g1 = psum(jnp.sum(x))
        g2 = psum(jnp.sum(x * x))
        s = QueryResult(estimate=g1 / p, variance=g2 * (1.0 - p) / (p * p))
        mean = g1 / jnp.maximum(n, 1.0)
        s_sq = jnp.maximum(g2 - n * mean * mean, 0.0) / jnp.maximum(n - 1.0,
                                                                    1.0)
        m = QueryResult(estimate=mean, variance=s_sq / jnp.maximum(n, 1.0))
        return s, m

    t = batches.value.shape[0]
    _, outs = jax.lax.scan(
        lambda c, xs: (c, tick(xs[0], xs[1])),
        0, (jnp.arange(t, dtype=jnp.int32), batches))
    return outs

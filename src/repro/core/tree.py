"""Hierarchy executors: the paper's logical tree, two ways.

``HostTree`` — a discrete-tick emulation of the edge topology (the Kafka
pipeline of §IV): per-node windows, asynchronous intervals, compacted
forwarding, query + error bounds at the root. Used by benchmarks/examples
to reproduce Figs. 6–12. Two execution engines share identical sampling
semantics (and identical randomness — per-node keys are derived by
folding (tick, level, node) into the tree's base key):

* ``engine="level"`` (default) — the level-vectorized engine. Each level's
  nodes live in one ``LevelState`` of stacked buffers; a tick issues
  exactly **one jitted dispatch per level**: WHS/SRS sampling vmapped (and
  selection flattened into a single composite-stratum sort / kernel pass,
  see ``whs.level_whsamp``), compaction row-wise, and child→parent routing
  done in-graph through static scatter indices, so the host only copies
  packed buffers. This is what keeps the host out of the hot loop at high
  fan-in, and — because a level is now a single array program — what makes
  sharding a level over a mesh axis a ``shard_map`` annotation rather than
  a rewrite.
* ``engine="loop"`` — the per-node reference engine (one jitted step per
  node per tick, the seed implementation). Kept as the bit-exact oracle
  for the vectorized engine and for dispatch-cost comparisons.

``sampler_backend`` selects the selection engine end-to-end — ``topk``
(``HostTree``'s default: dense partial-selection thresholds, bit-identical
to the reference and fastest on CPU), ``argsort`` (lexsort reference), or
``pallas`` (fused kernels); see ``core.sampling``.

``spmd_local_then_root`` — the in-graph two-level hierarchy used at pod
scale: every device samples its local sub-streams, compacts, all-gathers
the *reservoirs only* (this is the bandwidth saving), and the root stage
re-samples + answers the query. Pure ``shard_map``-compatible function; no
coordination beyond one all-gather of sampled data.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import error as err
from repro.core import sampling
from repro.core import whs
from repro.core.types import IntervalBatch, QueryResult, StratumMeta


# --------------------------------------------------------------------------
# Deterministic per-node keys: fold (tick, level, node) into the base key.
# Both engines use this chain, which is what makes them bit-comparable.
# --------------------------------------------------------------------------
def _node_key(key, t, lvl: int, ix):
    return jax.random.fold_in(
        jax.random.fold_in(jax.random.fold_in(key, t), lvl), ix
    )


def _level_keys(key, t, lvl: int, n_nodes: int):
    k = jax.random.fold_in(jax.random.fold_in(key, t), lvl)
    return jax.vmap(
        lambda i: jax.random.fold_in(k, i)
    )(jnp.arange(n_nodes, dtype=jnp.uint32))


def _child_routing(n_nodes: int, n_parents: int) -> np.ndarray:
    """Static routing table: ``child_of[p, j]`` = index of parent ``p``'s
    ``j``-th child (ascending), padded with the sentinel ``n_nodes``.
    Children map to parents by ``ix % n_parents`` (the testbed wiring)."""
    cpp = -(-n_nodes // n_parents)  # ceil
    child_of = np.full((n_parents, cpp), n_nodes, np.int32)
    for j in range(n_nodes):
        child_of[j % n_parents, j // n_parents] = j
    return child_of


def _present_strata(strata_c, valid_c, num_strata: int):
    """bool[n, X]: strata each node actually forwards items for (drives the
    parent's metadata fold — a message with no items for a stratum must not
    contribute metadata, mirroring ``Window.deliver``)."""
    n = strata_c.shape[0]
    node_ix = jnp.arange(n, dtype=jnp.int32)[:, None]
    seg = jnp.where(valid_c, node_ix * num_strata + strata_c, n * num_strata)
    cnt = jnp.zeros((n * num_strata + 1,), jnp.int32).at[
        seg.reshape(-1)
    ].add(1)[: n * num_strata]
    return (cnt > 0).reshape(n, num_strata)


def _route_pack(values_c, strata_c, valid_c, child_of: np.ndarray):
    """In-graph child→parent routing + packing.

    Gathers each parent's children (static indices), then stably packs the
    valid items to the front of each parent row — children in child-index
    order, items in compacted order, i.e. exactly the order the per-node
    loop engine would deliver them in. Returns
    ``(packed_values[P, D], packed_strata[P, D], n_delivered[P])``.
    """
    n, oc = values_c.shape
    p = child_of.shape[0]
    d = child_of.shape[1] * oc
    vpad = jnp.concatenate([values_c, jnp.zeros((1, oc), values_c.dtype)])
    spad = jnp.concatenate([strata_c, jnp.zeros((1, oc), strata_c.dtype)])
    mpad = jnp.concatenate([valid_c, jnp.zeros((1, oc), bool)])
    gather = jnp.asarray(child_of)
    gv = vpad[gather].reshape(p, d)
    gs = spad[gather].reshape(p, d)
    gm = mpad[gather].reshape(p, d)
    packed_v, packed_s, n_deliv = whs.pack_rows(gv, gs, gm, d)
    return packed_v, packed_s, n_deliv


# --------------------------------------------------------------------------
# Jitted per-node steps (loop engine — the bit-exact reference).
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _node_step(capacity: int, num_strata: int, out_capacity: int,
               allocation: str, backend: str, lvl: int):
    @jax.jit
    def step(key, t, ix, values, strata, valid, w_in, c_in, sample_size):
        k = _node_key(key, t, lvl, ix)
        batch = IntervalBatch(values, strata, valid, StratumMeta(w_in, c_in))
        res = whs.whsamp(k, batch, sample_size, num_strata,
                         allocation=allocation, backend=backend,
                         max_reservoir=out_capacity)
        out = whs.compact_sample(batch, res, out_capacity)
        return (out.value, out.stratum, out.valid,
                out.meta.weight, out.meta.count, res.y)

    return step


@functools.lru_cache(maxsize=None)
def _root_step(capacity: int, num_strata: int, allocation: str, backend: str,
               lvl: int, budget: int, hist_bins: int = 64):
    """Root = sampling + the user query (§III-A lines 16-20). The query here
    is the paper's evaluation workload: windowed SUM and MEAN with error
    bounds, plus a value histogram (a representative GROUP-BY aggregate —
    the datacenter node runs the real analytics, not just the sampler)."""
    from repro.core import queries

    @jax.jit
    def step(key, t, values, strata, valid, w_in, c_in, sample_size):
        k = _node_key(key, t, lvl, 0)
        batch = IntervalBatch(values, strata, valid, StratumMeta(w_in, c_in))
        res = whs.whsamp(k, batch, sample_size, num_strata,
                         allocation=allocation, backend=backend,
                         max_reservoir=budget)
        s = err.approx_sum(batch.value, batch.stratum, res.selected, res.meta, num_strata)
        m = err.approx_mean(batch.value, batch.stratum, res.selected, res.meta, num_strata)
        lo = jnp.min(jnp.where(res.selected, batch.value, jnp.inf))
        hi = jnp.max(jnp.where(res.selected, batch.value, -jnp.inf))
        edges = jnp.linspace(lo, hi + 1e-6, hist_bins + 1)
        h = queries.weighted_histogram(batch, res, num_strata, edges)
        return (s.estimate, s.variance, m.estimate, m.variance,
                jnp.sum(res.selected.astype(jnp.int32)), h.estimate)

    return step


# --- SRS baseline (§IV-B): coin-flip keep at every node, HT estimate at root.
@functools.lru_cache(maxsize=None)
def _srs_node_step(capacity: int, num_strata: int, out_capacity: int, lvl: int):
    from repro.core import srs

    out_cap = min(out_capacity, capacity)

    @jax.jit
    def step(key, t, ix, values, strata, valid, w_in, c_in, p_keep):
        k = _node_key(key, t, lvl, ix)
        batch = IntervalBatch(values, strata, valid, StratumMeta(w_in, c_in))
        selected = srs.srs_select(k, batch, p_keep)
        # compact without weight bookkeeping (SRS carries no metadata)
        v_c, s_c, n_sel = whs.pack_rows(values[None, :], strata[None, :],
                                        selected[None, :], out_cap)
        slot_valid = jnp.arange(out_cap) < jnp.minimum(n_sel[0], out_cap)
        return v_c[0], s_c[0], slot_valid, w_in, c_in, n_sel[0]

    return step


@functools.lru_cache(maxsize=None)
def _srs_root_step(capacity: int, num_strata: int, lvl: int,
                   hist_bins: int = 64):
    """Same query workload as the WHS root (fair throughput comparison):
    SUM/MEAN + histogram, with Horvitz–Thompson 1/f weights."""
    from repro.core import srs

    @jax.jit
    def step(key, t, values, strata, valid, w_in, c_in, p_keep, f_total):
        k = _node_key(key, t, lvl, 0)
        batch = IntervalBatch(values, strata, valid, StratumMeta(w_in, c_in))
        selected = srs.srs_select(k, batch, p_keep)
        s = srs.srs_sum(batch, selected, f_total)
        m = srs.srs_mean(batch, selected, f_total)
        lo = jnp.min(jnp.where(selected, batch.value, jnp.inf))
        hi = jnp.max(jnp.where(selected, batch.value, -jnp.inf))
        edges = jnp.linspace(lo, hi + 1e-6, hist_bins + 1)
        bin_ix = jnp.clip(jnp.searchsorted(edges, batch.value, side="right") - 1,
                          0, hist_bins - 1)
        hist = jnp.zeros((hist_bins,), jnp.float32).at[
            jnp.where(selected, bin_ix, hist_bins - 1)
        ].add(jnp.where(selected, 1.0 / f_total, 0.0))
        return (s.estimate, s.variance, m.estimate, m.variance,
                jnp.sum(selected.astype(jnp.int32)), hist)

    return step


# --------------------------------------------------------------------------
# Jitted level steps (level-vectorized engine): one dispatch per level.
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _whs_level_step(n_nodes: int, capacity: int, num_strata: int,
                    out_capacity: int, n_parents: int, allocation: str,
                    backend: str, lvl: int):
    child_of = _child_routing(n_nodes, n_parents)

    @jax.jit
    def step(key, t, values, strata, valid, w_in, c_in, sample_size):
        keys = _level_keys(key, t, lvl, n_nodes)
        res = whs.level_whsamp(keys, values, strata, valid, w_in, c_in,
                               sample_size, num_strata,
                               allocation=allocation, backend=backend,
                               max_reservoir=out_capacity)
        v_c, s_c, valid_c, meta = whs.level_compact(values, strata, res,
                                                    out_capacity)
        present = _present_strata(s_c, valid_c, num_strata)
        packed_v, packed_s, n_deliv = _route_pack(v_c, s_c, valid_c, child_of)
        n_fwd = jnp.sum(valid_c, axis=1, dtype=jnp.int32)
        return (packed_v, packed_s, n_deliv,
                meta.weight, meta.count, present, n_fwd)

    return step


@functools.lru_cache(maxsize=None)
def _srs_level_step(n_nodes: int, capacity: int, num_strata: int,
                    out_capacity: int, n_parents: int, lvl: int):
    child_of = _child_routing(n_nodes, n_parents)
    out_cap = min(out_capacity, capacity)

    @jax.jit
    def step(key, t, values, strata, valid, w_in, c_in, p_keep):
        keys = _level_keys(key, t, lvl, n_nodes)
        u = jax.vmap(lambda k: jax.random.uniform(k, (capacity,)))(keys)
        selected = (u < p_keep) & valid
        v_c, s_c, n_sel = whs.pack_rows(values, strata, selected, out_cap)
        n_keep = jnp.minimum(n_sel, out_cap)
        valid_c = jnp.arange(out_cap)[None, :] < n_keep[:, None]
        present = _present_strata(s_c, valid_c, num_strata)
        packed_v, packed_s, n_deliv = _route_pack(v_c, s_c, valid_c, child_of)
        # SRS carries no sampler metadata: W/C sets pass through unchanged.
        return packed_v, packed_s, n_deliv, w_in, c_in, present, n_keep

    return step


class HostTree:
    """Emulated edge topology (default geometry = the paper's testbed:
    8 sources → 4 edge nodes → 2 edge nodes → 1 root).

    ``mode="whs"`` runs the paper's weighted hierarchical sampler;
    ``mode="srs"`` runs the §IV-B coin-flip baseline (per-level keep
    probability ``p_level`` so the end-to-end fraction matches WHS's).

    ``engine`` selects the execution strategy (see module docstring):
    ``"level"`` issues one jitted dispatch per level per tick,
    ``"loop"`` one per node per tick. ``dispatch_count`` tracks jitted
    step invocations so tests/benchmarks can verify the dispatch model.
    ``sampler_backend`` is threaded through to every WHSamp call.

    Per-level processing wall-time is accumulated in ``level_time_s``
    (drives the Fig. 9/10 latency model)."""

    def __init__(
        self,
        fanin: list[int],                 # nodes per level, root last, e.g. [4, 2, 1]
        num_strata: int,
        capacity: int,
        sample_sizes: list[int],          # per level: interval budget
        interval_ticks: list[int] | None = None,
        allocation: str = "fair",
        seed: int = 0,
        mode: str = "whs",                # whs | srs
        fraction: float | None = None,    # srs: end-to-end sampling fraction
        engine: str = "level",            # level | loop
        # topk is bit-identical to the argsort reference (see core.sampling)
        # and ~1.7x faster on CPU — the tree defaults to it; the library
        # functions keep the argsort reference as their default.
        sampler_backend: str = "topk",
    ):
        from repro.core.window import LevelState, Window

        assert fanin[-1] == 1, "last level must be the single root"
        assert mode in ("whs", "srs")
        assert engine in ("level", "loop")
        self.fanin = fanin
        self.num_strata = num_strata
        self.allocation = allocation
        self.sample_sizes = sample_sizes
        self.mode = mode
        self.engine = engine
        self.sampler_backend = sampler_backend
        self.fraction = fraction
        # SRS keeps items with the same probability at every level so the
        # compounded keep-rate equals the end-to-end ``fraction``.
        self.p_level = (float(fraction) ** (1.0 / len(fanin))
                        if fraction is not None else 1.0)
        interval_ticks = interval_ticks or [1] * len(fanin)
        self.capacities: list[int] = []
        cap = capacity
        for lvl, n_nodes in enumerate(fanin):
            self.capacities.append(cap)
            if lvl + 1 < len(fanin):
                # Next level's buffer: every child may forward a full budget
                # per interval; 2x slack absorbs interval misalignment (§III-C).
                children_per_parent = -(-n_nodes // fanin[lvl + 1])  # ceil
                cap = max(2 * sample_sizes[lvl] * children_per_parent, 64)
        if engine == "loop":
            self.levels = [
                [Window(self.capacities[lvl], num_strata, interval_ticks[lvl])
                 for _ in range(n_nodes)]
                for lvl, n_nodes in enumerate(fanin)
            ]
        else:
            self.levels = [
                LevelState(n_nodes, self.capacities[lvl], num_strata,
                           interval_ticks[lvl])
                for lvl, n_nodes in enumerate(fanin)
            ]
        self._key = jax.random.PRNGKey(seed)
        self.items_forwarded = [0] * len(fanin)   # bandwidth accounting (Fig. 8)
        self.items_ingested = 0
        self.level_time_s = [0.0] * len(fanin)    # processing time (Fig. 9/10)
        self.dispatch_count = 0                   # jitted step invocations
        self.results: list[dict] = []

    def ingest(self, node: int, values: np.ndarray, strata: np.ndarray) -> None:
        """Source → level-0 node delivery."""
        self.items_ingested += len(values)
        if self.engine == "loop":
            self.levels[0][node].deliver(values, strata)
        else:
            self.levels[0].deliver(node, values, strata)

    def tick(self, t: int) -> None:
        """Advance one global tick: flush every due window, push upstream."""
        if self.engine == "loop":
            self._tick_loop(t)
        else:
            self._tick_level(t)

    # ------------------------------------------------------------- loop --
    def _tick_loop(self, t: int) -> None:
        import time as _time

        for lvl, nodes in enumerate(self.levels):
            is_root = lvl == len(self.levels) - 1
            n_parents = self.fanin[lvl + 1] if not is_root else 1
            for ix, win in enumerate(nodes):
                if not win.due(t) or win.fill == 0:
                    continue
                values, strata, valid, w_in, c_in = win.flush()
                t0 = _time.perf_counter()
                if is_root:
                    if self.mode == "srs":
                        step = _srs_root_step(win.capacity, self.num_strata, lvl)
                        se, sv, me, mv, nsel, hist = step(
                            self._key, t, values, strata, valid, w_in, c_in,
                            jnp.float32(self.p_level), jnp.float32(self.fraction))
                    else:
                        step = _root_step(win.capacity, self.num_strata,
                                          self.allocation, self.sampler_backend,
                                          lvl, int(self.sample_sizes[lvl]))
                        se, sv, me, mv, nsel, hist = step(
                            self._key, t, values, strata, valid, w_in, c_in,
                            jnp.float32(self.sample_sizes[lvl]))
                    self.dispatch_count += 1
                    hist = np.asarray(hist)
                    se = float(se)
                    self.level_time_s[lvl] += _time.perf_counter() - t0
                    self.results.append(dict(
                        tick=t, sum=se, sum_var=float(sv),
                        mean=float(me), mean_var=float(mv), n_sampled=int(nsel),
                        histogram=hist,
                    ))
                else:
                    out_cap = self.sample_sizes[lvl]
                    if self.mode == "srs":
                        step = _srs_node_step(win.capacity, self.num_strata,
                                              out_cap, lvl)
                        ov, os_, oval, w_out, c_out, _ = step(
                            self._key, t, ix, values, strata, valid, w_in, c_in,
                            jnp.float32(self.p_level))
                    else:
                        step = _node_step(win.capacity, self.num_strata, out_cap,
                                          self.allocation, self.sampler_backend,
                                          lvl)
                        ov, os_, oval, w_out, c_out, _ = step(
                            self._key, t, ix, values, strata, valid, w_in, c_in,
                            jnp.float32(self.sample_sizes[lvl]))
                    self.dispatch_count += 1
                    ov, os_, oval = np.asarray(ov), np.asarray(os_), np.asarray(oval)
                    self.level_time_s[lvl] += _time.perf_counter() - t0
                    n = int(oval.sum())
                    self.items_forwarded[lvl] += n
                    parent = self.levels[lvl + 1][ix % n_parents]
                    parent.deliver(ov[:n], os_[:n], np.asarray(w_out), np.asarray(c_out))

    # ------------------------------------------------------------ level --
    def _tick_level(self, t: int) -> None:
        import time as _time

        for lvl, state in enumerate(self.levels):
            is_root = lvl == len(self.levels) - 1
            if not state.due(t) or int(state.fill.sum()) == 0:
                continue
            values, strata, valid, w_in, c_in = state.flush_all()
            t0 = _time.perf_counter()
            if is_root:
                # The root is always a single node: squeeze the node axis and
                # run the (shared) scalar root step — still one dispatch.
                if self.mode == "srs":
                    step = _srs_root_step(state.capacity, self.num_strata, lvl)
                    se, sv, me, mv, nsel, hist = step(
                        self._key, t, values[0], strata[0], valid[0],
                        w_in[0], c_in[0],
                        jnp.float32(self.p_level), jnp.float32(self.fraction))
                else:
                    step = _root_step(state.capacity, self.num_strata,
                                      self.allocation, self.sampler_backend,
                                      lvl, int(self.sample_sizes[lvl]))
                    se, sv, me, mv, nsel, hist = step(
                        self._key, t, values[0], strata[0], valid[0],
                        w_in[0], c_in[0],
                        jnp.float32(self.sample_sizes[lvl]))
                self.dispatch_count += 1
                hist = np.asarray(hist)
                se = float(se)
                self.level_time_s[lvl] += _time.perf_counter() - t0
                self.results.append(dict(
                    tick=t, sum=se, sum_var=float(sv),
                    mean=float(me), mean_var=float(mv), n_sampled=int(nsel),
                    histogram=hist,
                ))
            else:
                n_parents = self.fanin[lvl + 1]
                out_cap = self.sample_sizes[lvl]
                if self.mode == "srs":
                    step = _srs_level_step(state.n_nodes, state.capacity,
                                           self.num_strata, out_cap,
                                           n_parents, lvl)
                    outs = step(self._key, t, values, strata, valid, w_in, c_in,
                                jnp.float32(self.p_level))
                else:
                    step = _whs_level_step(state.n_nodes, state.capacity,
                                           self.num_strata, out_cap, n_parents,
                                           self.allocation,
                                           self.sampler_backend, lvl)
                    outs = step(self._key, t, values, strata, valid, w_in, c_in,
                                jnp.float32(self.sample_sizes[lvl]))
                self.dispatch_count += 1
                (packed_v, packed_s, n_deliv,
                 w_out, c_out, present, n_fwd) = (np.asarray(o) for o in outs)
                self.level_time_s[lvl] += _time.perf_counter() - t0
                self.items_forwarded[lvl] += int(n_fwd.sum())
                parent = self.levels[lvl + 1]
                parent.deliver_packed(packed_v, packed_s, n_deliv)
                parent_ix = np.arange(state.n_nodes) % n_parents
                parent.fold_meta(parent_ix, present, w_out, c_out)


# --------------------------------------------------------------------------
# In-graph SPMD hierarchy (pod-scale data plane).
# --------------------------------------------------------------------------
def spmd_local_then_root(
    key: jax.Array,
    batch: IntervalBatch,
    *,
    axis_name: str,
    num_strata: int,
    local_budget: int,
    root_budget: int,
    allocation: str = "fair",
    sampler_backend: str = sampling.DEFAULT_BACKEND,
) -> tuple[QueryResult, QueryResult]:
    """Two-level hierarchical sampling across a mesh axis.

    Level 1 (edge): each device samples its local interval batch and
    compacts to ``local_budget`` slots. Level 2 (root): the compacted
    reservoirs — not the raw stream — are all-gathered and re-sampled,
    then SUM/MEAN + error bounds are computed. Returns (sum, mean).

    Call under ``shard_map`` with ``axis_name`` bound, e.g. the "data"
    axis; every device computes the root stage redundantly (no single
    point of failure, no coordination — §III-E). ``sampler_backend``
    selects the selection engine at both stages; with ``"pallas"`` the
    enclosing ``shard_map`` must pass ``check_rep=False`` (JAX has no
    replication rule for ``pallas_call``).
    """
    # Local stage: per-device key. Root stage: the SAME key on every device
    # so the redundantly-computed root result is bit-identical (replicated).
    k_local = jax.random.fold_in(key, jax.lax.axis_index(axis_name))
    k_root = jax.random.fold_in(key, 0x5F3759DF)
    res = whs.whsamp(k_local, batch, jnp.float32(local_budget), num_strata,
                     allocation=allocation, backend=sampler_backend,
                     max_reservoir=local_budget)
    compact = whs.compact_sample(batch, res, local_budget)

    g_val = jax.lax.all_gather(compact.value, axis_name, tiled=True)
    g_str = jax.lax.all_gather(compact.stratum, axis_name, tiled=True)
    g_vld = jax.lax.all_gather(compact.valid, axis_name, tiled=True)
    # Workers sample disjoint shards of each sub-stream (§III-E): the union
    # of their per-stratum reservoirs carries per-worker weights. Merging
    # parallel workers uses the count-weighted mean (the pool represents
    # Σ w_k·C_k originals over Σ C_k forwarded items) — see core/window.py
    # for why Eq. 5's max rule is path-only and biases parallel merges.
    g_c = jax.lax.psum(compact.meta.count, axis_name)
    g_w = (jax.lax.psum(compact.meta.weight * compact.meta.count, axis_name)
           / jnp.maximum(g_c, 1.0))
    # Strata empty across all workers: weight is irrelevant (no items) —
    # use 1 so the result stays replicated across the axis.
    g_w = jnp.where(g_c > 0.0, g_w, 1.0)

    root_batch = IntervalBatch(g_val, g_str, g_vld, StratumMeta(g_w, g_c))
    res_root = whs.whsamp(k_root, root_batch, jnp.float32(root_budget), num_strata,
                          allocation=allocation, backend=sampler_backend,
                          max_reservoir=root_budget)
    s = err.approx_sum(root_batch.value, root_batch.stratum, res_root.selected,
                       res_root.meta, num_strata)
    m = err.approx_mean(root_batch.value, root_batch.stratum, res_root.selected,
                        res_root.meta, num_strata)
    # The root stage is computed redundantly from all-gathered (identical)
    # data + an axis-invariant key, so results are replicated in value; a
    # scalar pmean re-types them as invariant for shard_map's vma check
    # (all_gather outputs stay `varying` under JAX's vma typing).
    rep = lambda t: jax.tree.map(lambda x: jax.lax.pmean(x, axis_name), t)
    return rep(s), rep(m)

"""Hierarchy executors: the paper's logical tree, two ways.

``HostTree``  — a discrete-tick emulation of the edge topology (the Kafka
pipeline of §IV): per-node windows, asynchronous intervals, compacted
forwarding, query + error bounds at the root. Drives the jitted node step;
used by benchmarks/examples to reproduce Figs. 6–12.

``spmd_local_then_root`` — the in-graph two-level hierarchy used at pod
scale: every device samples its local sub-streams, compacts, all-gathers
the *reservoirs only* (this is the bandwidth saving), and the root stage
re-samples + answers the query. Pure ``shard_map``-compatible function; no
coordination beyond one all-gather of sampled data.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import error as err
from repro.core import whs
from repro.core.types import IntervalBatch, QueryResult, StratumMeta


# --------------------------------------------------------------------------
# Jitted per-node interval step (shared across nodes of equal geometry).
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _node_step(capacity: int, num_strata: int, out_capacity: int, allocation: str):
    @jax.jit
    def step(key, values, strata, valid, w_in, c_in, sample_size):
        batch = IntervalBatch(values, strata, valid, StratumMeta(w_in, c_in))
        res = whs.whsamp(key, batch, sample_size, num_strata, allocation=allocation)
        out = whs.compact_sample(batch, res, out_capacity)
        return out.value, out.stratum, out.valid, res.meta.weight, res.meta.count, res.y

    return step


@functools.lru_cache(maxsize=None)
def _root_step(capacity: int, num_strata: int, allocation: str,
               hist_bins: int = 64):
    """Root = sampling + the user query (§III-A lines 16-20). The query here
    is the paper's evaluation workload: windowed SUM and MEAN with error
    bounds, plus a value histogram (a representative GROUP-BY aggregate —
    the datacenter node runs the real analytics, not just the sampler)."""
    from repro.core import queries

    @jax.jit
    def step(key, values, strata, valid, w_in, c_in, sample_size):
        batch = IntervalBatch(values, strata, valid, StratumMeta(w_in, c_in))
        res = whs.whsamp(key, batch, sample_size, num_strata, allocation=allocation)
        s = err.approx_sum(batch.value, batch.stratum, res.selected, res.meta, num_strata)
        m = err.approx_mean(batch.value, batch.stratum, res.selected, res.meta, num_strata)
        lo = jnp.min(jnp.where(res.selected, batch.value, jnp.inf))
        hi = jnp.max(jnp.where(res.selected, batch.value, -jnp.inf))
        edges = jnp.linspace(lo, hi + 1e-6, hist_bins + 1)
        h = queries.weighted_histogram(batch, res, num_strata, edges)
        return (s.estimate, s.variance, m.estimate, m.variance,
                jnp.sum(res.selected.astype(jnp.int32)), h.estimate)

    return step


# --- SRS baseline (§IV-B): coin-flip keep at every node, HT estimate at root.
@functools.lru_cache(maxsize=None)
def _srs_node_step(capacity: int, num_strata: int, out_capacity: int):
    from repro.core import srs

    @jax.jit
    def step(key, values, strata, valid, w_in, c_in, p_keep):
        batch = IntervalBatch(values, strata, valid, StratumMeta(w_in, c_in))
        selected = srs.srs_select(key, batch, p_keep)
        # compact without weight bookkeeping (SRS carries no metadata)
        order = jnp.argsort(jnp.where(selected, 0, 1), stable=True)
        take = order[:out_capacity]
        n_sel = jnp.sum(selected.astype(jnp.int32))
        slot_valid = jnp.arange(out_capacity) < n_sel
        return values[take], strata[take], slot_valid, w_in, c_in, n_sel

    return step


@functools.lru_cache(maxsize=None)
def _srs_root_step(capacity: int, num_strata: int, hist_bins: int = 64):
    """Same query workload as the WHS root (fair throughput comparison):
    SUM/MEAN + histogram, with Horvitz–Thompson 1/f weights."""
    from repro.core import srs

    @jax.jit
    def step(key, values, strata, valid, w_in, c_in, p_keep, f_total):
        batch = IntervalBatch(values, strata, valid, StratumMeta(w_in, c_in))
        selected = srs.srs_select(key, batch, p_keep)
        s = srs.srs_sum(batch, selected, f_total)
        m = srs.srs_mean(batch, selected, f_total)
        lo = jnp.min(jnp.where(selected, batch.value, jnp.inf))
        hi = jnp.max(jnp.where(selected, batch.value, -jnp.inf))
        edges = jnp.linspace(lo, hi + 1e-6, hist_bins + 1)
        bin_ix = jnp.clip(jnp.searchsorted(edges, batch.value, side="right") - 1,
                          0, hist_bins - 1)
        hist = jnp.zeros((hist_bins,), jnp.float32).at[
            jnp.where(selected, bin_ix, hist_bins - 1)
        ].add(jnp.where(selected, 1.0 / f_total, 0.0))
        return (s.estimate, s.variance, m.estimate, m.variance,
                jnp.sum(selected.astype(jnp.int32)), hist)

    return step


class HostTree:
    """Emulated edge topology (default geometry = the paper's testbed:
    8 sources → 4 edge nodes → 2 edge nodes → 1 root).

    ``mode="whs"`` runs the paper's weighted hierarchical sampler;
    ``mode="srs"`` runs the §IV-B coin-flip baseline (per-level keep
    probability ``p_level`` so the end-to-end fraction matches WHS's).
    Per-level processing wall-time is accumulated in ``level_time_s``
    (drives the Fig. 9/10 latency model)."""

    def __init__(
        self,
        fanin: list[int],                 # nodes per level, root last, e.g. [4, 2, 1]
        num_strata: int,
        capacity: int,
        sample_sizes: list[int],          # per level: interval budget
        interval_ticks: list[int] | None = None,
        allocation: str = "fair",
        seed: int = 0,
        mode: str = "whs",                # whs | srs
        fraction: float | None = None,    # srs: end-to-end sampling fraction
    ):
        from repro.core.window import Window

        assert fanin[-1] == 1, "last level must be the single root"
        assert mode in ("whs", "srs")
        self.fanin = fanin
        self.num_strata = num_strata
        self.allocation = allocation
        self.sample_sizes = sample_sizes
        self.mode = mode
        self.fraction = fraction
        # SRS keeps items with the same probability at every level so the
        # compounded keep-rate equals the end-to-end ``fraction``.
        self.p_level = (float(fraction) ** (1.0 / len(fanin))
                        if fraction is not None else 1.0)
        interval_ticks = interval_ticks or [1] * len(fanin)
        self.levels: list[list[Window]] = []
        cap = capacity
        for lvl, n_nodes in enumerate(fanin):
            self.levels.append([Window(cap, num_strata, interval_ticks[lvl]) for _ in range(n_nodes)])
            if lvl + 1 < len(fanin):
                # Next level's buffer: every child may forward a full budget
                # per interval; 2x slack absorbs interval misalignment (§III-C).
                children_per_parent = -(-n_nodes // fanin[lvl + 1])  # ceil
                cap = max(2 * sample_sizes[lvl] * children_per_parent, 64)
        self._rng = np.random.default_rng(seed)
        self._key = jax.random.PRNGKey(seed)
        self.items_forwarded = [0] * len(fanin)   # bandwidth accounting (Fig. 8)
        self.items_ingested = 0
        self.level_time_s = [0.0] * len(fanin)    # processing time (Fig. 9/10)
        self.results: list[dict] = []

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def ingest(self, node: int, values: np.ndarray, strata: np.ndarray) -> None:
        """Source → level-0 node delivery."""
        self.items_ingested += len(values)
        self.levels[0][node].deliver(values, strata)

    def tick(self, t: int) -> None:
        """Advance one global tick: flush every due window, push upstream."""
        import time as _time

        for lvl, nodes in enumerate(self.levels):
            is_root = lvl == len(self.levels) - 1
            n_parents = self.fanin[lvl + 1] if not is_root else 1
            for ix, win in enumerate(nodes):
                if not win.due(t) or win.fill == 0:
                    continue
                values, strata, valid, w_in, c_in = win.flush()
                key = self._next_key()
                t0 = _time.perf_counter()
                if is_root:
                    if self.mode == "srs":
                        step = _srs_root_step(win.capacity, self.num_strata)
                        se, sv, me, mv, nsel, hist = step(
                            key, values, strata, valid, w_in, c_in,
                            jnp.float32(self.p_level), jnp.float32(self.fraction))
                        hist = np.asarray(hist)
                    else:
                        step = _root_step(win.capacity, self.num_strata, self.allocation)
                        se, sv, me, mv, nsel, hist = step(
                            key, values, strata, valid, w_in, c_in,
                            jnp.float32(self.sample_sizes[lvl]))
                        hist = np.asarray(hist)
                    se = float(se)
                    self.level_time_s[lvl] += _time.perf_counter() - t0
                    self.results.append(dict(
                        tick=t, sum=se, sum_var=float(sv),
                        mean=float(me), mean_var=float(mv), n_sampled=int(nsel),
                        histogram=hist,
                    ))
                else:
                    out_cap = self.sample_sizes[lvl]
                    if self.mode == "srs":
                        step = _srs_node_step(win.capacity, self.num_strata, out_cap)
                        ov, os_, oval, w_out, c_out, _ = step(
                            key, values, strata, valid, w_in, c_in,
                            jnp.float32(self.p_level))
                    else:
                        step = _node_step(win.capacity, self.num_strata, out_cap,
                                          self.allocation)
                        ov, os_, oval, w_out, c_out, _ = step(
                            key, values, strata, valid, w_in, c_in,
                            jnp.float32(self.sample_sizes[lvl]))
                    ov, os_, oval = np.asarray(ov), np.asarray(os_), np.asarray(oval)
                    self.level_time_s[lvl] += _time.perf_counter() - t0
                    n = int(oval.sum())
                    self.items_forwarded[lvl] += n
                    parent = self.levels[lvl + 1][ix % n_parents]
                    parent.deliver(ov[:n], os_[:n], np.asarray(w_out), np.asarray(c_out))


# --------------------------------------------------------------------------
# In-graph SPMD hierarchy (pod-scale data plane).
# --------------------------------------------------------------------------
def spmd_local_then_root(
    key: jax.Array,
    batch: IntervalBatch,
    *,
    axis_name: str,
    num_strata: int,
    local_budget: int,
    root_budget: int,
    allocation: str = "fair",
) -> tuple[QueryResult, QueryResult]:
    """Two-level hierarchical sampling across a mesh axis.

    Level 1 (edge): each device samples its local interval batch and
    compacts to ``local_budget`` slots. Level 2 (root): the compacted
    reservoirs — not the raw stream — are all-gathered and re-sampled,
    then SUM/MEAN + error bounds are computed. Returns (sum, mean).

    Call under ``shard_map`` with ``axis_name`` bound, e.g. the "data"
    axis; every device computes the root stage redundantly (no single
    point of failure, no coordination — §III-E).
    """
    # Local stage: per-device key. Root stage: the SAME key on every device
    # so the redundantly-computed root result is bit-identical (replicated).
    k_local = jax.random.fold_in(key, jax.lax.axis_index(axis_name))
    k_root = jax.random.fold_in(key, 0x5F3759DF)
    res = whs.whsamp(k_local, batch, jnp.float32(local_budget), num_strata, allocation=allocation)
    compact = whs.compact_sample(batch, res, local_budget)

    g_val = jax.lax.all_gather(compact.value, axis_name, tiled=True)
    g_str = jax.lax.all_gather(compact.stratum, axis_name, tiled=True)
    g_vld = jax.lax.all_gather(compact.valid, axis_name, tiled=True)
    # Workers sample disjoint shards of each sub-stream (§III-E): the union
    # of their per-stratum reservoirs carries per-worker weights. Merging
    # parallel workers uses the count-weighted mean (the pool represents
    # Σ w_k·C_k originals over Σ C_k forwarded items) — see core/window.py
    # for why Eq. 5's max rule is path-only and biases parallel merges.
    g_c = jax.lax.psum(compact.meta.count, axis_name)
    g_w = (jax.lax.psum(compact.meta.weight * compact.meta.count, axis_name)
           / jnp.maximum(g_c, 1.0))
    # Strata empty across all workers: weight is irrelevant (no items) —
    # use 1 so the result stays replicated across the axis.
    g_w = jnp.where(g_c > 0.0, g_w, 1.0)

    root_batch = IntervalBatch(g_val, g_str, g_vld, StratumMeta(g_w, g_c))
    res_root = whs.whsamp(k_root, root_batch, jnp.float32(root_budget), num_strata,
                          allocation=allocation)
    s = err.approx_sum(root_batch.value, root_batch.stratum, res_root.selected,
                       res_root.meta, num_strata)
    m = err.approx_mean(root_batch.value, root_batch.stratum, res_root.selected,
                        res_root.meta, num_strata)
    # The root stage is computed redundantly from all-gathered (identical)
    # data + an axis-invariant key, so results are replicated in value; a
    # scalar pmean re-types them as invariant for shard_map's vma check
    # (all_gather outputs stay `varying` under JAX's vma typing).
    rep = lambda t: jax.tree.map(lambda x: jax.lax.pmean(x, axis_name), t)
    return rep(s), rep(m)

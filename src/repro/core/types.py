"""Core data model for the ApproxIoT stream-analytics plane.

The unit of work is an *interval batch*: a fixed-capacity flat buffer of
items observed by one node during one time interval, tagged with the
stratum (sub-stream / source id) of each item plus per-stratum metadata
(weight set ``W`` and count set ``C``) received from downstream nodes
(Alg. 1 of the paper).

Fixed capacity keeps every array shape static so the whole pipeline jits,
scans, and shards; the ``valid`` mask carries the dynamic item count.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class StratumMeta(NamedTuple):
    """Per-stratum metadata sets (``W^in/out``, ``C^in/out`` in the paper).

    Shapes: ``[num_strata]``. ``weight`` is the effective inverse sampling
    probability accumulated along the upstream path (Eq. 1 / Eq. 9);
    ``count`` is the number of items the immediate downstream node forwarded
    for the stratum (``C`` set, §III-C).
    """

    weight: jnp.ndarray  # f32[X]
    count: jnp.ndarray   # f32[X]

    @staticmethod
    def identity(num_strata: int) -> "StratumMeta":
        """Source-level metadata: weight 1, count 0 (no downstream node)."""
        return StratumMeta(
            weight=jnp.ones((num_strata,), jnp.float32),
            count=jnp.zeros((num_strata,), jnp.float32),
        )


class IntervalBatch(NamedTuple):
    """All items a node observes for one time interval.

    ``value``   f32[M]  — item payload (measurement, fare, loss, ...).
    ``stratum`` i32[M]  — source / sub-stream id in ``[0, num_strata)``.
    ``valid``   bool[M] — which slots hold real items this interval.
    ``meta``            — most recent ``W^in``/``C^in`` sets (§III-C keeps
                          the latest value per stratum across intervals).
    """

    value: jnp.ndarray
    stratum: jnp.ndarray
    valid: jnp.ndarray
    meta: StratumMeta

    @property
    def capacity(self) -> int:
        return self.value.shape[0]


class SampleResult(NamedTuple):
    """Output of one ``WHSamp`` call (Alg. 2).

    ``selected`` bool[M] — membership of each input slot in the sample.
    ``meta``             — the outgoing ``W^out``/``C^out`` sets.
    ``c``        f32[X]  — items observed per stratum this interval.
    ``y``        f32[X]  — items selected per stratum (``Y_i = min(c_i,N_i)``).
    ``reservoir`` f32[X] — the reservoir size ``N_i`` used per stratum.
    """

    selected: jnp.ndarray
    meta: StratumMeta
    c: jnp.ndarray
    y: jnp.ndarray
    reservoir: jnp.ndarray


class QueryResult(NamedTuple):
    """Approximate query output with rigorous error bounds (§III-D)."""

    estimate: jnp.ndarray   # scalar or [X]
    variance: jnp.ndarray   # matching shape
    # 68-95-99.7 rule: bound_k = k * sqrt(variance)
    def bound(self, sigmas: float = 2.0) -> jnp.ndarray:
        return sigmas * jnp.sqrt(jnp.maximum(self.variance, 0.0))

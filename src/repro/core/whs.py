"""Weighted Hierarchical Sampling — Alg. 2 with the §III-C async fix (Eq. 9).

One ``whsamp`` call is one node × one time interval. It is a pure function
of the interval batch + RNG key, so it jits, vmaps over nodes, and runs
under ``shard_map`` with zero cross-node coordination — the property the
paper's scalability argument rests on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sampling
from repro.core.types import IntervalBatch, SampleResult, StratumMeta


def _whs_meta(c, reservoirs, w_in, c_in, async_calibration):
    """Alg. 2 lines 12–20 weight/count update — shared by the per-node and
    level-vectorized paths (pure elementwise, any leading batch shape)."""
    y = jnp.minimum(c, jnp.maximum(reservoirs, 0.0))
    safe_n = jnp.maximum(reservoirs, 1.0)
    w_local = jnp.where(c > reservoirs, c / safe_n, 1.0)

    if async_calibration:
        # Eq. 9: calibrate by C^in / c — corrects the α bias when the
        # downstream node's interval straddles ours. C^in == 0 marks a
        # source stream (no downstream node): factor 1.
        calib = jnp.where((c_in > 0.0) & (c > 0.0), c_in / jnp.maximum(c, 1.0), 1.0)
    else:
        calib = jnp.ones_like(c)

    w_out = w_in * w_local * calib
    # Strata absent this interval keep their previous weight (§III-C: a node
    # maintains the most recent sets and only updates on arrival).
    w_out = jnp.where(c > 0.0, w_out, w_in)
    c_out = jnp.where(c > 0.0, y, c_in)
    return y, StratumMeta(weight=w_out, count=c_out)


def whsamp(
    key: jax.Array,
    batch: IntervalBatch,
    sample_size: jnp.ndarray,
    num_strata: int,
    *,
    allocation: str = "fair",
    async_calibration: bool = True,
    backend: str | sampling.SamplerBackend = sampling.DEFAULT_BACKEND,
    max_reservoir: int | None = None,
) -> SampleResult:
    """Run WHSamp over one interval batch.

    Weight update (Alg. 2 lines 12–20, with line 14 replaced by Eq. 9):

        w_i      = c_i / N_i            if c_i > N_i   else 1
        W_i^out  = W_i^in · w_i · C_i^in / c_i          (Eq. 9)
        C_i^out  = Y_i = min(c_i, N_i)

    With synchronized intervals ``C_i^in == c_i`` and Eq. 9 reduces to the
    plain Eq. 1 update. At a source node ``W^in = 1`` and ``C^in = 0``
    (sentinel meaning "no downstream sampler"), so the calibration factor
    is forced to 1.

    ``backend`` picks the selection engine (``argsort`` | ``topk`` |
    ``pallas``, see ``core.sampling``); all backends realize the same
    output law. ``max_reservoir`` is an optional static bound on every
    ``N_i`` (callers that know the interval budget statically should pass
    it — the ``topk`` backend uses it to size its partial selection).
    """
    be = sampling.get_backend(backend)
    c = be.counts(batch.stratum, batch.valid, num_strata)
    stds = None
    if allocation == "neyman":
        stds = sampling.stratum_stds(batch.value, batch.stratum, batch.valid,
                                     num_strata)
    reservoirs = sampling.allocate_reservoirs(sample_size, c,
                                              policy=allocation, stds=stds)

    def run_select():
        # Priorities are drawn here (not inside the backend) so every
        # backend sees identical randomness per key; drawing inside the
        # branch lets the saturation fast path skip the draw.
        priorities = jax.random.uniform(key, (batch.capacity,))
        return be.select(
            key, batch.stratum, batch.valid, reservoirs, num_strata,
            priorities=priorities, max_reservoir=max_reservoir,
        )

    # Saturation fast path (fraction ≥ 1.0): N_i ≥ c_i for every stratum
    # makes every backend's mask provably ``valid`` bit-for-bit — skip the
    # draw + selection entirely (see ``level_whsamp`` for the level-wide
    # version of the same argument).
    selected = jax.lax.cond(jnp.all(reservoirs >= c), lambda: batch.valid,
                            run_select)
    y, meta = _whs_meta(c, reservoirs, batch.meta.weight, batch.meta.count,
                        async_calibration)
    return SampleResult(
        selected=selected, meta=meta, c=c, y=y, reservoir=reservoirs,
    )


def level_whsamp(
    keys: jax.Array,
    values: jnp.ndarray,
    strata: jnp.ndarray,
    valid: jnp.ndarray,
    w_in: jnp.ndarray,
    c_in: jnp.ndarray,
    sample_size: jnp.ndarray,
    num_strata: int,
    *,
    allocation: str = "fair",
    async_calibration: bool = True,
    backend: str | sampling.SamplerBackend = sampling.DEFAULT_BACKEND,
    max_reservoir: int | None = None,
) -> SampleResult:
    """WHSamp over a whole hierarchy level in one array program.

    Inputs are stacked over the node axis: ``values/strata/valid`` are
    ``[n_nodes, cap]``, ``w_in/c_in`` are ``[n_nodes, X]``, ``keys`` is one
    PRNG key per node. Per-node arithmetic (counts, reservoir allocation,
    weight update) is vmapped. Selection runs as one batched program per
    level: vmapped over the node axis by default (XLA batches the sorts /
    top-k), or — for backends with ``flatten_for_level`` (pallas) —
    flattened into a single composite-stratum problem (stratum' = node·X +
    stratum) so the kernel makes exactly one pass over the level's items.
    Results are bit-identical to ``whsamp`` per node with the same
    per-node keys.
    """
    n_nodes, cap = values.shape
    be = sampling.get_backend(backend)

    node_ix = jnp.arange(n_nodes, dtype=jnp.int32)[:, None]
    comp = (node_ix * num_strata + strata).reshape(-1)
    flat_valid = valid.reshape(-1)

    c = be.counts(comp, flat_valid, n_nodes * num_strata)
    c = c.reshape(n_nodes, num_strata)
    if allocation == "neyman":
        stds = sampling.stratum_stds(
            values.reshape(-1), comp, flat_valid, n_nodes * num_strata,
        ).reshape(n_nodes, num_strata)
        reservoirs = jax.vmap(
            lambda ci, si: sampling.allocate_reservoirs(
                sample_size, ci, policy=allocation, stds=si)
        )(c, stds)
    else:
        reservoirs = jax.vmap(
            lambda ci: sampling.allocate_reservoirs(sample_size, ci, policy=allocation)
        )(c)

    def run_select():
        # The priority draw lives inside the selection branch so the
        # saturation fast path below skips it entirely — bit-identical,
        # since the draw is a pure function of ``keys`` consumed only
        # here, and every backend sees the same per-node streams.
        priorities = jax.vmap(lambda k: jax.random.uniform(k, (cap,)))(keys)
        if getattr(be, "flatten_for_level", False):
            return be.select(
                keys[0], comp, flat_valid, reservoirs.reshape(-1),
                n_nodes * num_strata, priorities=priorities.reshape(-1),
                max_reservoir=max_reservoir,
            ).reshape(n_nodes, cap)
        return jax.vmap(
            lambda k, s, v, r, p: be.select(
                k, s, v, r, num_strata, priorities=p,
                max_reservoir=max_reservoir, batch_hint=n_nodes)
        )(keys, strata, valid, reservoirs, priorities)

    # Saturation fast path: when every stratum's reservoir covers its count
    # (N_i ≥ c_i level-wide — the high-fraction regime), every backend's
    # mask is provably ``valid`` bit-for-bit (τ sinks below all priorities,
    # ties resolve to "keep all"), so skip the sort/top-k/kernel pass
    # entirely. ``cond`` executes one branch at runtime here — this
    # function sits directly under ``jit``/``lax.scan``, not under a
    # ``vmap`` that would force both branches.
    selected = jax.lax.cond(jnp.all(reservoirs >= c), lambda: valid,
                            run_select)

    y, meta = _whs_meta(c, reservoirs, w_in, c_in, async_calibration)
    return SampleResult(
        selected=selected, meta=meta, c=c, y=y, reservoir=reservoirs,
    )


def apply_sample(batch: IntervalBatch, result: SampleResult) -> IntervalBatch:
    """Forward step (Alg. 1 line 13): the upstream-bound interval batch.

    Sampled-out slots become invalid; values/strata stay in place (the
    fixed-capacity layout means "sending" is just masking — compaction is
    a host-side/transport concern, see ``core.tree``).
    """
    return IntervalBatch(
        value=batch.value,
        stratum=batch.stratum,
        valid=result.selected,
        meta=result.meta,
    )


def pack_rows(
    values: jnp.ndarray,
    strata: jnp.ndarray,
    keep: jnp.ndarray,
    out_capacity: int,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Row-wise O(M) compaction: pack each row's kept items to the front.

    ``values/strata/keep`` are ``[n, cap]``; returns ``[n, out_capacity]``
    buffers (kept items in original buffer order, overflow dropped) plus
    the per-row kept counts. One cumsum + one scatter instead of a
    per-row O(M log M) sort — this runs on every hop of every tick.
    """
    n, _ = values.shape
    dest = jnp.cumsum(keep.astype(jnp.int32), axis=1) - 1
    row = jnp.arange(n, dtype=jnp.int32)[:, None]
    ok = keep & (dest < out_capacity)
    idx = jnp.where(ok, row * out_capacity + dest, n * out_capacity).reshape(-1)
    values_c = jnp.zeros((n * out_capacity,), values.dtype).at[idx].set(
        values.reshape(-1), mode="drop").reshape(n, out_capacity)
    strata_c = jnp.zeros((n * out_capacity,), strata.dtype).at[idx].set(
        strata.reshape(-1), mode="drop").reshape(n, out_capacity)
    return values_c, strata_c, jnp.sum(keep, axis=1, dtype=jnp.int32)


def _truncation_corrected_meta(
    slot_valid: jnp.ndarray,
    result_y: jnp.ndarray,
    meta: StratumMeta,
    seg: jnp.ndarray,
    num_segments: int,
) -> StratumMeta:
    """Re-derive (W^out, C^out) from what actually fits in the out buffer.

    When every selected item fits (the provisioned case: ``Σ Y_i ≤
    out_capacity`` by construction of ``allocate_reservoirs``), kept == Y
    and this is an exact no-op (factor ``Y/Y == 1.0``). If the buffer *is*
    too small, dropping items without correction would bias every upstream
    estimate low; instead the extra thinning is folded into the weights
    (``W·Y/kept``) and ``C^out`` is set to the kept count so Eq. 9's
    ``C^in/c`` calibration at the parent stays consistent with the items
    it actually receives.
    """
    kept = jnp.zeros((num_segments + 1,), jnp.float32).at[
        jnp.where(slot_valid, seg, num_segments).reshape(-1)
    ].add(1.0)[:num_segments].reshape(meta.weight.shape)
    factor = jnp.where(kept > 0.0, result_y / jnp.maximum(kept, 1.0), 1.0)
    return StratumMeta(
        weight=meta.weight * factor,
        count=jnp.where(kept > 0.0, kept, meta.count),
    )


def compact_sample(
    batch: IntervalBatch, result: SampleResult, out_capacity: int
) -> IntervalBatch:
    """Pack selected items into a smaller buffer of ``out_capacity`` slots.

    This is the bandwidth saving of the paper (Fig. 8): a node forwards
    ``Σ_i Y_i ≤ sample_size`` items upstream, not the whole interval.
    Deterministic gather via sort-by-(!selected) keeps everything static.
    Should ``out_capacity`` be smaller than the number of selected items,
    the overflow is weight-corrected rather than silently dropped (see
    ``_truncation_corrected_meta``).
    """
    num_strata = result.meta.weight.shape[0]
    # A node can never forward more items than its buffer holds: a budget
    # larger than the capacity (possible for SRS's provisioning formula)
    # degenerates to "forward everything selected".
    out_capacity = min(out_capacity, batch.capacity)
    values_c, strata_c, n_sel = pack_rows(
        batch.value[None, :], batch.stratum[None, :],
        result.selected[None, :], out_capacity)
    slot_valid = jnp.arange(out_capacity) < jnp.minimum(n_sel[0], out_capacity)
    meta = _truncation_corrected_meta(
        slot_valid, result.y, result.meta, strata_c[0], num_strata
    )
    return IntervalBatch(
        value=values_c[0],
        stratum=strata_c[0],
        valid=slot_valid,
        meta=meta,
    )


def level_compact(
    values: jnp.ndarray,
    strata: jnp.ndarray,
    result: SampleResult,
    out_capacity: int,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, StratumMeta]:
    """``compact_sample`` over a stacked level: ``[n_nodes, cap]`` buffers →
    ``[n_nodes, out_capacity]`` forwarding buffers + corrected meta sets.

    Row-wise stable sort keeps each node's items in buffer order, so the
    packed output is bit-identical to running ``compact_sample`` per node.
    """
    n_nodes, cap = values.shape
    num_strata = result.meta.weight.shape[-1]
    out_capacity = min(out_capacity, cap)
    values_c, strata_c, n_sel = pack_rows(values, strata, result.selected,
                                          out_capacity)
    n_keep = jnp.minimum(n_sel, out_capacity)
    slot_valid = jnp.arange(out_capacity)[None, :] < n_keep[:, None]
    node_ix = jnp.arange(n_nodes, dtype=jnp.int32)[:, None]
    meta = _truncation_corrected_meta(
        slot_valid, result.y, result.meta,
        node_ix * num_strata + strata_c, n_nodes * num_strata,
    )
    return values_c, strata_c, slot_valid, meta


def level_tick(
    keys: jax.Array,
    values: jnp.ndarray,
    strata: jnp.ndarray,
    valid: jnp.ndarray,
    w_in: jnp.ndarray,
    c_in: jnp.ndarray,
    sample_size: jnp.ndarray,
    num_strata: int,
    *,
    out_capacity: int,
    allocation: str = "fair",
    async_calibration: bool = True,
    backend: str | sampling.SamplerBackend = sampling.DEFAULT_BACKEND,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, StratumMeta, SampleResult]:
    """One whole WHS level tick: sample + weight update + compact.

    Bit-identical to ``level_whsamp`` followed by ``level_compact`` for
    every backend, but lets the tick run as a single pass:

    * Backends advertising ``fused_level_tick`` (``pallas_fused``) run
      counts, reservoir allocation, threshold selection, the Alg. 2
      weight update and the compaction as ONE Pallas kernel with the
      item buffer VMEM-resident (``kernels.fused_level_tick``); only
      the truncation correction (a tiny ``[n, X]`` pass) stays in XLA.
    * Every other backend gets the saturation passthrough: when all
      reservoirs cover their counts AND the buffers are front-packed
      (the append-only window layout), selection is skipped (see
      ``level_whsamp``) and the compaction collapses to a truncating
      copy — zeros beyond the kept range, exactly what the scatter
      pack produces — killing the exact-path (fraction 1.0) overhead.

    Returns ``(values_c, strata_c, slot_valid, meta, result)``.
    """
    n_nodes, cap = values.shape
    out_cap = min(out_capacity, cap)
    be = sampling.get_backend(backend)

    if getattr(be, "fused_level_tick", False):
        from repro.kernels.fused_level_tick import ops as ft_ops

        priorities = jax.vmap(lambda k: jax.random.uniform(k, (cap,)))(keys)
        (keep, values_c, strata_c, n_sel, c, reservoirs, y, w_out,
         c_out) = ft_ops.fused_level_tick(
            values, strata, valid, priorities, w_in, c_in, sample_size,
            num_strata, out_cap, allocation=allocation,
            async_calibration=async_calibration, impl="pallas")
        result = SampleResult(selected=keep,
                              meta=StratumMeta(weight=w_out, count=c_out),
                              c=c, y=y, reservoir=reservoirs)
        n_keep = jnp.minimum(n_sel, out_cap)
        slot_valid = jnp.arange(out_cap)[None, :] < n_keep[:, None]
        node_ix = jnp.arange(n_nodes, dtype=jnp.int32)[:, None]
        meta = _truncation_corrected_meta(
            slot_valid, result.y, result.meta,
            node_ix * num_strata + strata_c, n_nodes * num_strata)
        return values_c, strata_c, slot_valid, meta, result

    result = level_whsamp(keys, values, strata, valid, w_in, c_in,
                          sample_size, num_strata, allocation=allocation,
                          async_calibration=async_calibration,
                          backend=backend, max_reservoir=out_capacity)
    n_valid = jnp.sum(valid, axis=1, dtype=jnp.int32)
    iota = jnp.arange(cap, dtype=jnp.int32)[None, :]
    front_packed = jnp.all(valid == (iota < n_valid[:, None]))
    saturated = jnp.all(result.reservoir >= result.c)
    node_ix = jnp.arange(n_nodes, dtype=jnp.int32)[:, None]

    def passthrough():
        # keep == valid (saturated) and valid is front-packed: packing is
        # a truncating copy, bit-identical to the scatter path.
        n_keep = jnp.minimum(n_valid, out_cap)
        slot_valid = jnp.arange(out_cap)[None, :] < n_keep[:, None]
        v_c = jnp.where(slot_valid, values[:, :out_cap], 0.0)
        s_c = jnp.where(slot_valid, strata[:, :out_cap], 0)
        meta = _truncation_corrected_meta(
            slot_valid, result.y, result.meta,
            node_ix * num_strata + s_c, n_nodes * num_strata)
        return v_c, s_c, slot_valid, meta

    def pack():
        v_c, s_c, slot_valid, meta = level_compact(values, strata, result,
                                                   out_cap)
        return v_c, s_c, slot_valid, meta

    v_c, s_c, slot_valid, meta = jax.lax.cond(saturated & front_packed,
                                              passthrough, pack)
    return v_c, s_c, slot_valid, meta, result

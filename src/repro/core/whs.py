"""Weighted Hierarchical Sampling — Alg. 2 with the §III-C async fix (Eq. 9).

One ``whsamp`` call is one node × one time interval. It is a pure function
of the interval batch + RNG key, so it jits, vmaps over nodes, and runs
under ``shard_map`` with zero cross-node coordination — the property the
paper's scalability argument rests on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sampling
from repro.core.types import IntervalBatch, SampleResult, StratumMeta


def whsamp(
    key: jax.Array,
    batch: IntervalBatch,
    sample_size: jnp.ndarray,
    num_strata: int,
    *,
    allocation: str = "fair",
    async_calibration: bool = True,
) -> SampleResult:
    """Run WHSamp over one interval batch.

    Weight update (Alg. 2 lines 12–20, with line 14 replaced by Eq. 9):

        w_i      = c_i / N_i            if c_i > N_i   else 1
        W_i^out  = W_i^in · w_i · C_i^in / c_i          (Eq. 9)
        C_i^out  = Y_i = min(c_i, N_i)

    With synchronized intervals ``C_i^in == c_i`` and Eq. 9 reduces to the
    plain Eq. 1 update. At a source node ``W^in = 1`` and ``C^in = 0``
    (sentinel meaning "no downstream sampler"), so the calibration factor
    is forced to 1.
    """
    c = sampling.stratum_counts(batch.stratum, batch.valid, num_strata)
    reservoirs = sampling.allocate_reservoirs(sample_size, c, policy=allocation)
    selected = sampling.stratified_priority_sample(
        key, batch.stratum, batch.valid, reservoirs, num_strata
    )
    y = jnp.minimum(c, jnp.maximum(reservoirs, 0.0))

    safe_n = jnp.maximum(reservoirs, 1.0)
    w_local = jnp.where(c > reservoirs, c / safe_n, 1.0)

    if async_calibration:
        # Eq. 9: calibrate by C^in / c — corrects the α bias when the
        # downstream node's interval straddles ours. C^in == 0 marks a
        # source stream (no downstream node): factor 1.
        calib = jnp.where(
            (batch.meta.count > 0.0) & (c > 0.0), batch.meta.count / jnp.maximum(c, 1.0), 1.0
        )
    else:
        calib = jnp.ones_like(c)

    w_out = batch.meta.weight * w_local * calib
    # Strata absent this interval keep their previous weight (§III-C: a node
    # maintains the most recent sets and only updates on arrival).
    w_out = jnp.where(c > 0.0, w_out, batch.meta.weight)
    c_out = jnp.where(c > 0.0, y, batch.meta.count)

    return SampleResult(
        selected=selected,
        meta=StratumMeta(weight=w_out, count=c_out),
        c=c,
        y=y,
        reservoir=reservoirs,
    )


def apply_sample(batch: IntervalBatch, result: SampleResult) -> IntervalBatch:
    """Forward step (Alg. 1 line 13): the upstream-bound interval batch.

    Sampled-out slots become invalid; values/strata stay in place (the
    fixed-capacity layout means "sending" is just masking — compaction is
    a host-side/transport concern, see ``core.tree``).
    """
    return IntervalBatch(
        value=batch.value,
        stratum=batch.stratum,
        valid=result.selected,
        meta=result.meta,
    )


def compact_sample(
    batch: IntervalBatch, result: SampleResult, out_capacity: int
) -> IntervalBatch:
    """Pack selected items into a smaller buffer of ``out_capacity`` slots.

    This is the bandwidth saving of the paper (Fig. 8): a node forwards
    ``Σ_i Y_i ≤ sample_size`` items upstream, not the whole interval.
    Deterministic gather via sort-by-(!selected) keeps everything static.
    """
    m = batch.capacity
    order = jnp.argsort(jnp.where(result.selected, 0, 1), stable=True)
    take = order[:out_capacity]
    n_sel = jnp.sum(result.selected.astype(jnp.int32))
    slot_valid = jnp.arange(out_capacity) < n_sel
    return IntervalBatch(
        value=batch.value[take],
        stratum=batch.stratum[take],
        valid=slot_valid,
        meta=result.meta,
    )

"""Straggler mitigation via ApproxIoT weight calibration (beyond-paper).

In synchronous data-parallel training the step waits for the slowest
shard. ApproxIoT's asynchronous-interval fix (Eq. 9) gives a principled
alternative: treat each DP shard as an edge node feeding the step (the
root query). If a shard misses the interval deadline, its examples simply
didn't arrive — ``c_i`` drops — and re-calibrating the weights of the
shards that DID arrive keeps the weighted loss an unbiased estimate of
the full-batch loss. The gradient is a linear query, so the same
correction applies to it.

Also provides the interval-deadline bookkeeping used by the train loop to
decide who "arrived" (deadline = multiple of the median shard latency).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class StragglerConfig:
    deadline_factor: float = 2.0   # × median shard latency
    min_quorum: float = 0.5        # refuse the step below this arrival rate


def calibrate_weights(weight: np.ndarray, present: np.ndarray) -> np.ndarray:
    """Eq. 9 applied to shard dropout.

    ``weight`` f32[B] — per-example ApproxIoT weights; ``present`` bool[B]
    — examples whose shard met the deadline. The surviving examples'
    weights are scaled by (Σ all w)/(Σ present w), so the weighted-loss
    estimator still targets the full-stream mean; absent examples get 0.
    """
    total = float(weight.sum())
    kept = float(weight[present].sum())
    if kept <= 0.0:
        return np.zeros_like(weight)
    alpha = kept / total                      # fraction that arrived
    out = np.where(present, weight / alpha, 0.0)
    return out.astype(weight.dtype)


class DeadlineTracker:
    """Rolling per-shard latency stats → who is a straggler this step."""

    def __init__(self, num_shards: int, cfg: StragglerConfig | None = None):
        self.cfg = cfg or StragglerConfig()
        self.lat = np.zeros((0, num_shards), np.float64)

    def observe(self, shard_latencies: np.ndarray) -> np.ndarray:
        """Record latencies; return bool[num_shards] present-mask."""
        self.lat = np.vstack([self.lat[-63:], shard_latencies[None]])
        med = float(np.median(self.lat))
        deadline = self.cfg.deadline_factor * med
        present = shard_latencies <= deadline
        if present.mean() < self.cfg.min_quorum:
            # degenerate interval — wait for everyone rather than bias hard
            present = np.ones_like(present)
        return present

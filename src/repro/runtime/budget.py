"""Adaptive budget controller — the paper's ``costFunction(budget)``
(Alg. 1 line 3) plus the "adaptive feedback mechanism" of §IV-B, which
the paper leaves as future work: we close the loop with a PI controller.

Two constraints, both expressible as a sample-size budget:
  * latency: keep measured interval processing time ≤ target,
  * accuracy: keep the root's relative ±2σ bound ≤ target (grow the
    sample when the error budget is violated).
The controller is per-node and uses only local measurements — no
cross-node coordination, preserving the paper's scalability property.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class BudgetConfig:
    min_size: int
    max_size: int
    target_latency_s: float | None = None
    target_rel_error: float | None = None   # relative ±2σ / estimate
    kp: float = 0.5
    ki: float = 0.1


class BudgetController:
    def __init__(self, cfg: BudgetConfig, initial_size: int):
        self.cfg = cfg
        self.size = float(initial_size)
        self._i_lat = 0.0
        self._i_err = 0.0
        # last observed inputs, surfaced by repro.obs.metrics
        self.last_latency_s: float | None = None
        self.last_rel_error: float | None = None

    def update(self, *, latency_s: float | None = None,
               rel_error: float | None = None) -> int:
        c = self.cfg
        if latency_s is not None:
            self.last_latency_s = float(latency_s)
        if rel_error is not None:
            self.last_rel_error = float(rel_error)
        scale = 0.0
        if c.target_latency_s is not None and latency_s is not None:
            # positive err → too slow → shrink the sample
            err = (latency_s - c.target_latency_s) / c.target_latency_s
            self._i_lat = max(min(self._i_lat + err, 5.0), -5.0)
            scale -= c.kp * err + c.ki * self._i_lat
        if c.target_rel_error is not None and rel_error is not None:
            # positive err → too inaccurate → grow the sample
            err = (rel_error - c.target_rel_error) / max(c.target_rel_error, 1e-9)
            self._i_err = max(min(self._i_err + err, 5.0), -5.0)
            scale += c.kp * err + c.ki * self._i_err
        self.size = self.size * (1.0 + max(min(scale, 1.0), -0.5))
        self.size = max(min(self.size, c.max_size), c.min_size)
        return int(self.size)


def level_error_shares(items_in, items_kept) -> list[float]:
    """Per-level share of the pipeline's sampling-induced variance.

    A sampling stage that keeps fraction ``f`` of its input inflates
    estimator variance by ~``(1-f)/f`` (the HT/SRS second-moment
    scaling), so a level's share of the end-to-end error is its
    normalized ``(1-f)/f``. Levels that forward everything (``f=1``)
    contribute 0; with no subsampling anywhere (or no traffic yet) the
    shares are uniform — there is nothing to attribute, so the arbiter
    degenerates to the legacy all-levels-together behaviour."""
    contrib = []
    for n_in, n_kept in zip(items_in, items_kept):
        n_in = max(float(n_in), 0.0)
        if n_in <= 0.0:
            contrib.append(0.0)
            continue
        f = min(max(float(n_kept) / n_in, 1e-9), 1.0)
        contrib.append((1.0 - f) / f)
    total = sum(contrib)
    if total <= 0.0:
        return [1.0 / max(len(contrib), 1)] * len(contrib)
    return [c / total for c in contrib]


class WorstTenantArbiter:
    """Fairness for N query tenants sharing one tree's error budget:
    **worst-tenant-first**. Each epoch the tenant with the largest
    measured relative error drives the shared ``BudgetController`` —
    the sample budget moves to satisfy the worst-off tenant, so no
    tenant can be starved by a neighbour whose queries are already
    comfortably inside the target (min-max fairness on the shared
    knob; the budget only shrinks when *every* tenant is under
    target). ``last_tenant`` records who drove each move for
    attribution/telemetry.

    Two feedback grains share the same fairness rule:

    * :meth:`update` — legacy single knob, every level moves together;
    * :meth:`update_levels` — per-level attribution: the worst tenant's
      error is split across tree levels by measured variance shares
      (:func:`level_error_shares`), and each level's own controller sees
      the error scaled by ``share x n_levels``. A level that dominates
      the tenant's error sees an amplified error and grows; a level that
      contributes nothing sees ~0 error (below target) and is free to
      shrink, releasing budget instead of riding along. The shares are
      self-correcting: shrinking a level lowers its keep-fraction, which
      raises its ``(1-f)/f`` share next epoch."""

    def __init__(self, cfg: BudgetConfig, initial_size: int):
        self.controller = BudgetController(cfg, initial_size)
        self.last_tenant: str | None = None
        self.last_shares: list[float] | None = None
        self._level_controllers: list[BudgetController] | None = None

    @property
    def size(self) -> float:
        return self.controller.size

    def update(self, tenant_rel_errors: dict) -> int:
        """``{tenant: measured relative ±2σ error}`` → new budget."""
        finite = {t: e for t, e in tenant_rel_errors.items()
                  if e == e and e != float("inf")}
        if not finite:
            return int(self.controller.size)
        worst = max(finite, key=lambda t: finite[t])
        self.last_tenant = worst
        return self.controller.update(rel_error=finite[worst])

    def update_levels(self, tenant_rel_errors: dict,
                      level_shares) -> list[int]:
        """``{tenant: rel error}`` + per-level variance shares → new
        per-level budgets (see class docstring). Lazily instantiates one
        ``BudgetController`` per level, seeded from the shared knob so
        the first per-level move continues where :meth:`update` left
        off."""
        n = len(level_shares)
        if (self._level_controllers is None
                or len(self._level_controllers) != n):
            self._level_controllers = [
                BudgetController(self.controller.cfg,
                                 int(self.controller.size))
                for _ in range(n)]
        finite = {t: e for t, e in tenant_rel_errors.items()
                  if e == e and e != float("inf")}
        if not finite:
            return [int(c.size) for c in self._level_controllers]
        worst = max(finite, key=lambda t: finite[t])
        self.last_tenant = worst
        self.last_shares = [float(s) for s in level_shares]
        return [ctl.update(rel_error=finite[worst] * float(s) * n)
                for ctl, s in zip(self._level_controllers, level_shares)]

    def update_from_windows(self, plan, windows) -> tuple[int, dict]:
        """One epoch's result rows → (new budget, per-tenant errors).

        Convenience over :func:`aggregate_tenant_rel_errors` +
        :meth:`update` — what both the local scan driver and the SPMD
        mesh driver call at each epoch boundary, so the closed loop
        behaves identically whether the error was attributed from a
        single tree's root or from the mesh's merged summaries."""
        per = aggregate_tenant_rel_errors(plan, windows)
        return self.update(per), per


def aggregate_tenant_rel_errors(plan, windows) -> dict[str, float]:
    """Aggregate per-tenant measured relative ±2σ errors over an epoch.

    ``windows`` are result rows carrying flat ``answers``/``bounds``
    vectors (``HostTree.results`` / ``CompiledPipeline.rows`` /
    ``CompiledSpmdPipeline.rows`` layout — the SPMD rows attribute from
    MERGED summaries, so the arbiter sees pod-wide per-tenant error).
    Per window the attribution rule is ``query.compiler.
    tenant_rel_errors`` (worst CLT bound per tenant); across the epoch
    each tenant reports the mean of its finite per-window errors."""
    import numpy as np

    from repro.query.compiler import tenant_rel_errors

    acc: dict[str, list] = {}
    for w in windows:
        if "answers" not in w:
            continue
        for t, r in tenant_rel_errors(plan, w["answers"],
                                      w["bounds"]).items():
            acc.setdefault(t, []).append(r)
    return {t: float(np.mean([r for r in rs if np.isfinite(r)] or [0.0]))
            for t, rs in acc.items()}

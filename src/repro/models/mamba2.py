"""Mamba2 (SSD) block — chunked parallel scan for training, O(1)-state
recurrence for decode. Follows the scalar-A-per-head SSD formulation
[Dao & Gu 2024], n_groups=1 (B/C shared across heads).

Chunked form (chunk length Q, log-decay l_t = Σ_{τ≤t} log a_τ per head):
    Y_intra = (C Bᵀ ∘ M) x̃            M_{tτ} = exp(l_t − l_τ), τ ≤ t
    Y_inter =  C · exp(l_t) · S_prev
    S_next  =  exp(l_Q)·S_prev + Σ_τ exp(l_Q − l_τ)·B_τ ⊗ x̃_τ
All decay algebra in fp32 log space; every contraction is an MXU matmul —
this is the TPU-native replacement for the CUDA selective-scan kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.meshctx import shard

Params = dict

CONV_WIDTH = 4
CHUNK = 128


def mamba2_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    d_inner = 2 * d
    n, p_dim = cfg.ssm_state, cfg.ssm_head_dim
    h = d_inner // p_dim
    conv_dim = d_inner + 2 * n
    ks = jax.random.split(key, 5)
    return {
        # fused in_proj → [z, x, B, C, dt]
        "w_in": jax.random.normal(ks[0], (d, 2 * d_inner + 2 * n + h), dtype) * d ** -0.5,
        "conv_w": jax.random.normal(ks[1], (CONV_WIDTH, conv_dim), dtype) * 0.3,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "w_out": jax.random.normal(ks[2], (d_inner, d), dtype) * d_inner ** -0.5,
    }


def _split_proj(cfg, proj):
    d_inner = 2 * cfg.d_model
    n = cfg.ssm_state
    h = d_inner // cfg.ssm_head_dim
    z, xbc_dt = jnp.split(proj, [d_inner], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_inner + 2 * n], axis=-1)
    return z, xbc, dt  # dt: [..., H]


def _causal_conv(xbc, conv_w, conv_b):
    """Depthwise causal conv, width 4, over [B, S, conv_dim]."""
    pads = jnp.pad(xbc, ((0, 0), (CONV_WIDTH - 1, 0), (0, 0)))
    out = sum(
        pads[:, i : i + xbc.shape[1], :] * conv_w[i][None, None, :]
        for i in range(CONV_WIDTH)
    )
    return jax.nn.silu(out + conv_b)


def mamba2_forward(p: Params, cfg, x: jnp.ndarray) -> jnp.ndarray:
    """Training path. x: [B, S, d] → [B, S, d]."""
    b, s, d = x.shape
    d_inner = 2 * d
    n, p_dim = cfg.ssm_state, cfg.ssm_head_dim
    h = d_inner // p_dim
    q = min(CHUNK, s)
    assert s % q == 0
    nc = s // q

    proj = x @ p["w_in"]
    z, xbc, dt = _split_proj(cfg, proj)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])        # [B,S,H]
    a = -jnp.exp(p["a_log"])                                           # [H] < 0
    log_decay = (dt * a).astype(jnp.float32)                           # [B,S,H] ≤ 0

    xh = xs.reshape(b, s, h, p_dim)
    xt = (xh.astype(jnp.float32) * dt[..., None]).astype(jnp.float32)  # dt·x
    bm = bmat.astype(jnp.float32).reshape(b, nc, q, n)
    cm = cmat.astype(jnp.float32).reshape(b, nc, q, n)
    xt = xt.reshape(b, nc, q, h, p_dim)
    ld = log_decay.reshape(b, nc, q, h)

    def chunk_step(state, inputs):
        bm_c, cm_c, xt_c, ld_c = inputs            # [B,Q,N],[B,Q,N],[B,Q,H,P],[B,Q,H]
        l = jnp.cumsum(ld_c, axis=1)               # inclusive  [B,Q,H]
        l_total = l[:, -1:, :]                     # [B,1,H]
        # intra-chunk: M_{tτ} = exp(l_t − l_τ) (τ ≤ t)
        scores = jnp.einsum("bqn,bkn->bqk", cm_c, bm_c)          # [B,Q,Q]
        gap = l[:, :, None, :] - l[:, None, :, :]                # [B,Q,Q,H]
        causal = jnp.tril(jnp.ones((q, q), bool))
        # mask the *argument* (exp(-1e30)=0): masking the result would
        # backprop 0·inf = NaN through the upper triangle.
        m = jnp.exp(jnp.where(causal[None, :, :, None], gap, -1e30))
        y_intra = jnp.einsum("bqk,bqkh,bkhp->bqhp", scores, m, xt_c)
        # inter-chunk from carried state [B,H,N,P]
        y_inter = jnp.einsum("bqn,bqh,bhnp->bqhp", cm_c, jnp.exp(l), state)
        # state update
        w_in = jnp.exp(l_total - l)                              # [B,Q,H]
        ds = jnp.einsum("bqn,bqh,bqhp->bhnp", bm_c, w_in, xt_c)
        state = jnp.exp(l_total[:, 0, :, None, None].transpose(0, 1, 2, 3)) * state + ds
        return state, y_intra + y_inter

    state0 = jnp.zeros((b, h, n, p_dim), jnp.float32)
    inputs = (
        bm.transpose(1, 0, 2, 3), cm.transpose(1, 0, 2, 3),
        xt.transpose(1, 0, 2, 3, 4), ld.transpose(1, 0, 2, 3),
    )
    _, ys = jax.lax.scan(chunk_step, state0, inputs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p_dim)
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, d_inner)

    # gated RMSNorm then out-proj
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"].astype(jnp.float32)
    return shard((y.astype(x.dtype)) @ p["w_out"], "batch", None, None)


def mamba2_init_state(cfg, batch: int, dtype=jnp.float32):
    d_inner = 2 * cfg.d_model
    n, p_dim = cfg.ssm_state, cfg.ssm_head_dim
    h = d_inner // p_dim
    conv_dim = d_inner + 2 * n
    return {
        "conv": jnp.zeros((batch, CONV_WIDTH - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, h, n, p_dim), jnp.float32),
    }


def mamba2_decode(p: Params, cfg, x: jnp.ndarray, state: dict):
    """One-token decode. x: [B, 1, d] → ([B, 1, d], new state)."""
    b = x.shape[0]
    d = cfg.d_model
    d_inner = 2 * d
    n, p_dim = cfg.ssm_state, cfg.ssm_head_dim
    h = d_inner // p_dim

    proj = x @ p["w_in"]
    z, xbc, dt = _split_proj(cfg, proj)
    window = jnp.concatenate([state["conv"], xbc], axis=1)     # [B, W, conv]
    conv_out = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    )[:, None, :]
    xs, bmat, cmat = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)

    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dtv * a)                                            # [B,H]
    xh = xs[:, 0].reshape(b, h, p_dim).astype(jnp.float32) * dtv[..., None]
    ssm = decay[..., None, None] * state["ssm"] + jnp.einsum(
        "bn,bhp->bhnp", bmat[:, 0].astype(jnp.float32), xh
    )
    y = jnp.einsum("bn,bhnp->bhp", cmat[:, 0].astype(jnp.float32), ssm)
    y = y + xs[:, 0].reshape(b, h, p_dim).astype(jnp.float32) * p["d_skip"][:, None]
    y = y.reshape(b, 1, d_inner)

    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"].astype(jnp.float32)
    out = y.astype(x.dtype) @ p["w_out"]
    return out, {"conv": window[:, 1:, :], "ssm": ssm}

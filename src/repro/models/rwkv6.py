"""RWKV-6 "Finch" block — data-dependent decay linear attention
[arXiv:2404.05892], chunked-parallel for training, O(1)-state decode.

Recurrence per head (state S ∈ R^{K×V}):
    y_t = r_t · (S_{t-1} + diag(u) · k_tᵀ v_t)
    S_t = diag(w_t) · S_{t-1} + k_tᵀ v_t
with per-channel decay  w_t = exp(−exp(w0 + tanh(x̃_t A) B))  (the LoRA
data-dependence that defines RWKV-6). Token shift uses static per-channel
mix coefficients (RWKV-5 style; the ddlerp refinement is orthogonal to the
scan structure — noted in DESIGN.md).

Chunked form with exclusive log-decay e_t = Σ_{τ<t} log w_τ:
    y_t = (r_t ⊙ exp(e_t))·S_0                        (inter)
        + Σ_{τ<t} [(r_t ⊙ exp(e_t))·(k_τ ⊙ exp(−e_{τ+1}))ᵀ] v_τ   (intra)
        + (r_t ⊙ u ⊙ k_t)·1 v_t                        (bonus diag)
    S_Q = exp(e_{Q+1})·S_0 + (k ⊙ exp(e_{Q+1} − e_next))ᵀ v
Everything is fp32 matmuls; exponents are clamped (decay ≤ 0 ⇒ the only
overflow risk is the factored exp(−e) term, bounded by the clamp).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.meshctx import shard

Params = dict

CHUNK = 64
LORA_R = 64
_CLAMP = 30.0  # exp argument clamp for the factored intra-chunk term


def rwkv6_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    hd = cfg.ssm_head_dim                      # head size (64)
    h = d // hd
    ks = jax.random.split(key, 12)
    s = d ** -0.5
    return {
        # time-mix: static token-shift coefficients per projection
        "mu_r": jnp.full((d,), 0.5, dtype), "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_v": jnp.full((d,), 0.5, dtype), "mu_g": jnp.full((d,), 0.5, dtype),
        "mu_w": jnp.full((d,), 0.5, dtype),
        "w_r": jax.random.normal(ks[0], (d, d), dtype) * s,
        "w_k": jax.random.normal(ks[1], (d, d), dtype) * s,
        "w_v": jax.random.normal(ks[2], (d, d), dtype) * s,
        "w_g": jax.random.normal(ks[3], (d, d), dtype) * s,
        "w_o": jax.random.normal(ks[4], (d, d), dtype) * s,
        # data-dependent decay LoRA: w0 + tanh(x A) B
        "w0": jnp.full((d,), -2.0, jnp.float32),
        "w_lora_a": jax.random.normal(ks[5], (d, LORA_R), dtype) * s,
        "w_lora_b": jax.random.normal(ks[6], (LORA_R, d), dtype) * LORA_R ** -0.5,
        "u_bonus": jax.random.normal(ks[7], (h, hd), jnp.float32) * 0.1,
        "ln_scale": jnp.ones((d,), dtype), "ln_bias": jnp.zeros((d,), dtype),
        # channel-mix
        "cm_mu": jnp.full((d,), 0.5, dtype),
        "cm_k": jax.random.normal(ks[8], (d, cfg.d_ff), dtype) * s,
        "cm_v": jax.random.normal(ks[9], (cfg.d_ff, d), dtype) * cfg.d_ff ** -0.5,
        "cm_r": jax.random.normal(ks[10], (d, d), dtype) * s,
    }


def _token_shift(x, last):
    """shifted_t = x_{t-1}; position 0 uses carried ``last``. [B,S,d]."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def _mix(x, x_shift, mu):
    return x + (x_shift - x) * mu  # lerp(x, x_prev, mu)


def rwkv6_time_mix(p: Params, cfg, x: jnp.ndarray, shift_last, state0):
    """x: [B,S,d]; state0: [B,H,K,V] fp32. Returns (y, shift_out, stateN)."""
    b, s, d = x.shape
    hd = cfg.ssm_head_dim
    h = d // hd
    q = min(CHUNK, s)
    assert s % q == 0
    nc = s // q

    xs = _token_shift(x, shift_last)
    r = _mix(x, xs, p["mu_r"]) @ p["w_r"]
    k = _mix(x, xs, p["mu_k"]) @ p["w_k"]
    v = _mix(x, xs, p["mu_v"]) @ p["w_v"]
    g = jax.nn.silu(_mix(x, xs, p["mu_g"]) @ p["w_g"])
    xw = _mix(x, xs, p["mu_w"])
    logw = -jnp.exp(
        p["w0"] + (jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]).astype(jnp.float32)
    )                                                            # [B,S,d] ≤ 0

    def heads(t):  # [B,S,d] → [B,nc,Q,H,hd] fp32
        return t.astype(jnp.float32).reshape(b, nc, q, h, hd)

    rh, kh, vh, lw = heads(r), heads(k), heads(v), logw.reshape(b, nc, q, h, hd)
    u = p["u_bonus"]                                             # [H,hd]

    def chunk_step(state, inp):
        r_c, k_c, v_c, lw_c = inp                  # [B,Q,H,K] etc (K=V=hd)
        # Heads shard over TP; the [B,H,K,V] chunk state (the dominant
        # saved activation of the chunked scan: nc per layer) stays
        # head-sharded too — rwkv6-7b train drops TP× of its footprint.
        r_c = shard(r_c, "batch", None, "model", None)
        k_c = shard(k_c, "batch", None, "model", None)
        v_c = shard(v_c, "batch", None, "model", None)
        lw_c = shard(lw_c, "batch", None, "model", None)
        state = shard(state, "batch", "model", None, None)
        e_inc = jnp.cumsum(lw_c, axis=1)           # inclusive Σ_{τ≤t}
        e_exc = e_inc - lw_c                       # exclusive Σ_{τ<t}
        e_tot = e_inc[:, -1:, :, :]                # [B,1,H,K]

        r_dec = r_c * jnp.exp(e_exc)                                   # [B,Q,H,K]
        k_dec = k_c * jnp.exp(jnp.clip(-e_inc, None, _CLAMP))          # [B,Q,H,K]
        att = jnp.einsum("bqhk,bthk->bhqt", r_dec, k_dec)              # [B,H,Q,Q]
        strict = jnp.tril(jnp.ones((q, q), bool), k=-1)
        att = jnp.where(strict[None, None], att, 0.0)
        y_intra = jnp.einsum("bhqt,bthv->bqhv", att, v_c)
        bonus = jnp.einsum("bqhk,bqhk->bqh", r_c * u[None, None], k_c)
        y_bonus = bonus[..., None] * v_c
        y_inter = jnp.einsum("bqhk,bhkv->bqhv", r_dec, state)
        # state to next chunk
        k_scaled = k_c * jnp.exp(jnp.clip(e_tot - e_inc, None, _CLAMP))
        ds = jnp.einsum("bqhk,bqhv->bhkv", k_scaled, v_c)
        state = jnp.exp(e_tot[:, 0])[..., None] * state + ds
        return state, y_intra + y_inter + y_bonus

    inputs = tuple(t.transpose(1, 0, 2, 3, 4) for t in (rh, kh, vh, lw))
    stateN, ys = jax.lax.scan(chunk_step, state0, inputs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, d)

    # per-head group norm, then gate + out-proj
    yg = y.reshape(b, s, h, hd)
    mu = yg.mean(-1, keepdims=True)
    var = jnp.var(yg, axis=-1, keepdims=True)
    yg = ((yg - mu) * jax.lax.rsqrt(var + 64e-5)).reshape(b, s, d)
    yg = yg * p["ln_scale"].astype(jnp.float32) + p["ln_bias"].astype(jnp.float32)
    out = (yg * g.astype(jnp.float32)).astype(x.dtype) @ p["w_o"]
    return shard(out, "batch", None, None), x[:, -1, :], stateN


def rwkv6_channel_mix(p: Params, cfg, x: jnp.ndarray, shift_last):
    xs = _token_shift(x, shift_last)
    xk = _mix(x, xs, p["cm_mu"])
    kk = jnp.square(jax.nn.relu(xk @ p["cm_k"]))
    kk = shard(kk, "batch", None, "model")
    r = jax.nn.sigmoid(x @ p["cm_r"])
    return shard(r * (kk @ p["cm_v"]), "batch", None, None), x[:, -1, :]


def rwkv6_init_state(cfg, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    hd = cfg.ssm_head_dim
    h = d // hd
    return {
        "tm_shift": jnp.zeros((batch, d), dtype),
        "cm_shift": jnp.zeros((batch, d), dtype),
        "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
    }


def rwkv6_decode(p: Params, cfg, x: jnp.ndarray, tm_shift: jnp.ndarray, wkv_state: jnp.ndarray):
    """One-token time-mix decode. x: [B,1,d] → (out, new_shift, new_wkv)."""
    b, _, d = x.shape
    hd = cfg.ssm_head_dim
    h = d // hd
    xs = tm_shift[:, None, :]
    r = _mix(x, xs, p["mu_r"]) @ p["w_r"]
    k = _mix(x, xs, p["mu_k"]) @ p["w_k"]
    v = _mix(x, xs, p["mu_v"]) @ p["w_v"]
    g = jax.nn.silu(_mix(x, xs, p["mu_g"]) @ p["w_g"])
    xw = _mix(x, xs, p["mu_w"])
    logw = -jnp.exp(
        p["w0"] + (jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]).astype(jnp.float32)
    )
    rh = r.astype(jnp.float32).reshape(b, h, hd)
    kh = k.astype(jnp.float32).reshape(b, h, hd)
    vh = v.astype(jnp.float32).reshape(b, h, hd)
    w = jnp.exp(logw.reshape(b, h, hd))
    kv = jnp.einsum("bhk,bhv->bhkv", kh, vh)
    y = jnp.einsum("bhk,bhkv->bhv", rh, wkv_state + p["u_bonus"][..., None] * kv)
    wkv = w[..., None] * wkv_state + kv

    yg = y.reshape(b, 1, h, hd)
    mu = yg.mean(-1, keepdims=True)
    var = jnp.var(yg, axis=-1, keepdims=True)
    yg = ((yg - mu) * jax.lax.rsqrt(var + 64e-5)).reshape(b, 1, d)
    yg = yg * p["ln_scale"].astype(jnp.float32) + p["ln_bias"].astype(jnp.float32)
    out = (yg * g.astype(jnp.float32)).astype(x.dtype) @ p["w_o"]
    return out, x[:, -1, :], wkv


def rwkv6_channel_mix_decode(p: Params, cfg, x: jnp.ndarray, shift_last):
    """One-token channel mix. x: [B,1,d] → (out, new_shift)."""
    xs = shift_last[:, None, :]
    xk = _mix(x, xs, p["cm_mu"])
    kk = jnp.square(jax.nn.relu(xk @ p["cm_k"]))
    r = jax.nn.sigmoid(x @ p["cm_r"])
    return r * (kk @ p["cm_v"]), x[:, -1, :]

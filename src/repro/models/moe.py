"""Mixture-of-Experts layer: top-k routing with capacity-based dispatch.

Expert parallelism: expert weight tensors carry a leading E dim sharded
over the "model"/"expert" mesh axis. Dispatch is sort-free: each token's
slot within its expert buffer is its running rank (cumsum over the one-hot
routing matrix); tokens beyond ``capacity = k·S/E·capacity_factor`` are
dropped (standard GShard/Switch semantics — the residual path carries
them). Compute is a grouped einsum ``[E,C,d]×[E,d,f]`` whose FLOPs equal
the *active* parameter count — this is what ``6·N_active·D`` in the
roofline refers to.

Covers Qwen2-MoE (60 routed top-4 + 4 shared experts fused into one dense
MLP of width 4·moe_d_ff) and Grok-1 (8 routed top-2, no shared).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.meshctx import shard
from repro.models import layers

Params = dict


def moe_init(key, cfg, dtype) -> Params:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "router": jax.random.normal(k1, (d, e), jnp.float32) * d ** -0.5,
        "w_gate": jax.random.normal(k2, (e, d, f), dtype) * d ** -0.5,
        "w_up": jax.random.normal(k3, (e, d, f), dtype) * d ** -0.5,
        "w_down": jax.random.normal(k4, (e, f, d), dtype) * f ** -0.5,
    }
    if cfg.num_shared_experts:
        p["shared"] = layers.swiglu_init(
            k5, d, cfg.num_shared_experts * f, dtype
        )
    return p


def moe_apply(p: Params, cfg, x: jnp.ndarray, *, capacity_factor: float = 1.25):
    """x: [B, S, d] → [B, S, d] plus aux load-balancing loss."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    t = b * s
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ p["router"])            # [T, E]
    gates_full = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ix = jax.lax.top_k(gates_full, k)        # [T, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Aux loss (Switch): E · Σ_e fraction_tokens_e · mean_gate_e
    me = gates_full.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[expert_ix.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    # -------- group-local dispatch -------------------------------------
    # Tokens are reshaped into G groups, G = number of batch shards in the
    # mesh, so the rank-cumsum, the scatter into expert buffers, and the
    # gather back are all SHARD-LOCAL: the [G, ...] leading dim carries the
    # data parallelism and XLA never materializes (or all-reduces) the
    # global token dim. Capacity is per group — exactly the per-shard
    # capacity real EP systems use. G=1 on a single device (smoke tests).
    from repro.launch.meshctx import current_mesh
    mesh = current_mesh()
    sizes = dict(mesh.shape) if mesh is not None else {}
    n_model = sizes.get("model", 1)
    n_batch = sizes.get("pod", 1) * sizes.get("data", 1)
    g = n_batch if t % max(n_batch, 1) == 0 else 1
    tg = t // g
    ep = e % n_model == 0  # expert-parallel vs ffn-TP layout (sharding.py)

    capacity = int(max(1, (k * tg / e) * capacity_factor))
    # slot = rank of this (token, choice) within its (group, expert).
    onehot = jax.nn.one_hot(expert_ix, e, dtype=jnp.int32)     # [T, k, E]
    oh_g = onehot.reshape(g, tg * k, e)
    ranks = jnp.cumsum(oh_g, axis=1) - oh_g                    # [G, Tg·k, E]
    slot = (ranks * oh_g).sum(-1).reshape(g, tg, k)            # [G, Tg, k]
    eix = expert_ix.reshape(g, tg, k)
    keep = slot < capacity

    xg = xt.reshape(g, tg, d)
    buf = jnp.zeros((g, e, capacity, d), x.dtype)
    safe_slot = jnp.where(keep, slot, capacity - 1)
    contrib = jnp.where(keep[..., None], xg[:, :, None, :], 0.0)
    gix = jnp.arange(g)[:, None, None]
    buf = buf.at[gix, eix, safe_slot].add(contrib.astype(x.dtype))
    buf = shard(buf, "batch", "expert" if ep else None, None, None)

    # Grouped expert FFN on the MXU: [G,E,C,d] @ [E,d,f]
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])) * jnp.einsum(
        "gecd,edf->gecf", buf, p["w_up"]
    )
    h = shard(h, "batch", "expert" if ep else None, None,
              None if ep else "model")
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["w_down"])     # [G, E, C, d]

    # Gather back with gate weighting (group-local).
    gathered = out_buf[gix, eix, safe_slot]                    # [G, Tg, k, d]
    y = jnp.sum(jnp.where(keep[..., None], gathered, 0.0)
                * gate_vals.reshape(g, tg, k)[..., None].astype(x.dtype),
                axis=2).reshape(t, d)

    if cfg.num_shared_experts:
        y = y + layers.swiglu(p["shared"], xt[None])[0]
    return shard(y.reshape(b, s, d), "batch", None, None), aux

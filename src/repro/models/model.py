"""Unified model API over all assigned architecture families.

    init_params(cfg, key)                     → params pytree
    forward(cfg, params, batch)               → (logits, aux_loss)
    loss_fn(cfg, params, batch)               → (loss, metrics)   [weighted]
    cache_specs / init_cache(cfg, B, S)       → decode-cache pytree
    decode_step(cfg, params, cache, tok, pos) → (logits, cache)

Layer stacks are ``lax.scan`` over stacked params (one compiled body per
family — small HLO, loop-hoisted FSDP collectives). ``cfg.remat`` wraps
the body in ``jax.checkpoint``. The ApproxIoT data plane enters through
``loss_fn``: per-example stratum weights from the hierarchical sampler
make the loss an unbiased *linear query* over the full stream (§DESIGN 3).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.launch.meshctx import shard
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models import rwkv6 as R6

Params = dict


# ------------------------------------------------------------------ utils --
def _stack_init(key, n, init_one):
    return jax.vmap(init_one)(jax.random.split(key, n))


def _tree_slice(tree, start: int, length: int):
    return jax.tree.map(lambda a: jax.lax.slice_in_dim(a, start, start + length, axis=0), tree)


def _norm(cfg):
    return L.NORM_APPLY[cfg.norm_type]


def _norm_init(cfg, d=None):
    return L.NORM_INIT[cfg.norm_type](d or cfg.d_model, cfg.param_dtype)


def _segments(cfg) -> list[int]:
    """zamba2: mamba-layer segment lengths between shared-attn applications."""
    k = cfg.attn_every
    full, rem = divmod(cfg.num_layers, k)
    return [k] * full + ([rem] if rem else [])


def _sinusoid(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    """[..., S] → [..., S, d] sinusoidal embedding (whisper stub pos-enc)."""
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (9.21034 / max(half - 1, 1)))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _maybe_remat(cfg, fn):
    if cfg.remat:
        return jax.checkpoint(fn, prevent_cse=False)
    return fn


# ------------------------------------------------------------------- init --
def init_params(cfg, key) -> Params:
    dt = cfg.param_dtype
    keys = jax.random.split(key, 8)
    p: Params = {"embed": L.embedding_init(keys[0], cfg.vocab_size, cfg.d_model, dt)}
    if not cfg.tie_embeddings:
        p["unembed"] = L.unembed_init(keys[1], cfg.d_model, cfg.vocab_size, dt)
    p["final_norm"] = _norm_init(cfg)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        def one(k):
            k1, k2 = jax.random.split(k)
            return {
                "ln1": _norm_init(cfg), "attn": L.attention_init(k1, cfg, dt),
                "ln2": _norm_init(cfg), "mlp": L.swiglu_init(k2, cfg.d_model, cfg.d_ff, dt),
            }
        p["layers"] = _stack_init(keys[2], cfg.num_layers, one)
    elif fam == "moe":
        def one(k):
            k1, k2 = jax.random.split(k)
            return {
                "ln1": _norm_init(cfg), "attn": L.attention_init(k1, cfg, dt),
                "ln2": _norm_init(cfg), "moe": MOE.moe_init(k2, cfg, dt),
            }
        p["layers"] = _stack_init(keys[2], cfg.num_layers, one)
    elif fam == "encdec":
        def enc_one(k):
            k1, k2 = jax.random.split(k)
            return {
                "ln1": _norm_init(cfg), "attn": L.attention_init(k1, cfg, dt),
                "ln2": _norm_init(cfg), "mlp": L.gelu_mlp_init(k2, cfg.d_model, cfg.d_ff, dt),
            }
        def dec_one(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {
                "ln1": _norm_init(cfg), "self_attn": L.attention_init(k1, cfg, dt),
                "ln_x": _norm_init(cfg), "cross_attn": L.attention_init(k2, cfg, dt),
                "ln2": _norm_init(cfg), "mlp": L.gelu_mlp_init(k3, cfg.d_model, cfg.d_ff, dt),
            }
        p["enc_layers"] = _stack_init(keys[2], cfg.encoder_layers, enc_one)
        p["enc_final_norm"] = _norm_init(cfg)
        p["layers"] = _stack_init(keys[3], cfg.num_layers, dec_one)
    elif fam == "hybrid":
        p["layers"] = _stack_init(keys[2], cfg.num_layers,
                                  lambda k: {"ln": _norm_init(cfg),
                                             "mamba": M2.mamba2_init(k, cfg, dt)})
        p["shared_attn"] = {"ln": _norm_init(cfg),
                            "attn": L.attention_init(keys[3], cfg, dt)}
    elif fam == "ssm":
        p["layers"] = _stack_init(keys[2], cfg.num_layers,
                                  lambda k: {"ln1": L.layernorm_init(cfg.d_model, dt),
                                             "tm_cm": R6.rwkv6_init(k, cfg, dt),
                                             "ln2": L.layernorm_init(cfg.d_model, dt)})
    else:
        raise ValueError(fam)
    return p


# ---------------------------------------------------------------- forward --
def _dense_stack(cfg, stacked, x, positions, *, causal=True, moe=False):
    norm = _norm(cfg)
    # Sequence-parallel residual (Megatron-SP): the stream between blocks is
    # sharded [batch, model(seq), -] so norms/adds are 1/TP the bytes, and
    # XLA lowers the TP boundary as all-gather + reduce-scatter (half the
    # bytes of the naive activation all-reduce). Also pins the saved scan
    # carry (remat boundary) to the sharded layout.
    sp = lambda t: shard(t, "batch", "model", None)

    def body(carry, lp):
        x, aux = carry
        h = norm(lp["ln1"], x)
        x = sp(x + L.attention(lp["attn"], cfg, h, positions, causal=causal,
                               attn_impl=cfg.attention_impl))
        h = norm(lp["ln2"], x)
        if moe:
            y, a = MOE.moe_apply(lp["moe"], cfg, h, capacity_factor=cfg.capacity_factor)
            return (sp(x + y), aux + a), None
        return (sp(x + L.swiglu(lp["mlp"], h)), aux), None

    x = sp(x)
    (x, aux), _ = jax.lax.scan(_maybe_remat(cfg, body), (x, jnp.float32(0.0)), stacked)
    return x, aux


def _encdec_encoder(cfg, params, frames):
    b, s_enc, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(s_enc)[None], (b, s_enc))
    x = frames + _sinusoid(pos, cfg.d_model).astype(frames.dtype)
    norm = _norm(cfg)

    def body(x, lp):
        h = norm(lp["ln1"], x)
        x = x + L.attention(lp["attn"], cfg, h, pos, causal=False,
                            attn_impl="xla")
        h = norm(lp["ln2"], x)
        return x + L.gelu_mlp(lp["mlp"], h), None

    x, _ = jax.lax.scan(_maybe_remat(cfg, body), x, params["enc_layers"])
    return norm(params["enc_final_norm"], x)


def _encdec_decoder(cfg, params, tokens, enc_out):
    b, s = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = L.embed(params["embed"], tokens)
    x = x + _sinusoid(pos, cfg.d_model).astype(x.dtype)
    norm = _norm(cfg)

    def body(x, lp):
        h = norm(lp["ln1"], x)
        x = x + L.attention(lp["self_attn"], cfg, h, pos, causal=True,
                            attn_impl=cfg.attention_impl)
        h = norm(lp["ln_x"], x)
        x = x + L.attention(lp["cross_attn"], cfg, h, pos, causal=False, kv_x=enc_out)
        h = norm(lp["ln2"], x)
        return x + L.gelu_mlp(lp["mlp"], h), None

    x, _ = jax.lax.scan(_maybe_remat(cfg, body), x, params["layers"])
    return x


def _hybrid_stack(cfg, params, x, positions):
    norm = _norm(cfg)

    def body(x, lp):
        h = norm(lp["ln"], x)
        return x + M2.mamba2_forward(lp["mamba"], cfg, h), None

    body = _maybe_remat(cfg, body)
    off = 0
    for i, seg in enumerate(_segments(cfg)):
        x, _ = jax.lax.scan(body, x, _tree_slice(params["layers"], off, seg))
        off += seg
        if i < len(_segments(cfg)) - 1 or off == cfg.num_layers:
            sa = params["shared_attn"]
            h = norm(sa["ln"], x)
            x = x + L.attention(sa["attn"], cfg, h, positions, causal=True,
                                attn_impl=cfg.attention_impl)
    return x


def _ssm_stack(cfg, params, x):
    b = x.shape[0]
    d = cfg.d_model
    h = d // cfg.ssm_head_dim
    zero_shift = jnp.zeros((b, d), x.dtype)
    zero_state = jnp.zeros((b, h, cfg.ssm_head_dim, cfg.ssm_head_dim), jnp.float32)

    sp = lambda t: shard(t, "batch", "model", None)   # SP residual (see _dense_stack)

    def body(x, lp):
        hh = L.layernorm(lp["ln1"], x)
        y, _, _ = R6.rwkv6_time_mix(lp["tm_cm"], cfg, hh, zero_shift, zero_state)
        x = sp(x + y)
        hh = L.layernorm(lp["ln2"], x)
        y, _ = R6.rwkv6_channel_mix(lp["tm_cm"], cfg, hh, zero_shift)
        return sp(x + y), None

    x, _ = jax.lax.scan(_maybe_remat(cfg, body), sp(x), params["layers"])
    return x


def forward(cfg, params: Params, batch: dict) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. Returns (logits [B,S,V], aux_loss)."""
    fam = cfg.family
    aux = jnp.float32(0.0)
    if fam == "encdec":
        enc_out = _encdec_encoder(cfg, params, batch["frames"])
        x = _encdec_decoder(cfg, params, batch["tokens"], enc_out)
    else:
        tokens = batch["tokens"]
        x = L.embed(params["embed"], tokens)
        if fam == "vlm":
            x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        if fam in ("dense", "vlm"):
            x, aux = _dense_stack(cfg, params["layers"], x, positions)
        elif fam == "moe":
            x, aux = _dense_stack(cfg, params["layers"], x, positions, moe=True)
        elif fam == "hybrid":
            x = _hybrid_stack(cfg, params, x, positions)
        elif fam == "ssm":
            x = _ssm_stack(cfg, params, x)
        else:
            raise ValueError(fam)

    x = _norm(cfg)(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T
    else:
        logits = L.unembed(params["unembed"], x)
    return logits, aux


def loss_fn(cfg, params: Params, batch: dict) -> tuple[jnp.ndarray, dict]:
    """ApproxIoT-weighted causal LM loss (unbiased full-stream estimate)."""
    logits, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    if cfg.family == "vlm":  # patch positions carry no labels
        pad = jnp.full((labels.shape[0], cfg.num_patches), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    mask = (labels >= 0).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    per_tok = -ll * mask
    per_ex = per_tok.sum(-1) / jnp.maximum(mask.sum(-1), 1.0)          # [B]

    w = batch.get("weight")
    if w is None:
        w = jnp.ones_like(per_ex)
    loss = jnp.sum(w * per_ex) / jnp.maximum(jnp.sum(w), 1e-9)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux_loss": aux,
                   "tokens": mask.sum(), "weight_sum": jnp.sum(w)}


def build_encdec_cache(cfg, params: Params, frames: jnp.ndarray, seq: int):
    """Serving helper: run the encoder and precompute per-decoder-layer
    cross-attention K/V into a fresh decode cache. ``frames`` [B,S_enc,d]
    must have S_enc == seq (the cache's cross length)."""
    b = frames.shape[0]
    enc_out = _encdec_encoder(cfg, params, frames)
    hkv, hd = cfg.num_kv_heads, cfg.head_dim

    def one(lp):
        k = _split(enc_out @ lp["cross_attn"]["wk"], hkv, hd)
        v = _split(enc_out @ lp["cross_attn"]["wv"], hkv, hd)
        return k, v

    _split = lambda x, h, d: x.reshape(b, -1, h, d).transpose(0, 2, 1, 3)
    ks, vs = jax.lax.map(one, params["layers"])
    cache = init_cache(cfg, b, seq)
    cache["k_cross"] = ks.astype(cache["k_cross"].dtype)
    cache["v_cross"] = vs.astype(cache["v_cross"].dtype)
    return cache


# ----------------------------------------------------------------- decode --
def cache_specs(cfg, batch: int, seq: int):
    """ShapeDtypeStruct pytree of the decode cache (zero allocation)."""
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        jax.eval_shape(lambda: init_cache(cfg, batch, seq)))


def init_cache(cfg, batch: int, seq: int):
    dt = cfg.param_dtype
    hkv, hd, lnum = cfg.num_kv_heads, cfg.head_dim, cfg.num_layers
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        return {"k": jnp.zeros((lnum, batch, hkv, seq, hd), dt),
                "v": jnp.zeros((lnum, batch, hkv, seq, hd), dt)}
    if fam == "encdec":
        return {"k": jnp.zeros((lnum, batch, hkv, seq, hd), dt),
                "v": jnp.zeros((lnum, batch, hkv, seq, hd), dt),
                "k_cross": jnp.zeros((lnum, batch, hkv, seq, hd), dt),
                "v_cross": jnp.zeros((lnum, batch, hkv, seq, hd), dt)}
    if fam == "hybrid":
        d_inner = 2 * cfg.d_model
        n = cfg.ssm_state
        h = d_inner // cfg.ssm_head_dim
        n_attn = len(_segments(cfg))
        return {
            "conv": jnp.zeros((lnum, batch, M2.CONV_WIDTH - 1, d_inner + 2 * n), dt),
            "ssm": jnp.zeros((lnum, batch, h, n, cfg.ssm_head_dim), jnp.float32),
            "attn_k": jnp.zeros((n_attn, batch, hkv, seq, hd), dt),
            "attn_v": jnp.zeros((n_attn, batch, hkv, seq, hd), dt),
        }
    if fam == "ssm":
        d = cfg.d_model
        h = d // cfg.ssm_head_dim
        k = cfg.ssm_head_dim
        return {"tm_shift": jnp.zeros((lnum, batch, d), dt),
                "cm_shift": jnp.zeros((lnum, batch, d), dt),
                "wkv": jnp.zeros((lnum, batch, h, k, k), jnp.float32)}
    raise ValueError(fam)


def decode_step(cfg, params: Params, cache, token: jnp.ndarray, pos: jnp.ndarray):
    """One-token decode. token: [B,1] i32 → (logits [B,V], new cache)."""
    fam = cfg.family
    x = L.embed(params["embed"], token)          # [B,1,d]
    norm = _norm(cfg)

    if fam in ("dense", "vlm", "moe"):
        def body(x, inp):
            lp, kc, vc = inp
            h = norm(lp["ln1"], x)
            a, kc, vc = L.attention_decode(lp["attn"] if "attn" in lp else lp, cfg, h, kc, vc, pos)
            x = x + a
            h = norm(lp["ln2"], x)
            if fam == "moe":
                y, _ = MOE.moe_apply(lp["moe"], cfg, h, capacity_factor=cfg.capacity_factor)
                x = x + y
            else:
                x = x + L.swiglu(lp["mlp"], h)
            return x, (kc, vc)

        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
        cache = {"k": ks, "v": vs}

    elif fam == "encdec":
        x = x + _sinusoid(jnp.full((token.shape[0], 1), pos), cfg.d_model).astype(x.dtype)

        def body(x, inp):
            lp, kc, vc, kx, vx = inp
            h = norm(lp["ln1"], x)
            a, kc, vc = L.attention_decode(lp["self_attn"], cfg, h, kc, vc, pos)
            x = x + a
            h = norm(lp["ln_x"], x)
            a, _, _ = L.attention_decode(lp["cross_attn"], cfg, h, kx, vx, pos,
                                         update_cache=False, cross=True)
            x = x + a
            h = norm(lp["ln2"], x)
            return x + L.gelu_mlp(lp["mlp"], h), (kc, vc)

        x, (ks, vs) = jax.lax.scan(
            body, x,
            (params["layers"], cache["k"], cache["v"], cache["k_cross"], cache["v_cross"]))
        cache = {"k": ks, "v": vs, "k_cross": cache["k_cross"], "v_cross": cache["v_cross"]}

    elif fam == "hybrid":
        def body(x, inp):
            lp, conv, ssm = inp
            h = norm(lp["ln"], x)
            y, st = M2.mamba2_decode(lp["mamba"], cfg, h, {"conv": conv, "ssm": ssm})
            return x + y, (st["conv"], st["ssm"])

        segs = _segments(cfg)
        off = 0
        convs, ssms, aks, avs = [], [], [], []
        for i, seg in enumerate(segs):
            sl = lambda t: jax.lax.slice_in_dim(t, off, off + seg, axis=0)
            x, (cv, sm) = jax.lax.scan(
                body, x, (_tree_slice(params["layers"], off, seg),
                          sl(cache["conv"]), sl(cache["ssm"])))
            convs.append(cv); ssms.append(sm)
            off += seg
            if i < len(segs) - 1 or off == cfg.num_layers:
                sa = params["shared_attn"]
                h = norm(sa["ln"], x)
                a, ak, av = L.attention_decode(
                    sa["attn"], cfg, h, cache["attn_k"][i], cache["attn_v"][i], pos)
                x = x + a
                aks.append(ak); avs.append(av)
        cache = {"conv": jnp.concatenate(convs, 0), "ssm": jnp.concatenate(ssms, 0),
                 "attn_k": jnp.stack(aks, 0), "attn_v": jnp.stack(avs, 0)}

    elif fam == "ssm":
        def body(x, inp):
            lp, tm_s, cm_s, wkv = inp
            h = L.layernorm(lp["ln1"], x)
            y, tm_s, wkv = R6.rwkv6_decode(lp["tm_cm"], cfg, h, tm_s, wkv)
            x = x + y
            h = L.layernorm(lp["ln2"], x)
            y, cm_s = R6.rwkv6_channel_mix_decode(lp["tm_cm"], cfg, h, cm_s)
            return x + y, (tm_s, cm_s, wkv)

        x, (tms, cms, wkvs) = jax.lax.scan(
            body, x, (params["layers"], cache["tm_shift"], cache["cm_shift"], cache["wkv"]))
        cache = {"tm_shift": tms, "cm_shift": cms, "wkv": wkvs}
    else:
        raise ValueError(fam)

    x = norm(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T
    else:
        logits = L.unembed(params["unembed"], x)
    return logits[:, 0, :], cache

"""Functional layer library (no framework deps): norms, RoPE, GQA
attention (full-sequence train path + single-token decode path), MLPs,
embeddings. Params are plain nested dicts of jnp arrays; every function is
pure. Activation shardings use logical axes via ``launch.meshctx.shard``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.launch.meshctx import shard

Params = dict


# ----------------------------------------------------------------- norms --
def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)


def nonparametric_ln(_: Params, x: jnp.ndarray) -> jnp.ndarray:
    """OLMo: LayerNorm without learnable scale/bias [arXiv:2402.00838]."""
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + 1e-5)).astype(x.dtype)


NORM_INIT = {"rmsnorm": rmsnorm_init, "layernorm": layernorm_init,
             "nonparametric_ln": lambda d, dt: {}}
NORM_APPLY = {"rmsnorm": rmsnorm, "layernorm": layernorm,
              "nonparametric_ln": nonparametric_ln}


# ------------------------------------------------------------------ rope --
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                          # [D/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------- attention --
def attention_init(key, cfg, dtype) -> Params:
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    s = d ** -0.5
    p = {
        "wq": jax.random.normal(k1, (d, h * hd), dtype) * s,
        "wk": jax.random.normal(k2, (d, hkv * hd), dtype) * s,
        "wv": jax.random.normal(k3, (d, hkv * hd), dtype) * s,
        "wo": jax.random.normal(k4, (h * hd, d), dtype) * (h * hd) ** -0.5,
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def _split_heads(x, n_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim).transpose(0, 2, 1, 3)


def attention(
    p: Params,
    cfg,
    x: jnp.ndarray,                     # [B, S, d]
    positions: jnp.ndarray,             # [B, S]
    *,
    causal: bool = True,
    kv_x: jnp.ndarray | None = None,    # cross-attention source
    attn_impl: str = "xla",
) -> jnp.ndarray:
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    src = x if kv_x is None else kv_x
    q = _split_heads(x @ p["wq"], h, hd)
    k = _split_heads(src @ p["wk"], hkv, hd)
    v = _split_heads(src @ p["wv"], hkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if kv_x is None and cfg.rope_theta > 0:
        q = apply_rope(q, positions[:, None, :], cfg.rope_theta)
        k = apply_rope(k, positions[:, None, :], cfg.rope_theta)

    if attn_impl == "pallas" and causal and kv_x is None:
        from repro.kernels.flash_attention.ops import attention as flash
        o = flash(q, k, v, causal=True, impl="pallas")
        b, _, s, _ = o.shape
        o = o.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    else:
        # TP strategy is mesh-aware:
        #   * kv heads divide the model axis → GQA-native grouped einsum,
        #     heads sharded, no head-repeated K/V materialization;
        #   * otherwise → head-repeated layout with (unevenly padded) head
        #     sharding — XLA's partial head sharding beats both full
        #     replication and context-parallel resharding here (measured:
        #     CP forces partial-contract projections + full-size
        #     all-reduces; see EXPERIMENTS.md §Perf iteration A2).
        # Both paths keep operands in bf16 with f32 accumulation.
        from repro.launch.meshctx import current_mesh
        mesh = current_mesh()
        n_model = dict(mesh.shape).get("model", 1) if mesh is not None else 1
        group = h // hkv
        b, _, sq_len, _ = q.shape
        if hkv % max(n_model, 1) == 0:
            qg = q.reshape(b, hkv, group, sq_len, hd)
            qg = shard(qg, "batch", "model", None, None, None)
            k = shard(k, "batch", "model", None, None)
            v = shard(v, "batch", "model", None, None)
            logits = jnp.einsum("bkgqd,bkld->bkgql", qg, k,
                                preferred_element_type=jnp.float32) / (hd ** 0.5)
            if causal:
                sq, sk = logits.shape[-2], logits.shape[-1]
                mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
                logits = jnp.where(mask, logits, -1e30)
            probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
            o = jnp.einsum("bkgql,bkld->bkgqd", probs, v,
                           preferred_element_type=jnp.float32).astype(x.dtype)
            o = o.transpose(0, 3, 1, 2, 4).reshape(b, sq_len, h * hd)
        else:
            kx = jnp.repeat(k, group, axis=1)
            vx = jnp.repeat(v, group, axis=1)
            q = shard(q, "batch", "model", None, None)
            kx = shard(kx, "batch", "model", None, None)
            vx = shard(vx, "batch", "model", None, None)
            logits = jnp.einsum("bhqd,bhkd->bhqk", q, kx,
                                preferred_element_type=jnp.float32) / (hd ** 0.5)
            if causal:
                sq, sk = logits.shape[-2], logits.shape[-1]
                mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
                logits = jnp.where(mask, logits, -1e30)
            probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
            o = jnp.einsum("bhqk,bhkd->bhqd", probs, vx,
                           preferred_element_type=jnp.float32).astype(x.dtype)
            o = o.transpose(0, 2, 1, 3).reshape(b, sq_len, h * hd)
    return o @ p["wo"]   # see swiglu: block-boundary SP constraint → RS


def attention_decode(
    p: Params,
    cfg,
    x: jnp.ndarray,           # [B, 1, d]
    k_cache: jnp.ndarray,     # [B, Hkv, S, hd]
    v_cache: jnp.ndarray,     # [B, Hkv, S, hd]
    pos: jnp.ndarray,         # scalar: index of the new token
    *,
    update_cache: bool = True,
    cross: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode against a KV cache; returns (out, k_cache, v_cache)."""
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    b = x.shape[0]
    q = _split_heads(x @ p["wq"], h, hd)                   # [B, H, 1, hd]
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
    if not cross and cfg.rope_theta > 0:
        q = apply_rope(q, jnp.full((b, 1, 1), pos, jnp.int32)[:, 0, :][:, None, :], cfg.rope_theta)

    if update_cache and not cross:
        k_new = _split_heads(x @ p["wk"], hkv, hd)         # [B, Hkv, 1, hd]
        v_new = _split_heads(x @ p["wv"], hkv, hd)
        if cfg.qk_norm:
            k_new = rmsnorm(p["k_norm"], k_new)
        if cfg.rope_theta > 0:
            k_new = apply_rope(k_new, jnp.full((b, 1, 1), pos, jnp.int32)[:, 0, :][:, None, :],
                               cfg.rope_theta)
        k_cache = jax.lax.dynamic_update_slice(k_cache, k_new.astype(k_cache.dtype),
                                               (0, 0, pos, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v_new.astype(v_cache.dtype),
                                               (0, 0, pos, 0))

    # GQA-native grouped attention: never materialize the head-repeated
    # cache (group× bytes) and read K/V in their storage dtype with f32
    # accumulation (the MXU accumulates f32 natively — casting operands
    # up-front would double the HBM read).
    group = h // hkv
    s_cache = k_cache.shape[2]
    qg = q.reshape(b, hkv, group, hd)                      # [B, Hkv, G, hd]
    logits = jnp.einsum("bkgd,bksd->bkgs", qg, k_cache,
                        preferred_element_type=jnp.float32) / (hd ** 0.5)
    valid = jnp.arange(s_cache) <= pos if not cross else jnp.ones((s_cache,), bool)
    logits = jnp.where(valid[None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", probs.astype(k_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    o = o.reshape(b, 1, h * hd)
    return o @ p["wo"], k_cache, v_cache


# ------------------------------------------------------------------ mlps --
def swiglu_init(key, d: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": jax.random.normal(k1, (d, d_ff), dtype) * d ** -0.5,
        "w_up": jax.random.normal(k2, (d, d_ff), dtype) * d ** -0.5,
        "w_down": jax.random.normal(k3, (d_ff, d), dtype) * d_ff ** -0.5,
    }


def swiglu(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = shard(h, "batch", None, "model")
    # No output constraint: the sequence-parallel residual constraint at
    # the block boundary turns the TP partial-sum into a reduce-scatter
    # (half the bytes of the all-reduce a replicated constraint forces).
    return h @ p["w_down"]


def gelu_mlp_init(key, d: int, d_ff: int, dtype) -> Params:
    k1, k2 = jax.random.split(key, 2)
    return {
        "w_up": jax.random.normal(k1, (d, d_ff), dtype) * d ** -0.5,
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": jax.random.normal(k2, (d_ff, d), dtype) * d_ff ** -0.5,
        "b_down": jnp.zeros((d,), dtype),
    }


def gelu_mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.gelu(x @ p["w_up"] + p["b_up"])
    h = shard(h, "batch", None, "model")
    return h @ p["w_down"] + p["b_down"]   # see swiglu: SP boundary → RS


# ------------------------------------------------------------ embeddings --
def embedding_init(key, vocab: int, d: int, dtype) -> Params:
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(p: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return shard(p["table"][tokens], "batch", None, None)


def unembed_init(key, d: int, vocab: int, dtype) -> Params:
    return {"w": jax.random.normal(key, (d, vocab), dtype) * d ** -0.5}


def unembed(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return shard(x @ p["w"], "batch", None, "model")

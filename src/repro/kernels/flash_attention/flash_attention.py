"""Pallas TPU kernel: causal GQA flash attention (online softmax).

Tiling: grid = (B·Hq, S/bq, S/bk); the kv loop is the innermost grid axis
so the (m, l, acc) running state lives in VMEM scratch across steps.
Block sizes default to 128×128 — MXU-aligned on both matmul dims — with
the full head_dim kept resident (≤128 for every assigned arch). VMEM
footprint per step ≈ (bq + 2·bk)·D·2B + bq·bk·4B ≈ 160 KiB ≪ 16 MiB, so
the compiler can double-buffer the k/v streams.

Causal blocks strictly above the diagonal are skipped with ``pl.when``
(predicated-off, no MXU issue), halving compute vs. a masked dense pass.
GQA is handled in the BlockSpec index map: the kv block fetched for
q-head ``h`` is head ``h // (Hq/Hkv)`` — no ``jnp.repeat`` materialization.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, scale, block_q, block_k):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(kj <= qi)  # causal: skip blocks entirely above the diagonal
    def _step():
        q = q_ref[0, :, :]                       # [bq, D]
        k = k_ref[0, :, :]                       # [bk, D]
        v = v_ref[0, :, :]                       # [bk, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                 # [bq, bk]

        # Diagonal block: apply the triangular mask in-register.
        rows = jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        s = jnp.where((kj < qi) | (rows >= cols), s, _NEG_INF)

        m_prev = m_scr[...]                      # [bq, 1]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)                   # [bq, bk]
        alpha = jnp.exp(m_prev - m_cur)          # [bq, 1]
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_cur

    @pl.when(kj == nk - 1)
    def _finalize():
        o_ref[0, :, :] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "interpret"))
def flash_attention(
    q: jnp.ndarray,  # [B, Hq, S, D]
    k: jnp.ndarray,  # [B, Hkv, S, D]
    v: jnp.ndarray,  # [B, Hkv, S, D]
    *,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = 1.0 / (d ** 0.5)
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, "seq must tile evenly"

    qf = q.reshape(b * hq, s, d)
    kf = k.reshape(b * hkv, s, d)
    vf = v.reshape(b * hkv, s, d)

    # bh = b_ix·Hq + h_ix  →  kv row = b_ix·Hkv + h_ix // group
    def kv_index(bh, qi, kj):
        return ((bh // hq) * hkv + (bh % hq) // group, kj, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_q=block_q, block_k=block_k),
        grid=(b * hq, s // block_q, s // block_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, s, d)

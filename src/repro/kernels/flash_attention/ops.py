"""Public op: attention with kernel/oracle/XLA dispatch.

``impl``:
    "xla"    — einsum reference path (default for dry-run lowering: XLA's
               cost model counts its FLOPs, Pallas custom-calls are opaque
               to ``cost_analysis``; the roofline harness adds kernel FLOPs
               analytically when the pallas path is selected).
    "pallas" — the flash kernel (interpret=True off-TPU).
    "ref"    — alias of "xla".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.flash_attention import flash_attention as _flash


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "impl"))
def attention(q, k, v, *, causal: bool = True, impl: str = "xla"):
    if impl == "pallas":
        assert causal, "flash kernel is causal-only"
        return _flash(q, k, v, interpret=not _on_tpu())
    return ref.attention(q, k, v, causal=causal)

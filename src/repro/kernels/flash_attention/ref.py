"""Pure-jnp oracle: causal GQA attention (materializes the S×S matrix)."""
from __future__ import annotations

import jax.numpy as jnp


def attention(
    q: jnp.ndarray,  # [B, Hq, S, D]
    k: jnp.ndarray,  # [B, Hkv, S, D]
    v: jnp.ndarray,  # [B, Hkv, S, D]
    *,
    causal: bool = True,
) -> jnp.ndarray:
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kx).astype(jnp.float32) / jnp.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask, logits, -jnp.inf)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(q.dtype), vx)

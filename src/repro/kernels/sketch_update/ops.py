"""Public ops: sketch-update passes with kernel/oracle dispatch.

Same boundary contract as ``kernels/stratified_stats``: on TPU the Pallas
kernels run compiled; elsewhere ``impl="pallas"`` runs them in interpret
mode (bit-accurate kernel-body semantics on CPU) and the default resolves
to the jnp oracle for speed — the query plane evaluates these inside a
``lax.scan`` epoch, where interpret-mode Pallas would dominate the tick.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.sketch_update import ref
from repro.kernels.sketch_update import sketch_update as _pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("depth", "width", "impl"))
def cms_update(
    keys: jnp.ndarray,
    weights: jnp.ndarray,
    depth: int,
    width: int,
    impl: str = "auto",
) -> jnp.ndarray:
    """Weighted count-min increments. impl ∈ {auto, pallas, ref}."""
    if impl == "pallas" or (impl == "auto" and _on_tpu()):
        return _pallas.cms_update(keys, weights, depth, width,
                                  interpret=not _on_tpu())
    return ref.cms_update(keys, weights, depth, width)


@functools.partial(jax.jit, static_argnames=("impl",))
def quantile_compact(
    values: jnp.ndarray,
    cumw_prev: jnp.ndarray,
    cumw: jnp.ndarray,
    targets: jnp.ndarray,
    impl: str = "auto",
) -> jnp.ndarray:
    """Equi-weight rank-target extraction. impl ∈ {auto, pallas, ref}."""
    if impl == "pallas" or (impl == "auto" and _on_tpu()):
        return _pallas.quantile_compact(values, cumw_prev, cumw, targets,
                                        interpret=not _on_tpu())
    return ref.quantile_compact(values, cumw_prev, cumw, targets)

"""Pure-jnp oracles for the sketch-update kernels — the exact math the
Pallas kernel bodies implement, used for bit-checking and as the fast
path inside host-traced programs (interpret-mode Pallas inside a long
``lax.scan`` is CPU-hostile; the oracle lowers to plain XLA)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.sketch_update.sketch_update import HASH_MULTIPLIERS


def hash_buckets(keys: jnp.ndarray, depth: int, width: int) -> jnp.ndarray:
    """i32[depth, M] multiply-shift buckets — the kernels' hash, verbatim."""
    shift = jnp.uint32(32 - (width - 1).bit_length())
    mult = jnp.asarray(HASH_MULTIPLIERS[:depth], jnp.uint32)
    return jax.lax.shift_right_logical(
        keys[None, :].astype(jnp.uint32) * mult[:, None], shift
    ).astype(jnp.int32)


def cms_update(keys: jnp.ndarray, weights: jnp.ndarray, depth: int,
               width: int) -> jnp.ndarray:
    """f32[depth, width] weighted bucket increments (scatter-add form)."""
    buckets = hash_buckets(keys, depth, width)
    rows = jnp.arange(depth, dtype=jnp.int32)[:, None]
    flat = (rows * width + buckets).reshape(-1)
    return jnp.zeros((depth * width,), jnp.float32).at[flat].add(
        jnp.broadcast_to(weights, buckets.shape).reshape(-1)
    ).reshape(depth, width)


def quantile_compact(values: jnp.ndarray, cumw_prev: jnp.ndarray,
                     cumw: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """f32[C]: interval-membership gather (same hit rule as the kernel).

    A target in [cumw_prev_i, cumw_i) picks slot i; a target at or past
    the total weight picks nothing and returns 0.
    """
    hit = (cumw_prev[:, None] <= targets[None, :]) & \
          (targets[None, :] < cumw[:, None])
    return jnp.sum(jnp.where(hit, values[:, None], 0.0), axis=0)

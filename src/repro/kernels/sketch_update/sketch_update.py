"""Pallas TPU kernels: sketch update passes for the continuous query plane.

Two kernels, one per mergeable sketch in ``repro.query.sketches``:

``cms_update`` — count-min accumulation. Each VMEM tile of (key, weight)
pairs hashes its keys once per depth row (multiply-shift over uint32) and
hits the MXU with a one-hot bucket matrix instead of a scatter per item
(gathers/scatters are VPU-serial on TPU, one-hot matmuls are not):

    counts[d, :] += weightᵀ @ one_hot(h_d(key))          f32[1,B]@[B,W]

``quantile_compact`` — the compaction gather of the KLL-style quantile
compactor. Stage 1 (XLA: sort + cumsum) produces value-sorted summary
slots with exclusive/inclusive cumulative weights; this kernel streams
the slots once and extracts, for each of the ``C`` equi-weight rank
targets, the value of the slot whose weight interval covers it:

    picked[k] = Σ_i value_i · 1[cumw_prev_i ≤ t_k < cumw_i]

— a [B, C] interval-membership matrix contracted against the value tile
on the MXU. Intervals partition [0, W) exactly (cumw_prev is the shifted
cumsum, not ``cumw − w``, so f32 rounding cannot double- or zero-assign
a target); zero-weight slots have empty intervals and capture nothing.

The grid walks item tiles sequentially (TPU grid order), accumulating
into the same output block — the standard revisiting-output reduction
pattern, as in ``kernels/stratified_stats``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BLOCK_ITEMS = 4096
# f32 elements of in-kernel one-hot tile the cms kernel may materialize
# per grid step (~4 MiB) — well under a TPU core's ~16 MiB VMEM once the
# item tiles and the [depth, width] accumulator are co-resident.
_ONEHOT_BUDGET_ELEMS = 1 << 20

# Odd multiply-shift constants (xxhash/Murmur finalization primes plus
# golden-ratio mixes): h_d(x) = (A[d]·x mod 2³²) >> (32 − log₂ width).
HASH_MULTIPLIERS = (0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F,
                    0x165667B1, 0xD3A2646D)


def _cms_kernel(keys_ref, w_ref, mult_ref, out_ref, *, depth: int,
                width: int, shift: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    k = keys_ref[0, :]                                     # u32[B]
    w = w_ref[0, :]                                        # f32[B]
    b = k.shape[0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (b, width), 1)
    for d in range(depth):                                 # static, small
        bucket = jax.lax.shift_right_logical(
            k * mult_ref[0, d], jnp.uint32(shift)).astype(jnp.int32)
        onehot = jnp.where(bucket[:, None] == cols, 1.0, 0.0)
        row = jax.lax.dot_general(                         # [1,B] @ [B,W]
            w[None, :], onehot,
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        out_ref[d, :] += row[0, :]


@functools.partial(jax.jit, static_argnames=("depth", "width", "interpret"))
def cms_update(
    keys: jnp.ndarray,
    weights: jnp.ndarray,
    depth: int,
    width: int,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """f32[depth, width] of weighted bucket increments for a key batch.

    ``keys`` u32[M], ``weights`` f32[M] (0 = masked-out item). ``width``
    must be a power of two; the caller adds the returned delta into its
    running count-min state (the pass is mergeable by construction).
    """
    assert width & (width - 1) == 0, "count-min width must be a power of 2"
    assert depth <= len(HASH_MULTIPLIERS)
    shift = 32 - (width - 1).bit_length()
    m_items = keys.shape[0]
    # The kernel's [block, width] one-hot tile must fit VMEM alongside the
    # item tiles and the [depth, width] accumulator: cap it at ~4 MiB of
    # f32 and shrink the item block as width grows (width 1024 → block
    # 1024), instead of letting block×width scale unbounded.
    block = min(_BLOCK_ITEMS, max(256, _ONEHOT_BUDGET_ELEMS // width),
                m_items)
    pad = (-m_items) % block
    if pad:
        keys = jnp.pad(keys, (0, pad))
        weights = jnp.pad(weights, (0, pad))
    n = keys.shape[0] // block
    mult = jnp.asarray(HASH_MULTIPLIERS[:depth], jnp.uint32).reshape(1, depth)

    return pl.pallas_call(
        functools.partial(_cms_kernel, depth=depth, width=width, shift=shift),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, depth), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((depth, width), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((depth, width), jnp.float32),
        interpret=interpret,
    )(keys.reshape(n, block), weights.reshape(n, block), mult)


def _compact_kernel(vals_ref, cwp_ref, cw_ref, tgt_ref, out_ref, *,
                    n_targets: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    v = vals_ref[0, :]                                     # f32[B]
    lo = cwp_ref[0, :]                                     # f32[B]
    hi = cw_ref[0, :]                                      # f32[B]
    t = tgt_ref[0, :]                                      # f32[C]
    hit = jnp.where((lo[:, None] <= t[None, :]) & (t[None, :] < hi[:, None]),
                    1.0, 0.0)                              # f32[B, C]
    picked = jax.lax.dot_general(                          # [1,B] @ [B,C]
        v[None, :], hit, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    out_ref[...] += picked


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantile_compact(
    values: jnp.ndarray,
    cumw_prev: jnp.ndarray,
    cumw: jnp.ndarray,
    targets: jnp.ndarray,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """f32[C]: value of the slot whose weight interval covers each target.

    ``values``/``cumw_prev``/``cumw`` f32[P] are value-sorted summary
    slots with exclusive/inclusive cumulative weights; ``targets`` f32[C]
    are rank targets in [0, W). Targets at or beyond W hit no interval
    and come back 0 — the caller substitutes the max summary value.
    """
    p_items = values.shape[0]
    n_targets = targets.shape[0]
    block = min(_BLOCK_ITEMS, p_items)
    pad = (-p_items) % block
    if pad:
        # padded slots get an empty interval at the very top: lo == hi == W
        top = cumw[-1]
        values = jnp.pad(values, (0, pad))
        cumw_prev = jnp.pad(cumw_prev, (0, pad), constant_values=0.0)
        cumw_prev = cumw_prev.at[p_items:].set(top)
        cumw = jnp.pad(cumw, (0, pad))
        cumw = cumw.at[p_items:].set(top)
    n = values.shape[0] // block

    return pl.pallas_call(
        functools.partial(_compact_kernel, n_targets=n_targets),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, n_targets), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n_targets), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n_targets), jnp.float32),
        interpret=interpret,
    )(values.reshape(n, block), cumw_prev.reshape(n, block),
      cumw.reshape(n, block), targets.reshape(1, n_targets))[0]

"""Pallas TPU kernel: the ENTIRE per-level WHS sampling tick, fused.

One grid step = one node of the hierarchy level. The node's interval
buffer (values / strata / valid / priorities) is loaded into VMEM once
and every stage of Alg. 2 runs on it without an HBM round-trip:

    counts     c_i            one-hot [cap, X] reduce
    allocation N_i            fair water-filling (same fori_loop as
                              ``core.sampling.allocate_reservoirs``)
    threshold  τ_i            bitwise binary search for the N_i-th
                              largest priority (31 fixed iterations on
                              the monotone IEEE-754 order; no in-kernel
                              sort needed)
    keep mask                 strict/tie decomposition — bit-identical
                              to the stable-lexsort law (``argsort``)
    weight update             Alg. 2 lines 12-20 + Eq. 9 (``_whs_meta``)
    compaction                cumsum destination + one-hot MXU scatter

The previous pallas backend ran this as three kernels
(``stratified_stats`` → threshold sort → ``sample_mask``) plus an XLA
compaction, with the item buffer leaving and re-entering HBM between
each stage. Here reservoirs and the per-stratum accumulators stay
VMEM-resident for the whole tick.

Saturation fast path (fraction ≥ 1.0): when every stratum's reservoir
covers its count, the keep mask is provably ``valid`` — the threshold
search and tie resolution are skipped under ``pl.when``, and when the
buffer is additionally front-packed the compaction collapses to a
truncating copy. This is what removes the exact-path overhead at
sampling fraction 1.0 (the sampler never loses when it samples
nothing).

Tie law (the bit-identity recipe, same as ``TopKBackend``): items with
``u > τ`` are kept outright; items with ``u == τ`` (exact f32
collisions) are kept in buffer-position order until the reservoir is
full — exactly the (priority desc, position asc) order of the stable
lexsort, so masks match ``argsort`` bit-for-bit.

VMEM budget: one node's buffers are ``O(cap·X)`` f32 for the one-hot
matrices plus ``O(cap·out_capacity)`` for the scatter matrix — at the
repo's scales (cap ≤ 8192, X ≤ 32) this fits the ~16 MB/core budget;
larger shapes should fall back to the unfused path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.sampling import allocate_reservoirs
from repro.core.whs import _whs_meta

# Binary-search iterations: priorities live in [0, 1), whose IEEE-754
# payloads span [0, 0x3F800000) ⊂ [0, 2^30) — 31 halvings pin the
# threshold to an exact item priority (extra iterations are no-ops).
_SEARCH_ITERS = 31


def _seg_lookup_f32(onehot_f: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Per-item gather ``table[s_k]`` as a one-hot MXU matmul (exact: each
    row of ``onehot_f`` has a single 1, so the dot returns the f32 entry
    bit-for-bit; gathers are VPU-serial on TPU, matmuls are not)."""
    return jax.lax.dot_general(
        onehot_f, table[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0]


def _seg_lookup_i32(onehot_f: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Per-item gather of an i32 table via two f32-exact matmuls (split
    into 19-bit high / 12-bit low halves — each < 2^24, so the f32
    matmul is exact — and recombined in integer space)."""
    hi = _seg_lookup_f32(onehot_f, (table >> 12).astype(jnp.float32))
    lo = _seg_lookup_f32(onehot_f, (table & 0xFFF).astype(jnp.float32))
    return hi.astype(jnp.int32) * 4096 + lo.astype(jnp.int32)


def _search_tau(u, onehot_f, valid, reservoirs, counts):
    """Exact per-stratum thresholds: τ_i = the ``N_i``-th largest valid
    priority of stratum i, found by binary search on the IEEE-754 bit
    pattern (monotone for non-negative floats). Sentinels match
    ``kernels.sample_mask.ops.thresholds_from_reservoirs``:
    keep-nothing (N ≤ 0) → +2.0, keep-everything (c ≤ N) → −1.0."""
    num_strata = reservoirs.shape[0]
    n_int = reservoirs.astype(jnp.int32)
    c_int = counts.astype(jnp.int32)
    # Effective rank: only searched when 0 < N < c (sentinels otherwise).
    n_eff = jnp.clip(jnp.minimum(n_int, c_int), 1, None)
    u_bits = jax.lax.bitcast_convert_type(u, jnp.int32)

    def body(_, state):
        lo, hi = state
        mid = (lo + hi) // 2
        seg_mid = _seg_lookup_i32(onehot_f, mid)
        pred = (valid & (u_bits >= seg_mid)).astype(jnp.float32)
        cnt = jax.lax.dot_general(
            onehot_f, pred[:, None], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[:, 0]
        ok = cnt >= n_eff.astype(jnp.float32)   # F(mid) ≥ N: mid feasible
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)

    lo0 = jnp.zeros((num_strata,), jnp.int32)            # F(0) = c ≥ n_eff
    hi0 = jnp.full((num_strata,), 0x3F800001, jnp.int32)  # > bits(max u)
    lo, _ = jax.lax.fori_loop(0, _SEARCH_ITERS, body, (lo0, hi0))
    tau = jax.lax.bitcast_convert_type(lo, jnp.float32)
    return jnp.where(n_int <= 0, 2.0,
                     jnp.where(c_int <= n_int, -1.0, tau))


def _select_block(u, s, m, onehot_f, reservoirs, counts, num_strata):
    """Keep mask for one VMEM-resident block — τ search + the strict/tie
    decomposition that reproduces the stable lexsort bit-for-bit."""
    cap = u.shape[0]
    tau = _search_tau(u, onehot_f, m, reservoirs, counts)
    seg_tau = _seg_lookup_f32(onehot_f, tau)
    strict = m & (u > seg_tau)
    m_strict = jax.lax.dot_general(
        onehot_f, strict.astype(jnp.float32)[:, None],
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0]
    slack = reservoirs - m_strict                       # f32, int-valued
    tie = m & (u == seg_tau)
    # Position-ordered tie rank per stratum: cumsum along the item axis of
    # the [X, cap] tie matrix, read back at each item's own column.
    onrow_t = jax.lax.broadcasted_iota(
        jnp.int32, (num_strata, cap), 0) == s[None, :]
    ranks = jnp.cumsum(
        jnp.where(onrow_t & tie[None, :], 1.0, 0.0), axis=1)
    rank_at = jnp.sum(jnp.where(onrow_t, ranks, 0.0), axis=0)
    seg_slack = _seg_lookup_f32(onehot_f, slack)
    return strict | (tie & (rank_at <= seg_slack))


def _kernel(values_ref, strata_ref, valid_ref, prio_ref, win_ref, cin_ref,
            size_ref, keep_ref, vals_ref, strc_ref, nk_ref, c_ref, res_ref,
            y_ref, w_ref, cout_ref, *, num_strata: int, out_capacity: int,
            allocation: str, async_calibration: bool):
    v = values_ref[0, :]
    s = strata_ref[0, :]
    m = valid_ref[0, :]
    u = prio_ref[0, :]
    cap = v.shape[0]

    cols = jax.lax.broadcasted_iota(jnp.int32, (cap, num_strata), 1)
    onehot_f = jnp.where((s[:, None] == cols) & m[:, None], 1.0, 0.0)

    # --- counts + reservoir allocation (VMEM-resident accumulators) ------
    c = jnp.sum(onehot_f, axis=0)                               # f32[X]
    stds = None
    if allocation == "neyman":
        # Per-stratum value moments on the MXU: invalid items contribute
        # nothing (their one-hot row is all-zero), so Σv / Σv² per stratum
        # come out of two more passes over the VMEM-resident buffer.
        s1 = jax.lax.dot_general(
            onehot_f, v[:, None], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[:, 0]
        s2 = jax.lax.dot_general(
            onehot_f, (v * v)[:, None], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[:, 0]
        safe = jnp.maximum(c, 1.0)
        var = jnp.maximum(s2 / safe - jnp.square(s1 / safe), 0.0)
        stds = jnp.sqrt(var)
    reservoirs = allocate_reservoirs(size_ref[0, 0], c, policy=allocation,
                                     stds=stds)
    c_ref[0, :] = c
    res_ref[0, :] = reservoirs

    # --- weight update (Alg. 2 lines 12-20 + Eq. 9) ----------------------
    y, meta = _whs_meta(c, reservoirs, win_ref[0, :], cin_ref[0, :],
                        async_calibration)
    y_ref[0, :] = y
    w_ref[0, :] = meta.weight
    cout_ref[0, :] = meta.count

    # --- selection, with the saturation fast path ------------------------
    saturated = jnp.all(reservoirs >= c)

    @pl.when(saturated)
    def _keep_all():
        # N_i ≥ c_i everywhere: τ sinks below every priority, ties resolve
        # to "keep all" — the mask is provably ``valid``. Skips the whole
        # threshold search (the fraction-1.0 exact path).
        keep_ref[0, :] = m

    @pl.when(jnp.logical_not(saturated))
    def _select():
        keep_ref[0, :] = _select_block(u, s, m, onehot_f, reservoirs, c,
                                       num_strata)

    # --- compaction ------------------------------------------------------
    keep = keep_ref[0, :]
    n_valid = jnp.sum(m.astype(jnp.int32))
    iota_cap = jax.lax.broadcasted_iota(jnp.int32, (1, cap), 1)[0, :]
    front_packed = jnp.all(m == (iota_cap < n_valid))
    out_iota = jax.lax.broadcasted_iota(jnp.int32, (1, out_capacity), 1)[0, :]

    @pl.when(saturated & front_packed)
    def _passthrough():
        # Everything valid is kept and already front-packed: compaction is
        # a truncating copy (zeros beyond the kept range, matching the
        # scatter path bit-for-bit).
        n_keep = jnp.minimum(n_valid, out_capacity)
        live = out_iota < n_keep
        vals_ref[0, :] = jnp.where(live, v[:out_capacity], 0.0)
        strc_ref[0, :] = jnp.where(live, s[:out_capacity], 0)
        nk_ref[0, 0] = n_valid

    @pl.when(jnp.logical_not(saturated & front_packed))
    def _pack():
        dest = jnp.cumsum(keep.astype(jnp.int32)) - 1
        ok = keep & (dest < out_capacity)
        dmat = jnp.where((dest[:, None] == out_iota[None, :]) & ok[:, None],
                         1.0, 0.0)                      # [cap, OC]
        vals_ref[0, :] = jax.lax.dot_general(
            dmat, v[:, None], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[:, 0]
        # Stratum ids are small ints (≪ 2^24): the f32 scatter is exact.
        strc_ref[0, :] = jax.lax.dot_general(
            dmat, s.astype(jnp.float32)[:, None], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[:, 0].astype(jnp.int32)
        nk_ref[0, 0] = jnp.sum(keep.astype(jnp.int32))


@functools.partial(
    jax.jit,
    static_argnames=("num_strata", "out_capacity", "allocation",
                     "async_calibration", "interpret"))
def fused_level_tick(
    values: jnp.ndarray,      # f32[n, cap]
    strata: jnp.ndarray,      # i32[n, cap]
    valid: jnp.ndarray,       # bool[n, cap]
    priorities: jnp.ndarray,  # f32[n, cap]
    w_in: jnp.ndarray,        # f32[n, X]
    c_in: jnp.ndarray,        # f32[n, X]
    sample_size: jnp.ndarray,  # f32[] level budget
    num_strata: int,
    out_capacity: int,
    *,
    allocation: str = "fair",
    async_calibration: bool = True,
    interpret: bool = True,
):
    """Run the fused WHS tick over a stacked level (one grid step/node).

    Returns ``(keep, values_c, strata_c, n_keep, c, reservoirs, y, w_out,
    c_out)`` — the keep mask ``bool[n, cap]``, the compacted forwarding
    buffers ``[n, out_capacity]`` + per-node kept counts ``i32[n]``, and
    the per-stratum ``f32[n, X]`` accumulators (counts, reservoirs, Y,
    W^out, C^out).
    """
    n, cap = values.shape
    x = w_in.shape[-1]
    size2 = jnp.broadcast_to(
        jnp.asarray(sample_size, jnp.float32).reshape(1, 1), (1, 1))

    row = pl.BlockSpec((1, cap), lambda i: (i, 0))
    xrow = pl.BlockSpec((1, x), lambda i: (i, 0))
    orow = pl.BlockSpec((1, out_capacity), lambda i: (i, 0))

    outs = pl.pallas_call(
        functools.partial(_kernel, num_strata=x, out_capacity=out_capacity,
                          allocation=allocation,
                          async_calibration=async_calibration),
        grid=(n,),
        in_specs=[row, row, row, row, xrow, xrow,
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=[row, orow, orow, pl.BlockSpec((1, 1), lambda i: (i, 0)),
                   xrow, xrow, xrow, xrow, xrow],
        out_shape=[
            jax.ShapeDtypeStruct((n, cap), jnp.bool_),
            jax.ShapeDtypeStruct((n, out_capacity), jnp.float32),
            jax.ShapeDtypeStruct((n, out_capacity), jnp.int32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
            jax.ShapeDtypeStruct((n, x), jnp.float32),
            jax.ShapeDtypeStruct((n, x), jnp.float32),
            jax.ShapeDtypeStruct((n, x), jnp.float32),
            jax.ShapeDtypeStruct((n, x), jnp.float32),
            jax.ShapeDtypeStruct((n, x), jnp.float32),
        ],
        interpret=interpret,
    )(values, strata, valid, priorities, w_in, c_in, size2)
    keep, vals_c, strata_c, nk, c, res, y, w_out, c_out = outs
    return keep, vals_c, strata_c, nk[:, 0], c, res, y, w_out, c_out


def _select_kernel(prio_ref, strata_ref, valid_ref, res_ref, keep_ref, *,
                   num_strata: int):
    u = prio_ref[0, :]
    s = strata_ref[0, :]
    m = valid_ref[0, :]
    cap = u.shape[0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (cap, num_strata), 1)
    onehot_f = jnp.where((s[:, None] == cols) & m[:, None], 1.0, 0.0)
    c = jnp.sum(onehot_f, axis=0)
    reservoirs = res_ref[0, :]
    saturated = jnp.all(reservoirs >= c)

    @pl.when(saturated)
    def _keep_all():
        keep_ref[0, :] = m

    @pl.when(jnp.logical_not(saturated))
    def _select():
        keep_ref[0, :] = _select_block(u, s, m, onehot_f, reservoirs, c,
                                       num_strata)


@functools.partial(jax.jit, static_argnames=("num_strata", "interpret"))
def fused_select(
    priorities: jnp.ndarray,  # f32[M]
    strata: jnp.ndarray,      # i32[M]
    valid: jnp.ndarray,       # bool[M]
    reservoirs: jnp.ndarray,  # f32[X]
    num_strata: int,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Selection-only entry (the ``SamplerBackend.select`` contract):
    caller-provided reservoirs, same τ search + tie law, bool[M] mask."""
    m_items = priorities.shape[0]
    return pl.pallas_call(
        functools.partial(_select_kernel, num_strata=num_strata),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1, m_items), lambda i: (0, 0)),
            pl.BlockSpec((1, m_items), lambda i: (0, 0)),
            pl.BlockSpec((1, m_items), lambda i: (0, 0)),
            pl.BlockSpec((1, num_strata), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, m_items), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, m_items), jnp.bool_),
        interpret=interpret,
    )(priorities.reshape(1, -1), strata.reshape(1, -1),
      valid.reshape(1, -1), reservoirs.reshape(1, -1)).reshape(-1)

"""Public ops for the fused level tick: kernel/oracle dispatch.

``impl``: ``pallas`` runs the fused Pallas kernel (compiled on TPU,
interpret mode elsewhere); ``ref`` runs the pure-jnp oracle that
composes the unfused reference stages; ``auto`` picks ``pallas``.
Both produce bit-identical outputs (the tie law reproduces the stable
lexsort exactly), which ``tests/test_fused_tick.py`` pins.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.fused_level_tick import ref
from repro.kernels.fused_level_tick.fused_level_tick import (
    fused_level_tick as _pallas_tick,
    fused_select as _pallas_select,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit,
    static_argnames=("num_strata", "out_capacity", "allocation",
                     "async_calibration", "impl"))
def fused_level_tick(values, strata, valid, priorities, w_in, c_in,
                     sample_size, num_strata: int, out_capacity: int,
                     *, allocation: str = "fair",
                     async_calibration: bool = True, impl: str = "auto"):
    """One fused WHS tick over a stacked level. Returns ``(keep,
    values_c, strata_c, n_keep, c, reservoirs, y, w_out, c_out)``."""
    if impl == "pallas" or impl == "auto":
        return _pallas_tick(values, strata, valid, priorities, w_in, c_in,
                            sample_size, num_strata, out_capacity,
                            allocation=allocation,
                            async_calibration=async_calibration,
                            interpret=not _on_tpu())
    return ref.fused_level_tick(values, strata, valid, priorities, w_in,
                                c_in, sample_size, num_strata, out_capacity,
                                allocation=allocation,
                                async_calibration=async_calibration)


@functools.partial(jax.jit, static_argnames=("num_strata", "impl"))
def fused_select(priorities, strata, valid, reservoirs, num_strata: int,
                 *, impl: str = "auto"):
    """Selection-only fused pass (the ``SamplerBackend.select`` contract)."""
    if impl == "pallas" or impl == "auto":
        return _pallas_select(priorities, strata, valid, reservoirs,
                              num_strata, interpret=not _on_tpu())
    return ref.fused_select(priorities, strata, valid, reservoirs,
                            num_strata)

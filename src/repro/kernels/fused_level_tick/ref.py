"""Pure-jnp oracle for the fused level-tick kernel.

Composes the exact reference pieces the unfused path runs — per-stratum
counts, fair reservoir allocation, the stable-lexsort selection law
(``stratified_priority_sample``), the Alg. 2 weight update and the
row-wise compaction — so the kernel can be bit-checked against the
``argsort`` oracle stage by stage.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sampling, whs


def fused_level_tick(
    values: jnp.ndarray,      # f32[n, cap]
    strata: jnp.ndarray,      # i32[n, cap]
    valid: jnp.ndarray,       # bool[n, cap]
    priorities: jnp.ndarray,  # f32[n, cap]
    w_in: jnp.ndarray,        # f32[n, X]
    c_in: jnp.ndarray,        # f32[n, X]
    sample_size: jnp.ndarray,
    num_strata: int,
    out_capacity: int,
    *,
    allocation: str = "fair",
    async_calibration: bool = True,
):
    n, cap = values.shape

    def node(v_row, s_row, m_row, u_row):
        c = sampling.stratum_counts(s_row, m_row, num_strata)
        stds = None
        if allocation == "neyman":
            stds = sampling.stratum_stds(v_row, s_row, m_row, num_strata)
        res = sampling.allocate_reservoirs(sample_size, c, policy=allocation,
                                           stds=stds)
        keep = sampling.stratified_priority_sample(
            None, s_row, m_row, res, num_strata, priorities=u_row)
        return c, res, keep

    c, reservoirs, keep = jax.vmap(node)(values, strata, valid, priorities)
    y, meta = whs._whs_meta(c, reservoirs, w_in, c_in, async_calibration)
    values_c, strata_c, n_keep = whs.pack_rows(values, strata, keep,
                                               out_capacity)
    return (keep, values_c, strata_c, n_keep, c, reservoirs, y,
            meta.weight, meta.count)


def fused_select(
    priorities: jnp.ndarray,  # f32[M]
    strata: jnp.ndarray,      # i32[M]
    valid: jnp.ndarray,       # bool[M]
    reservoirs: jnp.ndarray,  # f32[X]
    num_strata: int,
) -> jnp.ndarray:
    return sampling.stratified_priority_sample(
        None, strata, valid, reservoirs, num_strata, priorities=priorities)

"""Pure-jnp oracle for the fused stratified-stats kernel.

Per stratum over *selected* items: (count, Σx, Σx²). These three moments
are everything the root node needs for every linear query + its CLT error
bound (§III-D), so fusing them into one HBM pass is the analytics plane's
hot spot.
"""
from __future__ import annotations

import jax.numpy as jnp


def stratified_stats(
    values: jnp.ndarray,   # f32[M]
    strata: jnp.ndarray,   # i32[M]
    mask: jnp.ndarray,     # bool[M]  (selected & valid)
    num_strata: int,
) -> jnp.ndarray:          # f32[X, 3] = (count, sum, sumsq)
    seg = jnp.where(mask, strata, num_strata)
    z = jnp.zeros((num_strata + 1,), jnp.float32)
    cnt = z.at[seg].add(1.0)[:num_strata]
    s1 = z.at[seg].add(jnp.where(mask, values, 0.0))[:num_strata]
    s2 = z.at[seg].add(jnp.where(mask, values * values, 0.0))[:num_strata]
    return jnp.stack([cnt, s1, s2], axis=-1)

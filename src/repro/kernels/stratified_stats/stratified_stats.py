"""Pallas TPU kernel: fused per-stratum (count, Σx, Σx²) in one HBM pass.

TPU adaptation of the paper's per-stratum aggregation loops: instead of a
scatter per item (serial, VPU-hostile), each VMEM tile of items builds a
one-hot [block, X] stratum matrix and hits the MXU once:

    stats[X, 3] += one_hot(strata_tile)ᵀ @ [mask, x·mask, x²·mask]

The grid walks item tiles sequentially (TPU grid order), accumulating into
the same output block — the standard revisiting-output reduction pattern.
Arithmetic intensity: 6·X FLOPs per 4-byte item vs. 3 scalar scatters; the
pass is memory-bound, so one fused pass ≈ 3× fewer HBM bytes than three
separate segment-sums.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Item tile: (8, 128) f32 = one native VREG tile per load; 4 tiles deep to
# amortize grid overhead → 4096 items per grid step, 16 KiB of values in VMEM.
_BLOCK_ITEMS = 4096


def _kernel(values_ref, strata_ref, mask_ref, out_ref, *, num_strata: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    v = values_ref[0, :]                                  # f32[B]
    s = strata_ref[0, :]                                  # i32[B]
    m = mask_ref[0, :].astype(jnp.float32)                # f32[B]

    b = v.shape[0]
    # one_hot[B, X] — broadcasted iota keeps it 2D (TPU requires ≥2D iota).
    cols = jax.lax.broadcasted_iota(jnp.int32, (b, num_strata), 1)
    onehot = jnp.where(s[:, None] == cols, m[:, None], 0.0)

    feats = jnp.stack([m, v * m, v * v * m], axis=-1)     # f32[B, 3]
    # [X, B] @ [B, 3] on the MXU.
    tile = jax.lax.dot_general(
        onehot, feats, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    out_ref[...] += tile


@functools.partial(jax.jit, static_argnames=("num_strata", "interpret"))
def stratified_stats(
    values: jnp.ndarray,
    strata: jnp.ndarray,
    mask: jnp.ndarray,
    num_strata: int,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """f32[X, 3] per-stratum (count, Σx, Σx²) over masked items."""
    m_items = values.shape[0]
    block = min(_BLOCK_ITEMS, m_items)
    pad = (-m_items) % block
    if pad:
        values = jnp.pad(values, (0, pad))
        strata = jnp.pad(strata, (0, pad))
        mask = jnp.pad(mask, (0, pad))
    n = values.shape[0] // block
    v2 = values.reshape(n, block)
    s2 = strata.reshape(n, block)
    k2 = mask.reshape(n, block)

    return pl.pallas_call(
        functools.partial(_kernel, num_strata=num_strata),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((num_strata, 3), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_strata, 3), jnp.float32),
        interpret=interpret,
    )(v2, s2, k2)

"""Public op: stratified_stats with kernel/oracle dispatch.

On TPU the Pallas kernel runs compiled (``interpret=False``); everywhere
else it runs in interpret mode (bit-accurate kernel-body semantics on CPU)
or falls back to the jnp oracle for speed. The boundary is one function so
callers never see the backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.stratified_stats import ref
from repro.kernels.stratified_stats.stratified_stats import (
    stratified_stats as _pallas_stats,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("num_strata", "impl"))
def stratified_stats(
    values: jnp.ndarray,
    strata: jnp.ndarray,
    mask: jnp.ndarray,
    num_strata: int,
    impl: str = "auto",
) -> jnp.ndarray:
    """Fused per-stratum (count, Σx, Σx²). impl ∈ {auto, pallas, ref}."""
    if impl == "pallas" or (impl == "auto" and _on_tpu()):
        return _pallas_stats(values, strata, mask, num_strata, interpret=not _on_tpu())
    return ref.stratified_stats(values, strata, mask, num_strata)

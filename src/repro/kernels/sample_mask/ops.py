"""Public op: threshold-based selection (kernel/oracle dispatch) and the
exact per-stratum threshold computation that feeds it.

``thresholds_from_reservoirs`` reproduces the priority sampler exactly:
τ_i = the ``N_i``-th largest priority among stratum-i valid items (−∞ when
``c_i ≤ N_i``), so ``keep = u ≥ τ`` selects precisely the per-stratum
top-``N_i`` — the reservoir-sampling output law.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.sample_mask import ref
from repro.kernels.sample_mask.sample_mask import sample_mask as _pallas_mask


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("num_strata",))
def thresholds_from_reservoirs(
    priorities: jnp.ndarray,
    strata: jnp.ndarray,
    valid: jnp.ndarray,
    reservoirs: jnp.ndarray,
    num_strata: int,
) -> jnp.ndarray:
    """Exact τ[X]: N_i-th largest valid priority per stratum (−inf if c≤N)."""
    m = priorities.shape[0]
    seg = jnp.where(valid, strata, num_strata)
    # Lexicographic [stratum asc, priority desc]: full-precision priority
    # ordering regardless of how many strata there are (a packed single
    # float key loses priority bits as the stratum id grows).
    order = jnp.lexsort((jnp.where(valid, -priorities, 0.5), seg))
    counts = jnp.zeros((num_strata + 2,), jnp.int32).at[
        jnp.where(valid, strata, num_strata)
    ].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    n_int = reservoirs.astype(jnp.int32)
    c_int = counts[:num_strata]
    # Index of the N_i-th largest element of stratum i in sorted order.
    idx = starts[:num_strata] + jnp.clip(n_int - 1, 0, jnp.maximum(c_int - 1, 0))
    tau = priorities[order][jnp.clip(idx, 0, m - 1)]
    # Sentinels are finite so the kernel's one-hot·τ matmul stays NaN-free
    # (0·(±inf) would poison it); priorities live in [0, 1):
    #   keep-everything (c ≤ N)  → −1.0   (every valid item passes u ≥ τ)
    #   keep-nothing   (N ≤ 0)   → +2.0   (no priority can reach it; without
    #     this, the clipped idx would return the stratum's max priority and
    #     the threshold pass would keep one item where the rank pass keeps 0)
    return jnp.where(n_int <= 0, 2.0, jnp.where(c_int > n_int, tau, -1.0))


@functools.partial(jax.jit, static_argnames=("impl",))
def sample_mask(priorities, strata, valid, tau, weights, impl: str = "auto"):
    if impl == "pallas" or (impl == "auto" and _on_tpu()):
        return _pallas_mask(priorities, strata, valid, tau, weights,
                            interpret=not _on_tpu())
    return ref.sample_mask(priorities, strata, valid, tau, weights)

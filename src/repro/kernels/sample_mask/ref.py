"""Pure-jnp oracle for the fused sample-mask kernel.

Given per-item priorities and per-stratum selection thresholds τ (the
``N_i``-th largest priority within the stratum, +∞ if the stratum keeps
everything), emit the selection mask and the per-item effective weight in
one pass:

    keep_k   = valid_k ∧ (u_k ≥ τ[s_k])
    weight_k = keep_k ? W^out[s_k] : 0
"""
from __future__ import annotations

import jax.numpy as jnp


def sample_mask(
    priorities: jnp.ndarray,  # f32[M]
    strata: jnp.ndarray,      # i32[M]
    valid: jnp.ndarray,       # bool[M]
    tau: jnp.ndarray,         # f32[X] selection threshold per stratum
    weights: jnp.ndarray,     # f32[X] W^out per stratum
):
    keep = valid & (priorities >= tau[strata])
    w = jnp.where(keep, weights[strata], 0.0)
    return keep, w

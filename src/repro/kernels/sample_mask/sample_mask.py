"""Pallas TPU kernel: fused threshold-select + weight materialization.

Second stage of the TPU-native reservoir sampler: stage 1 (tiny, XLA sort
over per-stratum priorities) finds each stratum's ``N_i``-th largest
priority τ_i; this kernel then streams the item buffer once, emitting the
keep-mask and per-item weight. Lookup tables (τ, W) are broadcast to every
grid step and resolved with a one-hot MXU matmul instead of a dynamic
gather — gathers are VPU-serial on TPU, one-hot matmuls are not.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BLOCK_ITEMS = 4096


def _kernel(prio_ref, strata_ref, valid_ref, tau_ref, w_ref, keep_ref, wout_ref,
            *, num_strata: int):
    u = prio_ref[0, :]
    s = strata_ref[0, :]
    m = valid_ref[0, :]

    b = u.shape[0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (b, num_strata), 1)
    onehot = (s[:, None] == cols).astype(jnp.float32)          # [B, X]
    tau_i = onehot @ tau_ref[0, :]                              # [B]
    w_i = onehot @ w_ref[0, :]                                  # [B]

    keep = m & (u >= tau_i)
    keep_ref[0, :] = keep
    wout_ref[0, :] = jnp.where(keep, w_i, 0.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sample_mask(
    priorities: jnp.ndarray,
    strata: jnp.ndarray,
    valid: jnp.ndarray,
    tau: jnp.ndarray,
    weights: jnp.ndarray,
    *,
    interpret: bool = True,
):
    m_items = priorities.shape[0]
    num_strata = tau.shape[0]
    block = min(_BLOCK_ITEMS, m_items)
    pad = (-m_items) % block
    if pad:
        priorities = jnp.pad(priorities, (0, pad))
        strata = jnp.pad(strata, (0, pad))
        valid = jnp.pad(valid, (0, pad))
    n = priorities.shape[0] // block

    keep, w = pl.pallas_call(
        functools.partial(_kernel, num_strata=num_strata),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, num_strata), lambda i: (0, 0)),
            pl.BlockSpec((1, num_strata), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, block), jnp.bool_),
            jax.ShapeDtypeStruct((n, block), jnp.float32),
        ],
        interpret=interpret,
    )(
        priorities.reshape(n, block),
        strata.reshape(n, block),
        valid.reshape(n, block),
        tau.reshape(1, num_strata),
        weights.reshape(1, num_strata),
    )
    return keep.reshape(-1)[:m_items], w.reshape(-1)[:m_items]

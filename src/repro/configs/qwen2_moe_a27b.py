"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B] — 60 routed experts top-4
+ 4 shared experts, moe_d_ff=1408."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=151936,
    num_experts=60, num_experts_per_tok=4, num_shared_experts=4, moe_d_ff=1408,
)

"""--arch registry: name → ArchConfig, plus input_specs() per shape.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input (weak-type-correct, shardable, zero allocation) — the dry-run
lowers against these. Modality frontends are stubs: audio/vision entries
include precomputed frame/patch embeddings at ``d_model``.
"""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, shape_applicable

_MODULES = {
    "olmo-1b": "olmo_1b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "smollm-135m": "smollm_135m",
    "qwen3-4b": "qwen3_4b",
    "whisper-medium": "whisper_medium",
    "internvl2-1b": "internvl2_1b",
    "qwen2-moe-a2.7b": "qwen2_moe_a27b",
    "grok-1-314b": "grok_1_314b",
    "zamba2-1.2b": "zamba2_1_2b",
    "rwkv6-7b": "rwkv6_7b",
}

ARCH_NAMES = list(_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeConfig | str) -> dict:
    """ShapeDtypeStruct pytree for one (arch × shape) cell's step inputs."""
    if isinstance(shape, str):
        shape = SHAPES[shape]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.name} × {shape.name}: {why}")
    b, s = shape.global_batch, shape.seq_len

    if shape.kind in ("train", "prefill"):
        specs = {
            "tokens": _sds((b, s), jnp.int32),
            "stratum": _sds((b,), jnp.int32),
            "weight": _sds((b,), jnp.float32),
        }
        if shape.kind == "train":
            specs["labels"] = _sds((b, s), jnp.int32)
        if cfg.family == "encdec":
            # conv frontend stub: precomputed frame embeddings; split the
            # budget: encoder sees s//2 frames, decoder s//2 tokens.
            specs["frames"] = _sds((b, s // 2, cfg.d_model), cfg.param_dtype)
            specs["tokens"] = _sds((b, s // 2), jnp.int32)
            if shape.kind == "train":
                specs["labels"] = _sds((b, s // 2), jnp.int32)
        if cfg.family == "vlm":
            # vision stub: patch embeddings prepended to the text tokens.
            p = cfg.num_patches
            specs["patches"] = _sds((b, p, cfg.d_model), cfg.param_dtype)
            specs["tokens"] = _sds((b, s - p), jnp.int32)
            if shape.kind == "train":
                specs["labels"] = _sds((b, s - p), jnp.int32)
        return specs

    # decode: one new token against a cache of seq_len.
    from repro.models import model as model_lib

    specs = {
        "token": _sds((b, 1), jnp.int32),
        "pos": _sds((), jnp.int32),
        "cache": model_lib.cache_specs(cfg, b, s),
    }
    return specs


def all_cells():
    """Yield (arch_name, shape_name, applicable, reason)."""
    for a in ARCH_NAMES:
        cfg = get_config(a)
        for s_name, sh in SHAPES.items():
            ok, why = shape_applicable(cfg, sh)
            yield a, s_name, ok, why

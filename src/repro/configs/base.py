"""Architecture + shape configuration system.

Every assigned architecture is an ``ArchConfig`` (exact published
hyper-parameters) plus a ``reduced()`` variant for CPU smoke tests. Input
shapes are global: the launcher shards them over the mesh. ``long_500k``
is only legal for sub-quadratic archs (``supports_long_context``).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 → d_model // num_heads
    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    attn_every: int = 0              # zamba2: shared attn after every k layers
    # enc-dec / multimodal
    encoder_layers: int = 0
    num_patches: int = 0             # vlm: visual tokens per example
    frontend: str = "none"           # none | audio_stub | vision_stub
    # quirks
    norm_type: str = "rmsnorm"
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    # numerics / perf knobs
    param_dtype: Any = jnp.bfloat16
    remat: bool = True
    scan_layers: bool = True
    attention_impl: str = "xla"      # xla | pallas
    # ApproxIoT data plane
    num_strata: int = 16

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    # ---------------------------------------------------------------- props
    @property
    def supports_long_context(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode path

    def param_count(self) -> int:
        """Analytic total parameter count (embedding included)."""
        d, f, v, l = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hd, h, hkv = self.head_dim, self.num_heads, self.num_kv_heads
        attn = d * hd * (h + 2 * hkv) + h * hd * d
        if self.family in ("dense", "vlm"):
            per_layer = attn + 3 * d * f
            body = l * per_layer
        elif self.family == "moe":
            moe = self.num_experts * 3 * d * self.moe_d_ff + d * self.num_experts
            shared = 3 * d * self.num_shared_experts * self.moe_d_ff
            body = l * (attn + moe + shared)
        elif self.family == "encdec":
            enc = self.encoder_layers * (attn + 2 * d * f)
            dec = l * (2 * attn + 2 * d * f)
            body = enc + dec
        elif self.family == "hybrid":
            d_inner = 2 * d
            n = self.ssm_state
            mamba = d * (2 * d_inner + 2 * n + d_inner // self.ssm_head_dim) + d_inner * d
            n_attn = l // max(self.attn_every, 1)
            body = l * mamba + attn  # shared attn counted once
        elif self.family == "ssm":
            body = l * (6 * d * d + 2 * d * self.d_ff + d * 128)
        else:
            raise ValueError(self.family)
        emb = v * d * (1 if self.tie_embeddings else 2)
        return int(body + emb)

    def active_param_count(self) -> int:
        """Per-token active params (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        d, l = self.d_model, self.num_layers
        hd, h, hkv = self.head_dim, self.num_heads, self.num_kv_heads
        attn = d * hd * (h + 2 * hkv) + h * hd * d
        routed = self.num_experts_per_tok * 3 * d * self.moe_d_ff
        shared = 3 * d * self.num_shared_experts * self.moe_d_ff
        emb = self.vocab_size * d * 2
        return int(l * (attn + routed + shared + d * self.num_experts) + emb)

    # ------------------------------------------------------------- reduced
    def reduced(self) -> "ArchConfig":
        """Small same-family config for single-device smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            num_experts=min(self.num_experts, 8) if self.num_experts else 0,
            num_experts_per_tok=min(self.num_experts_per_tok, 2)
            if self.num_experts_per_tok else 0,
            num_shared_experts=min(self.num_shared_experts, 1)
            if self.num_shared_experts else 0,
            moe_d_ff=64 if self.moe_d_ff else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            attn_every=2 if self.attn_every else 0,
            encoder_layers=min(self.encoder_layers, 2),
            num_patches=min(self.num_patches, 8) if self.num_patches else 0,
            param_dtype=jnp.float32,
            remat=False,
            num_strata=4,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) — the DESIGN.md §Arch-applicability rules."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "full quadratic attention — 500k context skipped (DESIGN.md §6)"
    return True, ""

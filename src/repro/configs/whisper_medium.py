"""Whisper-medium [arXiv:2212.04356] — enc-dec audio; conv frontend STUBBED
(input_specs provides precomputed frame embeddings at d_model)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="encdec",
    num_layers=24, encoder_layers=24, d_model=1024, num_heads=16,
    num_kv_heads=16, d_ff=4096, vocab_size=51865,
    norm_type="layernorm", rope_theta=0.0,  # learned/sinusoidal pos (stubbed)
    frontend="audio_stub",
)

"""Grok-1 314B [hf:xai-org/grok-1] — 8 experts top-2, d_ff=32768, GQA kv=8."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b", family="moe",
    num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=32768, vocab_size=131072, head_dim=128,
    num_experts=8, num_experts_per_tok=2, num_shared_experts=0, moe_d_ff=32768,
)

"""Qwen3-4B [hf:Qwen/Qwen3-8B family] — dense, qk_norm, GQA kv=8."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b", family="dense",
    num_layers=36, d_model=2560, num_heads=32, num_kv_heads=8,
    d_ff=9728, vocab_size=151936, qk_norm=True, head_dim=128,
)

"""InternVL2-1B [arXiv:2404.16821; hf] — VLM; InternViT frontend STUBBED
(input_specs provides precomputed patch embeddings), Qwen2-0.5B-class LM."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b", family="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151655, num_patches=256,
    frontend="vision_stub",
)

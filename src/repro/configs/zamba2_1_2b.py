"""Zamba2-1.2B [arXiv:2411.15242; hf] — Mamba2 backbone + shared attention
block applied every 6 layers (weights shared across applications)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, attn_every=6, head_dim=64,
)

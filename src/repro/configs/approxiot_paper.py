"""The paper's own workload: the ApproxIoT analytics pipeline (no LM).

Used by benchmarks/examples to reproduce Figs. 6-12: a 4-level tree
(8 sources -> 4 -> 2 -> 1 root), 4 sub-streams, 1-second (1-tick) windows.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    name: str = "approxiot-paper"
    fanin: tuple = (4, 2, 1)      # sampling levels after the 8 sources
    num_sources: int = 8
    num_strata: int = 4
    capacity: int = 8192          # per-node interval buffer
    sampling_fraction: float = 0.1
    window_ticks: int = 1

    def sample_sizes(self) -> list:
        base = int(self.capacity * self.sampling_fraction)
        return [base for _ in self.fanin]


CONFIG = PipelineConfig()

"""RWKV-6 "Finch" 7B [arXiv:2404.05892; hf] — attention-free, data-dependent
decay; head size 64."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b", family="ssm",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
    d_ff=14336, vocab_size=65536,
    ssm_state=64, ssm_head_dim=64, norm_type="layernorm", rope_theta=0.0,
)

"""Bounded per-shard ingest queues with an explicit backpressure policy.

The serve plane's answer to "what happens when data arrives faster than
the device drains it": every edge shard owns ONE bounded queue between
its source subscription and the staging buffers, and the queue's policy
decides who pays when it fills:

    block        refuse the overflow — rejected items never enter the
                 queue and are counted ``deferred`` (the producer still
                 holds them; a Kafka-style consumer would simply not
                 advance its offset).
    drop_oldest  evict the oldest queued items to make room for the new
                 ones — freshest-data-wins, evictions counted
                 ``items_dropped``.
    degrade      drop each INCOMING item with probability depth/capacity
                 (deterministic per-queue RNG) — graceful load shedding
                 that sheds more as the queue fills, drops counted
                 ``items_dropped``.

Every drop is counted so the published bound stays honest: the executor
folds ``items_dropped`` into the Eq. 9 arrived-weight fraction α, so a
window that shed load publishes with a widened bound instead of a
silently optimistic one.

Accounting invariant (pinned in ``tests/test_serve_plane.py``):

    items_in == items_out + items_dropped + depth

(``deferred`` counts offers that never entered, so it sits outside the
identity on purpose.)
"""
from __future__ import annotations

import collections

import numpy as np

POLICIES = ("block", "drop_oldest", "degrade")


class BoundedShardQueue:
    """One shard's bounded ingest queue (see module doc for policies)."""

    def __init__(self, capacity: int, policy: str = "block", seed: int = 0):
        if capacity < 1:
            raise ValueError(f"queue capacity must be >= 1, got {capacity}")
        if policy not in POLICIES:
            raise ValueError(f"unknown backpressure policy {policy!r}; "
                             f"valid: {POLICIES}")
        self.capacity = int(capacity)
        self.policy = policy
        self._q: collections.deque = collections.deque()
        self._rng = np.random.default_rng(seed)
        self.items_in = 0
        self.items_out = 0
        self.items_dropped = 0
        self.deferred = 0
        self.high_watermark = 0

    # ------------------------------------------------------------- put --
    def put(self, values, strata, now: float) -> int:
        """Offer a batch of (value, stratum) items stamped with arrival
        time ``now``; returns the number actually enqueued."""
        values = np.asarray(values, np.float32)
        strata = np.asarray(strata, np.int32)
        offered = int(values.size)
        if offered == 0:
            return 0
        if self.policy == "block":
            take = min(offered, self.capacity - len(self._q))
            self.deferred += offered - take
            self.items_in += take
            for i in range(take):
                self._q.append((float(values[i]), int(strata[i]), now))
            accepted = take
        elif self.policy == "drop_oldest":
            self.items_in += offered
            for i in range(offered):
                self._q.append((float(values[i]), int(strata[i]), now))
            while len(self._q) > self.capacity:
                self._q.popleft()
                self.items_dropped += 1
            accepted = offered
        else:  # degrade
            self.items_in += offered
            p_drop = len(self._q) / self.capacity
            keep = self._rng.random(offered) >= p_drop
            self.items_dropped += int(offered - keep.sum())
            for i in np.flatnonzero(keep):
                self._q.append((float(values[i]), int(strata[i]), now))
            while len(self._q) > self.capacity:
                self._q.popleft()
                self.items_dropped += 1
            accepted = int(keep.sum())
        self.high_watermark = max(self.high_watermark, len(self._q))
        return accepted

    # -------------------------------------------------------- get_many --
    def get_many(self, max_records: int
                 ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Kafka-``getmany``-style batched drain: up to ``max_records``
        items in FIFO order → ``(values f32[n], strata i32[n],
        arrivals f64[n])``."""
        n = min(int(max_records), len(self._q))
        values = np.empty(n, np.float32)
        strata = np.empty(n, np.int32)
        arrivals = np.empty(n, np.float64)
        for i in range(n):
            values[i], strata[i], arrivals[i] = self._q.popleft()
        self.items_out += n
        return values, strata, arrivals

    # ------------------------------------------------------ accounting --
    @property
    def depth(self) -> int:
        return len(self._q)

    @property
    def accounting_ok(self) -> bool:
        """The drop-accounting law: every offered-and-admitted item is
        either drained, dropped, or still queued."""
        return self.items_in == (self.items_out + self.items_dropped
                                 + self.depth)

    def stats(self) -> dict:
        return {
            "capacity": self.capacity,
            "policy": self.policy,
            "depth": self.depth,
            "high_watermark": self.high_watermark,
            "items_in": self.items_in,
            "items_out": self.items_out,
            "items_dropped": self.items_dropped,
            "deferred": self.deferred,
        }

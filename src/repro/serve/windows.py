"""Straggler-tolerant window publication.

A window whose edge shards all met their deadline publishes the
pipeline's answers untouched — bit-for-bit what a fully synchronous run
produces (pinned in tests). A window with late shards or shed load
publishes a *partial* answer instead of waiting: the arrived-weight
fraction α from the executor's Eq. 9 accounting (``runtime.straggler.
calibrate_weights`` — scale what arrived by 1/α so the estimator still
targets the full stream) rescales the linear estimates and widens every
bound by 1/α ≥ 1. Late data is never dropped: it stays queued and folds
into the next window, so Σ(raw window counts) over a run still equals
every item that entered the tree.

Per-slot widening rules (slot kinds from the compiled plan's layout):

    sum / count / histogram   answer × 1/α,  bound × 1/α   (linear — Eq. 9
                              rescaling keeps the estimate unbiased)
    mean                      answer as-is,  bound × 1/α   (ratio — α
                              cancels in the estimate, not the spread)
    quantile / windowed_      answer as-is,  bound × 1/α   (rank error
        quantile                             grows with the missing mass)
    heavy_hitters / decayed_  key half as-is, estimate half × 1/α,
        heavy_hitters                        bound × 1/α

The built-in workload follows the same rules (SUM × 1/α with variance
× 1/α², MEAN untouched with variance × 1/α², histogram × 1/α).
``PublishedWindow.raw`` keeps the untouched row for conservation
accounting.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import numpy as np

_SCALE_ANSWER_KINDS = ("sum", "count", "histogram")
_KEYED_KINDS = ("heavy_hitters", "decayed_heavy_hitters")


class PublishedWindow(NamedTuple):
    """One published root window: the (possibly widened) serve answer
    plus its straggler/latency provenance."""

    tick: int
    partial: bool
    alpha: float              # arrived-weight fraction (1.0 when complete)
    publish_time: float
    first_arrival: float      # earliest staged arrival (inf if none)
    latency: float            # publish_time - first_arrival (0.0 if none)
    sum: float
    sum_var: float
    mean: float
    mean_var: float
    n_sampled: int
    histogram: np.ndarray
    answers: Any              # widened flat query answers (None w/o tenants)
    bounds: Any
    raw: dict                 # the untouched pipeline row


class WindowPublisher:
    """Applies the per-kind widening rules of one compiled pipeline's
    query layout (see module doc)."""

    def __init__(self, pipeline):
        self._layout = (pipeline.query_layout()
                        if pipeline.plan is not None else {})

    def publish(self, row: dict, *, alpha: float, partial: bool,
                publish_time: float, first_arrival: float
                ) -> PublishedWindow:
        alpha = float(alpha)
        latency = (publish_time - first_arrival
                   if math.isfinite(first_arrival) else 0.0)
        common = dict(tick=int(row["tick"]), partial=bool(partial),
                      alpha=alpha, publish_time=float(publish_time),
                      first_arrival=float(first_arrival), latency=latency,
                      raw=row)
        if not partial:
            # Complete window: pass every array through untouched so the
            # on-time path stays bitwise identical to a synchronous run.
            return PublishedWindow(
                sum=row["sum"], sum_var=row["sum_var"], mean=row["mean"],
                mean_var=row["mean_var"], n_sampled=row["n_sampled"],
                histogram=row["histogram"], answers=row.get("answers"),
                bounds=row.get("bounds"), **common)
        inv = 1.0 / alpha if alpha > 0.0 else 1.0
        answers = bounds = None
        if "answers" in row:
            answers = np.array(row["answers"], np.float32, copy=True)
            bounds = np.array(row["bounds"], np.float32, copy=True) * inv
            for _, (o, w, kind) in self._layout.items():
                if kind in _SCALE_ANSWER_KINDS:
                    answers[o:o + w] *= inv
                elif kind in _KEYED_KINDS:
                    answers[o + w // 2:o + w] *= inv
        return PublishedWindow(
            sum=row["sum"] * inv, sum_var=row["sum_var"] * inv * inv,
            mean=row["mean"], mean_var=row["mean_var"] * inv * inv,
            n_sampled=row["n_sampled"],
            histogram=np.asarray(row["histogram"]) * np.float32(inv),
            answers=answers, bounds=bounds, **common)

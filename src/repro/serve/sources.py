"""Subscribable stream sources for the serve plane.

A source is anything with the two-call lifecycle the executor drives
(modeled on the StreamingExecutor init/subscribe shape):

    source.subscribe(deliver)   # deliver(shard, values, strata)
    source.pump(now)            # emit this tick's items via deliver

``pump`` is the executor's clock edge — sources are passive between
pumps, so tests can inject a fake clock and get fully deterministic
runs. ``LateShardSource`` wraps any source to withhold its deliveries
for a tick range and release them afterwards: the executor publishes
the affected windows as *partial* (widened bound) and the released
items fold into the next window — the straggler semantics of ISSUE 9's
acceptance test, reproducible on demand.
"""
from __future__ import annotations

import numpy as np

from repro.data import stream as stream_mod


class ConstantSource:
    """Deterministic constant-rate, constant-value source — the unit
    tests' workhorse: with sampling fraction 1.0 every published answer
    is exactly predictable."""

    def __init__(self, shard: int, rate: int, value: float = 1.0,
                 stratum: int = 0):
        self.shard = int(shard)
        self.rate = int(rate)
        self.value = float(value)
        self.stratum = int(stratum)
        self._deliver = None

    def subscribe(self, deliver):
        self._deliver = deliver

    def pump(self, now: float):
        if self._deliver is None or self.rate == 0:
            return
        self._deliver(self.shard,
                      np.full(self.rate, self.value, np.float32),
                      np.full(self.rate, self.stratum, np.int32))


class SyntheticSource:
    """Adapts a ``data.stream.StreamSource`` (the paper's §V synthetic
    workloads) to the subscribe/pump lifecycle, feeding one shard."""

    def __init__(self, shard: int, specs=None, seed: int = 0,
                 source: stream_mod.StreamSource | None = None):
        self.shard = int(shard)
        self._src = source or stream_mod.StreamSource(
            specs if specs is not None else stream_mod.paper_gaussian(),
            seed=seed)
        self._deliver = None

    def subscribe(self, deliver):
        self._deliver = deliver

    def pump(self, now: float):
        if self._deliver is None:
            return
        values, strata = self._src.tick()
        if values.size:
            self._deliver(self.shard, values, strata)


class LateShardSource:
    """Straggler injection: buffers the wrapped source's deliveries for
    pump ticks in ``[start_tick, end_tick)`` and releases the backlog on
    the first pump at/after ``end_tick`` (before that tick's own items,
    preserving arrival order)."""

    def __init__(self, source, start_tick: int, end_tick: int):
        if not 0 <= start_tick < end_tick:
            raise ValueError(f"need 0 <= start_tick < end_tick, got "
                             f"[{start_tick}, {end_tick})")
        self._src = source
        self.start_tick = int(start_tick)
        self.end_tick = int(end_tick)
        self._tick = 0
        self._held: list = []
        self._deliver = None

    def subscribe(self, deliver):
        self._deliver = deliver
        self._src.subscribe(self._intercept)

    def _intercept(self, shard, values, strata):
        if self.start_tick <= self._tick < self.end_tick:
            self._held.append((shard, values, strata))
        else:
            self._deliver(shard, values, strata)

    def pump(self, now: float):
        if self._tick >= self.end_tick and self._held:
            for shard, values, strata in self._held:
                self._deliver(shard, values, strata)
            self._held.clear()
        self._src.pump(now)
        self._tick += 1

"""Double-buffered host staging for overlapped ingest/dispatch.

``DoubleBuffer`` owns two pre-allocated ``[T, nodes, width]`` host
buffer sets in the exact tick-major layout ``CompiledPipeline.
run_epoch`` consumes. The executor stages epoch ``k+1``'s arrivals into
the active set while epoch ``k`` — already handed to ``run_epoch``,
which copies host→device at dispatch — computes asynchronously on the
device. ``swap()`` hands the filled set over and re-activates the other
(zeroed) one, so ingest never waits for the device and the device never
waits for packing.

Per-(tick, node) packing reuses ``data.stream._pack_prefix`` — the ONE
epoch-ingest backpressure rule in the repo — so items beyond ``width``
are prefix-truncated exactly like every other ingest path; truncations
are counted (``truncated_total``) and the executor folds them into the
same α accounting as queue drops.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.data.stream import _pack_prefix


class StagedEpoch(NamedTuple):
    """One swapped-out epoch of staged ingest.

    ``values``/``strata``/``counts`` are ready for ``run_epoch``;
    ``offered`` is the pre-truncation per-(tick, node) count and
    ``first_arrival`` the earliest item-arrival timestamp staged into
    each tick row (``inf`` for empty ticks) — the window-latency clock
    starts there.
    """

    values: np.ndarray        # f32[T, nodes, width]
    strata: np.ndarray        # i32[T, nodes, width]
    counts: np.ndarray        # i32[T, nodes]
    offered: np.ndarray       # i64[T, nodes]
    first_arrival: np.ndarray  # f64[T]


class DoubleBuffer:
    def __init__(self, epoch_ticks: int, n_nodes: int, width: int):
        if epoch_ticks < 1 or n_nodes < 1 or width < 1:
            raise ValueError("epoch_ticks, n_nodes, width must be >= 1")
        self.epoch_ticks = int(epoch_ticks)
        self.n_nodes = int(n_nodes)
        self.width = int(width)
        self._bufs = [self._alloc(), self._alloc()]
        self._active = 0
        self.staged_total = 0
        self.truncated_total = 0
        self.swaps = 0

    def _alloc(self) -> dict:
        t, n, w = self.epoch_ticks, self.n_nodes, self.width
        return {
            "values": np.zeros((t, n, w), np.float32),
            "strata": np.zeros((t, n, w), np.int32),
            "counts": np.zeros((t, n), np.int32),
            "offered": np.zeros((t, n), np.int64),
            "first_arrival": np.full((t,), np.inf, np.float64),
        }

    # ----------------------------------------------------------- stage --
    def stage(self, t: int, node: int, values, strata,
              arrival: float | None = None) -> int:
        """Pack one shard's drained items into active tick-row ``t``;
        returns how many fit (the rest are truncated and counted)."""
        buf = self._bufs[self._active]
        values = np.asarray(values, np.float32)
        strata = np.asarray(strata, np.int32)
        fill = int(buf["counts"][t, node])
        new_fill = _pack_prefix(buf["values"][t, node], buf["strata"][t, node],
                                values, strata, fill, self.width)
        staged = new_fill - fill
        buf["counts"][t, node] = new_fill
        buf["offered"][t, node] += values.size
        self.staged_total += staged
        self.truncated_total += values.size - staged
        if arrival is not None and staged:
            buf["first_arrival"][t] = min(buf["first_arrival"][t],
                                          float(arrival))
        return staged

    def first_arrival(self, t: int) -> float:
        """Earliest arrival staged into active tick-row ``t`` so far."""
        return float(self._bufs[self._active]["first_arrival"][t])

    # ------------------------------------------------------------ swap --
    def swap(self) -> StagedEpoch:
        """Hand the active (filled) set over and activate the other one,
        zeroed for reuse. The returned arrays stay valid until the swap
        after next — ``run_epoch`` copies them host→device at dispatch,
        so that lifetime is enough by construction."""
        buf = self._bufs[self._active]
        out = StagedEpoch(buf["values"], buf["strata"], buf["counts"],
                          buf["offered"], buf["first_arrival"])
        self._active ^= 1
        nxt = self._bufs[self._active]
        nxt["values"][:] = 0.0
        nxt["strata"][:] = 0
        nxt["counts"][:] = 0
        nxt["offered"][:] = 0
        nxt["first_arrival"][:] = np.inf
        self.swaps += 1
        return out

"""The always-on streaming executor in front of a compiled pipeline.

``StreamingExecutor`` turns ``repro.api.CompiledPipeline`` — a pure
``run_epoch`` function — into a service with the classic streaming
lifecycle (init → subscribe → pump → stop):

* ``start(pipeline, sources)`` subscribes every source's deliveries into
  per-shard bounded queues (``serve.queues``; shard i feeds level-0
  node i).
* ``pump()`` is one tick: sources emit, queues batch-drain
  (``get_many``), items stage into the active host buffer
  (``serve.staging``), and the straggler monitor scores each shard's
  arrival lag against its rolling deadline.
* Every ``epoch_ticks`` pumps, the staged epoch dispatches to the
  device. JAX dispatch is asynchronous, so the NEXT epoch's ingest
  overlaps the in-flight device epoch; the executor measures the
  realized overlap (time spent ingesting while a dispatch was not yet
  ready ÷ total ingest time) rather than claiming it.
* Window publication is straggler-tolerant (``serve.windows``): per
  tick the executor computes the Eq. 9 arrived-weight fraction α —
  arrived items for on-time shards, the shard's EWMA rate as the
  expected-but-missing weight for late ones, plus a virtual absent
  shard carrying this tick's queue drops/truncations — through
  ``StragglerMonitor.calibrate`` (``runtime.straggler.
  calibrate_weights``). α < 1 publishes a *partial* window with
  rescaled linear estimates and 1/α-widened bounds; the late items stay
  queued and fold into the next window.
* ``stop()`` drains: queues empty through extra (source-less) ticks,
  a final short epoch flushes the staged remainder, the last dispatch
  collects. After ``stop()`` no queue holds items — pinned in tests.

Determinism: the epoch PRNG key is ``fold_in(pipeline.default_key,
epoch_index)``, sources are passive between pumps, and the clock is
injectable — a fake clock plus deterministic sources reproduces a run
bit-for-bit.
"""
from __future__ import annotations

import time
from typing import Any, NamedTuple

import jax
import numpy as np

from repro.obs.telemetry import StragglerMonitor
from repro.serve.queues import POLICIES, BoundedShardQueue
from repro.serve.staging import DoubleBuffer
from repro.serve.windows import PublishedWindow, WindowPublisher


class _Pending(NamedTuple):
    """One in-flight dispatched epoch awaiting collection."""

    wa: Any              # WindowAnswers (device arrays, possibly in flight)
    base_tick: int       # global tick of the epoch's first row
    dispatched: float


class StreamingExecutor:
    """See module doc. Construct once, ``start`` per stream session."""

    def __init__(self, *, epoch_ticks: int = 8, width: int = 256,
                 queue_capacity: int = 4096, policy: str = "block",
                 max_records: int | None = None, clock=time.monotonic,
                 straggler_cfg=None, rate_ewma: float = 0.2,
                 seed: int = 0):
        if policy not in POLICIES:
            raise ValueError(f"unknown backpressure policy {policy!r}; "
                             f"valid: {POLICIES}")
        self.epoch_ticks = int(epoch_ticks)
        self.width = int(width)
        self.queue_capacity = int(queue_capacity)
        self.policy = policy
        self.max_records = int(max_records or width)
        self.clock = clock
        self._straggler_cfg = straggler_cfg
        self.rate_ewma = float(rate_ewma)
        self.seed = int(seed)
        self._running = False
        self.published: list[PublishedWindow] = []

    # ----------------------------------------------------------- start --
    def start(self, pipeline, sources, budgets=None,
              warmup: bool = True) -> "StreamingExecutor":
        if self._running:
            raise RuntimeError("executor already started — stop() first")
        self._pipeline = pipeline
        self._budgets = budgets
        self._n_shards = int(pipeline.fanin[0])
        if warmup:
            # Trace/compile the fused epoch program on a throwaway state
            # BEFORE the service clock starts — otherwise the first
            # window's latency would be charged the whole XLA compile.
            scratch, wa = pipeline.run_epoch(
                pipeline.init(), pipeline.default_key,
                np.zeros((self.epoch_ticks, self._n_shards, self.width),
                         np.float32),
                np.zeros((self.epoch_ticks, self._n_shards, self.width),
                         np.int32),
                np.zeros((self.epoch_ticks, self._n_shards), np.int32),
                budgets)
            np.asarray(wa.ok)
            del scratch, wa
        self._sources = list(sources)
        self._queues = [BoundedShardQueue(self.queue_capacity, self.policy,
                                          seed=self.seed + i)
                        for i in range(self._n_shards)]
        self._staging = DoubleBuffer(self.epoch_ticks, self._n_shards,
                                     self.width)
        self._monitor = StragglerMonitor(self._n_shards,
                                         self._straggler_cfg)
        self._publisher = WindowPublisher(pipeline)
        self._state = pipeline.init()
        for src in self._sources:
            src.subscribe(self._deliver)
        now = self.clock()
        self._last_delivery = np.full(self._n_shards, now, np.float64)
        self._rate = np.zeros(self._n_shards, np.float64)
        self._t = 0                    # tick index within current epoch
        self._global_tick = 1          # matches PipelineState.tick init
        self._epoch = 0
        self._last_published_tick = 0
        self._meta: dict[int, dict] = {}
        self._pending: _Pending | None = None
        self._ingest_seconds = 0.0
        self._overlap_seconds = 0.0
        self.published = []
        self._running = True
        return self

    def _deliver(self, shard: int, values, strata):
        if not self._running:
            raise RuntimeError("delivery to a stopped executor")
        self._queues[shard % self._n_shards].put(values, strata,
                                                 self.clock())

    # ------------------------------------------------------------ pump --
    def pump(self) -> list[PublishedWindow]:
        """One tick; returns the windows published during this pump
        (possibly none — publication happens at epoch boundaries)."""
        return self._tick(drain=False)

    def run(self, ticks: int) -> list[PublishedWindow]:
        """``ticks`` pumps back to back; returns what they published."""
        n0 = len(self.published)
        for _ in range(int(ticks)):
            self._tick(drain=False)
        return self.published[n0:]

    def _tick(self, *, drain: bool) -> list[PublishedWindow]:
        if not self._running:
            raise RuntimeError("executor is not started")
        n0 = len(self.published)
        t_start = self.clock()
        device_busy = (self._pending is not None
                       and not _is_ready(self._pending.wa))
        drops0 = sum(q.items_dropped for q in self._queues)
        trunc0 = self._staging.truncated_total
        if not drain:
            for src in self._sources:
                src.pump(t_start)
        arrived = np.zeros(self._n_shards, np.int64)
        for shard, q in enumerate(self._queues):
            values, strata, arrivals = q.get_many(self.max_records)
            arrived[shard] = values.size
            if values.size:
                self._last_delivery[shard] = t_start
                self._staging.stage(self._t, shard, values, strata,
                                    arrival=float(arrivals.min()))
        now = self.clock()
        shed = ((sum(q.items_dropped for q in self._queues) - drops0)
                + (self._staging.truncated_total - trunc0))
        if drain:
            present = np.ones(self._n_shards, bool)
        else:
            present = self._monitor.observe(now - self._last_delivery)
            present = present | (arrived > 0)
        mask = arrived > 0
        fresh = mask & (self._rate == 0.0)
        self._rate = np.where(
            mask, (1.0 - self.rate_ewma) * self._rate
            + self.rate_ewma * arrived, self._rate)
        self._rate = np.where(fresh, arrived, self._rate)
        self._meta[self._global_tick] = self._tick_alpha(
            arrived, present, shed)
        self._t += 1
        self._global_tick += 1
        if self._t == self.epoch_ticks:
            self._flush(self.epoch_ticks)
        dt = self.clock() - t_start
        self._ingest_seconds += dt
        if device_busy:
            self._overlap_seconds += dt
        return self.published[n0:]

    def _tick_alpha(self, arrived, present, shed: int) -> dict:
        """Eq. 9 arrived-weight accounting for one tick: on-time shards
        weigh what they delivered, late shards weigh their EWMA expected
        rate, and a virtual absent shard carries this tick's shed items
        (queue drops + staging truncation). ``calibrate_weights`` scales
        the arrived weights by 1/α — the same factor later widens the
        window's bounds."""
        weight = np.where(present, arrived.astype(np.float64), self._rate)
        w_ext = np.append(weight, float(shed))
        p_ext = np.append(present, shed == 0)
        calibrated = self._monitor.calibrate(w_ext, p_ext)
        live = p_ext & (w_ext > 0)
        kept = float(w_ext[p_ext].sum())
        total = float(w_ext.sum())
        if live.any() and kept > 0.0:
            widen = float((calibrated[live] / w_ext[live]).max())
        else:
            widen = 1.0
        return {
            "kept": kept, "total": total, "widen": widen,
            "late": int((~present).sum()),
            "first_arrival": self._staging.first_arrival(self._t),
        }

    # ------------------------------------------------- epoch lifecycle --
    def _flush(self, n_ticks: int):
        # Always dispatch the full epoch_ticks program: a short final
        # epoch (stop() mid-epoch) keeps its zeroed tail rows, which
        # flush empty root windows (ok=False, no published rows) —
        # reusing the one warm jitted program instead of compiling a
        # fresh one per drain length.
        staged = self._staging.swap()
        self._state = self._monitor.fold_into(self._state)
        key = jax.random.fold_in(self._pipeline.default_key, self._epoch)
        self._state, wa = self._pipeline.run_epoch(
            self._state, key, staged.values, staged.strata, staged.counts,
            self._budgets)
        prev, self._pending = self._pending, _Pending(
            wa=wa, base_tick=self._global_tick - n_ticks,
            dispatched=self.clock())
        if prev is not None:
            self._collect(prev)
        self._epoch += 1
        self._t = 0
        # Padded empty ticks advanced the pipeline's tick counter past
        # the pump count; follow it so later rows keep matching metas.
        self._global_tick += self.epoch_ticks - n_ticks

    def _collect(self, pending: _Pending):
        rows = self._pipeline.rows(pending.wa)   # blocks until ready
        now = self.clock()
        for row in rows:
            tick = int(row["tick"])
            metas = [self._meta.pop(t) for t in
                     range(self._last_published_tick + 1, tick + 1)
                     if t in self._meta]
            kept = sum(m["kept"] for m in metas)
            total = sum(m["total"] for m in metas)
            alpha = kept / total if total > 0.0 else 1.0
            first_arrival = min((m["first_arrival"] for m in metas),
                                default=np.inf)
            self.published.append(self._publisher.publish(
                row, alpha=alpha, partial=alpha < 1.0 - 1e-9,
                publish_time=now, first_arrival=first_arrival))
            self._last_published_tick = tick

    # ------------------------------------------------------------ stop --
    def stop(self) -> dict:
        """Drain and shut down: empty every queue through source-less
        ticks, flush the staged remainder as one short epoch, collect
        the last dispatch. Returns ``stats()``."""
        if not self._running:
            raise RuntimeError("executor is not started")
        # Each drain tick removes up to max_records per queue, so the
        # loop terminates within depth/max_records ticks; the guard only
        # trips on a bookkeeping bug.
        limit = 2 * (self.queue_capacity // max(self.max_records, 1)
                     + self.epoch_ticks + 2)
        for _ in range(limit):
            if not any(q.depth for q in self._queues):
                break
            self._tick(drain=True)
        else:
            raise RuntimeError("drain did not converge — queue depths "
                               f"{[q.depth for q in self._queues]}")
        if self._t > 0:
            self._flush(self._t)
        if self._pending is not None:
            self._collect(self._pending)
            self._pending = None
        self._running = False
        return self.stats()

    # ------------------------------------------------------------ obs --
    @property
    def state(self):
        """The live pipeline state (telemetry snapshots etc.). Do not
        mutate: ``run_epoch`` donates it."""
        return self._state

    @property
    def monitor(self) -> StragglerMonitor:
        """The straggler monitor (running late/widened totals for the
        metrics plane)."""
        return self._monitor

    @property
    def overlap_fraction(self) -> float:
        """Measured ingest/dispatch overlap: share of ingest wall time
        spent while a dispatched epoch was still computing."""
        if self._ingest_seconds <= 0.0:
            return 0.0
        return self._overlap_seconds / self._ingest_seconds

    def window_latencies(self) -> np.ndarray:
        return np.asarray([w.latency for w in self.published
                           if w.latency > 0.0], np.float64)

    def stats(self) -> dict:
        queues = [q.stats() for q in getattr(self, "_queues", [])]
        lat = self.window_latencies()
        partial = sum(1 for w in self.published if w.partial)
        return {
            "policy": self.policy,
            "running": self._running,
            "epochs": getattr(self, "_epoch", 0),
            "queue_depth": [q["depth"] for q in queues],
            "queue_high_watermark": max(
                (q["high_watermark"] for q in queues), default=0),
            "queue_items_in": sum(q["items_in"] for q in queues),
            "queue_items_out": sum(q["items_out"] for q in queues),
            "queue_items_dropped": sum(q["items_dropped"] for q in queues),
            "queue_deferred": sum(q["deferred"] for q in queues),
            "staged_items": getattr(self._staging, "staged_total", 0)
            if hasattr(self, "_staging") else 0,
            "truncated_items": self._staging.truncated_total
            if hasattr(self, "_staging") else 0,
            "overlap_fraction": self.overlap_fraction,
            "ingest_seconds": self._ingest_seconds
            if hasattr(self, "_ingest_seconds") else 0.0,
            "windows_published": len(self.published),
            "windows_partial": partial,
            "latency_p50": float(np.percentile(lat, 50)) if lat.size else 0.0,
            "latency_p99": float(np.percentile(lat, 99)) if lat.size else 0.0,
        }


def _is_ready(wa) -> bool:
    ok = wa.ok
    if hasattr(ok, "is_ready"):
        return bool(ok.is_ready())
    return True

"""The always-on streaming serve plane (ISSUE 9 / ROADMAP item 1).

``StreamingExecutor`` fronts a compiled pipeline with the subscribe →
pump → stop lifecycle: per-shard bounded queues with explicit
backpressure (``queues``), double-buffered host staging that overlaps
ingest with the in-flight device epoch (``staging``), and
straggler-tolerant window publication with Eq. 9-widened partial
answers (``windows``). ``sources`` provides subscribable synthetic and
deterministic sources plus ``LateShardSource`` straggler injection.
"""
from repro.serve.executor import StreamingExecutor
from repro.serve.queues import POLICIES, BoundedShardQueue
from repro.serve.sources import (ConstantSource, LateShardSource,
                                 SyntheticSource)
from repro.serve.staging import DoubleBuffer, StagedEpoch
from repro.serve.windows import PublishedWindow, WindowPublisher

__all__ = [
    "StreamingExecutor", "BoundedShardQueue", "POLICIES", "DoubleBuffer",
    "StagedEpoch", "WindowPublisher", "PublishedWindow", "ConstantSource",
    "SyntheticSource", "LateShardSource",
]

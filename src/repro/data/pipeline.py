"""Approximate training data pipeline: ApproxIoT sampling in front of SGD.

Each interval, a shard's arriving examples are stratified by domain and
reservoir-sampled within the interval budget (``whsamp``); the surviving
examples carry ``W^out`` weights so the weighted loss is an unbiased
estimate of the full-stream loss. This is the paper's edge-sampling tree
with DP shards as the edge nodes and the train step as the root query.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import whs
from repro.core.types import IntervalBatch, StratumMeta
from repro.data.stream import TokenStream


@dataclasses.dataclass
class PipelineConfig:
    batch_size: int          # examples per step fed to the model
    interval_size: int       # examples arriving per interval (pre-sampling)
    num_strata: int
    sampling_fraction: float = 0.5
    allocation: str = "fair"
    seed: int = 0


class ApproxTrainPipeline:
    """Host-side loop: stream → stratified sample → weighted batches."""

    def __init__(self, cfg: PipelineConfig, stream: TokenStream):
        self.cfg = cfg
        self.stream = stream
        self._key = jax.random.PRNGKey(cfg.seed)
        self._sample = jax.jit(self._sample_fn, static_argnames=())
        self.stats = {"arrived": 0, "sampled": 0}

    def _sample_fn(self, key, strata, meta_w, meta_c):
        m = strata.shape[0]
        batch = IntervalBatch(
            value=jnp.zeros((m,), jnp.float32),
            stratum=strata,
            valid=jnp.ones((m,), bool),
            meta=StratumMeta(meta_w, meta_c),
        )
        size = jnp.float32(self.cfg.sampling_fraction * m)
        res = whs.whsamp(key, batch, size, self.cfg.num_strata,
                         allocation=self.cfg.allocation)
        return res.selected, res.meta.weight

    def next_batch(self) -> dict:
        cfg = self.cfg
        ex = self.stream.examples(cfg.interval_size)
        self._key, sub = jax.random.split(self._key)
        sel, w = self._sample(sub, jnp.asarray(ex["stratum"]),
                              jnp.ones((cfg.num_strata,), jnp.float32),
                              jnp.zeros((cfg.num_strata,), jnp.float32))
        sel = np.asarray(sel)
        w = np.asarray(w)
        idx = np.nonzero(sel)[0]
        self.stats["arrived"] += cfg.interval_size
        self.stats["sampled"] += len(idx)
        # pack into a fixed batch (repeat-pad if the sample is short; the
        # pad examples keep their true weights so the estimate stays valid)
        if len(idx) == 0:
            idx = np.arange(min(cfg.batch_size, cfg.interval_size))
            w = np.ones((cfg.num_strata,), np.float32)
        take = np.resize(idx, cfg.batch_size)
        dup = np.bincount(take, minlength=cfg.interval_size).astype(np.float32)
        strat = ex["stratum"][take]
        weight = w[strat] / dup[take]       # split weight across duplicates
        return {
            "tokens": ex["tokens"][take],
            "labels": ex["labels"][take],
            "stratum": strat,
            "weight": weight.astype(np.float32),
        }

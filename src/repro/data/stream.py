"""Synthetic data streams — the paper's §V workloads plus an LM token
stream with domain strata.

Paper microbenchmarks:
  * Gaussian sub-streams A(μ=10,σ=5) B(1e3,50) C(1e4,500) D(1e5,5e3)
  * Poisson  sub-streams A(λ=10) B(100) C(1000) D(10000)
  * skewed arrival-rate settings of §V-D/E (incl. the 80/19.89/0.1/0.01%
    mix with λ_D = 1e7)
Real-world-like stand-ins (no network access in this environment):
  * taxi:      lognormal fares, diurnal rate modulation  (≈ DEBS'15 NYC)
  * pollution: slow-moving AR(1) sensor values           (≈ Brasov/CityBench)
"""
from __future__ import annotations

import dataclasses

import numpy as np

GAUSSIAN = [(10.0, 5.0), (1_000.0, 50.0), (10_000.0, 500.0), (100_000.0, 5_000.0)]
POISSON = [10.0, 100.0, 1_000.0, 10_000.0]
POISSON_SKEWED = [10.0, 100.0, 1_000.0, 10_000_000.0]

# §V-D arrival-rate settings (items/sec for sub-streams A:B:C:D)
RATE_SETTINGS = {
    "setting1": (50_000, 25_000, 12_500, 625),
    "setting2": (25_000, 25_000, 25_000, 25_000),
    "setting3": (625, 12_500, 25_000, 50_000),
}
# §V-E skew: share of items per sub-stream
SKEW_SHARES = (0.80, 0.1989, 0.001, 0.0001)


@dataclasses.dataclass
class SubstreamSpec:
    dist: str           # gaussian | poisson | taxi | pollution
    params: tuple
    rate: float         # items per tick


def paper_gaussian(rates=(1000, 1000, 1000, 1000)) -> list[SubstreamSpec]:
    return [SubstreamSpec("gaussian", g, r) for g, r in zip(GAUSSIAN, rates)]


def paper_poisson(rates=(1000, 1000, 1000, 1000), skewed=False) -> list[SubstreamSpec]:
    lam = POISSON_SKEWED if skewed else POISSON
    return [SubstreamSpec("poisson", (l,), r) for l, r in zip(lam, rates)]


def taxi_like(num_zones: int = 4, rate: float = 1000) -> list[SubstreamSpec]:
    return [SubstreamSpec("taxi", (2.3 + 0.2 * z, 0.5), rate * (0.5 + z))
            for z in range(num_zones)]


def pollution_like(num_sensors: int = 4, rate: float = 200) -> list[SubstreamSpec]:
    return [SubstreamSpec("pollution", (40.0 + 10 * s, 2.0), rate)
            for s in range(num_sensors)]


class StreamSource:
    """One source node emitting a mix of sub-streams each tick."""

    def __init__(self, specs: list[SubstreamSpec], seed: int = 0):
        self.specs = specs
        self.rng = np.random.default_rng(seed)
        self._ar_state = np.array([p.params[0] for p in specs], np.float64)

    def tick(self) -> tuple[np.ndarray, np.ndarray]:
        """→ (values f32[n], strata i32[n]) for one tick."""
        vals, strs = [], []
        for i, sp in enumerate(self.specs):
            n = self.rng.poisson(sp.rate)
            if n == 0:
                continue
            if sp.dist == "gaussian":
                v = self.rng.normal(sp.params[0], sp.params[1], n)
            elif sp.dist == "poisson":
                v = self.rng.poisson(sp.params[0], n).astype(np.float64)
            elif sp.dist == "taxi":
                v = self.rng.lognormal(sp.params[0], sp.params[1], n)
            elif sp.dist == "pollution":
                self._ar_state[i] = (0.98 * self._ar_state[i]
                                     + 0.02 * sp.params[0]
                                     + self.rng.normal(0, sp.params[1]))
                v = self._ar_state[i] + self.rng.normal(0, 0.5, n)
            else:
                raise ValueError(sp.dist)
            vals.append(v)
            strs.append(np.full(n, i, np.int32))
        if not vals:
            return np.zeros(0, np.float32), np.zeros(0, np.int32)
        return (np.concatenate(vals).astype(np.float32),
                np.concatenate(strs))

    def batch(self, ticks: int, width: int | None = None
              ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Tick-major batched generation for the scan engine's epoch
        ingest: ``ticks`` consecutive ``tick()`` draws padded into
        ``(values f32[T, width], strata i32[T, width], counts i32[T])``.
        ``width`` defaults to the largest tick; larger ticks are
        prefix-truncated (the same items a capacity-``width`` buffer
        would keep). Consumes the source RNG exactly like ``ticks``
        sequential ``tick()`` calls."""
        draws = [self.tick() for _ in range(ticks)]
        if width is None:
            width = max((len(v) for v, _ in draws), default=0)
        values = np.zeros((ticks, width), np.float32)
        strata = np.zeros((ticks, width), np.int32)
        counts = np.zeros((ticks,), np.int32)
        for t, (v, s) in enumerate(draws):
            counts[t] = _pack_prefix(values[t], strata[t], v, s, 0, width)
        return values, strata, counts


def _pack_prefix(dst_v: np.ndarray, dst_s: np.ndarray, v: np.ndarray,
                 s: np.ndarray, fill: int, width: int) -> int:
    """THE epoch-ingest backpressure rule, in one place: write the prefix
    of ``v``/``s`` that fits at ``fill`` in a ``width``-slot row, drop the
    rest (what a capacity-``width`` buffer keeps). Returns the new fill."""
    take = min(len(v), width - fill)
    dst_v[fill:fill + take] = v[:take]
    dst_s[fill:fill + take] = s[:take]
    return fill + take


@dataclasses.dataclass
class IngestBatch:
    """One epoch's worth of source→level-0 ingest, tick-major.

    ``values``/``strata`` are ``[T, n_nodes, width]`` padded arrays,
    ``counts`` the ``[T, n_nodes]`` per-tick item counts after ``width``
    truncation — the layout ``HostTree.run_epoch`` moves host→device in
    one transfer. ``offered`` is the pre-truncation per-(tick, node)
    count (what the sequential drivers' ``items_ingested`` sees). The
    exact ground-truth aggregates (pre-truncation, accumulated in the
    same (tick, source) order as the sequential drivers) ride along for
    accuracy accounting.
    """

    values: np.ndarray
    strata: np.ndarray
    counts: np.ndarray
    offered: np.ndarray
    exact_sum: float
    exact_count: int


def batch_ingest(sources: list[StreamSource], ticks: int, n_nodes: int,
                 width: int) -> IngestBatch:
    """Assemble an epoch's ingest for ``n_nodes`` level-0 nodes.

    Source ``i`` feeds node ``i % n_nodes`` (the testbed wiring); per
    (tick, node) the sources' items are concatenated in source order and
    prefix-truncated at ``width`` — exactly the order and backpressure a
    sequential ``ingest()`` loop produces. The source RNGs are consumed
    tick-major, matching the sequential drivers draw for draw.
    """
    values = np.zeros((ticks, n_nodes, width), np.float32)
    strata = np.zeros((ticks, n_nodes, width), np.int32)
    counts = np.zeros((ticks, n_nodes), np.int32)
    offered = np.zeros((ticks, n_nodes), np.int32)
    exact_sum = 0.0
    exact_count = 0
    for t in range(ticks):
        fill = [0] * n_nodes
        for i, src in enumerate(sources):
            v, s = src.tick()
            exact_sum += float(v.sum())
            exact_count += len(v)
            node = i % n_nodes
            offered[t, node] += len(v)
            fill[node] = _pack_prefix(values[t, node], strata[t, node],
                                      v, s, fill[node], width)
        counts[t] = fill
    return IngestBatch(values, strata, counts, offered, exact_sum,
                       exact_count)


def ticks_to_ingest(tick_records, n_nodes: int, width: int) -> IngestBatch:
    """Pack host-collected per-tick records into the tick-major
    ``[T, n_nodes, width]`` epoch-ingest layout.

    ``tick_records`` is a list of ``(values, strata)`` pairs, one per
    tick (e.g. one serving batch's telemetry records per tick). Within a
    tick, item ``i`` lands on level-0 node ``i % n_nodes`` (round-robin
    in arrival order — the testbed's source wiring); per (tick, node)
    the items are prefix-truncated at ``width`` with the standard
    backpressure rule. Lets any host-side record stream (per-request
    telemetry, log events) drive a compiled pipeline's ``run_epoch``.
    """
    ticks = len(tick_records)
    values = np.zeros((ticks, n_nodes, width), np.float32)
    strata = np.zeros((ticks, n_nodes, width), np.int32)
    counts = np.zeros((ticks, n_nodes), np.int32)
    offered = np.zeros((ticks, n_nodes), np.int32)
    exact_sum = 0.0
    exact_count = 0
    for t, (v, s) in enumerate(tick_records):
        v = np.asarray(v, np.float32)
        s = np.asarray(s, np.int32)
        exact_sum += float(v.sum())
        exact_count += len(v)
        for node in range(n_nodes):
            vv, ss = v[node::n_nodes], s[node::n_nodes]
            offered[t, node] = len(vv)
            counts[t, node] = _pack_prefix(values[t, node], strata[t, node],
                                           vv, ss, 0, width)
    return IngestBatch(values, strata, counts, offered, exact_sum,
                       exact_count)


def rows_to_interval_batch(values: np.ndarray, strata: np.ndarray,
                           counts: np.ndarray, num_strata: int,
                           width: int | None = None):
    """Padded per-tick rows → the ``IntervalBatch``-with-tick-axis layout
    the SPMD pipeline consumes (``repro.api.compile(spec, mesh=...)``).

    ``values``/``strata`` are ``[T, W]`` padded rows with ``counts[T]``
    live items each (``StreamSource.batch`` emits exactly this; host
    record streams can go through ``ticks_to_ingest(..., n_nodes=1)``
    first). ``width`` re-pads the item axis — pass a multiple of the
    mesh axis size so the batch shards evenly; padding slots carry
    ``valid=False`` and are never sampled. Metadata is the source
    identity (weight 1, count 0) per tick.
    """
    import jax.numpy as jnp

    from repro.core.types import IntervalBatch, StratumMeta

    ticks, w0 = values.shape
    width = int(width or w0)
    if width != w0:
        out_v = np.zeros((ticks, width), np.float32)
        out_s = np.zeros((ticks, width), np.int32)
        keep = min(w0, width)
        out_v[:, :keep] = values[:, :keep]
        out_s[:, :keep] = strata[:, :keep]
        values, strata = out_v, out_s
        counts = np.minimum(counts, width)
    valid = np.arange(width)[None, :] < np.asarray(counts)[:, None]
    return IntervalBatch(
        value=jnp.asarray(values, jnp.float32),
        stratum=jnp.asarray(strata, jnp.int32),
        valid=jnp.asarray(valid),
        meta=StratumMeta(jnp.ones((ticks, num_strata), jnp.float32),
                         jnp.zeros((ticks, num_strata), jnp.float32)))


class TokenStream:
    """LM training stream: ``num_strata`` domains with distinct unigram
    stats and arrival rates — the ApproxIoT strata for approx-training."""

    def __init__(self, vocab: int, seq_len: int, num_strata: int,
                 rates: list[float] | None = None, seed: int = 0):
        self.vocab = vocab
        self.seq_len = seq_len
        self.num_strata = num_strata
        self.rates = np.asarray(rates if rates is not None
                                else [1.0] * num_strata, np.float64)
        self.rates = self.rates / self.rates.sum()
        self.rng = np.random.default_rng(seed)
        # distinct zipf-ish unigram distribution per domain
        self._offsets = self.rng.integers(0, vocab, num_strata)

    def examples(self, n: int) -> dict:
        """n example sequences with domain (stratum) tags."""
        strata = self.rng.choice(self.num_strata, n, p=self.rates).astype(np.int32)
        ranks = self.rng.zipf(1.3, size=(n, self.seq_len + 1))
        toks = (ranks + self._offsets[strata][:, None]) % self.vocab
        toks = toks.astype(np.int32)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "stratum": strata,
        }

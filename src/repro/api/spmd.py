"""``compile(spec, mesh=...)`` — the same spec on the pod-scale data plane.

The SPMD lowering of a ``PipelineSpec`` is the paper's §III-E hierarchy
run in-graph across a mesh axis, one jitted dispatch per epoch of ``T``
interval batches. Three lowerings share the front door:

* **Query tenants registered** (the full multi-tenant query plane):
  every device WHS-samples its shard of each window with the spec's
  backend/allocation and its own DONATED sketch state, and the window is
  answered by one batched root ``MultiTenantPlan`` evaluation over
  MERGED per-device summaries — ``psum``-ed CLT moments, all-gathered
  quantile buffers and count-min tables (``query.sketches`` merge
  algebra). Only O(sketch) summaries ever cross the device boundary;
  raw reservoir items never do. Per-tenant ``WindowAnswers`` come back
  with the same routing surface as the local pipeline
  (``answer``/``tenant_answers``/``tenant_rel_errors``), so the
  worst-tenant-first error-budget loop closes on the mesh: the applied
  sample budget is a TRACED input — moving it between epochs never
  retraces. State (global tick + per-device sketches) is explicit and
  donated, so multi-epoch runs resume bit-identically to one long epoch.
* **``whs`` without tenants** (the original §III-E two-level path):
  every device samples its local interval batch, compacts to the spec's
  level-0 budget, all-gathers the *reservoirs*, and the root stage
  re-samples and answers SUM/MEAN with error bounds —
  ``core.tree.spmd_local_then_root_epoch``. Stateless between intervals.
* **``srs``** (the §IV-B baseline): per-device coin-flip keeps, HT
  SUM / sample MEAN merged from ``psum``-ed moments — no items cross.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import spec as specmod
from repro.api.pipeline import QueryRouting, WindowAnswers
from repro.api.spec import PipelineSpec, SpecError
from repro.core import tree as T
from repro.core.types import IntervalBatch
from repro.launch.sharding import spmd_epoch_specs, spmd_query_epoch_specs


def _shard_map():
    try:
        return jax.shard_map                       # jax >= 0.6
    except AttributeError:
        from jax.experimental.shard_map import shard_map

        return shard_map


def _rep_check_kwargs(fn, enabled: bool) -> dict:
    """The replication-check kwarg was renamed ``check_rep`` →
    ``check_vma`` across jax versions; pass whichever this build has."""
    import inspect

    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):                # pragma: no cover
        params = {}
    name = "check_vma" if "check_vma" in params else "check_rep"
    return {name: enabled}


class SpmdPipelineState(NamedTuple):
    """Explicit state of the tenant SPMD pipeline: the next global tick
    (i32 scalar, replicated) and the standing queries' sketch state with
    a leading per-device axis sharded over the mesh (device ``d`` owns
    slice ``[d]`` of every leaf). A plain pytree — donate it into
    ``run_epoch`` exactly like the local ``PipelineState``."""

    tick: Any
    qstate: Any
    # Optional replicated ``repro.obs.telemetry.EpochTelemetry`` leaves
    # (every counter derives from psums, so every device carries the
    # identical values); ``()`` when telemetry is disabled.
    telemetry: Any = ()


# Traced-program cache for the tenant lowering, keyed on everything the
# shard_map'd epoch closes over — with the plan component being the
# canonical name-free ``SlotPlanCore``, so tenant churn (admit/retire)
# and re-compiles of same-shaped specs reuse ONE jitted executable per
# (mesh, bucket configuration). Mirrors ``api.pipeline._PROGRAM_CACHE``.
_SPMD_PROGRAM_CACHE: dict = {}
_SPMD_PROGRAM_STATS = {"misses": 0, "hits": 0}


def spmd_program_cache_stats() -> dict:
    """{"misses": distinct shard_map'd programs traced, "hits": reuses}
    — the mesh-path counterpart of ``pipeline.program_cache_stats``,
    consumed by the ``repro.obs.metrics`` exposition layer."""
    return dict(_SPMD_PROGRAM_STATS)


def _spmd_program_entry(mesh, axis_name, core, max_budget, num_strata,
                        allocation, backend,
                        telemetry=False) -> tuple[tuple, dict]:
    sig = (mesh, axis_name, core, max_budget, num_strata, allocation,
           backend, telemetry)
    entry = _SPMD_PROGRAM_CACHE.get(sig)
    if entry is not None:
        _SPMD_PROGRAM_STATS["hits"] += 1
        return sig, entry
    _SPMD_PROGRAM_STATS["misses"] += 1
    sm = _shard_map()
    rep_kw = _rep_check_kwargs(sm, backend != "pallas")
    counter = {"traces": 0}
    parts = spmd_query_epoch_specs(axis_name, core.init_state())
    # The telemetry leaves are replicated by construction (psum-derived);
    # a single P() prefix covers the whole subtree — and the empty ``()``
    # subtree when telemetry is off.
    state_spec = SpmdPipelineState(tick=parts["replicated"],
                                   qstate=parts["qstate"],
                                   telemetry=parts["replicated"])
    kw = dict(axis_name=axis_name, max_budget=max_budget,
              num_strata=num_strata, allocation=allocation,
              sampler_backend=backend)

    def epoch(state, key, budget, batches):
        counter["traces"] += 1
        n_ticks = batches.value.shape[0]
        local_q = jax.tree.map(lambda v: v[0], state.qstate)
        qfinal, outs = T.spmd_query_plane_epoch(
            key, state.tick, budget, batches, local_q, core, **kw)
        ts = state.tick + jnp.arange(n_ticks, dtype=jnp.int32)
        tel = state.telemetry
        if telemetry:
            # All counters derive from psum/pmean outputs (replicated →
            # axis-invariant), so the update costs one extra psum of a
            # [T] vector and stays inside the same epoch dispatch.
            ok, se, sv, nsel = outs[0], outs[1], outs[2], outs[5]
            ans, bnd = outs[7], outs[8]
            off_t = jax.lax.psum(
                jnp.sum(batches.valid.astype(jnp.float32), axis=1),
                axis_name)
            kept_t = nsel.astype(jnp.float32)
            rel = bnd / jnp.maximum(jnp.abs(ans), 1e-9)
            tel = tel._replace(
                items_in=tel.items_in + jnp.sum(off_t),
                items_kept=tel.items_kept + jnp.sum(kept_t),
                flushes=tel.flushes + jnp.sum(ok.astype(jnp.int32)),
                saturation_hits=tel.saturation_hits + jnp.sum(
                    (ok & (kept_t >= off_t)).astype(jnp.int32)),
                windows=tel.windows + jnp.sum(ok.astype(jnp.int32)),
                root_sum=tel.root_sum + jnp.sum(jnp.where(ok, se, 0.0)),
                root_sum_var=tel.root_sum_var
                + jnp.sum(jnp.where(ok, sv, 0.0)),
                slot_rel_bound_sum=tel.slot_rel_bound_sum
                + jnp.sum(jnp.where(ok[:, None], rel, 0.0), axis=0))
        state2 = SpmdPipelineState(
            tick=state.tick + jnp.int32(n_ticks),
            qstate=jax.tree.map(lambda v: v[None], qfinal),
            telemetry=tel)
        return state2, (ts,) + outs

    fn = sm(epoch, mesh=mesh,
            in_specs=(state_spec, parts["replicated"],
                      parts["replicated"], parts["batches"]),
            out_specs=(state_spec, parts["replicated"]), **rep_kw)
    entry = {"fn": jax.jit(fn, donate_argnums=(0,)),
             "trace_counter": counter}
    _SPMD_PROGRAM_CACHE[sig] = entry
    return sig, entry


class CompiledSpmdPipeline(QueryRouting):
    """Immutable SPMD compilation of one ``PipelineSpec`` (see module
    doc for the three lowerings).

    ``run_epoch(state, key, batches[, budgets])`` takes an
    ``IntervalBatch`` whose leaves carry a leading tick axis
    (``value[T, M]`` sharded over the mesh axis on M). With tenants it
    returns ``(state', WindowAnswers)`` — per-window answers/bounds for
    every tenant, replicated across the axis (every device evaluates the
    root redundantly from the identical merged summaries; no single
    point of failure). Without tenants it returns the legacy
    ``(state, (sum, mean))`` per-tick ``QueryResult`` pair."""

    def __init__(self, spec: PipelineSpec, mesh, *, axis_name: str = "data"):
        if axis_name not in mesh.axis_names:
            raise SpecError(f"mesh has no axis {axis_name!r} "
                            f"(axes: {mesh.axis_names})")
        r = specmod.resolve(spec)
        self.spec = spec
        self.mesh = mesh
        self.axis_name = axis_name
        self.n_devices = int(dict(mesh.shape)[axis_name])
        self.plan = r.plan
        self.tenant_names = tuple(t.name for t in spec.tenants)
        self.local_budget = int(r.sample_sizes[0])
        self.max_local_budget = int(r.max_sample_sizes[0])
        self.root_budget = int(r.sample_sizes[-1])
        self.telemetry_enabled = spec.telemetry.enabled
        self.trace_counter = {"traces": 0}
        sm = _shard_map()
        # pallas_call has no replication rule under shard_map's rep/vma
        # check — the kernel backend opts out (results are still
        # replicated by construction, see spmd_local_then_root).
        rep_kw = _rep_check_kwargs(sm, spec.sampler.backend != "pallas")
        if self.plan is not None:
            # Tenant lowering: merged-summary query plane. Spec
            # validation already guarantees mode == "whs" here (tenants
            # need WHS stratum metadata). The traced epoch closes over
            # the name-free slot CORE and is fetched from the program
            # cache, so churned pipelines reuse the executable.
            self._program_sig, entry = _spmd_program_entry(
                mesh, axis_name, self.plan.core, self.max_local_budget,
                spec.topology.num_strata, spec.sampler.allocation,
                spec.sampler.backend, telemetry=self.telemetry_enabled)
            self._fn = entry["fn"]
            self.trace_counter = entry["trace_counter"]
        elif spec.sampler.mode == "srs":
            in_specs, out_specs = spmd_epoch_specs(axis_name)
            frac = float(spec.sampler.fraction)

            def srs_epoch(key, batches):
                self.trace_counter["traces"] += 1
                return T.spmd_srs_epoch(key, batches, axis_name=axis_name,
                                        fraction=frac)

            fn = sm(srs_epoch, mesh=mesh, in_specs=in_specs,
                    out_specs=out_specs, **rep_kw)
            self._fn = jax.jit(fn)
        else:
            in_specs, out_specs = spmd_epoch_specs(axis_name)
            kw = dict(axis_name=axis_name,
                      num_strata=spec.topology.num_strata,
                      local_budget=self.local_budget,
                      root_budget=self.root_budget,
                      allocation=spec.sampler.allocation,
                      sampler_backend=spec.sampler.backend)
            fn = sm(lambda k, b: T.spmd_local_then_root_epoch(k, b, **kw),
                    mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    **rep_kw)
            self._fn = jax.jit(fn)

    # ---------------------------------------------------- tenant churn --
    def _with_plan(self, plan, tenants) -> "CompiledSpmdPipeline":
        import dataclasses

        pipe = object.__new__(CompiledSpmdPipeline)
        pipe.__dict__.update(self.__dict__)
        pipe.plan = plan
        pipe.tenant_names = plan.tenant_names
        # reuse the caller's TenantSpec objects: admit stays O(live)
        pipe.spec = dataclasses.replace(self.spec, tenants=tuple(tenants))
        if plan.core is not self.plan.core:
            # bucket boundary crossed: fetch/build the next bucket's
            # cached executable (same mesh, same statics, CHURNED core)
            pipe._program_sig, entry = _spmd_program_entry(
                self.mesh, self.axis_name, plan.core,
                self.max_local_budget, self.spec.topology.num_strata,
                self.spec.sampler.allocation, self.spec.sampler.backend,
                telemetry=self.telemetry_enabled)
            pipe._fn = entry["fn"]
            pipe.trace_counter = entry["trace_counter"]
        return pipe

    def _sync_telemetry_slots(self, state, n_out: int):
        """Keep the telemetry ``slot_rel_bound_sum`` leaf in step with a
        churned plan's padded answer width (same rule as the local
        pipeline's ``_sync_telemetry_slots``)."""
        tel = getattr(state, "telemetry", ())
        if not hasattr(tel, "slot_rel_bound_sum"):
            return state
        cur = tel.slot_rel_bound_sum
        if cur.shape[0] == n_out:
            return state
        if cur.shape[0] < n_out:
            new = jnp.concatenate(
                [cur, jnp.zeros((n_out - cur.shape[0],), cur.dtype)])
        else:
            new = cur[:n_out]
        return state._replace(
            telemetry=tel._replace(slot_rel_bound_sum=new))

    def admit(self, state, tenant
              ) -> tuple["CompiledSpmdPipeline", "SpmdPipelineState"]:
        """Mesh-path hot admission: edits every device's slot row
        (``[n_devices, n_slots, ...]`` leaves at ``[:, slot]``) and the
        replicated-in-content active mask — a pure sharded-state edit;
        the shard_map'd epoch executable is reused from the program
        cache."""
        if self.plan is None:
            raise SpecError("admit() needs a tenanted pipeline — compile "
                            "with at least one TenantSpec")
        try:
            new_plan, transform = self.plan.admit(tenant.name,
                                                  tuple(tenant.queries))
        except (KeyError, ValueError) as e:
            raise SpecError(str(e)) from e
        qstate = transform(state.qstate, 1)    # axis 0 = device
        state = self._sync_telemetry_slots(
            state._replace(qstate=qstate), new_plan.core.n_out)
        return (self._with_plan(new_plan, self.spec.tenants + (tenant,)),
                state)

    def retire(self, state, tenant_id: str
               ) -> tuple["CompiledSpmdPipeline", "SpmdPipelineState"]:
        """Mesh-path retirement: flips the slot's mask bit on every
        device; state freezes, the slot recycles on a later admit."""
        if self.plan is None:
            raise SpecError("retire() needs a tenanted pipeline")
        try:
            new_plan, transform = self.plan.retire(tenant_id)
        except (KeyError, ValueError) as e:
            raise SpecError(str(e)) from e
        qstate = transform(state.qstate, 1)
        state = self._sync_telemetry_slots(
            state._replace(qstate=qstate), new_plan.core.n_out)
        return (self._with_plan(
            new_plan, tuple(t for t in self.spec.tenants
                            if t.name != tenant_id)),
            state)

    @property
    def default_key(self) -> jax.Array:
        return jax.random.PRNGKey(self.spec.seed)

    def init(self, key: jax.Array | None = None):
        """Fresh explicit state. With tenants: global tick 0 plus one
        empty sketch state per device (leaves ``[n_devices, ...]``).
        Without tenants the path is stateless between intervals (each
        interval batch is complete): empty pytree."""
        del key
        if self.plan is None:
            return ()
        from jax.sharding import NamedSharding, PartitionSpec as P

        q0 = self.plan.init_state()
        # commit with the exact shardings the epoch fn emits, so every
        # epoch (first included) hits one compiled executable
        stacked = jax.tree.map(
            lambda v: jax.device_put(
                jnp.stack([v] * self.n_devices),
                NamedSharding(self.mesh, P(self.axis_name))), q0)
        tick = jax.device_put(jnp.int32(0),
                              NamedSharding(self.mesh, P()))
        tel = ()
        if self.telemetry_enabled:
            from repro.obs.telemetry import EpochTelemetry

            # single merged "level", no per-stratum root telemetry on
            # the summary-merge path (strata merge via psums, not a
            # single root SampleResult), padded slot width from the core
            tel = jax.tree.map(
                lambda v: jax.device_put(v, NamedSharding(self.mesh, P())),
                EpochTelemetry.create(1, 0, self.plan.core.n_out))
        return SpmdPipelineState(tick=tick, qstate=stacked, telemetry=tel)

    def telemetry_snapshot(self, state) -> dict | None:
        """Host-readable snapshot of the in-graph telemetry counters
        (``None`` when disabled) — see ``repro.obs.snapshot``."""
        from repro.obs.telemetry import snapshot

        return snapshot(state)

    def clamp_budgets(self, budgets) -> float:
        """Applied level-0 sample budget clamped to [1, ceiling] — same
        rule as the local pipeline; accepts a scalar or the per-level
        list every driver passes (only level 0 exists on this path)."""
        if budgets is None:
            return float(self.local_budget)
        if np.ndim(budgets) > 0:
            budgets = np.asarray(budgets).reshape(-1)[0]
        return min(max(float(budgets), 1.0), float(self.max_local_budget))

    def _check_batches(self, batches: IntervalBatch) -> None:
        m = batches.value.shape[-1]
        if m % self.n_devices:
            raise SpecError(
                f"the interval item axis ({m} slots) must divide evenly "
                f"across mesh axis {self.axis_name!r} ({self.n_devices} "
                f"devices) — pad the epoch batches to a multiple of the "
                f"axis size (padding slots carry valid=False)")

    def run_epoch(self, state, key: jax.Array, batches: IntervalBatch,
                  budgets=None):
        """``T`` interval batches in one dispatch.

        Tenant path: window ``i`` folds the global tick ``state.tick+i``
        into ``key`` (multi-epoch runs resume bit-identically);
        ``state`` is donated — do not reuse the argument. ``budgets``
        (traced) moves the applied level-0 sample budget with zero
        retraces. Returns ``(state', WindowAnswers)``.

        Legacy/no-tenant paths: stateless — tick ``i`` folds ``i`` into
        ``key``, bit-matching ``T`` per-interval calls; returns
        ``(state, (sum, mean))``."""
        self._check_batches(batches)
        if self.plan is None:
            if budgets is not None:
                raise SpecError("budgets are traced inputs of the tenant "
                                "query plane only — the no-tenant SPMD "
                                "path bakes the spec's budgets statically")
            return state, self._fn(key, batches)
        b = jnp.float32(self.clamp_budgets(budgets))
        state, outs = self._fn(state, key, b, batches)
        ts, ok, se, sv, me, mv, nsel, hist, ans, bnd = outs
        tel = getattr(state, "telemetry", ())
        if hasattr(tel, "merge_bytes"):
            # The byte model depends on the LIVE tenant set (admit/retire
            # change what crosses the axis), so the fold happens here on
            # the host per epoch rather than being baked into the traced
            # program: windows × the current static per-window model.
            windows_delta = int(np.asarray(ok).sum())
            state = state._replace(telemetry=tel._replace(
                merge_bytes=tel.merge_bytes + jnp.float32(
                    windows_delta * self.summary_bytes_per_window)))
        # padded slot vector → public live-tenant vector (eager gather
        # outside the jit — follows churn with zero retraces)
        ans, bnd = self.plan.compact(ans), self.plan.compact(bnd)
        wa = WindowAnswers(
            tick=ts, ok=ok, sum=se, sum_var=sv, mean=me, mean_var=mv,
            n_sampled=nsel, histogram=hist, answers=ans, bounds=bnd,
            # no raw items ever cross a boundary on this path — the
            # would-be "forwarded items" channel is identically empty
            n_forwarded=np.zeros((len(np.asarray(ts)), 1), np.int32))
        return state, wa

    @property
    def summary_bytes_per_window(self) -> int:
        """Upper bound on the per-device bytes the tenant query plane
        ships per window: sketch summaries (quantile value/weight
        buffers, CM tables via psum, top-k candidate keys) plus the
        per-query CLT/histogram moment scalars and the built-in
        workload's per-stratum reductions. Compare against
        ``reservoir_bytes_per_window`` — the cost the reservoir
        all-gather of the no-tenant path would pay (the README
        bandwidth table; asserted against the traced collectives in
        ``tests/test_spmd_query_plane.py``)."""
        if self.plan is None:
            return 0
        plans = getattr(self.plan, "plans", (self.plan,))
        n = 0
        for p in plans:
            for sp in p.specs:
                if sp.kind == "quantile":
                    n += (2 * sp.capacity + 1) * 4      # value+weight+comps
                elif sp.kind == "heavy_hitters":
                    n += (sp.depth * sp.width + sp.k) * 4  # CM psum + keys
                elif sp.kind == "histogram":
                    n += 2 * sp.bins * 4                # est + var psums
                else:
                    n += 3 * 4                          # est/var/share
        x = self.spec.topology.num_strata
        return n + (64 + 4 * x + 8) * 4  # built-in hist + moments + scalars

    @property
    def reservoir_bytes_per_window(self) -> int:
        """What the same window costs when compacted reservoirs cross
        instead (value f32 + stratum i32 + valid per kept item, plus the
        W/C metadata sets) — the no-tenant path's all-gather."""
        x = self.spec.topology.num_strata
        return self.local_budget * (4 + 4 + 1) + 2 * x * 4

"""``compile(spec, mesh=...)`` — the same spec on the pod-scale data plane.

The SPMD lowering of a ``PipelineSpec`` is the paper's §III-E two-level
hierarchy run in-graph across a mesh axis: every device WHS-samples its
local interval batch with the spec's backend/allocation, compacts to the
spec's level-0 budget, all-gathers the *reservoirs only*, and the root
stage re-samples to the spec's root budget and answers SUM/MEAN with
error bounds — ``core.tree.spmd_local_then_root_epoch`` under
``shard_map``, one dispatch per epoch of ``T`` interval batches.

The pipeline is stateless between intervals (the SPMD path carries no
sticky windows — each interval batch is complete), so ``init`` returns
an empty state and ``run_epoch`` is a pure function of (key, batches).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.api import spec as specmod
from repro.api.spec import PipelineSpec, SpecError
from repro.core import tree as T
from repro.core.types import IntervalBatch
from repro.launch.sharding import spmd_epoch_specs


def _shard_map():
    try:
        return jax.shard_map                       # jax >= 0.6
    except AttributeError:
        from jax.experimental.shard_map import shard_map

        return shard_map


def _rep_check_kwargs(fn, enabled: bool) -> dict:
    """The replication-check kwarg was renamed ``check_rep`` →
    ``check_vma`` across jax versions; pass whichever this build has."""
    import inspect

    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):                # pragma: no cover
        params = {}
    name = "check_vma" if "check_vma" in params else "check_rep"
    return {name: enabled}


class CompiledSpmdPipeline:
    """Immutable SPMD compilation of one ``PipelineSpec``.

    ``run_epoch(state, key, batches)`` takes an ``IntervalBatch`` whose
    leaves carry a leading tick axis (``value[T, M]`` sharded over the
    mesh axis on M) and returns ``(state, (sum, mean))`` — per-tick
    ``QueryResult``s with rigorous variance, replicated across the axis
    (every device computes the root redundantly; no single point of
    failure)."""

    def __init__(self, spec: PipelineSpec, mesh, *, axis_name: str = "data"):
        if spec.sampler.mode != "whs":
            raise SpecError("the SPMD path runs the weighted hierarchical "
                            "sampler: use sampler.mode='whs' (the SRS "
                            "baseline exists only in the emulated tree)")
        if spec.tenants:
            raise SpecError("query tenants are not lowered to the SPMD "
                            "path yet — drop spec.tenants for mesh "
                            "compilation (the root answers SUM/MEAN with "
                            "bounds); see ROADMAP 'Sketch answers inside "
                            "spmd_local_then_root'")
        if axis_name not in mesh.axis_names:
            raise SpecError(f"mesh has no axis {axis_name!r} "
                            f"(axes: {mesh.axis_names})")
        r = specmod.resolve(spec)
        self.spec = spec
        self.mesh = mesh
        self.axis_name = axis_name
        self.local_budget = int(r.sample_sizes[0])
        self.root_budget = int(r.sample_sizes[-1])
        in_specs, out_specs = spmd_epoch_specs(axis_name)
        kw = dict(axis_name=axis_name,
                  num_strata=spec.topology.num_strata,
                  local_budget=self.local_budget,
                  root_budget=self.root_budget,
                  allocation=spec.sampler.allocation,
                  sampler_backend=spec.sampler.backend)
        sm = _shard_map()
        # pallas_call has no replication rule under shard_map's rep/vma
        # check — the kernel backend opts out (results are still
        # replicated by construction, see spmd_local_then_root).
        fn = sm(lambda k, b: T.spmd_local_then_root_epoch(k, b, **kw),
                mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                **_rep_check_kwargs(sm, spec.sampler.backend != "pallas"))
        self._fn = jax.jit(fn)

    @property
    def default_key(self) -> jax.Array:
        return jax.random.PRNGKey(self.spec.seed)

    def init(self, key: jax.Array | None = None) -> tuple:
        """The SPMD path carries no cross-interval state: empty pytree."""
        del key
        return ()

    def run_epoch(self, state: tuple, key: jax.Array,
                  batches: IntervalBatch):
        """``T`` interval batches in one dispatch; tick ``i`` folds ``i``
        into ``key``, bit-matching ``T`` per-interval calls."""
        return state, self._fn(key, batches)

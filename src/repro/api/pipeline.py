"""``compile(spec) → (init, step/run_epoch)`` — the one runtime.

``CompiledPipeline`` is the immutable compilation of a ``PipelineSpec``:
it closes over every static quantity (topology, capacities, budget
ceilings, the fused multi-tenant query plan) and exposes pure,
jax-style entry points:

* ``init(key) -> PipelineState``             — fresh explicit state
  (the whole tree's window/reservoir/sketch buffers as one pytree plus
  the global tick counter). No hidden mutation anywhere: checkpointing
  is ``checkpoint.manager.save(state)``, and vmapping a pipeline over
  keys/budgets is just ``jax.vmap`` over these functions.
* ``run_epoch(state, key, values, strata, counts, budgets)
  -> (state', WindowAnswers)``               — ``T`` ticks fused into
  ONE jitted ``lax.scan`` dispatch with ``state`` donated; the fused
  tree-step is ``core.tree._build_scan_tick``, the same traced program
  the ``HostTree`` scan engine runs, so answers and sample state are
  bit-identical to every legacy engine (scan ≡ level ≡ loop).
* ``step(...)``                              — ``run_epoch`` with T=1
  (one dispatch per tick — the ``level``/``loop`` dispatch granularity
  on the same runtime).

``budgets`` are traced inputs: the closed-loop controller moves
per-level sample sizes between epochs with zero retraces. With N
tenants the root evaluates one fused plan and ``WindowAnswers`` routes
per-tenant answer slices and per-tenant error attribution back out —
N registries, one tree dispatch per epoch.

``compile(spec, mesh=...)`` lowers the same spec onto a device mesh
(see ``repro.api.spmd``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import spec as specmod
from repro.api.spec import PipelineSpec, SpecError
from repro.core import tree as T
from repro.core.window import TreeState


class PipelineState(NamedTuple):
    """Explicit pipeline state: the whole hierarchy's on-device buffers
    (``core.window.TreeState``, query-sketch state included) plus the
    next global tick. A plain pytree — donate it into ``run_epoch``,
    checkpoint it with ``checkpoint.manager``, vmap over it."""

    tree: TreeState
    tick: Any          # i32 scalar: next global tick to execute


class WindowAnswers(NamedTuple):
    """One epoch's stacked per-window outputs (leading axis = tick).

    ``ok`` masks ticks whose root window actually flushed items; the
    built-in workload (SUM/MEAN ± variance, sample count, histogram)
    is always present; ``answers``/``bounds`` are the standing-query
    plan's flat vectors (``None`` without tenants); ``n_forwarded`` is
    the per-(tick, level) forwarded-item count (bandwidth accounting).
    """

    tick: Any
    ok: Any
    sum: Any
    sum_var: Any
    mean: Any
    mean_var: Any
    n_sampled: Any
    histogram: Any
    answers: Any
    bounds: Any
    n_forwarded: Any


class QueryRouting:
    """Per-tenant answer routing + error attribution over a compiled
    (possibly multi-tenant) query plan — shared by the local
    ``CompiledPipeline`` and the mesh ``repro.api.spmd.
    CompiledSpmdPipeline``, so a driver can consume either front door's
    ``WindowAnswers`` through one surface. Consumers need
    ``self.plan`` (compiled plan or ``None``) and ``self.tenant_names``.
    """

    plan = None
    tenant_names: tuple = ()

    # -------------------------------------------------------- routing --
    def rows(self, wa: "WindowAnswers") -> list[dict]:
        """Host-side result rows (one dict per flushed root window) in
        the legacy ``HostTree.results`` layout — the migration shim for
        drivers that consumed the old list."""
        host = [np.asarray(x) for x in
                (wa.tick, wa.ok, wa.sum, wa.sum_var, wa.mean, wa.mean_var,
                 wa.n_sampled, wa.histogram)]
        ts, ok, se, sv, me, mv, nsel, hist = host
        ans = np.asarray(wa.answers) if wa.answers is not None else None
        bnd = np.asarray(wa.bounds) if wa.bounds is not None else None
        out = []
        for i in range(len(ts)):
            if not ok[i]:
                continue
            row = dict(tick=int(ts[i]), sum=float(se[i]),
                       sum_var=float(sv[i]), mean=float(me[i]),
                       mean_var=float(mv[i]), n_sampled=int(nsel[i]),
                       histogram=hist[i])
            if ans is not None:
                row["answers"], row["bounds"] = ans[i], bnd[i]
            out.append(row)
        return out

    def query_layout(self, tenant: str | None = None) -> dict:
        """name → (offset, width, kind) into the flat answer vector.
        With several tenants names are ``"tenant/query"``; pass
        ``tenant=`` for one tenant's block with local names and
        absolute offsets."""
        if self.plan is None:
            raise SpecError("this pipeline registers no query tenants")
        if tenant is None:
            return self.plan.layout()
        if len(self.tenant_names) == 1:
            if tenant != self.tenant_names[0]:
                raise KeyError(f"unknown tenant {tenant!r}; registered: "
                               f"{list(self.tenant_names)}")
            return self.plan.layout()
        base, _ = self.plan.tenant_slice(tenant)
        return {q: (base + o, w, kind) for q, (o, w, kind)
                in self.plan.plan_for(tenant).layout().items()}

    def answer(self, vec, name: str, tenant: str | None = None):
        """Slice one query's answers out of a flat (host) vector; with
        several tenants pass ``tenant=`` or a ``"tenant/query"`` name."""
        lay = self.query_layout(tenant)
        if name not in lay:
            raise KeyError(f"unknown query {name!r}; available: "
                           f"{sorted(lay)}")
        o, w, _ = lay[name]
        return np.asarray(vec)[..., o:o + w]

    def tenant_answers(self, vec, tenant: str):
        """One tenant's block of a flat answers/bounds vector — identical
        bit-for-bit to the vector a single-tenant pipeline of the same
        registry produces."""
        if self.plan is None:
            raise SpecError("this pipeline registers no query tenants")
        if len(self.tenant_names) == 1:
            if tenant != self.tenant_names[0]:
                raise KeyError(f"unknown tenant {tenant!r}; registered: "
                               f"{list(self.tenant_names)}")
            return np.asarray(vec)[..., :self.plan.n_out]
        o, w = self.plan.tenant_slice(tenant)
        return np.asarray(vec)[..., o:o + w]

    def tenant_rel_errors(self, answers_row, bounds_row) -> dict[str, float]:
        """Per-tenant measured relative error of one window — the
        per-tenant attribution signal the shared budget controller
        consumes; see ``query.compiler.tenant_rel_errors`` (the one
        implementation) for the exact rule."""
        from repro.query.compiler import tenant_rel_errors

        if self.plan is None:
            return {}
        return tenant_rel_errors(
            self.plan, answers_row, bounds_row,
            default_tenant=self.tenant_names[0])


# The traced-program cache: every quantity a trace closes over, keyed
# so pipelines that differ ONLY in tenant names/live sets share one
# entry (the traced plan component is the canonical, name-free
# ``SlotPlanCore`` from the size-bucketed plan cache). The tick fn, the
# per-epoch-length jitted epoch fns, AND the trace counter live here —
# sharing the jitted callables across pipeline objects is what makes
# tenant churn zero-retrace: ``admit``/``retire`` build a new
# ``CompiledPipeline`` wrapper, but it runs the same executables.
_PROGRAM_CACHE: dict = {}
_PROGRAM_STATS = {"misses": 0, "hits": 0}


def _program_entry(sig: tuple, traced_plan) -> dict:
    entry = _PROGRAM_CACHE.get(sig)
    if entry is None:
        # ``route_keys`` does not parameterize the trace itself (the tick
        # discovers routing from the state pytree structure) but keys the
        # cache, so routed and unrouted pipelines own separate trace
        # counters and jit caches.
        (fanin, capacities, max_sizes, iv, num_strata, allocation,
         backend, mode, p_level, fraction, _route_keys, telemetry,
         _plan) = sig
        trace_counter = {"traces": 0}
        tick_fn = T._build_scan_tick(
            list(fanin), list(capacities), list(max_sizes), list(iv),
            num_strata, allocation, backend, mode, p_level, fraction,
            trace_counter=trace_counter, plan=traced_plan,
            telemetry=telemetry)
        entry = {"tick_fn": tick_fn, "epoch_fns": {},
                 "trace_counter": trace_counter}
        _PROGRAM_CACHE[sig] = entry
        _PROGRAM_STATS["misses"] += 1
    else:
        _PROGRAM_STATS["hits"] += 1
    return entry


def program_cache_stats() -> dict:
    """{"misses": distinct traced-program families built, "hits":
    reuses} — a miss is (at most) one compile per epoch length; the
    tenant-churn benchmark asserts misses stay O(log n_tenants)."""
    return dict(_PROGRAM_STATS)


def _sync_telemetry_slots(state: "PipelineState", n_out: int
                          ) -> "PipelineState":
    """Churn across a slot-bucket boundary resizes the traced plan's
    padded answer width; the telemetry ``slot_rel_bound_sum`` leaf must
    follow (pad with zeros / truncate retired tail slots) or the next
    epoch's accumulate would shape-mismatch."""
    tel = state.tree.telemetry
    if not hasattr(tel, "slot_rel_bound_sum"):
        return state
    cur = tel.slot_rel_bound_sum
    if cur.shape[0] == n_out:
        return state
    if cur.shape[0] < n_out:
        new = jnp.concatenate(
            [cur, jnp.zeros((n_out - cur.shape[0],), cur.dtype)])
    else:
        new = cur[:n_out]
    return state._replace(tree=state.tree._replace(
        telemetry=tel._replace(slot_rel_bound_sum=new)))


class CompiledPipeline(QueryRouting):
    """Immutable compilation of one ``PipelineSpec`` (see module doc).

    Tenant churn: ``admit(state, tenant)`` / ``retire(state, name)``
    return a NEW ``(pipeline, state)`` pair — the slot mask and sketch
    rows are edited in place on device, and the new pipeline reuses the
    cached traced programs (zero retrace unless the live count crosses
    a slot-bucket boundary, which fetches/builds the next bucket's
    cached program)."""

    def __init__(self, spec: PipelineSpec):
        r = specmod.resolve(spec)
        self.spec = spec
        self.fanin = list(spec.topology.fanin)
        self.num_strata = spec.topology.num_strata
        self.capacities = list(r.capacities)
        self.sample_sizes = list(r.sample_sizes)
        self.max_sample_sizes = list(r.max_sample_sizes)
        self.interval_ticks = list(r.interval_ticks)
        self.plan = r.plan
        self.tenant_names = tuple(t.name for t in spec.tenants)
        self._traced_plan = r.plan.core if r.plan is not None else None
        self.telemetry_enabled = spec.telemetry.enabled
        self.route_keys = spec.strata.num_keys
        # The telemetry flag sits immediately before the traced-plan
        # element so _with_plan's ``sig[:-1] + (plan.core,)`` slice
        # stays valid across tenant churn.
        self._program_sig = (
            tuple(self.fanin), tuple(self.capacities),
            tuple(self.max_sample_sizes), tuple(self.interval_ticks),
            self.num_strata, spec.sampler.allocation, spec.sampler.backend,
            spec.sampler.mode, r.p_level, spec.sampler.fraction,
            self.route_keys, self.telemetry_enabled, self._traced_plan)
        entry = _program_entry(self._program_sig, self._traced_plan)
        self.trace_counter = entry["trace_counter"]
        self._tick_fn = entry["tick_fn"]
        self._epoch_fns = entry["epoch_fns"]

    # ---------------------------------------------------- tenant churn --
    def _with_plan(self, plan, tenants) -> "CompiledPipeline":
        """Cheap clone carrying a new routing wrapper (shared traced
        programs unless the wrapper's core changed buckets). ``tenants``
        is the already-edited TenantSpec tuple — reusing the caller's
        spec objects keeps admit O(live tenants) instead of
        re-materializing every TenantSpec (O(n) dataclass inits per
        admit would make a 10k-tenant sweep quadratic)."""
        pipe = object.__new__(CompiledPipeline)
        pipe.__dict__.update(self.__dict__)
        pipe.plan = plan
        pipe.tenant_names = plan.tenant_names
        pipe.spec = dataclasses.replace(self.spec, tenants=tuple(tenants))
        if plan.core is not self._traced_plan:
            pipe._traced_plan = plan.core
            pipe._program_sig = self._program_sig[:-1] + (plan.core,)
            entry = _program_entry(pipe._program_sig, plan.core)
            pipe.trace_counter = entry["trace_counter"]
            pipe._tick_fn = entry["tick_fn"]
            pipe._epoch_fns = entry["epoch_fns"]
        return pipe

    def admit(self, state: PipelineState, tenant
              ) -> tuple["CompiledPipeline", PipelineState]:
        """Hot-admit one tenant mid-stream: returns ``(pipeline',
        state')`` where ``state'`` has the tenant's slot activated (its
        sketch rows reset to init) — a pure state edit, no recompile.
        ``tenant`` is a ``TenantSpec`` (``registry.as_tenant(name)``).
        The returned pipeline's answers are bitwise what a fresh compile
        of the same live set would produce from the same state."""
        if self.plan is None:
            raise SpecError("admit() needs a tenanted pipeline — compile "
                            "with at least one TenantSpec")
        name, specs = tenant.name, tuple(tenant.queries)
        from repro.obs.trace import span
        with span("admit", tenant=name):
            try:
                new_plan, transform = self.plan.admit(name, specs)
            except (KeyError, ValueError) as e:
                raise SpecError(str(e)) from e
            qstate = transform(state.tree.qstate, 0)
            state = state._replace(tree=state.tree._replace(qstate=qstate))
            state = _sync_telemetry_slots(state, new_plan.core.n_out)
            return self._with_plan(new_plan,
                                   self.spec.tenants + (tenant,)), state

    def retire(self, state: PipelineState, tenant_id: str
               ) -> tuple["CompiledPipeline", PipelineState]:
        """Retire a live tenant: flips its slot's active mask off (the
        slot is recycled by a later ``admit``). Inactive slots answer
        zeros, keep frozen state, and never vote in budget arbitration.
        """
        if self.plan is None:
            raise SpecError("retire() needs a tenanted pipeline")
        from repro.obs.trace import span
        with span("retire", tenant=tenant_id):
            try:
                new_plan, transform = self.plan.retire(tenant_id)
            except (KeyError, ValueError) as e:
                raise SpecError(str(e)) from e
            qstate = transform(state.tree.qstate, 0)
            state = state._replace(tree=state.tree._replace(qstate=qstate))
            state = _sync_telemetry_slots(state, new_plan.core.n_out)
            return self._with_plan(
                new_plan, tuple(t for t in self.spec.tenants
                                if t.name != tenant_id)), state

    # ------------------------------------------------------------ init --
    @property
    def default_key(self) -> jax.Array:
        """The spec-seeded PRNG key (what ``HostTree`` threads through
        every tick) — pass it to ``run_epoch`` for spec-deterministic
        runs, or bring your own key."""
        return jax.random.PRNGKey(self.spec.seed)

    def init(self, key: jax.Array | None = None) -> PipelineState:
        """Fresh state: empty buffers, identity metadata, empty sketches,
        tick counter at 1. ``key`` is accepted for API symmetry (state
        initialization is deterministic — randomness enters per epoch)."""
        del key
        tel = ()
        if self.telemetry_enabled:
            from repro.obs.telemetry import EpochTelemetry

            tel = EpochTelemetry.create(
                len(self.fanin), self.num_strata,
                self._traced_plan.n_out
                if self._traced_plan is not None else 0)
        st = TreeState.create(
            self.fanin, self.capacities, self.num_strata,
            qstate=self.plan.init_state() if self.plan is not None else (),
            telemetry=tel,
            # Round-robin seed table == identity while num_keys ≤
            # num_strata; the modulo keeps every slot id valid either way.
            route=(jnp.arange(self.route_keys, dtype=jnp.int32)
                   % self.num_strata if self.route_keys else ()))
        return PipelineState(tree=st, tick=jnp.int32(1))

    def telemetry_snapshot(self, state: PipelineState) -> dict | None:
        """Host-readable snapshot of the in-graph telemetry counters
        (``None`` when ``spec.telemetry.enabled`` is off) — see
        ``repro.obs.snapshot``."""
        from repro.obs.telemetry import snapshot

        return snapshot(state)

    # ------------------------------------------------------------ run --
    def clamp_budgets(self, budgets) -> list[float]:
        """Per-level budgets clamped to [1, ceiling] — the provisioned
        buffers upstream were sized for the ceilings, so exceeding them
        would truncate forwards (same rule as the legacy
        ``HostTree.set_sample_sizes``)."""
        if budgets is None:
            budgets = self.sample_sizes
        budgets = list(budgets)
        if len(budgets) != len(self.fanin):
            raise SpecError(
                f"budgets must have one entry per level: got "
                f"{len(budgets)} for {len(self.fanin)} levels")
        return [min(max(float(s), 1.0), float(m))
                for s, m in zip(budgets, self.max_sample_sizes)]

    def _epoch_fn(self, epoch_ticks: int):
        fn = self._epoch_fns.get(epoch_ticks)
        if fn is not None:
            return fn
        tick_fn = self._tick_fn

        def epoch(state: PipelineState, key, budgets, ing_v, ing_s, ing_n):
            ts = state.tick + jnp.arange(epoch_ticks, dtype=jnp.int32)

            def body(st, xs):
                t, v, s, n = xs
                return tick_fn(st, key, t, budgets, v, s, n)

            tree, outs = jax.lax.scan(body, state.tree,
                                      (ts, ing_v, ing_s, ing_n))
            next_state = PipelineState(
                tree=tree, tick=state.tick + jnp.int32(epoch_ticks))
            return next_state, (ts,) + outs

        fn = jax.jit(epoch, donate_argnums=(0,))
        self._epoch_fns[epoch_ticks] = fn
        return fn

    def run_epoch(self, state: PipelineState, key: jax.Array,
                  values, strata, counts, budgets=None
                  ) -> tuple[PipelineState, WindowAnswers]:
        """Advance ``T = values.shape[0]`` ticks in ONE jitted dispatch.

        ``values``/``strata`` are ``[T, fanin[0], width]`` tick-major
        padded ingest (``data.stream.batch_ingest`` builds this layout),
        ``counts`` the per-(tick, node) item counts. ``state`` is
        donated — do not reuse the argument after the call (checkpoint
        *before* stepping). ``budgets`` (per-level sample sizes, default
        = the spec's) are traced: moving them between epochs never
        recompiles."""
        values = jnp.asarray(values, jnp.float32)
        strata = jnp.asarray(strata, jnp.int32)
        counts = jnp.asarray(counts, jnp.int32)
        epoch_ticks, n0 = counts.shape
        if n0 != self.fanin[0]:
            raise SpecError(f"ingest rows must match level-0 nodes: got "
                            f"{n0} for fanin {tuple(self.fanin)}")
        b = jnp.asarray(self.clamp_budgets(budgets), jnp.float32)
        state, outs = self._epoch_fn(epoch_ticks)(
            state, key, b, values, strata, counts)
        if self.plan is not None:
            ts, ok, se, sv, me, mv, nsel, hist, ans, bnd, n_fwd = outs
            # The traced program answers the PADDED slot vector; the
            # public vector is the live tenants' blocks (admission
            # order). Compaction is an eager gather outside the jit, so
            # it follows churn without retracing anything.
            ans, bnd = self.plan.compact(ans), self.plan.compact(bnd)
        else:
            ts, ok, se, sv, me, mv, nsel, hist, n_fwd = outs
            ans = bnd = None
        wa = WindowAnswers(tick=ts, ok=ok, sum=se, sum_var=sv, mean=me,
                           mean_var=mv, n_sampled=nsel, histogram=hist,
                           answers=ans, bounds=bnd, n_forwarded=n_fwd)
        return state, wa

    def step(self, state: PipelineState, key: jax.Array,
             values, strata, counts, budgets=None
             ) -> tuple[PipelineState, WindowAnswers]:
        """One tick (``values`` ``[fanin[0], width]``): ``run_epoch``
        with T=1 — the per-tick dispatch granularity of the legacy
        ``level``/``loop`` engines on the one fused runtime."""
        values = np.asarray(values)
        strata = np.asarray(strata)
        counts = np.asarray(counts)
        return self.run_epoch(state, key, values[None], strata[None],
                              counts[None], budgets)

    def reset_queries(self, state: PipelineState) -> PipelineState:
        """Empty the standing queries' sketch state (drivers call this
        after warmup so continuous answers cover only measured ticks)."""
        if self.plan is None:
            return state
        return state._replace(
            tree=state.tree._replace(qstate=self.plan.init_state()))

# ------------------------------------------------------- checkpointing --
def save_state(root, step: int, state: PipelineState, *,
               spec: PipelineSpec | None = None,
               pipeline: "CompiledPipeline | None" = None, keep_n: int = 3):
    """Checkpoint a ``PipelineState`` (atomic, keep-N — see
    ``checkpoint.manager``). ``spec`` rides in the manifest so a restore
    can verify it is loading into the same pipeline; pass ``pipeline=``
    (preferred) to also record the slot configuration — bucket sizes,
    active mask, tenant→slot assignment — which a CHURNED pipeline's
    spec alone cannot reconstruct (retirement leaves slot holes). Save
    *before* donating the state into ``run_epoch``."""
    from repro.checkpoint import manager
    from repro.obs.trace import span

    if pipeline is not None and spec is None:
        spec = pipeline.spec
    meta = {"pipeline_spec": spec.to_dict()} if spec is not None else {}
    plan = pipeline.plan if pipeline is not None else (
        specmod.build_plan(spec) if spec is not None else None)
    if plan is not None:
        meta["slots"] = plan.slot_manifest()
    with span("checkpoint", op="save", step=step):
        return manager.save(root, step, state, meta=meta, keep_n=keep_n)


def restore_state(root, compiled: CompiledPipeline, step: int | None = None
                  ) -> tuple[PipelineState, dict]:
    """Load a checkpointed ``PipelineState`` into ``compiled``'s state
    template (default: the latest step under ``root``). Restoring into a
    pipeline whose spec differs from the one recorded at save time is a
    ``SpecError`` — resuming a stream under different sampling semantics
    silently changes every answer."""
    from repro.checkpoint import manager

    if step is None:
        step = manager.latest_step(root)
        if step is None:
            raise SpecError(f"no pipeline checkpoints under {root!r}")
    # Peek at the manifest BEFORE materializing the state template —
    # slot-config mismatches must fail with an actionable error, not a
    # leaf-shape assertion three layers down.
    meta = manager.read_manifest(root, step).get("meta", {})
    saved = meta.get("pipeline_spec")
    if saved is not None and saved != compiled.spec.to_dict():
        raise SpecError(
            f"checkpoint at {root!r} step {step} was written by a "
            f"different PipelineSpec — recompile with "
            f"PipelineSpec.from_dict(manifest['pipeline_spec']) or point "
            f"at the right checkpoint directory")
    saved_slots = meta.get("slots")
    if saved_slots is not None and compiled.plan is not None:
        current = compiled.plan.slot_manifest()
        if saved_slots != current:
            raise SpecError(
                f"checkpoint at {root!r} step {step} was written under a "
                f"different tenant-slot configuration "
                f"(saved {saved_slots}, pipeline has {current}) — the "
                f"pipelines churned differently since compile, so "
                f"restoring would silently mis-route tenant answers. "
                f"Admit/retire this pipeline to the saved live set (same "
                f"order) or restore into a pipeline compiled from the "
                f"checkpoint's spec before any churn.")
    from repro.obs.trace import span
    with span("checkpoint", op="restore", step=step):
        state, meta = manager.restore(root, step, compiled.init())
    return state, meta


# Bounded: each entry pins a pipeline AND its jitted epoch executables,
# so an unbounded cache would grow without limit under spec sweeps
# (fig8 alone compiles ~19 distinct (fraction, seed) specs). 16 covers
# every concurrent-pipeline pattern in the repo; evicted pipelines just
# recompile on next use.
@functools.lru_cache(maxsize=16)
def _cached_compile(spec: PipelineSpec) -> CompiledPipeline:
    return CompiledPipeline(spec)


def compile(spec: PipelineSpec, *, mesh=None, axis_name: str = "data"):
    """The front door: ``PipelineSpec → CompiledPipeline``.

    With ``mesh=`` the same spec lowers onto the pod-scale SPMD
    two-level hierarchy instead (``repro.api.spmd.CompiledSpmdPipeline``
    — every device samples locally, reservoirs all-gather, the root
    re-samples; same sampler/backend/budget fields of the spec).

    Specs are frozen/hashable, so local compilations are cached: calling
    ``compile`` twice on an identical spec returns the same (stateless)
    pipeline object and reuses its jit caches."""
    if not isinstance(spec, PipelineSpec):
        raise SpecError(f"compile() takes a PipelineSpec, got "
                        f"{type(spec).__name__} — build one with "
                        f"repro.api.PipelineSpec(...) or "
                        f"PipelineSpec.from_dict(...)")
    if mesh is not None:
        from repro.api.spmd import CompiledSpmdPipeline

        return CompiledSpmdPipeline(spec, mesh, axis_name=axis_name)
    return _cached_compile(spec)

"""``PipelineSpec`` — the declarative job description for the whole system.

One frozen, serializable value describes everything the runtime needs:
the edge topology (fan-in per level, buffer capacity, per-level flush
intervals), the sampler (WHS or the SRS baseline, selection backend,
stratum allocation, end-to-end fraction), the standing-query plane as a
list of per-**tenant** query registries, and the budget policy (fixed
per-level sample sizes, or a closed-loop error budget with ceilings).
``repro.api.compile(spec)`` turns it into a pure ``init``/``run_epoch``
pipeline; ``HostTree.from_spec(spec, engine=...)`` consumes the same
spec through the legacy per-tick engines; ``compile(spec, mesh=...)``
lowers it onto a device mesh. All resolution (derived sample sizes,
buffer provisioning, compiled query plans) lives in :func:`resolve`, so
every consumer is bit-identical by construction.

Specs validate **at spec time**: every dataclass checks its own fields
in ``__post_init__`` and :func:`validate` checks cross-field combos
(budgets that overflow a level's buffer, SRS without a fraction, query
tenants on the SRS path, ...) with actionable messages — a bad topology
raises here, not three layers down inside a jit trace.

``to_dict()``/``from_dict()`` round-trip the spec through plain JSON
types; ``from_dict`` is strict (unknown or mistyped keys name the exact
path that is wrong).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

from repro.query.registry import QuerySpec


class SpecError(ValueError):
    """A pipeline spec that cannot be compiled, with a pointer to the
    offending field and the constraint it violates."""


_MODES = ("whs", "srs")
_BACKENDS = ("argsort", "topk", "pallas", "pallas_fused")
_ALLOCATIONS = ("fair", "proportional", "neyman")


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise SpecError(msg)


@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """The emulated edge hierarchy: ``fanin[l]`` nodes at level ``l``
    (root last, always 1), a level-0 buffer ``capacity`` (upper levels
    are provisioned automatically from the budget ceilings), per-level
    flush ``interval_ticks`` (default all-1 — the paper topology), and
    the number of sub-streams (``num_strata``)."""

    fanin: tuple = (4, 2, 1)
    capacity: int = 1024
    interval_ticks: tuple | None = None
    num_strata: int = 4

    def __post_init__(self):
        object.__setattr__(self, "fanin", tuple(int(n) for n in self.fanin))
        _require(len(self.fanin) >= 1,
                 "topology.fanin must name at least one level")
        _require(all(n >= 1 for n in self.fanin),
                 f"topology.fanin must be positive node counts, got "
                 f"{self.fanin}")
        _require(self.fanin[-1] == 1,
                 f"topology.fanin must end at a single root node, got "
                 f"{self.fanin} (last level is {self.fanin[-1]}, expected 1)")
        _require(int(self.capacity) >= 1,
                 f"topology.capacity must be >= 1, got {self.capacity}")
        object.__setattr__(self, "capacity", int(self.capacity))
        _require(int(self.num_strata) >= 1,
                 f"topology.num_strata must be >= 1, got {self.num_strata}")
        object.__setattr__(self, "num_strata", int(self.num_strata))
        if self.interval_ticks is not None:
            iv = tuple(int(i) for i in self.interval_ticks)
            _require(len(iv) == len(self.fanin),
                     f"topology.interval_ticks must have one entry per "
                     f"level: got {len(iv)} for {len(self.fanin)} levels")
            _require(all(i >= 1 for i in iv),
                     f"topology.interval_ticks must be >= 1 ticks, got {iv}")
            object.__setattr__(self, "interval_ticks", iv)

    @property
    def n_levels(self) -> int:
        return len(self.fanin)


@dataclasses.dataclass(frozen=True)
class SamplerSpec:
    """Which sampler runs at every node: the paper's weighted
    hierarchical sampler (``whs``) or the §IV-B stratified-random
    baseline (``srs``), the selection ``backend`` (see
    ``core.sampling``), the per-stratum budget ``allocation``, and the
    end-to-end sampling ``fraction`` (kept-items / offered-items, which
    sizes the default per-level budgets)."""

    mode: str = "whs"
    backend: str = "topk"
    allocation: str = "fair"
    fraction: float | None = 0.1

    def __post_init__(self):
        _require(self.mode in _MODES,
                 f"sampler.mode must be one of {_MODES}, got {self.mode!r}")
        _require(self.backend in _BACKENDS,
                 f"sampler.backend must be one of {_BACKENDS}, got "
                 f"{self.backend!r}")
        _require(self.allocation in _ALLOCATIONS,
                 f"sampler.allocation must be one of {_ALLOCATIONS}, got "
                 f"{self.allocation!r}")
        if self.fraction is not None:
            f = float(self.fraction)
            _require(0.0 < f <= 1.0,
                     f"sampler.fraction must be in (0, 1], got {f}")
            object.__setattr__(self, "fraction", f)


@dataclasses.dataclass(frozen=True)
class BudgetSpec:
    """Per-level sample budgets and the closed-loop policy.

    ``sample_sizes`` pins explicit per-level budgets (default: derived
    from ``sampler.fraction`` × capacity). ``max_fraction`` /
    ``max_sample_sizes`` provision buffer ceilings above the initial
    budgets so the error-budget controller can grow the sample with
    zero retraces. ``target_rel_error`` switches the policy from
    ``fixed`` to closed-loop: the controller consumes each epoch's
    measured relative ±2σ error — per tenant, worst-tenant-first when
    several tenants share the tree."""

    sample_sizes: tuple | None = None
    max_sample_sizes: tuple | None = None
    max_fraction: float | None = None
    target_rel_error: float | None = None
    min_size: int = 8
    kp: float = 0.5
    ki: float = 0.1

    def __post_init__(self):
        for name in ("sample_sizes", "max_sample_sizes"):
            v = getattr(self, name)
            if v is not None:
                v = tuple(int(s) for s in v)
                _require(all(s >= 1 for s in v),
                         f"budget.{name} must be positive, got {v}")
                object.__setattr__(self, name, v)
        if self.max_fraction is not None:
            f = float(self.max_fraction)
            _require(0.0 < f <= 1.0,
                     f"budget.max_fraction must be in (0, 1], got {f}")
            object.__setattr__(self, "max_fraction", f)
        if self.target_rel_error is not None:
            _require(float(self.target_rel_error) > 0.0,
                     f"budget.target_rel_error must be > 0, got "
                     f"{self.target_rel_error}")

    @property
    def policy(self) -> str:
        return "fixed" if self.target_rel_error is None else "error_budget"


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's standing-query registry. Every tenant's queries are
    answered from the same shared tree (one root evaluation per window),
    with per-tenant answer routing and error attribution."""

    name: str
    queries: tuple = ()

    def __post_init__(self):
        _require(bool(self.name) and isinstance(self.name, str),
                 f"tenant name must be a non-empty string, got {self.name!r}")
        _require("/" not in self.name,
                 f"tenant name {self.name!r} may not contain '/' (reserved "
                 f"for tenant/query answer routing)")
        qs = tuple(self.queries)
        _require(len(qs) >= 1,
                 f"tenant {self.name!r} registers no queries — drop the "
                 f"tenant or add QuerySpecs")
        for q in qs:
            _require(isinstance(q, QuerySpec),
                     f"tenant {self.name!r}: queries must be QuerySpec "
                     f"instances, got {type(q).__name__}")
        names = [q.name for q in qs]
        _require(len(set(names)) == len(names),
                 f"tenant {self.name!r} has duplicate query names: "
                 f"{sorted(n for n in names if names.count(n) > 1)}")
        object.__setattr__(self, "queries", qs)

    @classmethod
    def from_registry(cls, name: str, registry) -> "TenantSpec":
        """Wrap a ``repro.query.QueryRegistry`` as one tenant."""
        return cls(name=name, queries=tuple(registry.specs))


@dataclasses.dataclass(frozen=True)
class TelemetrySpec:
    """The in-graph observability plane (``repro.obs``).

    ``enabled`` statically compiles the ``EpochTelemetry`` counter
    update into the epoch program: the donated state gains cumulative
    per-level/per-stratum counters and the realized error-bound
    trajectory, read back via ``repro.obs.snapshot``. Telemetry
    consumes no PRNG and runs inside the existing tick, so sample state
    and window answers are bit-identical on or off, at zero extra
    dispatches. Off (the default) carries zero extra state leaves."""

    enabled: bool = False

    def __post_init__(self):
        _require(isinstance(self.enabled, bool),
                 f"telemetry.enabled must be a bool, got "
                 f"{self.enabled!r}")


@dataclasses.dataclass(frozen=True)
class StrataSpec:
    """Adaptive stratification (``repro.strata``).

    ``num_keys`` > 0 enables the key→stratum routing table: ingest
    stratum ids become *keys* gathered through an i32 ``[num_keys]``
    table carried in the donated tree state (seeded round-robin /
    identity at ``init``). A host-side split/merge of strata is then a
    same-shape edit of that leaf — zero retraces. ``adaptive`` runs the
    online ``StratumManager`` at epoch boundaries (drivers own the
    loop), splitting slots hotter than ``split_occupancy``× their fair
    share across a spare slot and merging slots starved below
    ``merge_occupancy``× of it. 0/False (the default) carries zero
    extra state leaves and is bitwise the pre-routing pipeline."""

    num_keys: int = 0
    adaptive: bool = False
    split_occupancy: float = 2.0
    merge_occupancy: float = 0.05

    def __post_init__(self):
        _require(int(self.num_keys) >= 0,
                 f"strata.num_keys must be >= 0, got {self.num_keys}")
        object.__setattr__(self, "num_keys", int(self.num_keys))
        _require(isinstance(self.adaptive, bool),
                 f"strata.adaptive must be a bool, got {self.adaptive!r}")
        _require(not self.adaptive or self.num_keys > 0,
                 "strata.adaptive needs strata.num_keys > 0 (the routing "
                 "table the manager edits)")
        _require(float(self.split_occupancy) > 1.0,
                 f"strata.split_occupancy is a multiple of the fair share "
                 f"and must be > 1, got {self.split_occupancy}")
        _require(0.0 <= float(self.merge_occupancy) < 1.0,
                 f"strata.merge_occupancy must be in [0, 1), got "
                 f"{self.merge_occupancy}")


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    """The whole job: topology × sampler × tenants × budget policy."""

    topology: TopologySpec = dataclasses.field(default_factory=TopologySpec)
    sampler: SamplerSpec = dataclasses.field(default_factory=SamplerSpec)
    tenants: tuple = ()
    budget: BudgetSpec = dataclasses.field(default_factory=BudgetSpec)
    seed: int = 0
    telemetry: TelemetrySpec = dataclasses.field(
        default_factory=TelemetrySpec)
    strata: StrataSpec = dataclasses.field(default_factory=StrataSpec)

    def __post_init__(self):
        object.__setattr__(self, "tenants", tuple(self.tenants))
        for t in self.tenants:
            _require(isinstance(t, TenantSpec),
                     f"tenants must be TenantSpec instances, got "
                     f"{type(t).__name__}")
        _require(isinstance(self.telemetry, TelemetrySpec),
                 f"telemetry must be a TelemetrySpec, got "
                 f"{type(self.telemetry).__name__}")
        _require(isinstance(self.strata, StrataSpec),
                 f"strata must be a StrataSpec, got "
                 f"{type(self.strata).__name__}")
        names = [t.name for t in self.tenants]
        if len(set(names)) != len(names):  # build the dup list lazily:
            # an eager f-string here would cost O(n^2) per spec build,
            # which admit() pays on every churn at 10k tenants
            import collections

            dups = sorted(n for n, c in
                          collections.Counter(names).items() if c > 1)
            _require(False, f"duplicate tenant names: {dups}")
        object.__setattr__(self, "seed", int(self.seed))
        validate(self)

    # -------------------------------------------------- serialization --
    def to_dict(self) -> dict:
        """Plain-JSON-types dict (tuples → lists), round-trips through
        :meth:`from_dict`."""
        d = dataclasses.asdict(self)
        d["version"] = 1

        def listify(x):
            if isinstance(x, tuple):
                return [listify(v) for v in x]
            if isinstance(x, list):
                return [listify(v) for v in x]
            if isinstance(x, dict):
                return {k: listify(v) for k, v in x.items()}
            return x

        return listify(d)

    @classmethod
    def from_dict(cls, d: dict) -> "PipelineSpec":
        """Strict inverse of :meth:`to_dict`: unknown keys, missing
        required keys, and mistyped values raise ``SpecError`` naming
        the exact path."""
        _require(isinstance(d, dict),
                 f"pipeline spec must be a dict, got {type(d).__name__}")
        d = dict(d)
        version = d.pop("version", 1)
        _require(version == 1,
                 f"unsupported pipeline spec version {version!r} "
                 f"(this build reads version 1)")
        sections = {
            "topology": TopologySpec, "sampler": SamplerSpec,
            "budget": BudgetSpec, "telemetry": TelemetrySpec,
            "strata": StrataSpec,
        }
        kwargs = {}
        for key, klass in sections.items():
            sub = d.pop(key, None)
            if sub is None:
                continue
            kwargs[key] = _build_section(key, klass, sub)
        tenants = d.pop("tenants", [])
        _require(isinstance(tenants, (list, tuple)),
                 f"tenants must be a list, got {type(tenants).__name__}")
        built = []
        for i, t in enumerate(tenants):
            _require(isinstance(t, dict),
                     f"tenants[{i}] must be a dict, got {type(t).__name__}")
            t = dict(t)
            queries = t.pop("queries", [])
            qspecs = []
            for j, q in enumerate(queries):
                _require(isinstance(q, dict),
                         f"tenants[{i}].queries[{j}] must be a dict, got "
                         f"{type(q).__name__}")
                qspecs.append(_build_section(
                    f"tenants[{i}].queries[{j}]", QuerySpec,
                    {**q, "qs": tuple(q.get("qs", ()))}))
            built.append(_build_section(f"tenants[{i}]", TenantSpec,
                                        {**t, "queries": tuple(qspecs)}))
        kwargs["tenants"] = tuple(built)
        if "seed" in d:
            kwargs["seed"] = d.pop("seed")
        _require(not d, f"unknown pipeline spec keys: {sorted(d)} "
                        f"(known: {sorted(list(sections) + ['tenants', 'seed', 'version'])})")
        return cls(**kwargs)


def _build_section(path: str, klass, payload: dict):
    _require(isinstance(payload, dict),
             f"{path} must be a dict, got {type(payload).__name__}")
    fields = {f.name for f in dataclasses.fields(klass)}
    unknown = sorted(set(payload) - fields)
    _require(not unknown,
             f"{path} has unknown keys {unknown} (known: {sorted(fields)})")
    coerced = {k: tuple(v) if isinstance(v, list) else v
               for k, v in payload.items()}
    try:
        return klass(**coerced)
    except SpecError:
        raise
    except (TypeError, ValueError) as e:
        raise SpecError(f"{path}: {e}") from e


# ------------------------------------------------------------ resolve --
class ResolvedPipeline(NamedTuple):
    """Everything the runtimes need, derived once from the spec: applied
    and ceiling per-level budgets, effective intervals, per-level buffer
    capacities, the SRS per-level keep probability, and the compiled
    (possibly multi-tenant) query plan."""

    sample_sizes: tuple
    max_sample_sizes: tuple
    interval_ticks: tuple
    capacities: tuple
    p_level: float
    plan: object   # SlottedTenantPlan | None


def derive_sample_sizes(spec: PipelineSpec) -> tuple[tuple, tuple]:
    """(sample_sizes, max_sample_sizes) per level — the same formulas the
    legacy ``launch.analytics.build_tree`` used, so spec-built pipelines
    bit-match the pre-API drivers."""
    topo, samp, budget = spec.topology, spec.sampler, spec.budget
    n = topo.n_levels
    if budget.sample_sizes is not None:
        sizes = budget.sample_sizes
    elif samp.mode == "srs":
        # Coin-flip keeps ~p of arrivals per level; a level-l node's
        # outbound buffer must hold p^(l+1) of the concentrated stream
        # with slack — truncation would break HT unbiasedness.
        p = samp.fraction ** (1.0 / n)
        total = topo.fanin[0] * topo.capacity
        sizes = tuple(max(int(1.3 * total * (p ** (lvl + 1))
                              / topo.fanin[lvl]), 8) for lvl in range(n))
    else:
        sizes = (max(int(topo.capacity * samp.fraction), 1),) * n
    if budget.max_sample_sizes is not None:
        max_sizes = budget.max_sample_sizes
    elif budget.max_fraction is not None:
        max_sizes = (max(int(topo.capacity * budget.max_fraction), 1),) * n
    elif budget.target_rel_error is not None:
        # Closed-loop accuracy mode grows the sample onto the target:
        # without an explicit ceiling, provision the full window
        # (max_fraction = 1.0 — the legacy driver's default), otherwise
        # the controller's ceiling would equal the initial budget and
        # the §IV-B "grow when the budget is violated" loop could never
        # move.
        max_sizes = (max(int(topo.capacity), 1),) * n
    else:
        max_sizes = sizes
    return tuple(sizes), tuple(max_sizes)


def build_plan(spec: PipelineSpec):
    """Compile the tenants' registries into a ``SlottedTenantPlan``
    (``None`` without tenants): tenants group by name-free shape
    signature, each group padded to its slot bucket and evaluated as one
    vmapped row batch over the cached ``SlotPlanCore``. Every slot's
    answers are bitwise what the pre-slot fused plans produced, but
    tenant churn is now a mask/state edit (``CompiledPipeline.admit`` /
    ``retire``) instead of a recompile."""
    if not spec.tenants:
        return None
    from repro.query.compiler import build_slotted_plan

    return build_slotted_plan([(t.name, t.queries) for t in spec.tenants],
                              spec.topology.num_strata)


def slot_bucket(n: int) -> int:
    """Re-export of the slot bucketing rule (see ``query.compiler``)."""
    from repro.query.compiler import slot_bucket as _sb

    return _sb(n)


def resolve(spec: PipelineSpec) -> ResolvedPipeline:
    """Validate + derive every runtime quantity (one source of truth for
    ``repro.api.compile`` and ``HostTree.from_spec``)."""
    from repro.core.tree import derive_capacities

    validate(spec)
    topo = spec.topology
    iv = topo.interval_ticks or (1,) * topo.n_levels
    sizes, max_sizes = derive_sample_sizes(spec)
    capacities = tuple(derive_capacities(list(topo.fanin), topo.capacity,
                                         list(max_sizes), list(iv)))
    p_level = (spec.sampler.fraction ** (1.0 / topo.n_levels)
               if spec.sampler.fraction is not None else 1.0)
    return ResolvedPipeline(sample_sizes=sizes, max_sample_sizes=max_sizes,
                            interval_ticks=iv, capacities=capacities,
                            p_level=p_level, plan=build_plan(spec))


def validate(spec: PipelineSpec) -> None:
    """Cross-field checks — everything a single dataclass can't see.
    Raises ``SpecError`` with the constraint spelled out."""
    topo, samp, budget = spec.topology, spec.sampler, spec.budget
    n = topo.n_levels
    if samp.mode == "srs":
        _require(samp.fraction is not None,
                 "sampler.mode='srs' needs sampler.fraction (the coin-flip "
                 "keep rate is derived from the end-to-end fraction)")
        _require(not spec.tenants,
                 "query tenants need WHS stratum metadata: use "
                 "sampler.mode='whs' or drop the tenants")
        _require(budget.target_rel_error is None,
                 "the error-budget controller drives WHS sample budgets: "
                 "use sampler.mode='whs' or drop budget.target_rel_error")
    if samp.fraction is None:
        _require(budget.sample_sizes is not None,
                 "set sampler.fraction or pin explicit budget.sample_sizes "
                 "— with neither there is no way to size the per-level "
                 "budgets")
    for name in ("sample_sizes", "max_sample_sizes"):
        v = getattr(budget, name)
        if v is not None:
            _require(len(v) == n,
                     f"budget.{name} must have one entry per level: got "
                     f"{len(v)} for {n} levels (fanin {topo.fanin})")
    sizes, max_sizes = derive_sample_sizes(spec)
    bad = [(lvl, s, m) for lvl, (s, m) in enumerate(zip(sizes, max_sizes))
           if m < s]
    _require(not bad,
             f"budget ceilings must dominate the initial budgets; level"
             f"{'s' if len(bad) > 1 else ''} "
             f"{[lvl for lvl, _, _ in bad]} have max < initial "
             f"({[(s, m) for _, s, m in bad]}) — raise max_fraction/"
             f"max_sample_sizes or lower the initial budgets")
    # WHS budgets must fit the buffers they sample from (a selection
    # can't return more slots than the level holds; SRS provisions its
    # outbound buffers with slack by design and clamps per level).
    # Upper-level buffers are derived from the downstream ceilings, so
    # this also catches pinned per-level budgets that overflow them.
    if samp.mode == "whs":
        from repro.core.tree import derive_capacities

        iv = topo.interval_ticks or (1,) * n
        caps = derive_capacities(list(topo.fanin), topo.capacity,
                                 list(max_sizes), list(iv))
        for lvl, (s, cap) in enumerate(zip(sizes, caps)):
            _require(s <= cap,
                     f"level-{lvl} sample budget {s} exceeds the level-"
                     f"{lvl} buffer capacity {cap}"
                     + (" — raise topology.capacity or lower "
                        "sampler.fraction/budget.sample_sizes"
                        if lvl == 0 else
                        f" (derived from the level-{lvl - 1} ceiling × "
                        f"fan-in) — lower budget.sample_sizes[{lvl}] or "
                        f"raise the downstream ceilings"))
    # Error-budget feasibility: the controller grows SAMPLE budgets, but a
    # quantile sketch's rank-error floor is set by its CAPACITY (the leveled
    # compaction schedule) — no sample budget can push the published bound
    # below it. A target under that floor would pin the controller at its
    # ceiling forever, so reject it at spec time.
    if budget.target_rel_error is not None:
        from repro.query.sketches import quantile_rank_error_bound

        target = float(budget.target_rel_error)
        for t in spec.tenants:
            for q in t.queries:
                if q.kind not in ("quantile", "windowed_quantile"):
                    continue
                floor = quantile_rank_error_bound(q.capacity)
                _require(floor <= target,
                         f"tenant {t.name!r} query {q.name!r}: a capacity-"
                         f"{q.capacity} quantile sketch bottoms out at rank "
                         f"error {floor:.4f} over the planning horizon — "
                         f"above budget.target_rel_error={target}; the "
                         f"error-budget controller could never settle. "
                         f"Raise the sketch capacity or relax the target.")

"""The declarative pipeline API — the repo's one front door.

    spec  = PipelineSpec(topology=..., sampler=..., tenants=..., budget=...)
    pipe  = compile(spec)                 # or compile(spec, mesh=...)
    state = pipe.init(key)
    state, answers = pipe.run_epoch(state, key, values, strata, counts,
                                    budgets)

Everything else — the legacy ``HostTree`` engines
(``HostTree.from_spec``), the SPMD pod-scale path, the analytics/serve
launchers, benchmarks and examples — consumes the same ``PipelineSpec``,
resolved by the same code, so every execution substrate is bit-identical
on identical ingest. See ``repro.api.spec`` and ``repro.api.pipeline``.
"""
from repro.api.pipeline import (CompiledPipeline, PipelineState,
                                WindowAnswers, compile, restore_state,
                                save_state)
from repro.api.spec import (BudgetSpec, PipelineSpec, SamplerSpec,
                            SpecError, TelemetrySpec, TenantSpec,
                            TopologySpec, resolve)

compile_pipeline = compile   # alias for call sites that shadow the builtin

__all__ = [
    "PipelineSpec", "TopologySpec", "SamplerSpec", "BudgetSpec",
    "TelemetrySpec",
    "TenantSpec", "SpecError", "resolve", "compile", "compile_pipeline",
    "CompiledPipeline", "PipelineState", "WindowAnswers",
    "save_state", "restore_state",
]

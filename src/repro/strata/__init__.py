"""Adaptive stratification: online stratum split/merge as pure state
edits on the key→stratum routing table (see ``repro.strata.manager``)."""
from repro.strata.manager import (            # noqa: F401
    StratumManager, StratumOp, remap_tree_state,
)

__all__ = ["StratumManager", "StratumOp", "remap_tree_state"]

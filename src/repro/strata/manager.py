"""Online stratum split/merge — adaptive stratification under drift.

Static strata starve under heavy-tailed key skew (Fig. 11c): when one
key carries 80% of the arrivals and another 0.01%, a fixed key→stratum
map wastes reservoir rows on near-empty strata while hot strata
saturate. Following the decentralized-stratified-sampling line of work
(PAPERS.md), the ``StratumManager`` watches per-key arrival rates and,
at epoch boundaries, *splits* slots hotter than ``split_occupancy``×
their fair share (moving a subset of their keys onto a spare slot) and
*merges* slots starved below ``merge_occupancy``× of it.

Everything is a pure state edit at a fixed shape:

* the key→stratum **routing table** is an i32 ``[num_keys]`` leaf of the
  donated ``TreeState`` (``core.window.TreeState.route``) — the scan
  tick gathers ingest keys through it, so installing a new table never
  recompiles (the PR-7 padded-slot idiom: capacity is static, meaning is
  host-assigned);
* the Eq. 9 calibration metadata (sticky ``W^in``/``C^in`` sets and the
  in-flight interval accumulators) is **remapped** with the table
  (:func:`remap_tree_state`), so published bounds stay honest across a
  remap: a split hands the child slot its proportional share of the
  parent's counts (same ``C^in/c`` ratio on both sides), a merge
  combines counts by sum and weights by count-weighted mean — the same
  merge law ``core.window`` applies to multi-message intervals.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class StratumOp:
    """One committed routing edit. ``split`` moves ``keys`` (a strict
    subset of ``src``'s keys, carrying ``share`` of its observed mass)
    onto the spare slot ``dst``; ``merge`` folds ALL of ``src``'s keys
    into ``dst`` (``share`` == 1)."""

    kind: str          # "split" | "merge"
    src: int
    dst: int
    keys: tuple
    share: float


class StratumManager:
    """Occupancy-driven split/merge planner over a key→slot table.

    ``observe(key_counts)`` feeds one epoch's per-key arrival counts
    (an EMA with factor ``decay`` smooths noisy epochs);
    ``maybe_adapt()`` plans and commits routing edits, returning the
    committed :class:`StratumOp` list (empty = table unchanged). The
    caller then installs ``manager.route`` into the running state —
    via :func:`remap_tree_state` to keep Eq. 9 metadata honest."""

    def __init__(self, route, num_slots: int, *,
                 split_occupancy: float = 2.0,
                 merge_occupancy: float = 0.05,
                 decay: float = 0.5):
        self.route = np.asarray(route, np.int32).copy()
        assert self.route.ndim == 1 and len(self.route) >= 1
        self.num_keys = int(len(self.route))
        self.num_slots = int(num_slots)
        assert np.all((self.route >= 0) & (self.route < self.num_slots))
        self.split_occupancy = float(split_occupancy)
        self.merge_occupancy = float(merge_occupancy)
        self.decay = float(decay)
        self.key_rate = np.zeros((self.num_keys,), np.float64)
        self.key_mass = np.zeros((self.num_keys,), np.float64)
        self.epochs_observed = 0
        self.ops_log: list[StratumOp] = []

    # ----------------------------------------------------------- inputs --
    def observe(self, key_counts, key_mass=None) -> None:
        """Fold one epoch's per-key arrival counts (and optionally the
        per-key Σ|value| mass) into the rate EMAs. Arrays shorter than
        ``num_keys`` are zero-padded (hosts typically produce both with
        ``np.bincount(keys, ...)``). Mass is the merge guard's signal:
        a key can be rare by count yet carry most of the window's value
        mass — folding it into another stratum would put its huge items
        behind a shared (possibly large) sampling weight, a variance
        cliff the count view cannot see."""
        def _pad(x):
            out = np.zeros((self.num_keys,), np.float64)
            src = np.asarray(x, np.float64).reshape(-1)[:self.num_keys]
            out[:len(src)] = src
            return out

        kc = _pad(key_counts)
        km = _pad(key_mass) if key_mass is not None else None
        if self.epochs_observed == 0:
            self.key_rate = kc
            self.key_mass = km if km is not None else self.key_mass
        else:
            self.key_rate = self.decay * self.key_rate + (1 - self.decay) * kc
            if km is not None:
                self.key_mass = (self.decay * self.key_mass
                                 + (1 - self.decay) * km)
        self.epochs_observed += 1

    def slot_occupancy(self) -> np.ndarray:
        """Observed arrival count mass per slot under the current table."""
        return np.bincount(self.route, weights=self.key_rate,
                           minlength=self.num_slots)[:self.num_slots]

    def slot_mass(self) -> np.ndarray:
        """Observed Σ|value| per slot (zeros when mass was never fed)."""
        return np.bincount(self.route, weights=self.key_mass,
                           minlength=self.num_slots)[:self.num_slots]

    # --------------------------------------------------------- planning --
    def plan(self) -> list[StratumOp]:
        """Plan (without committing) this epoch's split/merge ops."""
        occ = self.slot_occupancy().astype(np.float64)
        route = self.route.copy()
        total = float(occ.sum())
        if total <= 0.0:
            return []
        ops: list[StratumOp] = []

        # Merges first: starved slots fold into the lightest other active
        # slot, freeing capacity for the splits below. A slot is starved
        # only if BOTH its count occupancy AND its value-mass share are
        # negligible — a one-item stratum carrying most of the window's
        # mass is the stratification payoff, not overhead.
        n_active = max(int(np.sum(occ > 0)), 1)
        fair = total / n_active
        mass = self.slot_mass()
        mass_total = float(mass.sum())
        for s in np.argsort(occ):
            s = int(s)
            if occ[s] <= 0.0 or occ[s] >= self.merge_occupancy * fair:
                continue
            if (mass_total > 0.0
                    and mass[s] / mass_total >= self.merge_occupancy):
                continue
            others = [t for t in range(self.num_slots)
                      if t != s and occ[t] > 0.0]
            if not others:
                break
            dst = int(min(others, key=lambda t: occ[t]))
            keys = tuple(int(k) for k in np.nonzero(route == s)[0])
            if not keys:
                continue
            ops.append(StratumOp("merge", src=s, dst=dst, keys=keys,
                                 share=1.0))
            route[list(keys)] = dst
            occ[dst] += occ[s]
            occ[s] = 0.0
            mass[dst] += mass[s]
            mass[s] = 0.0

        # Splits: hottest multi-key slots shed their lighter keys onto a
        # spare slot (a slot no key routes to), aiming at a ~50/50 mass
        # split. Single-key slots cannot split — key granularity is the
        # floor of what routing can separate.
        spare = [t for t in range(self.num_slots)
                 if not np.any(route == t)]
        for s in np.argsort(-occ):
            s = int(s)
            if occ[s] < self.split_occupancy * fair:
                break
            keys = np.nonzero(route == s)[0]
            if len(keys) < 2 or not spare:
                continue
            order = keys[np.argsort(self.key_rate[keys])]
            moved, mass = [], 0.0
            for k in order[:-1]:                 # heaviest key stays put
                if mass >= occ[s] / 2.0:
                    break
                moved.append(int(k))
                mass += float(self.key_rate[k])
            if not moved:
                continue
            dst = spare.pop(0)
            share = mass / max(occ[s], 1e-12)
            ops.append(StratumOp("split", src=s, dst=dst,
                                 keys=tuple(moved), share=float(share)))
            route[moved] = dst
            occ[dst] = mass
            occ[s] -= mass
        return ops

    def maybe_adapt(self) -> list[StratumOp]:
        """Plan AND commit: applies the planned ops to ``self.route`` and
        returns them (empty list = the table is already balanced)."""
        ops = self.plan()
        for op in ops:
            self.route[list(op.keys)] = op.dst
        self.ops_log.extend(ops)
        return ops


def remap_tree_state(state, ops, route):
    """Apply committed ops to a ``TreeState`` as a pure same-shape edit.

    Installs the new routing table and remaps every level's Eq. 9
    metadata leaves (sticky ``w_in``/``c_in``, interval ``wc_acc``/
    ``c_acc``/``seen``) so the next flush's ``C^in/c`` calibration stays
    consistent with the remapped arrivals:

    * split ``s → d`` (share σ): slot ``d`` inherits ``W_s`` and σ of
      every count accumulator; slot ``s`` keeps ``1 − σ``.
    * merge ``s → d``: counts sum; ``W_d`` becomes the count-weighted
      mean (the unbiased multi-message merge law of ``core.window``);
      slot ``s`` resets to the identity metadata (W=1, C=0).

    No shape changes anywhere, so the next epoch runs the existing
    compiled program — zero retraces.
    """
    import jax.numpy as jnp

    new_route = jnp.asarray(route, jnp.int32)
    if not ops:
        return state._replace(route=new_route)
    n_levels = len(state.w_in)
    w_l = [np.array(a, np.float32) for a in state.w_in]
    c_l = [np.array(a, np.float32) for a in state.c_in]
    wc_l = [np.array(a, np.float32) for a in state.wc_acc]
    ca_l = [np.array(a, np.float32) for a in state.c_acc]
    sn_l = [np.array(a, bool) for a in state.seen]
    for op in ops:
        s, d = op.src, op.dst
        for lvl in range(n_levels):
            w, c, wc, ca, sn = (w_l[lvl], c_l[lvl], wc_l[lvl], ca_l[lvl],
                                sn_l[lvl])
            if op.kind == "split":
                sh = np.float32(op.share)
                w[:, d] = w[:, s]
                c[:, d] = c[:, s] * sh
                c[:, s] *= np.float32(1.0) - sh
                wc[:, d] = wc[:, s] * sh
                wc[:, s] *= np.float32(1.0) - sh
                ca[:, d] = ca[:, s] * sh
                ca[:, s] *= np.float32(1.0) - sh
                sn[:, d] = sn[:, s]
            else:                                   # merge s → d
                den = c[:, d] + c[:, s]
                merged = np.where(
                    den > 0,
                    (w[:, d] * c[:, d] + w[:, s] * c[:, s])
                    / np.maximum(den, np.float32(1e-30)),
                    w[:, d]).astype(np.float32)
                w[:, d] = merged
                c[:, d] = den
                wc[:, d] += wc[:, s]
                ca[:, d] += ca[:, s]
                sn[:, d] |= sn[:, s]
                w[:, s] = 1.0
                c[:, s] = 0.0
                wc[:, s] = 0.0
                ca[:, s] = 0.0
                sn[:, s] = False
    return state._replace(
        route=new_route,
        w_in=tuple(jnp.asarray(a) for a in w_l),
        c_in=tuple(jnp.asarray(a) for a in c_l),
        wc_acc=tuple(jnp.asarray(a) for a in wc_l),
        c_acc=tuple(jnp.asarray(a) for a in ca_l),
        seen=tuple(jnp.asarray(a) for a in sn_l),
    )

"""Layer 1: in-graph epoch telemetry.

``EpochTelemetry`` is a pytree of cumulative counters carried as an
optional leaf of the donated pipeline state (``TreeState.telemetry`` on
the local path, ``SpmdPipelineState.telemetry`` on the mesh). The scan
tick / SPMD epoch fill it from quantities they already compute — level
flush sizes, forwarded counts, the root ``SampleResult``'s per-stratum
``c``/``y``, the plan's padded answer/bound vectors — so telemetry
costs no extra dispatch and consumes no PRNG randomness: sample state
and window answers are bit-identical with telemetry on or off (pinned
in ``tests/test_observability.py``).

Telemetry is OFF by default. ``TelemetrySpec(enabled=True)`` on the
``PipelineSpec`` switches it on statically: the tick's telemetry update
is compiled in (or out) at trace time, and the off-state leaf stays the
empty tuple ``()`` so disabled pipelines carry zero extra leaves.

Host-side counters that the device cannot observe (straggler deadline
accounting, ``runtime.straggler``) fold into the same leaves between
epochs via :func:`fold_stragglers` / :class:`StragglerMonitor` — a pure
state edit, never a retrace.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import numpy as np


class EpochTelemetry(NamedTuple):
    """Cumulative in-graph counters (leading semantics per field):

    ``items_in``/``items_kept`` f32[n_levels] — items offered at each
        level's flush vs. items the level forwarded (root: selected).
    ``flushes`` i32[n_levels] — non-empty flushes per level.
    ``saturation_hits`` i32[n_levels] — flushes where the level kept
        every offered item (the WHS saturation fast path fired).
    ``stratum_in``/``stratum_kept`` f32[num_strata] — the root
        window's per-stratum counts ``c`` and kept ``y = min(c, N)``;
        their ratio is the realized per-stratum sampling fraction.
    ``windows`` i32[] — flushed root windows.
    ``root_sum``/``root_sum_var`` f32[] — Σ window SUM estimates and
        Σ window SUM variances; ``2·√(Σ var)`` is THE realized ±2σ
        bound (the one place that math lives — examples print it from
        here instead of recomputing).
    ``slot_rel_bound_sum`` f32[n_slots] — Σ over windows of each
        padded plan slot's ``bound/|answer|`` (CLT slots; sketch slots
        accumulate their structural bounds). Divide by ``windows`` for
        the per-tenant realized error-bound trajectory.
    ``merge_bytes`` f32[] — SPMD path: sketch-summary bytes shipped
        across the mesh axis (windows × the static per-window model
        ``CompiledSpmdPipeline.summary_bytes_per_window``).
    ``late_shards``/``widened_windows`` i32[] — host-folded straggler
        accounting (see :class:`StragglerMonitor`).
    """

    items_in: Any
    items_kept: Any
    flushes: Any
    saturation_hits: Any
    stratum_in: Any
    stratum_kept: Any
    windows: Any
    root_sum: Any
    root_sum_var: Any
    slot_rel_bound_sum: Any
    merge_bytes: Any
    late_shards: Any
    widened_windows: Any

    @staticmethod
    def create(n_levels: int, num_strata: int,
               n_slots: int) -> "EpochTelemetry":
        """Fresh zeroed counters (``n_slots`` = the traced plan's PADDED
        answer width, 0 without a plan)."""
        import jax.numpy as jnp

        f32 = jnp.float32
        i32 = jnp.int32
        return EpochTelemetry(
            items_in=jnp.zeros((n_levels,), f32),
            items_kept=jnp.zeros((n_levels,), f32),
            flushes=jnp.zeros((n_levels,), i32),
            saturation_hits=jnp.zeros((n_levels,), i32),
            stratum_in=jnp.zeros((num_strata,), f32),
            stratum_kept=jnp.zeros((num_strata,), f32),
            windows=jnp.zeros((), i32),
            root_sum=jnp.zeros((), f32),
            root_sum_var=jnp.zeros((), f32),
            slot_rel_bound_sum=jnp.zeros((n_slots,), f32),
            merge_bytes=jnp.zeros((), f32),
            late_shards=jnp.zeros((), i32),
            widened_windows=jnp.zeros((), i32),
        )


def _leaf(state) -> "EpochTelemetry | None":
    """Find the telemetry leaf on any state shape we hand out:
    ``PipelineState`` (``.tree.telemetry``), ``SpmdPipelineState`` /
    ``TreeState`` (``.telemetry``), or a bare ``EpochTelemetry``."""
    if isinstance(state, EpochTelemetry):
        return state
    tree = getattr(state, "tree", None)
    if tree is not None:
        state = tree
    tel = getattr(state, "telemetry", ())
    return tel if isinstance(tel, EpochTelemetry) else None


def snapshot(state) -> dict | None:
    """Host-readable snapshot of a state's telemetry leaves, with the
    derived signals every consumer wants: per-level and per-stratum
    effective sampling fractions, the realized ±2σ SUM bound, and the
    per-slot mean relative bounds. ``None`` when telemetry is disabled
    (the leaf is ``()``)."""
    tel = _leaf(state)
    if tel is None:
        return None
    h = {f: np.asarray(v) for f, v in zip(EpochTelemetry._fields, tel)}
    eps = 1e-9
    levels = []
    for l in range(h["items_in"].shape[0]):
        i_in = float(h["items_in"][l])
        i_kept = float(h["items_kept"][l])
        levels.append({
            "items_in": i_in, "items_kept": i_kept,
            "flushes": int(h["flushes"][l]),
            "saturation_hits": int(h["saturation_hits"][l]),
            "effective_fraction": i_kept / max(i_in, eps),
        })
    strata = []
    for s in range(h["stratum_in"].shape[0]):
        s_in = float(h["stratum_in"][s])
        s_kept = float(h["stratum_kept"][s])
        strata.append({
            "items_in": s_in, "items_kept": s_kept,
            "effective_fraction": s_kept / max(s_in, eps),
        })
    windows = int(h["windows"])
    total = float(h["root_sum"])
    bound = 2.0 * float(np.sqrt(max(float(h["root_sum_var"]), 0.0)))
    slot_rel = h["slot_rel_bound_sum"] / max(windows, 1)
    return {
        "levels": levels,
        "strata": strata,
        "windows": windows,
        "sum_estimate": total,
        "bound_2sigma": bound,
        "rel_bound_2sigma": bound / max(abs(total), eps),
        "slot_rel_bound_mean": slot_rel,
        "merge_bytes": float(h["merge_bytes"]),
        "late_shards": int(h["late_shards"]),
        "widened_windows": int(h["widened_windows"]),
    }


def tenant_rel_bounds(pipeline, state) -> dict[str, float]:
    """Per-tenant realized error bound from the telemetry leaves: each
    tenant's WORST CLT (sum/mean) slot of the window-mean relative
    bounds — the same attribution rule as ``query.compiler.
    tenant_rel_errors``, but sourced from the cumulative in-graph
    trajectory instead of one window's row."""
    from repro.query.compiler import tenant_clt_slots

    snap = snapshot(state)
    plan = getattr(pipeline, "plan", None)
    if snap is None or plan is None:
        return {}
    public = plan.compact(np.asarray(snap["slot_rel_bound_mean"]))
    out = {t: 0.0 for t in plan.tenant_names}
    for tenant, off in tenant_clt_slots(plan):
        out[tenant] = max(out[tenant], float(public[off]))
    return out


def reset(state):
    """Zero a state's telemetry counters in place (shape-preserving, no
    retrace) — drivers call this after warmup so the counters cover only
    the measured stream. No-op when telemetry is disabled."""
    tel = _leaf(state)
    if tel is None:
        return state
    import jax
    import jax.numpy as jnp

    return _replace_leaf(state, jax.tree.map(jnp.zeros_like, tel))


def _replace_leaf(state, tel: EpochTelemetry):
    tree = getattr(state, "tree", None)
    if tree is not None:
        return state._replace(tree=tree._replace(telemetry=tel))
    return state._replace(telemetry=tel)


def fold_stragglers(state, late_shards: int, widened_windows: int):
    """Fold host-side straggler accounting into the telemetry leaves —
    a pure eager state edit (no retrace; the leaves keep their shapes).
    No-op when telemetry is disabled."""
    tel = _leaf(state)
    if tel is None or (not late_shards and not widened_windows):
        return state
    import jax.numpy as jnp

    tel = tel._replace(
        late_shards=tel.late_shards + jnp.int32(int(late_shards)),
        widened_windows=tel.widened_windows + jnp.int32(
            int(widened_windows)))
    return _replace_leaf(state, tel)


class StragglerMonitor:
    """Wires ``runtime.straggler``'s deadline accounting into the
    telemetry plane (ROADMAP item 1's signal).

    Feed per-shard (edge-node / device) arrival latencies each window
    via :meth:`observe`; it returns the present-mask from
    ``DeadlineTracker`` and accumulates a late-shard counter plus a
    widened-bound flag (a window published with absent shards has its
    bounds widened by the Eq. 9 ``1/α`` recalibration —
    ``straggler.calibrate_weights``). :meth:`fold_into` moves the
    accumulated deltas into a pipeline state's telemetry leaves, and
    the exposition layer reports the running totals either way."""

    def __init__(self, num_shards: int, cfg=None):
        from repro.runtime.straggler import DeadlineTracker, StragglerConfig

        self.cfg = cfg or StragglerConfig()
        self.tracker = DeadlineTracker(int(num_shards), self.cfg)
        self.late_shards_total = 0
        self.widened_windows_total = 0
        self._pending_late = 0
        self._pending_widened = 0

    def observe(self, shard_latencies) -> np.ndarray:
        """Record one window's per-shard latencies; returns the
        present-mask (all-true when below quorum — the tracker waits
        rather than bias hard)."""
        lat = np.asarray(shard_latencies, np.float64)
        present = self.tracker.observe(lat)
        late = int((~present).sum())
        self.late_shards_total += late
        self._pending_late += late
        if late > 0:
            self.widened_windows_total += 1
            self._pending_widened += 1
        return present

    def calibrate(self, weight: np.ndarray,
                  present: np.ndarray) -> np.ndarray:
        """Eq. 9 weight recalibration for the arrived shards (the
        widened-bound correction) — ``straggler.calibrate_weights``."""
        from repro.runtime.straggler import calibrate_weights

        return calibrate_weights(weight, present)

    def fold_into(self, state):
        """Apply the deltas accumulated since the last fold to a
        pipeline state's telemetry leaves; returns the (possibly
        unchanged) state."""
        late, widened = self._pending_late, self._pending_widened
        self._pending_late = self._pending_widened = 0
        return fold_stragglers(state, late, widened)
